"""End-to-end behaviour tests: the paper's full workflow at integration scale
+ the framework's public API surface."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PropGraph, build_di
from repro.graph import attach_random_attributes, paper_graph, random_uniform_graph


def test_paper_workflow_end_to_end():
    """§V pipeline on a graph1-regime graph (scaled): ingest → attributes →
    query → subgraph → analytics, all three backends agreeing."""
    src, dst = paper_graph("graph1", scale_down=100)  # 1000 edges
    rels_pool = [f"rel{i}" for i in range(50)]
    labels_pool = [f"lab{i}" for i in range(50)]
    rng = np.random.default_rng(0)

    masks = {}
    for be in ("arr", "list", "listd"):
        pg = PropGraph(backend=be).add_edges_from(src, dst)
        nodes = np.asarray(pg.graph.node_map)
        es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
        rng_b = np.random.default_rng(1)
        pg.add_node_labels(nodes, rng_b.choice(labels_pool, len(nodes)))
        pg.add_edge_relationships(nodes[es], nodes[ed], rng_b.choice(rels_pool, len(es)))
        vm = np.asarray(pg.query_labels(["lab1", "lab2", "lab3"]))
        em = np.asarray(pg.query_relationships(["rel7"]))
        masks[be] = (vm, em)
        sub, kept = pg.subgraph(labels=["lab1", "lab2", "lab3"], relationships=["rel7"])
        assert sub.m == len(kept)

    for be in ("list", "listd"):
        assert (masks[be][0] == masks["arr"][0]).all()
        assert (masks[be][1] == masks["arr"][1]).all()


def test_query_throughput_metric():
    """The §VII-B throughput metric (edges/s) is computable from our harness."""
    import time

    from repro.core import build_dip_arr
    from repro.core.dip_arr import query_any_matvec

    m = 200_000
    ents, attrs = attach_random_attributes(m, n_attrs=50, seed=0)
    store = build_dip_arr(ents, attrs, k=50, n=m)
    qmask = jnp.zeros(50, bool).at[jnp.arange(5)].set(True)
    query_any_matvec(store, qmask).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(5):
        query_any_matvec(store, qmask).block_until_ready()
    eps = 5 * m / (time.time() - t0)
    assert eps > 1e6  # ≥1M edges/s on 1 CPU core (paper: 8.5M on 8×128 cores)


def test_di_block_distribution_shapes():
    """DI arrays accept a dp sharding without resharding copies (1-dev mesh)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    src, dst = random_uniform_graph(4096, seed=0)
    g = build_di(src, dst)
    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P("data"))
    src_s = jax.device_put(g.src, sh)
    assert src_s.sharding == sh


def test_bfs_on_typed_subgraph():
    src = [0, 1, 2, 3, 0]
    dst = [1, 2, 3, 4, 3]
    pg = PropGraph("arr").add_edges_from(src, dst)
    pg.add_edge_relationships([0, 1, 2, 3, 0], [1, 2, 3, 4, 3],
                              ["a", "a", "b", "a", "b"])
    d = np.asarray(pg.bfs([0], relationships=["a"]))
    assert d[1] == 1 and d[2] == 2 and d[3] == -1 or d[3] > 0  # 3 unreachable via 'a' from 0->1->2 (edge 2->3 is 'b')
    # precise: path 0-a->1-a->2 (b blocks 2->3); 0-b->3 blocked
    assert d.tolist()[:5] == [0, 1, 2, -1, -1]
