"""Sparse rowwise table updates: equivalence with the dense reference,
duplicate-index handling, untouched-row preservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.sparse_tables import (
    dense_rowwise_update, init_rowwise_state, sparse_table_update,
)


def _setup(B=8, F=3, MH=2, V=50, D=4, seed=0):
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(rng.standard_normal((F, V, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, V, (B, F, MH)), jnp.int32)
    pulled = jnp.asarray(rng.standard_normal((B, F, MH, D)), jnp.float32)
    return tables, idx, pulled


def _dense_grad_from_pulled(idx, pulled, V):
    """Reference: scatter the pulled grads densely (what jax.grad would give)."""
    B, F, MH, D = pulled.shape
    dense = np.zeros((F, V, D), np.float32)
    for b in range(B):
        for f in range(F):
            for h in range(MH):
                dense[f, idx[b, f, h]] += np.asarray(pulled)[b, f, h]
    return jnp.asarray(dense)


def test_sparse_matches_dense_reference():
    tables, idx, pulled = _setup()
    acc = init_rowwise_state(tables)
    t_sp, a_sp = sparse_table_update(tables, acc, idx, pulled, lr=0.05)
    dense_grad = _dense_grad_from_pulled(idx, pulled, tables.shape[1])
    t_dn, a_dn = dense_rowwise_update(tables, acc, dense_grad, lr=0.05)
    np.testing.assert_allclose(np.asarray(t_sp), np.asarray(t_dn), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a_sp), np.asarray(a_dn), rtol=1e-5, atol=1e-6)


def test_untouched_rows_unchanged():
    tables, idx, pulled = _setup()
    acc = init_rowwise_state(tables)
    t_new, a_new = sparse_table_update(tables, acc, idx, pulled)
    touched = np.zeros(tables.shape[:2], bool)
    for b, f, h in np.ndindex(*idx.shape):
        touched[f, np.asarray(idx)[b, f, h]] = True
    np.testing.assert_array_equal(
        np.asarray(t_new)[~touched], np.asarray(tables)[~touched])
    assert (np.asarray(a_new)[~touched] == 0).all()


def test_duplicate_indices_accumulate():
    """The same row hit twice must see the SUM of its gradients (dense semantics)."""
    tables = jnp.ones((1, 10, 2), jnp.float32)
    acc = init_rowwise_state(tables)
    idx = jnp.asarray([[[3]], [[3]]], jnp.int32)          # (B=2, F=1, MH=1), same row
    pulled = jnp.asarray([[[[1.0, 0.0]]], [[[1.0, 0.0]]]], jnp.float32)
    t_new, _ = sparse_table_update(tables, acc, idx, pulled, lr=1.0)
    # g_row = [2, 0]; g2 = mean(4,0)=2; scale = 1/sqrt(2+eps); Δ = 2/sqrt(2) = √2
    exp = 1.0 - np.sqrt(2.0)
    assert np.asarray(t_new)[0, 3, 0] == pytest.approx(exp, rel=1e-4)
    assert np.asarray(t_new)[0, 3, 1] == pytest.approx(1.0)


def test_end_to_end_with_vjp():
    """Integration: pull gradients from the model's gather via jax.vjp and
    feed them to the sparse update — loss decreases."""
    from repro.models import dlrm

    cfg = dlrm.DLRMConfig(vocab_size=100, bot_mlp=(13, 16, 8, 4), embed_dim=4,
                          top_mlp=(16, 8, 1))
    params = dlrm.init_params(jax.random.PRNGKey(0), cfg)
    acc = init_rowwise_state(params["tables"])
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.standard_normal((16, 13)), jnp.float32)
    sparse_idx = jnp.asarray(rng.integers(0, 100, (16, 26, 1)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, 16), jnp.int32)

    def loss_from_rows(rows, p):
        # rows: (B, F, MH, D) gathered embeddings, mean over MH downstream
        s = jnp.mean(rows, axis=2)
        d = dlrm.mlp_stack(p["bot"], dense, final_act=True)
        inter = dlrm._interact(d, s)
        logit = dlrm.mlp_stack(p["top"], jnp.concatenate([d, inter], -1))[:, 0]
        y = labels.astype(jnp.float32)
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    losses = []
    for _ in range(12):
        rows = jnp.take(params["tables"][0], sparse_idx[:, 0], axis=0)  # placeholder
        gathered = jnp.stack(
            [jnp.take(params["tables"][f], sparse_idx[:, f], axis=0)
             for f in range(26)], axis=1)  # (B, F, MH, D)
        l, pull = jax.vjp(lambda r: loss_from_rows(r, params), gathered)
        (g_rows,) = pull(jnp.float32(1.0))
        params["tables"], acc = sparse_table_update(
            params["tables"], acc, sparse_idx, g_rows, lr=0.5)
        losses.append(float(l))
    assert losses[-1] < losses[0]
