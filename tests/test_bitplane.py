"""Bit-packed mask plane (core.bitplane): layout, invariants, end-to-end parity.

Four layers:

* **Round-trip**: host and device pack/unpack agree with each other and with
  ``np.packbits(bitorder='little')`` — property-style over sizes straddling
  word boundaries (hypothesis-driven when the package is present, a seeded
  sweep otherwise, same assertions either way).
* **Tail-padding invariant**: every mutator path that produces packed words
  (bulk build, incremental ``insert``, overlay deltas/tombstones,
  compaction, sharded placement) leaves the padding bits of the last word
  ZERO — the property word-space algebra relies on.
* **Packed ≡ byte parity**: match / khop / components / overlay views give
  bitwise-identical results with ``REPRO_PG_BYTE_MASKS`` forced on and off,
  across all three backends and the mesh path, plus a subprocess rerun at
  P=8 virtual devices (modeled on ``test_shard_pg``).
* **Executor accounting**: the ``pg_exec_fused_masks`` counter counts EDGE
  mask steps riding the fused batched launch (regression: they used to run
  standalone), and the wire codec round-trips packed masks bitwise.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PropGraph, bitplane, dip_arr
from repro.graph import random_uniform_graph
from repro.launch.mesh import make_entity_mesh

BACKENDS = ("arr", "list", "listd")
SIZES = (0, 1, 5, 31, 32, 33, 63, 64, 100, 257, 1000, 4095, 4096, 4097)


def _tail_zero(words: np.ndarray, n: int) -> bool:
    """True iff every bit for entities ≥ n is zero (rows may batch)."""
    words = np.asarray(words)
    w = bitplane.n_words(n)
    if words.shape[-1] > w:  # padded word axis (sharded planes)
        if np.any(words[..., w:]):
            return False
        words = words[..., :w]
    rem = n % bitplane.WORD
    if w == 0 or rem == 0:
        return True
    return not np.any(words[..., w - 1] >> rem)


# ------------------------------------------------------------- round-trips
def _check_roundtrip(bits: np.ndarray) -> None:
    n = bits.shape[-1]
    host = bitplane.pack_bits_host(bits)
    # little-endian layout contract: packbits bytes == the words' byte view
    ref8 = np.packbits(bits, axis=-1, bitorder="little")
    assert np.array_equal(
        np.ascontiguousarray(host).view(np.uint8)[..., : ref8.shape[-1]], ref8)
    assert _tail_zero(host, n)
    assert np.array_equal(bitplane.unpack_bits_host(host, n), bits)
    dev = np.asarray(bitplane.pack_mask(jnp.asarray(bits)))
    assert np.array_equal(dev, host)  # device layout == host layout
    assert np.array_equal(
        np.asarray(bitplane.unpack_mask(jnp.asarray(host), n)), bits)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.booleans(), min_size=0, max_size=300))
    def test_roundtrip_hypothesis(bits):
        _check_roundtrip(np.asarray(bits, bool))

except ImportError:  # seeded sweep with the same assertions

    @pytest.mark.parametrize("n", SIZES)
    def test_roundtrip_sweep(n):
        rng = np.random.default_rng(n + 1)
        for density in (0.0, 0.3, 1.0):
            _check_roundtrip(rng.random(n) < density)


def test_roundtrip_2d():
    rng = np.random.default_rng(3)
    bits = rng.random((5, 100)) < 0.4
    packed = bitplane.pack_bits_host(bits)
    assert packed.shape == (5, bitplane.n_words(100))
    assert np.array_equal(bitplane.unpack_bits_host(packed, 100), bits)
    assert np.array_equal(np.asarray(bitplane.pack_mask(jnp.asarray(bits))),
                          packed)


def test_or_reduce_matches_bool_any():
    rng = np.random.default_rng(9)
    bits = rng.random((7, 130)) < 0.2
    words = bitplane.pack_mask(jnp.asarray(bits))
    got = np.asarray(bitplane.unpack_mask(bitplane.or_reduce(words), 130))
    assert np.array_equal(got, bits.any(axis=0))


# ------------------------------------------------- tail bits after mutators
@pytest.mark.parametrize("n", (1, 31, 33, 100, 1000))
def test_tail_zero_dip_arr_build_and_insert(n):
    rng = np.random.default_rng(n)
    k = 6
    ent = rng.integers(0, n, 3 * n)
    att = rng.integers(0, k, 3 * n)
    dip = dip_arr.build_dip_arr_host(ent, att, k=k, n=n, packed=True)
    assert dip.packed and _tail_zero(dip.bitmap, n)
    # incremental insert, including out-of-range ids (dropped, not wrapped)
    dip2 = dip_arr.insert(dip, np.array([0, n - 1, n, n + 31]),
                          np.array([1, 2, 3, 4]))
    assert _tail_zero(dip2.bitmap, n)
    dev = dip_arr.build_dip_arr(ent, att, k=k, n=n, packed=True)
    assert _tail_zero(dev.bitmap, n)


def test_tail_zero_propgraph_mutators_and_compaction():
    rng = np.random.default_rng(4)
    n, m = 333, 900  # 333 % 32 != 0 → real padding bits to corrupt
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)

    def planes(pg):
        out = []
        for store in (pg._vstore, pg._estore):
            if store is None:
                continue
            host = getattr(store, "_host", None)
            if host is not None and getattr(host, "packed", False):
                out.append((host.bitmap, host.n))
            dev = getattr(store, "_store", None)
            if dev is not None and getattr(dev, "packed", False):
                out.append((np.asarray(dev.bitmap), dev.n))
        return out

    pg = PropGraph(backend="arr").add_edges_from(src, dst)
    pg.add_node_labels(np.arange(0, n, 3), "a")
    pg.add_edge_relationships(src[::2], dst[::2], "r")
    for plane, size in planes(pg):
        assert _tail_zero(plane, size)
    # overlay: delta edges first (endpoints must be alive), then tombstones
    pg.insert_edges(src[:5], np.roll(dst[:5], 1))
    pg.delete_vertices(np.arange(0, n, 41))
    pg.delete_edges(src[::97], dst[::97])
    pg.add_node_labels(np.arange(1, n, 50), "b")
    d = pg._vstore._delta
    if d.size:
        ids = pg._vstore.known_ids(["b"])
        words = d.mask_words(ids, pg._vstore.out_n)
        assert _tail_zero(words, pg._vstore.out_n)
    for plane, size in planes(pg):
        assert _tail_zero(plane, size)
    pg.compact()
    pg.query_labels(["a"])  # force the compacted stores to materialize
    pg.query_relationships(["r"])
    assert planes(pg), "compacted arr graph should hold packed planes"
    for plane, size in planes(pg):
        assert _tail_zero(plane, size)


def test_tail_zero_sharded_plane():
    mesh = make_entity_mesh()
    rng = np.random.default_rng(5)
    n, m = 271, 800
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    pg = PropGraph(backend="arr", mesh=mesh).add_edges_from(src, dst)
    pg.add_node_labels(np.arange(0, n, 2), "x")
    ss = pg._vstore.finalize_sharded()
    assert ss.packed
    assert _tail_zero(np.asarray(ss.bitmap), ss.n)


# --------------------------------------------------- packed ≡ byte parity
def _build_graph(backend, mesh=None, m=1000, seed=11):
    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice(["p", "q", "r"], len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(
        nodes[es], nodes[ed], rng.choice(["f", "g"], len(es)))
    pg.add_node_properties(
        "age", nodes, rng.integers(0, 90, len(nodes)).astype(np.int32))
    pg.delete_vertices(nodes[:: max(len(nodes) // 10, 1)])
    return pg


def _parity_surfaces(pg):
    """Deterministic result bundle covering match/khop/components/overlay."""
    out = []
    res = pg.match("(a:p {age > 20})-[:f]->(b:q|r)")
    out += [res.vertex_mask, res.edge_mask, *res.node_masks, *res.edge_masks]
    res = pg.match("(a:p)-[:f*1..3]->(b)")
    out += [res.vertex_mask, res.edge_mask]
    nodes = np.asarray(pg.graph.node_map)
    out.append(pg.khop(nodes[:3], 2, pattern="(a)-[:f]->(b)"))
    out.append(pg.components("(a)-[:f|g]->(b)"))
    snap = pg.snapshot()  # overlay view: snapshot isolation surface
    out.append(snap.query_labels(["p"]))
    out.append(snap.match("(a:q)-[:g]->(b)").vertex_mask)
    return [np.asarray(x) for x in out]


@pytest.mark.parametrize("backend", BACKENDS)
def test_packed_equals_byte(backend):
    results = {}
    for packed in (True, False):
        with bitplane.byte_masks(not packed):
            results[packed] = _parity_surfaces(_build_graph(backend))
    for a, b in zip(results[True], results[False]):
        assert np.array_equal(a, b)


def test_packed_equals_byte_mesh():
    mesh = make_entity_mesh()
    results = {}
    for packed in (True, False):
        with bitplane.byte_masks(not packed):
            results[packed] = _parity_surfaces(_build_graph("arr", mesh=mesh))
    for a, b in zip(results[True], results[False]):
        assert np.array_equal(a, b)


def test_env_flag_forces_byte_store():
    with bitplane.byte_masks():
        pg = _build_graph("arr")
        assert not pg._vstore.packed
    pg = _build_graph("arr")
    assert pg._vstore.packed  # default this release


def test_eight_virtual_devices_subprocess():
    """P=8 parity: packed ≡ byte across backends and the mesh, in a fresh
    interpreter with 8 virtual CPU devices (word-axis sharding + the packed
    OR all-reduce frontier actually cross shard boundaries)."""
    code = """
import numpy as np
import jax
assert jax.device_count() == 8, jax.device_count()
import tests.test_bitplane as tb
from repro.core import bitplane
from repro.launch.mesh import make_entity_mesh

for backend in tb.BACKENDS:
    results = {}
    for packed in (True, False):
        with bitplane.byte_masks(not packed):
            results[packed] = tb._parity_surfaces(tb._build_graph(backend))
    for a, b in zip(results[True], results[False]):
        assert np.array_equal(a, b), backend
mesh = make_entity_mesh()
results = {}
for packed in (True, False):
    with bitplane.byte_masks(not packed):
        results[packed] = tb._parity_surfaces(tb._build_graph("arr", mesh=mesh))
for a, b in zip(results[True], results[False]):
    assert np.array_equal(a, b), "mesh"
print("P8 PARITY OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "P8 PARITY OK" in out.stdout


# -------------------------------------------------- executor fused counter
def test_edge_masks_ride_fused_batched_launch():
    """Regression: plans with ≥2 edge relationship masks fuse them into one
    batched launch — ``pg_exec_fused_masks`` counts node AND edge steps."""
    from repro.obs import metrics

    pg = _build_graph("arr")
    pattern = "(a:p)-[:f]->(b:q)-[:g]->(c:r)"  # 3 node + 2 edge mask steps
    plan_fused = pg.match(pattern).plan  # warm: also asserts it executes
    assert plan_fused.fused_node_slots == (0, 1, 2)
    assert plan_fused.fused_edge_slots == (0, 1)
    fused = metrics.GLOBAL.counter("pg_exec_fused_masks")
    masks = metrics.GLOBAL.counter("pg_exec_mask_steps")
    prev_enabled = metrics.set_enabled(True)
    f0, m0 = fused.value(), masks.value()
    try:
        pg.match(pattern)
    finally:
        metrics.set_enabled(prev_enabled)
    assert masks.value() - m0 == 5
    assert fused.value() - f0 == 5  # all five steps fused, edges included


# ------------------------------------------------------- wire round-trip
def test_wire_packed_masks_bitwise():
    from repro.service import wire

    pg = _build_graph("arr")
    res = pg.match("(a:p)-[:f]->(b)")
    meta, arrays = wire.result_to_wire(res)
    assert any(isinstance(a, wire.PackedMask) for a in arrays)
    frame = wire.encode_msg(dict(meta, op="match_result"), arrays)
    # PackedMask blobs must be byte-identical to the generic bool path
    plain = [np.asarray(x) if not isinstance(x, wire.PackedMask)
             else bitplane.unpack_bits_host(x.words, x.n) for x in arrays]
    assert frame == wire.encode_msg(dict(meta, op="match_result"), plain)
    import socket

    a, b = socket.socketpair()
    try:
        wire.send_msg(a, dict(meta, op="match_result"), arrays)
        hdr, arrs = wire.recv_msg(b)
    finally:
        a.close()
        b.close()
    got = wire.wire_to_result({"vars": meta["vars"]}, arrs)
    assert np.array_equal(got.vertex_mask, np.asarray(res.vertex_mask))
    assert np.array_equal(got.edge_mask, np.asarray(res.edge_mask))
    for k, v in res.bindings().items():
        assert np.array_equal(got.bindings()[k], np.asarray(v))
