"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finite values.  Plus transformer-specific behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch
from repro.launch.train import make_smoke_step


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_arch_smoke_train_step(arch_id):
    """Every assigned architecture: instantiate reduced config, run one real
    optimization step, assert finite loss and param updates."""
    state, step_fn, cfg = make_smoke_step(arch_id, batch=4, seq=32)
    (params, opt), metrics = step_fn(state, 0)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, loss)
    assert float(metrics["grad_norm"]) > 0
    leaves = jax.tree.leaves(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves), arch_id


def test_lm_decode_matches_forward():
    from repro.models import transformer as T

    cfg = get_arch("gemma2-9b").smoke_config()
    key = jax.random.PRNGKey(0)
    p = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    h, _ = T.forward(p, toks, cfg)
    full_logits = np.asarray(T._logits(p, h, cfg), np.float32)
    cache = T.init_cache(cfg, 2, 16)
    dec = jax.jit(T.decode_step, static_argnames="cfg")
    outs = []
    for t in range(12):
        lg, cache = dec(p, cache, toks[:, t: t + 1], cfg)
        outs.append(np.asarray(lg[:, 0], np.float32))
    err = np.abs(np.stack(outs, 1) - full_logits).max()
    assert err < 5e-3, err


def test_lm_causality():
    """Changing a future token must not change past logits."""
    from repro.models import transformer as T

    cfg = get_arch("qwen2-72b").smoke_config()
    p = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    h1, _ = T.forward(p, toks, cfg)
    toks2 = toks.at[0, 10].set((toks[0, 10] + 1) % cfg.vocab)
    h2, _ = T.forward(p, toks2, cfg)
    assert np.allclose(np.asarray(h1[:, :10], np.float32),
                       np.asarray(h2[:, :10], np.float32), atol=1e-5)


def test_attention_impl_agreement():
    from repro.nn.attention import attention

    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 16)) * 0.4
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16)) * 0.4
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    for kw in (dict(causal=True), dict(causal=True, window=16),
               dict(causal=False, cap=30.0)):
        a = attention(q, k, v, impl="direct", **kw)
        b = attention(q, k, v, impl="chunked", chunk=16, **kw)
        c = attention(q, k, v, impl="flash", **kw) if kw.get("window", 1) else None
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_moe_group_and_split_invariance():
    from repro.nn.moe import init_moe, moe_ffn

    key = jax.random.PRNGKey(0)
    p = init_moe(key, 16, 32, 4)
    x = jax.random.normal(key, (64, 16))
    o1, _ = moe_ffn(p, x, top_k=2, n_groups=1, capacity_factor=8.0)
    o2, _ = moe_ffn(p, x, top_k=2, n_groups=8, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_moe_capacity_drops():
    """Low capacity must drop tokens (zeros contribution), not corrupt others."""
    from repro.nn.moe import init_moe, moe_ffn

    key = jax.random.PRNGKey(0)
    p = init_moe(key, 8, 16, 2)
    x = jax.random.normal(key, (32, 8))
    o_lo, _ = moe_ffn(p, x, top_k=1, capacity_factor=0.25)
    o_hi, _ = moe_ffn(p, x, top_k=1, capacity_factor=8.0)
    # dropped rows are exactly zero; surviving rows match the high-capacity run
    drop = np.abs(np.asarray(o_lo)).sum(-1) == 0
    assert drop.any()
    np.testing.assert_allclose(np.asarray(o_lo)[~drop], np.asarray(o_hi)[~drop], atol=1e-5)


def test_gemma2_softcap_bounds_attn_logits():
    from repro.nn.layers import softcap

    x = jnp.asarray(np.linspace(-1000, 1000, 101), jnp.float32)
    y = np.asarray(softcap(x, 50.0))
    assert (np.abs(y) <= 50.0 + 1e-4).all()
    assert np.allclose(y[50], 0.0, atol=1e-3)
