"""DI structure: invariants (hypothesis property tests) + behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests are optional off-CI
from hypothesis import given, settings, strategies as st

from repro.core import build_di, build_reverse_di, degrees, edge_lookup, neighbors_padded

edges_strategy = st.integers(min_value=1, max_value=300)
seed_strategy = st.integers(min_value=0, max_value=2**31 - 1)


def _random_edges(m, seed, pool=None):
    rng = np.random.default_rng(seed)
    pool = pool or max(2, m)
    return rng.integers(0, pool, m), rng.integers(0, pool, m)


@settings(max_examples=50, deadline=None)
@given(m=edges_strategy, seed=seed_strategy)
def test_di_invariants(m, seed):
    """SEG monotone with seg[0]=0, seg[n]=m; SRC sorted; DST sorted per-run;
    node_map strictly increasing; degrees consistent."""
    src, dst = _random_edges(m, seed)
    g = build_di(src, dst)
    seg = np.asarray(g.seg)
    s, d = np.asarray(g.src), np.asarray(g.dst)
    assert seg[0] == 0 and seg[-1] == g.m and (np.diff(seg) >= 0).all()
    assert (np.diff(s) >= 0).all()
    for u in np.unique(s):
        adj = d[seg[u]: seg[u + 1]]
        assert (np.diff(adj) >= 0).all(), "adjacency list not sorted"
        assert (s[seg[u]: seg[u + 1]] == u).all()
    nm = np.asarray(g.node_map)
    assert (np.diff(nm) > 0).all()
    out_deg, in_deg = degrees(g)
    assert int(jnp.sum(out_deg)) == g.m and int(jnp.sum(in_deg)) == g.m


@settings(max_examples=30, deadline=None)
@given(m=edges_strategy, seed=seed_strategy)
def test_di_roundtrip_edges(m, seed):
    """The (src, dst) multiset (deduped) survives construction."""
    src, dst = _random_edges(m, seed)
    g = build_di(src, dst)
    nm = np.asarray(g.node_map)
    got = {(int(nm[a]), int(nm[b])) for a, b in zip(np.asarray(g.src), np.asarray(g.dst))}
    expect = set(zip(src.tolist(), dst.tolist()))
    assert got == expect


@settings(max_examples=30, deadline=None)
@given(m=edges_strategy, seed=seed_strategy)
def test_edge_lookup_total(m, seed):
    src, dst = _random_edges(m, seed)
    g = build_di(src, dst)
    idx = np.asarray(edge_lookup(g, g.src, g.dst))
    assert (idx == np.arange(g.m)).all()


def test_edge_lookup_missing():
    g = build_di([0, 1, 2], [1, 2, 0])
    assert int(edge_lookup(g, jnp.array([0]), jnp.array([2]))[0]) == -1


def _edge_lookup_scan_oracle(g, eu, ev):
    """The O(m·q) full scan edge_lookup replaces — the regression anchor."""
    s, d = np.asarray(g.src), np.asarray(g.dst)
    out = np.full(len(eu), -1, np.int32)
    for i, (u, v) in enumerate(zip(eu, ev)):
        hits = np.flatnonzero((s == u) & (d == v))
        if hits.size:
            out[i] = hits[0]
    return out


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_edge_lookup_equals_full_scan(seed):
    """Pin the cached-max_deg binary search bitwise to the O(m·q) scan,
    over present, absent and out-of-window pairs."""
    src, dst = _random_edges(250, seed, pool=40)
    g = build_di(src, dst)
    rng = np.random.default_rng(seed + 99)
    eu = rng.integers(0, g.n, 400).astype(np.int32)
    ev = rng.integers(0, g.n, 400).astype(np.int32)
    got = np.asarray(edge_lookup(g, jnp.asarray(eu), jnp.asarray(ev)))
    assert (got == _edge_lookup_scan_oracle(g, eu, ev)).all()


def test_max_deg_cached_and_propagated():
    """build_di/build_reverse_di stash the widest adjacency window (the
    sort-once statistic edge_lookup sizes its binary search with)."""
    src, dst = _random_edges(200, 5, pool=30)
    g = build_di(src, dst)
    seg = np.asarray(g.seg)
    assert g.max_deg == int(np.max(seg[1:] - seg[:-1]))
    rg = build_reverse_di(g)
    rseg = np.asarray(rg.seg)
    assert rg.max_deg == int(np.max(rseg[1:] - rseg[:-1]))
    # a hand-built graph without the cache still looks up correctly
    import dataclasses

    g_unknown = dataclasses.replace(g, max_deg=-1)
    a = np.asarray(edge_lookup(g, g.src, g.dst))
    b = np.asarray(edge_lookup(g_unknown, g.src, g.dst))
    assert (a == b).all() and (a == np.arange(g.m)).all()


def test_neighbors_padded():
    g = build_di([0, 0, 0, 1], [1, 2, 3, 2], normalize=False, n=4)
    nbrs, valid = neighbors_padded(g, jnp.array(0), max_deg=5)
    assert nbrs[:3].tolist() == [1, 2, 3] and valid.tolist() == [True] * 3 + [False] * 2


def test_reverse_di():
    g = build_di([0, 1, 2], [1, 2, 0], normalize=False, n=3)
    r = build_reverse_di(g)
    # in-neighbors of vertex 1 = {0}
    seg = np.asarray(r.seg)
    assert np.asarray(r.dst)[seg[1]: seg[2]].tolist() == [0]


def test_dedupe_multiedge():
    g = build_di([0, 0, 0], [1, 1, 2])
    assert g.m == 2  # (0,1) structural edge kept once (Fig. 1 semantics)
