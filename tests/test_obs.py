"""Observability layer: metrics registry, Prometheus exposition, trace
spans, and EXPLAIN ANALYZE (src/repro/obs/, docs/ARCHITECTURE.md §13).

The contracts under test:

* the registry is get-or-create on (name, labels) identity, type-checked,
  and counters are atomic under concurrent increments — including through
  ``Service._bump``, whose old dict-based counters this registry replaced
  (the lost-update audit);
* the enable flag is a real off switch: disabled counters/histograms do
  not move (gauges deliberately still do — they record state, not
  events), and ``set_enabled`` returns the previous value so guards can
  restore it;
* ``render_prometheus`` emits text that ``parse_prometheus`` reads back
  exactly — legacy short names normalize to ``pg_service_*_total``,
  explicit ``pg_*`` names pass through, histograms expose cumulative
  ``le`` buckets — and the exposition always agrees with
  ``Service.stats()``;
* traces are explicit span trees that serialize/rehydrate losslessly, and
  the ``TraceBuffer`` rings stay bounded;
* ``explain_analyze`` separates compile from steady-state execution, and
  ``match(profile=True)`` returns a result bitwise-identical to plain
  ``match()``;
* ``LRUCache.stats()`` keeps its size/capacity/eviction fields (the
  exposition mirrors them into gauges).
"""
import threading

import numpy as np
import pytest

from repro.launch.pgserve import build_tenant_graph
from repro.obs import (
    Span,
    Trace,
    TraceBuffer,
    new_trace_id,
    parse_prometheus,
    render_prometheus,
    set_enabled,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.service import Service, ServiceConfig
from repro.service.cache import LRUCache

PATTERN = "(a:l1|l2)-[:follows]->(b:l3)"


@pytest.fixture
def pg():
    return build_tenant_graph("arr", 600, seed=11)


# ---------------------------------------------------------------- registry
def test_registry_get_or_create_identity():
    reg = MetricsRegistry()
    c1 = reg.counter("hits", "help text")
    c2 = reg.counter("hits")
    assert c1 is c2
    # labels are part of the identity, order-insensitive
    a = reg.counter("pg_wire_frames", dir="sent")
    b = reg.counter("pg_wire_frames", dir="received")
    assert a is not b
    assert reg.counter("pg_wire_frames", dir="sent") is a
    h1 = reg.histogram("lat_ms", op="query", tier="x")
    h2 = reg.histogram("lat_ms", tier="x", op="query")
    assert h1 is h2


def test_registry_rejects_type_mismatch():
    reg = MetricsRegistry()
    reg.counter("thing")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("thing")


def test_registry_snapshot_keys():
    reg = MetricsRegistry()
    reg.counter("plain").inc(3)
    reg.gauge("occupancy", tier="result").set(7)
    snap = reg.snapshot()
    assert snap["plain"] == 3
    assert snap["occupancy{tier=result}"] == 7


def test_counter_concurrent_increments_exact():
    """The Service._bump audit: N threads × K increments lose nothing."""
    reg = MetricsRegistry()
    threads_n, per_thread = 8, 2_000

    def worker():
        for _ in range(per_thread):
            reg.counter("submitted").inc()

    ts = [threading.Thread(target=worker) for _ in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("submitted").value() == threads_n * per_thread


def test_service_bump_concurrent_exact():
    """Same audit at the Service layer: _bump rides the registry now."""
    svc = Service.__new__(Service)  # counters only — no scheduler needed
    svc.metrics = MetricsRegistry()
    svc._counters = {}

    def worker():
        for _ in range(1_000):
            svc._bump("submitted")

    ts = [threading.Thread(target=worker) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert svc.metrics.counter("submitted").value() == 8_000


def test_histogram_buckets_cumulative():
    h = Histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    snap = h.value()
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(555.5)
    assert snap["buckets"] == {1.0: 1, 10.0: 2, 100.0: 3}  # +Inf holds the 4th


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=(10.0, 1.0))


# ------------------------------------------------------------- enable flag
def test_disabled_metrics_do_not_move():
    c, h, g = Counter("c"), Histogram("h"), Gauge("g")
    prev = set_enabled(False)
    try:
        assert prev is True  # suite default
        c.inc(5)
        h.observe(1.0)
        g.set(3)
        assert c.value() == 0
        assert h.value()["count"] == 0
        assert g.value() == 3  # gauges record state: deliberately ungated
    finally:
        set_enabled(prev)
    c.inc(5)
    assert c.value() == 5


def test_set_enabled_returns_previous():
    try:
        assert set_enabled(False) is True  # suite default: on
        assert set_enabled(True) is False
        assert set_enabled(True) is True
    finally:
        set_enabled(True)


# -------------------------------------------------------------- exposition
def test_render_parse_roundtrip_and_name_normalization():
    reg = MetricsRegistry()
    reg.counter("result_hits").inc(4)           # legacy short name
    reg.counter("pg_wire_frames", dir="sent").inc(9)  # explicit pg_ name
    reg.gauge("pg_cache_size", tier="plan").set(3)
    reg.histogram("pg_wire_op_ms", op="query",
                  buckets=(1.0, 10.0)).observe(2.5)
    text = render_prometheus(reg)
    assert "# TYPE pg_service_result_hits_total counter" in text
    parsed = parse_prometheus(text)
    assert parsed["pg_service_result_hits_total"] == 4
    assert parsed['pg_wire_frames_total{dir="sent"}'] == 9
    assert parsed['pg_cache_size{tier="plan"}'] == 3
    assert parsed['pg_wire_op_ms_bucket{op="query",le="1"}'] == 0
    assert parsed['pg_wire_op_ms_bucket{op="query",le="10"}'] == 1
    assert parsed['pg_wire_op_ms_bucket{op="query",le="+Inf"}'] == 1
    assert parsed['pg_wire_op_ms_count{op="query"}'] == 1
    assert parsed['pg_wire_op_ms_sum{op="query"}'] == pytest.approx(2.5)


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("pg_thing_total notanumber\n")
    with pytest.raises(ValueError):
        parse_prometheus("   \x00garbage 1\n")


def test_service_exposition_agrees_with_stats(pg):
    with Service() as svc:
        svc.add_graph("g", pg)
        for _ in range(3):
            svc.query("g", PATTERN)
        st = svc.stats()
        parsed = parse_prometheus(svc.metrics_text())
    assert parsed["pg_service_submitted_total"] == st["submitted"] == 3
    assert parsed["pg_service_completed_total"] == st["completed"]
    # cache occupancy gauges mirrored in at render time
    assert parsed['pg_cache_size{tier="result"}'] == st["result_cache"]["size"]
    assert (parsed['pg_cache_hits_total{tier="result"}']
            == st["result_cache"]["hits"])


# ------------------------------------------------------------------- traces
def test_span_tree_and_serialization():
    tr = Trace("query", trace_id=new_trace_id())
    with tr.span("plan") as sp:
        sp.annotate(steps=3)
        with sp.span("inner"):
            pass
    tr.add_span("execute", 1.0, 1.25, batch_size=4)
    d = tr.finish().to_dict()
    assert d["trace_id"] == tr.trace_id
    names = [s["name"] for s in d["spans"]]
    assert names == ["plan", "execute"]
    assert d["spans"][0]["attrs"] == {"steps": 3}
    assert d["spans"][0]["spans"][0]["name"] == "inner"
    assert d["spans"][1]["ms"] == pytest.approx(250.0)
    back = Trace.from_dict(d)
    assert back.trace_id == tr.trace_id
    assert back.to_dict()["spans"][1]["ms"] == pytest.approx(250.0)


def test_span_context_manager_records_error():
    tr = Trace()
    with pytest.raises(RuntimeError):
        with tr.span("execute") as sp:
            raise RuntimeError("boom")
    assert sp.t1 is not None
    assert sp.attrs["error"] == "RuntimeError"


def test_trace_buffer_ring_bounds_and_slow_mirror():
    buf = TraceBuffer(maxlen=4, slow_ms=0.0, slow_maxlen=2)
    pushed = [Trace(trace_id=f"t{i:02d}") for i in range(7)]
    for t in pushed:
        buf.push(t)
    assert len(buf) == 4
    assert [t["trace_id"] for t in buf.traces()] == ["t03", "t04", "t05", "t06"]
    # slow_ms=0 mirrors everything; the slow ring keeps its own bound
    assert [t["trace_id"] for t in buf.slow()] == ["t05", "t06"]
    disabled = TraceBuffer(maxlen=0)
    disabled.push(Trace())
    assert len(disabled) == 0


def test_service_trace_ring_captures_span_stages(pg):
    cfg = ServiceConfig(slow_query_ms=0.0)
    with Service(config=cfg) as svc:
        svc.add_graph("g", pg)
        svc.query("g", PATTERN)   # cold: full pipeline
        svc.query("g", PATTERN)   # warm: submit fastpath result hit
        traces = svc.trace_log()
        slow = svc.slow_queries()
    assert len(traces) == 2
    cold_names = [s["name"] for s in traces[0]["spans"]]
    for stage in ("parse", "batch.wait", "cache", "plan", "execute"):
        assert stage in cold_names, cold_names
    warm = traces[1]["spans"]
    cache = next(s for s in warm if s["name"] == "cache")
    assert cache["attrs"]["hit"] is True
    assert len(slow) == 2  # slow_ms=0 captures everything


# ----------------------------------------------------------- explain analyze
def test_explain_analyze_cold_then_warm(pg):
    import jax

    pattern = "(a:l4)-[:likes]->(b:l5)"
    jax.clear_caches()  # guarantee the first run really compiles
    rep = pg.explain_analyze(pattern)
    assert rep.parse_ms >= 0 and rep.plan_ms >= 0
    assert rep.total_first_ms >= rep.steady_ms >= 0
    assert rep.cold and rep.compile_ms > 0
    rep2 = pg.explain_analyze(pattern)  # same jit cache: compile already paid
    # warm compile share collapses; a loose ratio (not the exact cold flag)
    # keeps host-timing jitter from flaking the assertion
    assert rep2.compile_ms < rep.compile_ms / 10
    d = rep.to_dict()
    assert {"compile_ms", "execute_ms", "masks_ms"} <= set(d)
    txt = rep.describe()
    assert "analyze" in txt and "compile" in txt


def test_match_profile_returns_identical_result(pg):
    ref = pg.match(PATTERN)
    got, rep = pg.match(PATTERN, profile=True)
    assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all()
    assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all()
    assert rep.steady_ms >= 0


# ----------------------------------------------------------------- lru cache
def test_lru_cache_stats_fields_regression():
    c = LRUCache(maxsize=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1
    assert c.get("zzz") is None
    c.put("c", 3)  # evicts b (a was refreshed by the hit)
    st = c.stats()
    assert st == {"size": 2, "maxsize": 2, "hits": 1, "misses": 1,
                  "evictions": 1}
    assert c.get("b") is None  # b was the eviction victim
