"""Fault tolerance: checkpoint atomicity, bitwise restart, elastic restore,
straggler-tolerant accumulation."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.ft import FailureInjector, TrainController, accumulate_grads


def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)), "step_count": jnp.zeros((), jnp.int32)}


def _toy_step(state, step):
    w = state["w"]
    w = w - 0.01 * (w + step * 0.001)
    return {"w": w, "step_count": state["step_count"] + 1}, {"loss": jnp.sum(w * w)}


def test_checkpoint_roundtrip(tmp_path):
    st = _toy_state()
    save(str(tmp_path), 7, st)
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, st)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(st["w"]))


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp directory is ignored and GC'd; only committed steps load."""
    st = _toy_state()
    save(str(tmp_path), 5, st)
    os.makedirs(tmp_path / "step_000000009.tmp")
    assert latest_step(str(tmp_path)) == 5
    CheckpointManager(str(tmp_path))  # GCs stale tmp
    assert not (tmp_path / "step_000000009.tmp").exists()


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _toy_state()
    for s in (1, 2, 3, 4):
        mgr.save_sync(s, st)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_bitwise_restart(tmp_path):
    """Crash at step k, restart, finish ⇒ identical final state to a fault-free
    run (deterministic step fn + step-addressed data contract)."""
    def run(fail):
        ckpt = CheckpointManager(str(tmp_path / ("a" if fail else "b")), keep=3)
        ctrl = TrainController(ckpt=ckpt, step_fn=_toy_step, ckpt_every=5)
        inj = FailureInjector([13]) if fail else None
        return ctrl.run(_toy_state(), 20, injector=inj)

    sa, sb = run(True), run(False)
    np.testing.assert_array_equal(np.asarray(sa["w"]), np.asarray(sb["w"]))
    assert int(sa["step_count"]) == 20


def test_async_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _toy_state()
    mgr.save_async(3, st)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 3


def test_elastic_restore_resharding(tmp_path):
    """Save on the default (1-device) layout, restore with an explicit
    sharding — the elastic path a rescheduled job takes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    st = {"w": jnp.arange(16.0).reshape(4, 4)}
    save(str(tmp_path), 1, st)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back = restore(str(tmp_path), 1, st, shardings=sh)
    assert back["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(st["w"]))


def test_accumulate_grads_drop_mask():
    """Dropping a microbatch renormalizes instead of biasing the mean."""
    params = {"w": jnp.ones((4,))}

    def loss(p, mb):
        return jnp.sum(p["w"] * mb)

    mbs = jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 3.0), jnp.full((4,), 100.0)])
    g_all, _ = accumulate_grads(loss, params, mbs)
    g_drop, _ = accumulate_grads(loss, params, mbs,
                                 drop_mask=jnp.array([True, True, False]))
    np.testing.assert_allclose(np.asarray(g_drop["w"]), np.full(4, 2.0))
    np.testing.assert_allclose(np.asarray(g_all["w"]), np.full(4, 104.0 / 3))


def test_training_restart_e2e(tmp_path):
    """End-to-end: real model training survives an injected failure."""
    from repro.launch.train import run_training

    state, losses = run_training(
        "gcn-cora", steps=12, batch=4, seq=16,
        ckpt_dir=str(tmp_path), ckpt_every=4, fail_at=(6,), log_every=100)
    assert len(losses) >= 12 and all(np.isfinite(l) for l in losses)
