"""Sharded ≡ single-device equivalence for the DIP stores (docs/ARCHITECTURE.md §7).

Two layers:

* In-process tests build a ``make_entity_mesh`` over however many devices the
  running interpreter has (1 under plain pytest — the mesh path must also be
  exact at P=1) and check every query surface bitwise against the default
  single-device path.
* ``test_eight_virtual_devices_subprocess`` re-runs the equivalence matrix in
  a fresh interpreter with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  so the multi-shard path (P=8, uneven entity counts, pmax mask combination)
  is exercised even when the parent process owns a single device.  CI sets
  the flag for the whole suite, making the in-process layer multi-device too.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PropGraph
from repro.core.io import load_propgraph, save_propgraph
from repro.graph import random_uniform_graph
from repro.launch.mesh import make_entity_mesh

BACKENDS = ("arr", "list", "listd")
PATTERNS = (
    "(a:l1|l2)-[:follows]->(b:l3)",
    "(a:l1|l2 {age > 30})-[:follows]->(b)",
    "(a)<-[:likes]-(b:l0|l4)",
    "(a:l1)-[:follows*1..3]->(b:l3)",  # var-length: frontier layers on mesh
)


_PAIR_CACHE = {}


def _build_pair(backend, mesh, m=1200, seed=7):
    """(single-device pg, mesh pg) with identical structure + attributes.
    Cached per (backend, mesh, m, seed) — graphs are immutable across the
    read-only tests; mutating tests must build their own."""
    key = (backend, id(mesh), m, seed)
    if key not in _PAIR_CACHE:
        _PAIR_CACHE[key] = _build_pair_uncached(backend, mesh, m, seed)
    return _PAIR_CACHE[key]


def _build_pair_uncached(backend, mesh, m, seed):
    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg1 = PropGraph(backend=backend).add_edges_from(src, dst)
    pg2 = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg1.graph.node_map)
    labels = rng.choice([f"l{i}" for i in range(12)], size=len(nodes))
    es, ed = np.asarray(pg1.graph.src), np.asarray(pg1.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es))
    ages = rng.integers(0, 90, len(nodes)).astype(np.int32)
    for pg in (pg1, pg2):
        pg.add_node_labels(nodes, labels)
        pg.add_edge_relationships(nodes[es], nodes[ed], rels)
        pg.add_node_properties("age", nodes, ages)
    return pg1, pg2


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


@pytest.fixture(scope="module")
def mesh():
    return make_entity_mesh()


@pytest.mark.parametrize("backend", BACKENDS)
def test_query_masks_bitwise_equal(backend, mesh):
    pg1, pg2 = _build_pair(backend, mesh)
    assert _eq(pg1.query_labels(["l1", "l2"]), pg2.query_labels(["l1", "l2"]))
    assert _eq(pg1.query_relationships(["follows"]),
               pg2.query_relationships(["follows"]))
    # degenerate queries short-circuit identically
    assert _eq(pg1.query_labels([]), pg2.query_labels([]))
    assert _eq(pg1.query_labels(["nope"]), pg2.query_labels(["nope"]))


# full pattern matrix on one backend, smoke pattern on the others — the mask
# materialization is the only backend-specific stage, and it is covered for
# every backend by the query tests above; this keeps compile time bounded
_MATCH_CASES = [("arr", p) for p in PATTERNS] + [
    ("list", PATTERNS[0]), ("listd", PATTERNS[0])
]


@pytest.mark.parametrize("backend,pattern", _MATCH_CASES)
def test_match_bitwise_equal(backend, pattern, mesh):
    pg1, pg2 = _build_pair(backend, mesh)
    r1, r2 = pg1.match(pattern), pg2.match(pattern)
    assert _eq(r1.vertex_mask, r2.vertex_mask)
    assert _eq(r1.edge_mask, r2.edge_mask)
    for m1, m2 in zip(r1.node_masks, r2.node_masks):
        assert _eq(m1, m2)
    for m1, m2 in zip(r1.edge_masks, r2.edge_masks):
        assert _eq(m1, m2)


def test_arr_impl_variants_agree(mesh):
    """All three DIP-ARR impls (scan / matvec / shard_map'd Pallas kernel)
    produce the same sharded mask."""
    pg1, pg2 = _build_pair("arr", mesh)
    ref = np.asarray(pg1.query_labels(["l1", "l2"]))
    for impl in ("matvec", "scan", "kernel"):
        assert _eq(ref, pg2.query_labels(["l1", "l2"], impl=impl)), impl
    with pytest.raises(ValueError, match="unknown impl"):
        pg2.query_labels(["l1", "l2"], impl="inverted")


def test_listd_single_device_impls_degrade(mesh):
    """budget/linked are single-device work layouts; the sharded path runs
    the inverted slot scan instead — same mask either way."""
    pg1, pg2 = _build_pair("listd", mesh)
    ref = np.asarray(pg1.query_labels(["l1"], impl="budget"))
    assert _eq(ref, pg2.query_labels(["l1"], impl="budget"))
    assert _eq(ref, pg2.query_labels(["l1"], impl="linked"))
    with pytest.raises(ValueError, match="unknown impl"):  # typos still fail
        pg2.query_labels(["l1"], impl="linkd")


def test_batched_fused_masks_equal(mesh):
    pg1, pg2 = _build_pair("arr", mesh)
    qs = [("l1", "l2"), ("l3",), ("l0", "l4", "l5")]
    assert _eq(pg1._vstore.query_any_batched(qs), pg2._vstore.query_any_batched(qs))


def test_incremental_insert_invalidates_sharded_store(mesh):
    """insert() after a query must rebuild the placed store, not serve the
    stale shard cache."""
    pg1, pg2 = _build_pair_uncached("list", mesh, 1200, 7)  # mutates: no cache
    before = np.asarray(pg2.query_labels(["extra"]))
    assert not before.any()
    nodes = np.asarray(pg1.graph.node_map)
    for pg in (pg1, pg2):
        pg.add_node_labels(nodes[:17], ["extra"] * 17)
    assert _eq(pg1.query_labels(["extra"]), pg2.query_labels(["extra"]))
    assert np.asarray(pg2.query_labels(["extra"])).sum() == 17


def test_save_load_onto_mesh(tmp_path, mesh):
    pg1, _ = _build_pair("arr", mesh)
    path = save_propgraph(str(tmp_path / "pg"), pg1)
    for backend in BACKENDS:
        pg2 = load_propgraph(path, backend=backend, mesh=mesh)
        assert _eq(pg1.query_labels(["l1", "l2"]), pg2.query_labels(["l1", "l2"]))
        assert _eq(pg1.match(PATTERNS[0]).edge_mask, pg2.match(PATTERNS[0]).edge_mask)


def test_submesh_sweep(mesh):
    """Every locale count P that fits the process (1, 2, 4, 8 ∩ available)
    yields the same masks — the bench_shard.py sweep's correctness basis."""
    import jax

    avail = len(jax.devices())
    pg1, _ = _build_pair("list", None)
    ref = np.asarray(pg1.query_labels(["l1", "l2"]))
    for p in (1, 2, 4, 8):
        if p > avail:
            continue
        sub = make_entity_mesh(p)
        _, pg2 = _build_pair("list", sub)
        assert _eq(ref, pg2.query_labels(["l1", "l2"])), p


_SUBPROCESS_SCRIPT = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, len(jax.devices())
import sys
sys.path.insert(0, {src!r})
from repro.core import PropGraph
from repro.graph import random_uniform_graph
from repro.launch.mesh import make_entity_mesh

rng = np.random.default_rng(7)
src, dst = random_uniform_graph(1200, seed=7)
mesh = make_entity_mesh()
assert mesh.devices.size == 8
for be in ("arr", "list", "listd"):
    pg1 = PropGraph(backend=be).add_edges_from(src, dst)
    pg2 = PropGraph(backend=be, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg1.graph.node_map)
    labels = rng.choice([f"l{{i}}" for i in range(12)], size=len(nodes))
    es, ed = np.asarray(pg1.graph.src), np.asarray(pg1.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es))
    for pg in (pg1, pg2):
        pg.add_node_labels(nodes, labels)
        pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    assert (np.asarray(pg1.query_labels(["l1", "l2"]))
            == np.asarray(pg2.query_labels(["l1", "l2"]))).all(), be
    assert (np.asarray(pg1.query_relationships(["follows"]))
            == np.asarray(pg2.query_relationships(["follows"]))).all(), be
    r1 = pg1.match("(a:l1|l2)-[:follows]->(b:l3)")
    r2 = pg2.match("(a:l1|l2)-[:follows]->(b:l3)")
    assert (np.asarray(r1.vertex_mask) == np.asarray(r2.vertex_mask)).all(), be
    assert (np.asarray(r1.edge_mask) == np.asarray(r2.edge_mask)).all(), be
print("SHARD8 OK")
"""


def test_eight_virtual_devices_subprocess():
    """The acceptance check proper: P=8 sharded ≡ single-device on all three
    backends, guaranteed 8 virtual devices via a fresh interpreter."""
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"  # skip accelerator probing in the child
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT.format(src=os.path.abspath(src_dir))],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARD8 OK" in proc.stdout
