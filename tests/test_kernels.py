"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

np.random.seed(7)


# ------------------------------------------------------------- bitmap_query
@pytest.mark.parametrize("k,n", [(1, 64), (50, 1000), (128, 4096), (7, 333)])
@pytest.mark.parametrize("density", [0.0, 0.05, 0.5])
def test_bitmap_query(k, n, density):
    from repro.kernels.bitmap_query import bitmap_query
    from repro.kernels.bitmap_query.ref import bitmap_query_ref

    bm = jnp.asarray((np.random.rand(k, n) < density).astype(np.int8))
    mask = jnp.asarray(np.random.rand(k) < 0.3)
    assert bool(jnp.all(bitmap_query(bm, mask) == bitmap_query_ref(bm, mask)))


def test_bitmap_query_all_selected():
    from repro.kernels.bitmap_query import bitmap_query
    from repro.kernels.bitmap_query.ref import bitmap_query_ref

    bm = jnp.asarray((np.random.rand(20, 500) < 0.1).astype(np.int8))
    mask = jnp.ones(20, bool)
    assert bool(jnp.all(bitmap_query(bm, mask) == bitmap_query_ref(bm, mask)))


@pytest.mark.parametrize("q,k,n", [(1, 50, 1000), (3, 50, 1000), (8, 128, 4096), (2, 7, 333)])
def test_bitmap_query_batched(q, k, n):
    """Multi-mask entry (planner fusion): one launch ≡ q single-mask calls."""
    from repro.kernels.bitmap_query import bitmap_query, bitmap_query_batched
    from repro.kernels.bitmap_query.ref import bitmap_query_batched_ref

    bm = jnp.asarray((np.random.rand(k, n) < 0.1).astype(np.int8))
    masks = jnp.asarray(np.random.rand(q, k) < 0.3)
    out = bitmap_query_batched(bm, masks)
    assert out.shape == (q, n)
    assert bool(jnp.all(out == bitmap_query_batched_ref(bm, masks)))
    for i in range(q):
        assert bool(jnp.all(out[i] == bitmap_query(bm, masks[i])))


# -------------------------------------------------------------------- seg_mm
@pytest.mark.parametrize("n,e,d", [(64, 256, 16), (500, 2000, 64), (37, 91, 8),
                                   (1000, 5000, 128)])
@pytest.mark.parametrize("weighted", [False, True])
def test_seg_mm(n, e, d, weighted):
    from repro.kernels.seg_mm import seg_mm
    from repro.kernels.seg_mm.ref import seg_mm_ref

    x = jnp.asarray(np.random.randn(n, d).astype(np.float32))
    src = jnp.asarray(np.random.randint(0, n, e).astype(np.int32))
    dst = jnp.asarray(np.sort(np.random.randint(0, n, e)).astype(np.int32))
    w = jnp.asarray(np.random.rand(e).astype(np.float32)) if weighted else None
    got = seg_mm(x, src, dst, n, edge_weight=w, nt=64, ec=64)
    exp = seg_mm_ref(x, src, dst, n, edge_weight=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-5)


def test_seg_mm_unsorted_dst():
    """ops.seg_mm sorts internally (reverse-DI layout build)."""
    from repro.kernels.seg_mm import seg_mm
    from repro.kernels.seg_mm.ref import seg_mm_ref

    n, e, d = 50, 200, 32
    x = jnp.asarray(np.random.randn(n, d).astype(np.float32))
    src = jnp.asarray(np.random.randint(0, n, e).astype(np.int32))
    dst = jnp.asarray(np.random.randint(0, n, e).astype(np.int32))  # unsorted
    got = seg_mm(x, src, dst, n, nt=32, ec=32)
    exp = seg_mm_ref(x, src, dst, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- flash_attention
@pytest.mark.parametrize(
    "b,sq,skv,hq,hkv,d,causal,window,cap",
    [
        (2, 128, 128, 4, 2, 32, True, None, None),
        (1, 256, 256, 8, 8, 64, True, 64, None),
        (1, 128, 128, 4, 1, 32, False, None, 50.0),
        (2, 128, 128, 8, 4, 64, True, 32, 30.0),
    ],
)
def test_flash_attention(b, sq, skv, hq, hkv, d, causal, window, cap):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(np.random.randn(b, sq, hq, d).astype(np.float32)) * 0.3
    k = jnp.asarray(np.random.randn(b, skv, hkv, d).astype(np.float32)) * 0.3
    v = jnp.asarray(np.random.randn(b, skv, hkv, d).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, window=window, cap=cap, bq=64, bkv=64)
    exp = flash_attention_ref(q, k, v, causal=causal, window=window, cap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(np.random.randn(1, 128, 4, 32), jnp.bfloat16) * 0.3
    k = jnp.asarray(np.random.randn(1, 128, 2, 32), jnp.bfloat16) * 0.3
    v = jnp.asarray(np.random.randn(1, 128, 2, 32), jnp.bfloat16)
    got = flash_attention(q, k, v)
    exp = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(exp, np.float32), rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------- embedding_bag
@pytest.mark.parametrize("b,f,mh,v,d", [(8, 4, 3, 100, 16), (16, 26, 1, 500, 64),
                                        (32, 2, 8, 50, 32)])
def test_embedding_bag(b, f, mh, v, d):
    from repro.kernels.embedding_bag import embedding_bag_fields
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    t = jnp.asarray(np.random.randn(f, v, d).astype(np.float32))
    ix = jnp.asarray(np.random.randint(0, v, (b, f, mh)).astype(np.int32))
    got = embedding_bag_fields(t, ix, bt=8)
    exp = embedding_bag_ref(t, ix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-6)


# --------------------------------------------- kernel-backed high-level paths
def test_dip_arr_kernel_path():
    from repro.core import build_dip_arr
    from repro.core.dip_arr import query_any

    bm = build_dip_arr(np.random.randint(0, 100, 50), np.random.randint(0, 8, 50),
                       k=8, n=100)
    mask = jnp.asarray(np.random.rand(8) < 0.5)
    a = query_any(bm, mask, impl="kernel")
    b = query_any(bm, mask, impl="scan")
    assert bool(jnp.all(a == b))


def test_spmm_kernel_path():
    from repro.graph.segment_ops import spmm_di

    n, e, d = 100, 400, 32
    x = jnp.asarray(np.random.randn(n, d).astype(np.float32))
    src = jnp.asarray(np.sort(np.random.randint(0, n, e)).astype(np.int32))
    dst = jnp.asarray(np.random.randint(0, n, e).astype(np.int32))
    a = spmm_di(x, src, dst, n, impl="kernel")
    b = spmm_di(x, src, dst, n, impl="segment")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
