"""Graph substrate: segment ops, sampler, analytics algorithms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests are optional off-CI
from hypothesis import given, settings, strategies as st

from repro.core import build_di
from repro.graph import (
    connected_components, pagerank, random_uniform_graph, sample_layers,
    segment_softmax, triangle_count,
)
from repro.graph.segment_ops import degree_norm, gather_scatter


def test_gather_scatter_agg_modes():
    n, e, d = 20, 60, 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    for agg in ("sum", "mean", "max"):
        out = gather_scatter(x, src, dst, n, agg=agg)
        assert out.shape == (n, d) and np.isfinite(np.asarray(out)).all()


def test_segment_softmax_normalizes():
    scores = jnp.asarray([1.0, 2.0, 3.0, -1.0, 5.0])
    seg = jnp.asarray([0, 0, 0, 2, 2])
    p = np.asarray(segment_softmax(scores, seg, 3))
    assert abs(p[:3].sum() - 1) < 1e-6 and abs(p[3:].sum() - 1) < 1e-6


def test_degree_norm_sym():
    src = jnp.asarray([0, 0, 1], jnp.int32)
    dst = jnp.asarray([1, 2, 2], jnp.int32)
    w = np.asarray(degree_norm(src, dst, 3, mode="sym"))
    # edge (0,1): 1/sqrt((1+2)(1+1)); edge (1,2): 1/sqrt((1+1)(1+2))
    assert abs(w[0] - 1 / np.sqrt(6)) < 1e-6
    assert abs(w[2] - 1 / np.sqrt(6)) < 1e-6


def test_connected_components_two_islands():
    g = build_di([0, 1, 3, 4], [1, 2, 4, 5], normalize=False, n=6)
    cc = np.asarray(connected_components(g))
    assert cc[0] == cc[1] == cc[2]
    assert cc[3] == cc[4] == cc[5]
    assert cc[0] != cc[3]


def test_pagerank_sums_to_one_and_ranks_hub():
    # star graph: everyone points to 0
    g = build_di([1, 2, 3, 4], [0, 0, 0, 0], normalize=False, n=5)
    pr = np.asarray(pagerank(g))
    assert abs(pr.sum() - 1) < 1e-3
    assert pr[0] == pr.max()


def test_triangle_count_known():
    # directed 3-cycle + symmetric K3 check
    import itertools
    e = list(itertools.permutations([0, 1, 2], 2))
    g = build_di([a for a, b in e], [b for a, b in e])
    assert int(triangle_count(g, max_deg=4)) == 6  # 6 closing wedges = 1 triangle


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sampler_validity(seed):
    """Every sampled edge must exist in the graph; masks consistent."""
    src, dst = random_uniform_graph(2000, seed=seed % 1000)
    g = build_di(src, dst)
    seeds = np.arange(16, dtype=np.int32)
    blocks = sample_layers(g, seeds, [5, 3], seed=seed % 97)
    S, D = np.asarray(g.src), np.asarray(g.dst)
    edge_set = set(zip(S.tolist(), D.tolist()))
    for b in blocks:
        sn, dn = np.asarray(b.src_nodes), np.asarray(b.dst_nodes)
        es, ed, em = np.asarray(b.edge_src), np.asarray(b.edge_dst), np.asarray(b.edge_mask)
        for i in np.flatnonzero(em):
            # block edges run in MESSAGE-FLOW direction (sampled neighbor →
            # frontier node); the sampler walks the DI out-adjacency, so the
            # underlying graph edge is (dst_node → src_node).  Callers wanting
            # in-neighbor flow pass build_reverse_di(g).
            assert (int(dn[ed[i]]), int(sn[es[i]])) in edge_set
    # last block's dst are exactly the seeds
    assert set(np.asarray(blocks[-1].dst_nodes).tolist()) == set(seeds.tolist())


def test_sampler_static_shapes():
    from repro.graph import block_shapes

    shapes = block_shapes(1024, [15, 10])
    assert shapes[-1] == (16384, 1024, 15360)
