"""pgd network front-end: wire codec, server/client round-trips, adaptive
batching (src/repro/service/{wire,server,client}.py, ARCHITECTURE §9).

The contracts under test:

* the codec round-trips headers and arrays exactly (bool masks travel
  packbits-packed and come back bitwise-identical), and rejects garbage
  frames with ``ProtocolError`` instead of misreading them;
* a query through ``PGClient`` → TCP → ``PGServer`` → ``Service`` returns
  masks bitwise-equal to in-process ``PropGraph.match`` (the paper §III
  client–server split must be invisible to correctness), including
  pipelined bursts, cross-backend ``load_graph`` reopens, and mutations
  applied over the wire;
* failures stay isolated: a bad request errors its own response (with the
  real exception type) and the session keeps serving;
* the adaptive micro-batch window (ROADMAP item): no batching latency when
  the queue is empty, window-batching under pressure, and ``window_ms=0``
  stays live (the negative-timeout clamp regression).
"""
import socket
import threading
import time

import numpy as np
import pytest

from repro.core import PropGraph
from repro.launch.pgserve import build_tenant_graph, pattern_pool
from repro.service import MicroBatcher, PGClient, PGServer, Service, ServiceConfig
from repro.service import wire

PATTERNS = (
    "(a:l1|l2)-[:follows]->(b:l3)",
    "(a:l0 {age > 30})-[:likes]->(b)",
    "(a)<-[:likes]-(b:l4|l5)",
    "(a:l6)-[:follows]->(b)-[:likes]->(c:l7)",
)


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


def _assert_wire_matches(got, ref):
    assert _eq(got.vertex_mask, ref.vertex_mask)
    assert _eq(got.edge_mask, ref.edge_mask)
    gb, rb = got.bindings(), ref.bindings()
    assert sorted(gb) == sorted(rb)
    for k in rb:
        assert _eq(gb[k], rb[k]), k


# ------------------------------------------------------------------- codec
def test_wire_roundtrip_header_and_arrays():
    arrays = [
        np.arange(7, dtype=np.int32),
        np.random.default_rng(0).standard_normal((3, 5)).astype(np.float32),
        np.array([], dtype=np.int64),
        np.random.default_rng(1).random(83) > 0.5,  # bool: packbits path
        np.zeros((2, 9), dtype=np.bool_),
    ]
    header = {"op": "query", "id": 3, "pattern": "(a)-[]->(b)", "impl": None}
    a, b = socket.socketpair()
    try:
        wire.send_msg(a, header, arrays)
        got_header, got_arrays = wire.recv_msg(b)
        assert got_header == header
        assert len(got_arrays) == len(arrays)
        for orig, back in zip(arrays, got_arrays):
            assert back.dtype == orig.dtype and back.shape == orig.shape
            assert _eq(back, orig)
    finally:
        a.close(), b.close()


def test_wire_rejects_garbage_and_truncation():
    a, b = socket.socketpair()
    try:
        a.sendall(b"HTTP/1.1 200 OK\r\n\r\n" + b"x" * 20)
        with pytest.raises(wire.ProtocolError, match="magic"):
            wire.recv_msg(b)
    finally:
        a.close(), b.close()
    a, b = socket.socketpair()
    try:
        frame = wire.encode_msg({"op": "ping", "id": 1}, [np.arange(100)])
        a.sendall(frame[: len(frame) // 2])
        a.close()  # truncated mid-frame
        with pytest.raises(wire.ProtocolError, match="truncated"):
            wire.recv_msg(b)
    finally:
        b.close()


def test_wire_rejects_hostile_array_specs():
    """A frame whose header carries bad array specs must surface as
    ProtocolError (the session/client loops only handle protocol errors),
    never a raw numpy exception."""
    import json
    import struct

    def frame_with_specs(specs, blob=b""):
        hdr = json.dumps({"op": "x", "id": 1, "arrays": specs}).encode()
        payload = struct.pack("!I", len(hdr)) + hdr + blob
        return wire.MAGIC + struct.pack("!I", len(payload)) + payload

    for specs in (
        [{"dtype": "bogus", "shape": [3]}],
        [{"dtype": "int32", "shape": [-4]}],
        [{"dtype": "object", "shape": [2]}],
        [{"shape": [2]}],
        [{"dtype": "int32", "shape": [2**30, 2**30, 2**30]}],  # int64 wrap
        "not-a-list",
    ):
        a, b = socket.socketpair()
        try:
            a.sendall(frame_with_specs(specs))
            with pytest.raises(wire.ProtocolError):
                wire.recv_msg(b)
        finally:
            a.close(), b.close()


def test_wire_clean_eof_is_connection_error():
    a, b = socket.socketpair()
    a.close()
    try:
        with pytest.raises(ConnectionError):
            wire.recv_msg(b)
    finally:
        b.close()


def test_wire_exception_roundtrip():
    e = wire.wire_to_exc(wire.exc_to_wire(KeyError("nosuchprop")))
    assert isinstance(e, KeyError) and "nosuchprop" in str(e)
    e = wire.wire_to_exc({"type": "SomeServerOnlyError", "message": "boom"})
    assert isinstance(e, wire.RemoteError) and "boom" in str(e)


def test_wire_match_result_roundtrip():
    pg = build_tenant_graph("arr", 400, seed=7)
    ref = pg.match(PATTERNS[0])
    meta, arrays = wire.result_to_wire(ref)
    back = wire.wire_to_result(meta, [np.asarray(x) for x in arrays])
    _assert_wire_matches(back, ref)


# ----------------------------------------------------------- server/client
@pytest.fixture(scope="module")
def served():
    """One server (own thread pool, real TCP socket) + the graph it serves;
    module-scoped — sessions are cheap, graphs are not."""
    pg = build_tenant_graph("arr", 800, seed=3)
    svc = Service()
    svc.add_graph("g", pg)
    server = PGServer(svc, port=0).start()
    yield server, pg
    server.close()
    svc.close()


def test_net_query_bitwise_equals_match(served):
    server, pg = served
    with PGClient(port=server.port) as c:
        assert c.ping()
        for p in PATTERNS:
            _assert_wire_matches(c.query("g", p), pg.match(p))


def test_net_pipelined_batch_with_duplicates(served):
    server, pg = served
    burst = list(PATTERNS) + [PATTERNS[0], PATTERNS[2]]
    with PGClient(port=server.port) as c:
        got = c.query_batch("g", burst)
    for p, res in zip(burst, got):
        _assert_wire_matches(res, pg.match(p))


def test_net_out_of_order_resolution(served):
    """Submit A then B, read B first: responses are matched by id, not
    arrival order — the pipelining contract."""
    server, pg = served
    with PGClient(port=server.port) as c:
        ha = c.submit("g", PATTERNS[0])
        hb = c.submit("g", PATTERNS[1])
        _assert_wire_matches(hb.result(), pg.match(PATTERNS[1]))
        _assert_wire_matches(ha.result(), pg.match(PATTERNS[0]))


def test_net_errors_fail_alone_and_session_survives(served):
    server, pg = served
    with PGClient(port=server.port) as c:
        with pytest.raises(KeyError, match="nosuchprop"):
            c.query("g", "(a {nosuchprop > 1})-[:follows]->(b)")
        with pytest.raises(KeyError, match="unknown graph"):
            c.query("nope", PATTERNS[0])
        with pytest.raises(Exception):  # noqa: B017 — any server-side error
            c._call("no_such_op")
        # the connection is still good after three failed requests
        _assert_wire_matches(c.query("g", PATTERNS[0]), pg.match(PATTERNS[0]))
        assert "plan" in c.explain("g", PATTERNS[0]).lower()
        stats = c.stats()
        assert stats["completed"] > 0
        assert c.graphs()["g"] == pg.version


def test_net_mutation_invalidates_and_stays_bitwise():
    pg = build_tenant_graph("arr", 500, seed=11)
    local = build_tenant_graph("arr", 500, seed=11)  # in-process reference
    with Service() as svc:
        svc.add_graph("g", pg)
        with PGServer(svc, port=0) as server, PGClient(port=server.port) as c:
            before = c.query("g", PATTERNS[0])
            nodes = np.asarray(local.graph.node_map)
            v = c.add_node_labels("g", nodes[:9], ["l1"] * 9)
            local.add_node_labels(nodes[:9], ["l1"] * 9)
            assert v == local.version
            after = c.query("g", PATTERNS[0])
            _assert_wire_matches(after, local.match(PATTERNS[0]))
            assert before is not None  # first query really executed
            stats = c.stats()
            assert stats.get("invalidated_results", 0) >= 1  # purge fired
            # property mutation over the wire too
            c.add_node_properties("g", "age", nodes[:5],
                                  np.full(5, 99, np.int32))
            local.add_node_properties("age", nodes[:5], np.full(5, 99, np.int32))
            _assert_wire_matches(c.query("g", PATTERNS[1]),
                                 local.match(PATTERNS[1]))


def test_net_load_graph_cross_backend(served, tmp_path):
    from repro.core.io import save_propgraph

    server, pg = served
    path = save_propgraph(str(tmp_path / "pg"), pg)
    with PGClient(port=server.port) as c:
        info = c.load_graph("disk", path, backend="listd")
        assert info["backend"] == "listd"
        assert info["n"] == pg.n_vertices and info["m"] == pg.n_edges
        _assert_wire_matches(c.query("disk", PATTERNS[0]), pg.match(PATTERNS[0]))


def test_net_concurrent_client_connections(served):
    """Several OS-level connections at once: per-session framing must not
    interleave (each session has its own write lock)."""
    server, pg = served
    refs = {p: pg.match(p) for p in PATTERNS}
    errors = []

    def one_client():
        try:
            with PGClient(port=server.port) as c:
                for p in PATTERNS:
                    _assert_wire_matches(c.query("g", p), refs[p])
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=one_client) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors


def test_net_graceful_drain_completes_inflight():
    pg = build_tenant_graph("arr", 500, seed=13)
    svc = Service()
    svc.add_graph("g", pg)
    server = PGServer(svc, port=0).start()
    try:
        with PGClient(port=server.port) as c:
            handles = [c.submit("g", p) for p in PATTERNS]
            c.drain()  # stops the listener, waits for the futures above
            for h, p in zip(handles, PATTERNS):
                _assert_wire_matches(h.result(), pg.match(p))
            # drained server accepts no NEW connections
            with pytest.raises(OSError):
                PGClient(port=server.port, connect_timeout=2).ping()
    finally:
        server.close()
        svc.close()


# ------------------------------------------------------- adaptive batching
def _collecting_batcher(**kw):
    batches, done = [], threading.Event()

    def execute(batch):
        batches.append(list(batch))
        done.set()

    return MicroBatcher(execute, **kw), batches, done


def test_adaptive_window_skips_wait_when_idle():
    """With a HUGE window, an idle-queue request must still execute
    immediately — the adaptive bypass is what removes the c=1 latency tax."""
    b, batches, done = _collecting_batcher(window_ms=5_000.0, adaptive=True)
    try:
        t0 = time.monotonic()
        b.submit("r1")
        assert done.wait(timeout=2.0), "request stuck behind the window"
        assert time.monotonic() - t0 < 2.0
        assert batches[0] == ["r1"]
    finally:
        b.close(timeout=1.0)


def test_window_opens_under_queue_pressure():
    """When requests are already queued, the window forms a real batch."""
    gate = threading.Event()
    batches = []

    def execute(batch):
        batches.append(list(batch))
        gate.wait(timeout=5.0)  # hold the worker so pressure builds

    b = MicroBatcher(execute, window_ms=200.0, adaptive=True, max_batch=8)
    try:
        b.submit("first")  # worker blocks inside execute()
        time.sleep(0.05)
        for i in range(5):
            b.submit(f"r{i}")  # all queued while the worker is held
        gate.set()
        b.close(timeout=5.0)  # drains: the 5 must have batched together
        assert batches[0] == ["first"]
        assert ["r%d" % i for i in range(5)] in batches  # one pressure batch
    finally:
        gate.set()
        b.close(timeout=1.0)


def test_window_ms_zero_stays_live():
    """The negative-timeout clamp regression: a zero (or already-expired)
    window must drain what is queued and never pass a negative timeout to
    the queue wait."""
    b, batches, _ = _collecting_batcher(window_ms=0.0, adaptive=False)
    try:
        for i in range(16):
            b.submit(i)
        b.close(timeout=5.0)
        assert sorted(x for batch in batches for x in batch) == list(range(16))
    finally:
        b.close(timeout=1.0)


def test_service_window_ms_zero_end_to_end():
    pg = build_tenant_graph("arr", 400, seed=5)
    with Service(config=ServiceConfig(window_ms=0.0)) as svc:
        svc.add_graph("g", pg)
        futs = [svc.submit("g", p) for p in PATTERNS]
        for f, p in zip(futs, PATTERNS):
            got = f.result(timeout=120)
            assert _eq(got.vertex_mask, pg.match(p).vertex_mask)


def test_fixed_window_config_still_available():
    """adaptive_window=False restores the PR 3 behavior (benchmark's
    fixed-window comparison row depends on it)."""
    pg = build_tenant_graph("arr", 400, seed=5)
    cfg = ServiceConfig(adaptive_window=False, window_ms=1.0)
    with Service(config=cfg) as svc:
        svc.add_graph("g", pg)
        got = svc.query("g", PATTERNS[0])
        assert _eq(got.vertex_mask, pg.match(PATTERNS[0]).vertex_mask)


# ------------------------------------------------------------ cross-process
def test_cross_process_net_roundtrip():
    """A REAL second OS process: spawn the serve-mode CLI, query it over
    TCP, compare bitwise against this process's match().  (The CI smoke
    runs the full three-backend version; this keeps a single-backend gate
    inside the suite.)"""
    from repro.launch.pgserve import spawn_server

    pg = build_tenant_graph("arr", 400, seed=0)
    proc, port = spawn_server(["--backends", "arr", "--m", "400", "--seed", "0"])
    try:
        with PGClient(port=port) as c:
            for p in PATTERNS[:2]:
                _assert_wire_matches(c.query("arr", p), pg.match(p))
            c.shutdown()
        assert proc.wait(timeout=60) == 0
    finally:
        if proc.poll() is None:
            proc.kill()


# ------------------------------------------------------------ observability
def test_net_trace_id_roundtrip(served):
    """The client mints a trace id per query; the server's span tree comes
    back in the response header rooted at that id (ARCHITECTURE §13)."""
    server, pg = served
    with PGClient(port=server.port) as c:
        h = c.submit("g", PATTERNS[0])
        res = h.result()
        assert h.trace_id and h.trace is not None
        assert h.trace["trace_id"] == h.trace_id
        names = [s["name"] for s in h.trace["spans"]]
        assert "serialize" in names, names
        assert "parse" in names or "cache" in names, names
        assert c.last_trace is h.trace
        _assert_wire_matches(res, pg.match(PATTERNS[0]))


def test_net_trace_opt_out(served):
    """client.trace = False sends no trace id; no tree comes back."""
    server, pg = served
    with PGClient(port=server.port) as c:
        c.trace = False
        h = c.submit("g", PATTERNS[1])
        h.result()
        assert h.trace_id is None and h.trace is None


def test_net_slow_query_ring_captures_client_trace():
    """slow_query_ms=0 marks every query slow: the traces verb's slow ring
    must hold span trees rooted at the CLIENT's ids."""
    pg = build_tenant_graph("arr", 400, seed=7)
    svc = Service(config=ServiceConfig(slow_query_ms=0.0))
    svc.add_graph("g", pg)
    server = PGServer(svc, port=0).start()
    try:
        with PGClient(port=server.port) as c:
            hs = [c.submit("g", p) for p in PATTERNS]
            for h in hs:
                h.result()
            payload = c.traces()
            slow_ids = {t["trace_id"] for t in payload["slow"]}
            assert {h.trace_id for h in hs} <= slow_ids
            assert {t["trace_id"] for t in payload["traces"]} >= slow_ids
    finally:
        server.close()
        svc.close()


def test_net_metrics_verb_parses_and_counts(served):
    """The metrics verb returns Prometheus text that parses, moves by
    exactly the burst size, and agrees with the stats verb."""
    from repro.obs import parse_prometheus

    server, pg = served
    with PGClient(port=server.port) as c:
        m1 = parse_prometheus(c.metrics())
        for p in PATTERNS:
            c.query("g", p)
        m2 = parse_prometheus(c.metrics())
        st = c.stats()
    assert (m2["pg_service_submitted_total"]
            == m1["pg_service_submitted_total"] + len(PATTERNS))
    assert m2["pg_service_submitted_total"] == st["submitted"]
    assert m2["pg_service_completed_total"] == st["completed"]
    # wire instrumentation rode along (labeled GLOBAL counters)
    assert any(k.startswith("pg_wire_bytes") for k in m2), sorted(m2)[:10]
