"""Fused property-filtered neighborhood sampling (docs/ARCHITECTURE.md §15).

What must hold, layer by layer:

* kernel vs numpy oracle — every output of ``neighbor_sample`` is a valid
  without-replacement sample of the FILTERED adjacency (membership, no
  duplicates, exact ``min(fanout, filtered degree)`` counts, -1 sentinels),
  including the edge cases the padding machinery can silently break:
  degree-0 seeds and degree ≤ fanout.
* statistics — selection is uniform over the allowed window lanes
  (chi-square on a hub vertex, one batched launch = thousands of
  independent draws) and NEVER emits a filtered-out edge.
* determinism — bitwise reproducible given (key, layer): repeated calls,
  jitted vs eager key derivation, layer independence under fold_in (the
  ``sampler.py`` re-keying fix: adding layers must not shift layer 0).
* serving — a coalesced batch is bitwise its sequential runs on every
  backend; deterministic results cache, keyed entropy never does; a
  64-request mixed-size burst stays within the bucketed compile budget
  (asserted via the PR 8 metrics registry).
* overlay — snapshots sample stably while a writer mutates the parent;
  delta edges are sampleable; tombstoned edges never appear.
* mesh — P=8 sharded sampling ≡ single-device, bitwise, in a subprocess
  with 8 guaranteed virtual devices.
"""
from __future__ import annotations

import os
import subprocess
import sys
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import PropGraph, bitplane  # noqa: E402
from repro.graph.sampler import layer_key, layer_keys_batch  # noqa: E402
from repro.kernels.neighbor_sample import (  # noqa: E402
    bucketed_requests,
    bucketed_seeds,
    neighbor_sample,
    neighbor_sample_batched,
    neighbor_sample_from_words,
    sample_compile_count,
    sample_embed,
)
from repro.kernels.neighbor_sample.ref import (  # noqa: E402
    check_sample,
    filtered_degrees,
)
from repro.launch.pgserve import build_tenant_graph  # noqa: E402
from repro.service import Service  # noqa: E402

BACKENDS = ("arr", "list", "listd")


def _graph(m=3_000, backend="arr", seed=0):
    return build_tenant_graph(backend, m, seed=seed)


def _blocks_equal(got, ref):
    assert len(got) == len(ref)
    for li, (bg, br) in enumerate(zip(got, ref)):
        for f in ("src_nodes", "dst_nodes", "edge_src", "edge_dst",
                  "edge_mask"):
            a, b = np.asarray(getattr(bg, f)), np.asarray(getattr(br, f))
            assert a.shape == b.shape and (a == b).all(), (li, f)


# ----------------------------------------------------- kernel vs numpy oracle
def test_kernel_outputs_valid_vs_oracle_with_filter():
    pg = _graph()
    seg, dstv = np.asarray(pg.graph.seg), np.asarray(pg.graph.dst)
    eok = np.asarray(pg.match("(a)-[:follows]->(b)").edge_mask)
    ew = bitplane.pack_mask(jnp.asarray(eok))
    rng = np.random.default_rng(1)
    for fanout in (1, 3, 8):
        seeds = rng.choice(pg.n_vertices, 100, replace=False).astype(np.int32)
        nb, ei, mk = neighbor_sample(
            pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges, seeds,
            jax.random.PRNGKey(fanout), fanout=fanout, edge_words=ew,
            max_deg=int(pg.graph.max_deg))
        check_sample(seg, dstv, seeds, eok, fanout, np.asarray(nb)[:100],
                     np.asarray(ei)[:100], np.asarray(mk)[:100])


def test_degree_zero_seeds_fully_masked():
    # 0 → {1, 2}, 3 → 4; vertices 1, 2, 4 have NO out-edges
    pg = PropGraph().add_edges_from(np.array([0, 0, 3]),
                                    np.array([1, 2, 4]))
    iso = pg._vertex_internal(np.array([1, 2, 4])).astype(np.int32)
    nb, _ei, mk = neighbor_sample(
        pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges, iso,
        jax.random.PRNGKey(0), fanout=4, max_deg=int(pg.graph.max_deg))
    assert not np.asarray(mk)[:3].any()
    assert (np.asarray(nb)[:3] == -1).all()


def test_degree_leq_fanout_keeps_every_edge_exactly_once():
    # hub with degree 5 < fanout 8: all 5 neighbors, no duplicates
    src = np.zeros(5, np.int64)
    dst = np.arange(1, 6)
    pg = PropGraph().add_edges_from(src, dst)
    hub = pg._vertex_internal(np.array([0])).astype(np.int32)
    for s in range(4):
        nb, _ei, mk = neighbor_sample(
            pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges, hub,
            jax.random.PRNGKey(s), fanout=8, max_deg=int(pg.graph.max_deg))
        row, ok = np.asarray(nb)[0], np.asarray(mk)[0]
        assert ok.sum() == 5
        assert len(set(row[ok].tolist())) == 5  # without replacement


def test_pattern_seed_path_equals_explicit_ascending_ids():
    """Device nonzero extraction ≡ host flatnonzero: the packed-bitmap seed
    path must sample exactly what explicit ascending ids would."""
    pg = _graph()
    mask = np.asarray(pg.match("(a:l0)").vertex_mask)
    ids = np.flatnonzero(mask).astype(np.int32)
    nodes = np.asarray(pg.graph.node_map)
    got = pg.sample("(a:l0)", [4, 3], seed=11)
    ref = pg.sample(nodes[ids], [4, 3], seed=11)
    _blocks_equal(got, ref)


def test_from_words_matches_pattern_mask():
    pg = _graph()
    mask = pg.match("(a:l1|l2)").vertex_mask
    words = bitplane.pack_mask(jnp.asarray(mask))
    count = int(np.asarray(mask).sum())
    idx, valid, nb, _ei, mk = neighbor_sample_from_words(
        pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges, words, count,
        jax.random.PRNGKey(2), fanout=4, max_deg=int(pg.graph.max_deg))
    keep = np.asarray(valid)
    assert keep.sum() == count
    assert np.array_equal(np.sort(np.asarray(idx)[keep]),
                          np.flatnonzero(np.asarray(mask)))
    check_sample(np.asarray(pg.graph.seg), np.asarray(pg.graph.dst),
                 np.asarray(idx)[keep], None, 4, np.asarray(nb)[keep],
                 np.asarray(_ei)[keep], np.asarray(mk)[keep])


# ------------------------------------------------------------------ statistics
def test_uniformity_chi_square_and_filtered_exclusion():
    """One hub, 64 out-edges, half filtered out.  2048 independent draws of
    fanout=1 in ONE batched launch: the 32 allowed lanes must be uniform
    (chi-square, 31 dof: 99.9th percentile ≈ 61.1) and the 32 forbidden
    lanes must never appear."""
    deg = 64
    src = np.zeros(deg, np.int64)
    dst = np.arange(1, deg + 1)
    pg = PropGraph().add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rels = np.where(np.asarray(pg.graph.dst) % 2 == 0, "ok", "no")
    pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    eok = np.asarray(pg.match("(x)-[:ok]->(y)").edge_mask)
    ew = bitplane.pack_mask(jnp.asarray(eok))
    hub = int(pg._vertex_internal(np.array([0]))[0])

    R = 2048
    cap = bucketed_seeds(1)
    seeds_m = np.zeros((bucketed_requests(R), cap), np.int32)
    seeds_m[:, 0] = hub
    valid_m = np.zeros_like(seeds_m, bool)
    valid_m[:R, 0] = True
    keys = layer_keys_batch(jnp.arange(bucketed_requests(R)), 0)
    words_m = jnp.stack([ew] * bucketed_requests(R))
    nb, _ei, mk = neighbor_sample_batched(
        pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges, seeds_m,
        valid_m, keys, fanout=1, edge_words=words_m,
        max_deg=int(pg.graph.max_deg))
    picks = np.asarray(nb)[:R, 0, 0]
    okrow = np.asarray(mk)[:R, 0, 0]
    assert okrow.all()  # hub has 32 allowed edges ≥ fanout 1
    allowed = set(np.asarray(pg.graph.dst)[eok].tolist())
    assert set(picks.tolist()) <= allowed  # filtered edges NEVER appear
    counts = np.bincount(picks, minlength=pg.n_vertices)[sorted(allowed)]
    expected = R / len(allowed)
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < 61.1, chi2


# ---------------------------------------------------------------- determinism
def test_bitwise_reproducible_and_jitted_key_parity():
    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    seeds = nodes[:64]
    a = pg.sample(seeds, [5, 3], pattern="(a)-[:likes]->(b)", seed=9)
    b = pg.sample(seeds, [5, 3], pattern="(a)-[:likes]->(b)", seed=9)
    _blocks_equal(a, b)
    # explicit key ≡ int seed (the jitted layer_key derivation is bitwise
    # the eager fold_in(PRNGKey(seed), layer) chain)
    c = pg.sample(seeds, [5, 3], pattern="(a)-[:likes]->(b)",
                  key=jax.random.PRNGKey(9))
    _blocks_equal(a, c)
    for s in (0, 7, 2**31 - 1):
        for layer in (0, 1, 5):
            assert np.array_equal(
                np.asarray(layer_key(s, layer)),
                np.asarray(jax.random.fold_in(jax.random.PRNGKey(s), layer)))
    kb = np.asarray(layer_keys_batch(jnp.arange(9), 1))
    for i in range(9):
        assert np.array_equal(kb[i], np.asarray(layer_key(i, 1)))


def test_layer_independence_under_fold_in():
    """The sampler re-keys per layer with fold_in(base, l): layer 0's draw
    must be IDENTICAL whether or not deeper layers exist (regression for
    the split-and-reuse bug), and two layers with the same fanout must not
    reuse each other's randomness."""
    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    seeds = nodes[:48]
    one = pg.sample(seeds, [4], seed=3)
    two = pg.sample(seeds, [4, 4], seed=3)
    _blocks_equal([one[-1]], [two[-1]])  # layer 0 unshifted by extra layer
    # same fanout, same frontier size ⇒ equal draws would mean key reuse
    l0, l1 = two[-1], two[-2]
    assert not (len(l0.edge_mask) == len(l1.edge_mask)
                and np.array_equal(np.asarray(l0.edge_src),
                                   np.asarray(l1.edge_src))
                and np.array_equal(np.asarray(l0.edge_mask),
                                   np.asarray(l1.edge_mask)))


def test_block_renumbering_is_stable_and_local():
    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    blocks = pg.sample(nodes[:32], [6, 4], seed=1)
    for b in blocks:
        sn = np.asarray(b.src_nodes)
        assert (np.diff(sn) > 0).all()  # sorted unique global ids
        es, ed = np.asarray(b.edge_src), np.asarray(b.edge_dst)
        ok = np.asarray(b.edge_mask)
        assert es[ok].max(initial=0) < b.n_src
        assert ed[ok].max(initial=0) < b.n_dst
        # every unmasked edge's endpoint resolves through the local ids
        dn = np.asarray(b.dst_nodes)
        assert set(dn.tolist()) <= set(sn.tolist())  # dst ⊆ src frontier
    # the widest frontier (blocks[0]) contains every id in the chain
    sub = set(np.asarray(blocks[0].src_nodes).tolist())
    for b in blocks:
        assert set(np.asarray(b.src_nodes).tolist()) <= sub


# -------------------------------------------------------------------- serving
@pytest.mark.parametrize("backend", BACKENDS)
def test_coalesced_batch_equals_sequential_sample(backend):
    pg = _graph(backend=backend)
    nodes = np.asarray(pg.graph.node_map)
    specs = [(nodes[13 * i:13 * i + 40], i) for i in range(6)]
    specs.append(("(a:l0)", 77))
    with Service() as svc:
        svc.add_graph("g", pg)
        got = svc.sample_batch("g", specs, [4, 2])
    for (seeds, sv), blocks in zip(specs, got):
        _blocks_equal(blocks, pg.sample(seeds, [4, 2], seed=sv))


def test_service_filtered_sample_parity_and_stats():
    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    with Service() as svc:
        svc.add_graph("g", pg)
        before = svc.stats().get("sample_requests", 0)
        got = svc.sample("g", nodes[:50], [5],
                         pattern="(a)-[:follows]->(b)", seed=4)
        _blocks_equal(got, pg.sample(nodes[:50], [5],
                                     pattern="(a)-[:follows]->(b)", seed=4))
        assert svc.stats()["sample_requests"] == before + 1


def test_result_cache_deterministic_hits_keyed_never_cached():
    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    with Service() as svc:
        svc.add_graph("g", pg)
        a = svc.sample("g", nodes[:40], [4], seed=5)
        h0 = svc.stats().get("result_hits", 0)
        b = svc.sample("g", nodes[:40], [4], seed=5)  # deterministic: hits
        assert svc.stats()["result_hits"] == h0 + 1
        _blocks_equal(a, b)
        h1 = svc.stats()["result_hits"]
        c = svc.sample("g", nodes[:40], [4], deterministic=False)
        d = svc.sample("g", nodes[:40], [4], deterministic=False)
        assert svc.stats()["result_hits"] == h1  # keyed entropy: no cache
        # fresh entropy per request: the picks differ (not just the unions)
        same = all(
            np.array_equal(np.asarray(x.edge_src), np.asarray(y.edge_src))
            and np.array_equal(np.asarray(x.edge_mask),
                               np.asarray(y.edge_mask))
            for x, y in zip(c, d))
        assert not same


def test_compile_count_bounded_across_mixed_size_burst():
    """64 requests with 64 different seed-set sizes must stay inside the
    bucketed specialization budget — the pg_sample_compiles counter (PR 8
    metrics registry) and sample_compile_count() agree."""
    from repro.obs.metrics import GLOBAL

    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    rng = np.random.default_rng(0)
    sizes = rng.integers(1, 500, 64)
    with Service() as svc:
        svc.add_graph("g", pg)
        svc.sample("g", nodes[:16], [3], seed=0)  # settle shared shapes
        c0 = sample_compile_count()
        m0 = GLOBAL.counter("pg_sample_compiles").value()
        assert c0 == m0  # the counter IS the seen-key set size
        futs = [svc.submit_sample("g", nodes[:int(s)], (3,), seed=i,
                                  deterministic=False)
                for i, s in enumerate(sizes)]
        for f in futs:
            f.result(timeout=120)
        grown = sample_compile_count() - c0
        # seed buckets for sizes < 512: {16,32,64,128,256,512} = 6, times
        # a handful of request buckets — far below one-per-request
        assert grown <= 16, grown
        assert GLOBAL.counter("pg_sample_compiles").value() == c0 + grown


# ------------------------------------------------------------------- overlays
def test_snapshot_sample_stable_under_concurrent_writer():
    pg = _graph(m=1_500)
    nodes = np.asarray(pg.graph.node_map)
    snap = pg.snapshot()
    ref = snap.sample(nodes[:40], [4, 3], seed=2)
    stop = threading.Event()

    def writer():
        r = np.random.default_rng(3)
        while not stop.is_set():
            u, v = nodes[r.integers(0, len(nodes), 2)]
            pg.insert_edges(np.array([u]), np.array([v]))

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(10):
            _blocks_equal(snap.sample(nodes[:40], [4, 3], seed=2), ref)
    finally:
        stop.set()
        t.join()


def test_delta_edges_sampleable_tombstones_never_appear():
    src = np.array([0, 0, 0, 1])
    dst = np.array([1, 2, 3, 2])
    pg = PropGraph().add_edges_from(src, dst)
    pg.delete_edges(np.array([0]), np.array([2]))
    pg.insert_edges(np.array([1]), np.array([3]))
    node_of = np.asarray(pg.graph.node_map)
    for s in range(6):  # fanout ≥ degree ⇒ EVERY live edge must appear
        blocks = pg.sample(np.array([0, 1]), [8], seed=s)
        b = blocks[0]
        sn, dn = np.asarray(b.src_nodes), np.asarray(b.dst_nodes)
        es, ed = np.asarray(b.edge_src), np.asarray(b.edge_dst)
        ok = np.asarray(b.edge_mask)
        pairs = {(int(node_of[dn[d]]), int(node_of[sn[s_]]))
                 for s_, d in zip(es[ok], ed[ok])}
        assert (0, 2) not in pairs  # tombstoned
        assert (1, 3) in pairs  # delta edge is live and must be drawn
        assert pairs == {(0, 1), (0, 3), (1, 2), (1, 3)}


def test_sample_embed_fused_equals_composition():
    pg = _graph()
    n = pg.n_vertices
    table = jax.random.normal(jax.random.PRNGKey(4), (n, 16), jnp.float32)
    seeds = np.arange(0, 96, dtype=np.int32)
    key = jax.random.PRNGKey(6)
    bags, nb, _ei, mk = sample_embed(
        pg.graph.seg, pg.graph.dst, n, pg.n_edges, seeds, key, table,
        fanout=5, max_deg=int(pg.graph.max_deg))
    nb2, _e2, mk2 = neighbor_sample(
        pg.graph.seg, pg.graph.dst, n, pg.n_edges, seeds, key, fanout=5,
        max_deg=int(pg.graph.max_deg))
    assert np.array_equal(np.asarray(nb), np.asarray(nb2))
    rows = np.asarray(table)[np.clip(np.asarray(nb2), 0, n - 1)]
    w = np.asarray(mk2)[..., None].astype(np.float32)
    cnt = np.maximum(np.asarray(mk2).sum(-1, keepdims=True), 1)
    ref = (rows * w).sum(1) / cnt.astype(np.float32)
    np.testing.assert_allclose(np.asarray(bags), ref, rtol=1e-5, atol=1e-5)
    dead = ~np.asarray(mk2).any(1)  # all-masked seeds → exactly zero bags
    assert (np.asarray(bags)[dead] == 0).all()


# ----------------------------------------------------------------------- wire
def test_wire_block_codec_roundtrip():
    from repro.service import wire

    pg = _graph()
    nodes = np.asarray(pg.graph.node_map)
    blocks = pg.sample(nodes[:32], [4, 2], seed=8)
    meta, arrays = wire.blocks_to_wire(blocks)
    back = wire.wire_to_blocks(meta, [np.asarray(a) for a in arrays])
    _blocks_equal(back, blocks)


# ------------------------------------------------------------------------ mesh
_SHARD_SCRIPT = """
import sys
sys.path.insert(0, {src!r})
import numpy as np
from repro.launch.mesh import make_entity_mesh
from repro.launch.pgserve import build_tenant_graph
import jax
assert len(jax.devices()) == 8, jax.devices()
pg1 = build_tenant_graph("arr", 2_000, seed=0)
pg2 = build_tenant_graph("arr", 2_000, mesh=make_entity_mesh(), seed=0)
nodes = np.asarray(pg1.graph.node_map)
for seeds, pat in ((nodes[:48], None), ("(a:l0)", "(a)-[:follows]->(b)")):
    a = pg1.sample(seeds, [4, 3], pattern=pat, seed=5)
    b = pg2.sample(seeds, [4, 3], pattern=pat, seed=5)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        for f in ("src_nodes", "dst_nodes", "edge_src", "edge_dst",
                  "edge_mask"):
            assert np.array_equal(np.asarray(getattr(x, f)),
                                  np.asarray(getattr(y, f))), f
print("SAMPLE8 OK")
"""


def test_sharded_sample_p8_subprocess():
    """P=8 sharded sampling ≡ single-device, bitwise, with 8 guaranteed
    virtual devices in a fresh interpreter (the mesh-locality rule: the
    seed bitmap rides the allreduce, sampling stays owner-local)."""
    src_dir = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT.format(src=src_dir)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SAMPLE8 OK" in proc.stdout
