"""PropGraph end-to-end: the paper's workflow (§V) + queries (§VI) + subgraphs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PropGraph
from repro.core.queries import filtered_bfs, induce_edge_mask
from repro.graph import attach_random_attributes, random_uniform_graph


@pytest.fixture(params=["arr", "list", "listd"])
def pg(request, rng):
    src, dst = random_uniform_graph(500, seed=3)
    g = PropGraph(backend=request.param).add_edges_from(src, dst)
    nodes = np.asarray(g.graph.node_map)
    labels = rng.choice(["person", "place", "thing"], size=len(nodes))
    g.add_node_labels(nodes, labels)
    es, ed = np.asarray(g.graph.src), np.asarray(g.graph.dst)
    rels = rng.choice(["follows", "likes", "knows"], size=len(es))
    g.add_edge_relationships(nodes[es], nodes[ed], rels)
    g._labels_np = labels
    g._rels_np = rels
    return g


def test_query_or_semantics(pg):
    vm = np.asarray(pg.query_labels(["person", "thing"]))
    expect = np.isin(pg._labels_np, ["person", "thing"])
    assert (vm == expect).all()
    em = np.asarray(pg.query_relationships(["likes"]))
    assert (em == (pg._rels_np == "likes")).all()


def test_unknown_attribute_empty(pg):
    assert not np.asarray(pg.query_labels(["nope"])).any()


def test_subgraph_intersection(pg):
    """Edges survive iff relationship matches AND both endpoints' labels match
    (the §VI mask-intersection contract)."""
    sub, kept = pg.subgraph(labels=["person"], relationships=["follows"])
    vm = np.isin(pg._labels_np, ["person"])
    em = pg._rels_np == "follows"
    s, d = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    expect = np.flatnonzero(em & vm[s] & vm[d])
    assert set(kept.tolist()) == set(expect.tolist())
    # subgraph node_map chains to ORIGINAL vertex ids
    nm = np.asarray(pg.graph.node_map)
    assert set(np.asarray(sub.node_map).tolist()) <= set(nm.tolist())


def test_filtered_bfs_respects_masks(pg):
    g = pg.graph
    em = pg.query_relationships(["follows"])
    depth = filtered_bfs(g, jnp.arange(5), edge_allowed=em)
    dnp = np.asarray(depth)
    # reference BFS on the filtered graph
    import collections
    allowed = np.asarray(em)
    adj = collections.defaultdict(list)
    for i, (a, b) in enumerate(zip(np.asarray(g.src), np.asarray(g.dst))):
        if allowed[i]:
            adj[int(a)].append(int(b))
    ref = np.full(g.n, -1)
    dq = collections.deque((int(s), 0) for s in range(5))
    for s in range(5):
        ref[s] = 0
    while dq:
        u, lv = dq.popleft()
        for v in adj[u]:
            if ref[v] < 0:
                ref[v] = lv + 1
                dq.append((v, lv + 1))
    assert (dnp == ref).all()


def test_properties_typed_columns(pg):
    nodes = np.asarray(pg.graph.node_map)
    ages = np.arange(len(nodes), dtype=np.int32)
    pg.add_node_properties("age", nodes[:10], ages[:10], fill=-1)
    col, valid = pg.vertex_props["age"]
    assert np.asarray(valid).sum() == 10
    assert (np.asarray(col)[np.asarray(valid)] == ages[:10]).all()


def test_attr_counts_match_brute_force(pg):
    """attr_counts() — the planner's selectivity stats — equals a host-side
    bincount of the inserted labels on every backend."""
    counts = pg._vstore.attr_counts()
    for i, name in enumerate(pg._vstore.amap.values):
        assert counts[i] == int((pg._labels_np == name).sum()), name


def test_attr_counts_invalidate_on_incremental_insert(pg):
    """insert() must clear the cached stats (and the store) so the planner
    never orders joins with stale counts."""
    before = dict(pg.label_counts())
    assert "vip" not in before
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes[:7], ["vip"] * 7)
    assert pg._vstore._counts is None and pg._vstore._dirty  # cache dropped
    after = pg.label_counts()
    assert after["vip"] == 7
    for name, c in before.items():
        assert after[name] == c, name  # old attributes unchanged
    # a second increment accumulates rather than resetting
    pg.add_node_labels(nodes[7:10], ["vip"] * 3)
    assert pg.label_counts()["vip"] == 10
    # and the refreshed stats drive a correct query
    assert int(np.asarray(pg.query_labels(["vip"])).sum()) == 10


def test_paper_generator_stats():
    """Tab. I regime: n/m ≈ 0.865 for the uniform generator."""
    src, dst = random_uniform_graph(100_000, seed=0)
    from repro.core import build_di
    g = build_di(src, dst)
    assert 0.85 < g.n / 100_000 < 0.88
    ents, attrs = attach_random_attributes(g.n, n_attrs=50, seed=0)
    assert attrs.max() < 50 and len(ents) == g.n
