"""Differential-oracle suite for the semiring frontier engine (§12).

The contracts under test:

* the relax algebra itself: instances are hashable (the sharded path
  lru-caches on them), the ⊕-identity/⊗-absorber behaves (a zero-vector
  relaxes to a zero-vector), and idempotent ⊕ (Boolean/tropical/minlabel)
  is insensitive to duplicated edges while counting ⊕ is not.
* ``PropGraph.shortest_paths`` ≡ a pure-numpy Bellman–Ford BITWISE on all
  three DIP backends over seeded randomized graphs — weighted, unweighted,
  pattern-filtered, reversed, undirected, unreachable (+inf) and
  property-masked edges (a weight column assigned on a subset of edges).
* ``PropGraph.pagerank`` ≡ a float64 numpy power iteration within atol,
  unweighted/weighted/vertex-filtered; the ``graph.algorithms.pagerank``
  delegate is regression-pinned BITWISE against a copy of the iteration
  body it replaced (same jaxpr — the §I kernel did not move).
* ``PropGraph.communities`` ≡ a sequential numpy reference replaying the
  documented rule: synchronous rounds, most frequent neighbor label,
  smallest label breaking ties, keep when isolated, capped at 64.
* sharded ≡ single-device for all three analytics, re-proved in a fresh
  P=8 subprocess (pmin/LPA bitwise, psum within atol).
* overlay: snapshots answer bitwise-stably while a writer streams edge
  inserts and weight updates into the parent; forks keep weight writes
  private; the service's analytics result cache dies on a weight-property
  ``MutationEvent`` and survives unrelated property writes.
* hypothesis (optional dep) property tests: relax axioms over random
  graphs, seed-permutation invariance, pattern-reorientation invariance.
"""
import os
import subprocess
import sys
import threading
from functools import partial

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import PropGraph
from repro.graph.algorithms import connected_components, pagerank as algo_pagerank
from repro.traverse import (
    BOOLEAN,
    COUNTING,
    MINLABEL,
    TROPICAL,
    components_masked,
    semiring_relax,
)

try:
    from hypothesis import given, strategies as st

    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _hyp_seeded(f):
    """@given(seed=...) when hypothesis is installed, a skip stub when not
    (requirements-dev.txt makes it optional; conftest pins the profile)."""
    if not HAVE_HYP:
        @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
        def stub():
            pass

        stub.__name__ = f.__name__
        stub.__doc__ = f.__doc__
        return stub
    return given(seed=st.integers(min_value=0, max_value=30))(f)


BACKENDS = ("arr", "list", "listd")


def _build(backend, *, n=16, m=50, seed=0, partial_w=0):
    """Seeded random PropGraph with x/y/z labels, r/s relationships and a
    ``w`` edge weight in [0.5, 2); ``partial_w`` > 0 additionally defines
    ``w2`` on only the first ``partial_w`` edges (the property-masked
    case: everything else has no value, hence is not traversable)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    lab = rng.choice(["x", "y", "z"], size=len(nodes))
    pg.add_node_labels(nodes, lab)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rel = rng.choice(["r", "s"], size=len(es))
    pg.add_edge_relationships(nodes[es], nodes[ed], rel)
    w = rng.uniform(0.5, 2.0, len(es)).astype(np.float32)
    pg.add_edge_properties("w", nodes[es], nodes[ed], w)
    if partial_w:
        pg.add_edge_properties("w2", nodes[es[:partial_w]],
                               nodes[ed[:partial_w]],
                               w[:partial_w] * np.float32(2))
    pg._labels_np, pg._rels_np, pg._w_np = lab, rel, w
    return pg


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


# ------------------------------------------------------------- numpy oracles
def _np_bellman(es, ed, w, n, seed_ids, e_ok, *, undirected=False):
    """Pure-numpy Bellman–Ford in f32.  min is exact and each candidate is
    one f32 add of the same operands the engine adds, so the fixed point
    is bitwise what the tropical relax converges to."""
    t = np.concatenate([es, ed]) if undirected else es
    h = np.concatenate([ed, es]) if undirected else ed
    ok = np.concatenate([e_ok, e_ok]) if undirected else e_ok
    t, h, wv = t[ok], h[ok], (np.concatenate([w, w]) if undirected else w)[ok]
    wv = wv.astype(np.float32)
    dist = np.full(n, np.inf, np.float32)
    dist[seed_ids] = np.float32(0)
    for _ in range(n + 1):
        nd = dist.copy()
        np.minimum.at(nd, h, dist[t] + wv)
        if np.array_equal(nd, dist):
            break
        dist = nd
    return dist


def _np_pagerank(es, ed, w, n, *, v_ok=None, damping=0.85, iters=20):
    """float64 numpy power iteration mirroring ``pagerank_masked``'s
    formula (teleport/dangling over the allowed count); compare atol."""
    w = w.astype(np.float64).copy()
    if v_ok is not None:
        w = np.where(v_ok[es] & v_ok[ed], w, 0.0)
        n_eff = max(float(v_ok.sum()), 1.0)
        r = np.where(v_ok, 1.0 / n_eff, 0.0)
    else:
        n_eff = float(max(n, 1))
        r = np.full(n, 1.0 / max(n, 1))
    out_deg = np.zeros(n)
    np.add.at(out_deg, es, w)
    inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1e-30), 0.0)
    for _ in range(iters):
        agg = np.zeros(n)
        np.add.at(agg, ed, (r * inv)[es] * w)
        dangling = r[out_deg <= 0].sum()
        r = (1 - damping) / n_eff + damping * (agg + dangling / n_eff)
        if v_ok is not None:
            r = np.where(v_ok, r, 0.0)
    return r


def _np_lpa(es, ed, n, *, e_act=None, v_ok=None, max_iters=64):
    """Sequential reference for synchronous label propagation under the
    documented tie-break: per round every vertex takes the most frequent
    label among its allowed (undirected, per-occurrence) neighbors,
    smallest label winning ties, keeping its own when isolated."""
    v_ok = np.ones(n, bool) if v_ok is None else v_ok
    e_act = np.ones(len(es), bool) if e_act is None else e_act
    e_act = e_act & v_ok[es] & v_ok[ed]
    tails = np.concatenate([es, ed])[np.concatenate([e_act, e_act])]
    heads = np.concatenate([ed, es])[np.concatenate([e_act, e_act])]
    labels = np.where(v_ok, np.arange(n), 0).astype(np.int64)
    for _ in range(max_iters):
        new = labels.copy()
        for v in range(n):
            msgs = labels[tails[heads == v]]
            if msgs.size:
                vals, cnts = np.unique(msgs, return_counts=True)
                new[v] = vals[cnts == cnts.max()].min()
        if np.array_equal(new, labels):
            break
        labels = new
    return np.where(v_ok, labels, -1).astype(np.int32)


# ---------------------------------------------------------- relax algebra
def test_semiring_instances_hashable():
    """The sharded relax lru-caches on (mesh, direction, undirected,
    semiring): instances must hash, which means numpy scalars for the
    zero elements — a jnp scalar is an unhashable placed array."""
    assert len({BOOLEAN, TROPICAL, COUNTING, MINLABEL}) == 4
    for sr in (TROPICAL, COUNTING):
        assert isinstance(sr.zero, np.float32), sr.name
    assert not isinstance(MINLABEL.zero, jax.Array)


@pytest.mark.parametrize("sr", [BOOLEAN, TROPICAL, COUNTING, MINLABEL],
                         ids=lambda s: s.name)
def test_relax_zero_vector_absorbs(sr):
    """⊕-identity/⊗-absorber: relaxing the all-zero vector yields the
    all-zero vector for every instance (no edge can manufacture mass)."""
    pg = _build("arr", seed=5)
    g = pg.graph
    if sr is BOOLEAN:
        x = jnp.zeros(g.n, jnp.bool_)
        ev = jnp.ones(g.m, jnp.bool_)
    elif sr is MINLABEL:
        x = jnp.full(g.n, sr.zero, jnp.int32)
        ev = jnp.ones(g.m, jnp.bool_)
    else:
        x = jnp.full(g.n, sr.zero, jnp.float32)
        ev = jnp.asarray(pg._w_np)
    for und in (False, True):
        out = semiring_relax(g, x, ev, sr, undirected=und)
        assert _eq(out, x), (sr.name, und)


def test_idempotent_oplus_ignores_duplicate_edges():
    """min/max ⊕ are idempotent: doubling the edge list changes nothing;
    counting ⊕ is not: contributions double.  (The reason tropical mesh
    rows are bitwise and pagerank rows are atol.)"""
    pg = _build("arr", seed=6)
    g = pg.graph
    from repro.core.di import DIGraph

    g2 = DIGraph(src=jnp.concatenate([g.src, g.src]),
                 dst=jnp.concatenate([g.dst, g.dst]),
                 seg=g.seg, node_map=g.node_map, n=g.n, m=2 * g.m,
                 max_deg=g.max_deg, unsorted=True)
    w = jnp.asarray(pg._w_np)
    w2 = jnp.concatenate([w, w])
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 3, g.n)
                    .astype(np.float32))
    assert _eq(semiring_relax(g, x, w, TROPICAL),
               semiring_relax(g2, x, w2, TROPICAL))
    once = np.asarray(semiring_relax(g, x, w, COUNTING))
    twice = np.asarray(semiring_relax(g2, x, w2, COUNTING))
    assert np.allclose(twice, 2 * once, rtol=1e-6)
    f = jnp.asarray(np.random.default_rng(1).random(g.n) > 0.5)
    ev = jnp.ones(g.m, jnp.bool_)
    assert _eq(semiring_relax(g, f, ev, BOOLEAN),
               semiring_relax(g2, f, jnp.ones(2 * g.m, jnp.bool_), BOOLEAN))


# ------------------------------------------------- shortest paths ≡ oracle
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_shortest_paths_vs_bellman_ford(backend, seed):
    pg = _build(backend, seed=seed)
    g = pg.graph
    nodes = np.asarray(g.node_map)
    es, ed, w = np.asarray(g.src), np.asarray(g.dst), pg._w_np
    seeds = nodes[:3]
    sid = pg._vertex_internal(seeds)
    ones = np.ones(g.m, np.float32)
    all_e = np.ones(g.m, bool)
    r_ok = pg._rels_np == "r"

    # unweighted = hop counts; weighted; pattern-filtered; undirected
    assert _eq(pg.shortest_paths(seeds),
               _np_bellman(es, ed, ones, g.n, sid, all_e))
    got = np.asarray(pg.shortest_paths(seeds, weight="w"))
    assert _eq(got, _np_bellman(es, ed, w, g.n, sid, all_e))
    assert got.dtype == np.float32
    assert np.all(got[sid] == 0.0)
    assert _eq(pg.shortest_paths(seeds, weight="w", pattern="(a)-[:r]->(b)"),
               _np_bellman(es, ed, w, g.n, sid, r_ok))
    # reversed pattern walks edges dst→src
    assert _eq(pg.shortest_paths(seeds, weight="w", pattern="(a)<-[:r]-(b)"),
               _np_bellman(ed, es, w, g.n, sid, r_ok))
    assert _eq(pg.shortest_paths(seeds, weight="w", undirected=True),
               _np_bellman(es, ed, w, g.n, sid, all_e, undirected=True))
    # label-filtered endpoints compose like khop
    xm = pg._labels_np == "x"
    assert _eq(
        pg.shortest_paths(seeds, weight="w", pattern="(a:x)-[:r]->(b)"),
        _np_bellman(es, ed, w, g.n, sid, r_ok & xm[es]))


def test_shortest_paths_unreachable_is_inf():
    """A seed on an isolated vertex: everything else stays +inf."""
    pg = PropGraph().add_edges_from(np.array([1, 2, 3]), np.array([2, 3, 4]))
    nodes = np.asarray(pg.graph.node_map)
    # the chain's sink has no outgoing edges: seeding it reaches nothing
    d = np.asarray(pg.shortest_paths([int(nodes[-1])]))
    assert np.isinf(d).sum() == pg.graph.n - 1, d
    assert np.isfinite(d).sum() == 1


def test_shortest_paths_property_masked_edges():
    """Edges without the weight property are NOT traversable: the column's
    validity mask ANDs into the edge filter (there is no sound default
    weight) — and an unknown property raises KeyError."""
    for backend in BACKENDS:
        pg = _build(backend, seed=7, partial_w=20)
        g = pg.graph
        nodes = np.asarray(g.node_map)
        es, ed = np.asarray(g.src), np.asarray(g.dst)
        sid = pg._vertex_internal(nodes[:3])
        col, valid = pg.edge_props["w2"]
        ref = _np_bellman(es, ed, np.asarray(col, np.float32), g.n, sid,
                          np.asarray(valid))
        assert _eq(pg.shortest_paths(nodes[:3], weight="w2"), ref), backend
        assert np.isinf(ref).sum() > 0, "masked case must exercise +inf"
    with pytest.raises(KeyError, match="nope"):
        pg.shortest_paths(nodes[:3], weight="nope")


# ------------------------------------------------------- pagerank ≡ oracle
@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_vs_numpy(backend):
    pg = _build(backend, seed=3)
    g = pg.graph
    es, ed, w = np.asarray(g.src), np.asarray(g.dst), pg._w_np
    ones = np.ones(g.m, np.float32)

    r = np.asarray(pg.pagerank())
    assert np.allclose(r, _np_pagerank(es, ed, ones, g.n), atol=1e-5)
    assert abs(r.sum() - 1.0) < 1e-4
    rw = np.asarray(pg.pagerank(weight="w"))
    assert np.allclose(rw, _np_pagerank(es, ed, w, g.n), atol=1e-5)
    # relationship filter: disallowed edges carry no mass but vertices stay
    r_ok = (pg._rels_np == "r").astype(np.float32)
    rf = np.asarray(pg.pagerank(pattern="(a)-[:r]->(b)"))
    assert np.allclose(rf, _np_pagerank(es, ed, r_ok, g.n), atol=1e-5)
    # node-only filter: teleport/dangling redistribute over |allowed| and
    # ranks vanish outside it
    vm = pg._labels_np != "z"
    rv = np.asarray(pg.pagerank(pattern="(v:x|y)"))
    assert np.allclose(rv, _np_pagerank(es, ed, ones, g.n, v_ok=vm),
                       atol=1e-5)
    assert np.all(rv[~vm] == 0.0)


def test_pagerank_delegate_matches_old_formula():
    """``graph.algorithms.pagerank`` now delegates to the semiring engine;
    pin it against a verbatim copy of the §I iteration body it replaced,
    with and without an edge mask.  The relax scatter fuses differently
    than the old ``segment_sum``, so the pin is one f32 ulp per step
    (observed ~2e-8 over 20 iterations), not bitwise — the delegate and
    the PropGraph verb ARE bitwise-identical to each other."""

    @partial(jax.jit, static_argnames=("iters",))
    def old_pagerank(g, *, damping=0.85, iters=20, edge_mask=None):
        w = (jnp.ones((g.m,), jnp.float32) if edge_mask is None
             else edge_mask.astype(jnp.float32))
        out_deg = jax.ops.segment_sum(w, g.src, g.n, indices_are_sorted=True)
        inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1e-30), 0.0)

        def step(r, _):
            contrib = r[g.src] * inv_deg[g.src] * w
            agg = jax.ops.segment_sum(contrib, g.dst, g.n)
            dangling = jnp.sum(jnp.where(out_deg > 0, 0.0, r))
            r_new = (1 - damping) / g.n + damping * (agg + dangling / g.n)
            return r_new, None

        r0 = jnp.full((g.n,), 1.0 / max(g.n, 1), jnp.float32)
        r, _ = jax.lax.scan(step, r0, None, length=iters)
        return r

    def pinned(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return np.allclose(a, b, rtol=0, atol=1e-6)

    for seed in (0, 4):
        pg = _build("arr", n=24, m=90, seed=seed)
        g = pg.graph
        assert pinned(algo_pagerank(g), old_pagerank(g)), seed
        em = jnp.asarray(pg._rels_np == "r")
        assert pinned(algo_pagerank(g, edge_mask=em),
                      old_pagerank(g, edge_mask=em)), seed
        assert pinned(algo_pagerank(g, damping=0.7, iters=7),
                      old_pagerank(g, damping=0.7, iters=7)), seed
        # the PropGraph verb with no filter is the same program: bitwise
        assert _eq(pg.pagerank(), algo_pagerank(g)), seed


def test_connected_components_delegate_pinned():
    """``graph.connected_components`` ≡ the engine's masked form with no
    masks — the other pre-semiring kernel that became a delegate."""
    pg = _build("list", n=30, m=70, seed=9)
    assert _eq(connected_components(pg.graph), components_masked(pg.graph))


# ---------------------------------------------------- communities ≡ oracle
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_communities_vs_sequential_oracle(backend, seed):
    pg = _build(backend, seed=seed)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    got = np.asarray(pg.communities())
    assert _eq(got, _np_lpa(es, ed, g.n))
    assert got.dtype == np.int32
    # deterministic: the tie-break is part of the contract
    assert _eq(got, pg.communities())
    # labels are member vertex ids
    assert np.all((got >= 0) & (got < g.n))
    # filtered: only x/y vertices participate, everything else is -1
    vm = pg._labels_np != "z"
    gotf = np.asarray(pg.communities("(v:x|y)"))
    assert _eq(gotf, _np_lpa(es, ed, g.n, v_ok=vm)), (backend, seed)
    assert np.all(gotf[~vm] == -1)
    # relationship-filtered edges
    e_ok = pg._rels_np == "r"
    assert _eq(pg.communities("(a)-[:r]->(b)"),
               _np_lpa(es, ed, g.n, e_act=e_ok))


def test_communities_two_cycle_oscillates_to_the_cap():
    """The classic synchronous-LPA degeneracy: a 2-cycle swaps labels every
    round and never reaches a fixed point, so the 64-round cap returns the
    even-parity state [0, 1].  The oracle must replay exactly that — it is
    part of the determinism contract, not a bug to paper over."""
    pg = PropGraph().add_edges_from(np.array([0, 1]), np.array([1, 0]))
    got = np.asarray(pg.communities())
    assert got.tolist() == [0, 1]
    assert _eq(got, _np_lpa(np.asarray(pg.graph.src),
                            np.asarray(pg.graph.dst), 2))
    # an odd cap lands on the swapped state — the cap is part of the answer
    assert np.asarray(pg.communities(max_iters=7)).tolist() == [1, 0]


# -------------------------------------------------- hypothesis (optional)
@_hyp_seeded
def test_relax_absorption_randomized(seed=0):
    """Zero-vector absorption holds on arbitrary random graphs."""
    rng = np.random.default_rng(seed)
    n, m = int(rng.integers(2, 30)), int(rng.integers(1, 80))
    pg = PropGraph().add_edges_from(rng.integers(0, n, m),
                                    rng.integers(0, n, m))
    g = pg.graph
    w = jnp.asarray(rng.uniform(0, 5, g.m).astype(np.float32))
    assert bool(np.all(np.isinf(np.asarray(semiring_relax(
        g, jnp.full(g.n, TROPICAL.zero, jnp.float32), w, TROPICAL)))))
    assert not np.asarray(semiring_relax(
        g, jnp.zeros(g.n, jnp.bool_), jnp.ones(g.m, jnp.bool_), BOOLEAN)).any()
    assert not np.asarray(semiring_relax(
        g, jnp.zeros(g.n, jnp.float32), w, COUNTING)).any()


@_hyp_seeded
def test_shortest_paths_seed_permutation_invariance(seed=0):
    """Distances are a function of the seed SET: order and duplicates in
    the seed list cannot change the answer (bitwise)."""
    pg = _build("arr", n=20, m=60, seed=seed)
    nodes = np.asarray(pg.graph.node_map)
    seeds = nodes[:4]
    shuffled = list(seeds[::-1]) + [int(seeds[0])]
    a = pg.shortest_paths(list(seeds), weight="w")
    b = pg.shortest_paths(shuffled, weight="w")
    assert _eq(a, b)


@_hyp_seeded
def test_pattern_reorientation_invariance(seed=0):
    """``(a:x)-[:r]->(b:y)`` and ``(b:y)<-[:r]-(a:x)`` denote the same
    edge set; under an undirected traversal (and for communities, which
    are undirected by construction) the answers are bitwise-identical."""
    pg = _build("arr", n=20, m=60, seed=seed)
    nodes = np.asarray(pg.graph.node_map)
    fwd, rev = "(a:x)-[:r]->(b:y)", "(b:y)<-[:r]-(a:x)"
    a = pg.shortest_paths(nodes[:4], weight="w", pattern=fwd, undirected=True)
    b = pg.shortest_paths(nodes[:4], weight="w", pattern=rev, undirected=True)
    assert _eq(a, b)
    assert _eq(pg.communities(fwd), pg.communities(rev))
    assert _eq(pg.pagerank(pattern=fwd),
               np.asarray(pg.pagerank(pattern=rev)))


# ------------------------------------------------------- sharded subprocess
_SUBPROCESS_SCRIPT = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, len(jax.devices())
import sys
sys.path.insert(0, {src!r})
from repro.core import PropGraph
from repro.launch.mesh import make_entity_mesh

rng = np.random.default_rng(11)
src = rng.integers(0, 60, 300)
dst = rng.integers(0, 60, 300)
mesh = make_entity_mesh()
assert mesh.devices.size == 8
pg1 = PropGraph(backend="arr").add_edges_from(src, dst)
pg2 = PropGraph(backend="arr", mesh=mesh).add_edges_from(src, dst)
for pg in (pg1, pg2):
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rng2 = np.random.default_rng(5)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng2.choice(["r", "s"], size=len(es)))
    pg.add_edge_properties("w", nodes[es], nodes[ed],
                           rng2.uniform(0.5, 2.0, len(es)).astype(np.float32))
nodes = np.asarray(pg1.graph.node_map)
seeds = nodes[:4]
# tropical relax all-reduces with pmin: exact, so bitwise
a = np.asarray(pg1.shortest_paths(seeds, weight="w", pattern="(a)-[:r]->(b)"))
b = np.asarray(pg2.shortest_paths(seeds, weight="w", pattern="(a)-[:r]->(b)"))
assert (a == b).all(), np.abs(a - b).max()
assert np.isfinite(a).any() and np.isinf(a).any()
# counting relax all-reduces with psum: reassociates, atol only
a = np.asarray(pg1.pagerank(weight="w"))
b = np.asarray(pg2.pagerank(weight="w"))
assert np.allclose(a, b, atol=1e-5), np.abs(a - b).max()
# the mode relax is all-integer: GSPMD runs the same program, bitwise
a = np.asarray(pg1.communities())
b = np.asarray(pg2.communities())
assert (a == b).all()
print("SEMIRING SHARD8 OK")
"""


def test_sharded_analytics_eight_devices_subprocess():
    """P=8 sharded ≡ single-device for shortest paths (bitwise), PageRank
    (atol) and communities (bitwise) — a fresh interpreter guarantees the
    virtual-device mesh, like tests/test_traverse.py's harness."""
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_SCRIPT.format(src=os.path.abspath(src_dir))],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SEMIRING SHARD8 OK" in proc.stdout


# ------------------------------------------------------------------ overlay
def test_snapshot_analytics_stable_under_streaming_weight_writes():
    """A frozen snapshot's analytics are BITWISE stable while a writer
    streams edge inserts and weight updates into the parent; afterwards
    the parent's answers reflect every delta (≡ oracle on its effective
    edge list)."""
    pg = _build("arr", n=24, m=80, seed=12)
    nodes = np.asarray(pg.graph.node_map)
    seeds = [int(nodes[0]), int(nodes[1])]
    snap = pg.snapshot()
    sp_pin = np.asarray(snap.shortest_paths(seeds, weight="w"))
    pr_pin = np.asarray(snap.pagerank(weight="w"))
    cm_pin = np.asarray(snap.communities())

    stop = threading.Event()
    err: list = []

    es0 = np.asarray(pg.graph.src)
    ed0 = np.asarray(pg.graph.dst)

    def writer():
        rng = np.random.default_rng(99)
        try:
            for i in range(8):
                a = nodes[rng.integers(0, len(nodes), 6)]
                b = nodes[rng.integers(0, len(nodes), 6)]
                pg.insert_edges(a, b)
                # rewrite REAL base edges' weights (pairs that exist)
                sel = rng.integers(0, len(es0), 10)
                pg.update_edge_properties(
                    "w", nodes[es0[sel]], nodes[ed0[sel]],
                    rng.uniform(3.0, 9.0, len(sel)).astype(np.float32))
        except Exception as e:  # noqa: BLE001 — surfaced by the main thread
            err.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=writer)
    t.start()
    reads = 0
    while not stop.is_set() or reads == 0:
        assert _eq(snap.shortest_paths(seeds, weight="w"), sp_pin)
        assert _eq(snap.pagerank(weight="w"), pr_pin)
        assert _eq(snap.communities(), cm_pin)
        reads += 1
    t.join()
    assert not err, err[0]

    # the parent absorbed the stream: recompute the oracle on its
    # EFFECTIVE (base ++ delta) edge list and current weight column —
    # via the engine's own extractor, which pads (0, invalid) for delta
    # edges the column predates
    from repro.query import edge_weight_values

    g = pg._require_graph()  # the combined base ++ delta view, not the base
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    col, valid = edge_weight_values(pg, "w")
    sid = pg._vertex_internal(seeds)
    ref = _np_bellman(es, ed, np.asarray(col, np.float32), g.n, sid,
                      np.asarray(valid))
    assert _eq(pg.shortest_paths(seeds, weight="w"), ref)
    # and a deterministic final write must move the answer off the pin:
    # scaling EVERY base edge weight ×10 scales every finite distance
    pg.update_edge_properties("w", nodes[es0], nodes[ed0],
                              (pg._w_np * 10).astype(np.float32))
    after = np.asarray(pg.shortest_paths(seeds, weight="w"))
    assert not _eq(after, sp_pin)
    # the snapshot STILL answers from its frozen state
    assert _eq(snap.shortest_paths(seeds, weight="w"), sp_pin)


def test_fork_keeps_weight_writes_private():
    pg = _build("arr", n=20, m=60, seed=13)
    nodes = np.asarray(pg.graph.node_map)
    seeds = [int(nodes[0])]
    base = np.asarray(pg.shortest_paths(seeds, weight="w"))
    fork = pg.fork()
    es, ed = np.asarray(fork.graph.src), np.asarray(fork.graph.dst)
    fork.update_edge_properties("w", nodes[es], nodes[ed],
                                (pg._w_np * 10).astype(np.float32))
    fork.insert_edges(nodes[:3], nodes[-3:])
    # parent unchanged, fork reflects its private weights + edges
    assert _eq(pg.shortest_paths(seeds, weight="w"), base)
    from repro.query import edge_weight_values

    g = fork._require_graph()  # combined view: includes the inserted edges
    col, valid = edge_weight_values(fork, "w")
    ref = _np_bellman(np.asarray(g.src), np.asarray(g.dst),
                      np.asarray(col, np.float32), g.n,
                      fork._vertex_internal(seeds), np.asarray(valid))
    assert _eq(fork.shortest_paths(seeds, weight="w"), ref)
    assert not _eq(fork.shortest_paths(seeds, weight="w"), base)


def test_service_analytics_cache_weight_invalidation():
    """The analytics result cache footprints carry the weight property:
    a ``w`` MutationEvent kills the weighted entries; an unrelated
    property write leaves them live; communities (no weight ref)
    survives the weight write."""
    from repro.service import Service

    pg = _build("arr", n=24, m=80, seed=14)
    nodes = np.asarray(pg.graph.node_map)
    seeds = [int(nodes[0]), int(nodes[1])]
    with Service() as svc:
        svc.add_graph("g", pg)
        d0 = svc.shortest_paths("g", seeds, weight="w")
        svc.communities("g")
        s0 = svc.stats()
        assert _eq(svc.shortest_paths("g", seeds, weight="w"), d0)
        assert svc.stats().get("result_hits", 0) == s0.get("result_hits", 0) + 1

        # unrelated property write → entry survives (overlap purge)
        pg.add_node_properties("age", nodes,
                               np.arange(len(nodes), dtype=np.int32))
        s1 = svc.stats()
        assert _eq(svc.shortest_paths("g", seeds, weight="w"), d0)
        assert svc.stats().get("result_hits", 0) == s1.get("result_hits", 0) + 1

        # weight write → weighted entry dies, unweighted communities lives
        es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
        pg.update_edge_properties("w", nodes[es[:10]], nodes[ed[:10]],
                                  np.full(10, 7.5, np.float32))
        s2 = svc.stats()
        d1 = svc.shortest_paths("g", seeds, weight="w")
        st = svc.stats()
        assert st["result_misses"] == s2.get("result_misses", 0) + 1
        assert not _eq(d0, d1) or True  # distances may or may not change,
        # the contract is the recompute (miss), asserted above
        s3 = svc.stats()
        svc.communities("g")
        assert svc.stats().get("result_hits", 0) == s3.get("result_hits", 0) + 1

        # structural write purges everything, analytics included
        pg.insert_edges(nodes[:2], nodes[-2:])
        s4 = svc.stats()
        svc.shortest_paths("g", seeds, weight="w")
        svc.communities("g")
        assert svc.stats().get("result_misses", 0) == s4.get("result_misses", 0) + 2
