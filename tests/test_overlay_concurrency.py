"""Snapshot isolation under concurrent writes (docs/ARCHITECTURE.md §11).

The overlay's concurrency story: a writer appends to the delta chain
(host-side chunk lists, reassigned copy-on-write, never edited in place)
while readers keep answering from a ``snapshot()`` that pinned the chain's
frozen prefix.  The reader must observe EXACTLY the pinned state — every
``components()`` / ``match()`` during the write storm bitwise-identical to
the answer computed before the writer started — with no torn reads and no
writer blocking.

Two layers, mirroring tests/test_shard_pg.py:

* in-process: writer thread streams ``insert_edges`` batches (the delta
  write path is pure host work — no device compilation in the writer)
  while the main thread re-reads the snapshot;
* ``test_snapshot_isolation_eight_devices_subprocess`` re-runs the race on
  a P=8 virtual-device mesh in a fresh interpreter, so the sharded query
  path reads the frozen overlay under write load too.
"""
import os
import subprocess
import sys
import threading
import time

import numpy as np

from repro.core import PropGraph
from repro.graph import random_uniform_graph

PATTERN = "(a:l1|l2)-[:follows]->(b:l3)"
COMP_PATTERN = "(a)-[:follows]->(b)"
N_BATCHES = 10
BATCH = 64


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


def _build(backend="arr", m=800, seed=19):
    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice(["l1", "l2", "l3"], size=len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng.choice(["follows", "likes"], size=len(es)))
    return pg


def _batches(nodes, seed=31):
    rng = np.random.default_rng(seed)
    return [(rng.choice(nodes, BATCH), rng.choice(nodes, BATCH))
            for _ in range(N_BATCHES)]


def test_snapshot_reads_are_isolated_from_writer_thread():
    pg = _build()
    nodes = np.asarray(pg.graph.node_map)
    np.asarray(pg.match(PATTERN).edge_mask)  # seal → writes go to the delta

    snap = pg.snapshot()
    # the ground truth, computed BEFORE any write starts — what every read
    # during the storm must reproduce bitwise
    want_comp = np.asarray(snap.components(COMP_PATTERN))
    want_match = np.asarray(snap.match(PATTERN).vertex_mask)
    want_khop = np.asarray(snap.khop(nodes[:8], 3))

    stop = threading.Event()
    errors = []

    def writer():
        try:
            for bs, bd in _batches(nodes):
                pg.insert_edges(bs, bd)
                pg.add_edge_relationships(bs, bd, ["follows"] * BATCH)
                pg.add_node_labels(bs[:8], ["l1"] * 8)
                time.sleep(0.002)  # interleave with reads
        except Exception as e:  # surface in the main thread
            errors.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=writer)
    t.start()
    reads = 0
    try:
        while not stop.is_set() or reads < 5:
            assert _eq(snap.components(COMP_PATTERN), want_comp)
            assert _eq(snap.match(PATTERN).vertex_mask, want_match)
            assert _eq(snap.khop(nodes[:8], 3), want_khop)
            reads += 1
            if reads > 500:  # safety valve, never hit in practice
                break
    finally:
        t.join(timeout=60)
    assert not errors, errors
    assert reads >= 5
    assert pg.delta_stats()["delta_edges"] > 0  # the writer really wrote

    # the writer's view converged to a from-scratch build of the final state
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    all_s = np.concatenate([nodes[es]] + [b[0] for b in _batches(nodes)])
    all_d = np.concatenate([nodes[ed]] + [b[1] for b in _batches(nodes)])
    ref = PropGraph(backend="arr").add_edges_from(all_s, all_d)
    rng = np.random.default_rng(19)
    ref.add_node_labels(nodes, rng.choice(["l1", "l2", "l3"],
                                          size=len(nodes)))
    ref.add_edge_relationships(nodes[es], nodes[ed],
                               rng.choice(["follows", "likes"], size=len(es)))
    for bs, bd in _batches(nodes):
        ref.add_edge_relationships(bs, bd, ["follows"] * BATCH)
        ref.add_node_labels(bs[:8], ["l1"] * 8)
    assert _eq(pg.components(COMP_PATTERN), ref.components(COMP_PATTERN))
    assert _eq(pg.khop(nodes[:8], 3), ref.khop(nodes[:8], 3))
    assert _eq(pg.match(PATTERN).vertex_mask, ref.match(PATTERN).vertex_mask)
    # ...and the snapshot STILL answers from the pinned state
    assert _eq(snap.components(COMP_PATTERN), want_comp)


def test_service_serves_pinned_snapshot_during_writes():
    """Same race through the service: the snapshot's cached result keeps
    serving hits while the parent absorbs a write stream."""
    from repro.service import Service

    pg = _build(m=600, seed=23)
    nodes = np.asarray(pg.graph.node_map)
    with Service() as svc:
        svc.add_graph("g", pg)
        snap = svc.snapshot_graph("g")
        pinned = svc.query(snap, PATTERN)
        want = np.asarray(pinned.vertex_mask)

        stop = threading.Event()
        errors = []

        def writer():
            try:
                for bs, bd in _batches(nodes, seed=37):
                    pg.insert_edges(bs, bd)
                    time.sleep(0.002)
            except Exception as e:
                errors.append(e)
            finally:
                stop.set()

        t = threading.Thread(target=writer)
        t.start()
        reads = 0
        try:
            while not stop.is_set() or reads < 5:
                got = svc.query(snap, PATTERN)
                assert got is pinned  # cache hit: no recompute, no purge
                assert _eq(got.vertex_mask, want)
                reads += 1
                if reads > 500:
                    break
        finally:
            t.join(timeout=60)
        assert not errors, errors
        assert reads >= 5
        # the parent's entries were structurally purged along the way;
        # a fresh read sees the post-stream graph
        fresh = np.asarray(svc.query("g", PATTERN).vertex_mask)
        assert _eq(fresh, pg.match(PATTERN).vertex_mask)


def test_writes_survive_concurrent_background_compaction():
    """A writer streaming edge/attribute batches while the background
    ``Compactor`` repeatedly folds the overlay must lose NOTHING — the
    per-graph write lock serializes every mutator with compaction's
    gather→rebuild→swap window, so a write can never land inside it and be
    discarded by the swap.  The final compacted graph is bitwise what the
    same batch stream produces with no compactor racing it."""
    from repro.overlay.compactor import Compactor
    from repro.service import GraphRegistry

    def run(compactor_threshold):
        pg = _build(m=600, seed=41)
        nodes = np.asarray(pg.graph.node_map)
        np.asarray(pg.match(PATTERN).edge_mask)  # seal → delta write path
        comp = None
        if compactor_threshold is not None:
            reg = GraphRegistry()
            reg.register("g", pg)
            comp = Compactor(reg, threshold=compactor_threshold,
                             interval=0.001)
            comp.start()
        try:
            for bs, bd in _batches(nodes, seed=53):
                pg.insert_edges(bs, bd)
                pg.add_edge_relationships(bs, bd, ["follows"] * BATCH)
                pg.add_node_labels(bs[:8], ["l1"] * 8)
        finally:
            if comp is not None:
                # let the compactor drain the tail of the stream too, so at
                # least one background compaction is guaranteed to have run
                deadline = time.monotonic() + 60
                while pg.has_overlay() and time.monotonic() < deadline:
                    time.sleep(0.005)
                comp.stop()
                assert comp.compactions >= 1
                assert comp.errors == 0, comp.last_error
        pg.compact()
        return pg

    raced = run(compactor_threshold=16)
    ref = run(compactor_threshold=None)
    assert raced.n_edges == ref.n_edges
    assert raced.n_vertices == ref.n_vertices
    assert _eq(raced.match(PATTERN).vertex_mask, ref.match(PATTERN).vertex_mask)
    assert _eq(raced.match(PATTERN).edge_mask, ref.match(PATTERN).edge_mask)
    assert _eq(raced.components(COMP_PATTERN), ref.components(COMP_PATTERN))
    assert raced.label_counts() == ref.label_counts()
    assert raced.relationship_counts() == ref.relationship_counts()


_SUBPROCESS_SCRIPT = r"""
import threading, time
import numpy as np, jax
assert len(jax.devices()) == 8, len(jax.devices())
import sys
sys.path.insert(0, {src!r})
from repro.core import PropGraph
from repro.graph import random_uniform_graph
from repro.launch.mesh import make_entity_mesh

PATTERN = "(a:l1|l2)-[:follows]->(b:l3)"
COMP = "(a)-[:follows]->(b)"
mesh = make_entity_mesh()
assert mesh.devices.size == 8

rng = np.random.default_rng(19)
src, dst = random_uniform_graph(800, seed=19)
pg = PropGraph(backend="arr", mesh=mesh).add_edges_from(src, dst)
nodes = np.asarray(pg.graph.node_map)
pg.add_node_labels(nodes, rng.choice(["l1", "l2", "l3"], size=len(nodes)))
es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
pg.add_edge_relationships(nodes[es], nodes[ed],
                          rng.choice(["follows", "likes"], size=len(es)))
np.asarray(pg.match(PATTERN).edge_mask)  # seal the sharded stores

snap = pg.snapshot()
want_comp = np.asarray(snap.components(COMP))
want_match = np.asarray(snap.match(PATTERN).vertex_mask)

brng = np.random.default_rng(31)
batches = [(brng.choice(nodes, 64), brng.choice(nodes, 64))
           for _ in range(10)]
stop = threading.Event()
errors = []

def writer():
    try:
        for bs, bd in batches:
            pg.insert_edges(bs, bd)
            pg.add_edge_relationships(bs, bd, ["follows"] * 64)
            time.sleep(0.002)
    except Exception as e:
        errors.append(e)
    finally:
        stop.set()

t = threading.Thread(target=writer)
t.start()
reads = 0
while not stop.is_set() or reads < 3:
    assert (np.asarray(snap.components(COMP)) == want_comp).all(), reads
    assert (np.asarray(snap.match(PATTERN).vertex_mask) == want_match).all(), reads
    reads += 1
    if reads > 500:
        break
t.join(timeout=60)
assert not errors, errors
assert reads >= 3
assert pg.delta_stats()["delta_edges"] > 0

# the mesh parent converged to the single-device delta-path answer
ref = PropGraph(backend="arr").add_edges_from(src, dst)
rng2 = np.random.default_rng(19)
ref.add_node_labels(nodes, rng2.choice(["l1", "l2", "l3"], size=len(nodes)))
ref.add_edge_relationships(nodes[es], nodes[ed],
                           rng2.choice(["follows", "likes"], size=len(es)))
np.asarray(ref.match(PATTERN).edge_mask)  # seal → same delta path
for bs, bd in batches:
    ref.insert_edges(bs, bd)
    ref.add_edge_relationships(bs, bd, ["follows"] * 64)
assert (np.asarray(pg.components(COMP)) == np.asarray(ref.components(COMP))).all()
assert (np.asarray(pg.match(PATTERN).vertex_mask)
        == np.asarray(ref.match(PATTERN).vertex_mask)).all()
assert (np.asarray(snap.components(COMP)) == want_comp).all()  # still pinned
print("OVERLAY8 OK")
"""


def test_snapshot_isolation_eight_devices_subprocess():
    """The same race on a guaranteed P=8 mesh: sharded snapshot reads stay
    pinned while the writer streams delta batches."""
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"  # skip accelerator probing in the child
    proc = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_SCRIPT.format(src=os.path.abspath(src_dir))],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "OVERLAY8 OK" in proc.stdout
