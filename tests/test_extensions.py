"""Beyond-pool extensions: PropGraph persistence, GAT/GraphSAGE, typed
algorithms, gradient-compression integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PropGraph
from repro.core.io import load_propgraph, save_propgraph
from repro.data import synthetic_graph_batch
from repro.graph import random_uniform_graph
from repro.graph.typed_algorithms import (
    attribute_assortativity, khop_typed, label_histogram, typed_components,
)
from repro.models import gat


@pytest.fixture
def pg(rng):
    src, dst = random_uniform_graph(800, seed=5)
    g = PropGraph(backend="arr").add_edges_from(src, dst)
    nodes = np.asarray(g.graph.node_map)
    g.add_node_labels(nodes, rng.choice(["a", "b", "c"], len(nodes)))
    es, ed = np.asarray(g.graph.src), np.asarray(g.graph.dst)
    g.add_edge_relationships(nodes[es], nodes[ed], rng.choice(["x", "y"], len(es)))
    g.add_node_properties("score", nodes, rng.random(len(nodes)).astype(np.float32))
    return g


# ------------------------------------------------------------- persistence
def test_propgraph_save_load_roundtrip(pg, tmp_path):
    p = str(tmp_path / "graph")
    save_propgraph(p, pg)
    back = load_propgraph(p)
    assert back.n_vertices == pg.n_vertices and back.n_edges == pg.n_edges
    q = ["a", "c"]
    assert bool(jnp.all(back.query_labels(q) == pg.query_labels(q)))
    assert bool(jnp.all(back.query_relationships(["x"]) == pg.query_relationships(["x"])))
    col0, _ = pg.vertex_props["score"]
    col1, _ = back.vertex_props["score"]
    np.testing.assert_array_equal(np.asarray(col0), np.asarray(col1))


def test_propgraph_load_different_backend(pg, tmp_path):
    p = str(tmp_path / "graph")
    save_propgraph(p, pg)
    back = load_propgraph(p, backend="listd")
    assert back.backend == "listd"
    assert bool(jnp.all(back.query_labels(["b"]) == pg.query_labels(["b"])))


def test_propgraph_save_overwrites_existing_directory(pg, tmp_path):
    """Regression: saving onto an existing destination must replace it
    safely (the old 'tmp + rename' could not rename onto a non-empty
    directory) and leave no tmp/old litter behind."""
    p = str(tmp_path / "graph")
    save_propgraph(p, pg)
    stale = load_propgraph(p)
    # mutate, overwrite IN PLACE, reload: new content, not the stale save
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, ["fresh"] * len(nodes))
    save_propgraph(p, pg)
    back = load_propgraph(p)
    assert "fresh" in back.label_set()
    assert "fresh" not in stale.label_set()
    assert bool(jnp.all(back.query_labels(["fresh"]) == pg.query_labels(["fresh"])))
    # the swap cleaned up after itself: only the graph dir remains
    assert [e.name for e in tmp_path.iterdir()] == ["graph"]
    # and a THIRD overwrite works too (old dir non-empty both times)
    save_propgraph(p, pg)
    assert load_propgraph(p).n_edges == pg.n_edges


def test_propgraph_cross_backend_reopen_match_bitwise(tmp_path):
    """save on arr → load as list/listd: the full pattern path (labels,
    relationships, predicates) must return bitwise-identical masks on the
    reopened stores."""
    from repro.launch.pgserve import build_tenant_graph, pattern_pool

    pg = build_tenant_graph("arr", 800, seed=21)
    path = save_propgraph(str(tmp_path / "pg"), pg)
    patterns = pattern_pool()[:6]
    refs = {p: pg.match(p) for p in patterns}
    for backend in ("list", "listd"):
        back = load_propgraph(path, backend=backend)
        assert back.backend == backend
        for p in patterns:
            got, ref = back.match(p), refs[p]
            np.testing.assert_array_equal(np.asarray(got.vertex_mask),
                                          np.asarray(ref.vertex_mask), err_msg=p)
            np.testing.assert_array_equal(np.asarray(got.edge_mask),
                                          np.asarray(ref.edge_mask), err_msg=p)
            gb, rb = got.bindings(), ref.bindings()
            assert sorted(gb) == sorted(rb)
            for k in rb:
                np.testing.assert_array_equal(np.asarray(gb[k]),
                                              np.asarray(rb[k]), err_msg=(p, k))


# ---------------------------------------------------------------- GAT/SAGE
def test_gat_smoke_and_grad():
    cfg = gat.GATConfig(d_in=16, d_hidden=4, n_heads=2, n_classes=3)
    b = synthetic_graph_batch(n_nodes=30, n_edges=90, d_feat=16, n_classes=3, seed=0)
    params = gat.init_gat(jax.random.PRNGKey(0), cfg)
    loss = gat.gat_loss(params, b, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(gat.gat_loss)(params, b, cfg)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


def test_gat_attention_normalized():
    """Per-destination attention weights sum to 1 over incoming edges."""
    from repro.graph.segment_ops import segment_softmax

    scores = jnp.asarray(np.random.default_rng(0).standard_normal(50), jnp.float32)
    seg = jnp.sort(jnp.asarray(np.random.default_rng(1).integers(0, 10, 50)))
    alpha = segment_softmax(scores, seg, 10)
    sums = jax.ops.segment_sum(alpha, seg, 10)
    present = np.unique(np.asarray(seg))
    np.testing.assert_allclose(np.asarray(sums)[present], 1.0, rtol=1e-5)


def test_sage_smoke():
    cfg = gat.SAGEConfig(d_in=16, d_hidden=8, n_classes=4)
    b = synthetic_graph_batch(n_nodes=30, n_edges=90, d_feat=16, n_classes=4, seed=1)
    params = gat.init_sage(jax.random.PRNGKey(0), cfg)
    out = gat.sage_forward(params, b, cfg)
    assert out.shape == (30, 4) and np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------- typed algorithms
def test_khop_typed_grows_monotonically(pg):
    g = pg.graph
    e_ok = pg.query_relationships(["x"])
    seeds = jnp.arange(4)
    m1 = khop_typed(g, seeds, e_ok, k=1)
    m3 = khop_typed(g, seeds, e_ok, k=3)
    assert bool(jnp.all(m1 <= m3))
    assert int(m1.sum()) >= 4


def test_label_histogram_counts(pg):
    counts, names = label_histogram(pg)
    assert counts.sum() == pg.n_vertices  # every vertex got exactly one label
    assert set(names) == {"a", "b", "c"}


def test_typed_components_respects_types(pg):
    comps = typed_components(pg, ["x"])
    # vertices joined only by 'y' edges must not merge: verify against a
    # reference union-find over 'x' edges only
    import numpy as np

    g = pg.graph
    e_ok = np.asarray(pg.query_relationships(["x"]))
    parent = np.arange(g.n)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for s, d in zip(np.asarray(g.src)[e_ok], np.asarray(g.dst)[e_ok]):
        ra, rb = find(s), find(d)
        if ra != rb:
            parent[ra] = rb
    ref = np.asarray([find(i) for i in range(g.n)])
    got = np.asarray(comps)
    # same partition ⇔ same pairwise-equality structure (checked via canonical relabel)
    import collections
    canon = {}
    for arr in (ref, got):
        pass
    ref_c = np.unique(ref, return_inverse=True)[1]
    got_c = np.unique(got, return_inverse=True)[1]
    mapping = {}
    ok = True
    for a, b in zip(ref_c, got_c):
        if a in mapping and mapping[a] != b:
            ok = False
            break
        mapping[a] = b
    assert ok and len(set(mapping.values())) == len(mapping)


def test_assortativity_bounds(pg):
    v = attribute_assortativity(pg, ["a"])
    assert 0.0 <= v <= 1.0
