"""Overlay subsystem semantics (src/repro/overlay/, docs/ARCHITECTURE.md §11).

The contracts under test:

* sealed-store attribute mutations land in the delta and query results are
  bitwise what a from-scratch build with the same attributes produces, on
  all three DIP backends — including attribute VALUES first seen after the
  base was sealed;
* ``insert_edges`` (delta edges) / ``delete_vertices`` / ``delete_edges``
  (tombstones) flow through ``match`` / ``khop`` / ``components`` exactly;
* ``snapshot()`` pins an immutable view (writes behind it are invisible,
  its mutators raise); ``fork()`` branches a private writable overlay;
* ``compact()`` is a pure layout change: answers bitwise-identical to a
  from-scratch build of the surviving state;
* the service's overlap-based result-cache invalidation: non-overlapping
  writes keep cached results live, overlapping or structural writes purge,
  snapshot-pinned entries survive parent writes;
* no-op mutations never bump the version (cached results stay live);
* ``save_propgraph`` flattens an overlay on a private fork (compact-on-
  save) so reloads round-trip, without touching the caller's overlay.
"""
import numpy as np
import pytest

from repro.core import PropGraph
from repro.graph import random_uniform_graph
from repro.launch.pgserve import build_tenant_graph
from repro.service import GraphRegistry, Service, ServiceConfig

BACKENDS = ("arr", "list", "listd")
PATTERN = "(a:l1|l2)-[:follows]->(b:l3)"


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


def _edge_pair_set(pg, emask):
    """Edge mask → set of external (u, v) pairs, so masks over differently
    ORDERED edge lists (base++delta view vs sorted rebuild) compare."""
    g = pg._require_graph()
    em = np.asarray(emask)
    nm = np.asarray(g.node_map)
    s, d = np.asarray(g.src)[em], np.asarray(g.dst)[em]
    return set(zip(nm[s].tolist(), nm[d].tolist()))


def _build(backend, m=400, seed=3):
    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice(["l1", "l2", "l3"], size=len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng.choice(["follows", "likes"], size=len(es)))
    return pg


def _replay(backend, m, seed, extra_labels=(), extra_rels=()):
    """From-scratch reference: the same base build plus the given attribute
    batches applied to UNSEALED stores (the pre-overlay rebuild path)."""
    pg = _build(backend, m, seed)
    for nodes, labs in extra_labels:
        pg.add_node_labels(nodes, labs)
    for s, d, r in extra_rels:
        pg.add_edge_relationships(s, d, r)
    return pg


# ----------------------------------------------------------- delta queries
@pytest.mark.parametrize("backend", BACKENDS)
def test_sealed_label_delta_query_parity(backend):
    pg = _build(backend)
    nodes = np.asarray(pg.graph.node_map)
    _ = np.asarray(pg.query_labels(["l1"]))  # builds the store → sealed
    assert pg._vstore.sealed
    batches = [(nodes[:50], ["zz"] * 50),       # value unseen at seal time
               (nodes[50:90], ["l1"] * 40)]     # existing value
    for n, l in batches:
        pg.add_node_labels(n, l)
    assert pg._vstore._delta.size > 0  # really went down the delta path
    ref = _replay(backend, 400, 3, extra_labels=batches)
    for q in (["l1"], ["zz"], ["l1", "zz"], ["l2"], [], ["nope"]):
        assert _eq(pg.query_labels(q), ref.query_labels(q)), q
    # exact stats too: a delta pair duplicating a base pair counts once
    # (set semantics — computed independently here because the unsealed
    # listd base keeps duplicate pairs in its CSR segments)
    rng = np.random.default_rng(3)
    base_labels = rng.choice(["l1", "l2", "l3"], size=len(nodes))
    pairs = set(zip(nodes.tolist(), base_labels.tolist()))
    for n, l in batches:
        pairs |= set(zip(n.tolist(), l))
    want = {}
    for _, lab in pairs:
        want[lab] = want.get(lab, 0) + 1
    assert pg.label_counts() == want


@pytest.mark.parametrize("backend", BACKENDS)
def test_sealed_relationship_delta_query_parity(backend):
    pg = _build(backend)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    _ = np.asarray(pg.query_relationships(["follows"]))  # seal
    assert pg._estore.sealed
    batch = (nodes[es[:30]], nodes[ed[:30]], ["mentions"] * 30)
    pg.add_edge_relationships(*batch)
    assert pg._estore._delta.size > 0
    ref = _replay(backend, 400, 3, extra_rels=[batch])
    for q in (["follows"], ["mentions"], ["follows", "mentions"], ["likes"]):
        assert _eq(pg.query_relationships(q), ref.query_relationships(q)), q
    assert pg.relationship_counts() == ref.relationship_counts()


@pytest.mark.parametrize("backend", BACKENDS)
def test_sealed_delta_match_parity(backend):
    """Full declarative matches read the delta through the mask union."""
    pg = _build(backend)
    nodes = np.asarray(pg.graph.node_map)
    ref0 = pg.match(PATTERN)  # seals both stores
    pg.add_node_labels(nodes[:25], ["l1"] * 25)
    ref = _replay(backend, 400, 3, extra_labels=[(nodes[:25], ["l1"] * 25)])
    got, want = pg.match(PATTERN), ref.match(PATTERN)
    assert _eq(got.vertex_mask, want.vertex_mask)
    assert _eq(got.edge_mask, want.edge_mask)
    assert not _eq(got.vertex_mask, ref0.vertex_mask)  # the write is visible


# ------------------------------------------------------------- delta edges
@pytest.mark.parametrize("backend", BACKENDS)
def test_insert_edges_match_khop_components_parity(backend):
    pg = _build(backend, m=400, seed=5)
    nodes = np.asarray(pg.graph.node_map)
    pg.match(PATTERN)  # seal
    m_base = pg.n_edges
    rng = np.random.default_rng(11)
    bs, bd = rng.choice(nodes, 64), rng.choice(nodes, 64)
    pg.insert_edges(bs, bd)
    pg.add_edge_relationships(bs, bd, ["follows"] * 64)
    assert pg.delta_stats()["delta_edges"] > 0
    assert pg.n_edges == m_base + pg.delta_stats()["delta_edges"]

    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    ref = PropGraph(backend=backend).add_edges_from(
        np.concatenate([nodes[es], bs]), np.concatenate([nodes[ed], bd]))
    rng2 = np.random.default_rng(5)
    ref.add_node_labels(nodes, rng2.choice(["l1", "l2", "l3"],
                                           size=len(nodes)))
    ref.add_edge_relationships(
        nodes[es], nodes[ed],
        rng2.choice(["follows", "likes"], size=len(es)))
    ref.add_edge_relationships(bs, bd, ["follows"] * 64)

    got, want = pg.match(PATTERN), ref.match(PATTERN)
    assert _eq(got.vertex_mask, want.vertex_mask)
    assert _edge_pair_set(pg, got.edge_mask) == _edge_pair_set(ref, want.edge_mask)
    seeds = nodes[:8]
    assert _eq(pg.khop(seeds, 3), ref.khop(seeds, 3))
    assert _eq(pg.components("(a)-[:follows]->(b)"),
               ref.components("(a)-[:follows]->(b)"))


def test_insert_edges_dedup_and_unknown_endpoints():
    pg = _build("arr")
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.match(PATTERN)
    v0 = pg.version
    # re-inserting existing base edges is a no-op (DI: one edge per (u, v))
    pg.insert_edges(nodes[es[:10]], nodes[ed[:10]])
    assert pg.version == v0 and not pg.has_overlay()
    # within-delta duplicates collapse too
    pg.insert_edges([nodes[0]] * 3, [nodes[-1]] * 3)
    assert pg.delta_stats()["delta_edges"] <= 1
    with pytest.raises(ValueError, match="add_edges_from"):
        pg.insert_edges([10**9], [nodes[0]])


# -------------------------------------------------------------- tombstones
def test_tombstone_vertex_blocks_traversal():
    pg = PropGraph(backend="arr").add_edges_from([0, 1], [1, 2])
    assert _eq(pg.khop([0], 2),
               np.ones(3, bool))  # path 0→1→2, node_map = [0, 1, 2]
    pg.delete_vertices([1])
    assert _eq(pg.khop([0], 2), [True, False, False])  # 1 dead, 2 cut off
    assert _eq(pg.components(), [0, -1, 2])  # singletons; dead = -1
    lab = np.asarray(pg.query_labels([]))
    assert not lab.any()


def test_tombstone_edge_and_revival_semantics():
    pg = PropGraph(backend="arr").add_edges_from([0, 1], [1, 2])
    pg.delete_edges([1], [2])
    assert _eq(pg.khop([0], 2), [True, True, False])
    v = pg.version
    pg.delete_edges([1], [2])  # already dead: no-op
    assert pg.version == v
    # delete then re-delete of a missing pair is a no-op too
    pg.delete_edges([2], [0])
    assert pg.version == v


@pytest.mark.parametrize("backend", BACKENDS)
def test_tombstones_vs_numpy_reference(backend):
    """Masked query surfaces against an explicit numpy model of liveness."""
    pg = _build(backend, m=300, seed=9)
    nodes = np.asarray(pg.graph.node_map)
    pg.match(PATTERN)  # seal
    dead_nodes = nodes[5:9]
    pg.delete_vertices(dead_nodes)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.delete_edges(nodes[es[:7]], nodes[ed[:7]])

    alive_v = np.ones(len(nodes), bool)
    alive_v[5:9] = False
    alive_e = np.ones(len(es), bool)
    alive_e[:7] = False
    alive_e &= alive_v[es] & alive_v[ed]

    ref = _build(backend, m=300, seed=9)
    lab = np.asarray(ref.query_labels(["l1"]))
    assert _eq(pg.query_labels(["l1"]), lab & alive_v)
    rel = np.asarray(ref.query_relationships(["follows"]))
    assert _eq(pg.query_relationships(["follows"]), rel & alive_e)
    got = pg.match(PATTERN)
    assert not np.asarray(got.vertex_mask)[~alive_v].any()
    assert not np.asarray(got.edge_mask)[~alive_e].any()


# -------------------------------------------------------- snapshots / forks
def test_snapshot_pins_state_and_freezes_mutators():
    pg = _build("arr")
    nodes = np.asarray(pg.graph.node_map)
    before = pg.match(PATTERN)
    snap = pg.snapshot()
    assert snap.frozen
    # parent keeps absorbing every kind of write...
    pg.add_node_labels(nodes[:20], ["l1"] * 20)
    pg.insert_edges(nodes[:8], nodes[-8:])
    pg.delete_vertices(nodes[:1])
    pg.add_node_properties("age", nodes, np.arange(len(nodes), dtype=np.int32))
    pg.update_node_properties("age", nodes[:3], [99, 99, 99])
    # ...and the snapshot still answers from the pinned state
    got = snap.match(PATTERN)
    assert _eq(got.vertex_mask, before.vertex_mask)
    assert _eq(got.edge_mask, before.edge_mask)
    assert snap.n_edges == len(np.asarray(before.edge_mask))
    # every mutator on the snapshot raises
    for call in (
        lambda: snap.add_edges_from([0], [1]),
        lambda: snap.insert_edges(nodes[:1], nodes[1:2]),
        lambda: snap.add_node_labels(nodes[:1], ["x"]),
        lambda: snap.add_edge_relationships(nodes[:1], nodes[1:2], ["r"]),
        lambda: snap.add_node_properties("p", nodes[:1], [1]),
        lambda: snap.delete_vertices(nodes[:1]),
        lambda: snap.delete_edges(nodes[:1], nodes[1:2]),
        lambda: snap.compact(),
    ):
        with pytest.raises(RuntimeError, match="frozen"):
            call()
    # a fork OF the snapshot is writable again
    branch = snap.fork()
    branch.add_node_labels(nodes[:2], ["x"] * 2)
    assert not branch.frozen


def test_snapshot_of_graph_with_live_overlay():
    """The pinned state includes the delta chain as of the snapshot."""
    pg = _build("arr")
    nodes = np.asarray(pg.graph.node_map)
    pg.match(PATTERN)
    pg.insert_edges(nodes[:6], nodes[-6:])
    pg.add_node_labels(nodes[:10], ["l1"] * 10)
    snap = pg.snapshot()
    want_v = np.asarray(pg.match(PATTERN).vertex_mask)
    pg.insert_edges(nodes[6:12], nodes[-12:-6])  # grows PAST the snapshot
    pg.add_node_labels(nodes[10:30], ["l1"] * 20)
    assert _eq(snap.match(PATTERN).vertex_mask, want_v)
    assert snap.delta_stats()["delta_edges"] == 6


def test_fork_what_if_delete_hub():
    pg = _build("arr", m=500, seed=7)
    nodes = np.asarray(pg.graph.node_map)
    es = np.asarray(pg.graph.src)
    hub = nodes[np.argmax(np.bincount(es, minlength=len(nodes)))]
    comps_before = np.asarray(pg.components())
    v0 = pg.version

    fork = pg.fork()
    fork.delete_vertices([hub])
    forked = np.asarray(fork.components())
    assert not _eq(forked, comps_before)  # the hub held something together

    # the parent never noticed: same answers, same version, no overlay
    assert _eq(pg.components(), comps_before)
    assert pg.version == v0 and not pg.has_overlay()
    # and the fork's own version moved independently
    assert fork.version == v0 + 1


def test_update_properties_are_snapshot_safe():
    pg = _build("arr")
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_properties("age", nodes, np.full(len(nodes), 10, np.int32))
    snap = pg.snapshot()
    pg.update_node_properties("age", nodes[:4], [77] * 4)
    got = np.asarray(pg.vertex_props["age"][0])
    assert (got[pg._vertex_internal(nodes[:4])] == 77).all()
    assert (np.asarray(snap.vertex_props["age"][0]) == 10).all()
    with pytest.raises(KeyError, match="unknown vertex property"):
        pg.update_node_properties("nope", nodes[:1], [1])
    # edge columns pad to the effective edge count when deltas exist
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_properties("w", nodes[es], nodes[ed],
                           np.ones(len(es), np.int32))
    pg.match(PATTERN)
    pg.insert_edges(nodes[:5], nodes[-5:])
    pg.update_edge_properties("w", nodes[:5], nodes[-5:], [3] * 5)
    col, valid = pg.edge_props["w"]
    assert int(col.shape[0]) == pg.n_edges
    assert int(np.asarray(valid).sum()) >= len(es)


# -------------------------------------------------------------- compaction
@pytest.mark.parametrize("backend", BACKENDS)
def test_compact_bitwise_vs_from_scratch(backend):
    """The acceptance criterion proper: after writes of every kind,
    ``compact()`` answers exactly like a from-scratch build of the
    surviving state — match, khop, components."""
    pg = _build(backend, m=400, seed=13)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.match(PATTERN)  # seal
    rng = np.random.default_rng(29)
    bs, bd = rng.choice(nodes, 48), rng.choice(nodes, 48)
    pg.insert_edges(bs, bd)
    pg.add_edge_relationships(bs, bd, ["follows"] * 48)
    pg.add_node_labels(nodes[:30], ["zz"] * 30)
    pg.delete_vertices(nodes[3:5])
    pg.delete_edges(nodes[es[:5]], nodes[ed[:5]])

    # surviving external edge list, gathered from the overlay state itself
    g_eff = pg._require_graph()
    nm = np.asarray(g_eff.node_map)
    s_all, d_all = np.asarray(g_eff.src), np.asarray(g_eff.dst)
    alive = np.ones(len(s_all), bool)
    if pg._dead_e is not None:
        alive[pg._dead_e] = False
    av = ~pg._dead_v
    alive &= av[s_all] & av[d_all]
    surv_s, surv_d = nm[s_all[alive]], nm[d_all[alive]]

    pg.compact()
    assert not pg.has_overlay()
    assert pg._vstore._pairs_e and not pg._vstore.sealed  # fresh base stores

    ref = PropGraph(backend=backend).add_edges_from(surv_s, surv_d)
    ref_nodes = np.asarray(ref.graph.node_map)
    keep = np.isin(nodes, ref_nodes) & av
    rng2 = np.random.default_rng(13)
    labels = rng2.choice(["l1", "l2", "l3"], size=len(nodes))
    rels = rng2.choice(["follows", "likes"], size=len(es))
    ref.add_node_labels(nodes[keep], labels[keep])
    ref.add_edge_relationships(nodes[es], nodes[ed], rels)  # dead pairs drop
    ref.add_edge_relationships(bs, bd, ["follows"] * 48)
    zkeep = keep[:30]
    ref.add_node_labels(nodes[:30][zkeep], ["zz"] * int(zkeep.sum()))

    assert pg.n_vertices == ref.n_vertices and pg.n_edges == ref.n_edges
    got, want = pg.match(PATTERN), ref.match(PATTERN)
    assert _eq(got.vertex_mask, want.vertex_mask)
    assert _eq(got.edge_mask, want.edge_mask)
    seeds = ref_nodes[:8]
    assert _eq(pg.khop(seeds, 3), ref.khop(seeds, 3))
    assert _eq(pg.components("(a)-[:follows]->(b)"),
               ref.components("(a)-[:follows]->(b)"))
    assert _eq(pg.query_labels(["zz"]), ref.query_labels(["zz"]))


def test_compact_is_noop_without_overlay():
    pg = _build("arr")
    v0 = pg.version
    pg.compact()
    assert pg.version == v0


# ------------------------------------------------- service cache contracts
def test_result_cache_overlap_invalidation():
    pg = build_tenant_graph("arr", 600, seed=3)
    with Service() as svc:
        svc.add_graph("g", pg)
        first = svc.query("g", PATTERN)
        assert len(svc.result_cache) == 1
        nodes = np.asarray(pg.graph.node_map)

        # non-overlapping label write: {l9} ∩ {l1,l2,l3} = ∅ → entry lives
        pg.add_node_labels(nodes[:5], ["l9"] * 5)
        assert len(svc.result_cache) == 1
        assert svc.query("g", PATTERN) is first

        # non-overlapping property write: PATTERN references no properties
        pg.update_node_properties("age", nodes[:3], [1, 2, 3])
        assert svc.query("g", PATTERN) is first

        # overlapping relationship write → purge + recompute
        es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
        pg.add_edge_relationships(nodes[es[:6]], nodes[ed[:6]],
                                  ["follows"] * 6)
        assert len(svc.result_cache) == 0
        fresh = svc.query("g", PATTERN)
        assert fresh is not first
        assert _eq(fresh.edge_mask, pg.match(PATTERN).edge_mask)

        # structural write (delta edges) → purge everything for the graph
        svc.query("g", "(a:l9)-[:likes]->(b)")
        assert len(svc.result_cache) >= 1
        pg.insert_edges(nodes[:4], nodes[-4:])
        assert len(svc.result_cache) == 0
        stats = svc.stats()
        assert stats["invalidated_results"] >= 2


def test_snapshot_results_survive_parent_writes():
    pg = build_tenant_graph("arr", 600, seed=4)
    with Service() as svc:
        svc.add_graph("g", pg)
        snap = svc.snapshot_graph("g")
        pinned = svc.query(snap, PATTERN)
        live = svc.query("g", PATTERN)
        nodes = np.asarray(pg.graph.node_map)
        # overlapping AND structural writes on the parent
        pg.add_node_labels(nodes[:9], ["l1"] * 9)
        pg.insert_edges(nodes[:6], nodes[-6:])
        # parent entries died, the snapshot's entry is still served
        assert svc.query(snap, PATTERN) is pinned
        refreshed = svc.query("g", PATTERN)
        assert refreshed is not live
        assert _eq(refreshed.vertex_mask, pg.match(PATTERN).vertex_mask)
        # snapshot at the same version is idempotent
        assert svc.snapshot_graph("g") == svc.snapshot_graph("g")
        # dropping the snapshot clears its cache entries
        svc.drop_graph(snap)
        assert snap not in svc.registry
        assert all(k[0] != snap for k in svc.result_cache._data)


def test_noop_mutations_keep_version_and_cache():
    """Empty batches must not bump the version — a cached result survives
    all nine mutators fed nothing."""
    pg = build_tenant_graph("arr", 400, seed=6)
    with Service() as svc:
        svc.add_graph("g", pg)
        first = svc.query("g", PATTERN)
        v0 = pg.version
        empty = np.zeros(0, np.int64)
        pg.add_edges_from(empty, empty)
        pg.add_node_labels(empty, [])
        pg.add_edge_relationships(empty, empty, [])
        pg.add_node_properties("p_new", empty, empty)
        pg.add_edge_properties("q_new", empty, empty, empty)
        pg.insert_edges(empty, empty)
        pg.delete_vertices(empty)
        pg.delete_edges(empty, empty)
        pg.update_node_properties("age", empty, empty)
        assert pg.version == v0
        assert "p_new" not in pg.vertex_props  # no phantom column either
        assert len(svc.result_cache) == 1
        assert svc.query("g", PATTERN) is first


# -------------------------------------------------------------- compactor
def test_background_compactor_sweeps_by_threshold():
    import time

    from repro.overlay.compactor import Compactor

    reg = GraphRegistry()
    pg = _build("arr", m=300, seed=21)
    reg.register("g", pg)
    pg.match(PATTERN)  # seal
    nodes = np.asarray(pg.graph.node_map)
    pg.insert_edges(nodes[:20], nodes[-20:])
    assert pg.has_overlay()

    comp = Compactor(reg, threshold=4, interval=0.01)
    comp.start()
    deadline = time.monotonic() + 60
    while pg.has_overlay() and time.monotonic() < deadline:
        time.sleep(0.01)
    comp.stop()
    assert not pg.has_overlay()
    assert comp.compactions >= 1

    # frozen snapshots are never compacted; small overlays are left alone
    pg.insert_edges(nodes[:2], nodes[-2:])
    snap = pg.snapshot()
    reg.register("s", snap)
    small = Compactor(reg, threshold=1000)
    assert small.sweep() == 0  # under threshold: untouched
    assert pg.has_overlay()
    big = Compactor(reg, threshold=1)
    assert big.sweep() == 1  # pg compacted, snapshot skipped
    assert not pg.has_overlay() and snap.has_overlay()


@pytest.mark.parametrize("backend", BACKENDS)
def test_insert_after_delete_edge_revives_bare(backend):
    """``delete_edges`` → ``insert_edges`` behaves exactly like the same
    sequence with ``compact()`` in between (compaction transparency): the
    pair exists again as a FRESH bare edge — the dead edge's relationships
    do not carry over — and the re-insert bumps the version so caches
    invalidate."""
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 0])

    def build():
        pg = PropGraph(backend=backend).add_edges_from(src, dst)
        pg.add_edge_relationships([0], [1], ["follows"])
        pg.add_node_labels([0, 1], ["person", "person"])
        return pg

    a = build()
    a.delete_edges([0], [1])
    v0 = a.version
    a.insert_edges([0], [1])
    assert a.version > v0  # the edge universe changed; caches must die

    b = build()
    b.delete_edges([0], [1])
    b.compact()
    b.insert_edges([0], [1])

    for pat in ("(x)-[:follows]->(y)", "(x:person)-[]->(y)"):
        # pre-compaction: same answers (edge universes differ in order only)
        assert _eq(a.match(pat).vertex_mask, b.match(pat).vertex_mask), pat
        assert (_edge_pair_set(a, a.match(pat).edge_mask)
                == _edge_pair_set(b, b.match(pat).edge_mask)), pat
    a.compact()
    b.compact()
    for pat in ("(x)-[:follows]->(y)", "(x:person)-[]->(y)"):
        assert _eq(a.match(pat).vertex_mask, b.match(pat).vertex_mask), pat
        assert _eq(a.match(pat).edge_mask, b.match(pat).edge_mask), pat
    assert a.n_edges == b.n_edges == 4
    # the revived edge is bare: the tombstoned edge's relationship is gone
    assert not np.asarray(a.query_relationships(["follows"])).any()

    # attribute/property writes on the revived pair address the LIVE edge,
    # and deleting it again kills the revived edge, not the old tombstone
    c = build()
    c.delete_edges([0], [1])
    c.insert_edges([0], [1])
    c.add_edge_relationships([0], [1], ["likes"])
    assert c.relationship_counts()["likes"] == 1
    c.delete_edges([0], [1])
    assert c.relationship_counts()["likes"] == 0
    c.compact()
    assert c.n_edges == 3


@pytest.mark.parametrize("backend", BACKENDS)
def test_insert_edges_tombstoned_endpoint_raises(backend):
    """An endpoint tombstoned by ``delete_vertices`` is gone — inserting an
    edge at it raises ``ValueError`` BEFORE compaction exactly as it does
    after (when the vertex has physically left the universe)."""
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 0])

    def build():
        return PropGraph(backend=backend).add_edges_from(src, dst)

    pre = build().delete_vertices([2])
    post = build().delete_vertices([2]).compact()
    for pg in (pre, post):
        with pytest.raises(ValueError):
            pg.insert_edges([1], [2])
        with pytest.raises(ValueError):
            pg.insert_edges([2], [3])


@pytest.mark.parametrize("backend", BACKENDS)
def test_counts_subtract_tombstones(backend):
    """``label_counts`` / ``relationship_counts`` agree with what the
    tombstone-masked query paths return — the planner's 'exact' stats must
    not overcount dead entities."""
    pg = _build(backend, m=300, seed=9)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)

    pg.delete_vertices(nodes[:40])
    want_l = {lab: int(np.asarray(pg.query_labels([lab])).sum())
              for lab in pg.label_set()}
    assert pg.label_counts() == want_l

    # dead edges = explicit tombstones ++ edges detached by dead endpoints
    pg.delete_edges(nodes[es[:25]], nodes[ed[:25]])
    want_r = {r: int(np.asarray(pg.query_relationships([r])).sum())
              for r in pg.relationship_set()}
    assert pg.relationship_counts() == want_r

    # post-compaction the same consistency holds (the universe may shrink
    # further: detached vertices vanish with their labels, like a
    # from-scratch build of the surviving edges)
    pg.compact()
    assert pg.label_counts() == {
        lab: int(np.asarray(pg.query_labels([lab])).sum())
        for lab in pg.label_set()}
    assert pg.relationship_counts() == {
        r: int(np.asarray(pg.query_relationships([r])).sum())
        for r in pg.relationship_set()}


def test_compactor_records_failures_and_skips():
    """A deterministically-failing compaction is counted, surfaced and —
    after MAX_FAILURES consecutive failures — skipped, instead of being
    retried forever in a silent hot loop.  Draining the overlay by other
    means (a manual compact) forgives the graph."""
    from repro.overlay.compactor import Compactor

    reg = GraphRegistry()
    pg = _build("arr", m=200, seed=33)
    reg.register("g", pg)
    pg.match(PATTERN)  # seal
    nodes = np.asarray(pg.graph.node_map)
    pg.insert_edges(nodes[:8], nodes[-8:])
    assert pg.has_overlay()

    comp = Compactor(reg, threshold=1)
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("kaboom")

    pg.compact = boom  # instance attribute shadows the real method
    for _ in range(comp.MAX_FAILURES + 2):
        assert comp.sweep() == 0
    assert len(calls) == comp.MAX_FAILURES  # then skipped, not retried
    assert comp.errors == comp.MAX_FAILURES
    assert "kaboom" in comp.last_error
    assert comp.stats()["failing_graphs"] == {"g": comp.MAX_FAILURES}

    del pg.compact  # restore the real method
    pg.compact()  # manual drain
    assert comp.sweep() == 0  # under threshold now...
    assert comp.stats()["failing_graphs"] == {}  # ...and forgiven
    pg.insert_edges(nodes[:4], nodes[-4:])
    assert comp.sweep() == 1  # compacts again once it is healthy


def test_service_stats_surface_compactor():
    cfg = ServiceConfig(auto_compact_threshold=8)
    with Service(config=cfg) as svc:
        svc.add_graph("g", build_tenant_graph("arr", 300, seed=5))
        st = svc.stats()
        assert st["compactor"]["errors"] == 0
        assert st["compactor"]["failing_graphs"] == {}
    # without auto-compaction there is no compactor section
    with Service() as svc:
        assert "compactor" not in svc.stats()


def test_service_auto_compaction_invalidates_results():
    """Compaction is structural: when the service's background Compactor
    folds the overlay in, cached results for the graph die."""
    import time

    pg = build_tenant_graph("arr", 400, seed=8)
    cfg = ServiceConfig(auto_compact_threshold=8)
    with Service(config=cfg) as svc:
        svc.add_graph("g", pg)
        svc.query("g", PATTERN)
        nodes = np.asarray(pg.graph.node_map)
        pg.insert_edges(nodes[:16], nodes[-16:])  # past the threshold
        deadline = time.monotonic() + 60
        while pg.has_overlay() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not pg.has_overlay()
        assert len(svc.result_cache) == 0
        got = svc.query("g", PATTERN)
        assert _eq(got.edge_mask, pg.match(PATTERN).edge_mask)


# ------------------------------------------------------------- persistence
@pytest.mark.parametrize("backend", BACKENDS)
def test_save_flattens_overlay_and_roundtrips(backend, tmp_path):
    from repro.core.io import load_propgraph, save_propgraph

    pg = _build(backend, m=300, seed=17)
    nodes = np.asarray(pg.graph.node_map)
    pg.match(PATTERN)  # seal
    pg.insert_edges(nodes[:12], nodes[-12:])
    pg.add_edge_relationships(nodes[:12], nodes[-12:], ["follows"] * 12)
    pg.add_node_labels(nodes[:15], ["zz"] * 15)
    stats_before = pg.delta_stats()

    path = save_propgraph(str(tmp_path / "pg"), pg)
    # compact-on-save ran on a private fork: the caller's overlay is intact
    assert pg.delta_stats() == stats_before and pg.has_overlay()

    flat = pg.fork()
    flat.compact()
    for b2 in BACKENDS:
        got = load_propgraph(path, backend=b2)
        assert got.n_vertices == flat.n_vertices
        assert got.n_edges == flat.n_edges
        r1, r2 = got.match(PATTERN), flat.match(PATTERN)
        assert _eq(r1.vertex_mask, r2.vertex_mask)
        assert _eq(r1.edge_mask, r2.edge_mask)
        assert _eq(got.query_labels(["zz"]), flat.query_labels(["zz"]))

    # save → mutate → save again → reload picks up the second overlay too
    pg.insert_edges(nodes[12:20], nodes[-20:-12])
    save_propgraph(str(tmp_path / "pg"), pg)
    flat2 = pg.fork()
    flat2.compact()
    got2 = load_propgraph(str(tmp_path / "pg"), backend=backend)
    assert got2.n_edges == flat2.n_edges
    assert _eq(got2.match(PATTERN).edge_mask, flat2.match(PATTERN).edge_mask)
