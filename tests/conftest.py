"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py forces 512 placeholder devices."""
import os

import numpy as np
import pytest

try:  # optional dep (requirements-dev.txt): property tests importorskip it
    from hypothesis import settings

    # deterministic CI profile: derandomize pins the example stream to the
    # test body (no hidden per-run seed — the stale-seed wart), no deadline
    # because first-call jit compilation dwarfs any per-example budget
    settings.register_profile("ci", deadline=None, derandomize=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
