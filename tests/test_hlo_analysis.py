"""HLO analyzer: exact dot-FLOP counting + while-loop trip multiplication.

The analyzer is roofline-critical infrastructure; these tests pin its
semantics against tiny compiled programs with known analytic costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    t = analyze_hlo(_compile(lambda a, b: a @ b, a, b))
    assert t["flops"] == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_multiplies_flops_by_trip_count():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def fn(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    t = analyze_hlo(_compile(fn, a))
    assert t["flops"] == pytest.approx(7 * 2 * 32 ** 3, rel=0.05)


def test_nested_scan_trips_compose():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def fn(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    t = analyze_hlo(_compile(fn, a))
    assert t["flops"] == pytest.approx(15 * 2 * 16 ** 3, rel=0.05)


def test_layers_scale_linearly():
    """The failure mode that motivated the analyzer: cost_analysis reports
    L-independent FLOPs for scanned layers; analyze_hlo must scale."""
    def make(nl):
        w = jax.ShapeDtypeStruct((nl, 32, 32), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

        def fn(w, x):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            y, _ = jax.lax.scan(body, x, w)
            return y
        return analyze_hlo(_compile(fn, w, x))["flops"]

    f2, f8 = make(2), make(8)
    assert f8 / f2 == pytest.approx(4.0, rel=0.05)


def test_bytes_reasonable_for_copy():
    """A memcpy-like op: traffic ≈ 2×payload (+args read once), not 100×."""
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    t = analyze_hlo(_compile(lambda x: x * 2.0, a))
    payload = 1024 * 1024 * 4
    assert payload <= t["bytes"] <= 6 * payload
