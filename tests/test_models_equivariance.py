"""Property tests: E(3) equivariance of the molecular models under random
rotations + translations (the MACE/DimeNet correctness contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests are optional off-CI
from hypothesis import given, settings, strategies as st

from repro.data import synthetic_graph_batch
from repro.models import dimenet, mace


def _rotation(seed):
    rng = np.random.default_rng(seed)
    a, b, c = rng.uniform(0, 2 * np.pi, 3)
    rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0], [0, 0, 1]])
    rx = np.array([[1, 0, 0], [0, np.cos(b), -np.sin(b)], [0, np.sin(b), np.cos(b)]])
    ry = np.array([[np.cos(c), 0, np.sin(c)], [0, 1, 0], [-np.sin(c), 0, np.cos(c)]])
    return (rz @ rx @ ry).astype(np.float32)


def _transform(batch, R, t):
    import dataclasses as dc
    pos = jnp.asarray(np.asarray(batch.pos) @ R.T + t)
    return dc.replace(batch, pos=pos)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mace_invariance(seed):
    cfg = mace.MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = mace.init_params(jax.random.PRNGKey(0), cfg)
    b = synthetic_graph_batch(n_nodes=24, n_edges=80, with_pos=True, n_species=4,
                              n_graphs=2, seed=seed)
    R, t = _rotation(seed), np.float32(np.random.default_rng(seed).normal(size=3))
    e0 = mace.forward(params, b, cfg)
    e1 = mace.forward(params, _transform(b, R, t), cfg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4, atol=1e-4)


def test_mace_force_equivariance():
    """Forces (−∂E/∂pos) rotate with the frame: F(Rx) = R·F(x)."""
    cfg = mace.MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = mace.init_params(jax.random.PRNGKey(0), cfg)
    b = synthetic_graph_batch(n_nodes=16, n_edges=48, with_pos=True, n_species=4, seed=1)
    R = _rotation(3)

    def energy(pos, batch):
        import dataclasses as dc
        return mace.forward(params, dc.replace(batch, pos=pos), cfg).sum()

    f0 = -np.asarray(jax.grad(energy)(b.pos, b))
    b_rot = _transform(b, R, np.zeros(3, np.float32))
    f1 = -np.asarray(jax.grad(energy)(b_rot.pos, b_rot))
    np.testing.assert_allclose(f1, f0 @ R.T, rtol=1e-3, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_dimenet_invariance(seed):
    cfg = dimenet.DimeNetConfig(n_blocks=2, d_hidden=16, n_bilinear=4,
                                n_spherical=3, n_radial=3, n_species=4)
    params = dimenet.init_params(jax.random.PRNGKey(0), cfg)
    b = synthetic_graph_batch(n_nodes=20, n_edges=60, with_pos=True, n_species=4,
                              with_triplets=True, seed=seed)
    R, t = _rotation(seed + 1), np.float32([1.0, -2.0, 0.5])
    e0 = dimenet.forward(params, b, cfg)
    e1 = dimenet.forward(params, _transform(b, R, t), cfg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4, atol=1e-4)


def test_mace_permutation_invariance():
    """Energy invariant under relabeling atoms (permutation of node ids)."""
    import dataclasses as dc

    cfg = mace.MACEConfig(channels=8, n_rbf=4, n_species=4)
    params = mace.init_params(jax.random.PRNGKey(0), cfg)
    b = synthetic_graph_batch(n_nodes=12, n_edges=36, with_pos=True, n_species=4, seed=5)
    perm = np.random.default_rng(0).permutation(12)
    inv = np.argsort(perm)
    b2 = dc.replace(
        b,
        pos=b.pos[perm], species=b.species[perm],
        edge_src=jnp.asarray(inv)[b.edge_src], edge_dst=jnp.asarray(inv)[b.edge_dst],
        graph_ids=b.graph_ids[perm], node_mask=b.node_mask[perm],
    )
    e0 = mace.forward(params, b, cfg)
    e1 = mace.forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=1e-4, atol=1e-4)
