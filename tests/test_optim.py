"""Optimizer + gradient compression tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamWConfig, apply_updates, cosine_schedule, init_state
from repro.optim.compression import compress_with_feedback, decompress, init_error_state


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_state(params)
    for _ in range(100):
        grads = jax.tree.map(lambda w: 2 * w, params)
        params, state, m = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_norm():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = init_state(params)
    _, _, metrics = apply_updates(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(cosine_schedule(cfg, jnp.int32(5))) == pytest.approx(0.5, rel=0.05)
    assert float(cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=0.05)


def test_compression_roundtrip_accuracy():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)}
    comp, err = compress_with_feedback(g, init_error_state(g))
    back = decompress(comp)
    # int8 with per-tensor scale: ~1% of amax error bound
    amax = float(jnp.abs(g["a"]).max())
    assert float(jnp.abs(back["a"] - g["a"]).max()) <= amax / 127 + 1e-6
    assert comp.q["a"].dtype == jnp.int8  # 4× smaller all-reduce payload


def test_error_feedback_unbiased_over_time():
    """With error feedback, the SUM of decompressed grads tracks the sum of
    true grads (residual never lost)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(64) * 1e-3, jnp.float32)
    err = init_error_state({"w": g_true})
    total = jnp.zeros(64)
    for _ in range(32):
        comp, err = compress_with_feedback({"w": g_true}, err)
        total = total + decompress(comp)["w"]
    drift = float(jnp.abs(total - 32 * g_true).max())
    assert drift <= float(jnp.abs(g_true).max()) + 1e-5  # bounded by one-step residual
