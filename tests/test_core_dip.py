"""DIP attribute stores: cross-variant equivalence (the paper's §IV contract —
all three variants answer identical queries) + store-specific behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests are optional off-CI
from hypothesis import given, settings, strategies as st

from repro.core import (
    AttributeMap, build_dip_arr, build_dip_list, build_dip_listd,
)
from repro.core import dip_arr, dip_list, dip_listd


@st.composite
def attr_instance(draw):
    n = draw(st.integers(2, 200))
    k = draw(st.integers(1, 20))
    nnz = draw(st.integers(0, 400))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    ents = rng.integers(0, n, nnz)
    attrs = rng.integers(0, k, nnz)
    qmask = rng.random(k) < 0.3
    return n, k, ents, attrs, qmask


@settings(max_examples=60, deadline=None)
@given(inst=attr_instance())
def test_variant_equivalence(inst):
    """DIP-ARR (scan & matvec), DIP-LIST, DIP-LISTD (linked & inverted) agree."""
    n, k, ents, attrs, qmask = inst
    qm = jnp.asarray(qmask)
    arr = build_dip_arr(ents, attrs, k=k, n=n)
    lst = build_dip_list(ents, attrs, k=k, n=n)
    lkd = build_dip_listd(ents, attrs, k=k, n=n)

    ref = np.zeros(n, bool)
    for e, a in zip(ents, attrs):
        if qmask[a]:
            ref[e] = True

    assert (np.asarray(dip_arr.query_any_scan(arr, qm)) == ref).all()
    assert (np.asarray(dip_arr.query_any_matvec(arr, qm)) == ref).all()
    assert (np.asarray(dip_list.query_any(lst, qm)) == ref).all()
    assert (np.asarray(dip_listd.query_any_linked(lkd, qm)) == ref).all()
    assert (np.asarray(dip_listd.query_any_inverted(lkd, qm)) == ref).all()


@settings(max_examples=30, deadline=None)
@given(inst=attr_instance())
def test_budget_query(inst):
    n, k, ents, attrs, qmask = inst
    lkd = build_dip_listd(ents, attrs, k=k, n=n)
    ids = np.flatnonzero(qmask).astype(np.int32)
    if len(ids) == 0:
        ids = np.array([-1], np.int32)
    a_off = np.asarray(lkd.a_off)
    budget = int(sum(a_off[i + 1] - a_off[i] for i in ids if i >= 0)) + 8
    got = dip_listd.query_any_budget(lkd, jnp.asarray(ids), budget=budget)
    ref = np.zeros(n, bool)
    for e, a in zip(ents, attrs):
        if qmask[a]:
            ref[e] = True
    assert (np.asarray(got) == ref).all()


@settings(max_examples=30, deadline=None)
@given(inst=attr_instance())
def test_entity_attribute_roundtrip(inst):
    """attrs_of_entity agrees between ARR and LIST (padded)."""
    n, k, ents, attrs, _ = inst
    arr = build_dip_arr(ents, attrs, k=k, n=n)
    lst = build_dip_list(ents, attrs, k=k, n=n)
    e = int(ents[0]) if len(ents) else 0
    from_arr = set(np.flatnonzero(np.asarray(dip_arr.attrs_of_entity(arr, jnp.int32(e)))))
    vals, valid = dip_list.attrs_of_entity_padded(lst, jnp.int32(e), max_k=k)
    from_lst = set(np.asarray(vals)[np.asarray(valid)].tolist())
    assert from_arr == from_lst


def test_listd_chain_structure():
    """Linked chains replay insertion order; last_tracker points at the tail."""
    d = build_dip_listd([0, 1, 2, 1], [5, 5, 5, 3], k=6, n=3)
    lt = np.asarray(d.last_tracker)
    assert lt[5] == 2 and lt[3] == 3
    # walk attr 5 backwards: entities 2 -> 1 -> 0
    prev = np.asarray(d.prev)
    ent = np.asarray(d.entity)
    chain = []
    node = lt[5]
    while node >= 0:
        chain.append(int(ent[node]))
        node = prev[node]
    assert chain == [2, 1, 0]


def test_attribute_map():
    am = AttributeMap()
    ids = am.encode(["a", "b", "a", "c"])
    assert ids.tolist() == [0, 1, 0, 2]
    assert am.decode([2, 0]) == ["c", "a"]
    assert am.lookup("missing") == -1
    mask = am.mask(["a", "missing", "c"], k=4)
    assert mask.tolist() == [True, False, True, False]


def test_empty_attribute_sets():
    """Label/relationship/property sets can be empty (paper Fig. 1 note)."""
    arr = build_dip_arr([], [], k=1, n=5)
    assert not np.asarray(dip_arr.query_any_matvec(arr, jnp.ones(1, bool))).any()
