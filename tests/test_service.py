"""Service-layer semantics (src/repro/service/, docs/ARCHITECTURE.md §8).

The contracts under test:

* coalesced / batched execution is bitwise-equal to sequential
  ``PropGraph.match`` on ALL three backends (and on a device mesh when the
  interpreter has >1 device — CI runs the suite under 8 virtual devices);
* the result cache is invalidated by ``add_node_labels`` /
  ``add_edges_from`` version bumps (registry → mutation hook → purge);
* plan-cache hits are accounted (and survive mutations — plans are keyed
  without the graph version on purpose);
* mesh-mode stores never cache a dense single-device replica (the PR 2
  follow-up: per-device memory O(NK/P)).
"""
import threading

import numpy as np
import pytest

from repro.core import PropGraph
from repro.graph import random_uniform_graph
from repro.launch.pgserve import build_tenant_graph
from repro.service import GraphRegistry, LRUCache, Service, ServiceConfig
from repro.service.scheduler import execute_coalesced

BACKENDS = ("arr", "list", "listd")
PATTERNS = (
    "(a:l1|l2)-[:follows]->(b:l3)",
    "(a:l0 {age > 30})-[:likes]->(b)",
    "(a)<-[:likes]-(b:l4|l5)",
    "(a:l6)-[:follows]->(b)-[:likes]->(c:l7)",
)


def _build(backend, m=800, seed=3, mesh=None):
    # the same synthetic tenant the smoke/bench paths serve — one recipe
    return build_tenant_graph(backend, m, mesh=mesh, seed=seed)


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


def _assert_same_result(got, ref):
    assert _eq(got.vertex_mask, ref.vertex_mask)
    assert _eq(got.edge_mask, ref.edge_mask)
    gb, rb = got.bindings(), ref.bindings()
    assert sorted(gb) == sorted(rb)
    for k in rb:
        assert _eq(gb[k], rb[k]), k


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("backend", BACKENDS)
def test_coalesced_batch_equals_sequential_match(backend):
    """query_batch (ONE coalesced group, deterministic composition) ≡
    per-request match, bitwise, duplicates included."""
    pg = _build(backend)
    patterns = list(PATTERNS) + [PATTERNS[0], PATTERNS[2]]  # dups coalesce
    with Service() as svc:
        svc.add_graph("g", pg)
        got = svc.query_batch("g", patterns)
        stats = svc.stats()
    for p, res in zip(patterns, got):
        _assert_same_result(res, pg.match(p))
    if backend == "arr":
        assert stats["coalesced_launches"] > 0
        assert stats["coalesced_masks"] >= 4
    else:
        assert stats["fallback_requests"] > 0  # same API, per-request path
    assert stats["dedup_hits"] == 2


@pytest.mark.parametrize("backend", BACKENDS)
def test_concurrent_submit_equals_sequential_match(backend):
    """Futures resolved through the async micro-batching path carry the
    same masks as direct match, regardless of how batches formed."""
    pg = _build(backend)
    refs = {p: pg.match(p) for p in PATTERNS}
    with Service() as svc:
        svc.add_graph("g", pg)
        futs = []
        threads = [
            threading.Thread(
                target=lambda p=p: futs.append((p, svc.submit("g", p))))
            for p in PATTERNS for _ in range(3)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for p, f in futs:
            _assert_same_result(f.result(timeout=120), refs[p])


def test_execute_coalesced_bucket_padding_exact():
    """Padding Q to a bucket with empty queries must not leak into results
    (pad rows are all-False and sliced off)."""
    pg = _build("arr")
    from repro.query import parse, plan_pattern

    for n_plans in (1, 2, 3):  # crosses Q buckets 2 and 4 with edge masks
        plans = [plan_pattern(pg, parse(p)) for p in PATTERNS[:n_plans]]
        got = execute_coalesced(pg, plans)
        for p, res in zip(PATTERNS[:n_plans], got):
            _assert_same_result(res, pg.match(p))


@pytest.mark.skipif(
    len(__import__("jax").devices()) < 2,
    reason="mesh equivalence needs >1 device (CI forces 8)",
)
def test_service_on_mesh_equals_single_device():
    from repro.launch.mesh import make_entity_mesh

    mesh = make_entity_mesh()
    pg1 = _build("arr")
    pg2 = _build("arr", mesh=mesh)
    with Service() as svc:
        svc.add_graph("g", pg2)
        for res, p in zip(svc.query_batch("g", list(PATTERNS)), PATTERNS):
            _assert_same_result(res, pg1.match(p))


# ------------------------------------------------------------ invalidation
def test_result_cache_invalidated_by_label_mutation():
    pg = _build("arr")
    with Service() as svc:
        svc.add_graph("g", pg)
        v0 = svc.registry.version("g")
        first = svc.query("g", PATTERNS[0])
        assert svc.query("g", PATTERNS[0]) is first  # cached object served
        nodes = np.asarray(pg.graph.node_map)
        pg.add_node_labels(nodes[:9], ["l1"] * 9)  # version bump via hook
        assert svc.registry.version("g") == v0 + 1
        stats = svc.stats()
        assert stats["invalidated_results"] >= 1
        assert len(svc.result_cache) == 0  # eager purge, not just new keys
        fresh = svc.query("g", PATTERNS[0])
        _assert_same_result(fresh, pg.match(PATTERNS[0]))
        assert not _eq(fresh.vertex_mask, first.vertex_mask)  # l1 grew


def test_result_cache_invalidated_by_edge_rebuild():
    """add_edges_from (structure rebuild) also bumps + purges."""
    pg = _build("arr", m=400, seed=5)
    with Service() as svc:
        svc.add_graph("g", pg)
        svc.query("g", PATTERNS[0])
        assert len(svc.result_cache) == 1
        src, dst = random_uniform_graph(500, seed=11)
        pg.add_edges_from(src, dst)  # fresh stores, attributes dropped
        assert len(svc.result_cache) == 0
        nodes = np.asarray(pg.graph.node_map)
        pg.add_node_labels(nodes, ["l1"] * len(nodes))
        pg.add_edge_relationships(
            nodes[np.asarray(pg.graph.src)], nodes[np.asarray(pg.graph.dst)],
            ["follows"] * pg.n_edges)
        res = svc.query("g", "(a:l1)-[:follows]->(b:l1)")
        _assert_same_result(res, pg.match("(a:l1)-[:follows]->(b:l1)"))


def test_version_counter_covers_every_mutator():
    pg = PropGraph(backend="arr")
    assert pg.version == 0
    src, dst = random_uniform_graph(200, seed=1)
    pg.add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes[:5], ["x"] * 5)
    pg.add_edge_relationships(src[:3], dst[:3], ["r"] * 3)
    pg.add_node_properties("p", nodes[:5], np.arange(5))
    pg.add_edge_properties("q", src[:3], dst[:3], np.arange(3))
    assert pg.version == 5


# -------------------------------------------------------------- accounting
def test_plan_cache_hit_accounting():
    """Same canonical pattern → one plan miss then hits; plans survive
    version bumps (keyed without version — perf-only staleness)."""
    pg = _build("arr")
    cfg = ServiceConfig(result_cache_size=0)  # isolate the plan cache
    with Service(config=cfg) as svc:
        svc.add_graph("g", pg)
        svc.query("g", PATTERNS[0])
        svc.query("g", " (a:l1|l2)-[:follows]->(b:l3) ")  # canonicalizes same
        stats = svc.stats()
        assert stats["plan_misses"] == 1
        assert stats["plan_hits"] == 1
        pg.add_node_labels(np.asarray(pg.graph.node_map)[:3], ["l9"] * 3)
        svc.query("g", PATTERNS[0])
        assert svc.stats()["plan_hits"] == 2  # survived the bump


def test_bad_request_does_not_poison_cobatched_group():
    """A request that fails planning (unknown property) must fail alone —
    co-batched valid requests still get their results."""
    pg = _build("arr")
    with Service(config=ServiceConfig(window_ms=250.0)) as svc:
        svc.add_graph("g", pg)
        bad = svc.submit("g", "(a {nosuchprop > 1})-[:follows]->(b)")
        good = svc.submit("g", PATTERNS[0])  # same window, same group
        with pytest.raises(KeyError, match="nosuchprop"):
            bad.result(timeout=120)
        _assert_same_result(good.result(timeout=120), pg.match(PATTERNS[0]))
    # the deterministic form, via the shared serve pipeline directly
    with Service() as svc:
        svc.add_graph("g", pg)
        good_c, good_ast = svc._canon(PATTERNS[0])
        bad_c, bad_ast = svc._canon("(a {nosuchprop > 1})-[:follows]->(b)")
        out = svc._serve_group(pg, "g", None,
                               {bad_c: bad_ast, good_c: good_ast})
        assert isinstance(out[bad_c], KeyError)
        _assert_same_result(out[good_c], pg.match(PATTERNS[0]))


def test_result_cache_hit_and_fastpath_accounting():
    pg = _build("arr")
    with Service() as svc:
        svc.add_graph("g", pg)
        svc.query("g", PATTERNS[0])
        svc.query("g", PATTERNS[0])
        svc.query_batch("g", [PATTERNS[0]])
        stats = svc.stats()
    assert stats["result_hits"] == 2
    assert stats["fastpath_hits"] == 1  # 2nd query skipped the queue
    assert stats["result_misses"] == 1


def test_lru_cache_eviction_and_disable():
    c = LRUCache(2)
    c.put("a", 1), c.put("b", 2)
    assert c.get("a") == 1
    c.put("c", 3)  # evicts b (a was refreshed)
    assert c.get("b") is None and c.get("c") == 3
    assert c.stats()["evictions"] == 1
    off = LRUCache(0)
    off.put("a", 1)
    assert off.get("a") is None and len(off) == 0


def test_lru_cache_put_on_existing_key_refreshes_recency():
    """Regression: re-inserting a hot key must move it to the MRU end —
    an overwrite that leaves the entry in its old position gets the entry
    evicted as if cold."""
    c = LRUCache(2)
    c.put("hot", 1)
    c.put("b", 2)
    c.put("hot", 10)  # overwrite must also refresh recency
    c.put("c", 3)  # evicts b — NOT the just-re-inserted "hot"
    assert c.get("hot") == 10
    assert c.get("b") is None
    assert c.get("c") == 3


def test_registry_concurrent_subscribe_during_notify():
    """Regression: subscribe/unsubscribe racing an in-flight _notify must
    not corrupt the listener list (snapshot under the registry lock)."""
    pg = _build("arr", m=300, seed=9)
    reg = GraphRegistry()
    reg.register("g", pg)
    nodes = np.asarray(pg.graph.node_map)
    stop = threading.Event()
    errors = []

    def churn():
        try:
            while not stop.is_set():
                listeners = [lambda name, g: None for _ in range(4)]
                for ln in listeners:
                    reg.subscribe(ln)
                for ln in listeners:
                    reg.unsubscribe(ln)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    def mutate():
        try:
            for i in range(60):  # every mutation fires _notify
                pg.add_node_labels(nodes[:2], [f"l{i % 3}"] * 2)
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    churners = [threading.Thread(target=churn) for _ in range(3)]
    mut = threading.Thread(target=mutate)
    for t in churners:
        t.start()
    mut.start()
    mut.join(timeout=120)
    stop.set()
    for t in churners:
        t.join(timeout=30)
    assert not errors
    # steady state: only the registration hook's listeners remain
    survivor = []
    reg.subscribe(lambda name, g: survivor.append(name))
    pg.add_node_labels(nodes[:2], ["x"] * 2)
    assert survivor == ["g"]


# ---------------------------------------------------------------- registry
def test_registry_load_and_errors(tmp_path):
    from repro.core.io import save_propgraph

    pg = _build("arr", m=300, seed=9)
    path = save_propgraph(str(tmp_path / "pg"), pg)
    reg = GraphRegistry()
    reg.load("disk", path, backend="listd")
    assert "disk" in reg and reg.names() == ["disk"]
    got = reg.get("disk").match(PATTERNS[0])
    _assert_same_result(got, pg.match(PATTERNS[0]))
    with pytest.raises(KeyError, match="unknown graph"):
        reg.get("nope")
    with Service(registry=reg) as svc:
        with pytest.raises(KeyError, match="unknown graph"):
            svc.submit("nope", PATTERNS[0]).result(timeout=60)
    assert reg._listeners == []  # closed service detached from the registry


def test_registry_reregister_is_idempotent_and_silences_replaced_graph():
    """Refreshing a registration must not stack duplicate hooks, and a
    replaced graph's mutations must stop notifying under the name."""
    pg1 = _build("arr", m=300, seed=9)
    pg2 = _build("arr", m=300, seed=10)
    reg = GraphRegistry()
    events = []
    reg.subscribe(lambda name, pg: events.append((name, pg)))
    reg.register("g", pg1)
    reg.register("g", pg1)  # refresh: same graph, no extra hook
    events.clear()
    nodes = np.asarray(pg1.graph.node_map)
    pg1.add_node_labels(nodes[:2], ["x"] * 2)
    assert len(events) == 1  # one hook, one notification
    reg.register("g", pg2)  # replacement
    events.clear()
    pg1.add_node_labels(nodes[:2], ["y"] * 2)  # old graph mutates
    assert events == []  # replaced graph is silent under the name
    pg2.add_node_labels(np.asarray(pg2.graph.node_map)[:2], ["z"] * 2)
    assert len(events) == 1


# --------------------------------------------- O(NK/P) dense-copy release
def test_mesh_mode_never_caches_dense_store():
    """The PR 2 follow-up closed: with a mesh, queries AND planner stats
    must not leave a dense single-device store cached anywhere."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (CI forces 8)")
    from repro.launch.mesh import make_entity_mesh

    pg = _build("arr", mesh=make_entity_mesh())
    pg.match(PATTERNS[0])  # planner stats + sharded query
    pg.label_counts()  # stats-only read
    for store in (pg._vstore, pg._estore):
        assert store._store is None
        assert store._host is None  # host build released after placement
        assert store._sharded is not None
        assert store._counts is not None


def test_label_counts_reads_cached_stats_without_device_store():
    """label_counts/relationship_counts come off attr_counts — derived
    host-side; reading them must not build a device store."""
    pg = _build("list", m=300, seed=2)
    counts = pg.label_counts()
    assert pg._vstore._store is None  # stats never touched a device store
    labels = np.asarray(pg._vstore.amap.values)
    assert set(counts) == set(labels.tolist())
    rcounts = pg.relationship_counts()
    assert pg._estore._store is None
    assert sum(rcounts.values()) == pg._estore.nnz
    # and the stats agree with the actual query masks
    for lab, c in counts.items():
        assert int(np.asarray(pg.query_labels([lab])).sum()) == c
