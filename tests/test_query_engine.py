"""Pattern engine: parser round-trips, planner selectivity decisions, and
match() ≡ hand-composed mask pipelines on random graphs, all DIP backends."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PropGraph
from repro.core.queries import induce_edge_mask
from repro.query import (
    EdgePattern,
    NodePattern,
    ParseError,
    Pattern,
    Predicate,
    parse,
    plan_pattern,
)
from repro.query.planner import BUDGET_SEL_CUTOFF


# ------------------------------------------------------------------ parser
@pytest.mark.parametrize(
    "text",
    [
        "(a)",
        "(a:person)",
        "(:person|place)",
        "(a:person {age > 30})",
        '(a:person {age >= 30, name == "bob"})',
        "(a:person)-[:follows]->(b:person)",
        "(a)<-[r:follows|likes]-(b:place {x < -3})",
        "(a:l1)-[:r1]->(b)-[e2:r2 {w != 0.5}]->(c:l2|l3)",
        "(a {score <= 1.5})",
        "(a:x)-[:r*1..3]->(b)",
        "(a)-[v:r|s*]->(b:y)",
        "(a)<-[:r*2..]-(b)",
        "(a)-[:r*3 {w > 0.5}]->(b)",
        "(a)-[:r*0..2]->(b)",
    ],
)
def test_parse_roundtrip(text):
    pat = parse(text)
    assert parse(pat.to_text()) == pat


def test_parse_star_bounds():
    assert parse("(a)-[:r*]->(b)").edges[0].lo == 1
    assert parse("(a)-[:r*]->(b)").edges[0].hi is None
    assert (parse("(a)-[:r*..4]->(b)").edges[0].lo,
            parse("(a)-[:r*..4]->(b)").edges[0].hi) == (1, 4)
    assert (parse("(a)-[:r*2]->(b)").edges[0].lo,
            parse("(a)-[:r*2]->(b)").edges[0].hi) == (2, 2)
    assert parse("(a)-[:r]->(b)").edges[0].is_fixed
    assert not parse("(a)-[:r*1..2]->(b)").edges[0].is_fixed
    # bounds keep float literals intact: '1.' is still a number elsewhere
    assert parse("(a {x > 1.})").nodes[0].predicates[0].value == 1.0


@pytest.mark.parametrize("bad", [
    "(a)-[:r*3..1]->(b)",      # upper below lower
    "(a)-[:r*1.5]->(b)",       # non-integer bound
    "(a)-[:r*-2]->(b)",        # negative bound
    "(a:x*2)-[:r]->(b)",       # '*' is edge-only syntax
])
def test_parse_star_errors(bad):
    with pytest.raises(ParseError):
        parse(bad)


def test_parse_duplicate_variable_raises():
    """Repeated variables would read as an equality join, which the engine
    does not implement — rejected at parse time instead of silently
    OR-ing the masks (the old documented wart)."""
    for bad in ["(a)-[:r]->(a)", "(a)-[x:r]->(b)<-[x:s]-(c)",
                "(v)-[v:r]->(b)"]:
        with pytest.raises(ParseError, match="bound more than once"):
            parse(bad)
    parse("(a)-[:r]->(b)-[:s]->(c)")  # anonymous slots never collide


def test_parse_ast_shape():
    pat = parse('(a:person {age > 30})-[f:follows]->(b:person|place)')
    assert pat == Pattern(
        nodes=(
            NodePattern(var="a", labels=("person",),
                        predicates=(Predicate("age", ">", 30),)),
            NodePattern(var="b", labels=("person", "place")),
        ),
        edges=(EdgePattern(var="f", rels=("follows",), direction=1),),
    )
    assert pat.hops == 1


def test_parse_direction_and_eq_normalization():
    pat = parse("(a)<-[:r]-(b {x = 3})")
    assert pat.edges[0].direction == -1
    assert pat.nodes[1].predicates[0].op == "=="


@pytest.mark.parametrize("bad", ["(a", "(a)-(b)", "(a)-[:r]-(b)", "(a)->[:r]->(b)", "(a{x~3})"])
def test_parse_errors(bad):
    with pytest.raises(ParseError):
        parse(bad)


def test_pattern_reversed_involution():
    pat = parse("(a:l1)-[:r1]->(b)<-[:r2]-(c:l2)")
    assert pat.reversed().reversed() == pat
    assert pat.reversed().edges[0].direction == 1  # <-[:r2]- flips to -[:r2]->


# ----------------------------------------------------------------- fixture
@pytest.fixture(params=["arr", "list", "listd"])
def pg(request, rng):
    src = rng.integers(0, 60, 300)
    dst = rng.integers(0, 60, 300)
    g = PropGraph(backend=request.param).add_edges_from(src, dst)
    nodes = np.asarray(g.graph.node_map)
    labels = rng.choice(["rare", "mid", "common"], size=len(nodes), p=[0.1, 0.3, 0.6])
    g.add_node_labels(nodes, labels)
    es, ed = np.asarray(g.graph.src), np.asarray(g.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es), p=[0.2, 0.8])
    g.add_edge_relationships(nodes[es], nodes[ed], rels)
    g.add_node_properties("age", nodes, rng.integers(0, 60, len(nodes)).astype(np.int32))
    g._labels_np, g._rels_np = labels, rels
    return g


# ---------------------------------------------------------------- planner
def test_planner_reverses_toward_selective_end(pg):
    plan = plan_pattern(pg, parse("(a:common)-[:follows]->(b:rare)"))
    assert plan.reversed_chain
    assert plan.pattern.nodes[0].labels == ("rare",)
    assert plan.pattern.edges[0].direction == -1
    plan = plan_pattern(pg, parse("(a:rare)-[:follows]->(b:common)"))
    assert not plan.reversed_chain


def test_planner_skewed_selectivity_picks_cheaper_impl():
    """listd: a selective query plans the output-sized budget gather, an
    unselective one the full inverted scan — driven by attr_counts skew."""
    rng = np.random.default_rng(7)
    src = rng.integers(0, 200, 2000)
    dst = rng.integers(0, 200, 2000)
    pg = PropGraph(backend="listd").add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    labels = rng.choice(["needle", "hay"], size=len(nodes), p=[0.02, 0.98])
    pg.add_node_labels(nodes, labels)

    plan_sel = plan_pattern(pg, parse("(a:needle)"))
    plan_uns = plan_pattern(pg, parse("(a:hay)"))
    (step_sel,) = plan_sel.mask_steps
    (step_uns,) = plan_uns.mask_steps
    assert step_sel.impl == "budget"
    assert step_uns.impl == "inverted"
    assert step_sel.est_selectivity < BUDGET_SEL_CUTOFF < step_uns.est_selectivity
    assert "budget" in pg.explain("(a:needle)")
    assert "inverted" in pg.explain("(a:hay)")
    # both impls produce the same (correct) mask
    expect = labels == "needle"
    assert (np.asarray(pg.match("(a:needle)").vertex_mask) == expect).all()


def test_planner_fuses_arr_label_masks(pg):
    plan = plan_pattern(pg, parse("(a:rare)-[:follows]->(b:common)"))
    if pg.backend == "arr":
        assert plan.fused_node_slots == (0, 1)
        assert all(s.fused for s in plan.mask_steps if s.kind == "node")
        assert "fused" in plan.describe()
    else:
        assert plan.fused_node_slots == ()


def test_impl_override_respected(pg):
    override = {"arr": "scan", "list": None, "listd": "inverted"}[pg.backend]
    plan = plan_pattern(pg, parse("(a:rare)-[:follows]->(b:common)"), impl=override)
    assert plan.fused_node_slots == ()
    if override:
        assert all(s.impl == override for s in plan.mask_steps)


# --------------------------------------------------------------- executor
def _hand_single_hop(pg, l_tail, rel, l_head):
    """The §VI hand-composed pipeline the acceptance criterion names."""
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    vm_t = np.asarray(pg.query_labels([l_tail]))
    vm_h = np.asarray(pg.query_labels([l_head]))
    em = np.asarray(pg.query_relationships([rel]))
    emask = em & vm_t[es] & vm_h[ed]
    vmask = np.zeros(pg.n_vertices, bool)
    vmask[es[emask]] = True
    vmask[ed[emask]] = True
    return vmask, emask


def test_match_equals_hand_composed_pipeline(pg):
    res = pg.match("(a:rare)-[:follows]->(b:common)")
    vexp, eexp = _hand_single_hop(pg, "rare", "follows", "common")
    assert (np.asarray(res.edge_mask) == eexp).all()
    assert (np.asarray(res.vertex_mask) == vexp).all()


def test_match_same_label_equals_induce_edge_mask(pg):
    """Uniform-label hop ≡ the existing induce_edge_mask + endpoint collect."""
    res = pg.match("(a:mid)-[:likes]->(b:mid)")
    vm = pg.query_labels(["mid"])
    em = pg.query_relationships(["likes"])
    eexp = np.asarray(induce_edge_mask(pg.graph, vm, em))
    assert (np.asarray(res.edge_mask) == eexp).all()


def _brute_force(pg, node_label_sets, edge_specs):
    """Exhaustive path enumeration over the chain (exponential; tiny graphs)."""
    labels, rels = pg._labels_np, pg._rels_np
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    n, m, h = pg.n_vertices, pg.n_edges, len(edge_specs)
    nodeok = [
        np.ones(n, bool) if ls is None else np.isin(labels, ls)
        for ls in node_label_sets
    ]
    edgeok = [
        np.ones(m, bool) if rs is None else np.isin(rels, rs)
        for rs, _ in edge_specs
    ]
    adj_out = [[] for _ in range(n)]
    adj_in = [[] for _ in range(n)]
    for i, (a, b) in enumerate(zip(es, ed)):
        adj_out[a].append((i, b))
        adj_in[b].append((i, a))
    vexp = np.zeros(n, bool)
    eexp = np.zeros(m, bool)

    def rec(pos, v, vs, epath):
        if pos == h:
            vexp[vs] = True
            eexp[epath] = True
            return
        _, direction = edge_specs[pos]
        for ei, w in adj_out[v] if direction == 1 else adj_in[v]:
            if edgeok[pos][ei] and nodeok[pos + 1][w]:
                rec(pos + 1, w, vs + [w], epath + [ei])

    for v in np.flatnonzero(nodeok[0]):
        rec(0, int(v), [int(v)], [])
    return vexp, eexp


@pytest.mark.parametrize(
    "text,node_sets,edge_specs",
    [
        ("(a:rare)-[:follows]->(b)-[:likes]->(c:common)",
         [["rare"], None, ["common"]], [(["follows"], 1), (["likes"], 1)]),
        ("(a:rare)<-[:likes]-(b:mid|common)",
         [["rare"], ["mid", "common"]], [(["likes"], -1)]),
        ("(a)-[:follows]->(b:rare)<-[:follows]-(c)",
         [None, ["rare"], None], [(["follows"], 1), (["follows"], -1)]),
        ("(a:common)-[:follows|likes]->(b:rare)",
         [["common"]], None),  # reversed-chain case, specs filled below
    ],
)
def test_match_equals_brute_force(pg, text, node_sets, edge_specs):
    if edge_specs is None:
        node_sets = [["common"], ["rare"]]
        edge_specs = [(["follows", "likes"], 1)]
    res = pg.match(text)
    vexp, eexp = _brute_force(pg, node_sets, edge_specs)
    assert (np.asarray(res.vertex_mask) == vexp).all(), text
    assert (np.asarray(res.edge_mask) == eexp).all(), text


def test_match_with_predicates(pg):
    res = pg.match("(a:rare|mid {age > 30})-[:likes]->(b)")
    ages = np.asarray(pg.vertex_props["age"][0])
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    vm_a = np.isin(pg._labels_np, ["rare", "mid"]) & (ages > 30)
    eexp = (pg._rels_np == "likes") & vm_a[es]
    assert (np.asarray(res.edge_mask) == eexp).all()


def test_match_single_node_pattern(pg):
    res = pg.match("(a:rare {age <= 20})")
    ages = np.asarray(pg.vertex_props["age"][0])
    expect = (pg._labels_np == "rare") & (ages <= 20)
    assert (np.asarray(res.vertex_mask) == expect).all()
    assert res.n_edges() == 0


def test_match_bindings_and_subgraph(pg):
    res = pg.match("(a:rare)-[f:follows]->(b:common)")
    b = res.bindings()
    assert set(b) == {"a", "f", "b"}
    vexp, eexp = _hand_single_hop(pg, "rare", "follows", "common")
    assert (np.asarray(b["f"]) == eexp).all()
    assert (np.asarray(b["a"] | b["b"]) == vexp).all()
    sub, kept = res.subgraph(pg.graph)
    assert sub.m == int(eexp.sum())
    expanded = res.expand(pg.graph, 1)
    assert bool(jnp.all(res.vertex_mask <= expanded))


def test_match_unknown_label_empty(pg):
    res = pg.match("(a:nope)-[:follows]->(b)")
    assert res.n_vertices() == 0 and res.n_edges() == 0


def test_match_unknown_property_raises(pg):
    with pytest.raises(KeyError):
        pg.match("(a {height > 3})")


def test_match_string_predicate_raises(pg):
    """Strings parse as literals but columns are numeric — ==/!= would
    silently broadcast to a scalar, so they are rejected at PLAN time
    (naming the column), before any store work or server round-trip."""
    with pytest.raises(TypeError, match="labels/relationships"):
        pg.match('(a {age != "old"})')
    with pytest.raises(TypeError, match="age"):
        pg.explain('(a {age != "old"})')  # explain plans too — no execution


def test_match_result_is_pytree(pg):
    import jax

    res = pg.match("(a:rare)-[:follows]->(b:common)")
    leaves = jax.tree_util.tree_leaves(res)
    assert all(hasattr(x, "dtype") for x in leaves)  # masks only, plan is meta
    jax.block_until_ready(res)  # benchmarks rely on this blocking for real


# ------------------------------------------------------ satellite regressions
def test_query_any_empty_values_fast_path(pg):
    assert not np.asarray(pg.query_labels([])).any()
    assert not np.asarray(pg.query_relationships([])).any()
    assert not np.asarray(pg._vstore.query_any([])).any()


def test_queries_before_build_raise_runtime_error():
    pg = PropGraph(backend="arr")
    with pytest.raises(RuntimeError, match="add_edges_from"):
        pg.query_labels(["x"])
    with pytest.raises(RuntimeError, match="add_edges_from"):
        pg.query_relationships(["x"])
    with pytest.raises(RuntimeError, match="add_edges_from"):
        pg.subgraph(labels=["x"])
    with pytest.raises(RuntimeError, match="add_edges_from"):
        pg.match("(a:x)")


def test_attr_counts_match_histogram(pg):
    counts = pg.label_counts()
    for lab in ("rare", "mid", "common"):
        assert counts[lab] == int((pg._labels_np == lab).sum())
    rcounts = pg.relationship_counts()
    assert rcounts["follows"] == int((pg._rels_np == "follows").sum())


def test_query_any_batched_consistent(pg):
    queries = [["rare"], ["mid", "common"], ["nope"]]
    batched = np.asarray(pg._vstore.query_any_batched(queries))
    for q, row in zip(queries, batched):
        assert (row == np.asarray(pg.query_labels(q))).all()
    if pg.backend == "arr":  # scan/kernel impls agree with matvec
        for impl in ("scan", "kernel"):
            alt = np.asarray(pg._vstore.query_any_batched(queries, impl=impl))
            assert (alt == batched).all(), impl
