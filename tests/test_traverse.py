"""Frontier engine + variable-length patterns (src/repro/traverse/,
docs/ARCHITECTURE.md §10).

The contracts under test:

* ``match('(a:x)-[:r*lo..hi]->(b:y)')`` is bitwise-equal to brute-force
  WALK enumeration (the documented semantics: traversals may revisit
  vertices/edges) on all three DIP backends, across bounds, directions,
  predicates and mixed fixed/var chains — seeded randomized sweep, the
  property-based check the acceptance criterion names.
* fixed-point ``*`` equals the iterated bounded form ``*1..2n`` at
  convergence (any walk shortens to < n edges).
* the engine's three execution paths — edge-centric ``khop_mask``, the
  CSR small-frontier fast path ``khop_csr``, and the shard_map all-reduce
  path — produce identical masks; sharded ≡ single-device is re-proved in
  a fresh P=8 subprocess (like tests/test_shard_pg.py).
* ``PropGraph.khop``/``components`` respect label/relationship/property
  filters (vs. numpy BFS / union-find oracles).
* the service serves traversal patterns: coalescer falls back per-request
  (``traversal_fallback_requests``), result cache hits and dies on
  mutation; the wire path returns bitwise-identical masks and surfaces
  plan-time errors (string predicates) with the real exception type.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import PropGraph
from repro.graph import connected_components
from repro.query import ParseError, parse
from repro.query.planner import MAX_VARLEN
from repro.traverse import (
    components_masked,
    frontier_step,
    khop_csr,
    khop_mask,
    reach_closure,
)

BACKENDS = ("arr", "list", "listd")


def _build(backend, *, n=14, m=40, seed=0, rels=("r", "s"),
           labels=("x", "y", "z"), props=False):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    lab = rng.choice(labels, size=len(nodes))
    pg.add_node_labels(nodes, lab)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rel = rng.choice(rels, size=len(es))
    pg.add_edge_relationships(nodes[es], nodes[ed], rel)
    if props:
        pg.add_node_properties("age", nodes,
                               rng.integers(0, 60, len(nodes)).astype(np.int32))
        pg.add_edge_properties("w", nodes[es], nodes[ed],
                               rng.random(len(es)).astype(np.float32))
    pg._labels_np, pg._rels_np = lab, rel
    return pg


def _eq(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and bool((a == b).all())


# -------------------------------------------------------------- engine core
def test_frontier_step_matches_numpy():
    pg = _build("arr", seed=3)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    rng = np.random.default_rng(0)
    f = rng.random(g.n) > 0.6
    e_ok = rng.random(g.m) > 0.3
    fwd = np.zeros(g.n, bool)
    np.logical_or.at(fwd, ed[f[es] & e_ok], True)
    assert _eq(frontier_step(g, f, e_ok), fwd)
    bwd = np.zeros(g.n, bool)
    np.logical_or.at(bwd, es[f[ed] & e_ok], True)
    assert _eq(frontier_step(g, f, e_ok, direction=-1), bwd)
    und = fwd | bwd
    assert _eq(frontier_step(g, f, e_ok, undirected=True), und)


def _np_khop(es, ed, n, seed_ids, e_ok, k, direction=1, undirected=False):
    mask = np.zeros(n, bool)
    mask[seed_ids] = True
    for _ in range(k):
        nm = mask.copy()
        if direction == 1 or undirected:
            np.logical_or.at(nm, ed[mask[es] & e_ok], True)
        if direction == -1 or undirected:
            np.logical_or.at(nm, es[mask[ed] & e_ok], True)
        if (nm == mask).all():
            break
        mask = nm
    return mask


@pytest.mark.parametrize("k", [0, 1, 3, 7])
def test_khop_mask_equals_csr_equals_numpy(k):
    pg = _build("arr", n=25, m=90, seed=5)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    rng = np.random.default_rng(k)
    e_ok = rng.random(g.m) > 0.4
    seeds = rng.integers(0, g.n, 3)
    ref = _np_khop(es, ed, g.n, seeds, e_ok, k)
    seed_mask = np.zeros(g.n, bool)
    seed_mask[seeds] = True
    assert _eq(khop_mask(g, seed_mask, e_ok, k=k), ref)
    assert _eq(khop_csr(g, seeds, e_ok, k=k), ref)
    # closure = khop at n steps
    if k == 7:
        assert _eq(reach_closure(g, seed_mask, e_ok),
                   _np_khop(es, ed, g.n, seeds, e_ok, g.n))


def _np_components(es, ed, n, e_ok, v_ok):
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in np.flatnonzero(e_ok & v_ok[es] & v_ok[ed]):
        a, b = find(es[i]), find(ed[i])
        if a != b:
            parent[max(a, b)] = min(a, b)
    lab = np.array([find(x) for x in range(n)], dtype=np.int64)
    out = np.full(n, -1, np.int64)
    for c in np.unique(lab[v_ok]) if v_ok.any() else []:
        members = np.flatnonzero((lab == c) & v_ok)
        out[members] = members.min()
    return out


def test_components_masked_equals_union_find():
    pg = _build("arr", n=30, m=70, seed=9)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    rng = np.random.default_rng(2)
    e_ok = rng.random(g.m) > 0.5
    v_ok = rng.random(g.n) > 0.3
    assert _eq(components_masked(g, v_ok, e_ok),
               _np_components(es, ed, g.n, e_ok, v_ok))
    # unmasked form == the public structural kernel
    all_e, all_v = np.ones(g.m, bool), np.ones(g.n, bool)
    assert _eq(connected_components(g),
               _np_components(es, ed, g.n, all_e, all_v))


# ----------------------------------------------- var-length ≡ brute force
def _brute_varlen(pg, l_a, rel, l_b, lo, hi, direction=1, edge_pred=None):
    """Exhaustive WALK enumeration (revisits allowed) — the documented
    ``*lo..hi`` semantics; exponential, tiny graphs only."""
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    n, m = pg.n_vertices, pg.n_edges
    ca = np.isin(pg._labels_np, l_a)
    cb = np.isin(pg._labels_np, l_b)
    e_ok = np.isin(pg._rels_np, rel)
    if edge_pred is not None:
        e_ok = e_ok & edge_pred
    adj = [[] for _ in range(n)]
    for i in range(m):
        t, h = (es[i], ed[i]) if direction == 1 else (ed[i], es[i])
        if e_ok[i]:
            adj[t].append((i, h))
    vexp = np.zeros(n, bool)
    eexp = np.zeros(m, bool)

    def rec(v, depth, vs, epath):
        if lo <= depth <= hi and cb[v]:
            vexp[vs] = True
            eexp[epath] = True
        if depth == hi:
            return
        for ei, w in adj[v]:
            rec(w, depth + 1, vs + [w], epath + [ei])

    for v in np.flatnonzero(ca):
        rec(int(v), 0, [int(v)], [])
    return vexp, eexp


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_varlen_match_equals_brute_force(backend, seed):
    """The acceptance-criterion sweep: several bounds × both directions on
    random graphs, every backend, bitwise (vertex, edge AND bindings)."""
    pg = _build(backend, seed=seed)
    for lo, hi in [(1, 2), (1, 3), (2, 4), (0, 2), (3, 3)]:
        for arrow_l, arrow_r, direction in (("-", "->", 1), ("<-", "-", -1)):
            star = f"*{lo}..{hi}" if lo != hi else f"*{lo}"
            pat = f"(a:x){arrow_l}[v:r{star}]{arrow_r}(b:y|z)"
            res = pg.match(pat)
            vexp, eexp = _brute_varlen(pg, ["x"], ["r"], ["y", "z"],
                                       lo, hi, direction)
            assert _eq(res.vertex_mask, vexp), (pat, seed)
            assert _eq(res.edge_mask, eexp), (pat, seed)
            assert _eq(res.bindings()["v"], eexp), (pat, seed)


def test_varlen_with_edge_predicate():
    pg = _build("arr", seed=4, props=True)
    w = np.asarray(pg.edge_props["w"][0])
    res = pg.match("(a:x)-[:r*1..3 {w > 0.4}]->(b:y)")
    vexp, eexp = _brute_varlen(pg, ["x"], ["r"], ["y"], 1, 3,
                               edge_pred=w > 0.4)
    assert _eq(res.vertex_mask, vexp)
    assert _eq(res.edge_mask, eexp)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fixpoint_star_equals_iterated_bounded(backend):
    """``*`` ≡ ``*1..2n``: any walk shortens to a path of < n edges, and
    the participation masks need at most two of them stitched."""
    pg = _build(backend, seed=6)
    cap = min(2 * pg.n_vertices, MAX_VARLEN)
    r1 = pg.match("(a:x)-[:r*]->(b:y)")
    r2 = pg.match(f"(a:x)-[:r*1..{cap}]->(b:y)")
    assert _eq(r1.vertex_mask, r2.vertex_mask)
    assert _eq(r1.edge_mask, r2.edge_mask)
    # and *0.. includes the zero-length (a == b) coincidences
    r0 = pg.match("(a:x)-[:r*0..]->(b:x)")
    both = np.asarray(pg.query_labels(["x"]))
    assert bool((np.asarray(r0.vertex_mask) >= both).all())


def test_varlen_in_mixed_chain_equals_brute_force():
    pg = _build("arr", n=12, m=35, seed=8)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    n, m = pg.n_vertices, pg.n_edges
    cx = pg._labels_np == "x"
    cy = pg._labels_np == "y"
    rm = pg._rels_np == "r"
    sm = pg._rels_np == "s"
    adj_r = [[] for _ in range(n)]
    adj_s = [[] for _ in range(n)]
    for i in range(m):
        (adj_r if rm[i] else adj_s)[es[i]].append((i, ed[i]))
    vexp = np.zeros(n, bool)
    eexp = np.zeros(m, bool)
    for a in np.flatnonzero(cx):
        stack = [(int(a), 0, [int(a)], [])]
        while stack:
            v, d, vs, ep = stack.pop()
            if 1 <= d <= 2:
                for ei, c in adj_s[v]:
                    if cy[c]:
                        vexp[vs + [c]] = True
                        eexp[ep + [ei]] = True
            if d < 2:
                for ei, w in adj_r[v]:
                    stack.append((w, d + 1, vs + [w], ep + [ei]))
    res = pg.match("(a:x)-[:r*1..2]->(b)-[:s]->(c:y)")
    assert _eq(res.vertex_mask, vexp)
    assert _eq(res.edge_mask, eexp)


def test_varlen_planner_reorientation_is_invisible():
    """A selective right end reverses the chain; the match set must not
    change (walk patterns reverse cleanly)."""
    pg = _build("arr", seed=10, labels=("common",))
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes[:2], ["needle", "needle"])
    pg._labels_np = np.where(np.isin(np.arange(pg.n_vertices), [0, 1]),
                             "needle", "common")
    assert "reversed" in pg.explain("(a:common)-[:r*1..3]->(b:needle)")
    res = pg.match("(a:common)-[:r*1..3]->(b:needle)")
    vexp, eexp = _brute_varlen(pg, ["common"], ["r"], ["needle"], 1, 3)
    assert _eq(res.vertex_mask, vexp)
    assert _eq(res.edge_mask, eexp)


def test_varlen_plan_time_rejections():
    pg = _build("arr")
    with pytest.raises(ValueError, match="upper bound"):
        pg.explain("(a)-[:r*2..]->(b)")  # unbounded needs lo ≤ 1
    with pytest.raises(ValueError, match="MAX_VARLEN"):
        pg.explain(f"(a)-[:r*1..{MAX_VARLEN + 1}]->(b)")
    assert "fixed-point" in pg.explain("(a)-[:r*]->(b)")
    assert "unrolled" in pg.explain("(a)-[:r*1..3]->(b)")


# ----------------------------------------------------- PropGraph analytics
def test_khop_respects_all_filter_layers():
    pg = _build("arr", n=30, m=120, seed=11, props=True)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    nodes = np.asarray(g.node_map)
    seeds = nodes[:4]
    sid = pg._vertex_internal(seeds)
    w = np.asarray(pg.edge_props["w"][0])
    e_ok = (pg._rels_np == "r") & (w > 0.3)
    cb = pg._labels_np == "y"
    ref = _np_khop(es, ed, g.n, sid, e_ok & cb[ed], 3)
    got = pg.khop(seeds, 3, pattern="(a)-[:r {w > 0.3}]->(b:y)")
    assert _eq(got, ref)
    # reverse-hop pattern walks edges dst→src
    ref_r = _np_khop(es, ed, g.n, sid, (pg._rels_np == "r"), 2, direction=-1)
    got_r = pg.khop(seeds, 2, pattern="(a)<-[:r]-(b)")
    assert _eq(got_r, ref_r)
    # node-only pattern confines traversal to matching vertices
    vok = pg._labels_np == "x"
    ref_n = _np_khop(es, ed, g.n, sid, vok[es] & vok[ed], 2)
    got_n = pg.khop(seeds, 2, pattern="(v:x)")
    assert _eq(got_n, ref_n)
    # undirected expansion
    ref_u = _np_khop(es, ed, g.n, sid, pg._rels_np == "r", 2, undirected=True)
    got_u = pg.khop(seeds, 2, pattern="(a)-[:r]->(b)", undirected=True)
    assert _eq(got_u, ref_u)
    with pytest.raises(ValueError, match="unknown impl"):
        pg.khop(seeds, 2, impl="bitmap")
    with pytest.raises(ValueError, match="single-hop"):
        pg.khop(seeds, 2, pattern="(a)-[:r]->(b)-[:s]->(c)")
    with pytest.raises(ValueError, match="variable-length"):
        pg.khop(seeds, 2, pattern="(a)-[:r*1..2]->(b)")


def test_khop_csr_impl_bitwise_equal():
    pg = _build("list", n=40, m=160, seed=12)
    nodes = np.asarray(pg.graph.node_map)
    seeds = nodes[:2]
    for k in (1, 2, 5):
        a = pg.khop(seeds, k, pattern="(a)-[:r]->(b)")
        b = pg.khop(seeds, k, pattern="(a)-[:r]->(b)", impl="csr")
        assert _eq(a, b), k


def test_components_pattern_filters():
    pg = _build("arr", n=30, m=80, seed=13)
    g = pg.graph
    es, ed = np.asarray(g.src), np.asarray(g.dst)
    e_ok = pg._rels_np == "r"
    v_all = np.ones(g.n, bool)
    assert _eq(pg.components("(a)-[:r]->(b)"),
               _np_components(es, ed, g.n, e_ok, v_all))
    vok = np.isin(pg._labels_np, ["x", "y"])
    got = pg.components("(a:x|y)-[:r]->(b:x|y)")
    assert _eq(got, _np_components(es, ed, g.n, e_ok, vok))
    assert bool((np.asarray(got)[~vok] == -1).all())
    # match() composes with components: the flagged-subgraph CC story
    labels = np.asarray(pg.components(None))
    assert _eq(labels, _np_components(es, ed, g.n, np.ones(g.m, bool), v_all))


# ------------------------------------------------------- sharded subprocess
_SUBPROCESS_SCRIPT = r"""
import numpy as np, jax
assert len(jax.devices()) == 8, len(jax.devices())
import sys
sys.path.insert(0, {src!r})
from repro.core import PropGraph
from repro.launch.mesh import make_entity_mesh

rng = np.random.default_rng(7)
src = rng.integers(0, 60, 300)
dst = rng.integers(0, 60, 300)
mesh = make_entity_mesh()
assert mesh.devices.size == 8
for be in ("arr", "list", "listd"):
    pg1 = PropGraph(backend=be).add_edges_from(src, dst)
    pg2 = PropGraph(backend=be, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg1.graph.node_map)
    labels = rng.choice(["x", "y", "z"], size=len(nodes))
    es, ed = np.asarray(pg1.graph.src), np.asarray(pg1.graph.dst)
    rels = rng.choice(["r", "s"], size=len(es))
    for pg in (pg1, pg2):
        pg.add_node_labels(nodes, labels)
        pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    for pat in ("(a:x)-[:r*1..3]->(b:y)", "(a:x)-[v:r*]->(b:y|z)"):
        r1, r2 = pg1.match(pat), pg2.match(pat)
        assert (np.asarray(r1.vertex_mask) == np.asarray(r2.vertex_mask)).all(), (be, pat)
        assert (np.asarray(r1.edge_mask) == np.asarray(r2.edge_mask)).all(), (be, pat)
    seeds = nodes[:3]
    a = np.asarray(pg1.khop(seeds, 3, pattern="(a)-[:r]->(b)"))
    b = np.asarray(pg2.khop(seeds, 3, pattern="(a)-[:r]->(b)"))
    assert (a == b).all(), be
    c1 = np.asarray(pg1.components("(a)-[:r]->(b)"))
    c2 = np.asarray(pg2.components("(a)-[:r]->(b)"))
    assert (c1 == c2).all(), be
print("TRAVERSE SHARD8 OK")
"""


def test_sharded_traversal_eight_devices_subprocess():
    """P=8 sharded ≡ single-device for var-length match, khop and
    components — the frontier all-reduce path, guaranteed multi-device
    via a fresh interpreter (same harness as test_shard_pg)."""
    src_dir = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_SCRIPT.format(src=os.path.abspath(src_dir))],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "TRAVERSE SHARD8 OK" in proc.stdout


# ------------------------------------------------------------- service/wire
def test_service_traversal_fallback_cache_and_invalidation():
    from repro.service import Service

    pg = _build("arr", n=30, m=120, seed=14)
    nodes = np.asarray(pg.graph.node_map)
    pat = "(a:x)-[:r*1..3]->(b:y)"
    with Service() as svc:
        svc.add_graph("g", pg)
        ref = pg.match(pat)
        got = svc.query("g", pat)
        assert _eq(got.edge_mask, ref.edge_mask)
        svc.query("g", pat)  # second hit comes from the result cache
        st = svc.stats()
        assert st.get("result_hits", 0) >= 1, st
        assert st.get("traversal_fallback_requests", 0) >= 1, st
        # mixed batch: fixed plans still coalesce around the traversal
        outs = svc.query_batch("g", [pat, "(a:x)-[:r]->(b:y)",
                                     "(a:y)-[:s]->(b)"])
        assert _eq(outs[1].edge_mask, pg.match("(a:x)-[:r]->(b:y)").edge_mask)
        assert svc.stats().get("coalesced_launches", 0) >= 1
        # mutation kills the cached traversal result
        pg.add_node_labels(nodes[:5], ["y"] * 5)
        got2 = svc.query("g", pat)
        assert _eq(got2.edge_mask, pg.match(pat).edge_mask)
        assert svc.stats().get("invalidated_results", 0) > 0


def test_wire_traversal_and_plan_time_errors():
    """PGClient round-trip: var-length masks bitwise, and the plan-time
    string-predicate TypeError (naming the column) arrives BEFORE any
    execution — the satellite's over-the-wire contract."""
    from repro.service import PGClient, PGServer, Service

    pg = _build("arr", n=30, m=120, seed=15, props=True)
    svc = Service()
    svc.add_graph("g", pg)
    server = PGServer(svc, port=0).start()
    try:
        with PGClient(port=server.port) as c:
            pat = "(a:x)-[:r*1..4]->(b:y)"
            ref = pg.match(pat)
            got = c.query("g", pat)
            assert _eq(got.vertex_mask, ref.vertex_mask)
            assert _eq(got.edge_mask, ref.edge_mask)
            gb, rb = got.bindings(), ref.bindings()
            assert sorted(gb) == sorted(rb)
            for k in rb:
                assert _eq(gb[k], rb[k]), k
            with pytest.raises(TypeError, match="labels/relationships"):
                c.query("g", '(a {age == "old"})-[:r]->(b)')
            with pytest.raises(TypeError, match="age"):
                c.explain("g", '(a {age == "old"})')
            # duplicate variables are a parse error, also pre-execution
            try:
                c.query("g", "(a)-[:r]->(a)")
            except Exception as e:  # noqa: BLE001 — ParseError crosses as
                assert "bound more than once" in str(e)  # its message
            else:
                raise AssertionError("duplicate variable should fail")
            assert c.ping()  # session survived all failed requests
    finally:
        server.close()
        svc.close()
