#!/usr/bin/env python
"""Docs link check — keeps ARCHITECTURE.md (and friends) honest.

Two rules, run over the checked docs:

1. Every repo-relative path referenced in a checked doc (markdown links and
   backticked ``src/...``-style paths) must exist.
2. No dangling ``DESIGN.md`` references may reappear in the property-graph
   core (``src/repro/core``, ``src/repro/launch``, ``src/repro/query``,
   ``src/repro/kernels/bitmap_query``) — they were replaced by
   ``docs/ARCHITECTURE.md`` sections in PR 2.  (Seed-era modules elsewhere
   still carry them; Appendix A of ARCHITECTURE.md decodes those.)

Exit 0 = clean; exit 1 prints every violation.  Run from the repo root:
    python tools/check_links.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CHECKED_DOCS = [
    "docs/ARCHITECTURE.md",
    "src/repro/query/README.md",
    "src/repro/service/README.md",
    "src/repro/overlay/README.md",
]
NO_DESIGN_REF_TREES = [
    "src/repro/core",
    "src/repro/launch",
    "src/repro/query",
    "src/repro/kernels/bitmap_query",
]

# markdown links [text](target) with local targets, plus backticked paths
# (which may carry a trailing section/member, e.g. `docs/ARCHITECTURE.md §7`
# or `src/x/y.py: name` — _strip_member reduces them to the file part)
MD_LINK = re.compile(r"\[[^\]]+\]\(([^)#:]+)(?:#[^)]*)?\)")
TICKED_PATH = re.compile(r"`((?:src|docs|tests|benchmarks|examples|tools)/[^`]+?)`")


def _strip_member(path: str) -> str:
    """``src/x/y.py: name`` / ``src/x/y.py §7``-style refs → the file part."""
    return path.split(":")[0].split(" ")[0].strip()


def check_doc(rel: str) -> list:
    errs = []
    doc = os.path.join(REPO, rel)
    text = open(doc).read()
    targets = set()
    for pat in (MD_LINK, TICKED_PATH):
        for mt in pat.finditer(text):
            t = _strip_member(mt.group(1))
            if t and not t.startswith(("http", "mailto")):
                targets.add(t)
    base = os.path.dirname(doc)
    for t in sorted(targets):
        # relative to the doc's directory, else to the repo root
        if not (os.path.exists(os.path.join(base, t))
                or os.path.exists(os.path.join(REPO, t))):
            errs.append(f"{rel}: broken reference {t!r}")
    return errs


def check_no_design_refs() -> list:
    errs = []
    for tree in NO_DESIGN_REF_TREES:
        for dirpath, _, files in os.walk(os.path.join(REPO, tree)):
            for f in files:
                if not f.endswith((".py", ".md")):
                    continue
                p = os.path.join(dirpath, f)
                for i, line in enumerate(open(p), 1):
                    if "DESIGN.md" in line:
                        rel = os.path.relpath(p, REPO)
                        errs.append(f"{rel}:{i}: dangling DESIGN.md reference "
                                    "(cite docs/ARCHITECTURE.md instead)")
    return errs


def main() -> int:
    errs = []
    for rel in CHECKED_DOCS:
        if not os.path.exists(os.path.join(REPO, rel)):
            errs.append(f"missing checked doc: {rel}")
            continue
        errs.extend(check_doc(rel))
    errs.extend(check_no_design_refs())
    for e in errs:
        print(e)
    print(f"check_links: {len(errs)} problem(s) in {len(CHECKED_DOCS)} doc(s)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
