"""DLRM serving: batched CTR scoring + graph-side user context at QPS.

    PYTHONPATH=src python examples/recsys_serving.py

The embedding-bag lookup here is the DIP-LIST query generalized to weighted
segment reduction (DESIGN.md §4) — same offsets+values layout, same
entity-dimension distribution rule.  The second half builds the user
context the DLRM consumes FROM THE PROPERTY GRAPH: a Cypher-lite pattern
picks the eligible interaction edges, and the fused sample+embed verb
(docs/ARCHITECTURE.md §15) draws each user's neighborhood and reduces it
to one embedding bag in a single launch — pattern→sample→embed with no
host round-trip in between.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import dlrm_batch
from repro.models import dlrm

cfg = dlrm.DLRMConfig(vocab_size=50_000, bot_mlp=(13, 128, 64, 32), embed_dim=32,
                      top_mlp=(128, 64, 1))
params = dlrm.init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
print(f"DLRM: {n_params/1e6:.1f}M params ({cfg.n_sparse} tables × {cfg.vocab_size:,} rows)")

serve = jax.jit(lambda p, d, s: dlrm.forward(p, d, s, cfg))

# --- online scoring (serve_p99 shape regime) ---------------------------------
batch = dlrm_batch(0, batch=512, vocab=cfg.vocab_size)
scores = serve(params, batch["dense"], batch["sparse"])
scores.block_until_ready()
t0 = time.perf_counter()
for step in range(1, 6):
    b = dlrm_batch(step, batch=512, vocab=cfg.vocab_size)
    serve(params, b["dense"], b["sparse"]).block_until_ready()
dt = (time.perf_counter() - t0) / 5
print(f"online scoring: batch=512 in {dt*1e3:.2f} ms  ({512/dt:,.0f} req/s)")

# --- bulk offline scoring (serve_bulk regime, scaled) -------------------------
b = dlrm_batch(7, batch=16384, vocab=cfg.vocab_size)
t0 = time.perf_counter()
serve(params, b["dense"], b["sparse"]).block_until_ready()
print(f"bulk scoring: 16,384 rows in {(time.perf_counter()-t0)*1e3:.1f} ms")

# --- retrieval (1 query vs 100k candidates, blocked matvec + top-k) -----------
cands = jax.random.normal(jax.random.PRNGKey(1), (100_000, cfg.embed_dim))
retr = jax.jit(lambda p, d, s, c: dlrm.retrieval_scores(p, d, s, c, cfg, top_k=10))
q = dlrm_batch(9, batch=1, vocab=cfg.vocab_size)
vals, idx = retr(params, q["dense"], q["sparse"], cands)
jax.block_until_ready(vals)
t0 = time.perf_counter()
vals, idx = retr(params, q["dense"], q["sparse"], cands)
jax.block_until_ready(vals)
print(f"retrieval: top-10 of 100,000 candidates in {(time.perf_counter()-t0)*1e3:.2f} ms")
print("top scores:", np.asarray(vals)[:3].round(3).tolist())

# --- graph-side user context: fused pattern→sample→embed (§15) ---------------
from repro.core import PropGraph, bitplane
from repro.kernels.neighbor_sample import sample_embed

rng = np.random.default_rng(0)
N_USERS, N_ITEMS, M = 2_000, 8_000, 40_000
u = rng.integers(0, N_USERS, M)
i = N_USERS + rng.integers(0, N_ITEMS, M)
pg = PropGraph().add_edges_from(u, i)
nodes = np.asarray(pg.graph.node_map)
pg.add_node_labels(nodes, np.where(nodes < N_USERS, "user", "item"))
es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
pg.add_edge_relationships(nodes[es], nodes[ed],
                          rng.choice(["clicked", "bought"], size=len(es)))
print(f"interaction graph: n={pg.n_vertices:,} m={pg.n_edges:,}")

# one (n, d) embedding table covering users and items; the packed mask of
# "(u)-[:bought]->(i)" restricts sampling to purchase edges in-kernel
table = jax.random.normal(jax.random.PRNGKey(2), (pg.n_vertices, cfg.embed_dim))
bought = bitplane.pack_mask(jnp.asarray(pg.match("(u)-[:bought]->(i)").edge_mask))
serve_users = np.flatnonzero(
    np.asarray(pg.match("(a:user)").vertex_mask))[:512].astype(np.int32)

bags, nbrs, _eids, mask = sample_embed(
    pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges,
    jnp.asarray(serve_users), jax.random.PRNGKey(3), table,
    fanout=8, edge_words=bought, max_deg=int(pg.graph.max_deg))
jax.block_until_ready(bags)
t0 = time.perf_counter()
bags, nbrs, _eids, mask = sample_embed(
    pg.graph.seg, pg.graph.dst, pg.n_vertices, pg.n_edges,
    jnp.asarray(serve_users), jax.random.PRNGKey(3), table,
    fanout=8, edge_words=bought, max_deg=int(pg.graph.max_deg))
jax.block_until_ready(bags)
dt = time.perf_counter() - t0
sampled = int(np.asarray(mask).sum())
print(f"fused sample+embed: {len(serve_users)} users → {sampled} purchases → "
      f"{bags.shape} bags in {dt*1e3:.2f} ms (one launch)")

# the bag IS the user's context vector: nearest items by dot product
item_rows = table[N_USERS:]
top = jax.lax.top_k(bags @ item_rows.T, 5)[1]
jax.block_until_ready(top)
print("user 0 recommended items:",
      (N_USERS + np.asarray(top)[0]).tolist())
print("OK")
