"""DLRM serving: batched CTR scoring + retrieval against 100k candidates.

    PYTHONPATH=src python examples/recsys_serving.py

The embedding-bag lookup here is the DIP-LIST query generalized to weighted
segment reduction (DESIGN.md §4) — same offsets+values layout, same
entity-dimension distribution rule.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import dlrm_batch
from repro.models import dlrm

cfg = dlrm.DLRMConfig(vocab_size=50_000, bot_mlp=(13, 128, 64, 32), embed_dim=32,
                      top_mlp=(128, 64, 1))
params = dlrm.init_params(jax.random.PRNGKey(0), cfg)
n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
print(f"DLRM: {n_params/1e6:.1f}M params ({cfg.n_sparse} tables × {cfg.vocab_size:,} rows)")

serve = jax.jit(lambda p, d, s: dlrm.forward(p, d, s, cfg))

# --- online scoring (serve_p99 shape regime) ---------------------------------
batch = dlrm_batch(0, batch=512, vocab=cfg.vocab_size)
scores = serve(params, batch["dense"], batch["sparse"])
scores.block_until_ready()
t0 = time.perf_counter()
for step in range(1, 6):
    b = dlrm_batch(step, batch=512, vocab=cfg.vocab_size)
    serve(params, b["dense"], b["sparse"]).block_until_ready()
dt = (time.perf_counter() - t0) / 5
print(f"online scoring: batch=512 in {dt*1e3:.2f} ms  ({512/dt:,.0f} req/s)")

# --- bulk offline scoring (serve_bulk regime, scaled) -------------------------
b = dlrm_batch(7, batch=16384, vocab=cfg.vocab_size)
t0 = time.perf_counter()
serve(params, b["dense"], b["sparse"]).block_until_ready()
print(f"bulk scoring: 16,384 rows in {(time.perf_counter()-t0)*1e3:.1f} ms")

# --- retrieval (1 query vs 100k candidates, blocked matvec + top-k) -----------
cands = jax.random.normal(jax.random.PRNGKey(1), (100_000, cfg.embed_dim))
retr = jax.jit(lambda p, d, s, c: dlrm.retrieval_scores(p, d, s, c, cfg, top_k=10))
q = dlrm_batch(9, batch=1, vocab=cfg.vocab_size)
vals, idx = retr(params, q["dense"], q["sparse"], cands)
jax.block_until_ready(vals)
t0 = time.perf_counter()
vals, idx = retr(params, q["dense"], q["sparse"], cands)
jax.block_until_ready(vals)
print(f"retrieval: top-10 of 100,000 candidates in {(time.perf_counter()-t0)*1e3:.2f} ms")
print("top scores:", np.asarray(vals)[:3].round(3).tolist())
print("OK")
