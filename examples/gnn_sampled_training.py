"""Sampled GNN training over the DI structure: GraphSAGE-style minibatches.

    PYTHONPATH=src python examples/gnn_sampled_training.py

Builds a 100k-edge graph, then trains the gcn-cora architecture with fanout
(10, 5) neighbor sampling — the ``minibatch_lg`` execution mode at laptop
scale.  The sampler IS the DI structure at work: every frontier expansion is
a SEG-offset slice.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_di
from repro.graph import random_uniform_graph, sample_layers
from repro.models import gcn
from repro.models.gnn_common import GraphBatch
from repro.optim import AdamWConfig, apply_updates, init_state

rng = np.random.default_rng(0)
src, dst = random_uniform_graph(100_000, seed=0)
g = build_di(src, dst)
print(f"graph: n={g.n:,} m={g.m:,}")

D_FEAT, N_CLASSES = 64, 7
feats = rng.standard_normal((g.n, D_FEAT)).astype(np.float32)
labels = rng.integers(0, N_CLASSES, g.n).astype(np.int32)

cfg = gcn.GCNConfig(d_in=D_FEAT, d_hidden=16, n_classes=N_CLASSES)
params = gcn.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
opt = init_state(params)


def subgraph_batch(blocks, seed_ids):
    """Union-of-blocks compacted subgraph (the minibatch_lg execution form)."""
    outer = blocks[0]
    nodes = np.asarray(outer.src_nodes)
    idx = {int(v): i for i, v in enumerate(nodes)}
    es, ed, em = [], [], []
    for b in blocks:
        sn, dn = np.asarray(b.src_nodes), np.asarray(b.dst_nodes)
        s, d, m = np.asarray(b.edge_src), np.asarray(b.edge_dst), np.asarray(b.edge_mask)
        for i in np.flatnonzero(m):
            es.append(idx[int(sn[s[i]])]); ed.append(idx[int(dn[d[i]])]); em.append(True)
    nmask = np.zeros(len(nodes), bool)
    for v in seed_ids:
        nmask[idx[int(v)]] = True
    order = np.argsort(es, kind="stable")
    return GraphBatch(
        x=jnp.asarray(feats[nodes]), pos=None, species=None,
        edge_src=jnp.asarray(np.asarray(es, np.int32)[order]),
        edge_dst=jnp.asarray(np.asarray(ed, np.int32)[order]),
        edge_attr=None, edge_mask=jnp.asarray(np.asarray(em)[order]),
        node_mask=jnp.asarray(nmask), labels=jnp.asarray(labels[nodes]),
        graph_ids=jnp.zeros(len(nodes), jnp.int32),
        n_nodes=len(nodes), n_edges=len(es), n_graphs=1)


grad_fn = jax.value_and_grad(gcn.loss_fn)
for step in range(30):
    seeds = rng.choice(g.n, 256, replace=False).astype(np.int32)
    blocks = sample_layers(g, seeds, [10, 5], seed=step)
    batch = subgraph_batch(blocks, seeds)
    loss, grads = grad_fn(params, batch, cfg)
    params, opt, metrics = apply_updates(params, grads, opt, opt_cfg)
    if step % 5 == 0:
        print(f"step {step:3d}  sampled n={batch.n_nodes:5d} e={batch.n_edges:6d}  "
              f"loss {float(loss):.4f}")
print("OK")
