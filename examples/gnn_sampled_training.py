"""Sampled GNN training over a PROPERTY graph: pattern-seeded minibatches.

    PYTHONPATH=src python examples/gnn_sampled_training.py

Builds a labeled/attributed citation-style graph, selects the training
population with a Cypher-lite pattern, and draws every GraphSAGE-style
minibatch neighborhood through ``PropGraph.sample`` — the fused sampling
path (docs/ARCHITECTURE.md §15): the pattern's seed mask feeds the sampler
bit-packed, edge eligibility (``cites`` edges only) is rejected in-kernel
before reservoir selection, and the blocks come back renumbered with
local ids ready for the GCN forward.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PropGraph
from repro.graph import random_uniform_graph
from repro.models import gcn
from repro.models.gnn_common import GraphBatch
from repro.optim import AdamWConfig, apply_updates, init_state

rng = np.random.default_rng(0)
src, dst = random_uniform_graph(50_000, seed=0)
pg = PropGraph().add_edges_from(src, dst)
nodes = np.asarray(pg.graph.node_map)
n = pg.n_vertices
pg.add_node_labels(nodes, rng.choice(["paper", "author"], size=n, p=[0.7, 0.3]))
pg.add_node_properties("year", nodes,
                       rng.integers(2000, 2026, n).astype(np.int32))
es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
pg.add_edge_relationships(nodes[es], nodes[ed],
                          rng.choice(["cites", "writes"], size=len(es)))
print(f"graph: n={pg.n_vertices:,} m={pg.n_edges:,}")

# the training population is a QUERY, not an id list: recent papers only
SEED_PATTERN = "(a:paper {year >= 2010})"
FILTER = "(a)-[:cites]->(b)"  # only citation edges may be sampled
pool = np.flatnonzero(np.asarray(pg.match(SEED_PATTERN).vertex_mask))
print(f"seed pool |{SEED_PATTERN}| = {len(pool):,} vertices")

# one fully fused pattern→sample round trip: seeds never visit the host
blocks = pg.sample(SEED_PATTERN, [10, 5], pattern=FILTER, seed=0)
print("pattern-seeded blocks:",
      [(b.n_src, b.n_dst, b.n_edges) for b in blocks])

D_FEAT, N_CLASSES = 64, 7
feats = rng.standard_normal((n, D_FEAT)).astype(np.float32)
labels = rng.integers(0, N_CLASSES, n).astype(np.int32)

cfg = gcn.GCNConfig(d_in=D_FEAT, d_hidden=16, n_classes=N_CLASSES)
params = gcn.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=100)
opt = init_state(params)


def subgraph_batch(blocks, seed_int):
    """Union-of-blocks compacted subgraph (the minibatch_lg execution form).

    ``blocks[0].src_nodes`` is the widest frontier — a sorted superset of
    every id in the chain — so renumbering is one ``searchsorted`` per
    block.  Block ids are the graph's internal ids, which index ``feats``
    and ``labels`` directly."""
    sub = np.asarray(blocks[0].src_nodes)
    es_l, ed_l = [], []
    for b in blocks:
        sn, dn = np.asarray(b.src_nodes), np.asarray(b.dst_nodes)
        s, d = np.asarray(b.edge_src), np.asarray(b.edge_dst)
        keep = np.asarray(b.edge_mask)
        es_l.append(np.searchsorted(sub, sn[s[keep]]))
        ed_l.append(np.searchsorted(sub, dn[d[keep]]))
    e_src = np.concatenate(es_l).astype(np.int32)
    e_dst = np.concatenate(ed_l).astype(np.int32)
    order = np.argsort(e_src, kind="stable")
    nmask = np.zeros(len(sub), bool)
    nmask[np.searchsorted(sub, seed_int)] = True
    return GraphBatch(
        x=jnp.asarray(feats[sub]), pos=None, species=None,
        edge_src=jnp.asarray(e_src[order]), edge_dst=jnp.asarray(e_dst[order]),
        edge_attr=None, edge_mask=jnp.ones(len(e_src), bool),
        node_mask=jnp.asarray(nmask), labels=jnp.asarray(labels[sub]),
        graph_ids=jnp.zeros(len(sub), jnp.int32),
        n_nodes=len(sub), n_edges=len(e_src), n_graphs=1)


grad_fn = jax.value_and_grad(gcn.loss_fn)
for step in range(30):
    seed_int = rng.choice(pool, 256, replace=False)
    blocks = pg.sample(nodes[seed_int], [10, 5], pattern=FILTER, seed=step)
    batch = subgraph_batch(blocks, seed_int)
    loss, grads = grad_fn(params, batch, cfg)
    params, opt, metrics = apply_updates(params, grads, opt, opt_cfg)
    if step % 5 == 0:
        print(f"step {step:3d}  sampled n={batch.n_nodes:5d} "
              f"e={batch.n_edges:6d}  loss {float(loss):.4f}")
print("OK")
