"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart mid-run (the (b) deliverable's training flavor).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses a 100M-parameter gemma2-style config (post-norms, softcaps, GQA, local/
global alternation — the full feature set) on synthetic step-addressed data;
injects a failure at mid-run to demonstrate restart, then verifies the loss
kept improving.
"""
import argparse

import jax.numpy as jnp

parser = argparse.ArgumentParser()
parser.add_argument("--steps", type=int, default=200)  # "few hundred" on TPU; use ~8-20 on CPU
parser.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
args = parser.parse_args()

import jax

from repro.checkpoint import CheckpointManager
from repro.data import lm_batch
from repro.ft import FailureInjector, TrainController
from repro.models import transformer as T
from repro.optim import AdamWConfig, apply_updates, init_state

# ~100M params: 12L, d=768, 12H/4KV, ff=2048, vocab=32768
cfg = T.TransformerConfig(
    name="lm100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
    d_ff=2048, vocab=32768, pattern=("local", "global"), window=256,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True, scale_embed=True,
    tie_embeddings=True, dtype=jnp.float32, loss_chunk=128, attn_impl="direct",
)
print(f"model: {cfg.n_params/1e6:.1f}M params")

params = T.init_params(jax.random.PRNGKey(0), cfg)
opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
state = (params, init_state(params))

BATCH, SEQ = 2, 128  # CPU-demo scale; raise on real hardware


@jax.jit
def jit_step(state, batch):
    params, opt = state
    loss, grads = jax.value_and_grad(T.loss_fn)(params, batch["tokens"], batch["labels"], cfg)
    params, opt, metrics = apply_updates(params, grads, opt, opt_cfg)
    return (params, opt), {"loss": loss, **metrics}


def step_fn(state, step):
    return jit_step(state, lm_batch(step, batch=BATCH, seq=SEQ, vocab=cfg.vocab))


losses = []


def log(step, metrics):
    losses.append(float(metrics["loss"]))
    if step % 20 == 0:
        print(f"step {step:4d}  loss {losses[-1]:.4f}  lr {float(metrics['lr']):.2e}")


ctrl = TrainController(CheckpointManager(args.ckpt_dir, keep=2), step_fn, ckpt_every=50)
ctrl.run(state, args.steps, injector=FailureInjector([args.steps // 2 + 1]), log=log)
print(f"\ninitial loss {losses[0]:.4f} → final {losses[-1]:.4f} "
      f"(survived 1 injected failure, {len(losses)} total steps incl. replay)")
assert losses[-1] < losses[0], "loss did not improve"
print("OK")
