"""Quickstart: the paper's property-graph workflow end-to-end (§V + §VI).

    PYTHONPATH=src python examples/quickstart.py

Builds a Tab.-I-regime random graph, attaches labels/relationships from
50-value pools, runs OR-semantics queries on all three DIP backends, induces a
typed subgraph and runs property-filtered BFS + PageRank on it.
"""
import numpy as np

from repro.core import PropGraph
from repro.graph import pagerank, random_uniform_graph

rng = np.random.default_rng(0)

# -- 1. ingest: edges in bulk (the Arkouda dataframe → Arachne path) ---------
src, dst = random_uniform_graph(100_000, seed=0)  # graph1 regime: n ≈ 0.865 m
pg = PropGraph(backend="arr").add_edges_from(src, dst)
print(f"graph: n={pg.n_vertices:,} vertices, m={pg.n_edges:,} edges")

# -- 2. attributes: labels + relationships from 50-value pools ---------------
nodes = np.asarray(pg.graph.node_map)
labels = rng.choice([f"label{i}" for i in range(50)], size=len(nodes))
pg.add_node_labels(nodes, labels)
es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
rels = rng.choice([f"rel{i}" for i in range(50)], size=len(es))
pg.add_edge_relationships(nodes[es], nodes[ed], rels)
pg.add_node_properties("score", nodes, rng.random(len(nodes)).astype(np.float32))
print(f"attributes: {len(pg.label_set())} labels, {len(pg.relationship_set())} relationships")

# -- 3. queries (OR semantics, §VI) -------------------------------------------
vmask = pg.query_labels(["label1", "label2", "label3"])
emask = pg.query_relationships(["rel7", "rel8"])
print(f"query: {int(vmask.sum()):,} vertices, {int(emask.sum()):,} edges matched")

# all three backends agree
for be in ("list", "listd"):
    pg2 = PropGraph(backend=be).add_edges_from(src, dst)
    pg2.add_node_labels(nodes, labels)
    assert bool((pg2.query_labels(["label1", "label2", "label3"]) == vmask).all()), be
print("backend agreement: arr == list == listd ✓")

# -- 4. subgraph induction + analytics on the typed subgraph ------------------
sub, kept = pg.subgraph(labels=["label1", "label2", "label3"],
                        relationships=["rel7", "rel8"])
print(f"induced subgraph: n={sub.n:,}, m={sub.m:,}")

depths = pg.bfs(nodes[:8], relationships=["rel7", "rel8"])
reached = int((np.asarray(depths) >= 0).sum())
print(f"property-filtered BFS from 8 sources reached {reached:,} vertices")

pr = pagerank(pg.graph, edge_mask=emask)
top = np.argsort(np.asarray(pr))[-3:][::-1]
print(f"typed-edge PageRank top vertices: {[int(nodes[i]) for i in top]}")

# -- 5. declarative patterns: match() / explain() -----------------------------
# Instead of composing masks by hand, describe the shape you want
# (grammar: src/repro/query/README.md).  Labels OR with '|', typed property
# predicates go in '{...}', '-[...]->' / '<-[...]-' set hop direction.
pg.add_node_properties("age", nodes, rng.integers(0, 90, len(nodes)).astype(np.int32))
pattern = '(a:label1|label2|label3 {age > 30})-[f:rel7|rel8]->(b:label4|label5|label6)'

# explain() shows the plan before paying for it: which DIP impl each mask
# uses (selectivity-driven), chain orientation, and kernel fusion.
print(pg.explain(pattern))

res = pg.match(pattern)
print(f"match: {res.n_vertices():,} vertices, {res.n_edges():,} edges in full matches")
binds = res.bindings()  # per-variable masks: 'a'/'b' over vertices, 'f' over edges
print(f"bindings: a={int(binds['a'].sum()):,} f={int(binds['f'].sum()):,} "
      f"b={int(binds['b'].sum()):,}")

# results are plain masks — they compose with everything above:
msub, mkept = res.subgraph(pg.graph)          # materialize matched edges
halo = res.expand(pg.graph, 2)                # 2-hop neighborhood of the match
print(f"match subgraph: n={msub.n:,}, m={msub.m:,}; 2-hop halo: {int(halo.sum()):,}")

# the same match, hand-composed (what the engine fuses for you):
from repro.core.queries import induce_edge_mask_directed
vm_a = (pg.query_labels(["label1", "label2", "label3"])
        & pg.vertex_predicate_mask("age", ">", 30))
vm_b = pg.query_labels(["label4", "label5", "label6"])
hand = induce_edge_mask_directed(
    pg.graph, vm_a, vm_b, pg.query_relationships(["rel7", "rel8"]), 1)
assert bool((res.edge_mask == hand).all())
print("match == hand-composed pipeline ✓")

# -- 5b. reachability: variable-length patterns + frontier analytics ----------
# '-[:rel*1..k]->' matches walks of 1..k typed edges (the cybersecurity
# "within k flows-hops" shape); '*' runs to a fixed point.  The same
# frontier engine (docs/ARCHITECTURE.md §10) powers k-hop and connected
# components that RESPECT the property layer — no subgraph materialized.
vres = pg.match('(a:label1)-[:rel7*1..3]->(b:label2)')
print(f"variable-length match (*1..3): {vres.n_vertices():,} vertices, "
      f"{vres.n_edges():,} edges on matched walks")

halo3 = pg.khop(nodes[:8], 3, pattern='(a)-[:rel7|rel8]->(b)', impl='csr')
assert bool((pg.khop(nodes[:8], 3, pattern='(a)-[:rel7|rel8]->(b)') == halo3).all())
print(f"k-hop: {int(halo3.sum()):,} vertices within 3 typed hops of 8 seeds "
      f"(impl='csr' gathers only the frontier's adjacency ≡ frontier path)")

comp = np.asarray(pg.components('(a)-[:rel7]->(b)'))
sizes = np.bincount(comp[comp >= 0])
print(f"components of the rel7 subgraph: {int((sizes > 0).sum()):,} "
      f"components, largest = {int(sizes.max()):,} vertices")

# -- 6. persistence: ingest once, reload in seconds ---------------------------
# save_propgraph stores the DI arrays + raw attribute pairs (backend- and
# placement-independent), so the expensive §V ingestion never reruns.
import os
import tempfile

from repro.core.io import load_propgraph, save_propgraph

path = save_propgraph(os.path.join(tempfile.mkdtemp(), "quickstart_pg"), pg)
pg_l = load_propgraph(path, backend="listd")  # reload under a DIFFERENT backend
assert bool((pg_l.query_labels(["label1", "label2", "label3"]) == vmask).all())
assert bool((pg_l.match(pattern).edge_mask == res.edge_mask).all())
print(f"save/load round-trip (arr → listd) ✓  ({path})")

# -- 7. sharded execution: the paper's P locales on a device mesh -------------
# PropGraph(mesh=...) distributes the entity axis of every store over the
# mesh; queries run shard-local and return bitwise-identical masks
# (docs/ARCHITECTURE.md §7).  Needs >1 device — on CPU, launch with
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
#       PYTHONPATH=src python examples/quickstart.py
import jax

if len(jax.devices()) > 1:
    from repro.launch.mesh import make_entity_mesh

    mesh = make_entity_mesh()
    pg_s = load_propgraph(path, mesh=mesh)  # reload straight onto the mesh
    svmask = pg_s.query_labels(["label1", "label2", "label3"])
    assert bool((svmask == vmask).all())
    sres = pg_s.match(pattern)
    assert bool((sres.edge_mask == res.edge_mask).all())
    from repro.launch.sharding import pg_arr_specs

    print(f"sharded over {len(mesh.devices)} devices: masks identical ✓ "
          f"(bitmap layout {pg_arr_specs(mesh)['bitmap']})")
else:
    print("sharded demo skipped: 1 device "
          "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# -- 8. serving: many concurrent queries → few fused launches -----------------
# The service layer (src/repro/service/README.md, docs/ARCHITECTURE.md §8)
# holds named graphs in a registry, micro-batches concurrent requests,
# coalesces their masks into single batched kernel launches and caches
# plans + results (invalidated automatically when a graph mutates).
# CLI driver with a synthetic multi-tenant workload:
#   PYTHONPATH=src python -m repro.launch.pgserve --smoke
from repro.service import Service

with Service() as svc:
    svc.add_graph("quickstart", pg)  # or svc.load_graph("quickstart", path)
    res_s = svc.query("quickstart", pattern)  # blocking single query
    assert bool((res_s.edge_mask == res.edge_mask).all())
    futs = [svc.submit("quickstart", pattern) for _ in range(8)]  # concurrent
    assert all(bool((f.result().edge_mask == res.edge_mask).all()) for f in futs)
    s = svc.stats()
    print(f"service: {s['completed']} served, {s['result_hits']} cache hits, "
          f"{s.get('coalesced_launches', 0)} coalesced launches ✓")

# -- 9. streaming ingest: LSM overlay, snapshots, what-if forks ---------------
# The first query sealed the DIP stores; from here on, mutations append to
# an overlay delta instead of re-running the §V ingest pipeline
# (docs/ARCHITECTURE.md §11, src/repro/overlay/README.md).  snapshot()
# pins an immutable version for readers; fork() branches a writable
# copy-on-write view; compact() folds the overlay back into sorted base
# stores (bitwise-identical to a from-scratch build).
snap = pg.snapshot()                   # zero-copy: shares the sealed stores
pinned = np.asarray(snap.query_labels(["label1"]))

bs, bd = nodes[:512], nodes[512:1024]  # a late-arriving edge batch
pg.insert_edges(bs, bd)                # O(batch): no re-sort, no rebuild
pg.add_edge_relationships(bs, bd, ["rel7"] * 512)
assert bool((np.asarray(snap.query_labels(["label1"])) == pinned).all())
print(f"streamed {pg.delta_stats()['delta_edges']:,} delta edges; "
      f"snapshot still answers from the pinned version ✓")

what_if = pg.fork()                    # private overlay over the shared base
top_rel7 = np.argsort(np.asarray(pr))[-4:]
what_if.delete_vertices(nodes[top_rel7])   # tombstones; parent untouched
c_now = np.asarray(pg.components("(a)-[:rel7]->(b)"))
c_wo = np.asarray(what_if.components("(a)-[:rel7]->(b)"))
print(f"what-if fork: rel7 subgraph has {int((np.bincount(c_wo[c_wo >= 0]) > 0).sum()):,} "
      f"components without the top-PageRank vertices "
      f"(vs {int((np.bincount(c_now[c_now >= 0]) > 0).sum()):,} live) — "
      f"parent version {pg.version}, fork version {what_if.version}")

before = np.asarray(pg.match(pattern).vertex_mask)
pg.compact()                           # merge: overlay → fresh base stores
assert not pg.has_overlay()
assert bool((np.asarray(pg.match(pattern).vertex_mask) == before).all())
print("compaction folded the overlay in; answers unchanged ✓")

# -- 10. weighted analytics: one semiring relax, three algorithms -------------
# The frontier step generalizes over a semiring (docs/ARCHITECTURE.md §12):
# (min, +) over an edge-property weight = Bellman–Ford shortest paths,
# (+, ×) = PageRank, mode-relax = label-propagation communities.  All take
# the same single-hop pattern hook as khop/components, and an edge WITHOUT
# the weight property is not traversable (no sound default).
rng_w = np.random.default_rng(7)
esn, edn = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
pg.add_edge_properties("toll", nodes[esn], nodes[edn],
                       rng_w.uniform(0.5, 2.0, len(esn)).astype(np.float32))
dist = np.asarray(pg.shortest_paths(nodes[:8], weight="toll",
                                    pattern="(a)-[:rel7]->(b)"))
print(f"weighted shortest paths: {int(np.isfinite(dist).sum()):,} vertices "
      f"reachable over rel7, median toll "
      f"{float(np.median(dist[np.isfinite(dist)])):.2f}")
prw = np.asarray(pg.pagerank(weight="toll"))
comm = np.asarray(pg.communities("(a)-[:rel7]->(b)"))
sizes10 = np.bincount(comm[comm >= 0])
print(f"toll-weighted PageRank sums to {float(prw.sum()):.3f}; "
      f"label propagation found {int((sizes10 > 0).sum()):,} communities "
      f"on the rel7 subgraph")

# -- 11. observability: EXPLAIN ANALYZE, trace spans, Prometheus metrics ------
# Every query can report where its wall time went (docs/ARCHITECTURE.md §13).
# explain_analyze() runs the plan's device stages twice under
# block_until_ready, so the first call's jit compilation separates cleanly
# from steady-state execution; the service keeps per-query span trees in a
# bounded ring (slow_query_ms=0 captures every query, the demo lever) and
# renders every counter as Prometheus text — the same text the pgd
# `metrics` wire verb serves to a scraper.
from repro.obs import parse_prometheus
from repro.service import ServiceConfig

rep = pg.explain_analyze(pattern)
print(f"explain analyze: compile {rep.compile_ms:.1f} ms once, then "
      f"{rep.steady_ms:.3f} ms/query steady-state (cold={rep.cold})")
with Service(config=ServiceConfig(slow_query_ms=0.0)) as svc:
    svc.add_graph("g", pg)
    for _ in range(4):
        svc.query("g", pattern)
    tr = svc.trace_log()[-1]
    stages = [s["name"] for s in tr["spans"]]
    parsed = parse_prometheus(svc.metrics_text())
    print(f"trace {tr['trace_id']}: {' → '.join(stages)}")
    print(f"metrics: {int(parsed['pg_service_submitted_total'])} submitted, "
          f"{int(parsed.get('pg_service_result_hits_total', 0))} result-cache "
          f"hits, {len(parsed)} series exposed")

# -- 12. bit-packed mask plane: 8× smaller bitmaps, same answers --------------
# DIP-arr planes — and every mask they emit, through the kernels, the shard
# collectives and the wire — are uint32 bitmaps: 1 bit/entity instead of the
# paper's 1 byte (docs/ARCHITECTURE.md §14).  The byte layout stays available
# for one release (REPRO_PG_BYTE_MASKS=1, or bitplane.byte_masks() in-process);
# answers are bitwise-identical either way.
from repro.core import bitplane

with bitplane.byte_masks():
    pg_byte = PropGraph(backend="arr").add_edges_from(src, dst)
    pg_byte.add_node_labels(nodes, labels)
    assert bool((pg_byte.query_labels(["label1", "label2", "label3"]) == vmask).all())
plane = pg._vstore.finalize().bitmap        # packed: (K, ⌈n/32⌉) uint32
plane_byte = pg_byte._vstore.finalize().bitmap  # byte fallback: (K, n) int8
print(f"label plane: {plane_byte.nbytes:,} B (byte layout) → {plane.nbytes:,} B "
      f"(packed, {plane_byte.nbytes / plane.nbytes:.1f}× smaller), "
      f"answers bitwise-identical ✓")

# -- 13. fused neighborhood sampling: pattern → sample → blocks ---------------
# PropGraph.sample() is the one-launch GNN data path (docs/ARCHITECTURE.md
# §15): seeds can be a Cypher-lite pattern (the match mask feeds the
# sampler bit-packed, never unpacked to host), an edge pattern restricts
# which edges may be sampled IN-KERNEL before reservoir selection, and the
# result is a renumbered bipartite block per layer — uniform without
# replacement, bitwise-reproducible for a fixed seed.  The service serves
# the same verb at QPS, coalescing concurrent requests into one batched
# launch (see examples/gnn_sampled_training.py for training on these
# blocks and examples/recsys_serving.py for the fused sample+embed bags).
blocks = pg.sample("(a:label1 {age > 30})", [8, 4],
                   pattern="(a)-[:rel7|rel8]->(b)", seed=0)
again = pg.sample("(a:label1 {age > 30})", [8, 4],
                  pattern="(a)-[:rel7|rel8]->(b)", seed=0)
assert all(bool((b.edge_mask == a.edge_mask).all())
           for b, a in zip(blocks, again))
print(f"fused sampling: {blocks[-1].n_dst:,} pattern seeds → blocks "
      f"{[(b.n_src, b.n_dst, b.n_edges) for b in blocks]}, reproducible ✓")
with Service() as svc:
    svc.add_graph("g", pg)
    specs = [(nodes[32 * i:32 * i + 32], i) for i in range(8)]
    batch = svc.sample_batch("g", specs, [4])
    s = svc.stats()
    print(f"served sampling: {len(batch)} requests in "
          f"{s.get('sample_coalesced_launches', 0)} coalesced launch(es) ✓")
print("OK")
