"""Bit-packed vs byte mask-plane scan bandwidth (the PR-9 headline numbers).

The DIP-arr plane is the bandwidth-bound object in the whole query path: a
label query streams all ``k × n`` plane entries once (roofline: ~0.1
flop/byte).  Packing the plane 8× smaller (uint32 words, 1 bit/entity)
cuts the streamed bytes 8× — these rows measure both the structural
bytes-moved ratio and the realized wall-clock speedup on ``bitmap_query``
at ``n ≥ 1M``, plus the executor-level payoff: a fused predicate+label
``match()`` vs the two-op composition it replaces.

Rows append to ``BENCH_scan.json`` (override: ``BENCH_JSON_PATH``) with
``run_id``/``git_sha`` stamps like every other JSON section.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit_json, time_call
from repro.core import bitplane, dip_arr


def _plane(n: int, k: int, packed: bool, seed: int = 0):
    rng = np.random.default_rng(seed)
    ent = rng.integers(0, n, size=2 * n).astype(np.int64)
    att = rng.integers(0, k, size=2 * n).astype(np.int64)
    return dip_arr.build_dip_arr_host(ent, att, k=k, n=n, packed=packed)


def run(n: int = 1_000_000, k: int = 64,
        json_path: str = "BENCH_scan.json") -> None:
    byte = _plane(n, k, packed=False)
    packed = _plane(n, k, packed=True)
    mask = jnp.zeros((k,), bool).at[jnp.arange(0, k, 3)].set(True)

    # parity first — a fast wrong answer is not a benchmark row
    ref = np.asarray(dip_arr.query_any(byte, mask, impl="scan"))
    got = np.asarray(bitplane.unpack_mask(
        dip_arr.query_any_words(packed, mask), n))
    assert np.array_equal(ref, got), "packed/byte disagree — not benchmarking"

    byte_bytes = byte.bitmap.size * byte.bitmap.dtype.itemsize  # k·n int8
    word_bytes = packed.bitmap.size * packed.bitmap.dtype.itemsize  # k·⌈n/32⌉·4

    t_byte = time_call(lambda: dip_arr.query_any(byte, mask, impl="scan"))
    emit_json(f"scan_byte_n{n}", t_byte, path=json_path, n=n, k=k,
              bytes_moved=byte_bytes,
              gb_per_s=round(byte_bytes / t_byte / 1e9, 2))
    t_packed = time_call(lambda: dip_arr.query_any_words(packed, mask))
    emit_json(f"scan_packed_n{n}", t_packed, path=json_path, n=n, k=k,
              bytes_moved=word_bytes,
              gb_per_s=round(word_bytes / t_packed / 1e9, 2),
              bytes_ratio=round(byte_bytes / word_bytes, 2),
              speedup=round(t_byte / t_packed, 2))
    # packed including the one boundary unpack (what a bool consumer pays)
    t_pu = time_call(lambda: bitplane.unpack_mask(
        dip_arr.query_any_words(packed, mask), n))
    emit_json(f"scan_packed_unpack_n{n}", t_pu, path=json_path, n=n, k=k,
              speedup=round(t_byte / t_pu, 2))

    # batched (Q=8) — the executor's fused-launch shape
    masks = jnp.zeros((8, k), bool).at[jnp.arange(8)[:, None],
                                       jnp.arange(0, k, 5)[None, :]].set(True)
    t_byte_b = time_call(lambda: dip_arr.query_any_batched(byte, masks))
    emit_json(f"scan_batched_byte_n{n}", t_byte_b, path=json_path, n=n, k=k, q=8)
    t_packed_b = time_call(lambda: dip_arr.query_any_batched_words(packed, masks))
    emit_json(f"scan_batched_packed_n{n}", t_packed_b, path=json_path, n=n,
              k=k, q=8, speedup=round(t_byte_b / t_packed_b, 2))

    # -- executor payoff: fused predicate+label match vs two-op composition --
    from repro.core import PropGraph

    rng = np.random.default_rng(1)
    m = n  # one edge per vertex keeps the build cheap; masks dominate anyway
    src = rng.integers(0, n // 2, m)
    dst = rng.integers(0, n // 2, m)
    # 0-hop pattern so the mask-combination stage IS the measurement —
    # hop propagation would swamp it with edge-scatter time
    pat = "(a:person {age > 40})"
    for lbl, p in (("packed", True), ("byte", False)):
        with bitplane.byte_masks(not p):
            pg = PropGraph(backend="arr").add_edges_from(src, dst)
            nodes = np.asarray(pg.graph.node_map)
            pg.add_node_labels(nodes, rng.choice(["person", "org"], len(nodes)))
            pg.add_node_properties(
                "age", nodes, rng.integers(0, 80, len(nodes)).astype(np.float32))
            plan = None
            from repro.query import execute_plan, parse, plan_pattern
            plan = plan_pattern(pg, parse(pat))
            t = time_call(lambda: execute_plan(pg, plan))
            emit_json(f"match_pred_label_{lbl}_n{n}", t, path=json_path,
                      n=len(nodes), mode=lbl)

            def composed():  # the two-op baseline the fused combine replaces
                return (pg.query_labels(["person"])
                        & pg.vertex_predicate_mask("age", ">", 40.0))

            t2 = time_call(composed)
            emit_json(f"mask_pred_label_composed_{lbl}_n{n}", t2,
                      path=json_path, n=len(nodes), mode=lbl)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--k", type=int, default=64)
    a = ap.parse_args()
    run(n=a.n, k=a.k)
