"""Sharded DIP query scaling: locale sweep over virtual devices.

The paper scales 1→8 Chapel locales (§VII); here the same sweep runs as REAL
multi-device execution — ``make_entity_mesh(P)`` sub-meshes over virtual CPU
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``, set
automatically when this file is the main module), each device scanning its
N/P entity slice under ``shard_map`` (docs/ARCHITECTURE.md §7).

Rows (JSON via ``benchmarks.common.emit_json``; ``BENCH_JSON_PATH`` appends
to a file for the cross-PR trajectory):
  * ``shard_query_{backend}_d{P}``  — query_labels on a P-device mesh.
  * ``shard_match_{backend}_d{P}``  — full 1-hop ``match`` on the mesh.
  * ``shard_query_{backend}_d0``    — the single-device (mesh=None) baseline.

Method note: virtual host devices share one CPU's cores, so wall-clock is NOT
expected to drop 1/P — the sweep validates the distribution machinery
(placement, shard_map, collective combination) and measures its overhead;
true scaling needs one chip per shard (``method`` records this).
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede first jax init to take effect
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import numpy as np

from benchmarks.common import emit_json, time_call

METHOD = "host-virtual-devices"
PATTERN = "(a:l1|l2)-[:follows]->(b:l3)"


def _build(backend: str, m: int, mesh, seed: int = 0):
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    labels = rng.choice([f"l{i}" for i in range(12)], size=len(nodes))
    pg.add_node_labels(nodes, labels)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es))
    pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    return pg


def run(m: int = 100_000, device_counts=(1, 2, 4, 8)) -> None:
    import shutil
    import tempfile

    import jax

    from repro.core.io import load_propgraph, save_propgraph
    from repro.launch.mesh import make_entity_mesh

    avail = len(jax.devices())
    counts = [c for c in device_counts if c <= avail]
    if counts != list(device_counts):
        print(f"# bench_shard: only {avail} device(s) visible — sweeping {counts} "
              "(run standalone or set XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    # ingest ONCE (the expensive §V path), then reopen per backend / locale
    # count from disk — the saved format is backend- and placement-independent
    tmp = tempfile.mkdtemp(prefix="bench_shard_")
    path = save_propgraph(f"{tmp}/pg", _build("arr", m, mesh=None))

    for backend in ("arr", "list", "listd"):
        pg0 = load_propgraph(path, backend=backend)
        t = time_call(lambda: pg0.query_labels(["l1", "l2"]))
        emit_json(f"shard_query_{backend}_d0_m{m}", t, backend=backend, m=m,
                  devices=0, method=METHOD, note="single-device baseline")
        baseline = np.asarray(pg0.query_labels(["l1", "l2"]))

        for p in counts:
            mesh = make_entity_mesh(p)
            pg = load_propgraph(path, backend=backend, mesh=mesh)
            got = np.asarray(pg.query_labels(["l1", "l2"]))
            assert (got == baseline).all(), (backend, p)  # bench rows are verified
            t = time_call(lambda: pg.query_labels(["l1", "l2"]))
            emit_json(f"shard_query_{backend}_d{p}_m{m}", t, backend=backend,
                      m=m, devices=p, method=METHOD)
            t = time_call(lambda: pg.match(PATTERN))
            emit_json(f"shard_match_{backend}_d{p}_m{m}", t, backend=backend,
                      m=m, devices=p, method=METHOD)

    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=100_000)
    a = ap.parse_args()
    run(m=a.m)
