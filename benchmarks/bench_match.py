"""Pattern-engine benchmark: end-to-end ``match()`` plus the planner's two
headline optimizations, each against its unoptimized counterpart.

Rows (JSON via ``benchmarks.common.emit_json`` — set ``BENCH_JSON_PATH`` to
also append to a file for a cross-PR perf trajectory):
  * ``match_1hop`` / ``match_2hop``  — full parse→plan→execute per backend.
  * ``match_exec_1hop``              — execution only (pattern pre-planned),
    vs ``hand_pipeline_1hop``, the §VI hand-composed mask pipeline the
    engine replaces; the delta is the declarative layer's overhead.
  * ``arr_fused_masks`` vs ``arr_separate_masks`` — the batched multi-mask
    bitmap query (one launch) vs one launch per node slot.
  * ``listd_budget`` vs ``listd_inverted`` — output-sized gather vs full
    scan on a selective label, the planner's skew decision.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit_json, time_call
from repro.core import PropGraph
from repro.core.queries import induce_edge_mask_directed
from repro.graph import random_uniform_graph
from repro.query import execute_plan, parse, plan_pattern

PATTERN_1HOP = "(a:needle)-[:follows]->(b:common)"
PATTERN_2HOP = "(a:needle)-[:follows]->(b)-[:likes]->(c:common)"


def _build(backend: str, m: int, seed: int = 0) -> PropGraph:
    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    labels = rng.choice(["needle", "mid", "common"], size=len(nodes), p=[0.02, 0.18, 0.8])
    pg.add_node_labels(nodes, labels)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es), p=[0.3, 0.7])
    pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    return pg


def run(m: int = 100_000) -> None:
    for backend in ("arr", "list", "listd"):
        pg = _build(backend, m)
        n = pg.n_vertices

        t = time_call(lambda: pg.match(PATTERN_1HOP))
        emit_json(f"match_1hop_{backend}_m{m}", t, backend=backend, m=m,
                  edges_per_s=round(m / t))
        t = time_call(lambda: pg.match(PATTERN_2HOP))
        emit_json(f"match_2hop_{backend}_m{m}", t, backend=backend, m=m,
                  edges_per_s=round(m / t))

        plan = plan_pattern(pg, parse(PATTERN_1HOP))
        t = time_call(lambda: execute_plan(pg, plan))
        emit_json(f"match_exec_1hop_{backend}_m{m}", t, backend=backend, m=m)

        def hand():
            vm_a = pg.query_labels(["needle"])
            vm_b = pg.query_labels(["common"])
            em = pg.query_relationships(["follows"])
            return induce_edge_mask_directed(pg.graph, vm_a, vm_b, em, 1)

        t = time_call(hand)
        emit_json(f"hand_pipeline_1hop_{backend}_m{m}", t, backend=backend, m=m)

    # -- fusion: one batched bitmap launch vs one launch per mask (arr) ------
    pg = _build("arr", m)
    queries = [("needle",), ("mid",), ("common",)]
    t = time_call(lambda: pg._vstore.query_any_batched(queries))
    emit_json(f"arr_fused_masks_m{m}", t, q=len(queries))
    t = time_call(lambda: [pg.query_labels(list(q)) for q in queries])
    emit_json(f"arr_separate_masks_m{m}", t, q=len(queries))

    # -- fused packed predicate+label combine vs the byte two-op pipeline ----
    # (arr; 0-hop pattern so mask combination IS the work).  "composed" is
    # the pre-bitplane pipeline: byte store, label query + separate
    # predicate mask op ANDed in bool space.  "fused" evaluates the
    # predicate inside the single packed word-space combine launch.
    from repro.core import bitplane

    pred_pat = "(a:common {age > 40})"
    times = {}
    for mode, p in (("fused", True), ("composed", False)):
        with bitplane.byte_masks(not p):
            pg = _build("arr", m)
            nodes = np.asarray(pg.graph.node_map)
            rng = np.random.default_rng(9)
            pg.add_node_properties(
                "age", nodes,
                rng.integers(0, 80, len(nodes)).astype(np.float32))
            plan = plan_pattern(pg, parse(pred_pat))
            times[mode] = time_call(lambda: execute_plan(pg, plan))
    emit_json(f"arr_pred_label_fused_m{m}", times["fused"], m=m,
              speedup=round(times["composed"] / times["fused"], 2))
    emit_json(f"arr_pred_label_composed_m{m}", times["composed"], m=m)

    # -- skew: budget gather vs inverted scan on a selective label (listd) ---
    pg = _build("listd", m)
    t = time_call(lambda: pg.query_labels(["needle"], impl="budget"))
    emit_json(f"listd_budget_needle_m{m}", t)
    t = time_call(lambda: pg.query_labels(["needle"], impl="inverted"))
    emit_json(f"listd_inverted_needle_m{m}", t)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=100_000)
    a = ap.parse_args()
    run(m=a.m)
