"""Fused property-filtered neighborhood sampling: one-launch pipeline vs
the host-loop baseline, served QPS, and the sample+embed fusion
(docs/ARCHITECTURE.md §15).

Rows (JSON via ``benchmarks.common.emit_json``; ``benchmarks/run.py``
points them at ``BENCH_sample.json`` so the cross-PR perf trajectory
records):

  * ``sample_hostloop_*`` vs ``sample_fused_*`` — the tentpole
    comparison: the Arkouda-shaped baseline runs ``match`` → ships the
    seed mask to the host → python-loops over seeds slicing and
    filtering each adjacency window with numpy; the fused path keeps the
    packed seed bitmap on device and draws every seed's filtered sample
    in ONE launch (``neighbor_sample_from_words``).  Explicit-seed rows
    at S ∈ {256, 1024} use ``neighbor_sample``; ``sample_fused_batch8x256``
    is the service's coalesced shape — 8 concurrent 256-seed requests as
    ONE ``neighbor_sample_batched`` launch — against the host loop over
    the same 2048 seeds.  ``speedup`` on each fused row is hostloop/fused
    at the same seed set.
  * ``sample_serve_c{c}_*`` — a pipelined closed loop driving
    ``Service.submit_sample`` with ``c`` requests outstanding (submitted
    in waves of ``c``, the shape an async client produces; thread-per-
    client loops measure the GIL, not the service, at these microsecond
    scales).  Keyed entropy, so NOTHING is served from the result cache —
    every request samples.  ``speedup`` is QPS over the
    ``sample_serve_seq_*`` row: the same request stream issued one at a
    time (sequential submission), which is what request coalescing is
    supposed to beat.  ``sample_direct_seq_*`` records the no-service
    ``PropGraph.sample`` loop for scale.
  * ``sample_embed_fused_*`` vs ``sample_embed_twoprog_*`` — the
    ``sample+lookup`` verb as one device program vs sample-then-embed as
    two programs with the sampled block crossing the host boundary
    between them (what the composition costs when sampling and embedding
    are separate requests, which is exactly the case fusion removes).

Every surface is oracle-verified BEFORE timing: kernel outputs against
``kernels.neighbor_sample.ref.check_sample`` (membership, no
duplicates, exact counts, filtered-edge exclusion), the host-loop
baseline against filtered degrees, the service path bitwise against
direct ``PropGraph.sample``, and the fused bags bitwise against the
two-program composition.  ``compiles`` on the last row records
``sample_compile_count()`` — the bucketing's bounded-specialization
claim, measured.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np

from benchmarks.common import emit_json, time_call


def _host_loop(seg, dstv, seeds, eok, fanout, rng):
    """The match→host→per-seed-loop baseline: slice each seed's window,
    filter with the host bool mask, numpy-choice without replacement."""
    out = []
    for s in seeds:
        lo, hi = seg[s], seg[s + 1]
        cand = np.arange(lo, hi)[eok[lo:hi]]
        k = min(fanout, cand.size)
        out.append(dstv[rng.choice(cand, size=k, replace=False)]
                   if k else np.empty(0, np.int64))
    return out


def _blocks_equal(got, ref) -> bool:
    if len(got) != len(ref):
        return False
    for bg, br in zip(got, ref):
        for f in ("src_nodes", "dst_nodes", "edge_src", "edge_dst",
                  "edge_mask"):
            a, b = np.asarray(getattr(bg, f)), np.asarray(getattr(br, f))
            if a.shape != b.shape or not (a == b).all():
                return False
    return True


def run(m: int = 50_000, requests: int = 64, seed: int = 0, repeats: int = 3,
        json_path: Optional[str] = None) -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import bitplane
    from repro.kernels.neighbor_sample import (
        neighbor_sample,
        neighbor_sample_from_words,
        sample_compile_count,
        sample_embed,
    )
    from repro.kernels.neighbor_sample.ref import check_sample
    from repro.launch.pgserve import build_tenant_graph
    from repro.service import Service

    FAN = 8
    FILT = "(a)-[:follows]->(b)"
    SEED_PAT = "(a:l0|l1|l2)"

    pg = build_tenant_graph("arr", m, seed=seed)
    nodes = np.asarray(pg.graph.node_map)
    n, me = pg.n_vertices, pg.n_edges
    seg_d, dst_d = pg.graph.seg, pg.graph.dst
    seg, dstv = np.asarray(seg_d), np.asarray(dst_d)
    max_deg = int(pg.graph.max_deg)
    eok = np.asarray(pg.match(FILT).edge_mask)
    ew = bitplane.pack_mask(jnp.asarray(eok))
    rng = np.random.default_rng(seed)

    # ---- oracle verification before ANY timing ----------------------------
    for S in (256, 1024):
        sds = rng.choice(n, S, replace=False).astype(np.int32)
        nb, ei, mk = neighbor_sample(seg_d, dst_d, n, me, sds,
                                     jax.random.PRNGKey(1), fanout=FAN,
                                     edge_words=ew, max_deg=max_deg)
        check_sample(seg, dstv, sds, eok, FAN, np.asarray(nb)[:S],
                     np.asarray(ei)[:S], np.asarray(mk)[:S])
        base = _host_loop(seg, dstv, sds, eok, FAN, np.random.default_rng(2))
        fdeg = np.asarray([eok[seg[s]:seg[s + 1]].sum() for s in sds])
        assert all(len(b) == min(FAN, d) for b, d in zip(base, fdeg))

    # ---- tentpole: fused one-launch vs match→host→per-seed-loop -----------
    res = pg.match(SEED_PAT)
    n_seeds = int(np.asarray(res.vertex_mask).sum())
    key = jax.random.PRNGKey(3)

    def fused_pattern():
        r = pg.match(SEED_PAT)
        words = bitplane.pack_mask(jnp.asarray(r.vertex_mask))
        cnt = int(jnp.sum(jnp.asarray(r.vertex_mask)))
        out = neighbor_sample_from_words(
            seg_d, dst_d, n, me, words, cnt, key, fanout=FAN,
            edge_words=ew, max_deg=max_deg)
        return np.asarray(out[2])  # neighbors, back on host like the baseline

    def hostloop_pattern():
        vm = np.asarray(pg.match(SEED_PAT).vertex_mask)  # device → host
        return _host_loop(seg, dstv, np.flatnonzero(vm), eok, FAN,
                          np.random.default_rng(4))

    t_fused = time_call(fused_pattern, warmup=2, iters=max(repeats, 3))
    t_host = time_call(hostloop_pattern, warmup=1, iters=max(repeats, 3))
    emit_json(f"sample_hostloop_pattern_m{m}", t_host, path=json_path,
              seeds=n_seeds, fanout=FAN, m=m, mode="match-host-perseed-loop")
    emit_json(f"sample_fused_pattern_m{m}", t_fused, path=json_path,
              seeds=n_seeds, fanout=FAN, m=m, mode="fused-one-launch",
              speedup=round(t_host / t_fused, 2))

    for S in (256, 1024):
        sds = rng.choice(n, S, replace=False).astype(np.int32)
        t_f = time_call(
            lambda: np.asarray(neighbor_sample(
                seg_d, dst_d, n, me, sds, key, fanout=FAN, edge_words=ew,
                max_deg=max_deg)[0]),
            warmup=2, iters=max(repeats, 3))
        t_h = time_call(
            lambda: _host_loop(seg, dstv, sds, eok, FAN,
                               np.random.default_rng(4)),
            warmup=1, iters=max(repeats, 3))
        emit_json(f"sample_hostloop_s{S}_m{m}", t_h, path=json_path,
                  seeds=S, fanout=FAN, m=m, mode="perseed-loop")
        emit_json(f"sample_fused_s{S}_m{m}", t_f, path=json_path,
                  seeds=S, fanout=FAN, m=m, mode="fused-one-launch",
                  speedup=round(t_h / t_f, 2))

    # the coalesced serving shape: 8 concurrent 256-seed requests, layer 0
    # of ALL of them in one batched launch (what _serve_sample_group runs)
    from repro.graph.sampler import layer_keys_batch
    from repro.kernels.neighbor_sample import (
        bucketed_requests,
        neighbor_sample_batched,
    )

    RQ, SB = 8, 256
    rcap = bucketed_requests(RQ)
    seeds_m = np.zeros((rcap, SB), np.int32)
    for i in range(RQ):
        seeds_m[i] = rng.choice(n, SB, replace=False)
    valid_m = np.zeros((rcap, SB), bool)
    valid_m[:RQ] = True
    keys_b = layer_keys_batch(jnp.arange(rcap), 0)
    words_m = jnp.stack([ew] * rcap)
    nb, ei, mk = neighbor_sample_batched(
        seg_d, dst_d, n, me, seeds_m, valid_m, keys_b, fanout=FAN,
        edge_words=words_m, max_deg=max_deg)
    for i in range(RQ):  # every row oracle-checked before timing
        check_sample(seg, dstv, seeds_m[i], eok, FAN, np.asarray(nb)[i],
                     np.asarray(ei)[i], np.asarray(mk)[i])
    t_fb = time_call(
        lambda: np.asarray(neighbor_sample_batched(
            seg_d, dst_d, n, me, seeds_m, valid_m, keys_b, fanout=FAN,
            edge_words=words_m, max_deg=max_deg)[0]),
        warmup=2, iters=max(repeats, 3))
    t_hb = time_call(
        lambda: _host_loop(seg, dstv, seeds_m[:RQ].ravel(), eok, FAN,
                           np.random.default_rng(4)),
        warmup=1, iters=max(repeats, 3))
    emit_json(f"sample_hostloop_batch8x256_m{m}", t_hb, path=json_path,
              seeds=RQ * SB, fanout=FAN, m=m, mode="perseed-loop")
    emit_json(f"sample_fused_batch8x256_m{m}", t_fb, path=json_path,
              seeds=RQ * SB, fanout=FAN, m=m, mode="batched-one-launch",
              speedup=round(t_hb / t_fb, 2))

    # ---- served QPS: coalesced concurrency vs sequential submission -------
    K = 8
    seed_sets = [nodes[rng.choice(n, 256, replace=False)] for _ in range(K)]
    fanouts = [4]
    with Service() as svc:
        svc.add_graph("g", pg)
        for i in (0, 3):  # parity before timing: service ≡ direct, bitwise
            assert _blocks_equal(
                svc.sample("g", seed_sets[i], fanouts, seed=i),
                pg.sample(seed_sets[i], fanouts, seed=i)), i

    def direct_loop():
        for i in range(requests):
            pg.sample(seed_sets[i % K], fanouts, seed=1000 + i)

    t_direct = time_call(direct_loop, warmup=1, iters=max(repeats, 2))
    emit_json(f"sample_direct_seq_m{m}", t_direct / requests, path=json_path,
              qps=round(requests / t_direct, 1), requests=requests, m=m,
              mode="propgraph-sample-loop")

    def serve_round(svc, c: int) -> float:
        t0 = time.monotonic()
        for w in range(0, requests, c):
            futs = [svc.submit_sample("g", seed_sets[i % K], fanouts,
                                      seed=i, deterministic=False)
                    for i in range(w, min(w + c, requests))]
            for f in futs:
                f.result(timeout=120)
        return time.monotonic() - t0

    seq_qps = None
    for c in (1, 8):
        with Service() as svc:
            svc.add_graph("g", pg)
            svc.sample("g", seed_sets[0], fanouts, seed=0)  # warm the path
            wall = min(serve_round(svc, c) for _ in range(max(repeats, 2)))
            stats = svc.stats()
        qps = requests / wall
        extra = {}
        if c == 1:
            seq_qps = qps
            name = f"sample_serve_seq_m{m}"
        else:
            name = f"sample_serve_c{c}_m{m}"
            extra["speedup"] = round(qps / seq_qps, 2)
        emit_json(name, wall / requests, path=json_path,
                  qps=round(qps, 1), concurrency=c, requests=requests, m=m,
                  coalesced=stats.get("sample_coalesced_launches", 0),
                  mode="service-sample", **extra)

    # ---- sample+embed: one fused program vs two programs + host sync ------
    D = 64
    table = jax.random.normal(jax.random.PRNGKey(5), (n, D), jnp.float32)
    sds = rng.choice(n, 1024, replace=False).astype(np.int32)
    ekey = jax.random.PRNGKey(9)

    @jax.jit
    def embed_only(nb, mk):
        rows = table[jnp.clip(nb, 0, n - 1)]
        w = mk[..., None].astype(jnp.float32)
        cnt = jnp.maximum(mk.sum(-1, keepdims=True), 1).astype(jnp.float32)
        return jnp.sum(rows * w, axis=1) / cnt

    def two_prog():
        nb, _ei, mk = neighbor_sample(seg_d, dst_d, n, me, sds, ekey,
                                      fanout=FAN, edge_words=ew,
                                      max_deg=max_deg)
        # the sampled block leaves the device between the two programs —
        # exactly what happens when sample and embed are separate requests
        nb_h, mk_h = np.asarray(nb), np.asarray(mk)
        return embed_only(jnp.asarray(nb_h), jnp.asarray(mk_h))

    bags_f = sample_embed(seg_d, dst_d, n, me, sds, ekey, table, fanout=FAN,
                          edge_words=ew, max_deg=max_deg)[0]
    assert np.array_equal(np.asarray(bags_f), np.asarray(two_prog())), \
        "fused bags != two-program bags"
    t_two = time_call(two_prog, warmup=2, iters=max(repeats, 3))
    t_one = time_call(
        lambda: sample_embed(seg_d, dst_d, n, me, sds, ekey, table,
                             fanout=FAN, edge_words=ew, max_deg=max_deg)[0],
        warmup=2, iters=max(repeats, 3))
    emit_json(f"sample_embed_twoprog_m{m}", t_two, path=json_path,
              seeds=1024, fanout=FAN, dim=D, m=m, mode="sample-then-embed")
    emit_json(f"sample_embed_fused_m{m}", t_one, path=json_path,
              seeds=1024, fanout=FAN, dim=D, m=m, mode="fused-sample-embed",
              speedup=round(t_two / t_one, 2),
              compiles=sample_compile_count())


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--json-path", default=None)
    a = ap.parse_args()
    run(m=a.m, requests=a.requests, json_path=a.json_path)
