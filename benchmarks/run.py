"""Benchmark runner — one section per paper table/figure + kernel/roofline rows.

Prints ``name,us_per_call,derived`` CSV (benchmarks/common.emit).  Sections:
  tab1_build   — DI construction ladder (paper Tab. I / §V)
  fig6_insert  — attribute insertion per DIP variant (paper Fig. 6)
  fig5_query   — query throughput per DIP variant + impl (paper Fig. 5, §VII-B;
                 includes the DIP-LISTD linked-chase 10× validation)
  kernels      — Pallas kernels vs oracles (interpret mode)
  scan         — bit-packed vs byte mask plane: scan bandwidth/bytes-moved
                 at n≥1M and fused predicate+label match vs two-op
                 composition (JSON lines appended to ``BENCH_scan.json`` —
                 override with ``BENCH_JSON_PATH``; see bench_scan.py)
  match        — pattern-engine rows (beyond-paper; JSON lines via
                 benchmarks.common.emit_json, see bench_match.py)
  shard        — sharded-store locale sweep 1→8 virtual devices (JSON lines;
                 run ``python -m benchmarks.bench_shard`` standalone to get
                 8 virtual devices — in-process it sweeps what's visible)
  traverse     — frontier engine: k-hop CSR vs edge-centric vs k repeated
                 single-hop match() calls, property-aware components,
                 mesh sweep (JSON lines; ALWAYS appended to
                 ``BENCH_traverse.json`` — override with
                 ``BENCH_JSON_PATH``; see bench_traverse.py)
  analytics    — semiring analytics: weighted shortest paths, PageRank,
                 label-propagation communities + mesh sweep, every row
                 oracle-verified before timing (JSON lines appended to
                 ``BENCH_traverse.json`` like the traverse section — they
                 share the frontier engine; see bench_analytics.py)
  serve        — service layer: coalesced concurrent serving vs sequential
                 per-request baseline, concurrency 1/2/4/8, adaptive- vs
                 fixed-window, plus cross-process TCP rows (JSON lines;
                 ALWAYS appended to ``BENCH_serve.json`` — override with
                 ``BENCH_JSON_PATH`` — so the perf trajectory records;
                 see bench_serve.py)
  sample       — fused property-filtered neighborhood sampling: one-launch
                 pattern→sample vs match→host→per-seed-loop baseline, the
                 coalesced 8×256 batched launch, served QPS at c∈{1,8}
                 vs sequential submission, and sample+embed fused vs
                 two-program — every row oracle-verified before timing
                 (JSON lines; ALWAYS appended to ``BENCH_sample.json`` —
                 override with ``BENCH_JSON_PATH``; see bench_sample.py)
  ingest       — overlay subsystem: streamed-batch ingest on the delta
                 write path vs full-rebuild path, read latency under write
                 load, compaction ≡ from-scratch verification (JSON lines;
                 ALWAYS appended to ``BENCH_ingest.json`` — override with
                 ``BENCH_JSON_PATH``; see bench_ingest.py)
Roofline rows come from the dry-run: ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import os
import sys


def main() -> None:
    small = "--small" in sys.argv
    print("name,us_per_call,derived")

    print("# tab1_build (DI construction, paper Tab. I ladder)")
    from benchmarks import bench_build
    bench_build.run(scales=(10_000, 100_000) if small else (10_000, 100_000, 1_000_000))

    print("# fig6_insert (attribute insertion per DIP variant)")
    from benchmarks import bench_insert
    bench_insert.run(scales=(100_000,) if small else (100_000, 1_000_000))

    print("# fig5_query (query throughput per DIP variant / impl)")
    from benchmarks import bench_query
    bench_query.run(m=100_000 if small else 1_000_000)

    print("# kernels (Pallas interpret vs jnp oracle)")
    from benchmarks import bench_kernels
    bench_kernels.run()

    print("# scan (bit-packed vs byte mask plane: bandwidth + fused match)")
    from benchmarks import bench_scan
    bench_scan.run(n=100_000 if small else 1_000_000,
                   json_path=os.environ.get("BENCH_JSON_PATH",
                                            "BENCH_scan.json"))

    print("# match (pattern engine: declarative vs hand-composed, fusion, skew)")
    from benchmarks import bench_match
    bench_match.run(m=20_000 if small else 100_000)

    print("# shard (sharded DIP stores: locale sweep over virtual devices)")
    from benchmarks import bench_shard
    bench_shard.run(m=20_000 if small else 100_000)

    print("# traverse (frontier engine: khop csr/frontier/per-hop-match, components)")
    from benchmarks import bench_traverse
    bench_traverse.run(m=20_000 if small else 100_000,
                       json_path=os.environ.get("BENCH_JSON_PATH",
                                                "BENCH_traverse.json"))

    print("# analytics (semiring engine: shortest paths, pagerank, communities)")
    from benchmarks import bench_analytics
    bench_analytics.run(m=20_000 if small else 100_000,
                        json_path=os.environ.get("BENCH_JSON_PATH",
                                                 "BENCH_traverse.json"))

    print("# serve (service layer: coalesced vs sequential, concurrency sweep,")
    print("#        adaptive vs fixed window, cross-process TCP)")
    from benchmarks import bench_serve
    bench_serve.run(m=10_000 if small else 50_000,
                    requests=32 if small else 64,
                    json_path=os.environ.get("BENCH_JSON_PATH",
                                             "BENCH_serve.json"))

    print("# sample (fused pattern→sample→embed: one-launch vs host loop, QPS)")
    from benchmarks import bench_sample
    bench_sample.run(m=10_000 if small else 50_000,
                     requests=32 if small else 64,
                     json_path=os.environ.get("BENCH_JSON_PATH",
                                              "BENCH_sample.json"))

    print("# ingest (overlay delta write path vs rebuild, reads under writes)")
    from benchmarks import bench_ingest
    bench_ingest.run(m=5_000 if small else 20_000,
                     json_path=os.environ.get("BENCH_JSON_PATH",
                                              "BENCH_ingest.json"))


if __name__ == "__main__":
    main()
