"""§Roofline: three-term analysis per (arch × shape × mesh) from the dry-run.

Reads artifacts/dryrun.json (written by repro.launch.dryrun) and emits the
roofline table:

    compute    = flops_bf16/peak_bf16 + flops_f32/peak_f32     [s, per chip]
    memory     = hbm_bytes / HBM_bw                            [s, per chip]
    collective = coll_bytes / (links × link_bw)                [s, per chip]

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip (f32 ≈ 1/4 of that on the
MXU), 819 GB/s HBM, ~50 GB/s/link ICI; a chip in a 2-D torus drives ~4 links,
but collectives serialize on the bottleneck ring axis — we charge 2 links
(one ring's two directions), the conservative convention.

All analyzer quantities are per-device (the compiled module is the per-device
SPMD program), so terms divide by single-chip peaks directly.

MODEL_FLOPS: 6·N_active·tokens (train) / 2·N_active·tokens (inference) for
LMs; analytic dense-matmul counts for GNN/recsys (formulas inline).  The
ratio HLO/MODEL exposes remat & redundancy waste.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

PEAK_BF16 = 197e12
PEAK_F32 = PEAK_BF16 / 4
HBM_BW = 819e9
LINK_BW = 50e9
N_LINKS = 2
COLL_ALPHA = 5e-6  # per-collective launch/sync latency (α-β model); collectives
#                    inside scanned layers fire once per trip, so count×α is a
#                    real floor for latency-bound (small-payload) collectives


# ---------------------------------------------------------------- MODEL_FLOPS
def _lm_model_flops(arch: str, shape: str, kind: str) -> float:
    from repro.configs import common
    from repro.configs.registry import get_arch

    cfg = get_arch(arch).full_config()
    sh = common.LM_SHAPES[shape]
    if kind == "train":
        toks = sh["global_batch"] * sh["seq_len"]
        return 6.0 * cfg.n_active_params * toks
    if kind == "prefill":
        toks = sh["global_batch"] * sh["seq_len"]
        return 2.0 * cfg.n_active_params * toks
    # decode: one token per sequence + attention over the cache
    toks = sh["global_batch"]
    attn = 0.0
    for kind_l in cfg.pattern:
        w = cfg.window if kind_l == "local" else None
        ctx = min(w, sh["seq_len"]) if w else sh["seq_len"]
        attn += (cfg.n_groups * toks * 2 * 2 * cfg.n_heads * cfg.d_head * ctx)
    return 2.0 * cfg.n_active_params * toks + attn


def _mlp_flops(dims, rows):  # dense stack fwd
    f = 0.0
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2.0 * rows * a * b
    return f


def _gnn_model_flops(arch: str, shape: str) -> float:
    from repro.configs import common
    from repro.configs.registry import cell_specs, get_arch

    kind, specs, cfg = cell_specs(arch, shape)
    mod = get_arch(arch)
    TRAIN = 3.0  # fwd + ~2× bwd
    if mod.MODEL == "gcn":
        n, e = specs.n_nodes, specs.n_edges
        dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
        f = _mlp_flops(dims, n)
        for d_out in dims[1:]:
            f += 2.0 * e * d_out  # edge aggregation
        return TRAIN * f
    if mod.MODEL == "mace":
        n, e, C = specs.n_nodes, specs.n_edges, cfg.channels
        per_layer = (_mlp_flops([cfg.n_rbf, 64, 3 * C], e)           # radial MLP
                     + 2.0 * e * C * (1 + 3 + 9)                      # A-features
                     + 2.0 * n * C * 60                               # product basis (l≤2 einsums)
                     + _mlp_flops([2 * C, C, C], n)
                     + 2.0 * n * (7 * C * C + 5 * C * C * 3 + 4 * C * C * 9))
        return TRAIN * (cfg.n_layers * per_layer + _mlp_flops([C, C // 2, 1], n))
    if mod.MODEL == "dimenet":
        n, e = specs.n_nodes, specs.n_edges
        t = specs.edge_attr.shape[0]
        D, B = cfg.d_hidden, cfg.n_bilinear
        per_block = (_mlp_flops([D, D, D], e) + 2.0 * t * D * B + 2.0 * t * B
                     + 2.0 * t * B * D + _mlp_flops([D, D], e) + 2.0 * e * cfg.n_radial * D)
        return TRAIN * (cfg.n_blocks * per_block + _mlp_flops([2 * D + cfg.n_radial, D, D], e))
    # graphcast
    ng, nm = specs.n_grid, specs.n_mesh
    eg, em, e2 = specs.n_g2m, specs.n_mesh_e, specs.n_m2g
    d = cfg.d_hidden
    inter = lambda ne, nn: (_mlp_flops([2 * d + d, d, d], ne) + _mlp_flops([2 * d, d, d], nn))
    f = (_mlp_flops([cfg.n_vars, d, d], ng) + inter(eg, nm)
         + cfg.n_layers * inter(em, nm) + inter(e2, ng) + _mlp_flops([d, d, cfg.n_vars], ng))
    return 3.0 * f


def _recsys_model_flops(shape: str, kind: str) -> float:
    from repro.configs import common
    from repro.configs.registry import get_arch

    cfg = get_arch("dlrm-rm2").full_config()
    sh = common.RECSYS_SHAPES[shape]
    B = sh["batch"]
    f = _mlp_flops(list(cfg.bot_mlp), B)
    f += 2.0 * B * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim  # dot interaction
    f += _mlp_flops([cfg.top_in] + list(cfg.top_mlp[1:]), B)
    if kind == "retrieval":
        f += 2.0 * common.pad512(sh["n_candidates"]) * cfg.embed_dim
    return (3.0 if kind == "train" else 1.0) * f


def model_flops(rec: Dict) -> Optional[float]:
    from repro.configs.registry import get_arch

    fam = get_arch(rec["arch"]).FAMILY
    if fam == "lm":
        return _lm_model_flops(rec["arch"], rec["shape"], rec["kind"])
    if fam == "gnn":
        return _gnn_model_flops(rec["arch"], rec["shape"])
    return _recsys_model_flops(rec["shape"], rec["kind"])


# -------------------------------------------------------------------- report
def improvement_note(dom: str, rec: Dict) -> str:
    kind = rec["kind"]
    if dom == "compute":
        return "increase arithmetic intensity is moot — push bf16 fraction & MXU util (block shapes)"
    if dom == "memory":
        if kind == "decode":
            return "quantize KV cache (int8) / shrink f32 staging; paged windows"
        return "more aggressive remat policy + bf16 intermediates; fuse scatter chains"
    return "shrink collective volume: overlap AG/RS with compute, 2:4-compress grads, wider model axis"


def _arch_peak(arch: str) -> float:
    """Per-arch MXU peak: XLA:CPU legalizes bf16 dots to f32 before our HLO
    analysis sees them, so dtype-sniffing the compiled dots undercounts bf16
    (measured 4% on qwen2 which is bf16 end-to-end).  Classify by the arch's
    configured compute dtype instead; genuinely-f32 science models (mace,
    dimenet, dlrm) get the f32 peak."""
    import jax.numpy as jnp

    from repro.configs.registry import get_arch

    mod = get_arch(arch)
    if mod.FAMILY == "lm" or getattr(mod, "MODEL", "") == "graphcast":
        return PEAK_BF16
    return PEAK_F32


def analyze(records, *, multi_pod: bool = False):
    rows = []
    for rec in records:
        if rec.get("skipped") or rec["multi_pod"] != multi_pod:
            continue
        n_dev = rec["n_devices"]
        compute = rec["flops_per_dev"] / _arch_peak(rec["arch"])
        memory = rec["hbm_bytes_per_dev"] / HBM_BW
        n_coll = sum(rec.get("coll_count", {}).values())
        coll = rec["coll_bytes_per_dev"] / (N_LINKS * LINK_BW) + n_coll * COLL_ALPHA
        dom = max((("compute", compute), ("memory", memory), ("collective", coll)),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(rec)
        hlo_total = rec["flops_per_dev"] * n_dev
        ratio = mf / hlo_total if (mf and hlo_total) else None
        bound = max(compute, memory, coll)
        frac = compute / bound if bound > 0 else 0.0
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
            "compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom, "model_flops": mf, "hlo_flops_total": hlo_total,
            "useful_ratio": ratio, "roofline_frac": frac,
            "note": improvement_note(dom, rec),
            "fits_hbm": (rec.get("temp_size_in_bytes", 0)
                         + rec.get("argument_size_in_bytes", 0)) < 16 * 2**30,
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="artifacts/dryrun.json")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.dryrun) as f:
        records = json.load(f)
    rows = analyze(records, multi_pod=args.multi_pod)
    hdr = (f"{'arch':>14} {'shape':>14} {'kind':>9} {'compute':>9} {'memory':>9} "
           f"{'collect':>9} {'dominant':>10} {'MODEL/HLO':>9} {'fits':>5}")
    print(hdr)
    for r in rows:
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        print(f"{r['arch']:>14} {r['shape']:>14} {r['kind']:>9} "
              f"{r['compute_s']:9.3e} {r['memory_s']:9.3e} {r['collective_s']:9.3e} "
              f"{r['dominant']:>10} {ur:>9} {str(r['fits_hbm']):>5}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
