"""Paper Fig. 5 + §VII-B query claims: attribute→entities query throughput per
DIP variant and per implementation.

Validation targets:
  * DIP-LISTD's linked pointer chase is ~10× slower than DIP-LIST/DIP-ARR
    (the paper's headline finding — ours reproduces it on one core because the
    chase is inherently serial while the scans vectorize).
  * DIP-ARR query scales O(N/P) and parallelizes trivially.
  * throughput in entities/s (the paper reports 8.5M edges/s on 8×128 cores
    for graph5; we report per-core numbers + the sharded dry-run covers scale).
Shard sweep: --shards splits the entity dim and measures per-shard time
(strong-scaling denominator; see benchmarks/common.py note).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import build_dip_arr, build_dip_list, build_dip_listd
from repro.core import dip_arr, dip_list, dip_listd
from repro.graph import attach_random_attributes


def run(m: int = 1_000_000, n_attrs: int = 50, n_query: int = 5,
        shards=(1, 2, 4, 8), include_linked: bool = True) -> None:
    ents, attrs = attach_random_attributes(m, n_attrs=n_attrs, seed=0)
    qmask = jnp.zeros(n_attrs, bool).at[jnp.arange(n_query)].set(True)

    arr = build_dip_arr(ents, attrs, k=n_attrs, n=m)
    lst = build_dip_list(ents, attrs, k=n_attrs, n=m)
    lkd = build_dip_listd(ents, attrs, k=n_attrs, n=m)

    t = time_call(dip_arr.query_any_scan, arr, qmask)
    emit(f"query_arr_scan_m{m}", t, f"ents_per_s={m / t:.0f}")
    t = time_call(dip_arr.query_any_matvec, arr, qmask)
    emit(f"query_arr_matvec_m{m}", t, f"ents_per_s={m / t:.0f}")
    t = time_call(dip_list.query_any, lst, qmask)
    emit(f"query_list_m{m}", t, f"ents_per_s={m / t:.0f}")
    t = time_call(dip_listd.query_any_inverted, lkd, qmask)
    emit(f"query_listd_inverted_m{m}", t, f"ents_per_s={m / t:.0f}")

    ids = jnp.arange(n_query, dtype=jnp.int32)
    a_off = np.asarray(lkd.a_off)
    budget = int((a_off[1:] - a_off[:-1])[:n_query].sum()) + 8
    budget = -(-budget // 128) * 128
    t = time_call(lambda d, i: dip_listd.query_any_budget(d, i, budget=budget), lkd, ids)
    emit(f"query_listd_budget_m{m}", t, f"ents_per_s={m / t:.0f};budget={budget}")

    if include_linked:
        t = time_call(dip_listd.query_any_linked, lkd, qmask, iters=2)
        emit(f"query_listd_linked_m{m}", t, f"ents_per_s={m / t:.0f};SERIAL_CHASE")

    # shard sweep (per-shard strong-scaling slice, ARR matvec)
    for s in shards:
        msub = m // s
        sub = build_dip_arr(ents[ents < msub], attrs[ents < msub], k=n_attrs, n=msub)
        t = time_call(dip_arr.query_any_matvec, sub, qmask)
        emit(f"query_arr_shard{s}_m{m}", t, f"per_shard_ents_per_s={msub / t:.0f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=1_000_000)
    ap.add_argument("--no-linked", action="store_true")
    a = ap.parse_args()
    run(m=a.m, include_linked=not a.no_linked)
