"""Paper Fig. 6 / §VII-B: attribute (relationship) insertion throughput per
DIP variant.  Validates: DIP-ARR insert is O(NK/P) flag-sets and fastest;
DIP-LISTD build pays the linked-chain constant (the paper's c overhead);
the internal store step is small vs remap/index-gen (graph5 note)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import build_dip_arr, build_dip_list, build_dip_listd
from repro.graph import attach_random_attributes


def run(scales=(100_000, 1_000_000), n_attrs: int = 50) -> None:
    # warmup: populate jit caches for the scatter/sort ops so the timed builds
    # measure steady-state ingestion, not first-call compilation
    we, wa = attach_random_attributes(1024, n_attrs=n_attrs, seed=9)
    build_dip_arr(we, wa, k=n_attrs, n=1024)
    build_dip_list(we, wa, k=n_attrs, n=1024)
    build_dip_listd(we, wa, k=n_attrs, n=1024)
    for m in scales:
        ents, attrs = attach_random_attributes(m, n_attrs=n_attrs, seed=0)
        for name, builder in (
            ("arr", lambda: build_dip_arr(ents, attrs, k=n_attrs, n=m)),
            ("list", lambda: build_dip_list(ents, attrs, k=n_attrs, n=m)),
            ("listd", lambda: build_dip_listd(ents, attrs, k=n_attrs, n=m)),
        ):
            t0 = time.perf_counter()
            store = builder()
            import jax
            jax.block_until_ready(jax.tree.leaves(store))
            dt = time.perf_counter() - t0
            emit(f"dip_insert_{name}_m{m}", dt, f"pairs_per_s={m / dt:.0f}")


if __name__ == "__main__":
    run()
