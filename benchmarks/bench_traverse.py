"""Frontier-engine benchmark: k-hop + components, CSR vs edge-centric vs
the per-hop composition the engine replaces, single-device vs mesh.

Rows (JSON via ``benchmarks.common.emit_json``; ``BENCH_JSON_PATH`` or the
``json_path`` arg appends to a file — run.py pins ``BENCH_traverse.json``
so the perf trajectory records):

  * ``khop_frontier_{backend}_k{K}`` — ``PropGraph.khop``: ONE jitted
    ``while_loop`` of masked frontier steps (docs/ARCHITECTURE.md §10).
  * ``khop_csr_{backend}_k{K}``      — the CSR fast path: per step, gather
    only the live frontier's adjacency slices (O(|F|·d̂) work, not O(m)).
  * ``khop_perhop_match_{backend}_k{K}`` — the baseline the acceptance
    criterion names: k repeated single-hop ``match()`` calls, each paying
    parse→plan→mask materialization→propagation→host sync, with the
    frontier expanded host-side between them — what composing k-hop out
    of the pre-frontier-engine pieces costs.  ``speedup_csr`` on the CSR
    row is perhop/csr at the same k.
  * ``components_{backend}``         — ``PropGraph.components`` over the
    ``follows`` subgraph (property-aware CC).
  * ``khop_mesh_d{P}``               — the shard_map frontier path on a
    P-device sub-mesh (virtual devices; like bench_shard, this validates
    the distribution machinery and measures its overhead — true scaling
    needs one chip per shard; ``method`` records it).

Every timed row is verified bitwise against its siblings first.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede first jax init to take effect
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
from typing import Optional

import numpy as np

from benchmarks.common import emit_json, time_call

METHOD = "host-virtual-devices"
PATTERN = "(a)-[:follows]->(b)"
N_SEEDS = 16
KS = (2, 4, 8)


def _build(backend: str, m: int, mesh=None, seed: int = 0):
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    labels = rng.choice(["l0", "l1", "l2"], size=len(nodes))
    pg.add_node_labels(nodes, labels)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es), p=[0.3, 0.7])
    pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    return pg


def _perhop_match(pg, seeds, k: int) -> np.ndarray:
    """k-hop composed from k separate single-hop ``match()`` calls — the
    pre-engine workflow: every hop re-derives the typed edge mask through
    the full declarative pipeline, then expands the frontier host-side."""
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    sid = pg._vertex_internal(seeds)
    mask = np.zeros(pg.n_vertices, bool)
    mask[sid[sid >= 0]] = True
    for _ in range(k):
        em = np.asarray(pg.match(PATTERN).edge_mask)
        nm = mask.copy()
        np.logical_or.at(nm, ed[mask[es] & em], True)
        if (nm == mask).all():
            break
        mask = nm
    return mask


def run(m: int = 100_000, json_path: Optional[str] = None,
        device_counts=(1, 2, 4, 8)) -> None:
    import jax

    from repro.launch.mesh import make_entity_mesh

    for backend in ("arr", "list"):
        pg = _build(backend, m)
        nodes = np.asarray(pg.graph.node_map)
        seeds = nodes[:N_SEEDS]
        for k in KS:
            ref = _perhop_match(pg, seeds, k)
            fr = np.asarray(pg.khop(seeds, k, pattern=PATTERN))
            cs = np.asarray(pg.khop(seeds, k, pattern=PATTERN, impl="csr"))
            assert (fr == ref).all() and (cs == ref).all(), (backend, k)

            t_per = time_call(lambda: _perhop_match(pg, seeds, k))
            emit_json(f"khop_perhop_match_{backend}_k{k}_m{m}", t_per,
                      path=json_path, backend=backend, m=m, k=k,
                      seeds=N_SEEDS)
            t_fr = time_call(lambda: pg.khop(seeds, k, pattern=PATTERN))
            emit_json(f"khop_frontier_{backend}_k{k}_m{m}", t_fr,
                      path=json_path, backend=backend, m=m, k=k,
                      seeds=N_SEEDS,
                      speedup_vs_perhop=round(t_per / t_fr, 2))
            t_cs = time_call(
                lambda: pg.khop(seeds, k, pattern=PATTERN, impl="csr"))
            emit_json(f"khop_csr_{backend}_k{k}_m{m}", t_cs,
                      path=json_path, backend=backend, m=m, k=k,
                      seeds=N_SEEDS,
                      speedup_vs_perhop=round(t_per / t_cs, 2))

        t = time_call(lambda: pg.components(PATTERN))
        emit_json(f"components_{backend}_m{m}", t, path=json_path,
                  backend=backend, m=m)

    avail = len(jax.devices())
    counts = [c for c in device_counts if c <= avail]
    if counts != list(device_counts):
        print(f"# bench_traverse: only {avail} device(s) visible — sweeping "
              f"{counts} (run standalone or set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    pg0 = _build("arr", m)
    nodes = np.asarray(pg0.graph.node_map)
    seeds = nodes[:N_SEEDS]
    base = np.asarray(pg0.khop(seeds, 4, pattern=PATTERN))
    for p in counts:
        mesh = make_entity_mesh(p)
        pg = _build("arr", m, mesh=mesh)
        got = np.asarray(pg.khop(seeds, 4, pattern=PATTERN))
        assert (got == base).all(), p  # bench rows are verified
        t = time_call(lambda: pg.khop(seeds, 4, pattern=PATTERN))
        emit_json(f"khop_mesh_d{p}_m{m}", t, path=json_path, m=m, k=4,
                  devices=p, method=METHOD)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=100_000)
    a = ap.parse_args()
    run(m=a.m, json_path=os.environ.get("BENCH_JSON_PATH"))
