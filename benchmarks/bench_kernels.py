"""Kernel microbenchmarks (interpret-mode wall times are NOT TPU times — these
rows exist to compare kernel vs oracle algorithmic agreement cost on CPU and to
exercise the kernel paths; TPU perf is the roofline's business)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call


def run() -> None:
    rng = np.random.default_rng(0)

    from repro.kernels.bitmap_query import bitmap_query
    from repro.kernels.bitmap_query.ref import bitmap_query_ref

    bm = jnp.asarray((rng.random((50, 100_000)) < 0.1).astype(np.int8))
    mask = jnp.asarray(rng.random(50) < 0.2)
    emit("kern_bitmap_query_oracle", time_call(bitmap_query_ref, bm, mask), "k=50;n=1e5")
    emit("kern_bitmap_query_pallas", time_call(bitmap_query, bm, mask), "interpret")

    from repro.kernels.seg_mm import seg_mm
    from repro.kernels.seg_mm.ref import seg_mm_ref

    n, e, d = 5000, 20000, 64
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    dst = jnp.asarray(np.sort(rng.integers(0, n, e)), jnp.int32)
    emit("kern_seg_mm_oracle", time_call(seg_mm_ref, x, src, dst, n), f"n={n};e={e};d={d}")
    emit("kern_seg_mm_pallas", time_call(lambda *a: seg_mm(*a), x, src, dst, n), "interpret")

    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref

    q = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    emit("kern_flash_attn_oracle", time_call(flash_attention_ref, q, k, v), "s=512;gqa4")
    emit("kern_flash_attn_pallas", time_call(flash_attention, q, k, v), "interpret")

    from repro.kernels.embedding_bag import embedding_bag_fields
    from repro.kernels.embedding_bag.ref import embedding_bag_ref

    t = jnp.asarray(rng.standard_normal((26, 10_000, 64)), jnp.float32)
    ix = jnp.asarray(rng.integers(0, 10_000, (256, 26, 1)), jnp.int32)
    emit("kern_embedbag_oracle", time_call(embedding_bag_ref, t, ix), "b=256;f=26")
    emit("kern_embedbag_pallas", time_call(embedding_bag_fields, t, ix), "interpret")

    vmem_report()


def vmem_report() -> None:
    """Static per-grid-step VMEM budget per kernel block shape (the structural
    tuning table — interpret-mode wall times say nothing about TPU; VMEM
    residency and MXU alignment are what the block shapes control).
    ~16 MiB/core VMEM envelope; MXU wants multiples of 128 on the lane dim."""
    rows = []
    # flash_attention: q(bq,D) + k/v(bkv,D) + acc(bq,D) f32 + m/l + out
    for bq, bkv, d in [(128, 128, 128), (128, 128, 256), (256, 128, 128),
                       (128, 256, 128), (512, 128, 128)]:
        b = (bq * d * 2 + 2 * bkv * d * 2 + bq * d * 4 + 2 * bq * 4 + bq * d * 2
             + bq * bkv * 4)
        rows.append((f"flash_bq{bq}_bkv{bkv}_d{d}", b))
    # seg_mm: onehot(nt,ec) f32 + msgs(ec,d) + out(nt,d) + dst(1,ec)
    for nt, ec, d in [(256, 256, 128), (256, 256, 512), (512, 256, 128),
                      (128, 512, 256)]:
        b = nt * ec * 4 + ec * d * 4 + nt * d * 4 + ec * 4
        rows.append((f"segmm_nt{nt}_ec{ec}_d{d}", b))
    # bitmap_query: (k,tile_n) int8 + mask(1,k) f32 + out
    for k, tn in [(50, 2048), (128, 2048), (512, 4096)]:
        b = k * tn + k * 4 + tn
        rows.append((f"bitmapq_k{k}_tn{tn}", b))
    for name, b in rows:
        fit = "OK" if b < 12 * 2**20 else "OVER"  # leave ~4MiB headroom
        emit(f"vmem_{name}", 0.0, f"vmem_bytes={b};{fit}")


if __name__ == "__main__":
    run()
