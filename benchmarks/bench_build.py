"""Paper Tab. I / §V: DI graph build time vs edge count (the ingest path).

Reproduces the build ladder (10× steps) at CPU-feasible scales; the paper's
observation to validate: build cost is dominated by the remap + index-gen
steps (sort/searchsorted), not the final store."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import build_di
from repro.graph import random_uniform_graph


def run(scales=(10_000, 100_000, 1_000_000)) -> None:
    for m in scales:
        src, dst = random_uniform_graph(m, seed=0)
        t0 = time.perf_counter()
        g = build_di(src, dst)
        dt = time.perf_counter() - t0
        emit(f"di_build_m{m}", dt, f"n={g.n};edges_per_s={m / dt:.0f}")


if __name__ == "__main__":
    run()
