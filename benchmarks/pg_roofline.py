"""Paper-technique cell at pod scale: DIP-ARR relationship query on a
graph4-regime edge set (10⁸ edges, K=50), lowered on the production mesh.

This is the §Perf 'most representative of the paper's technique' experiment:
  baseline   — paper-faithful row-scan query (bool AND + OR-reduce over rows)
  optimized  — beyond-paper MXU matvec form (bf16 dot), int8 bitmap
  packed     — bit-packed word plane (uint32, 1 bit/entity): word-select +
               OR-reduce, 8× fewer plane bytes than the int8 forms
All are lowered + compiled on the 16×16 mesh with the bitmap entity-sharded
(the paper's distribution; packed shards the WORD axis), and the three
roofline terms compared.

Run:  PYTHONPATH=src python -m benchmarks.pg_roofline
"""
import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

K, M = 50, 100_000_000  # graph4: 1e8 edges, 50 relationships

HBM_BW = 819e9
PEAK_BF16 = 197e12
PEAK_I8 = 394e12  # v5e int8 ops
LINK_BW = 50e9


def scan_query(bitmap, mask):  # paper-faithful §VI-C row scan
    sel = bitmap.astype(jnp.bool_) & mask[:, None]
    return jnp.any(sel, axis=0)


def matvec_query(bitmap, mask):  # beyond-paper MXU form
    return (mask.astype(jnp.bfloat16) @ bitmap.astype(jnp.bfloat16)) > 0


def packed_query(plane, mask):  # bit-packed word plane (core.bitplane layout)
    sel = jnp.where(mask[:, None], plane, jnp.uint32(0))
    return jax.lax.reduce(sel, jnp.uint32(0), jax.lax.bitwise_or, (0,))


def main():
    mesh = make_production_mesh()
    bitmap_sh = NamedSharding(mesh, P(None, ("data", "model")))  # entity-sharded
    mask_sh = NamedSharding(mesh, P(None))
    bm = jax.ShapeDtypeStruct((K, M), jnp.int8, sharding=bitmap_sh)
    mk = jax.ShapeDtypeStruct((K,), jnp.bool_, sharding=mask_sh)
    # packed plane: same M entities in ⌈M/32⌉ uint32 words, word axis
    # sharded — padded to whole words per device (launch.sharding.pg_word_pad)
    n_dev = 256
    w_pad = -(-(M // 32) // n_dev) * n_dev
    pm = jax.ShapeDtypeStruct((K, w_pad), jnp.uint32, sharding=bitmap_sh)
    out_sh = NamedSharding(mesh, P(("data", "model")))

    for name, fn, arg in (("scan(paper)", scan_query, bm),
                          ("matvec(ours)", matvec_query, bm),
                          ("packed(ours)", packed_query, pm)):
        with mesh:
            comp = jax.jit(fn, in_shardings=(bitmap_sh, mask_sh),
                           out_shardings=out_sh).lower(arg, mk).compile()
        t = analyze_hlo(comp.as_text())
        mem_t = t["bytes"] / HBM_BW
        cmp_t = t["flops"] / PEAK_BF16
        coll_t = t["coll_bytes"] / (2 * LINK_BW)
        dom = max((("compute", cmp_t), ("memory", mem_t), ("collective", coll_t)),
                  key=lambda kv: kv[1])
        # useful-byte floor: the K×M_local int8 bitmap must be read once
        # (the packed plane's floor is 8× lower — 1 bit per entity)
        bits = 1 if arg is pm else 8
        floor = (K * M * bits / 8 / 256) / HBM_BW
        print(f"{name:13s} compute={cmp_t:.3e}s memory={mem_t:.3e}s "
              f"collective={coll_t:.3e}s dominant={dom[0]} "
              f"| memory-term/byte-floor={mem_t / floor:.2f}")


if __name__ == "__main__":
    main()
