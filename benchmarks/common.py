"""Benchmark utilities: timing, CSV emission, shard-sweep helper.

Locale-scaling methodology: the paper varies 1→8 Chapel locales; on one CPU we
sweep the SHARD COUNT of the entity dimension (host-sharded execution over a
1×N device mesh is impossible on 1 device, so we emulate scaling by measuring
per-shard work on 1/N slices — the strong-scaling denominator; the multi-chip
path is exercised by the dry-run/roofline instead).  Every row records the
method so readers can't confuse the two.
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, List, Optional, Tuple

import jax

__all__ = ["time_call", "emit", "emit_json"]

_RUN_STAMP: Optional[Tuple[int, Optional[str]]] = None


def _run_stamp() -> Tuple[int, Optional[str]]:
    """(run_id, git_sha) minted once per process.

    ``run_id`` is a wall-clock epoch second — monotonic across successive
    benchmark runs, constant within one, so rows appended to the same JSONL
    file group by run and sort chronologically.  ``git_sha`` ties the row to
    the code that produced it (None outside a git checkout).
    """
    global _RUN_STAMP
    if _RUN_STAMP is None:
        sha: Optional[str] = None
        try:
            out = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=5,
            )
            sha = out.stdout.strip() or None
        except Exception:
            sha = None
        _RUN_STAMP = (int(time.time()), sha)
    return _RUN_STAMP


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (s) of jitted fn; blocks on results."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_json(name: str, seconds: float, path: Optional[str] = None, **fields) -> None:
    """JSON-line benchmark row — the machine-readable trajectory format.

    Prints one JSON object per row; when ``path`` (or the ``BENCH_JSON_PATH``
    env var) is set the row is also appended there, so successive PRs can
    diff perf without parsing stdout.
    """
    run_id, git_sha = _run_stamp()
    row = {"name": name, "us_per_call": round(seconds * 1e6, 1),
           "run_id": run_id, "git_sha": git_sha, **fields}
    line = json.dumps(row, sort_keys=True)
    print(line)
    path = path or os.environ.get("BENCH_JSON_PATH")
    if path:
        with open(path, "a") as f:
            f.write(line + "\n")
