"""Semiring analytics benchmark: weighted shortest paths, PageRank and
label-propagation communities through the frontier engine's semiring relax
(docs/ARCHITECTURE.md §12), single-device vs mesh.

Rows (JSON via ``benchmarks.common.emit_json``; ``BENCH_JSON_PATH`` or the
``json_path`` arg appends to a file — run.py pins ``BENCH_traverse.json``,
the frontier engine's trajectory file, since these are its instances):

  * ``shortest_paths_{backend}`` — ``PropGraph.shortest_paths`` over the
    ``w`` edge property, pattern-filtered: the (min, +) tropical fixed
    point in ONE jitted ``while_loop``.
  * ``pagerank_{backend}``       — ``PropGraph.pagerank``, weighted: the
    (+, ×) counting instance, 20 scan steps.
  * ``communities_{backend}``    — ``PropGraph.communities``: the mode
    relax (sort + segment counts per round).
  * ``{sp,pagerank}_mesh_d{P}``  — the shard_map paths on a P-device
    sub-mesh (virtual devices — validates the distribution machinery and
    measures its overhead, like bench_traverse's mesh rows; ``method``
    records it).

Every timed row is verified against a vectorized numpy oracle first
(Bellman–Ford / power iteration / synchronous mode propagation) — SP and
communities bitwise, PageRank within float tolerance; mesh rows verify
against the single-device result (pmin exact, psum atol).
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede first jax init to take effect
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
from typing import Optional

import numpy as np

from benchmarks.common import emit_json, time_call

METHOD = "host-virtual-devices"
PATTERN = "(a)-[:follows]->(b)"
N_SEEDS = 16
PR_ITERS = 20


def _build(backend: str, m: int, mesh=None, seed: int = 0):
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend, mesh=mesh).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    rels = rng.choice(["follows", "likes"], size=len(es), p=[0.3, 0.7])
    pg.add_edge_relationships(nodes[es], nodes[ed], rels)
    pg.add_edge_properties("w", nodes[es], nodes[ed],
                           rng.uniform(0.5, 2.0, len(es)).astype(np.float32))
    return pg, rels


def np_bellman_ford(es, ed, w, n, seed_ids, e_ok) -> np.ndarray:
    """Vectorized numpy Bellman–Ford in f32 — the tropical oracle."""
    t, h, wv = es[e_ok], ed[e_ok], w[e_ok].astype(np.float32)
    dist = np.full(n, np.inf, np.float32)
    dist[seed_ids] = 0.0
    for _ in range(n + 1):
        nd = dist.copy()
        np.minimum.at(nd, h, dist[t] + wv)
        if np.array_equal(nd, dist):
            break
        dist = nd
    return dist


def np_pagerank(es, ed, w, n, *, damping=0.85, iters=PR_ITERS) -> np.ndarray:
    """Vectorized numpy power iteration in f32 — the counting oracle."""
    w = w.astype(np.float32)
    out_deg = np.zeros(n, np.float32)
    np.add.at(out_deg, es, w)
    inv = np.where(out_deg > 0, 1.0 / np.maximum(out_deg, 1e-30), 0.0)
    r = np.full(n, 1.0 / max(n, 1), np.float32)
    for _ in range(iters):
        agg = np.zeros(n, np.float32)
        np.add.at(agg, ed, (r * inv)[es] * w)
        dangling = np.sum(np.where(out_deg > 0, np.float32(0), r))
        r = np.float32((1 - damping) / n) + np.float32(damping) * (
            agg + dangling / np.float32(n))
    return r


def np_label_propagation(es, ed, n, *, max_iters=64) -> np.ndarray:
    """Vectorized numpy synchronous label propagation — the mode oracle:
    per round every vertex takes its neighbors' (undirected) most frequent
    label, smallest label breaking ties; fixed point or ``max_iters``."""
    heads = np.concatenate([ed, es])
    tails = np.concatenate([es, ed])
    labels = np.arange(n, dtype=np.int32)
    for _ in range(max_iters):
        lab = labels[tails]
        key = heads.astype(np.int64) * n + lab
        uniq, counts = np.unique(key, return_counts=True)
        uh = (uniq // n).astype(np.int64)
        ul = (uniq % n).astype(np.int32)
        # per head: max count, then smallest label — lexsort is stable so
        # the first row of each head group is the winner
        order = np.lexsort((ul, -counts, uh))
        uh, ul = uh[order], ul[order]
        first = np.ones(len(uh), bool)
        first[1:] = uh[1:] != uh[:-1]
        new = labels.copy()
        new[uh[first]] = ul[first]
        if np.array_equal(new, labels):
            break
        labels = new
    return labels


def run(m: int = 100_000, json_path: Optional[str] = None,
        device_counts=(1, 2, 4, 8)) -> None:
    import jax

    from repro.launch.mesh import make_entity_mesh

    for backend in ("arr", "list"):
        pg, rels = _build(backend, m)
        nodes = np.asarray(pg.graph.node_map)
        es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
        w = np.asarray(pg.edge_props["w"][0])
        n = pg.graph.n
        seeds = nodes[:N_SEEDS]
        sid = pg._vertex_internal(seeds)

        got = np.asarray(pg.shortest_paths(seeds, weight="w", pattern=PATTERN))
        ref = np_bellman_ford(es, ed, w, n, sid, rels == "follows")
        assert np.array_equal(got, ref), backend
        t = time_call(lambda: pg.shortest_paths(seeds, weight="w",
                                                pattern=PATTERN))
        emit_json(f"shortest_paths_{backend}_m{m}", t, path=json_path,
                  backend=backend, m=m, seeds=N_SEEDS, semiring="tropical")

        got = np.asarray(pg.pagerank(weight="w", iters=PR_ITERS))
        ref = np_pagerank(es, ed, w, n, iters=PR_ITERS)
        assert np.allclose(got, ref, atol=1e-6), backend
        t = time_call(lambda: pg.pagerank(weight="w", iters=PR_ITERS))
        emit_json(f"pagerank_{backend}_m{m}", t, path=json_path,
                  backend=backend, m=m, iters=PR_ITERS, semiring="counting")

        got = np.asarray(pg.communities())
        ref = np_label_propagation(es, ed, n)
        assert np.array_equal(got, ref), backend
        t = time_call(lambda: pg.communities())
        emit_json(f"communities_{backend}_m{m}", t, path=json_path,
                  backend=backend, m=m, semiring="mode")

    avail = len(jax.devices())
    counts = [c for c in device_counts if c <= avail]
    if counts != list(device_counts):
        print(f"# bench_analytics: only {avail} device(s) visible — sweeping "
              f"{counts} (run standalone or set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    pg0, _ = _build("arr", m)
    nodes = np.asarray(pg0.graph.node_map)
    seeds = nodes[:N_SEEDS]
    sp_base = np.asarray(pg0.shortest_paths(seeds, weight="w", pattern=PATTERN))
    pr_base = np.asarray(pg0.pagerank(weight="w", iters=PR_ITERS))
    for p in counts:
        mesh = make_entity_mesh(p)
        pg, _ = _build("arr", m, mesh=mesh)
        got = np.asarray(pg.shortest_paths(seeds, weight="w", pattern=PATTERN))
        assert np.array_equal(got, sp_base), p  # pmin is exact: bitwise
        t = time_call(lambda: pg.shortest_paths(seeds, weight="w",
                                                pattern=PATTERN))
        emit_json(f"sp_mesh_d{p}_m{m}", t, path=json_path, m=m, devices=p,
                  semiring="tropical", method=METHOD)
        got = np.asarray(pg.pagerank(weight="w", iters=PR_ITERS))
        assert np.allclose(got, pr_base, atol=1e-5), p  # psum reassociates
        t = time_call(lambda: pg.pagerank(weight="w", iters=PR_ITERS))
        emit_json(f"pagerank_mesh_d{p}_m{m}", t, path=json_path, m=m,
                  devices=p, semiring="counting", method=METHOD)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=100_000)
    a = ap.parse_args()
    run(m=a.m, json_path=os.environ.get("BENCH_JSON_PATH"))
