"""Streaming-ingest benchmark: the overlay delta write path vs the rebuild
path it replaces (docs/ARCHITECTURE.md §11).

The pre-overlay repro (and Arachne itself, PAPER.md §V) absorbs a late-
arriving edge batch by re-running the whole ingest pipeline: re-sort the DI
arrays, rebuild both DIP stores, re-intern every attribute.  The overlay
subsystem appends the batch to an ``EdgeDelta`` / ``AttrDelta`` instead and
lets queries union ``base | delta`` masks.  This benchmark streams the same
edge batches down both paths and times each batch.

Rows (JSON via ``benchmarks.common.emit_json``; run.py pins
``BENCH_ingest.json``):

  * ``ingest_delta_batch_{backend}``   — median per-batch wall time of
    ``insert_edges`` + ``add_edge_relationships`` on a sealed graph (the
    delta path), measured over the late batches (index ≥ 8) where the
    rebuild path's cost has fully compounded; ``speedup`` = rebuild/delta.
  * ``ingest_rebuild_batch_{backend}`` — the same batches absorbed by
    ``add_edges_from`` of everything-so-far + full re-attribution.
  * ``read_under_writes_{backend}``    — warm ``match()`` latency right
    after a delta batch landed (the combined base++delta view), and
  * ``read_baseline_{backend}``        — the same query on the static
    pre-stream graph, so the overlay's read-side tax is a visible row.

Before any timing, the full stream is verified: after ``compact()`` the
delta-path graph's ``match`` / ``khop`` / ``components`` answers are
bitwise-identical to a from-scratch build of the complete edge list on all
three backends — compaction is a pure layout change.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede first jax init to take effect
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time
from typing import Optional

import numpy as np

from benchmarks.common import emit_json, time_call

BACKENDS = ("arr", "list", "listd")
PATTERN = "(a:l1)-[:follows]->(b:l2)"
RELS = ("follows", "likes")
N_BATCHES = 12
TAIL_FROM = 8  # acceptance window: per-batch medians over batches ≥ this


def _build(backend: str, m: int, seed: int = 0):
    from repro.core import PropGraph
    from repro.graph import random_uniform_graph

    rng = np.random.default_rng(seed)
    src, dst = random_uniform_graph(m, seed=seed)
    pg = PropGraph(backend=backend).add_edges_from(src, dst)
    nodes = np.asarray(pg.graph.node_map)
    pg.add_node_labels(nodes, rng.choice(["l0", "l1", "l2"], size=len(nodes)))
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    pg.add_edge_relationships(nodes[es], nodes[ed],
                              rng.choice(RELS, size=len(es)))
    return pg


def _make_batches(nodes: np.ndarray, batch: int, seed: int):
    """Edge batches over the EXISTING vertex universe (the delta path's
    contract; growing the universe is add_edges_from's bulk rebuild)."""
    rng = np.random.default_rng(seed + 1)
    out = []
    for _ in range(N_BATCHES):
        bs = rng.choice(nodes, size=batch)
        bd = rng.choice(nodes, size=batch)
        out.append((bs, bd, rng.choice(RELS, size=batch)))
    return out


def _attribute_all(pg, labels, base_rels, batches, upto: int) -> None:
    """Re-apply every attribute after a rebuild: base labels/relationships
    (addressed by endpoint pair, exactly as the delta path received them)
    plus the relationships of all batches streamed so far."""
    pg.add_node_labels(np.asarray(pg.graph.node_map), labels)
    pg.add_edge_relationships(*base_rels)
    for bs, bd, br in batches[:upto]:
        pg.add_edge_relationships(bs, bd, br)


def _verify_compaction(backend: str, m: int, batch: int, seed: int) -> None:
    """Stream → compact ≡ from-scratch build, bitwise, on every surface."""
    import jax

    pg = _build(backend, m, seed=seed)
    nodes = np.asarray(pg.graph.node_map)
    es, ed = np.asarray(pg.graph.src), np.asarray(pg.graph.dst)
    # replay _build's rng stream so the reference gets identical attributes
    rng = np.random.default_rng(seed)
    labels = rng.choice(["l0", "l1", "l2"], size=len(nodes))
    base_rel_vals = rng.choice(RELS, size=len(es))
    batches = _make_batches(nodes, batch, seed)

    jax.block_until_ready(pg.match(PATTERN).edge_mask)  # seal the stores
    for bs, bd, br in batches:
        pg.insert_edges(bs, bd)
        pg.add_edge_relationships(bs, bd, br)
    pg.compact()
    assert not pg.has_overlay()

    from repro.core import PropGraph

    all_src = np.concatenate([np.asarray(nodes[es])]
                             + [b[0] for b in batches])
    all_dst = np.concatenate([np.asarray(nodes[ed])]
                             + [b[1] for b in batches])
    ref = PropGraph(backend=backend).add_edges_from(all_src, all_dst)
    ref.add_node_labels(nodes, labels)  # batches reuse the same universe
    ref.add_edge_relationships(nodes[es], nodes[ed], base_rel_vals)
    for bs, bd, br in batches:
        ref.add_edge_relationships(bs, bd, br)

    got, want = pg.match(PATTERN), ref.match(PATTERN)
    assert (np.asarray(got.vertex_mask) == np.asarray(want.vertex_mask)).all(), backend
    assert (np.asarray(got.edge_mask) == np.asarray(want.edge_mask)).all(), backend
    seeds = nodes[:16]
    assert (np.asarray(pg.khop(seeds, 3)) == np.asarray(ref.khop(seeds, 3))).all(), backend
    assert (np.asarray(pg.components("(a)-[:follows]->(b)"))
            == np.asarray(ref.components("(a)-[:follows]->(b)"))).all(), backend
    print(f"# compaction ≡ from-scratch verified ({backend})")


def run(m: int = 20_000, batch: int = 256, seed: int = 0,
        json_path: Optional[str] = None) -> None:
    import jax

    for backend in BACKENDS:
        _verify_compaction(backend, min(m, 5_000), batch, seed)

    for backend in BACKENDS:
        base = _build(backend, m, seed=seed)
        nodes = np.asarray(base.graph.node_map)
        es, ed = np.asarray(base.graph.src), np.asarray(base.graph.dst)
        rng = np.random.default_rng(seed)  # _build's stream, replayed
        labels = rng.choice(["l0", "l1", "l2"], size=len(nodes))
        base_rels = (nodes[es], nodes[ed], rng.choice(RELS, size=len(es)))
        batches = _make_batches(nodes, batch, seed)

        # ---- read baseline on the static graph (sealed stores, no delta)
        base_read = time_call(lambda: base.match(PATTERN).edge_mask)
        emit_json(f"read_baseline_{backend}", base_read, path=json_path,
                  m=m, method="warm match, no overlay")

        # ---- delta path: sealed graph absorbs batches as appends
        pg = _build(backend, m, seed=seed)
        jax.block_until_ready(pg.match(PATTERN).edge_mask)  # seal
        delta_times, read_times = [], []
        for bs, bd, br in batches:
            t0 = time.perf_counter()
            pg.insert_edges(bs, bd)
            pg.add_edge_relationships(bs, bd, br)
            delta_times.append(time.perf_counter() - t0)
            # warm read latency against the combined base++delta view
            read_times.append(time_call(
                lambda: pg.match(PATTERN).edge_mask, warmup=1, iters=3))
        delta_med = float(np.median(delta_times[TAIL_FROM:]))

        # ---- rebuild path: every batch re-runs the whole ingest pipeline
        pg2 = _build(backend, m, seed=seed)
        jax.block_until_ready(pg2.match(PATTERN).edge_mask)
        acc_src = [nodes[es]]
        acc_dst = [nodes[ed]]
        rebuild_times = []
        for i, (bs, bd, br) in enumerate(batches):
            acc_src.append(bs)
            acc_dst.append(bd)
            t0 = time.perf_counter()
            pg2.add_edges_from(np.concatenate(acc_src),
                               np.concatenate(acc_dst))
            _attribute_all(pg2, labels, base_rels, batches, i + 1)
            rebuild_times.append(time.perf_counter() - t0)
        rebuild_med = float(np.median(rebuild_times[TAIL_FROM:]))

        speedup = rebuild_med / max(delta_med, 1e-12)
        emit_json(f"ingest_delta_batch_{backend}", delta_med, path=json_path,
                  m=m, batch=batch, batches=N_BATCHES, tail_from=TAIL_FROM,
                  speedup=round(speedup, 1),
                  method="insert_edges + add_edge_relationships (delta)")
        emit_json(f"ingest_rebuild_batch_{backend}", rebuild_med,
                  path=json_path, m=m, batch=batch, batches=N_BATCHES,
                  tail_from=TAIL_FROM,
                  method="add_edges_from of all-so-far + re-attribution")
        emit_json(f"read_under_writes_{backend}",
                  float(np.median(read_times)), path=json_path, m=m,
                  batch=batch, overlay_edges=int(pg.delta_stats()["delta_edges"]),
                  method="warm match between delta batches")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--m", type=int, default=20_000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=os.environ.get("BENCH_JSON_PATH",
                                                     "BENCH_ingest.json"))
    args = ap.parse_args()
    run(m=args.m, batch=args.batch, seed=args.seed, json_path=args.json)


if __name__ == "__main__":
    main()
