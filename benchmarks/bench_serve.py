"""Service-layer throughput/latency: coalesced concurrent serving vs the
sequential per-request baseline, in-process and over the pgd wire
(docs/ARCHITECTURE.md §8–§9).

The workload is ``launch.pgserve``'s synthetic multi-tenant stream: a
zipf-skewed draw over a 12-pattern pool — hot patterns repeat, the
distribution request coalescing and result caching exist for.  Rows (JSON
via ``benchmarks.common.emit_json``; ``benchmarks/run.py`` points them at
``BENCH_serve.json`` so the cross-PR perf trajectory records):

  * ``serve_seq_baseline_m{m}``      — per-request ``PropGraph.match`` loop
    (no service, no caches, no coalescing), the concurrency-independent
    denominator.
  * ``serve_arr_c{c}_m{m}``          — full service (adaptive-window
    micro-batching + coalesced launches + plan/result caches) at c
    closed-loop clients, c ∈ {1, 2, 4, 8}; ``speedup`` = qps / baseline.
  * ``serve_arr_cold_c1_m{mw}`` vs ``serve_arr_cold_fixedwin_c1_m{mw}`` —
    the ROADMAP "cold-pattern latency tax": result cache and submit
    fastpath disabled so EVERY request crosses the batching queue at c=1.
    Under the PR 3 fixed window a lone request sat out ``window_ms``
    before executing (p50 grows by ≈ the window); the adaptive window
    executes it immediately — compare the two rows' p50.  These rows run
    on a small graph (mw = min(m, 10k)) on purpose: they isolate the
    SCHEDULER's latency floor, which a large graph's execution time would
    bury in noise.
  * ``serve_arr_nocache_c{c}_m{m}``  — result cache disabled: what
    coalescing + plan caching buy on their own (the honesty row — every
    request executes).
  * ``serve_net_c{c}_m{m}``          — the same workload through a REAL
    second OS process over TCP (``PGServer``/``PGClient``), c client
    connections; measures the wire + framing overhead on top of the
    in-process rows.

Both paths are warmed first (jit compiles for every pattern shape and
every Q bucket; the net server warms itself before LISTENING), so rows
measure steady-state serving, not compilation; every row is
best-of-``repeats`` replays (closed-loop threading is highly exposed to
cgroup CPU-quota throttling — the best run is the least-interfered
estimate; ``runs`` in each row records it).  Each service row is verified
bitwise against direct match before timing, including through the wire.
"""
from __future__ import annotations

import argparse
from typing import Optional

import numpy as np

from benchmarks.common import emit_json


def _verify_service(svc_query, pg, pool) -> None:
    for p in pool:
        got = svc_query(p)
        ref = pg.match(p)
        assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all(), p
        assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), p


def run(m: int = 50_000, requests: int = 64, concurrencies=(1, 2, 4, 8),
        seed: int = 0, repeats: int = 3, net: bool = True,
        json_path: Optional[str] = None) -> None:
    from repro.launch.pgserve import (
        build_tenant_graph,
        pattern_pool,
        run_sequential,
        run_workload,
        run_workload_net,
        spawn_server,
        synthetic_workload,
        warm_serving_path,
    )
    from repro.service import PGClient, Service, ServiceConfig

    pg = build_tenant_graph("arr", m, seed=seed)
    graphs = {"tenant0": pg}
    pool = pattern_pool()
    wl = synthetic_workload(sorted(graphs), pool, requests, seed=seed)

    # -- warmup: compile every pattern's propagation program AND every Q
    # bucket — batch composition varies with concurrency, so an unvisited
    # bucket would pay its compile inside a measured window
    warm_serving_path(pg, pool)

    # verification before timing: service ≡ direct match on every pattern
    with Service() as v:
        v.add_graph("tenant0", pg)
        _verify_service(lambda p: v.query("tenant0", p), pg, pool)

    seq = run_sequential(graphs, wl, repeats=repeats)
    emit_json(f"serve_seq_baseline_m{m}", seq["wall_s"] / requests,
              path=json_path, qps=round(seq["qps"], 1), requests=requests,
              m=m, runs=repeats, mode="sequential-match")

    def service_row(name: str, config, c: int, *, graph=pg, workload=wl,
                    baseline=None, **extra) -> None:
        with Service(config=config) as svc:  # fresh caches per row; jits warm
            svc.add_graph("tenant0", graph)
            met = run_workload(svc, workload, c, repeats=repeats)
            stats = svc.stats()
        if baseline is not None:
            extra["speedup"] = round(met["qps"] / baseline["qps"], 2)
        emit_json(
            name, met["wall_s"] / len(workload), path=json_path,
            qps=round(met["qps"], 1), concurrency=c,
            requests=len(workload),
            p50_ms=round(met["p50_ms"], 3), p95_ms=round(met["p95_ms"], 3),
            runs=repeats,
            coalesced_launches=stats.get("coalesced_launches", 0),
            result_hits=stats.get("result_hits", 0), **extra,
        )

    for c in concurrencies:
        service_row(f"serve_arr_c{c}_m{m}", None, c, baseline=seq, m=m,
                    mode="service-coalesced")

    # the fixed-window tax the adaptive window removes: with caches/fastpath
    # off, every c=1 request crosses the queue — under a fixed window it
    # waits out window_ms first, under the adaptive one it runs immediately.
    # Small graph on purpose (docstring): isolate the scheduler, not the
    # executor.
    mw = min(m, 10_000)
    pg_win = pg if mw == m else build_tenant_graph("arr", mw, seed=seed)
    if pg_win is not pg:
        warm_serving_path(pg_win, pool)
    cold = dict(result_cache_size=0, submit_fastpath=False)
    for name, cfg, mode in (
        (f"serve_arr_cold_c1_m{mw}", ServiceConfig(**cold),
         "service-cold-adaptive"),
        (f"serve_arr_cold_fixedwin_c1_m{mw}",
         ServiceConfig(adaptive_window=False, **cold),
         "service-cold-fixed-window"),
    ):
        service_row(name, cfg, 1, graph=pg_win, m=mw, mode=mode,
                    window_ms=ServiceConfig().window_ms)

    service_row(f"serve_arr_nocache_c{max(concurrencies)}_m{m}",
                ServiceConfig(result_cache_size=0), max(concurrencies),
                baseline=seq, m=m, mode="service-coalesce-only")

    # -- observability overhead guard (docs/ARCHITECTURE.md §13): the
    # metrics registry must be free when disabled and near-free when on.
    # Measure the same c=max coalesced workload with metrics enabled vs
    # disabled (fresh service each, jits warm) in ALTERNATING trials —
    # back-to-back blocks read scheduler drift as flag overhead at this
    # row's ~tens-of-ms wall time — take best-of per side, and record the
    # relative difference; the build fails if flipping the flag moves the
    # coalesce timing by ≥5%.
    from repro.obs import set_enabled

    cmax = max(concurrencies)

    def _measure(c: int):
        with Service() as svc:
            svc.add_graph("tenant0", pg)
            return run_workload(svc, wl, c, repeats=repeats)

    met_on, met_off = None, None
    for _ in range(max(repeats, 3)):
        m_on = _measure(cmax)
        prev = set_enabled(False)
        try:
            m_off = _measure(cmax)
        finally:
            set_enabled(prev)
        if met_on is None or m_on["wall_s"] < met_on["wall_s"]:
            met_on = m_on
        if met_off is None or m_off["wall_s"] < met_off["wall_s"]:
            met_off = m_off
    overhead = (met_on["wall_s"] - met_off["wall_s"]) / met_off["wall_s"]
    emit_json(
        f"serve_arr_metrics_off_c{cmax}_m{m}",
        met_off["wall_s"] / requests, path=json_path,
        qps=round(met_off["qps"], 1), concurrency=cmax, requests=requests,
        m=m, p50_ms=round(met_off["p50_ms"], 3), runs=repeats,
        qps_metrics_on=round(met_on["qps"], 1),
        metrics_overhead=round(overhead, 4), mode="service-metrics-disabled",
    )
    # one-sided: negative readings mean scheduler noise beat the best-of
    # filter (metrics can't make the service faster), not a regression
    assert overhead < 0.05, (
        f"metrics flag slowed c={cmax} coalesce timing by "
        f"{overhead:+.1%} (guard: <5%)")

    if not net:
        return
    # -- cross-process: same workload through a spawned server over TCP
    proc, port = spawn_server(["--graphs", "1", "--backend", "arr",
                               "--m", str(m), "--seed", str(seed), "--warm"])
    try:
        with PGClient(port=port) as c0:
            _verify_service(lambda p: c0.query("tenant0", p), pg, pool)
        for c in (1, max(concurrencies)):
            met = run_workload_net(port, wl, c, repeats=repeats)
            emit_json(
                f"serve_net_c{c}_m{m}", met["wall_s"] / requests,
                path=json_path, qps=round(met["qps"], 1), concurrency=c,
                requests=requests, m=m, p50_ms=round(met["p50_ms"], 3),
                p95_ms=round(met["p95_ms"], 3),
                speedup=round(met["qps"] / seq["qps"], 2), runs=repeats,
                mode="service-net",
            )
        with PGClient(port=port) as c0:
            c0.shutdown()
        proc.wait(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--no-net", action="store_true",
                    help="skip the cross-process TCP rows")
    ap.add_argument("--json-path", default=None)
    a = ap.parse_args()
    run(m=a.m, requests=a.requests, net=not a.no_net, json_path=a.json_path)
