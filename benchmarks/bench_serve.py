"""Service-layer throughput/latency: coalesced concurrent serving vs the
sequential per-request baseline (docs/ARCHITECTURE.md §8).

The workload is ``launch.pgserve``'s synthetic multi-tenant stream: a
zipf-skewed draw over a 12-pattern pool — hot patterns repeat, the
distribution request coalescing and result caching exist for.  Rows (JSON
via ``benchmarks.common.emit_json``; ``BENCH_JSON_PATH`` appends for the
cross-PR trajectory):

  * ``serve_seq_baseline_m{m}``      — per-request ``PropGraph.match`` loop
    (no service, no caches, no coalescing), the concurrency-independent
    denominator.
  * ``serve_arr_c{c}_m{m}``          — full service (micro-batching +
    coalesced launches + plan/result caches) at c closed-loop clients,
    c ∈ {1, 2, 4, 8}; ``speedup`` = qps / baseline qps.
  * ``serve_arr_nocache_c{c}_m{m}``  — result cache disabled: what
    coalescing + plan caching buy on their own (the honesty row — every
    request executes).

Both paths are warmed first (jit compiles for every pattern shape and
every Q bucket), so rows measure steady-state serving, not compilation;
every row is best-of-``repeats`` replays (closed-loop threading is highly
exposed to cgroup CPU-quota throttling — the best run is the
least-interfered estimate; ``runs`` in each row records it).  Each service
row is verified bitwise against direct match before timing.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit_json


def run(m: int = 50_000, requests: int = 64, concurrencies=(1, 2, 4, 8),
        seed: int = 0, repeats: int = 3) -> None:
    from repro.launch.pgserve import (
        build_tenant_graph,
        pattern_pool,
        run_sequential,
        run_workload,
        synthetic_workload,
        warm_serving_path,
    )
    from repro.service import Service, ServiceConfig

    pg = build_tenant_graph("arr", m, seed=seed)
    graphs = {"tenant0": pg}
    pool = pattern_pool()
    wl = synthetic_workload(sorted(graphs), pool, requests, seed=seed)

    # -- warmup: compile every pattern's propagation program AND every Q
    # bucket — batch composition varies with concurrency, so an unvisited
    # bucket would pay its compile inside a measured window
    warm_serving_path(pg, pool)

    # verification before timing: service ≡ direct match on every pattern
    with Service() as v:
        v.add_graph("tenant0", pg)
        for p in pool:
            got = v.query("tenant0", p)
            ref = pg.match(p)
            assert (np.asarray(got.vertex_mask) == np.asarray(ref.vertex_mask)).all(), p
            assert (np.asarray(got.edge_mask) == np.asarray(ref.edge_mask)).all(), p

    seq = run_sequential(graphs, wl, repeats=repeats)
    emit_json(f"serve_seq_baseline_m{m}", seq["wall_s"] / requests,
              qps=round(seq["qps"], 1), requests=requests, m=m, runs=repeats,
              mode="sequential-match")

    for c in concurrencies:
        with Service() as svc:  # fresh caches per row; jits stay warm
            svc.add_graph("tenant0", pg)
            met = run_workload(svc, wl, c, repeats=repeats)
            stats = svc.stats()
        emit_json(
            f"serve_arr_c{c}_m{m}", met["wall_s"] / requests,
            qps=round(met["qps"], 1), concurrency=c, requests=requests, m=m,
            p50_ms=round(met["p50_ms"], 3), p95_ms=round(met["p95_ms"], 3),
            speedup=round(met["qps"] / seq["qps"], 2), runs=repeats,
            coalesced_launches=stats.get("coalesced_launches", 0),
            result_hits=stats.get("result_hits", 0),
            mode="service-coalesced",
        )

    nocache = ServiceConfig(result_cache_size=0)
    for c in (max(concurrencies),):
        with Service(config=nocache) as svc:
            svc.add_graph("tenant0", pg)
            met = run_workload(svc, wl, c, repeats=repeats)
            stats = svc.stats()
        emit_json(
            f"serve_arr_nocache_c{c}_m{m}", met["wall_s"] / requests,
            qps=round(met["qps"], 1), concurrency=c, requests=requests, m=m,
            p50_ms=round(met["p50_ms"], 3), p95_ms=round(met["p95_ms"], 3),
            speedup=round(met["qps"] / seq["qps"], 2), runs=repeats,
            coalesced_launches=stats.get("coalesced_launches", 0),
            mode="service-coalesce-only",
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=64)
    a = ap.parse_args()
    run(m=a.m, requests=a.requests)
