"""Sharded checkpointing with atomic commit, async writes and elastic restore.

Layout per step::

    <dir>/step_000123.tmp/            # staging (never read)
    <dir>/step_000123/                # committed by atomic rename
        manifest.json                 # treedef, shapes, dtypes, mesh, step
        shard_p0.npz                  # this process's addressable data

Fault-tolerance contract (DESIGN.md §5):
  * **Atomicity** — readers only ever see fully-written checkpoints (rename is
    atomic on POSIX); a crash mid-write leaves a ``.tmp`` that is ignored and
    garbage-collected.
  * **Elastic restore** — arrays are saved logically (per-process shards of
    the *global* array + the manifest); ``restore`` re-chunks onto ANY mesh /
    sharding handed to it, so a job can come back on a different pod count.
  * **Async** — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes on a worker thread; ``wait`` joins before the next save so at
    most one write is in flight (bounded memory).
  * **Retention** — ``keep`` newest checkpoints survive GC.

On multi-host deployments each process writes ``shard_p{i}.npz`` with its
addressable shards; this container is single-process, which is the i=0 case of
the same format.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "save", "restore", "latest_step"]

_SEP = "/"


def _flatten_with_paths(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out, treedef


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def save(ckpt_dir: str, step: int, tree, *, extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save of a pytree of (possibly sharded) jax arrays."""
    flat, _ = _flatten_with_paths(tree)
    tmp = os.path.join(ckpt_dir, f"step_{step:09d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)

    arrays = {}
    manifest = {"step": step, "process": jax.process_index(),
                "n_processes": jax.process_count(), "leaves": {}, "extra": extra or {}}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        manifest["leaves"][name] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, f"shard_p{jax.process_index()}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``; optionally place each leaf
    with the matching entry of ``shardings`` (elastic: any mesh shape)."""
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with np.load(os.path.join(path, f"shard_p0.npz")) as z:
        data = {k: z[k] for k in z.files}
    flat, treedef = _flatten_with_paths(like_tree)
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten_with_paths(shardings)[0]]
    leaves = []
    for i, (name, like) in enumerate(flat):
        arr = data[name]
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves)


class CheckpointManager:
    """Async, retention-managed checkpointing for the training loop."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_stale()

    def _gc_stale(self):
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    def _gc_old(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, *, extra: Optional[Dict] = None):
        """Snapshot to host now; write on a worker thread (one in flight)."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save(self.dir, step, host_tree, extra=extra)
            self._gc_old()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree, *, extra: Optional[Dict] = None) -> str:
        self.wait()
        p = save(self.dir, step, tree, extra=extra)
        self._gc_old()
        return p

    def restore_latest(self, like_tree, *, shardings=None) -> Tuple[Optional[int], Any]:
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, like_tree
        return step, restore(self.dir, step, like_tree, shardings=shardings)
