"""GCN (Kipf & Welling, arXiv:1609.02907) over DI edge arrays.

The assigned ``gcn-cora`` config: 2 layers, d_hidden=16, sym normalization.
Message passing is the paper's DI aggregation — ``spmm_di`` (segment_sum over
the sorted edge list, or the Pallas ``seg_mm`` kernel with ``impl='kernel'``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.graph.segment_ops import degree_norm, spmm_di
from repro.models.gnn_common import GraphBatch
from repro.nn.layers import init_linear, linear

__all__ = ["GCNConfig", "init_params", "forward", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    norm: str = "sym"          # 'sym' | 'rw'
    aggregator: str = "mean"   # kept for config fidelity; norm implies weighting
    dropout: float = 0.0
    spmm_impl: str = "segment"
    dtype: Any = jnp.float32


def init_params(key, cfg: GCNConfig) -> Dict:
    ks = jax.random.split(key, cfg.n_layers)
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    return {"layers": [init_linear(ks[i], dims[i], dims[i + 1], bias=True)
                       for i in range(cfg.n_layers)]}


def forward(params: Dict, batch: GraphBatch, cfg: GCNConfig) -> jax.Array:
    x = batch.x.astype(cfg.dtype)
    w = degree_norm(batch.edge_src, batch.edge_dst, batch.n_nodes, mode=cfg.norm)
    w = w * batch.edge_mask.astype(w.dtype)
    for i, lp in enumerate(params["layers"]):
        x = linear(lp, x)
        # Ã·X·W with self loops: aggregate + self-term (sym-normalized)
        agg = spmm_di(x, batch.edge_src, batch.edge_dst, batch.n_nodes,
                      edge_weight=w, impl=cfg.spmm_impl)
        deg = jax.ops.segment_sum(jnp.ones_like(batch.edge_dst, cfg.dtype),
                                  batch.edge_dst, batch.n_nodes) + 1.0
        x = agg + x / deg[:, None]  # self loop with 1/(1+deg) weight
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params: Dict, batch: GraphBatch, cfg: GCNConfig) -> jax.Array:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, batch.labels[:, None], axis=-1)[..., 0]
    nll = (lse - true) * batch.node_mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(batch.node_mask), 1)
