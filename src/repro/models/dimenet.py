"""DimeNet (arXiv:2003.03123) — directional message passing with triplet gather.

Assigned config: 6 interaction blocks, d_hidden=128, 8 bilinear units,
7 spherical × 6 radial basis functions.

Messages live on *directed edges*; each interaction refines m_ji from all
m_kj (k ∈ N(j)\{i}) weighted by a 2-D (distance, angle) basis — the
triplet-gather kernel regime (taxonomy §B.3) that plain SpMM cannot express.
Triplet index lists (kj_edge, ji_edge) are **inputs** built by the data
pipeline from DI adjacency (standard DimeNet practice); the dry-run caps them
at 8×n_edges (DESIGN.md §4).

Basis simplification (documented): spherical Bessel j_l is replaced by its
sin(nπd/c)/d radial family and Y_l0 by Legendre P_l(cos α) — the same
(radial × angular) separable structure with identical shapes/FLOPs.
The bilinear interaction uses the DimeNet++ down-projected form.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.gnn_common import GraphBatch, init_mlp_stack, mlp_stack
from repro.nn.layers import init_linear, linear

__all__ = ["DimeNetConfig", "init_params", "forward", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    n_species: int = 16
    r_cut: float = 5.0
    dtype: Any = jnp.float32


def _rbf(d, n: int, c: float):
    d = jnp.maximum(d, 1e-6)
    k = jnp.arange(1, n + 1, dtype=jnp.float32)
    return jnp.sin(k * jnp.pi * d[:, None] / c) / d[:, None]


def _legendre(cos_a, l_max: int):
    """P_0..P_{l_max-1}(cos α) via recurrence. (T,) → (T, l_max)."""
    p0 = jnp.ones_like(cos_a)
    ps = [p0]
    if l_max > 1:
        ps.append(cos_a)
    for l in range(2, l_max):
        ps.append(((2 * l - 1) * cos_a * ps[-1] - (l - 1) * ps[-2]) / l)
    return jnp.stack(ps, axis=-1)


def _sbf(d_kj, cos_a, cfg: DimeNetConfig):
    """(T, n_spherical·n_radial) separable distance×angle basis."""
    rad = _rbf(d_kj, cfg.n_radial, cfg.r_cut)          # (T, n_radial)
    ang = _legendre(cos_a, cfg.n_spherical)            # (T, n_spherical)
    return (rad[:, None, :] * ang[:, :, None]).reshape(d_kj.shape[0], -1)


def init_params(key, cfg: DimeNetConfig) -> Dict:
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    D, B = cfg.d_hidden, cfg.n_bilinear
    blocks = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[i], 8)
        blocks.append({
            "msg_mlp": init_mlp_stack(kb[0], [D, D, D]),
            "w_down": init_linear(kb[1], D, B),
            "w_sbf": init_linear(kb[2], cfg.n_spherical * cfg.n_radial, B),
            "w_up": init_linear(kb[3], B, D),
            "rbf_gate": init_linear(kb[4], cfg.n_radial, D),
            "out_mlp": init_mlp_stack(kb[5], [D, D]),
        })
    return {
        "embed": jax.random.normal(ks[-1], (cfg.n_species, D), jnp.float32) * 0.5,
        "edge_embed": init_mlp_stack(ks[-2], [2 * D + cfg.n_radial, D, D]),
        "out_rbf": init_linear(ks[-3], cfg.n_radial, D),
        "readout": init_mlp_stack(ks[-4], [D, D // 2, 1]),
        "blocks": blocks,
    }


def forward(params: Dict, batch: GraphBatch, cfg: DimeNetConfig) -> jax.Array:
    """Per-graph energies.  batch.edge_attr packs triplets:
    edge_attr = (t_kj, t_ji, t_mask) via aux fields — see data pipeline;
    here we expect ``batch.edge_attr`` of shape (T, 3): [kj_edge, ji_edge, mask].
    """
    src, dst, emask = batch.edge_src, batch.edge_dst, batch.edge_mask
    E = batch.n_edges
    r = batch.pos[dst] - batch.pos[src]
    d = jnp.linalg.norm(r, axis=-1)
    rbf = _rbf(d, cfg.n_radial, cfg.r_cut) * emask[:, None]

    t_kj = batch.edge_attr[:, 0].astype(jnp.int32)
    t_ji = batch.edge_attr[:, 1].astype(jnp.int32)
    t_mask = batch.edge_attr[:, 2].astype(cfg.dtype)

    # angle at shared vertex j between edges (k→j) and (j→i)
    v_kj = -r[t_kj]  # j→k direction reversed: use vector from j to k = pos[k]-pos[j] = -(r of k→j)? r[e]=pos[dst]-pos[src]; for edge k→j: r = pos[j]-pos[k]; vector j→k = -r
    v_ji = r[t_ji]   # for edge j→i: r = pos[i]-pos[j], vector j→i
    cos_a = jnp.sum(v_kj * v_ji, -1) / jnp.maximum(
        jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-6
    )
    sbf = _sbf(jnp.linalg.norm(v_kj, axis=-1), cos_a, cfg) * t_mask[:, None]

    h = params["embed"][batch.species]
    m = mlp_stack(params["edge_embed"], jnp.concatenate([h[src], h[dst], rbf], -1))

    def block(m, bp):
        m2 = mlp_stack(bp["msg_mlp"], m)
        t = linear(bp["w_down"], m2[t_kj])          # (T, B)
        s = linear(bp["w_sbf"], sbf)                # (T, B)
        inter = linear(bp["w_up"], t * s) * t_mask[:, None]
        agg = jax.ops.segment_sum(inter, t_ji, E)   # sum over k → edge ji
        gate = jax.nn.sigmoid(linear(bp["rbf_gate"], rbf))
        return m + mlp_stack(bp["out_mlp"], (m2 + agg) * gate)

    block_fn = jax.checkpoint(block)  # bound backward storage to block carries
    for bp in params["blocks"]:
        m = block_fn(m, bp)

    # per-atom readout: sum incoming messages, gated by rbf projection
    per_edge = m * linear(params["out_rbf"], rbf)
    h_atom = jax.ops.segment_sum(per_edge * emask[:, None], dst, batch.n_nodes)
    e_atom = mlp_stack(params["readout"], h_atom)[:, 0] * batch.node_mask
    return jax.ops.segment_sum(e_atom, batch.graph_ids, batch.n_graphs)


def loss_fn(params: Dict, batch: GraphBatch, cfg: DimeNetConfig) -> jax.Array:
    e = forward(params, batch, cfg)
    return jnp.mean((e - batch.labels.astype(e.dtype)) ** 2)
