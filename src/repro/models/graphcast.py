"""GraphCast (arXiv:2212.12794) — encoder-processor-decoder mesh GNN.

Assigned config: 16 processor layers, d_hidden=512, 227 variables,
sum aggregation.

Structure: grid→mesh encoder (bipartite interaction network), 16-layer mesh
processor (scanned InteractionNetworks), mesh→grid decoder.  The assigned
generic GNN shapes map as: grid_nodes = n_nodes, mesh_nodes ≈ n_nodes/4,
g2m/m2g edges = n_edges, mesh edges = n_edges/2 (DESIGN.md §4); edge features
(4-d displacement stand-ins) and all index arrays are pipeline inputs.

Each InteractionNetwork: e' = MLP([e, h_src, h_dst]); h' = MLP([h, Σ e'])
with residuals and LayerNorm — the MeshGraphNet/GraphCast block.  The mesh
processor scans stacked params (compile-time flat in depth, like the LMs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn_common import init_mlp_stack, mlp_stack
from repro.nn.layers import init_layernorm, layernorm

__all__ = ["GraphCastConfig", "GCBatch", "init_params", "forward", "loss_fn"]

from functools import partial


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    d_edge: int = 4
    mesh_refinement: int = 6
    aggregator: str = "sum"
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # sharding annotation axes (set by the launch layer)
    dp_axes: Any = None      # tuple of mesh axes for the entity dim
    tp_axis: Any = None      # mesh axis for wide feature dims


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["grid_x", "g2m_src", "g2m_dst", "g2m_attr", "mesh_src", "mesh_dst",
                 "mesh_attr", "m2g_src", "m2g_dst", "m2g_attr", "targets"],
    meta_fields=["n_grid", "n_mesh", "n_g2m", "n_mesh_e", "n_m2g"],
)
@dataclasses.dataclass(frozen=True)
class GCBatch:
    grid_x: jax.Array      # (Ng, n_vars)
    g2m_src: jax.Array     # (Eg2m,) grid ids
    g2m_dst: jax.Array     # (Eg2m,) mesh ids
    g2m_attr: jax.Array    # (Eg2m, d_edge)
    mesh_src: jax.Array
    mesh_dst: jax.Array
    mesh_attr: jax.Array   # (Em, d_edge)
    m2g_src: jax.Array     # mesh ids
    m2g_dst: jax.Array     # grid ids
    m2g_attr: jax.Array
    targets: jax.Array     # (Ng, n_vars)
    n_grid: int
    n_mesh: int
    n_g2m: int
    n_mesh_e: int
    n_m2g: int


def _init_interaction(key, d: int, d_edge_in: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "edge_mlp": init_mlp_stack(k1, [2 * d + d_edge_in, d, d]),
        "node_mlp": init_mlp_stack(k2, [2 * d, d, d]),
        "ln_e": init_layernorm(d),
        "ln_n": init_layernorm(d),
    }


def _interaction(p, h_src, h_dst, e, src, dst, n_dst: int):
    """One bipartite interaction step → (h_dst', e').

    Projection pushdown (§Perf, graphcast hillclimb): the edge MLP's first
    layer over concat([e, h_src[src], h_dst[dst]]) is decomposed as
    ``e@We + (h_src@Ws)[src] + (h_dst@Wd)[dst]`` — the node projections run at
    NODE rows (50× fewer than edge rows on ogb_products) and only the
    projected 512-wide results are gathered.  Mathematically identical
    (gather is linear); measured 9.4× lower collective volume vs the concat
    form, whose (E, 1536) f32 input was all-gathered every layer.
    The node MLP is decomposed the same way."""
    from repro.nn.layers import linear as _lin

    W = p["edge_mlp"][0]["w"]
    b = p["edge_mlp"][0].get("b")
    d_e = e.shape[-1]
    d = h_src.shape[-1]
    We, Ws, Wd = W[:d_e], W[d_e:d_e + d], W[d_e + d:]
    z = (e @ We.astype(e.dtype)
         + (h_src @ Ws.astype(h_src.dtype))[src]
         + (h_dst @ Wd.astype(h_dst.dtype))[dst])
    if b is not None:
        z = z + b.astype(z.dtype)
    z = jax.nn.silu(z)
    e_new = layernorm(p["ln_e"], mlp_stack(p["edge_mlp"][1:], z))
    agg = jax.ops.segment_sum(e_new, dst, n_dst)

    Wn = p["node_mlp"][0]["w"]
    bn = p["node_mlp"][0].get("b")
    Wh, Wa = Wn[:d], Wn[d:]
    zn = h_dst @ Wh.astype(h_dst.dtype) + agg @ Wa.astype(agg.dtype)
    if bn is not None:
        zn = zn + bn.astype(zn.dtype)
    zn = jax.nn.silu(zn)
    h_new = layernorm(p["ln_n"], mlp_stack(p["node_mlp"][1:], zn))
    return h_dst + h_new, e_new


def init_params(key, cfg: GraphCastConfig) -> Dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_hidden
    proc_keys = jax.random.split(ks[3], cfg.n_layers)
    proc = jax.vmap(lambda k: _init_interaction(k, d, d))(proc_keys)  # stacked
    return {
        "grid_embed": init_mlp_stack(ks[0], [cfg.n_vars, d, d]),
        "mesh_embed": init_mlp_stack(ks[1], [cfg.d_edge, d, d]),  # mesh node init from static attrs
        "edge_embed_g2m": init_mlp_stack(ks[2], [cfg.d_edge, d, d]),
        "edge_embed_mesh": init_mlp_stack(ks[4], [cfg.d_edge, d, d]),
        "edge_embed_m2g": init_mlp_stack(ks[5], [cfg.d_edge, d, d]),
        "encoder": _init_interaction(ks[6], d, d),
        "processor": proc,
        "decoder": _init_interaction(ks[7], d, d),
        "out_mlp": init_mlp_stack(jax.random.fold_in(key, 99), [d, d, cfg.n_vars]),
    }


def _constrain(x, cfg):
    """Entity-dim block distribution + feature-dim TP for intermediates —
    without these GSPMD replicates scatter outputs (node tables) per device
    (measured 181 GiB/dev on ogb_products; EXPERIMENTS.md §Perf)."""
    if cfg.dp_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    tp = cfg.tp_axis if (x.ndim == 2 and x.shape[-1] % 16 == 0) else None
    return jax.lax.with_sharding_constraint(x, P(cfg.dp_axes, *( [tp] + [None]*(x.ndim-2) )))


def forward(params: Dict, b: GCBatch, cfg: GraphCastConfig) -> jax.Array:
    dt = cfg.dtype
    hg = mlp_stack(params["grid_embed"], b.grid_x.astype(dt))
    # mesh nodes initialized from aggregated static g2m attrs (positional proxy)
    mesh_init = jax.ops.segment_sum(
        mlp_stack(params["mesh_embed"], b.g2m_attr.astype(dt)), b.g2m_dst, b.n_mesh
    )
    hm = mesh_init

    # --- encode grid → mesh -------------------------------------------------
    e_g2m = mlp_stack(params["edge_embed_g2m"], b.g2m_attr.astype(dt))
    hm, _ = _interaction(params["encoder"], hg, hm, e_g2m, b.g2m_src, b.g2m_dst, b.n_mesh)
    hm = _constrain(hm, cfg)

    # --- process on mesh (scan over stacked layers) ---------------------------
    e_mesh0 = mlp_stack(params["edge_embed_mesh"], b.mesh_attr.astype(dt))

    def body(carry, lp):
        hm, e = carry
        hm2, e2 = _interaction(lp, hm, hm, e, b.mesh_src, b.mesh_dst, b.n_mesh)
        return (_constrain(hm2, cfg), _constrain(e2, cfg)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (hm, _), _ = jax.lax.scan(body_fn, (hm, e_mesh0), params["processor"])

    # --- decode mesh → grid ---------------------------------------------------
    e_m2g = mlp_stack(params["edge_embed_m2g"], b.m2g_attr.astype(dt))
    hg, _ = _interaction(params["decoder"], hm, hg, e_m2g, b.m2g_src, b.m2g_dst, b.n_grid)

    return mlp_stack(params["out_mlp"], hg).astype(jnp.float32)


def loss_fn(params: Dict, b: GCBatch, cfg: GraphCastConfig) -> jax.Array:
    pred = forward(params, b, cfg)
    return jnp.mean((pred - b.targets.astype(pred.dtype)) ** 2)
