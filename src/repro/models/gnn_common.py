"""Shared containers/utilities for the GNN model family.

``GraphBatch`` is the uniform device-side graph: DI-ordered edge arrays + node
features + masks.  Batched small graphs (the ``molecule`` shape) are flattened
with ``graph_ids`` for segment readout; sampled minibatches (``minibatch_lg``)
arrive as one compacted subgraph produced by ``repro.graph.sampler`` (static
worst-case shapes for the dry-run).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import init_linear, linear

__all__ = ["GraphBatch", "init_mlp_stack", "mlp_stack"]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["x", "pos", "species", "edge_src", "edge_dst", "edge_attr", "edge_mask",
                 "node_mask", "labels", "graph_ids"],
    meta_fields=["n_nodes", "n_edges", "n_graphs"],
)
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """One (possibly batched/flattened) graph.

    x:         (N, F) float features, or None (equivariant models use species+pos)
    pos:       (N, 3) positions or None
    species:   (N,) int atomic types or None
    edge_src/edge_dst: (E,) int32 — DI order (sorted by src)
    edge_attr: (E, Fe) or None
    edge_mask: (E,) bool — padding slots False
    node_mask: (N,) bool
    labels:    (N,) node labels / (G,) graph targets / (N, F) regression targets
    graph_ids: (N,) int32 graph membership for readout (zeros if single graph)
    """

    x: Optional[jax.Array]
    pos: Optional[jax.Array]
    species: Optional[jax.Array]
    edge_src: jax.Array
    edge_dst: jax.Array
    edge_attr: Optional[jax.Array]
    edge_mask: jax.Array
    node_mask: jax.Array
    labels: jax.Array
    graph_ids: jax.Array
    n_nodes: int
    n_edges: int
    n_graphs: int


def init_mlp_stack(key, dims, *, bias: bool = True):
    """[d0→d1→…] MLP params (SiLU between)."""
    ks = jax.random.split(key, len(dims) - 1)
    return [init_linear(k, dims[i], dims[i + 1], bias=bias) for i, k in enumerate(ks)]


def mlp_stack(params, x, *, act=jax.nn.silu, final_act: bool = False):
    for i, p in enumerate(params):
        x = linear(p, x)
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x
