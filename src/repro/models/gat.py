"""GAT (arXiv:1710.10903) and GraphSAGE (arXiv:1706.02216) — beyond-pool
extensions exercising the SDDMM → segment-softmax → SpMM regime over DI.

Not part of the assigned 10; added because the paper's substrate (sorted DI
edge arrays + segment ops) makes them ~free, and GAT's edge softmax is the
one GNN kernel regime (taxonomy §B.3) the assigned four don't cover.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.graph.segment_ops import gather_scatter, segment_softmax
from repro.models.gnn_common import GraphBatch
from repro.nn.layers import init_linear, linear

__all__ = ["GATConfig", "SAGEConfig", "init_gat", "gat_forward", "gat_loss",
           "init_sage", "sage_forward", "sage_loss"]


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: Any = jnp.float32


def init_gat(key, cfg: GATConfig) -> Dict:
    layers = []
    dims_in = [cfg.d_in] + [cfg.d_hidden * cfg.n_heads] * (cfg.n_layers - 1)
    dims_out = [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    heads = [cfg.n_heads] * (cfg.n_layers - 1) + [1]
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "w": init_linear(k1, dims_in[i], heads[i] * dims_out[i]),
            "a_src": jax.random.normal(k2, (heads[i], dims_out[i]), jnp.float32) * 0.1,
            "a_dst": jax.random.normal(k3, (heads[i], dims_out[i]), jnp.float32) * 0.1,
        })
    return {"layers": layers}


def gat_forward(params: Dict, batch: GraphBatch, cfg: GATConfig) -> jax.Array:
    x = batch.x.astype(cfg.dtype)
    src, dst = batch.edge_src, batch.edge_dst
    n = batch.n_nodes
    heads = [cfg.n_heads] * (cfg.n_layers - 1) + [1]
    dims_out = [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    for i, lp in enumerate(params["layers"]):
        h = linear(lp["w"], x).reshape(n, heads[i], dims_out[i])  # (N, H, D)
        # SDDMM: per-edge attention logits from endpoint projections
        e_src = jnp.einsum("nhd,hd->nh", h, lp["a_src"])[src]  # (E, H)
        e_dst = jnp.einsum("nhd,hd->nh", h, lp["a_dst"])[dst]
        logits = jax.nn.leaky_relu(e_src + e_dst, cfg.negative_slope)
        logits = jnp.where(batch.edge_mask[:, None], logits, -1e30)
        # segment softmax per destination, per head
        alpha = jax.vmap(lambda lg: segment_softmax(lg, dst, n), in_axes=1, out_axes=1)(logits)
        alpha = alpha * batch.edge_mask[:, None]
        # SpMM: attention-weighted aggregation
        msgs = h[src] * alpha[:, :, None]
        agg = jax.ops.segment_sum(msgs, dst, n)  # (N, H, D)
        x = agg.reshape(n, heads[i] * dims_out[i])
        if i < cfg.n_layers - 1:
            x = jax.nn.elu(x)
    return x  # (N, n_classes)


def gat_loss(params: Dict, batch: GraphBatch, cfg: GATConfig) -> jax.Array:
    logits = gat_forward(params, batch, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, batch.labels[:, None], axis=-1)[..., 0]
    nll = (lse - true) * batch.node_mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(batch.node_mask), 1)


# ------------------------------------------------------------------ GraphSAGE
@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 64
    n_classes: int = 41
    aggregator: str = "mean"   # 'mean' | 'max'
    dtype: Any = jnp.float32


def init_sage(key, cfg: SAGEConfig) -> Dict:
    layers = []
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append({
            "w_self": init_linear(k1, dims[i], dims[i + 1], bias=True),
            "w_nbr": init_linear(k2, dims[i], dims[i + 1]),
        })
    return {"layers": layers}


def sage_forward(params: Dict, batch: GraphBatch, cfg: SAGEConfig) -> jax.Array:
    x = batch.x.astype(cfg.dtype)
    for i, lp in enumerate(params["layers"]):
        agg = gather_scatter(x, batch.edge_src, batch.edge_dst, batch.n_nodes,
                             agg=cfg.aggregator,
                             edge_weight=batch.edge_mask.astype(cfg.dtype))
        x = linear(lp["w_self"], x) + linear(lp["w_nbr"], agg)
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
            # L2 normalize (SAGE §3.1)
            x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)
    return x


def sage_loss(params: Dict, batch: GraphBatch, cfg: SAGEConfig) -> jax.Array:
    logits = sage_forward(params, batch, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true = jnp.take_along_axis(logits, batch.labels[:, None], axis=-1)[..., 0]
    nll = (lse - true) * batch.node_mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(batch.node_mask), 1)
