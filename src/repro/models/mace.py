"""MACE (arXiv:2206.07697) — higher-order E(3)-equivariant message passing.

Assigned config: 2 layers, 128 channels, l_max=2, correlation order 3, 8 radial
Bessel functions.

TPU adaptation (DESIGN.md §4): irreps are carried in **Cartesian form** —
l=0 scalars ``(N, C)``, l=1 vectors ``(N, C, 3)``, l=2 traceless-symmetric
matrices ``(N, C, 3, 3)`` — so every tensor product is an isotropic einsum that
maps onto the MXU, instead of sparse Clebsch-Gordan gathers (the GPU-idiomatic
e3nn layout).  The Cartesian maps used are exactly the CG couplings for l ≤ 2:

    1⊗1→0: v·w        1⊗1→1: v×w        1⊗1→2: sym-traceless(v⊗w)
    2⊗1→1: M·v        2⊗2→0: tr(M·N)    2⊗2→2: sym-traceless(M·N)

Equivariance is by construction (all ops are O(3)-isotropic) and property-
tested under random rotations in tests/test_models_equivariance.py.
The ACE product basis (correlation order 3) is built from symmetric products
of the per-atom A-features using the table above, channel-mixed by learnable
weights — the simplification vs full MACE (which enumerates generalized CG
couplings) is recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn_common import GraphBatch, init_mlp_stack, mlp_stack
from repro.nn.layers import init_linear, linear

__all__ = ["MACEConfig", "init_params", "forward", "loss_fn"]

_I3 = jnp.eye(3)


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    channels: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    n_species: int = 16
    r_cut: float = 5.0
    dtype: Any = jnp.float32


def _bessel(d, n_rbf: int, r_cut: float):
    """Radial Bessel basis sin(nπd/rc)/d with smooth cutoff envelope."""
    d = jnp.maximum(d, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rbf = jnp.sin(n * jnp.pi * d[:, None] / r_cut) / d[:, None]
    u = jnp.clip(d / r_cut, 0, 1)
    env = 1 - 10 * u**3 + 15 * u**4 - 6 * u**5  # polynomial cutoff
    return rbf * env[:, None]


def _sym_traceless(t):
    """Project (…,3,3) onto the l=2 (traceless symmetric) component."""
    s = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * _I3 / 3.0


def init_params(key, cfg: MACEConfig) -> Dict:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    C = cfg.channels
    layers = []
    for li in range(cfg.n_layers):
        kl = jax.random.split(ks[li], 8)
        layers.append({
            "radial": init_mlp_stack(kl[0], [cfg.n_rbf, 64, 3 * C]),  # per-l weights
            # channel mixers for the product basis (scalars + l1 + l2 outputs)
            "mix0": init_linear(kl[1], 7 * C, C, bias=True),
            "mix1": init_linear(kl[2], 5 * C, C),
            "mix2": init_linear(kl[3], 4 * C, C),
            "update0": init_mlp_stack(kl[4], [2 * C, C, C]),
        })
    return {
        "embed": jax.random.normal(ks[-1], (cfg.n_species, C), jnp.float32) * 0.5,
        "layers": layers,
        "readout": init_mlp_stack(ks[-2], [C, C // 2, 1]),
    }


def _layer(lp, h0, h1, h2, batch: GraphBatch, cfg: MACEConfig):
    """One MACE interaction: A-features (density) then order-3 product basis."""
    C = cfg.channels
    src, dst, emask = batch.edge_src, batch.edge_dst, batch.edge_mask
    r = batch.pos[dst] - batch.pos[src]  # (E, 3)
    d = jnp.linalg.norm(r, axis=-1)
    rhat = r / jnp.maximum(d, 1e-6)[:, None]
    y1 = rhat                                    # (E, 3)   l=1 SH (cartesian)
    y2 = _sym_traceless(rhat[:, :, None] * rhat[:, None, :])  # (E, 3, 3)

    rbf = _bessel(d, cfg.n_rbf, cfg.r_cut) * emask[:, None]
    Rw = mlp_stack(lp["radial"], rbf).reshape(-1, 3, C)  # (E, l, C)

    hsrc = h0[src]  # (E, C) scalar neighbor features
    w0 = Rw[:, 0] * hsrc
    w1 = Rw[:, 1] * hsrc
    w2 = Rw[:, 2] * hsrc
    n = batch.n_nodes
    A0 = jax.ops.segment_sum(w0, dst, n)                                  # (N, C)
    A1 = jax.ops.segment_sum(w1[:, :, None] * y1[:, None, :], dst, n)     # (N, C, 3)
    A2 = jax.ops.segment_sum(w2[:, :, None, None] * y2[:, None], dst, n)  # (N, C, 3, 3)

    # ---- ACE product basis, correlation ≤ 3 (Cartesian CG table) ----------
    n11_0 = jnp.einsum("ncd,ncd->nc", A1, A1)                 # |A1|²        (ν=2)
    n22_0 = jnp.einsum("ncde,ncde->nc", A2, A2)               # tr(A2²)      (ν=2)
    a2v_1 = jnp.einsum("ncde,nce->ncd", A2, A1)               # A2·A1  l=1   (ν=2)
    c121_0 = jnp.einsum("ncd,ncd->nc", a2v_1, A1)             # A1·A2·A1     (ν=3)
    t11_2 = _sym_traceless(A1[..., :, None] * A1[..., None, :])  # A1⊗A1 l=2 (ν=2)
    c112_0 = jnp.einsum("ncde,ncde->nc", t11_2, A2)           # (A1⊗A1)·A2   (ν=3)

    B0 = jnp.concatenate(
        [A0, A0 * A0, A0 * A0 * A0, n11_0, n22_0, c121_0, c112_0], axis=-1
    )  # (N, 7C) invariants up to ν=3
    B1 = jnp.concatenate(
        [A1, A0[..., None] * A1, a2v_1, n11_0[..., None] * A1,
         (A0 * A0)[..., None] * A1],
        axis=1,
    )  # (N, 5C, 3) equivariant l=1, ν≤3
    m22_2 = _sym_traceless(jnp.einsum("ncde,ncef->ncdf", A2, A2))
    B2 = jnp.concatenate(
        [A2, A0[..., None, None] * A2, t11_2, m22_2], axis=1
    )  # (N, 4C, 3, 3) equivariant l=2, ν≤3

    msg0 = linear(lp["mix0"], B0)
    msg1 = jnp.einsum("nkd,kc->ncd", B1, lp["mix1"]["w"])
    msg2 = jnp.einsum("nkde,kc->ncde", B2, lp["mix2"]["w"])

    h0_new = h0 + mlp_stack(lp["update0"], jnp.concatenate([h0, msg0], -1))
    h1_new = h1 + msg1
    h2_new = h2 + msg2
    return h0_new, h1_new, h2_new


def forward(params: Dict, batch: GraphBatch, cfg: MACEConfig) -> jax.Array:
    """Per-graph energies (n_graphs,)."""
    C = cfg.channels
    N = batch.n_nodes
    h0 = params["embed"][batch.species]
    h1 = jnp.zeros((N, C, 3), cfg.dtype)
    h2 = jnp.zeros((N, C, 3, 3), cfg.dtype)
    layer_fn = jax.checkpoint(
        lambda lp, h0, h1, h2: _layer(lp, h0, h1, h2, batch, cfg))
    for lp in params["layers"]:
        h0, h1, h2 = layer_fn(lp, h0, h1, h2)
    e_atom = mlp_stack(params["readout"], h0)[:, 0] * batch.node_mask
    return jax.ops.segment_sum(e_atom, batch.graph_ids, batch.n_graphs)


def loss_fn(params: Dict, batch: GraphBatch, cfg: MACEConfig) -> jax.Array:
    e = forward(params, batch, cfg)
    return jnp.mean((e - batch.labels.astype(e.dtype)) ** 2)
