"""Decoder-only transformer family covering the five assigned LM architectures.

One implementation, config-selected features:
  * GQA (n_kv_heads < n_heads), RoPE, optional QKV bias (Qwen2)
  * sliding-window attention + local/global layer alternation (Mixtral, Gemma-2)
  * attn/final logit softcap + post-norms + GeGLU (Gemma-2)
  * MoE FFN with top-k routing (Mixtral 8e/top-2, DBRX 16e/top-4)

Layers are grouped into a repeating *pattern* (e.g. ``("local","global")`` for
Gemma-2) and scanned with ``lax.scan`` over stacked group params — essential to
keep HLO size and compile time flat in depth (80-layer Qwen2-72B compiles the
same program as an 8-layer toy).  ``jax.checkpoint`` on the group body gives
the standard per-layer remat policy for training.

Decode uses ring-buffer KV caches for windowed layers (cache length = window)
and linear caches for global layers — this is what makes ``long_500k`` legal
for the SWA archs (window-bounded local caches) as recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.attention import attention
from repro.nn.layers import init_linear, init_mlp, init_rmsnorm, linear, mlp, rmsnorm, rope, softcap
from repro.nn.moe import init_moe, moe_ffn

__all__ = ["TransformerConfig", "init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention features
    rope_theta: float = 10000.0
    window: Optional[int] = None            # sliding-window width for local layers
    pattern: Tuple[str, ...] = ("global",)  # repeating layer pattern
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    post_norms: bool = False                # gemma-2 post-attn/post-ffn norms
    # ffn
    act: str = "silu"
    gated: bool = True
    # moe (None ⇒ dense)
    n_experts: Optional[int] = None
    top_k: int = 2
    moe_renorm: str = "topk"
    capacity_factor: float = 1.25
    # grouped dispatch (GShard 'G' dim): groups = dp shards; axes for
    # with_sharding_constraint annotations (set by the launch layer)
    moe_groups: int = 1
    moe_dp_axes: Optional[Tuple[str, ...]] = None
    moe_expert_axis: Optional[str] = None
    moe_tp_axis: Optional[str] = None
    moe_virtual_split: int = 1   # F-slice virtual experts (see nn/moe.py)
    # Megatron sequence parallelism: shard the seq dim of inter-block
    # activations over this axis — the remat-stored per-layer carry shrinks
    # |model|×; SP all-gather/reduce-scatter collectives appear per block
    # (set by the launch layer for training)
    seq_shard_axis: Optional[str] = None
    batch_shard_axes: Optional[Tuple[str, ...]] = None
    # embedding
    scale_embed: bool = False               # gemma multiplies by sqrt(d)
    tie_embeddings: bool = False
    # numerics / runtime
    dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"
    attn_chunk: int = 1024
    loss_chunk: int = 1024                  # sequence chunking for lm-head+loss
    remat: bool = True
    remat_policy: str = "full"              # 'full' | 'dots' (save matmul outputs)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    def layer_window(self, kind: str) -> Optional[int]:
        return self.window if kind == "local" else None

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6·N·D roofline accounting)."""
        c = self
        attn = c.d_model * c.d_head * (c.n_heads + 2 * c.n_kv_heads) + c.n_heads * c.d_head * c.d_model
        if c.n_experts:
            ffn = c.n_experts * c.d_model * c.d_ff * (3 if c.gated else 2) + c.d_model * c.n_experts
        else:
            ffn = c.d_model * c.d_ff * (3 if c.gated else 2)
        per_layer = attn + ffn + 2 * c.d_model
        embed = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
        return c.n_layers * per_layer + embed

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        c = self
        attn = c.d_model * c.d_head * (c.n_heads + 2 * c.n_kv_heads) + c.n_heads * c.d_head * c.d_model
        if c.n_experts:
            ffn = c.top_k * c.d_model * c.d_ff * (3 if c.gated else 2) + c.d_model * c.n_experts
        else:
            ffn = c.d_model * c.d_ff * (3 if c.gated else 2)
        per_layer = attn + ffn + 2 * c.d_model
        embed = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
        return c.n_layers * per_layer + embed


# --------------------------------------------------------------------------- init
def _init_layer(key, cfg: TransformerConfig) -> Dict:
    ks = jax.random.split(key, 8)
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "ln1": init_rmsnorm(d),
        "wq": init_linear(ks[0], d, hq * dh, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, hkv * dh, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, hkv * dh, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], hq * dh, d),
        "ln2": init_rmsnorm(d),
    }
    if cfg.post_norms:
        p["ln1b"] = init_rmsnorm(d)
        p["ln2b"] = init_rmsnorm(d)
    if cfg.n_experts:
        p["moe"] = init_moe(ks[4], d, cfg.d_ff, cfg.n_experts, gated=cfg.gated,
                            virtual_split=cfg.moe_virtual_split)
    else:
        p["mlp"] = init_mlp(ks[5], d, cfg.d_ff, gated=cfg.gated, act=cfg.act)
    return p


def init_params(key, cfg: TransformerConfig) -> Dict:
    """Group params are stacked over n_groups (scan axis 0)."""
    ke, kh, *kl = jax.random.split(key, 2 + len(cfg.pattern))
    groups = []
    for i, _ in enumerate(cfg.pattern):
        def one(k):
            return _init_layer(k, cfg)
        keys = jax.random.split(kl[i], cfg.n_groups)
        groups.append(jax.vmap(one)(keys))
    p = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "groups": groups,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(kh, cfg.d_model, cfg.vocab)
    return p


# ----------------------------------------------------------------------- forward
def _attn_block(lp, x, cfg: TransformerConfig, kind: str, *, positions, cache=None,
                cache_slot=None):
    """Pre-norm attention with optional cache read/write.  Returns (y, new_kv)."""
    B, S, D = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    h = rmsnorm(lp["ln1"], x, plus_one=cfg.post_norms)
    q = linear(lp["wq"], h).reshape(B, S, hq, dh)
    k = linear(lp["wk"], h).reshape(B, S, hkv, dh)
    v = linear(lp["wv"], h).reshape(B, S, hkv, dh)
    q = rope(q, positions, theta=cfg.rope_theta)
    k = rope(k, positions, theta=cfg.rope_theta)
    window = cfg.layer_window(kind)

    if cache is None:
        o = attention(q, k, v, causal=True, window=window, cap=cfg.attn_softcap,
                      impl=cfg.attn_impl, chunk=cfg.attn_chunk)
        new_kv = (k, v)
    else:
        # decode: per-layer cache slice rides scan xs/ys — this bounds the
        # GSPMD write-amplification of DUS-at-traced-offset to ONE layer slice
        # per step (the carry-the-full-stack variant full-buffer-selects and
        # copy-protects the whole (G,·) stack per layer: measured 8× worse;
        # §Perf log).  Dots stay in cache dtype with f32 accumulation.
        ck, cv, cur = cache  # ck: (B, Scache, hkv, dh); cur: absolute position
        Sc = ck.shape[1]
        if window is not None and Sc == window:
            slot = cur % window
        else:
            slot = cur
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        if window is not None and Sc == window:
            # ring buffer: slot i holds absolute position cur - ((cur - i) mod W)
            i = jnp.arange(Sc)
            k_pos = cur - jnp.mod(cur - i, window)
            valid = k_pos >= 0
        else:
            i = jnp.arange(Sc)
            k_pos = i
            valid = i <= cur
        o = _decode_attend(q, ck, cv, k_pos, valid, cur, cfg)
        new_kv = (ck, cv)

    o = linear(lp["wo"], o.reshape(B, S, hq * dh))
    if cfg.post_norms:
        o = rmsnorm(lp["ln1b"], o, plus_one=True)
    return o, new_kv


def _decode_attend(q, ck, cv, k_pos, valid, cur, cfg: TransformerConfig):
    """Direct attention against a (possibly ring-buffered) cache with explicit
    per-slot absolute positions.  q: (B, 1, Hq, D).

    Dots run in the cache's native dtype with f32 accumulation
    (preferred_element_type) — casting k/v to f32 materializes a full f32 copy
    of the cache in HBM (measured 20× traffic blowup in the dry-run; §Perf)."""
    B, Sq, Hq, Dh = q.shape
    Hkv = ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh).astype(ck.dtype)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                   preferred_element_type=jnp.float32) * (Dh ** -0.5)
    s = softcap(s, cfg.attn_softcap)
    ok = valid & (k_pos <= cur)
    s = jnp.where(ok[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


def _ffn_block(lp, x, cfg: TransformerConfig):
    h = rmsnorm(lp["ln2"], x, plus_one=cfg.post_norms)
    if cfg.n_experts:
        B, S, D = h.shape
        shard_axes = None
        if cfg.moe_dp_axes is not None:
            shard_axes = {"dp": cfg.moe_dp_axes, "expert": cfg.moe_expert_axis,
                          "tp": cfg.moe_tp_axis}
        y, aux = moe_ffn(lp["moe"], h.reshape(B * S, D), top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, renorm=cfg.moe_renorm,
                         n_groups=cfg.moe_groups, virtual_split=cfg.moe_virtual_split,
                         shard_axes=shard_axes)
        y = y.reshape(B, S, D)
    else:
        y, aux = mlp(lp["mlp"], h, act=cfg.act), 0.0
    if cfg.post_norms:
        y = rmsnorm(lp["ln2b"], y, plus_one=True)
    return y, aux


def forward(params: Dict, tokens: jax.Array, cfg: TransformerConfig) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward.  tokens: (B, S) → (hidden (B,S,D), aux_loss)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    positions = jnp.arange(S)[None, :]

    def sp(x):
        # sequence-parallel carry: remat stores (B/dp, S/model, D) per group
        if cfg.seq_shard_axis is None:
            return x
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, P(cfg.batch_shard_axes, cfg.seq_shard_axis, None))

    def group_body(carry, gparams):
        x, aux = carry
        for kind, lp in zip(cfg.pattern, gparams):
            a, _ = _attn_block(lp, x, cfg, kind, positions=positions)
            x = x + a
            f, a_aux = _ffn_block(lp, x, cfg)
            x = sp(x + f)
            aux = aux + a_aux
        return (x, aux), None

    if cfg.remat and cfg.remat_policy == "dots":
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat:
        body = jax.checkpoint(group_body)
    else:
        body = group_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), tuple(params["groups"]))
    x = rmsnorm(params["final_norm"], x, plus_one=cfg.post_norms)
    return x, aux / cfg.n_layers


def _logits(params, h, cfg: TransformerConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    lg = h @ w.astype(h.dtype)
    return softcap(lg, cfg.final_softcap)


def loss_fn(params: Dict, tokens: jax.Array, labels: jax.Array, cfg: TransformerConfig):
    """Chunked LM loss: the (B,S,V) logits tensor is never materialized; the
    head+softmax run per sequence chunk inside a scan (memory-roofline lever)."""
    h, aux = forward(params, tokens, cfg)
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    n_chunks = S // chunk
    hc = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def step(tot, xs):
        hb, lb = xs  # (B, chunk, D), (B, chunk)
        lg = _logits(params, hb, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        true = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - true), None

    tot, _ = jax.lax.scan(step, jnp.float32(0.0), (hc, lc))
    loss = tot / (B * n_chunks * chunk)
    return loss + 0.01 * aux


# ------------------------------------------------------------------------ decode
def init_cache(cfg: TransformerConfig, batch: int, max_len: int, dtype=None) -> Dict:
    """Stacked caches per pattern position.  Windowed layers get ring buffers of
    length min(window, max_len); global layers full max_len."""
    dtype = dtype or cfg.dtype
    caches = {}
    for i, kind in enumerate(cfg.pattern):
        w = cfg.layer_window(kind)
        L = min(w, max_len) if w is not None else max_len
        caches[f"pos{i}"] = {
            "k": jnp.zeros((cfg.n_groups, batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
            "v": jnp.zeros((cfg.n_groups, batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
        }
    caches["cur"] = jnp.zeros((), jnp.int32)
    return caches


def decode_step(params: Dict, cache: Dict, tokens: jax.Array, cfg: TransformerConfig):
    """One decode step.  tokens: (B, 1) → (logits (B, 1, V), new cache).

    Per-layer cache slices ride scan xs/ys (see _attn_block decode note)."""
    B, S = tokens.shape
    assert S == 1
    cur = cache["cur"]
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    positions = jnp.full((B, 1), cur, jnp.int32)

    def group_body(carry, xs):
        x = carry
        gparams, gcache = xs
        new_kv = {}
        for i, (kind, lp) in enumerate(zip(cfg.pattern, gparams)):
            c = gcache[f"pos{i}"]
            a, (ck, cv) = _attn_block(
                lp, x, cfg, kind, positions=positions, cache=(c["k"], c["v"], cur)
            )
            x = x + a
            f, _ = _ffn_block(lp, x, cfg)
            x = x + f
            new_kv[f"pos{i}"] = {"k": ck, "v": cv}
        return x, new_kv

    gcaches = {k: v for k, v in cache.items() if k != "cur"}
    x, new_caches = jax.lax.scan(group_body, x, (tuple(params["groups"]), gcaches))
    x = rmsnorm(params["final_norm"], x, plus_one=cfg.post_norms)
    logits = _logits(params, x, cfg)
    new_caches["cur"] = cur + 1
    return logits, new_caches


def prefill(params: Dict, tokens: jax.Array, cfg: TransformerConfig):
    """Prefill forward: returns last-position logits (the cache write-back is
    shape-identical to init_cache and omitted from the lowered artifact — the
    roofline-relevant work is the forward itself)."""
    h, _ = forward(params, tokens, cfg)
    return _logits(params, h[:, -1:, :], cfg)
