"""DLRM RM2 (arXiv:1906.00091) — sparse embedding tables + dot interaction.

Assigned config: 13 dense features, 26 sparse fields, embed_dim=64,
bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction.

The embedding lookup is the hot path.  JAX has no native EmbeddingBag —
multi-hot bags are implemented as ``jnp.take`` + ``segment_sum`` (and the
Pallas ``embedding_bag`` kernel), which is **the DIP-LIST query generalized**
from OR-mask to weighted sum: offsets+values CSR per sample-field, reduce by
segment (DESIGN.md §4).  Tables are row-sharded over the ``model`` axis (the
paper's entity-dimension distribution rule applied to vocab rows).

``retrieval_cand`` scores one query against 10⁶ candidates: blocked matvec
against the candidate embedding matrix + top-k — not a loop.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.gnn_common import init_mlp_stack, mlp_stack
from repro.nn.layers import init_linear, linear

__all__ = ["DLRMConfig", "init_params", "forward", "loss_fn", "retrieval_scores"]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_size: int = 1_000_000       # rows per table
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    interaction: str = "dot"
    multi_hot: int = 1                # indices per bag (1 ⇒ one-hot lookup)
    dtype: Any = jnp.float32
    embed_impl: str = "take"          # 'take' | 'kernel' (Pallas embedding_bag)

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2

    @property
    def top_in(self) -> int:
        return self.n_interact + self.embed_dim


def init_params(key, cfg: DLRMConfig) -> Dict:
    ks = jax.random.split(key, 4)
    tables = (
        jax.random.normal(ks[0], (cfg.n_sparse, cfg.vocab_size, cfg.embed_dim), jnp.float32)
        * (cfg.embed_dim ** -0.5)
    )
    top_dims = (cfg.top_in,) + tuple(cfg.top_mlp[1:])
    return {
        "tables": tables,
        "bot": init_mlp_stack(ks[1], list(cfg.bot_mlp)),
        "top": init_mlp_stack(ks[2], list(top_dims)),
    }


def _embedding_bag(tables, idx, cfg: DLRMConfig):
    """idx: (B, n_sparse, multi_hot) → (B, n_sparse, embed_dim) mean-bags."""
    if cfg.embed_impl == "kernel":
        from repro.kernels.embedding_bag import ops as _ops

        return _ops.embedding_bag_fields(tables, idx)
    # vectorized take: one gather per field batched via vmap over fields
    def per_field(table, ix):  # table (V, D); ix (B, multi_hot)
        emb = jnp.take(table, ix, axis=0)  # (B, mh, D)
        return jnp.mean(emb, axis=1)

    return jnp.swapaxes(jax.vmap(per_field)(tables, jnp.swapaxes(idx, 0, 1)), 0, 1)


def _interact(dense_emb, sparse_emb):
    """Dot interaction: pairwise dots of the 27 embedding vectors (upper tri)."""
    B = dense_emb.shape[0]
    z = jnp.concatenate([dense_emb[:, None, :], sparse_emb], axis=1)  # (B, F, D)
    zz = jnp.einsum("bfd,bgd->bfg", z, z)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    return zz[:, iu, ju]  # (B, F(F-1)/2)


def forward(params: Dict, dense: jax.Array, sparse_idx: jax.Array, cfg: DLRMConfig) -> jax.Array:
    """dense: (B, 13) f32; sparse_idx: (B, 26, multi_hot) int32 → (B,) logits."""
    d = mlp_stack(params["bot"], dense.astype(cfg.dtype), final_act=True)  # (B, 64)
    s = _embedding_bag(params["tables"], sparse_idx, cfg).astype(cfg.dtype)
    inter = _interact(d, s)
    top_in = jnp.concatenate([d, inter], axis=-1)
    return mlp_stack(params["top"], top_in)[:, 0]


def loss_fn(params: Dict, dense, sparse_idx, labels, cfg: DLRMConfig) -> jax.Array:
    logit = forward(params, dense, sparse_idx, cfg).astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def retrieval_scores(params: Dict, dense: jax.Array, sparse_idx: jax.Array,
                     candidates: jax.Array, cfg: DLRMConfig, *, top_k: int = 100):
    """Score one query against (n_cand, embed_dim) candidates: blocked matvec
    + top-k.  dense: (1, 13); sparse_idx: (1, 26, mh)."""
    d = mlp_stack(params["bot"], dense.astype(cfg.dtype), final_act=True)
    s = _embedding_bag(params["tables"], sparse_idx, cfg).astype(cfg.dtype)
    q = d + jnp.sum(s, axis=1)  # (1, D) pooled query embedding
    scores = (candidates.astype(cfg.dtype) @ q[0]).astype(jnp.float32)  # (n_cand,)
    return jax.lax.top_k(scores, top_k)
