"""repro.kernels — Pallas TPU kernels for the perf-critical compute layers.

Each kernel package ships: ``kernel.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd public wrapper, auto interpret off-TPU), ``ref.py``
(pure-jnp oracle).  Validation: tests/test_kernels.py sweeps shapes/dtypes and
asserts allclose against the oracle in interpret mode.

Kernels (DESIGN.md §6):
  bitmap_query   — DIP-ARR attribute query as MXU matvec (the paper's hot loop)
  seg_mm         — DI neighborhood aggregation: block-CSR one-hot MXU SpMM
  flash_attention— blockwise online-softmax attention (causal/SWA/softcap/GQA)
  embedding_bag  — DLRM multi-hot gather-reduce (FBGEMM-TBE pattern on TPU)
"""
from repro.kernels.bitmap_query import bitmap_query
from repro.kernels.embedding_bag import embedding_bag_fields
from repro.kernels.flash_attention import flash_attention
from repro.kernels.seg_mm import seg_mm

__all__ = ["bitmap_query", "embedding_bag_fields", "flash_attention", "seg_mm"]
