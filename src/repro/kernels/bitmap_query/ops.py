"""Jit'd public wrappers for the bitmap_query kernels.

Dispatches interpret mode automatically off-TPU; on TPU backends the compiled
Pallas kernels run with lane-aligned tiles.

The ``*_sharded`` entries wrap the kernels in ``shard_map`` over the entity
axis of a device mesh: the (K, N) bitmap arrives pre-sharded ``P(None,
entity_axes)`` (``launch.sharding.pg_arr_specs``), the query mask(s) arrive
replicated, and each device launches the kernel over ONLY its local (K, N/P)
bitmap slice — the paper's "each locale only processes the array chunk it
owns", O(N/P) per device with zero collectives (the output mask stays
entity-sharded).
"""
from functools import partial

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.bitmap_query.kernel import (
    bitmap_query_batched_packed_pallas,
    bitmap_query_batched_pallas,
    bitmap_query_packed_pallas,
    bitmap_query_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Q-dimension buckets for the batched entries.  The batched kernels (and the
# jitted matvec fallbacks) specialize on Q, so a service coalescing a varying
# number of concurrent queries would otherwise compile once per distinct
# batch size.  Padding Q up to the next bucket (pad masks are all-False ⇒
# all-False output rows, sliced off by the caller) bounds the number of
# compiled programs to len(Q_BUCKETS) per (K, N) shape.
Q_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def bucketed_q(q: int) -> int:
    """Smallest bucket ≥ ``q`` (multiples of the largest bucket beyond it).

    ``src/repro/service/scheduler.py`` pads its coalesced mask batches to
    this size before calling the ``*_batched`` entries (single-device or
    shard_map'd alike — both specialize on Q)."""
    if q < 1:
        raise ValueError(f"q must be ≥ 1, got {q}")
    for b in Q_BUCKETS:
        if q <= b:
            return b
    top = Q_BUCKETS[-1]
    return -(-q // top) * top


def bitmap_query(bitmap: jax.Array, attr_mask: jax.Array, *, tile_n: int = 2048) -> jax.Array:
    """(K, N) int8 bitmap × (K,) bool query mask → (N,) bool entity mask."""
    return bitmap_query_pallas(bitmap, attr_mask, tile_n=tile_n, interpret=not _on_tpu())


def bitmap_query_batched(
    bitmap: jax.Array, attr_masks: jax.Array, *, tile_n: int = 2048
) -> jax.Array:
    """(K, N) int8 bitmap × (Q, K) bool query masks → (Q, N) bool entity
    masks, all Q queries in one kernel launch (planner fusion entry)."""
    return bitmap_query_batched_pallas(
        bitmap, attr_masks, tile_n=tile_n, interpret=not _on_tpu()
    )


def bitmap_query_packed(plane: jax.Array, attr_mask: jax.Array, *,
                        tile_w: int = 512) -> jax.Array:
    """(K, W) uint32 word plane × (K,) bool query → (W,) uint32 word mask —
    the packed scan path: bitwise OR of selected rows, 1 bit/entity moved."""
    return bitmap_query_packed_pallas(
        plane, attr_mask, tile_w=tile_w, interpret=not _on_tpu())


def bitmap_query_batched_packed(plane: jax.Array, attr_masks: jax.Array, *,
                                tile_w: int = 512) -> jax.Array:
    """(K, W) uint32 word plane × (Q, K) bool queries → (Q, W) uint32 word
    masks, one launch (planner fusion entry, packed form)."""
    return bitmap_query_batched_packed_pallas(
        plane, attr_masks, tile_w=tile_w, interpret=not _on_tpu())


def _entity_axes(mesh):
    from repro.launch.sharding import pg_entity_axes

    return pg_entity_axes(mesh)


@partial(jax.jit, static_argnames=("mesh", "tile_n"))
def bitmap_query_sharded(
    bitmap: jax.Array, attr_mask: jax.Array, *, mesh, tile_n: int = 2048
) -> jax.Array:
    """Sharded single-mask query: (K, N) bitmap with N divisible by the
    entity shard count → (N,) bool mask, entity-sharded, one kernel launch
    per device over its local slice."""
    ax = _entity_axes(mesh)
    f = shard_map(
        lambda b, m: bitmap_query(b, m, tile_n=tile_n),
        mesh=mesh,
        in_specs=(P(None, ax), P()),
        out_specs=P(ax),
        check_rep=False,  # no replication rule for pallas_call
    )
    return f(bitmap, attr_mask)


@partial(jax.jit, static_argnames=("mesh", "tile_n"))
def bitmap_query_batched_sharded(
    bitmap: jax.Array, attr_masks: jax.Array, *, mesh, tile_n: int = 2048
) -> jax.Array:
    """Sharded multi-mask query: (Q, K) masks replicated, bitmap entity-
    sharded → (Q, N) bool, entity-sharded on N.  Each device runs the fused
    batched kernel on its (K, N/P) slice — the planner's fusion and the
    paper's distribution compose."""
    ax = _entity_axes(mesh)
    f = shard_map(
        lambda b, m: bitmap_query_batched(b, m, tile_n=tile_n),
        mesh=mesh,
        in_specs=(P(None, ax), P()),
        out_specs=P(None, ax),
        check_rep=False,  # no replication rule for pallas_call
    )
    return f(bitmap, attr_masks)


@partial(jax.jit, static_argnames=("mesh", "tile_w"))
def bitmap_query_packed_sharded(
    plane: jax.Array, attr_mask: jax.Array, *, mesh, tile_w: int = 512
) -> jax.Array:
    """Sharded packed query: the (K, W) word plane is sharded on its WORD
    axis (W divisible by the shard count, so entity ownership stays word-
    aligned) → (W,) uint32, word-sharded, zero collectives."""
    ax = _entity_axes(mesh)
    f = shard_map(
        lambda b, m: bitmap_query_packed(b, m, tile_w=tile_w),
        mesh=mesh,
        in_specs=(P(None, ax), P()),
        out_specs=P(ax),
        check_rep=False,  # no replication rule for pallas_call
    )
    return f(plane, attr_mask)


@partial(jax.jit, static_argnames=("mesh", "tile_w"))
def bitmap_query_batched_packed_sharded(
    plane: jax.Array, attr_masks: jax.Array, *, mesh, tile_w: int = 512
) -> jax.Array:
    """Sharded packed multi-mask query: (Q, K) masks replicated, plane
    word-sharded → (Q, W) uint32 word-sharded on W."""
    ax = _entity_axes(mesh)
    f = shard_map(
        lambda b, m: bitmap_query_batched_packed(b, m, tile_w=tile_w),
        mesh=mesh,
        in_specs=(P(None, ax), P()),
        out_specs=P(None, ax),
        check_rep=False,  # no replication rule for pallas_call
    )
    return f(plane, attr_masks)
