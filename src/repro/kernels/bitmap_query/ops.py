"""Jit'd public wrappers for the bitmap_query kernels.

Dispatches interpret mode automatically off-TPU; on TPU backends the compiled
Pallas kernels run with lane-aligned tiles.
"""
import jax

from repro.kernels.bitmap_query.kernel import (
    bitmap_query_batched_pallas,
    bitmap_query_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bitmap_query(bitmap: jax.Array, attr_mask: jax.Array, *, tile_n: int = 2048) -> jax.Array:
    """(K, N) int8 bitmap × (K,) bool query mask → (N,) bool entity mask."""
    return bitmap_query_pallas(bitmap, attr_mask, tile_n=tile_n, interpret=not _on_tpu())


def bitmap_query_batched(
    bitmap: jax.Array, attr_masks: jax.Array, *, tile_n: int = 2048
) -> jax.Array:
    """(K, N) int8 bitmap × (Q, K) bool query masks → (Q, N) bool entity
    masks, all Q queries in one kernel launch (planner fusion entry)."""
    return bitmap_query_batched_pallas(
        bitmap, attr_masks, tile_n=tile_n, interpret=not _on_tpu()
    )
