"""Pure-jnp oracle for the bitmap_query kernel (paper-faithful row scan)."""
import jax
import jax.numpy as jnp


@jax.jit
def bitmap_query_ref(bitmap: jax.Array, attr_mask: jax.Array) -> jax.Array:
    """bitmap: (K, N) int8; attr_mask: (K,) bool → (N,) bool."""
    sel = bitmap.astype(jnp.bool_) & attr_mask[:, None]
    return jnp.any(sel, axis=0)


@jax.jit
def bitmap_query_batched_ref(bitmap: jax.Array, attr_masks: jax.Array) -> jax.Array:
    """bitmap: (K, N) int8; attr_masks: (Q, K) bool → (Q, N) bool."""
    sel = bitmap.astype(jnp.bool_)[None] & attr_masks[:, :, None]
    return jnp.any(sel, axis=1)


@jax.jit
def bitmap_query_packed_ref(plane: jax.Array, attr_mask: jax.Array) -> jax.Array:
    """plane: (K, W) uint32 word plane; attr_mask: (K,) bool → (W,) uint32."""
    from repro.core import bitplane

    sel = jnp.where(attr_mask[:, None], plane, jnp.uint32(0))
    return bitplane.or_reduce(sel, axis=0)


@jax.jit
def bitmap_query_batched_packed_ref(plane: jax.Array, attr_masks: jax.Array) -> jax.Array:
    """plane: (K, W) uint32; attr_masks: (Q, K) bool → (Q, W) uint32."""
    from repro.core import bitplane

    sel = jnp.where(attr_masks[:, :, None], plane[None], jnp.uint32(0))
    return bitplane.or_reduce(sel, axis=1)
