"""Pallas TPU kernels: DIP-ARR attribute query, byte (MXU) and packed (VPU).

The paper's DIP-ARR query scans the selected attribute rows of the (K, N)
byte bitmap and ORs them (§VI-C, O(N/P)).  On TPU the byte form is
reformulated for the systolic array:

    counts(1, Nt) = mask(1, K) @ bitmap(K, Nt);   out = counts > 0

Grid: 1-D over entity tiles (the paper's distribution dimension).  Each step
holds a (K, Nt) bitmap block and the full (1, K) query mask in VMEM.
VMEM budget: K ≤ 512 attributes × Nt = 2048 entities × 4 B (f32 on the MXU
path) ≈ 4 MiB — comfortably inside the ~16 MiB/core VMEM envelope; Nt is the
lane-aligned (×128) tunable.

The PACKED form works on the (K, W = ceil(N/32)) uint32 word plane instead.
There is no MXU trick for bitwise OR, but none is needed: the scan is
bandwidth-bound, and the packed plane moves 8× fewer bytes than int8 (32×
fewer than the f32 the MXU path casts to).  The kernel is a VPU loop over K
accumulating ``acc |= select[a] & plane[a]`` on (Q, Wt) uint32 lanes —
query masks arrive pre-broadcast as full-word 0x00000000/0xFFFFFFFF selects
so the inner loop is two vector ops per row.  uint32 is a 32-bit lane type
⇒ (8, 128) minimum tile; Wt = 512 words (= 16 384 entities) keeps the
(K, Wt) block at K=512 to 1 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 2048
DEFAULT_TILE_W = 512  # packed words per grid step (×128 lane-aligned)


def _bitmap_query_kernel(mask_ref, bitmap_ref, out_ref):
    mask = mask_ref[...]          # (Q, K) f32 — Q=1 for the single-query form
    block = bitmap_ref[...]       # (K, Nt) int8
    counts = jnp.dot(mask, block.astype(jnp.float32),
                     preferred_element_type=jnp.float32)  # (Q, Nt) on the MXU
    out_ref[...] = (counts > 0.5)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def bitmap_query_pallas(bitmap: jax.Array, attr_mask: jax.Array, *,
                        tile_n: int = DEFAULT_TILE_N, interpret: bool = True) -> jax.Array:
    """bitmap: (K, N) int8; attr_mask: (K,) bool → (N,) bool."""
    k, n = bitmap.shape
    tile_n = min(tile_n, n)
    pad = (-n) % tile_n
    if pad:
        bitmap = jnp.pad(bitmap, ((0, 0), (0, pad)))
    n_pad = n + pad
    maskf = attr_mask.astype(jnp.float32)[None, :]  # (1, K)

    out = pl.pallas_call(
        _bitmap_query_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),        # query mask: replicated
            pl.BlockSpec((k, tile_n), lambda i: (0, i)),   # bitmap: entity tiles
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.bool_),
        interpret=interpret,
    )(maskf, bitmap)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def bitmap_query_batched_pallas(bitmap: jax.Array, attr_masks: jax.Array, *,
                                tile_n: int = DEFAULT_TILE_N,
                                interpret: bool = True) -> jax.Array:
    """Batched multi-mask form: ``bitmap (K, N) int8 × attr_masks (Q, K) bool
    → (Q, N) bool`` in ONE kernel launch.

    The planner fuses the label masks of every node slot of a pattern into
    this single launch: the (K, Nt) bitmap tile is read from HBM once and
    reused across all Q query rows on the MXU (``(Q, K) @ (K, Nt)``) instead
    of once per mask — same grid, Q× the arithmetic intensity.
    """
    k, n = bitmap.shape
    q = attr_masks.shape[0]
    tile_n = min(tile_n, n)
    pad = (-n) % tile_n
    if pad:
        bitmap = jnp.pad(bitmap, ((0, 0), (0, pad)))
    n_pad = n + pad
    maskf = attr_masks.astype(jnp.float32)  # (Q, K)

    out = pl.pallas_call(
        _bitmap_query_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((q, k), lambda i: (0, 0)),        # all queries: replicated
            pl.BlockSpec((k, tile_n), lambda i: (0, i)),   # bitmap: entity tiles
        ],
        out_specs=pl.BlockSpec((q, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, n_pad), jnp.bool_),
        interpret=interpret,
    )(maskf, bitmap)
    return out[:, :n]


def _bitmap_query_packed_kernel(select_ref, plane_ref, out_ref):
    select = select_ref[...]      # (Q, K) uint32 — 0 or 0xFFFFFFFF per query row
    k = select.shape[1]

    def body(a, acc):
        return acc | (select[:, a][:, None] & plane_ref[a, :][None, :])

    acc0 = jnp.zeros_like(out_ref)
    out_ref[...] = jax.lax.fori_loop(0, k, body, acc0)


@functools.partial(jax.jit, static_argnames=("tile_w", "interpret"))
def bitmap_query_batched_packed_pallas(
    plane: jax.Array, attr_masks: jax.Array, *,
    tile_w: int = DEFAULT_TILE_W, interpret: bool = True
) -> jax.Array:
    """Packed batched query: ``plane (K, W) uint32 × attr_masks (Q, K) bool
    → (Q, W) uint32`` word masks, one launch for all Q queries.

    The fori_loop over K keeps VMEM at (K, Wt) + (Q, Wt) — no (Q, K, Wt)
    intermediate — while each (K, Wt) plane tile streams from HBM exactly
    once for all Q query rows.
    """
    k, w = plane.shape
    q = attr_masks.shape[0]
    tile_w = min(tile_w, w)
    pad = (-w) % tile_w
    if pad:
        plane = jnp.pad(plane, ((0, 0), (0, pad)))
    w_pad = w + pad
    select = jnp.where(attr_masks, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))

    out = pl.pallas_call(
        _bitmap_query_packed_kernel,
        grid=(w_pad // tile_w,),
        in_specs=[
            pl.BlockSpec((q, k), lambda i: (0, 0)),        # selects: replicated
            pl.BlockSpec((k, tile_w), lambda i: (0, i)),   # plane: word tiles
        ],
        out_specs=pl.BlockSpec((q, tile_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, w_pad), jnp.uint32),
        interpret=interpret,
    )(select, plane)
    return out[:, :w]


@functools.partial(jax.jit, static_argnames=("tile_w", "interpret"))
def bitmap_query_packed_pallas(plane: jax.Array, attr_mask: jax.Array, *,
                               tile_w: int = DEFAULT_TILE_W,
                               interpret: bool = True) -> jax.Array:
    """Packed single query: ``plane (K, W) uint32 × attr_mask (K,) bool →
    (W,) uint32`` word mask."""
    out = bitmap_query_batched_packed_pallas(
        plane, attr_mask[None, :], tile_w=tile_w, interpret=interpret)
    return out[0]
