"""Pallas TPU kernel: DIP-ARR attribute query as an MXU matvec.

The paper's DIP-ARR query scans the selected attribute rows of the (K, N)
byte bitmap and ORs them (§VI-C, O(N/P)).  On TPU the same reduction is
reformulated for the systolic array:

    counts(1, Nt) = mask(1, K) @ bitmap(K, Nt);   out = counts > 0

Grid: 1-D over entity tiles (the paper's distribution dimension).  Each step
holds a (K, Nt) bitmap block and the full (1, K) query mask in VMEM.
VMEM budget: K ≤ 512 attributes × Nt = 2048 entities × 4 B (f32 on the MXU
path) ≈ 4 MiB — comfortably inside the ~16 MiB/core VMEM envelope; Nt is the
lane-aligned (×128) tunable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 2048


def _bitmap_query_kernel(mask_ref, bitmap_ref, out_ref):
    mask = mask_ref[...]          # (Q, K) f32 — Q=1 for the single-query form
    block = bitmap_ref[...]       # (K, Nt) int8
    counts = jnp.dot(mask, block.astype(jnp.float32),
                     preferred_element_type=jnp.float32)  # (Q, Nt) on the MXU
    out_ref[...] = (counts > 0.5)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def bitmap_query_pallas(bitmap: jax.Array, attr_mask: jax.Array, *,
                        tile_n: int = DEFAULT_TILE_N, interpret: bool = True) -> jax.Array:
    """bitmap: (K, N) int8; attr_mask: (K,) bool → (N,) bool."""
    k, n = bitmap.shape
    tile_n = min(tile_n, n)
    pad = (-n) % tile_n
    if pad:
        bitmap = jnp.pad(bitmap, ((0, 0), (0, pad)))
    n_pad = n + pad
    maskf = attr_mask.astype(jnp.float32)[None, :]  # (1, K)

    out = pl.pallas_call(
        _bitmap_query_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),        # query mask: replicated
            pl.BlockSpec((k, tile_n), lambda i: (0, i)),   # bitmap: entity tiles
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.bool_),
        interpret=interpret,
    )(maskf, bitmap)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def bitmap_query_batched_pallas(bitmap: jax.Array, attr_masks: jax.Array, *,
                                tile_n: int = DEFAULT_TILE_N,
                                interpret: bool = True) -> jax.Array:
    """Batched multi-mask form: ``bitmap (K, N) int8 × attr_masks (Q, K) bool
    → (Q, N) bool`` in ONE kernel launch.

    The planner fuses the label masks of every node slot of a pattern into
    this single launch: the (K, Nt) bitmap tile is read from HBM once and
    reused across all Q query rows on the MXU (``(Q, K) @ (K, Nt)``) instead
    of once per mask — same grid, Q× the arithmetic intensity.
    """
    k, n = bitmap.shape
    q = attr_masks.shape[0]
    tile_n = min(tile_n, n)
    pad = (-n) % tile_n
    if pad:
        bitmap = jnp.pad(bitmap, ((0, 0), (0, pad)))
    n_pad = n + pad
    maskf = attr_masks.astype(jnp.float32)  # (Q, K)

    out = pl.pallas_call(
        _bitmap_query_kernel,
        grid=(n_pad // tile_n,),
        in_specs=[
            pl.BlockSpec((q, k), lambda i: (0, 0)),        # all queries: replicated
            pl.BlockSpec((k, tile_n), lambda i: (0, i)),   # bitmap: entity tiles
        ],
        out_specs=pl.BlockSpec((q, tile_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, n_pad), jnp.bool_),
        interpret=interpret,
    )(maskf, bitmap)
    return out[:, :n]
