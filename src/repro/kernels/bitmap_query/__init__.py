from repro.kernels.bitmap_query import ops, ref
from repro.kernels.bitmap_query.ops import (
    Q_BUCKETS,
    bitmap_query,
    bitmap_query_batched,
    bucketed_q,
)

__all__ = ["ops", "ref", "bitmap_query", "bitmap_query_batched",
           "bucketed_q", "Q_BUCKETS"]
