from repro.kernels.bitmap_query import ops, ref
from repro.kernels.bitmap_query.ops import bitmap_query

__all__ = ["ops", "ref", "bitmap_query"]
