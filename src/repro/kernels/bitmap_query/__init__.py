from repro.kernels.bitmap_query import ops, ref
from repro.kernels.bitmap_query.ops import bitmap_query, bitmap_query_batched

__all__ = ["ops", "ref", "bitmap_query", "bitmap_query_batched"]
