"""Jit'd public wrapper for the flash_attention kernel."""
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    cap: Optional[float] = None, q_offset: int = 0,
                    bq: int = 128, bkv: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, cap=cap, q_offset=q_offset,
        bq=bq, bkv=bkv, interpret=not _on_tpu())
