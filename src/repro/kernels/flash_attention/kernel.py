"""Pallas TPU kernel: blockwise online-softmax attention (FlashAttention on MXU).

Supports causal masking, sliding-window, Gemma-2 logit softcap, and GQA
(query-head groups share KV heads via the grid mapping, no KV replication).

Grid: (batch·kv_heads·q_groups, Sq tiles, Skv tiles) — the Skv axis is the
innermost (sequential on TPU), carrying the running (max, denom, acc) in VMEM
scratch; the output tile is written on the last KV step.  Causal + window
tiles that are fully masked are skipped cheaply (the mask still computes, but
contributes exp(-inf)=0; a block-skip via index remap is a recorded §Perf
follow-up).  Block sizes default to (128, 128) — MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  cap: Optional[float], q_offset: int, bq: int, bkv: int, n_kv: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bkv, d)
    v = v_ref[0]  # (bkv, d)
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32) * scale  # (bq, bkv)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    q_pos = q_offset + pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    k_pos = kv_i * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "cap", "q_offset", "bq", "bkv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: Optional[int] = None, cap: Optional[float] = None,
    q_offset: int = 0, bq: int = 128, bkv: int = 128, interpret: bool = True,
) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) → (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0, (Sq, bq, Skv, bkv)
    n_q, n_kv = Sq // bq, Skv // bkv

    # layout: fold (B, Hkv, G) into the leading grid axis; kv indexed by (B, Hkv)
    qr = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 3, 1, 4).reshape(B * Hkv * G, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window, cap=cap,
        q_offset=q_offset, bq=bq, bkv=bkv, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv * G, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv * G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hkv, G, Sq, D).transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
