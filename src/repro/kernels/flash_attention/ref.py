"""Pure-jnp oracle for flash_attention (direct softmax attention)."""
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                        cap: Optional[float] = None, q_offset: int = 0):
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D) → (B,Sq,Hq,D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * (D ** -0.5)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
