"""Jit'd public wrapper for the embedding_bag kernel."""
import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag_fields(tables: jax.Array, idx: jax.Array, *, bt: int = 256) -> jax.Array:
    """(F, V, D) tables × (B, F, MH) multi-hot indices → (B, F, D) mean bags."""
    return embedding_bag_pallas(tables, idx, bt=bt, interpret=not _on_tpu())
