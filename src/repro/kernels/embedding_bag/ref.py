"""Pure-jnp oracle for embedding_bag: take + mean over the bag dimension."""
import jax
import jax.numpy as jnp


@jax.jit
def embedding_bag_ref(tables: jax.Array, idx: jax.Array) -> jax.Array:
    """tables: (F, V, D); idx: (B, F, MH) → (B, F, D) mean-pooled."""
    def per_field(table, ix):  # (V, D), (B, MH)
        return jnp.mean(jnp.take(table, ix, axis=0), axis=1)

    return jnp.swapaxes(jax.vmap(per_field)(tables, jnp.swapaxes(idx, 0, 1)), 0, 1)
