"""Pallas TPU kernel: embedding-bag (multi-hot gather-reduce) for DLRM.

The FBGEMM-TBE access pattern adapted to TPU: per (field, batch-tile) grid
step, the kernel walks the tile's bag indices (scalar-prefetched) and issues
row loads from the field's table — on real TPU these become HBM→VMEM DMAs of
one row each (the table lives in ANY/HBM memory space; rows are gathered with
dynamic slices), accumulated in a VMEM scratch tile and divided by the bag
size (mean pooling).  This is the DIP-LIST CSR query generalized from OR-mask
to weighted segment reduction (DESIGN.md §4).

Sizing: bag indices are (Bt, MH) int32 in SMEM; accumulation tile (Bt, D) f32
in VMEM — Bt=256, D≤128 ⇒ 128 KiB, trivially VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256


def _embedding_bag_kernel(idx_ref, table_ref, out_ref, acc_scr, *, bt: int, mh: int):
    f = pl.program_id(0)  # field (tables are field-major in HBM)

    def bag_body(b, acc):
        def hot_body(h, a):
            row = idx_ref[0, b, h]
            vec = pl.load(table_ref, (f, pl.dslice(row, 1), slice(None)))  # (1, D) DMA
            return a.at[b, :].add(vec[0].astype(jnp.float32))

        return jax.lax.fori_loop(0, mh, hot_body, acc)

    acc_scr[...] = jax.lax.fori_loop(0, bt, bag_body, jnp.zeros_like(acc_scr))
    out_ref[0] = (acc_scr[...] / mh).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def embedding_bag_pallas(tables: jax.Array, idx: jax.Array, *, bt: int = DEFAULT_BT,
                         interpret: bool = True) -> jax.Array:
    """tables: (F, V, D); idx: (B, F, MH) int32 → (B, F, D) mean-pooled bags."""
    B, F, MH = idx.shape
    _, V, D = tables.shape
    bt = min(bt, B)
    assert B % bt == 0, (B, bt)
    idx_t = idx.transpose(1, 0, 2)  # (F, B, MH) — field-major for the grid

    out = pl.pallas_call(
        functools.partial(_embedding_bag_kernel, bt=bt, mh=MH),
        grid=(F, B // bt),
        in_specs=[
            pl.BlockSpec((1, bt, MH), lambda f, b: (f, b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),  # whole table stack in HBM
        ],
        out_specs=pl.BlockSpec((1, bt, D), lambda f, b: (f, b, 0)),
        out_shape=jax.ShapeDtypeStruct((F, B, D), tables.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        interpret=interpret,
    )(idx_t, tables)
    return out.transpose(1, 0, 2)  # (B, F, D)
