"""Public wrapper for the seg_mm kernel.

``seg_mm`` takes raw DI edge arrays; the block-CSR layout is built host-side
once per (static) graph and LRU-cached on the id of the destination array —
graphs are static per the paper (§II), so the routing tables amortize to zero.
The gather + weighting stays in XLA (it fuses well); the kernel owns the
scatter-reduce, which is the part XLA lowers poorly (serial scatter loops).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.seg_mm.kernel import SegMMLayout, build_layout, seg_mm_pallas

_LAYOUT_CACHE: dict = {}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def get_layout(dst_idx, n_nodes: int, *, nt: int = 256, ec: int = 256) -> SegMMLayout:
    key = (id(dst_idx), n_nodes, nt, ec)
    if key not in _LAYOUT_CACHE:
        dst_np = np.asarray(dst_idx)
        order = np.argsort(dst_np, kind="stable")
        if (dst_np[1:] >= dst_np[:-1]).all():
            order = np.arange(len(dst_np))
        _LAYOUT_CACHE[key] = (build_layout(dst_np[order], n_nodes, nt=nt, ec=ec),
                              jnp.asarray(order.astype(np.int32)))
    return _LAYOUT_CACHE[key]


def seg_mm(x: jax.Array, src_idx: jax.Array, dst_idx: jax.Array, n_nodes: int, *,
           edge_weight: Optional[jax.Array] = None, nt: int = 256, ec: int = 256) -> jax.Array:
    """Drop-in replacement for segment_sum message passing over DI edges."""
    layout, order = get_layout(dst_idx, n_nodes, nt=nt, ec=ec)
    msgs = x[src_idx]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    msgs = msgs[order]  # dst-sorted (reverse-DI) order
    perm = layout.edge_perm
    msgs_padded = jnp.where((perm >= 0)[:, None], msgs[jnp.maximum(perm, 0)], 0)
    out = seg_mm_pallas(
        msgs_padded, layout.chunk_tile, layout.chunk_first, layout.dst_local,
        n_tiles=layout.n_tiles, nt=layout.nt, ec=layout.ec, interpret=not _on_tpu(),
    )
    return out[:n_nodes]
