from repro.kernels.seg_mm import ops, ref
from repro.kernels.seg_mm.ops import seg_mm

__all__ = ["ops", "ref", "seg_mm"]
