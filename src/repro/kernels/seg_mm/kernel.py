"""Pallas TPU kernel: DI neighborhood aggregation (SpMM) via block-CSR + one-hot MXU.

The GNN message-passing primitive ``out[v] = Σ_{e:dst_e=v} w_e · x[src_e]`` is
mapped onto the MXU instead of scalar scatter loops (the GPU-idiomatic
GE-SpMM/FusedMM shape, re-thought for the systolic array — DESIGN.md §2):

  1. Host layout pass (block-CSR): edges sorted by dst (the reverse-DI
     invariant) are cut into fixed ``Ec``-edge chunks *aligned to node tiles*
     of ``Nt`` rows, so each chunk scatters into exactly one output tile.
  2. Kernel per chunk: build the (Ec, Nt) one-hot scatter block from local dst
     ids with iota-compare, then ``out_tile += onehotᵀ @ msgs`` — an
     (Nt × Ec) · (Ec × D) MXU matmul.
  3. Chunk→tile routing is scalar-prefetched (PrefetchScalarGridSpec), the
     revisiting-output accumulation pattern: TPU grids execute sequentially,
     so ``out_ref[...] +=`` across chunks of one tile is race-free; the first
     chunk of each tile zero-initializes.

VMEM per step: one-hot (Ec×Nt) f32 + msgs (Ec×D) + out (Nt×D); defaults
Ec=256, Nt=256, D-tile = full D (≤ 512) ≈ 1.3 MiB.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_EC = 256
DEFAULT_NT = 256


class SegMMLayout(NamedTuple):
    """Host-built block-CSR routing (one-time per static graph)."""

    chunk_tile: jax.Array    # (n_chunks,) int32 — output node tile per chunk
    chunk_first: jax.Array   # (n_chunks,) int32 — 1 if first chunk of its tile
    edge_perm: jax.Array     # (n_chunks·Ec,) int32 — edge index per slot, -1 pad
    dst_local: jax.Array     # (n_chunks, Ec) int32 — dst - tile·Nt, Nt ⇒ pad
    n_tiles: int
    nt: int
    ec: int


def build_layout(dst_sorted: np.ndarray, n_nodes: int, *, nt: int = DEFAULT_NT,
                 ec: int = DEFAULT_EC) -> SegMMLayout:
    """dst_sorted: (E,) int32 non-decreasing destination ids."""
    dst_sorted = np.asarray(dst_sorted)
    n_tiles = max(1, -(-n_nodes // nt))
    bounds = np.searchsorted(dst_sorted, np.arange(n_tiles + 1) * nt)
    chunk_tile, chunk_first, edge_idx = [], [], []
    for i in range(n_tiles):
        s, e = int(bounds[i]), int(bounds[i + 1])
        n_chunks_i = max(1, -(-(e - s) // ec))
        for j in range(n_chunks_i):
            chunk_tile.append(i)
            chunk_first.append(1 if j == 0 else 0)
            lo = s + j * ec
            idx = np.arange(lo, min(lo + ec, e), dtype=np.int32)
            pad = np.full(ec - len(idx), -1, np.int32)
            edge_idx.append(np.concatenate([idx, pad]))
    edge_idx = np.stack(edge_idx)  # (n_chunks, Ec)
    tiles = np.asarray(chunk_tile, np.int32)
    d_local = np.where(
        edge_idx >= 0, dst_sorted[np.maximum(edge_idx, 0)] - tiles[:, None] * nt, nt
    ).astype(np.int32)
    return SegMMLayout(
        chunk_tile=jnp.asarray(tiles),
        chunk_first=jnp.asarray(chunk_first, dtype=jnp.int32),
        edge_perm=jnp.asarray(edge_idx.reshape(-1)),
        dst_local=jnp.asarray(d_local),
        n_tiles=n_tiles,
        nt=nt,
        ec=ec,
    )


def _seg_mm_kernel(chunk_tile, chunk_first, dst_local_ref, msgs_ref, out_ref, *, nt: int):
    c = pl.program_id(0)

    @pl.when(chunk_first[c] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    d_local = dst_local_ref[...]  # (1, Ec)
    msgs = msgs_ref[...]          # (Ec, D)
    # one-hot scatter block on the MXU: (Nt, Ec) @ (Ec, D)
    rows = jax.lax.broadcasted_iota(jnp.int32, (nt, d_local.shape[1]), 0)
    onehot = (rows == d_local).astype(jnp.float32)  # pad slots (==nt) never match
    out_ref[...] += jnp.dot(onehot, msgs.astype(jnp.float32),
                            preferred_element_type=jnp.float32).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_tiles", "nt", "ec", "interpret"))
def seg_mm_pallas(msgs_padded: jax.Array, layout_chunk_tile, layout_chunk_first,
                  layout_dst_local, *, n_tiles: int, nt: int, ec: int,
                  interpret: bool = True) -> jax.Array:
    """msgs_padded: (n_chunks·Ec, D) gathered/weighted messages (pad rows zero).
    Returns (n_tiles·Nt, D) aggregated node features."""
    n_chunks = layout_dst_local.shape[0]
    d = msgs_padded.shape[-1]
    kernel = functools.partial(_seg_mm_kernel, nt=nt)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(n_chunks,),
            in_specs=[
                pl.BlockSpec((1, ec), lambda c, tm, fs: (c, 0)),   # dst_local
                pl.BlockSpec((ec, d), lambda c, tm, fs: (c, 0)),   # msgs chunk
            ],
            out_specs=pl.BlockSpec((nt, d), lambda c, tm, fs: (tm[c], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_tiles * nt, d), msgs_padded.dtype),
        interpret=interpret,
    )(layout_chunk_tile, layout_chunk_first, layout_dst_local, msgs_padded)
