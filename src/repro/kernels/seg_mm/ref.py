"""Pure-jnp oracle for seg_mm: plain segment_sum over gathered messages."""
from typing import Optional

import jax
import jax.numpy as jnp


def seg_mm_ref(x: jax.Array, src_idx: jax.Array, dst_idx: jax.Array, n_nodes: int,
               *, edge_weight: Optional[jax.Array] = None) -> jax.Array:
    """out[v] = Σ_{e: dst_e = v} w_e · x[src_e]."""
    msgs = x[src_idx]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, dst_idx, num_segments=n_nodes)
