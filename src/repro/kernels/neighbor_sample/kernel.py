"""Pallas TPU kernel: per-seed adjacency-window gather + filtered select.

The TPU lowering of ``ops._window_select``: per seed, one contiguous
HBM→VMEM DMA of its ``DST`` window (``pl.dslice(start, W)`` — the DIP
contiguity the paper builds SEG/DST for), the packed edge-mask words
covering that window loaded the same way and bit-expanded in-register
(no bool plane ever materializes), then ``fanout`` rounds of
argmin-extract over the priority row.  ``jnp.argmin`` takes the first
occurrence on ties, matching ``lax.top_k``'s lower-index-first rule on
the negated matrix, so this lowering is bitwise the XLA one given the
same priorities — tests pin that in interpret mode.

Priorities are drawn by the CALLER with ``jax.random`` (ops.py): the
kernel is deterministic given its inputs, which is what keeps TPU and
CPU serving bitwise-identical for a fixed PRNG key.

Sizing: seeds are tiled ``st ≤ 128`` per grid step; the priority tile
(st, W) f32 and one (1, W) window row live in VMEM (W = bucketed window,
f32 tile ≤ 128·1024·4 B at the largest realistic bucket); ``start``/
``deg`` are scalar-prefetched in SMEM; DST and the edge words stay in
ANY/HBM and are sliced per seed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitplane

DEFAULT_ST = 128


def _select_kernel(start_ref, deg_ref, pri_ref, dst_ref, ew_ref,
                   nbr_ref, eid_ref, msk_ref, *,
                   st: int, W: int, wt: int, fanout: int):
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    neg1 = jnp.full((1, 1), -1, jnp.int32)

    def seed_body(i, carry):
        s0 = start_ref[0, i]
        dg = deg_ref[0, i]
        win = pl.load(dst_ref, (pl.dslice(s0, W),))[None, :]  # (1, W) DMA
        # packed-word window covering bits [s0, s0+W): wt words starting at
        # word s0>>5; lane l is bit b = (s0 & 31) + l of that window
        wwin = pl.load(ew_ref, (pl.dslice(s0 >> 5, wt),))
        b = (s0 & 31) + lane
        bit = jnp.zeros((1, W), jnp.int32)
        for wi in range(wt):  # static unroll — wt = W//32 + 1
            word = wwin[wi]
            bit = bit | jnp.where(
                (b >> 5) == wi,
                ((word >> (b & 31).astype(jnp.uint32)) &
                 jnp.uint32(1)).astype(jnp.int32),
                0)
        allowed = (lane < dg) & (bit == 1)
        pri = pl.load(pri_ref, (pl.dslice(i, 1), slice(None)))  # (1, W)
        pri = jnp.where(allowed, pri, jnp.float32(jnp.inf))
        for k in range(fanout):  # static unroll: argmin-extract rounds
            v = jnp.min(pri)
            idx = jnp.argmin(pri).astype(jnp.int32)  # first-occurrence ties
            hit = lane == idx
            ok = v < jnp.float32(jnp.inf)
            nbr = jnp.sum(jnp.where(hit, win, 0))  # win[idx], gather-free
            pl.store(nbr_ref, (pl.dslice(i, 1), pl.dslice(k, 1)),
                     jnp.where(ok, nbr, -1).reshape(1, 1))
            pl.store(eid_ref, (pl.dslice(i, 1), pl.dslice(k, 1)),
                     jnp.where(ok, s0 + idx, neg1[0, 0]).reshape(1, 1))
            pl.store(msk_ref, (pl.dslice(i, 1), pl.dslice(k, 1)),
                     ok.astype(jnp.int32).reshape(1, 1))
            pri = jnp.where(hit, jnp.float32(jnp.inf), pri)
        return carry

    jax.lax.fori_loop(0, st, seed_body, 0)


@functools.partial(jax.jit, static_argnames=("m", "fanout", "interpret"))
def window_select_pallas(start: jax.Array, deg: jax.Array, dst: jax.Array,
                         ew_words, pri: jax.Array, *, m: int, fanout: int,
                         interpret=None):
    """start/deg: (S,) int32 window offsets + effective degrees (0 for pad
    seeds); dst: (m,) int32; ew_words: packed uint32 edge bitmap or None
    (= all allowed); pri: (S, W) f32.  Returns (nbrs, eids, mask) shaped
    (S, fanout), -1 sentinels in masked slots — the ``_window_select``
    contract."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, W = pri.shape
    wt = W // 32 + 1
    st = min(DEFAULT_ST, S)
    assert S % st == 0, (S, st)
    # pad DST and the word plane so the fixed-size window DMAs of the last
    # edges stay in bounds (padding is never selected: lane < deg excludes it)
    dst_pad = jnp.concatenate([dst.astype(jnp.int32),
                               jnp.zeros((W,), jnp.int32)])
    nw = bitplane.n_words(max(m, 1))
    if ew_words is None:
        ew = jnp.full((nw,), 0xFFFFFFFF, jnp.uint32)
    else:
        ew = ew_words.astype(jnp.uint32)
    ew_pad = jnp.concatenate([ew, jnp.zeros((wt,), jnp.uint32)])

    nbrs, eids, msk = pl.pallas_call(
        functools.partial(_select_kernel, st=st, W=W, wt=wt, fanout=fanout),
        grid=(S // st,),
        in_specs=[
            pl.BlockSpec((1, st), lambda b: (0, b), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, st), lambda b: (0, b), memory_space=pltpu.SMEM),
            pl.BlockSpec((st, W), lambda b: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # DST stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # packed words in HBM
        ],
        out_specs=[
            pl.BlockSpec((st, fanout), lambda b: (b, 0)),
            pl.BlockSpec((st, fanout), lambda b: (b, 0)),
            pl.BlockSpec((st, fanout), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, fanout), jnp.int32),
            jax.ShapeDtypeStruct((S, fanout), jnp.int32),
            jax.ShapeDtypeStruct((S, fanout), jnp.int32),
        ],
        interpret=interpret,
    )(start.reshape(1, S).astype(jnp.int32),
      deg.reshape(1, S).astype(jnp.int32),
      pri.astype(jnp.float32), dst_pad, ew_pad)
    return nbrs, eids, msk.astype(bool)
