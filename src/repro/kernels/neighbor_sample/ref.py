"""Numpy oracle for the neighbor_sample kernels.

Two layers of reference, matching how the device path splits randomness
from selection:

* :func:`select_by_priority_ref` — EXACT selection given a priority
  matrix: per seed, the ``fanout`` allowed window lanes with the smallest
  priorities, ascending, ties to the lower lane.  The device path draws
  its priorities with ``jax.random`` and selects with ``lax.top_k`` over
  the negated matrix; feeding the same priorities here must reproduce the
  device output bit for bit (tests/test_sample.py pins it), so the oracle
  checks the *algorithm*, not the RNG.
* :func:`check_sample` — structural validation of any sampled output
  against the CSR + edge filter, independent of randomness: every
  unmasked slot is a real, filter-allowed edge of its seed; no slot is
  sampled twice (without replacement); the number of unmasked slots is
  exactly ``min(fanout, filtered degree)``; masked slots hold the -1
  sentinel.  This is what the benches verify before timing.
"""
from __future__ import annotations

import numpy as np

__all__ = ["filtered_degrees", "select_by_priority_ref", "check_sample"]


def filtered_degrees(seg: np.ndarray, edge_ok, seeds: np.ndarray) -> np.ndarray:
    """Per-seed count of adjacency-window edges the filter allows."""
    seg = np.asarray(seg)
    seeds = np.asarray(seeds)
    out = np.zeros(seeds.shape[0], np.int64)
    for i, s in enumerate(seeds):
        lo, hi = int(seg[s]), int(seg[s + 1])
        if edge_ok is None:
            out[i] = hi - lo
        else:
            out[i] = int(np.asarray(edge_ok[lo:hi]).sum())
    return out


def select_by_priority_ref(seg, dst, seeds, edge_ok, priorities, fanout: int):
    """Reference selection: smallest-priority allowed lanes per seed.

    ``priorities`` is (S, W) float; lane w of seed i corresponds to global
    edge ``seg[seeds[i]] + w`` while in window.  Returns ``(nbrs, eids,
    mask)`` shaped (S, fanout): global neighbor ids / edge ids (-1 where
    masked), and the validity mask.
    """
    seg = np.asarray(seg)
    dst = np.asarray(dst)
    seeds = np.asarray(seeds)
    pri = np.asarray(priorities, np.float64)
    S, W = pri.shape
    nbrs = np.full((S, fanout), -1, np.int64)
    eids = np.full((S, fanout), -1, np.int64)
    mask = np.zeros((S, fanout), bool)
    for i in range(S):
        s = int(seeds[i])
        lo, hi = int(seg[s]), int(seg[s + 1])
        deg = min(hi - lo, W)
        lanes = [
            w for w in range(deg)
            if edge_ok is None or bool(np.asarray(edge_ok[lo + w]))
        ]
        # stable sort on priority → ties break to the lower lane, matching
        # lax.top_k's documented lower-index-first tie rule on -priority
        lanes.sort(key=lambda w: (pri[i, w], w))
        for k, w in enumerate(lanes[:fanout]):
            eids[i, k] = lo + w
            nbrs[i, k] = dst[lo + w]
            mask[i, k] = True
    return nbrs, eids, mask


def check_sample(seg, dst, seeds, edge_ok, fanout: int,
                 nbrs, eids, mask) -> None:
    """Raise AssertionError unless (nbrs, eids, mask) is a valid
    without-replacement uniform-candidate sample of the filtered
    adjacency (module docstring).  RNG-independent."""
    seg = np.asarray(seg)
    dst = np.asarray(dst)
    seeds = np.asarray(seeds)
    nbrs = np.asarray(nbrs)
    eids = np.asarray(eids)
    mask = np.asarray(mask)
    want = np.minimum(filtered_degrees(seg, edge_ok, seeds), fanout)
    got = mask.sum(axis=1)
    assert (got == want).all(), (
        f"sampled-slot counts {got.tolist()} != min(fanout, filtered deg) "
        f"{want.tolist()}")
    for i, s in enumerate(seeds):
        lo, hi = int(seg[s]), int(seg[s + 1])
        live = eids[i][mask[i]]
        assert len(set(live.tolist())) == len(live), (
            f"seed {s}: duplicate edges sampled: {live.tolist()}")
        for e in live.tolist():
            assert lo <= e < hi, f"seed {s}: edge {e} outside window [{lo},{hi})"
            if edge_ok is not None:
                assert bool(np.asarray(edge_ok[e])), (
                    f"seed {s}: filtered-out edge {e} sampled")
        assert (nbrs[i][mask[i]] == dst[live]).all(), (
            f"seed {s}: neighbor ids disagree with DST at sampled edges")
        assert (nbrs[i][~mask[i]] == -1).all(), (
            f"seed {s}: masked slots must hold -1, got {nbrs[i][~mask[i]]}")
        assert (eids[i][~mask[i]] == -1).all()
