"""Batched property-filtered neighbor sampling — one launch per seed batch.

The serving-path sampler (docs/ARCHITECTURE.md §15): gather the SEG/DST
adjacency window of every seed in a batch, reject edges the packed edge
mask disallows by reading its uint32 words DIRECTLY (bit ``e & 31`` of
word ``e >> 5`` — the ``core.bitplane`` layout, no bool materialization),
draw one uniform priority per window lane, and keep the ``fanout``
smallest-priority allowed lanes per seed.  Order statistics of i.i.d.
uniforms make that a uniform without-replacement sample of the filtered
adjacency; degree-0 (or fully filtered) seeds come out fully masked, and
seeds with filtered degree ≤ fanout keep every allowed edge exactly once.

Shape discipline mirrors ``bitmap_query``: the jitted programs specialize
on (request count R, seed capacity S, window W, fanout), so all three are
bucketed — R through :func:`bucketed_requests` (the scheduler's coalesced
group), S through :func:`bucketed_seeds`, W through
:func:`bucketed_window` (graph max-degree, static per graph).  Compile
count across QPS traffic is therefore bounded by the bucket grids, which
:func:`sample_compile_count` (backed by the ``pg_sample_compiles``
process counter) makes assertable.

Lowerings: the selection math is plain XLA (`lax.top_k` over negated
priorities — ties break to the lower lane); on TPU the single-request
window gather+select can run the Pallas kernel
(``kernel.window_select_pallas``), which tests pin bitwise against the
XLA lowering in interpret mode.  The batched/vmapped entries always use
the XLA lowering (one fused program; composes with GSPMD-sharded
``seg``/``dst`` under a mesh, where sampling stays owner-device local —
each seed's window gather touches only the shard holding its slice).

Randomness contract: callers pass explicit PRNG keys; every program
derives its uniforms ONLY from the per-request key (row r of a batched
launch uses key r and nothing else), so a request samples bitwise
identically whether it runs alone or coalesced into any batch — the
parity the service tests rely on.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane
from repro.obs.metrics import GLOBAL as _OBS
from repro.obs.metrics import enabled as _obs_enabled

__all__ = [
    "SEED_BUCKET_MIN",
    "WINDOW_BUCKET_MIN",
    "REQUEST_BUCKETS",
    "bucketed_requests",
    "bucketed_seeds",
    "bucketed_window",
    "neighbor_sample",
    "neighbor_sample_batched",
    "neighbor_sample_from_words",
    "sample_compile_count",
    "sample_embed",
]

SEED_BUCKET_MIN = 16  # smallest seed-capacity bucket (khop_csr's floor)
WINDOW_BUCKET_MIN = 8  # smallest adjacency-window bucket
REQUEST_BUCKETS = (1, 2, 4, 8, 16, 32)  # coalesced-group R buckets

_M_COMPILES = _OBS.counter(
    "pg_sample_compiles", "distinct neighbor_sample program specializations")
_M_LAUNCHES = _OBS.counter(
    "pg_sample_launches", "neighbor_sample device launches")
_SEEN_KEYS: set = set()


def _pow2_bucket(size: int, floor: int) -> int:
    cap = floor
    while cap < size:
        cap <<= 1
    return cap


def bucketed_seeds(s: int) -> int:
    """Seed-batch capacity bucket: next power of two ≥ s (min 16)."""
    return _pow2_bucket(max(int(s), 1), SEED_BUCKET_MIN)


def bucketed_window(w: int) -> int:
    """Adjacency-window bucket: next power of two ≥ w (min 8).  Static per
    graph — callers pass max(graph max-degree, fanout)."""
    return _pow2_bucket(max(int(w), 1), WINDOW_BUCKET_MIN)


def bucketed_requests(r: int) -> int:
    """Coalesced request-count bucket (``bucketed_q`` scheme: fixed grid,
    multiples of the top bucket beyond it)."""
    if r < 1:
        raise ValueError(f"r must be ≥ 1, got {r}")
    for b in REQUEST_BUCKETS:
        if r <= b:
            return b
    top = REQUEST_BUCKETS[-1]
    return -(-r // top) * top


def _note_launch(kind: str, shape_key: tuple) -> None:
    """Host-side compile/launch accounting: a (kind, static shapes) tuple
    not seen before in this process is a new XLA specialization."""
    if not _obs_enabled():
        return
    _M_LAUNCHES.inc()
    key = (kind,) + shape_key
    if key not in _SEEN_KEYS:
        _SEEN_KEYS.add(key)
        _M_COMPILES.inc()


def sample_compile_count() -> int:
    """Distinct sampler program specializations this process has seen."""
    return len(_SEEN_KEYS)


# --------------------------------------------------------------- core select
def _bit_at(words: jax.Array, idx: jax.Array) -> jax.Array:
    """Read bit ``idx`` of a packed uint32 word vector (bitplane layout)."""
    w = words[idx >> 5]
    return ((w >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)).astype(bool)


def _window_select(seg, dst, m: int, n: int, seeds, valid, ew_words, u,
                   fanout: int):
    """The selection core (traceable): per seed, gather its SEG window,
    mask disallowed lanes to +inf priority, keep the ``fanout`` smallest.

    seeds (S,) int32 in [0, n) (pad rows arbitrary but ``valid`` False),
    u (S, W) f32 uniforms, ew_words packed (ceil(m/32),) uint32 or None.
    Returns (nbrs, eids, mask) each (S, fanout); -1 in masked slots.
    """
    W = u.shape[1]
    sidx = jnp.clip(seeds, 0, max(n - 1, 0))
    start = seg[sidx]
    deg = seg[sidx + 1] - start
    lane = jnp.arange(W, dtype=jnp.int32)
    eidx = start[:, None] + lane[None, :]
    in_win = (lane[None, :] < deg[:, None]) & valid[:, None]
    eidx_c = jnp.clip(eidx, 0, max(m - 1, 0))
    allowed = in_win if ew_words is None else in_win & _bit_at(ew_words, eidx_c)
    pri = jnp.where(allowed, u, jnp.float32(jnp.inf))
    neg, sel = jax.lax.top_k(-pri, fanout)  # ties → lower lane first
    ok = neg > jnp.float32(-jnp.inf)
    sel_e = jnp.take_along_axis(eidx_c, sel, axis=1)
    nbrs = jnp.where(ok, dst[sel_e], -1)
    eids = jnp.where(ok, sel_e, -1)
    return nbrs, eids, ok


@partial(jax.jit, static_argnames=("m", "n", "fanout", "window", "use_pallas"))
def _sample_one(seg, dst, seeds, valid, ew_words, key, *, m: int, n: int,
                fanout: int, window: int, use_pallas: bool = False):
    u = jax.random.uniform(key, (seeds.shape[0], window))
    if use_pallas:
        from repro.kernels.neighbor_sample.kernel import window_select_pallas

        sidx = jnp.clip(seeds, 0, max(n - 1, 0))
        start = seg[sidx]
        deg = jnp.where(valid, seg[sidx + 1] - start, 0)
        return window_select_pallas(
            start, deg, dst, ew_words, u, m=m, fanout=fanout)
    return _window_select(seg, dst, m, n, seeds, valid, ew_words, u, fanout)


@partial(jax.jit, static_argnames=("m", "n", "fanout", "window"))
def _sample_many(seg, dst, seeds, valid, ew_words, keys, *, m: int, n: int,
                 fanout: int, window: int):
    """(R, S) stacked requests → (R, S, fanout) outputs, ONE launch.  Row r
    reads only its own key (and its own edge words when per-request
    filters differ), so each row is bitwise the row's solo launch."""

    def row(sd, vl, ew, k):
        u = jax.random.uniform(k, (sd.shape[0], window))
        return _window_select(seg, dst, m, n, sd, vl, ew, u, fanout)

    if ew_words is None:
        return jax.vmap(lambda sd, vl, k: row(sd, vl, None, k))(
            seeds, valid, keys)
    return jax.vmap(row)(seeds, valid, ew_words, keys)


@partial(jax.jit, static_argnames=("m", "n", "cap", "fanout", "window"))
def _sample_from_words(seg, dst, seed_words, ew_words, key, *, m: int, n: int,
                       cap: int, fanout: int, window: int):
    """Packed-seed entry: the uint32 seed bitmap feeds the window gather
    inside ONE program — bit-expansion and index extraction never leave
    the device (the §15 seed-bitmap handoff)."""
    bits = bitplane.unpack_mask(seed_words, n)
    idx = jnp.nonzero(bits, size=cap, fill_value=n)[0].astype(jnp.int32)
    valid = idx < n
    u = jax.random.uniform(key, (cap, window))
    nbrs, eids, ok = _window_select(
        seg, dst, m, n, idx, valid, ew_words, u, fanout)
    return idx, valid, nbrs, eids, ok


@partial(jax.jit, static_argnames=("m", "n", "fanout", "window"))
def _sample_embed_one(seg, dst, seeds, valid, ew_words, key, table, *,
                      m: int, n: int, fanout: int, window: int):
    """Fused sample+lookup: the sampled neighbor ids index an embedding
    table and mean-pool inside the SAME program — a recsys request is one
    device program instead of sample → host → embedding_bag."""
    u = jax.random.uniform(key, (seeds.shape[0], window))
    nbrs, eids, ok = _window_select(
        seg, dst, m, n, seeds, valid, ew_words, u, fanout)
    rows = table[jnp.clip(nbrs, 0, table.shape[0] - 1)]  # (S, fanout, D)
    w = ok[..., None].astype(table.dtype)
    cnt = jnp.maximum(ok.sum(axis=-1, keepdims=True), 1).astype(table.dtype)
    bags = jnp.sum(rows * w, axis=1) / cnt  # (S, D); all-masked seeds → 0
    return bags, nbrs, eids, ok


# ---------------------------------------------------------- public wrappers
def _pad_seeds(seeds, cap: int) -> Tuple[jax.Array, jax.Array]:
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    s = int(seeds.shape[0])
    if s > cap:
        raise ValueError(f"{s} seeds exceed capacity {cap}")
    valid = jnp.arange(cap, dtype=jnp.int32) < s
    if s < cap:
        seeds = jnp.concatenate([seeds, jnp.zeros((cap - s,), jnp.int32)])
    return seeds, valid


def _window_for(max_deg: Optional[int], seg, fanout: int) -> int:
    if max_deg is None or max_deg < 0:
        max_deg = int(np.max(np.asarray(seg[1:]) - np.asarray(seg[:-1]),
                             initial=0))
    return bucketed_window(max(int(max_deg), int(fanout)))


def neighbor_sample(seg, dst, n: int, m: int, seeds, key, *, fanout: int,
                    edge_words=None, max_deg: Optional[int] = None,
                    use_pallas: bool = False):
    """Sample ≤ ``fanout`` filtered out-neighbors per seed, one launch.

    ``edge_words``: packed (ceil(m/32),) uint32 edge-allowed bitmap (None
    = every edge).  Returns (nbrs, eids, mask) shaped (S_cap, fanout) with
    S_cap = ``bucketed_seeds(len(seeds))``; rows past the real seed count
    are fully masked.  ``use_pallas`` opts the TPU window kernel in (off
    by default; the XLA lowering is the canonical path and the two are
    pinned bitwise)."""
    cap = bucketed_seeds(np.asarray(seeds).size)
    window = _window_for(max_deg, seg, fanout)
    sd, valid = _pad_seeds(seeds, cap)
    _note_launch("one", (cap, window, int(fanout), edge_words is not None,
                         bool(use_pallas), n, m))
    return _sample_one(
        seg, dst, sd, valid,
        None if edge_words is None else jnp.asarray(edge_words),
        key, m=m, n=n, fanout=int(fanout), window=window,
        use_pallas=bool(use_pallas))


def neighbor_sample_batched(seg, dst, n: int, m: int, seeds, valid, keys, *,
                            fanout: int, edge_words=None,
                            max_deg: Optional[int] = None):
    """Coalesced entry: R stacked requests → ONE launch (module docstring).

    ``seeds``/``valid``: (R, S_cap) padded id rows; ``keys``: (R, 2)
    uint32 per-request PRNG keys; ``edge_words``: (R, W_m) per-request
    packed edge filters or None.  R must already be padded to
    ``bucketed_requests`` (pad rows: valid all-False, any key).  Returns
    (nbrs, eids, mask) shaped (R, S_cap, fanout)."""
    seeds = jnp.asarray(seeds, jnp.int32)
    R, S = int(seeds.shape[0]), int(seeds.shape[1])
    window = _window_for(max_deg, seg, fanout)
    _note_launch("many", (R, S, window, int(fanout), edge_words is not None,
                          n, m))
    return _sample_many(
        seg, dst, seeds, jnp.asarray(valid),
        None if edge_words is None else jnp.asarray(edge_words),
        jnp.asarray(keys), m=m, n=n, fanout=int(fanout), window=window)


def neighbor_sample_from_words(seg, dst, n: int, m: int, seed_words,
                               seed_count: int, key, *, fanout: int,
                               edge_words=None,
                               max_deg: Optional[int] = None):
    """Packed-seed entry: seeds arrive as a uint32 bitmap (the ``match()``
    combine's output words); ``seed_count`` (its popcount, the one scalar
    the host reads) picks the capacity bucket.  Returns (seeds, valid,
    nbrs, eids, mask) with S_cap = ``bucketed_seeds(seed_count)``."""
    cap = bucketed_seeds(seed_count)
    window = _window_for(max_deg, seg, fanout)
    _note_launch("words", (cap, window, int(fanout), edge_words is not None,
                           n, m))
    return _sample_from_words(
        seg, dst, jnp.asarray(seed_words),
        None if edge_words is None else jnp.asarray(edge_words),
        key, m=m, n=n, cap=cap, fanout=int(fanout), window=window)


def sample_embed(seg, dst, n: int, m: int, seeds, key, table, *, fanout: int,
                 edge_words=None, max_deg: Optional[int] = None):
    """Fused ``sample+lookup`` verb: sample filtered neighbors AND
    mean-pool their embedding rows in one program.  ``table``: (V, D)
    with V ≥ n.  Returns (bags (S_cap, D), nbrs, eids, mask); bags of
    fully-masked seeds are zero."""
    cap = bucketed_seeds(np.asarray(seeds).size)
    window = _window_for(max_deg, seg, fanout)
    sd, valid = _pad_seeds(seeds, cap)
    _note_launch("embed", (cap, window, int(fanout), edge_words is not None,
                           n, m, int(table.shape[-1])))
    return _sample_embed_one(
        seg, dst, sd, valid,
        None if edge_words is None else jnp.asarray(edge_words),
        key, jnp.asarray(table), m=m, n=n, fanout=int(fanout), window=window)
