"""Batched property-filtered neighborhood sampling (docs/ARCHITECTURE.md §15)."""
from repro.kernels.neighbor_sample.ops import (
    SEED_BUCKET_MIN,
    WINDOW_BUCKET_MIN,
    bucketed_requests,
    bucketed_seeds,
    bucketed_window,
    neighbor_sample,
    neighbor_sample_batched,
    neighbor_sample_from_words,
    sample_compile_count,
    sample_embed,
)

__all__ = [
    "SEED_BUCKET_MIN",
    "WINDOW_BUCKET_MIN",
    "bucketed_requests",
    "bucketed_seeds",
    "bucketed_window",
    "neighbor_sample",
    "neighbor_sample_batched",
    "neighbor_sample_from_words",
    "sample_compile_count",
    "sample_embed",
]
