from repro.data.graph import build_triplets, synthetic_gc_batch, synthetic_graph_batch
from repro.data.lm import lm_batch
from repro.data.recsys import dlrm_batch

__all__ = ["build_triplets", "synthetic_gc_batch", "synthetic_graph_batch", "lm_batch",
           "dlrm_batch"]
