"""Graph data pipeline: GraphBatch/GCBatch builders for every GNN shape.

Produces concrete batches (smoke tests, examples) mirroring exactly the
ShapeDtypeStructs that ``configs.input_specs`` hands the dry-run, including
DimeNet triplet lists (built from DI adjacency, capped at 8×E) and GraphCast's
derived mesh sizes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.di import build_di
from repro.models.gnn_common import GraphBatch
from repro.models.graphcast import GCBatch

__all__ = ["synthetic_graph_batch", "build_triplets", "synthetic_gc_batch", "graphcast_sizes",
           "TRIPLET_CAP_FACTOR"]

TRIPLET_CAP_FACTOR = 8


def build_triplets(src: np.ndarray, dst: np.ndarray, cap: int) -> np.ndarray:
    """(kj_edge, ji_edge, valid) triplet list: edges (k→j), (j→i), k≠i.

    Built from the DI reverse index: for each edge e2=(j→i), its partners are
    the in-edges of j.  Capped/padded to ``cap`` rows (DESIGN.md policy)."""
    e = len(src)
    by_dst = {}
    for i, d in enumerate(dst):
        by_dst.setdefault(int(d), []).append(i)
    rows = []
    for e2 in range(e):
        j, i = int(src[e2]), int(dst[e2])
        for e1 in by_dst.get(j, ()):
            if int(src[e1]) != i:
                rows.append((e1, e2, 1))
                if len(rows) >= cap:
                    break
        if len(rows) >= cap:
            break
    while len(rows) < cap:
        rows.append((0, 0, 0))
    return np.asarray(rows, np.int32)


def synthetic_graph_batch(
    *, n_nodes: int, n_edges: int, d_feat: Optional[int] = None, n_classes: int = 7,
    n_graphs: int = 1, with_pos: bool = False, n_species: int = 16,
    with_triplets: bool = False, seed: int = 0,
) -> GraphBatch:
    rng = np.random.default_rng(seed)
    src = np.sort(rng.integers(0, n_nodes, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((n_nodes, d_feat), np.float32)) if d_feat else None
    pos = jnp.asarray(rng.standard_normal((n_nodes, 3), np.float32)) if with_pos else None
    species = jnp.asarray(rng.integers(0, n_species, n_nodes, dtype=np.int32)) if with_pos else None
    tri = None
    if with_triplets:
        tri = jnp.asarray(build_triplets(src, dst, TRIPLET_CAP_FACTOR * n_edges))
    if n_graphs > 1:
        gid = np.sort(rng.integers(0, n_graphs, n_nodes)).astype(np.int32)
        labels = jnp.asarray(rng.standard_normal(n_graphs, np.float32))
    else:
        gid = np.zeros(n_nodes, np.int32)
        labels = (jnp.asarray(rng.standard_normal(1, np.float32)) if with_pos
                  else jnp.asarray(rng.integers(0, n_classes, n_nodes, dtype=np.int32)))
    return GraphBatch(
        x=x, pos=pos, species=species,
        edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst), edge_attr=tri,
        edge_mask=jnp.ones(n_edges, bool), node_mask=jnp.ones(n_nodes, bool),
        labels=labels, graph_ids=jnp.asarray(gid),
        n_nodes=n_nodes, n_edges=n_edges, n_graphs=n_graphs,
    )


def graphcast_sizes(n_nodes: int, n_edges: int) -> Tuple[int, int, int, int, int]:
    """(n_grid, n_mesh, n_g2m, n_mesh_e, n_m2g) — DESIGN.md §4 mapping."""
    n_mesh = max(8, n_nodes // 4)
    return n_nodes, n_mesh, n_edges, max(8, n_edges // 2), n_edges


def synthetic_gc_batch(*, n_nodes: int, n_edges: int, n_vars: int, d_edge: int = 4,
                       seed: int = 0) -> GCBatch:
    ng, nm, ne_g2m, ne_mesh, ne_m2g = graphcast_sizes(n_nodes, n_edges)
    rng = np.random.default_rng(seed)
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s, np.float32))
    ids = lambda hi, n: jnp.asarray(rng.integers(0, hi, n, dtype=np.int32))
    return GCBatch(
        grid_x=f32(ng, n_vars),
        g2m_src=ids(ng, ne_g2m), g2m_dst=ids(nm, ne_g2m), g2m_attr=f32(ne_g2m, d_edge),
        mesh_src=ids(nm, ne_mesh), mesh_dst=ids(nm, ne_mesh), mesh_attr=f32(ne_mesh, d_edge),
        m2g_src=ids(nm, ne_m2g), m2g_dst=ids(ng, ne_m2g), m2g_attr=f32(ne_m2g, d_edge),
        targets=f32(ng, n_vars),
        n_grid=ng, n_mesh=nm, n_g2m=ne_g2m, n_mesh_e=ne_mesh, n_m2g=ne_m2g,
    )
