"""Synthetic LM data pipeline — deterministic, step-addressed token batches.

Step-addressed determinism is the property fault-tolerant training needs: the
batch for global step k is a pure function of (seed, k), so a job restored at
step k re-sees exactly the data it would have seen — no stateful iterator to
checkpoint.  (A real deployment swaps in a tokenized corpus reader with the
same step→batch contract.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lm_batch"]


def lm_batch(step: int, *, batch: int, seq: int, vocab: int, seed: int = 0):
    """Returns {tokens, labels} — labels are next-token shifted."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    toks = jax.random.randint(key, (batch, seq + 1), 0, vocab, dtype=jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
