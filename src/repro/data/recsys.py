"""Synthetic recsys pipeline — step-addressed DLRM batches (Criteo-like)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dlrm_batch"]


def dlrm_batch(step: int, *, batch: int, n_dense: int = 13, n_sparse: int = 26,
               vocab: int = 1_000_000, multi_hot: int = 1, seed: int = 0):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "dense": jax.random.normal(k1, (batch, n_dense), jnp.float32),
        "sparse": jax.random.randint(k2, (batch, n_sparse, multi_hot), 0, vocab, jnp.int32),
        "labels": jax.random.bernoulli(k3, 0.3, (batch,)).astype(jnp.int32),
    }
