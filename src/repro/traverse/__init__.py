"""repro.traverse — the semiring frontier engine (docs/ARCHITECTURE.md §10, §12).

One jitted, masked relax primitive, generalized over a configurable
semiring (⊕ combine, ⊗ extend), that the pattern matcher's
variable-length hops (``-[:rel*1..k]->``, ``*``), the Boolean
reachability analytics (``PropGraph.khop`` / ``components``) and the
numeric analytics (``shortest_paths`` / ``pagerank`` / ``communities``)
all execute through: edge-centric relax steps, a CSR small-frontier fast
path, and a shard_map path that ⊕-all-reduces the per-device partial
value vector per step (pmax / pmin / psum).
"""
from repro.traverse.analytics import (
    components_masked,
    label_propagation_masked,
    pagerank_masked,
    pagerank_sharded,
    shortest_paths_masked,
    shortest_paths_sharded,
    single_hop_filters,
)
from repro.traverse.engine import (
    BOOLEAN,
    COUNTING,
    MINLABEL,
    TROPICAL,
    Semiring,
    frontier_step,
    khop_csr,
    khop_mask,
    khop_mask_sharded,
    reach_closure,
    reach_closure_sharded,
    semiring_relax,
    semiring_relax_sharded,
)

__all__ = [
    "Semiring",
    "BOOLEAN",
    "TROPICAL",
    "COUNTING",
    "MINLABEL",
    "semiring_relax",
    "semiring_relax_sharded",
    "frontier_step",
    "khop_mask",
    "khop_csr",
    "khop_mask_sharded",
    "reach_closure",
    "reach_closure_sharded",
    "components_masked",
    "shortest_paths_masked",
    "shortest_paths_sharded",
    "pagerank_masked",
    "pagerank_sharded",
    "label_propagation_masked",
    "single_hop_filters",
]
