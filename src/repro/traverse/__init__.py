"""repro.traverse — the frontier engine (docs/ARCHITECTURE.md §10).

One jitted, masked frontier-expansion primitive that both the pattern
matcher's variable-length hops (``-[:rel*1..k]->``, ``*``) and the
property-aware analytics (``PropGraph.khop`` / ``PropGraph.components``)
execute through: edge-centric bitmap steps, a CSR small-frontier fast
path, and a shard_map path that all-reduces the frontier bitmask per step.
"""
from repro.traverse.analytics import components_masked, single_hop_filters
from repro.traverse.engine import (
    frontier_step,
    khop_csr,
    khop_mask,
    khop_mask_sharded,
    reach_closure,
    reach_closure_sharded,
)

__all__ = [
    "frontier_step",
    "khop_mask",
    "khop_csr",
    "khop_mask_sharded",
    "reach_closure",
    "reach_closure_sharded",
    "components_masked",
    "single_hop_filters",
]
