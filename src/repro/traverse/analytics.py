"""Property-aware analytics over the semiring frontier engine.

The paper's §I workloads (cybersecurity flows, brain networks) are
reachability-shaped: "which hosts are within k ``flows``-hops of a flagged
host", "components of the ``follows`` subgraph".  The Arachne follow-up
work (community detection, weighted analytics) extends the same shape to
numeric semirings.  These run here as clients of
:func:`repro.traverse.engine.semiring_relax` that RESPECT the property
layer: every function takes (or derives from a single-hop pattern, via
``single_hop_filters``) vertex/edge masks and an optional numeric edge
weight, so labels, relationship types and typed-property predicates all
filter the traversal — no subgraph is ever materialized.

Instances (docs/ARCHITECTURE.md §12):

  * ``components_masked``       — (min, select) min-hook label propagation
    + pointer jumping to a fixed point.
  * ``shortest_paths_masked``   — (min, +) tropical Bellman–Ford from a
    seed set over a numeric edge property; unreachable = +inf.
  * ``pagerank_masked``         — (+, ×) power iteration with out-degree
    normalization on the property-filtered subgraph (the §I kernel,
    filter-aware).
  * ``label_propagation_masked``— mode relax (argmax neighbor-label count,
    smallest label breaks ties): synchronous label propagation, the
    community-detection entry point.

``single_hop_filters`` is the shared pattern→masks front door for
``PropGraph.khop`` / ``components`` / ``shortest_paths`` / ``pagerank`` /
``communities``: a node-only or single-hop pattern
(``"(a:host)-[:flows {bytes > 0}]->(b)"``) becomes
(tail mask, head mask, edge mask, direction), the same §VI masks the
query engine composes.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.di import DIGraph
from repro.traverse.engine import (
    COUNTING,
    MINLABEL,
    TROPICAL,
    _all_edges,
    _ends,
    _pad_edges,
    _sharded_relax_fn,
    semiring_relax,
)

__all__ = [
    "components_masked",
    "shortest_paths_masked",
    "shortest_paths_sharded",
    "pagerank_masked",
    "pagerank_sharded",
    "label_propagation_masked",
    "single_hop_filters",
]


@partial(jax.jit, static_argnames=("max_iters",))
def components_masked(
    g: DIGraph,
    vertex_allowed: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    *,
    max_iters: int = 128,
) -> jax.Array:
    """Connected components of the masked subgraph: (n,) int32 labels
    (component id = smallest member vertex id), -1 for vertices outside
    ``vertex_allowed``.  Edges are treated as undirected; an edge
    participates iff its own mask AND both endpoint masks are set.
    The hook step is the (min, select) :data:`MINLABEL` instance of the
    semiring relax, iterated with pointer jumping: O(log n) rounds."""
    n = g.n
    v_ok = jnp.ones((n,), jnp.bool_) if vertex_allowed is None else vertex_allowed
    e_ok = jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed
    e_act = e_ok & v_ok[g.src] & v_ok[g.dst]
    big = jnp.int32(n)  # sentinel: excluded vertices never hook anything
    labels0 = jnp.where(v_ok, jnp.arange(n, dtype=jnp.int32), big)

    def body(state):
        labels, _, it = state
        hook = semiring_relax(g, labels, e_act, MINLABEL, undirected=True)
        new = jnp.minimum(labels, hook)
        # pointer jumping — only real labels (< n) chase; the sentinel
        # would index out of range
        jumped = new[jnp.clip(new, 0, max(n - 1, 0))]
        new = jnp.where(new < n, jumped, new)
        return new, jnp.any(new != labels), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return jnp.where(v_ok, labels, jnp.int32(-1))


# ------------------------------------------------------- shortest paths (min,+)
@partial(jax.jit, static_argnames=("direction", "undirected", "max_iters"))
def shortest_paths_masked(
    g: DIGraph,
    seed_mask: jax.Array,
    weights: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    *,
    direction: int = 1,
    undirected: bool = False,
    max_iters: Optional[int] = None,
) -> jax.Array:
    """Multi-source shortest-path distances over the (min, +) tropical
    semiring: (n,) f32, 0.0 at the seeds, +inf where unreachable.

    Bellman–Ford as a frontier fixed point: each round relaxes every
    allowed edge (``dist' = min(dist, ⊕ dist[tail] + w)``) inside one
    jitted ``while_loop`` with early exit when no distance improves.
    ``weights`` defaults to unit weights (hop counts); masked edges carry
    +inf (the ⊗ absorber), so they never relax.  With non-negative
    weights n-1 rounds always suffice; ``max_iters`` (default n+1) bounds
    the loop so a negative cycle cannot spin it forever."""
    w = (jnp.ones((g.m,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    e_ok = _all_edges(g, edge_allowed)
    ew = jnp.where(e_ok, w, jnp.inf)
    dist0 = jnp.where(seed_mask, jnp.float32(0), jnp.inf)
    bound = (g.n + 1) if max_iters is None else max_iters

    def body(state):
        dist, _, it = state
        new = jnp.minimum(dist, semiring_relax(
            g, dist, ew, TROPICAL, direction=direction, undirected=undirected))
        return new, jnp.any(new != dist), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < bound)

    dist, _, _ = jax.lax.while_loop(
        cond, body, (dist0, jnp.bool_(True), jnp.int32(0)))
    return dist


@lru_cache(maxsize=None)
def _sharded_bellman_fn(mesh, direction: int, undirected: bool):
    """Jitted tropical Bellman–Ford whose relax runs under ``shard_map``:
    per-device partial (n,) distance vectors, ⊕-combined with ONE ``pmin``
    all-reduce per round.  min over f32 is exact, so the result is
    bitwise-identical to the single-device path."""
    from repro.launch.sharding import pg_entity_shards

    step = _sharded_relax_fn(mesh, direction, undirected, TROPICAL)
    p = pg_entity_shards(mesh)

    @partial(jax.jit, static_argnames=("max_iters",))
    def fn(g: DIGraph, dist0, ew, *, max_iters: int):
        tail, head, ew = _pad_edges(g, ew, p, direction, TROPICAL.zero)

        def body(state):
            dist, _, it = state
            new = jnp.minimum(dist, step(tail, head, ew, dist))
            return new, jnp.any(new != dist), it + 1

        def cond(state):
            _, changed, it = state
            return changed & (it < max_iters)

        dist, _, _ = jax.lax.while_loop(
            cond, body, (dist0, jnp.bool_(True), jnp.int32(0)))
        return dist

    return fn


def shortest_paths_sharded(
    g: DIGraph,
    seed_mask: jax.Array,
    weights: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    *,
    mesh,
    direction: int = 1,
    undirected: bool = False,
    max_iters: Optional[int] = None,
) -> jax.Array:
    """``shortest_paths_masked`` with the per-round shard_map/``pmin``
    all-reduce layout; bitwise-identical to the single-device path."""
    w = (jnp.ones((g.m,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    ew = jnp.where(_all_edges(g, edge_allowed), w, jnp.inf)
    dist0 = jnp.where(seed_mask, jnp.float32(0), jnp.inf)
    fn = _sharded_bellman_fn(mesh, direction, undirected)
    bound = (g.n + 1) if max_iters is None else max_iters
    return fn(g, dist0, ew, max_iters=bound)


# ------------------------------------------------------------ pagerank (+, ×)
@partial(jax.jit, static_argnames=("iters", "direction"))
def pagerank_masked(
    g: DIGraph,
    vertex_allowed: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    *,
    damping: float = 0.85,
    iters: int = 20,
    direction: int = 1,
) -> jax.Array:
    """PageRank on the property-filtered subgraph: (n,) f32 ranks, 0.0
    outside ``vertex_allowed``.

    Power iteration whose per-step aggregation is the (+, ×)
    :data:`COUNTING` instance of the semiring relax: contributions
    ``rank[tail] / out_deg[tail] · w[e]`` scatter-⊕ (sum) into the heads.
    Out-degrees are (weight-)summed over ALLOWED edges only; an edge
    participates iff its own mask AND both endpoint masks are set.
    Dangling mass (allowed vertices with no allowed out-edge) and the
    teleport term redistribute over the |allowed| vertex count — with no
    vertex filter this is exactly the classic iteration the §I kernel
    suite ran (``repro.graph.pagerank`` now delegates here)."""
    w = (jnp.ones((g.m,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    if edge_allowed is not None:
        w = jnp.where(edge_allowed, w, jnp.float32(0))
    tail, head = _ends(g, direction)
    if vertex_allowed is not None:
        w = jnp.where(vertex_allowed[tail] & vertex_allowed[head], w,
                      jnp.float32(0))
        n_eff = jnp.maximum(jnp.sum(vertex_allowed.astype(jnp.float32)), 1.0)
        r0 = jnp.where(vertex_allowed, 1.0 / n_eff, 0.0).astype(jnp.float32)
    else:
        n_eff = g.n  # static: keeps the unfiltered formula exactly the
        # pre-semiring graph/algorithms.py iteration (regression-pinned to
        # 1 ulp — the relax scatter fuses differently than segment_sum)
        r0 = jnp.full((g.n,), 1.0 / max(g.n, 1), jnp.float32)
    out_deg = jax.ops.segment_sum(w, tail, g.n)
    inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1e-30), 0.0)

    def step(r, _):
        agg = semiring_relax(g, r * inv_deg, w, COUNTING, direction=direction)
        dangling = jnp.sum(jnp.where(out_deg > 0, 0.0, r))
        r_new = (1 - damping) / n_eff + damping * (agg + dangling / n_eff)
        if vertex_allowed is not None:
            r_new = jnp.where(vertex_allowed, r_new, 0.0)
        return r_new, None

    r, _ = jax.lax.scan(step, r0, None, length=iters)
    return r


@lru_cache(maxsize=None)
def _sharded_pagerank_fn(mesh, direction: int):
    """Jitted power iteration whose aggregation runs under ``shard_map``:
    per-device partial contribution sums, ⊕-combined with ONE ``psum``
    all-reduce per step.  float sums reassociate across device blocks, so
    the sharded ranks agree with the single-device path within tolerance
    (atol), not bitwise — the one non-idempotent ⊕ in the table (§12)."""
    from repro.launch.sharding import pg_entity_shards

    step_relax = _sharded_relax_fn(mesh, direction, False, COUNTING)
    p = pg_entity_shards(mesh)

    @partial(jax.jit, static_argnames=("iters",))
    def fn(g: DIGraph, v_ok, w, damping, *, iters: int):
        tail, head, wp = _pad_edges(g, w, p, direction, COUNTING.zero)
        n_eff = jnp.maximum(jnp.sum(v_ok.astype(jnp.float32)), 1.0)
        out_deg = jax.ops.segment_sum(w, tail[: g.m], g.n)
        inv_deg = jnp.where(out_deg > 0, 1.0 / jnp.maximum(out_deg, 1e-30), 0.0)
        r0 = jnp.where(v_ok, 1.0 / n_eff, 0.0).astype(jnp.float32)

        def step(r, _):
            agg = step_relax(tail, head, wp, r * inv_deg)
            dangling = jnp.sum(jnp.where(out_deg > 0, 0.0, r))
            r_new = (1 - damping) / n_eff + damping * (agg + dangling / n_eff)
            return jnp.where(v_ok, r_new, 0.0), None

        r, _ = jax.lax.scan(step, r0, None, length=iters)
        return r

    return fn


def pagerank_sharded(
    g: DIGraph,
    vertex_allowed: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    weights: Optional[jax.Array] = None,
    *,
    mesh,
    damping: float = 0.85,
    iters: int = 20,
    direction: int = 1,
) -> jax.Array:
    """``pagerank_masked`` with the per-step shard_map/``psum`` all-reduce
    layout; equal to the single-device path within float tolerance."""
    w = (jnp.ones((g.m,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    if edge_allowed is not None:
        w = jnp.where(edge_allowed, w, jnp.float32(0))
    tail, head = _ends(g, direction)
    v_ok = (jnp.ones((g.n,), jnp.bool_) if vertex_allowed is None
            else vertex_allowed)
    if vertex_allowed is not None:
        w = jnp.where(v_ok[tail] & v_ok[head], w, jnp.float32(0))
    fn = _sharded_pagerank_fn(mesh, direction)
    return fn(g, v_ok, w, jnp.float32(damping), iters=iters)


# ------------------------------------------------- label propagation (mode)
@partial(jax.jit, static_argnames=("max_iters",))
def label_propagation_masked(
    g: DIGraph,
    vertex_allowed: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    *,
    max_iters: int = 64,
) -> jax.Array:
    """Community detection by synchronous label propagation: (n,) int32
    community labels, -1 outside ``vertex_allowed``.

    Mode relax under a FIXED deterministic tie-break: every round, every
    allowed vertex simultaneously adopts the most frequent label among its
    allowed neighbors (edges count as undirected, both endpoint masks and
    the edge mask gate participation); ties break toward the SMALLEST
    label; a vertex with no allowed incident edge keeps its label.  Labels
    start as vertex ids, so label ids are always member vertex ids.

    The per-round mode is built from the engine's scatter-⊕ machinery: a
    two-key lexicographic sort groups (head, neighbor label) pairs (no
    fused int key — safe for any n, m < 2**31 with x64 off), a segment
    sum counts each group, then two idempotent ⊕ scatters pick the
    argmax: scatter-max the counts per head, scatter-min the labels that
    achieve them.  Every op is integer, so the result is exact — sharded
    execution (GSPMD over placed arrays) is bitwise-identical; there is
    no hand-written all-reduce path because partial per-device label
    counts would need a cross-device join, not an elementwise ⊕.

    Synchronous updates can oscillate on bipartite structures, so the
    fixed point is capped at ``max_iters`` rounds (the sequential oracle
    in tests/test_semiring.py replays the same rule and cap)."""
    n = g.n
    v_ok = jnp.ones((n,), jnp.bool_) if vertex_allowed is None else vertex_allowed
    e_ok = jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed
    labels0 = jnp.where(v_ok, jnp.arange(n, dtype=jnp.int32), jnp.int32(0))
    if g.m == 0 or n == 0:
        return jnp.where(v_ok, labels0, jnp.int32(-1))
    e_act = e_ok & v_ok[g.src] & v_ok[g.dst]
    # undirected: every edge contributes its tail's label to its head in
    # both orientations
    heads = jnp.concatenate([g.dst, g.src])
    tails = jnp.concatenate([g.src, g.dst])
    ok2 = jnp.concatenate([e_act, e_act])
    n_pos = int(heads.shape[0])

    def body(state):
        labels, _, it = state
        h = jnp.where(ok2, heads, jnp.int32(n))  # masked pairs sort last
        l = jnp.where(ok2, labels[tails], jnp.int32(0))
        sh, sl = jax.lax.sort((h, l), num_keys=2)
        start = jnp.concatenate([
            jnp.ones((1,), jnp.bool_),
            (sh[1:] != sh[:-1]) | (sl[1:] != sl[:-1])])
        sid = jnp.cumsum(start.astype(jnp.int32)) - 1
        valid = sh < n
        group_cnt = jax.ops.segment_sum(
            valid.astype(jnp.int32), sid, num_segments=n_pos,
            indices_are_sorted=True)
        cnt = group_cnt[sid]  # every position carries its group's count
        shc = jnp.clip(sh, 0, max(n - 1, 0))
        best_cnt = jnp.zeros((n,), jnp.int32).at[sh].max(
            jnp.where(valid, cnt, 0), mode="drop")
        is_best = valid & (cnt == best_cnt[shc])
        best_lab = jnp.full((n,), n, jnp.int32).at[sh].min(
            jnp.where(is_best, sl, jnp.int32(n)), mode="drop")
        new = jnp.where(best_lab < n, best_lab, labels)
        return new, jnp.any(new != labels), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return jnp.where(v_ok, labels, jnp.int32(-1))


def single_hop_filters(
    pg, pattern
) -> Tuple[Optional[jax.Array], Optional[jax.Array], Optional[jax.Array], int]:
    """Derive traversal filters from a node-only or single-hop pattern.

    Returns ``(tail_mask, head_mask, edge_mask, direction)`` — each mask
    ``None`` when unconstrained.  For ``(a:x {p})-[:r {q}]->(b:y)``: an
    edge is traversable iff it holds ``r`` and satisfies ``q``, its tail
    (in traversal order — ``<-[...]-`` flips it) matches ``a`` and its
    head matches ``b``.  A node-only pattern constrains BOTH endpoints
    (traversal confined to matching vertices).  Multi-hop and
    variable-length patterns are rejected: k-hop/components/shortest
    paths take their step structure from ``k``/the fixed point, not from
    the pattern — this is the ``shortestPath()``-style hook (a path
    predicate wraps a single-hop step pattern, never a chain).
    """
    from repro.query import parse
    from repro.query.planner import validate_pattern

    if pattern is None:
        return None, None, None, 1
    pat = parse(pattern) if isinstance(pattern, str) else pattern
    if pat.hops > 1:
        raise ValueError(
            f"khop/components take a node-only or single-hop filter pattern, "
            f"got {pat.hops} hops in {pat.to_text()!r}")
    validate_pattern(pat)  # plan-time contract: string predicates etc.

    def node_mask(node):
        mask = None
        if node.labels:
            mask = pg.query_labels(list(node.labels))
        for p in node.predicates:
            pm = pg.vertex_predicate_mask(p.name, p.op, p.value)
            mask = pm if mask is None else mask & pm
        return mask

    if pat.hops == 0:
        vm = node_mask(pat.nodes[0])
        return vm, vm, None, 1

    edge = pat.edges[0]
    if not edge.is_fixed:
        raise ValueError(
            f"variable-length hop {edge.to_text()!r} in a khop/components "
            "filter: the traversal depth comes from k / the fixed point, "
            "use a plain single-hop filter")
    em = None
    if edge.rels:
        em = pg.query_relationships(list(edge.rels))
    for p in edge.predicates:
        pm = pg.edge_predicate_mask(p.name, p.op, p.value)
        em = pm if em is None else em & pm
    return node_mask(pat.nodes[0]), node_mask(pat.nodes[1]), em, edge.direction
