"""Property-aware analytics over the frontier engine.

The paper's §I workloads (cybersecurity flows, brain networks) are
reachability-shaped: "which hosts are within k ``flows``-hops of a flagged
host", "components of the ``follows`` subgraph".  These run here as
frontier-engine clients that RESPECT the property layer: every function
takes (or derives from a single-hop pattern) vertex/edge masks, so labels,
relationship types and typed-property predicates all filter the traversal
— no subgraph is ever materialized.

``components_masked`` is the min-label generalization of the Boolean
frontier step: the same edge-centric relax, over the (min, ≤) semiring
instead of (OR, AND), iterated with pointer jumping to a fixed point.

``single_hop_filters`` is the shared pattern→masks front door for
``PropGraph.khop`` / ``PropGraph.components``: a node-only or single-hop
pattern (``"(a:host)-[:flows {bytes > 0}]->(b)"``) becomes
(tail mask, head mask, edge mask, direction), the same §VI masks the
query engine composes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.di import DIGraph

__all__ = ["components_masked", "single_hop_filters"]


@partial(jax.jit, static_argnames=("max_iters",))
def components_masked(
    g: DIGraph,
    vertex_allowed: Optional[jax.Array] = None,
    edge_allowed: Optional[jax.Array] = None,
    *,
    max_iters: int = 128,
) -> jax.Array:
    """Connected components of the masked subgraph: (n,) int32 labels
    (component id = smallest member vertex id), -1 for vertices outside
    ``vertex_allowed``.  Edges are treated as undirected; an edge
    participates iff its own mask AND both endpoint masks are set.
    Min-hook label propagation + pointer jumping: O(log n) rounds."""
    n = g.n
    v_ok = jnp.ones((n,), jnp.bool_) if vertex_allowed is None else vertex_allowed
    e_ok = jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed
    e_act = e_ok & v_ok[g.src] & v_ok[g.dst]
    big = jnp.int32(n)  # sentinel: excluded vertices never hook anything
    labels0 = jnp.where(v_ok, jnp.arange(n, dtype=jnp.int32), big)

    def body(state):
        labels, _, it = state
        m1 = jnp.minimum(labels[g.src], labels[g.dst])
        upd = jnp.where(e_act, m1, big)
        new = labels.at[g.src].min(upd)
        new = new.at[g.dst].min(upd)
        # pointer jumping — only real labels (< n) chase; the sentinel
        # would index out of range
        jumped = new[jnp.clip(new, 0, max(n - 1, 0))]
        new = jnp.where(new < n, jumped, new)
        return new, jnp.any(new != labels), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return jnp.where(v_ok, labels, jnp.int32(-1))


def single_hop_filters(
    pg, pattern
) -> Tuple[Optional[jax.Array], Optional[jax.Array], Optional[jax.Array], int]:
    """Derive traversal filters from a node-only or single-hop pattern.

    Returns ``(tail_mask, head_mask, edge_mask, direction)`` — each mask
    ``None`` when unconstrained.  For ``(a:x {p})-[:r {q}]->(b:y)``: an
    edge is traversable iff it holds ``r`` and satisfies ``q``, its tail
    (in traversal order — ``<-[...]-`` flips it) matches ``a`` and its
    head matches ``b``.  A node-only pattern constrains BOTH endpoints
    (traversal confined to matching vertices).  Multi-hop and
    variable-length patterns are rejected: k-hop/components take their
    step structure from ``k``/the fixed point, not from the pattern.
    """
    from repro.query import parse
    from repro.query.planner import validate_pattern

    if pattern is None:
        return None, None, None, 1
    pat = parse(pattern) if isinstance(pattern, str) else pattern
    if pat.hops > 1:
        raise ValueError(
            f"khop/components take a node-only or single-hop filter pattern, "
            f"got {pat.hops} hops in {pat.to_text()!r}")
    validate_pattern(pat)  # plan-time contract: string predicates etc.

    def node_mask(node):
        mask = None
        if node.labels:
            mask = pg.query_labels(list(node.labels))
        for p in node.predicates:
            pm = pg.vertex_predicate_mask(p.name, p.op, p.value)
            mask = pm if mask is None else mask & pm
        return mask

    if pat.hops == 0:
        vm = node_mask(pat.nodes[0])
        return vm, vm, None, 1

    edge = pat.edges[0]
    if not edge.is_fixed:
        raise ValueError(
            f"variable-length hop {edge.to_text()!r} in a khop/components "
            "filter: the traversal depth comes from k / the fixed point, "
            "use a plain single-hop filter")
    em = None
    if edge.rels:
        em = pg.query_relationships(list(edge.rels))
    for p in edge.predicates:
        pm = pg.edge_predicate_mask(p.name, p.op, p.value)
        em = pm if em is None else em & pm
    return node_mask(pat.nodes[0]), node_mask(pat.nodes[1]), em, edge.direction
