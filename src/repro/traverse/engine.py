"""Frontier engine — masked frontier expansion over DI (docs/ARCHITECTURE.md §10).

One primitive unifies the query executor's chain propagation and the
reachability-style analytics (k-hop, connected components): a Boolean
frontier over the n vertices crossed with a relationship/property-masked
edge set yields the next frontier.  Everything here is a client of
:func:`frontier_step`:

  * ``khop_mask``      — union of ≤k expansions (``while_loop`` with
    early exit; one XLA program for the whole traversal).
  * ``reach_closure``  — expansion to a fixed point (the ``*`` unbounded
    pattern hop and reachability closures; bounded by ``n`` rounds).
  * ``khop_csr``       — the CSR fast path: instead of relaxing all m
    edges per step (the edge-centric bitmap step), gather only the
    frontier vertices' adjacency slices off ``seg``/``dst`` — O(|F|·d̂)
    per step, which beats O(m) while the frontier is small (§10 cost
    model).  Host-orchestrated BFS levels, bucketed frontier capacity to
    bound compiles; bitwise-equal to ``khop_mask``.
  * ``*_sharded``      — the multi-device path: each device relaxes its
    own block of the edge list under ``shard_map`` and the per-step
    frontier bitmask is OR-combined with ONE ``pmax`` all-reduce
    (1 byte/entity/step — the same replication argument as the DIP mask
    combination, docs/ARCHITECTURE.md §7).

All functions are exact (Boolean algebra, no estimates): sharded, CSR and
edge-centric paths produce bitwise-identical masks (tests/test_traverse.py).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph

__all__ = [
    "frontier_step",
    "khop_mask",
    "reach_closure",
    "khop_csr",
    "khop_mask_sharded",
    "reach_closure_sharded",
]


def _ends(g: DIGraph, direction: int):
    """(tail, head) endpoint arrays for a traversal direction: +1 follows
    DI edges src→dst, -1 walks them dst→src."""
    return (g.src, g.dst) if direction == 1 else (g.dst, g.src)


def _all_edges(g: DIGraph, edge_allowed) -> jax.Array:
    return jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed


def frontier_step(
    g: DIGraph,
    frontier: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """ONE masked expansion: heads of allowed edges whose tail is in the
    frontier.  (n,) bool × (m,) bool → (n,) bool; exactly one step — the
    result does NOT include the input frontier.  Traceable (not jitted):
    compose it inside jitted loops; the public entry points here do."""
    e_ok = _all_edges(g, edge_allowed)
    tail, head = _ends(g, direction)
    out = jnp.zeros_like(frontier).at[head].max(frontier[tail] & e_ok)
    if undirected:
        out = out | jnp.zeros_like(frontier).at[tail].max(frontier[head] & e_ok)
    return out


@partial(jax.jit, static_argnames=("k", "direction", "undirected"))
def khop_mask(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """Vertices within ≤k allowed hops of the seeds (seeds included), as one
    jitted ``while_loop`` with early exit when the mask stops growing."""
    e_ok = _all_edges(g, edge_allowed)

    def body(state):
        mask, _, it = state
        new = mask | frontier_step(g, mask, e_ok, direction=direction,
                                   undirected=undirected)
        return new, jnp.any(new != mask), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < k)

    mask, _, _ = jax.lax.while_loop(
        cond, body, (seed_mask, jnp.bool_(True), jnp.int32(0)))
    return mask


@partial(jax.jit, static_argnames=("direction", "undirected", "max_iters"))
def reach_closure(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    direction: int = 1,
    undirected: bool = False,
    max_iters: Optional[int] = None,
) -> jax.Array:
    """Fixed point of frontier expansion: everything reachable from the
    seeds in ≥0 allowed hops.  The cumulative mask grows monotonically, so
    n rounds always suffice (``max_iters`` defaults to that bound)."""
    bound = (g.n + 1) if max_iters is None else max_iters
    return khop_mask(g, seed_mask, edge_allowed, k=bound,
                     direction=direction, undirected=undirected)


# ------------------------------------------------------------- CSR fast path
def _bucket(size: int) -> int:
    """Frontier capacity bucket: next power of two ≥ size (min 16), so the
    per-(capacity, max_deg) jitted step compiles O(log n) times, not once
    per frontier size the data produces."""
    cap = 16
    while cap < size:
        cap <<= 1
    return cap


@partial(jax.jit, static_argnames=("cap", "max_deg"))
def _csr_step(g: DIGraph, reached: jax.Array, frontier_idx: jax.Array,
              e_ok: jax.Array, *, cap: int, max_deg: int) -> jax.Array:
    """Gather the padded adjacency of ``frontier_idx`` (pad entries = n,
    whose SEG window is empty) and scatter the allowed neighbors into the
    reached mask.  Work is O(cap · max_deg), independent of m."""
    lane = jnp.arange(max_deg, dtype=jnp.int32)
    start = g.seg[frontier_idx]
    deg = g.seg[jnp.minimum(frontier_idx + 1, g.n)] - start
    eidx = jnp.clip(start[:, None] + lane[None, :], 0, max(g.m - 1, 0))
    ok = (lane[None, :] < deg[:, None]) & e_ok[eidx]
    nbr = jnp.where(ok, g.dst[eidx], g.n)  # pad lanes scatter out of range
    return reached.at[nbr.reshape(-1)].max(True, mode="drop")


def khop_csr(
    g: DIGraph,
    seed_ids,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    max_deg: Optional[int] = None,
) -> jax.Array:
    """CSR-gather k-hop: BFS levels, each expanding only the NEW frontier's
    adjacency slices.  Follows DI edges src→dst (the layout CSR indexes);
    use ``khop_mask(direction=-1)`` / ``build_reverse_di`` for pull-side
    walks.  Bitwise-equal to ``khop_mask`` — the union of ≤k expansions is
    the union of the first k BFS levels."""
    if getattr(g, "unsorted", False):
        # combined base++delta overlay view: SEG covers only the sorted base
        # prefix, so the adjacency windows this path gathers would silently
        # miss every delta edge — the caller must use the edge-centric
        # ``khop_mask`` (PropGraph.khop degrades automatically)
        raise ValueError(
            "khop_csr requires a sorted DI graph with valid SEG; got an "
            "unsorted combined view — use khop_mask instead")
    e_ok = _all_edges(g, edge_allowed)
    if max_deg is None:
        max_deg = g.max_deg if g.max_deg >= 0 else int(
            np.max(np.asarray(g.seg[1:] - g.seg[:-1]), initial=0))
    max_deg = max(max_deg, 1)
    seed_ids = np.unique(np.asarray(seed_ids, np.int32))
    reached = jnp.zeros((g.n,), jnp.bool_).at[jnp.asarray(seed_ids)].set(True)
    frontier = seed_ids
    for _ in range(k):
        if frontier.size == 0 or g.m == 0:
            break
        cap = _bucket(frontier.size)
        fidx = np.full((cap,), g.n, np.int32)
        fidx[: frontier.size] = frontier
        new = _csr_step(g, reached, jnp.asarray(fidx), e_ok,
                        cap=cap, max_deg=max_deg)
        fresh = np.asarray(new & ~reached)
        reached = new
        frontier = np.flatnonzero(fresh).astype(np.int32)
    return reached


# ------------------------------------------------------------- sharded path
@lru_cache(maxsize=None)
def _sharded_khop_fn(mesh, direction: int, undirected: bool):
    """Jitted k-hop whose step runs under ``shard_map``: every device
    relaxes only its own block of the (padded) edge list into a partial
    (n,) int8 mask, and ONE ``pmax`` all-reduce ORs the partials — the
    frontier is the only thing that moves between devices, 1 byte/entity
    per step.  Cached per (mesh, direction, undirected); jit re-specializes
    on shapes/k as usual."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import pg_entity_axes, pg_entity_shards

    ax = pg_entity_axes(mesh)
    p = pg_entity_shards(mesh)

    def local(tail_l, head_l, e_l, f):
        part = jnp.zeros((f.shape[0],), jnp.int8)
        part = part.at[head_l].max((f[tail_l] & e_l).astype(jnp.int8))
        if undirected:
            part = part.at[tail_l].max((f[head_l] & e_l).astype(jnp.int8))
        return jax.lax.pmax(part, ax) > 0

    step = shard_map(local, mesh=mesh,
                     in_specs=(P(ax), P(ax), P(ax), P()), out_specs=P())

    @partial(jax.jit, static_argnames=("k",))
    def fn(g: DIGraph, seed_mask, e_ok, *, k: int):
        tail, head = _ends(g, direction)
        m = tail.shape[0]
        pad = (-(-max(m, 1) // p)) * p - m
        # pad edges are disabled (e_ok False) and point at vertex 0 — the
        # relax reads them but they never scatter a True
        tail = jnp.pad(tail, (0, pad))
        head = jnp.pad(head, (0, pad))
        e_ok = jnp.pad(e_ok, (0, pad))

        def body(state):
            mask, _, it = state
            new = mask | step(tail, head, e_ok, mask)
            return new, jnp.any(new != mask), it + 1

        def cond(state):
            _, changed, it = state
            return changed & (it < k)

        mask, _, _ = jax.lax.while_loop(
            cond, body, (seed_mask, jnp.bool_(True), jnp.int32(0)))
        return mask

    return fn


def khop_mask_sharded(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    mesh,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """``khop_mask`` with the per-step shard_map/all-reduce layout; the
    result is bitwise-identical to the single-device path."""
    fn = _sharded_khop_fn(mesh, direction, undirected)
    return fn(g, seed_mask, _all_edges(g, edge_allowed), k=k)


def reach_closure_sharded(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    mesh,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """Sharded fixed-point expansion (n rounds always suffice)."""
    return khop_mask_sharded(g, seed_mask, edge_allowed, k=g.n + 1,
                             mesh=mesh, direction=direction,
                             undirected=undirected)
