"""Frontier engine — semiring frontier expansion over DI (docs/ARCHITECTURE.md §10, §12).

One primitive unifies the query executor's chain propagation and the
frontier analytics (k-hop, connected components, weighted shortest paths,
PageRank): a per-vertex value vector crossed with a (possibly masked /
weighted) edge set yields the next value vector, under a configurable
:class:`Semiring` — ⊕ combines the messages arriving at a vertex, ⊗
extends a vertex value along an edge.  Everything here is a client of
:func:`semiring_relax`:

  * ``frontier_step``   — the (OR, AND) Boolean instance: heads of allowed
    edges whose tail is in the frontier.
  * ``khop_mask``       — union of ≤k Boolean expansions (``while_loop``
    with early exit; one XLA program for the whole traversal).
  * ``reach_closure``   — expansion to a fixed point (the ``*`` unbounded
    pattern hop and reachability closures; bounded by ``n`` rounds).
  * ``khop_csr``        — the CSR fast path: instead of relaxing all m
    edges per step (the edge-centric bitmap step), gather only the
    frontier vertices' adjacency slices off ``seg``/``dst`` — O(|F|·d̂)
    per step, which beats O(m) while the frontier is small (§10 cost
    model).  Host-orchestrated BFS levels, bucketed frontier capacity to
    bound compiles; bitwise-equal to ``khop_mask``.
  * ``*_sharded``       — the multi-device path: each device relaxes its
    own block of the edge list under ``shard_map`` into a partial (n,)
    value vector and ONE all-reduce combines the partials with the
    semiring's ⊕ primitive — ``pmax`` for the Boolean frontier bitmask
    (1 byte/entity/step), ``pmin`` for tropical distances, ``psum`` for
    PageRank contributions (the same replication argument as the DIP
    mask combination, docs/ARCHITECTURE.md §7).

The Boolean / tropical / min-label instances are exact (idempotent ⊕,
order-insensitive): sharded and single-device paths produce bitwise-
identical results.  The counting (+, ×) instance reassociates float sums
across devices, so the sharded PageRank path is equal within float
tolerance only (tests/test_semiring.py pins both).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph

__all__ = [
    "Semiring",
    "BOOLEAN",
    "TROPICAL",
    "COUNTING",
    "MINLABEL",
    "semiring_relax",
    "semiring_relax_sharded",
    "frontier_step",
    "khop_mask",
    "reach_closure",
    "khop_csr",
    "khop_mask_sharded",
    "reach_closure_sharded",
]

_I32_MAX = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """One relax algebra: ⊕ combines messages at a vertex, ⊗ extends a
    vertex value along an edge.

    ``zero`` is the ⊕ identity AND the ⊗ absorber (a zero-valued vertex
    contributes nothing through any edge), so an all-``zero`` relax input
    is a fixed point — the axiom tests/test_semiring.py property-checks.
    ``scatter`` names the ``.at[].{max,min,add}`` combine the relax
    scatters with; ``allreduce`` names the matching cross-device ⊕
    primitive for the shard_map path.  Instances are module-level
    constants — hashable, so they ride jit static arguments.
    """

    name: str
    zero: object  # ⊕ identity / ⊗ absorber (False, +inf, 0.0, INT32_MAX)
    scatter: str  # "max" | "min" | "add" — the ⊕ scatter combine
    extend: Callable  # ⊗: (tail value, edge value) → message
    allreduce: str  # "pmax" | "pmin" | "psum" — cross-device ⊕


# (OR, AND) over bool — reachability.  ⊕ = any (scatter max), ⊗ = frontier
# bit AND edge-allowed bit.
BOOLEAN = Semiring("boolean", False, "max", lambda x, w: x & w, "pmax")

# (min, +) over f32 — weighted shortest paths.  zero = +inf: unreachable
# stays unreachable (inf + w = inf), and a masked edge (weight forced to
# +inf) never relaxes anything.
TROPICAL = Semiring("tropical", np.float32(np.inf), "min",
                    lambda x, w: x + w, "pmin")

# (+, ×) over f32 — weighted SpMV, the PageRank contribution step.  zero
# = 0.0: a rank-0 vertex contributes nothing, a weight-0 (masked) edge
# carries nothing.
COUNTING = Semiring("counting", np.float32(0.0), "add",
                    lambda x, w: x * w, "psum")

# (min, select) over int32 — the component min-hook: an allowed edge
# forwards the tail's label unchanged, a masked edge forwards the
# identity.  zero = INT32_MAX so any real label wins the min.
MINLABEL = Semiring("minlabel", _I32_MAX, "min",
                    lambda x, w: jnp.where(w, x, _I32_MAX), "pmin")


def _ends(g: DIGraph, direction: int):
    """(tail, head) endpoint arrays for a traversal direction: +1 follows
    DI edges src→dst, -1 walks them dst→src."""
    return (g.src, g.dst) if direction == 1 else (g.dst, g.src)


def _all_edges(g: DIGraph, edge_allowed) -> jax.Array:
    return jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed


def semiring_relax(
    g: DIGraph,
    x: jax.Array,
    edge_vals: jax.Array,
    sr: Semiring,
    *,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """ONE edge-centric relax: ``out[v] = ⊕_{(u→v) edges} x[u] ⊗ w[e]``.

    (n,) value vector × (m,) edge-value vector → (n,) messages; vertices
    with no incoming allowed edge hold ``sr.zero``.  The result does NOT
    include the input values — compose with the running state outside
    (``mask | relax``, ``minimum(dist, relax)``, …).  ``undirected``
    additionally relaxes every edge in reverse into the same output (⊕ is
    commutative/associative, so a second scatter is exact).  Traceable
    (not jitted): compose it inside jitted loops; the public entry points
    here do.
    """
    tail, head = _ends(g, direction)
    out = jnp.full_like(x, sr.zero)
    out = getattr(out.at[head], sr.scatter)(sr.extend(x[tail], edge_vals))
    if undirected:
        out = getattr(out.at[tail], sr.scatter)(sr.extend(x[head], edge_vals))
    return out


def frontier_step(
    g: DIGraph,
    frontier: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """ONE masked Boolean expansion: heads of allowed edges whose tail is
    in the frontier — the (OR, AND) :data:`BOOLEAN` instance of
    :func:`semiring_relax`.  (n,) bool × (m,) bool → (n,) bool; exactly
    one step, the result does NOT include the input frontier."""
    return semiring_relax(g, frontier, _all_edges(g, edge_allowed), BOOLEAN,
                          direction=direction, undirected=undirected)


@partial(jax.jit, static_argnames=("k", "direction", "undirected"))
def khop_mask(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """Vertices within ≤k allowed hops of the seeds (seeds included), as one
    jitted ``while_loop`` with early exit when the mask stops growing."""
    e_ok = _all_edges(g, edge_allowed)

    def body(state):
        mask, _, it = state
        new = mask | frontier_step(g, mask, e_ok, direction=direction,
                                   undirected=undirected)
        return new, jnp.any(new != mask), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < k)

    mask, _, _ = jax.lax.while_loop(
        cond, body, (seed_mask, jnp.bool_(True), jnp.int32(0)))
    return mask


@partial(jax.jit, static_argnames=("direction", "undirected", "max_iters"))
def reach_closure(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    direction: int = 1,
    undirected: bool = False,
    max_iters: Optional[int] = None,
) -> jax.Array:
    """Fixed point of frontier expansion: everything reachable from the
    seeds in ≥0 allowed hops.  The cumulative mask grows monotonically, so
    n rounds always suffice (``max_iters`` defaults to that bound)."""
    bound = (g.n + 1) if max_iters is None else max_iters
    return khop_mask(g, seed_mask, edge_allowed, k=bound,
                     direction=direction, undirected=undirected)


# ------------------------------------------------------------- CSR fast path
def _bucket(size: int) -> int:
    """Frontier capacity bucket: next power of two ≥ size (min 16), so the
    per-(capacity, max_deg) jitted step compiles O(log n) times, not once
    per frontier size the data produces."""
    cap = 16
    while cap < size:
        cap <<= 1
    return cap


@partial(jax.jit, static_argnames=("cap", "max_deg"))
def _csr_step(g: DIGraph, reached: jax.Array, frontier_idx: jax.Array,
              e_ok: jax.Array, *, cap: int, max_deg: int) -> jax.Array:
    """Gather the padded adjacency of ``frontier_idx`` (pad entries = n,
    whose SEG window is empty) and scatter the allowed neighbors into the
    reached mask.  Work is O(cap · max_deg), independent of m."""
    lane = jnp.arange(max_deg, dtype=jnp.int32)
    start = g.seg[frontier_idx]
    deg = g.seg[jnp.minimum(frontier_idx + 1, g.n)] - start
    eidx = jnp.clip(start[:, None] + lane[None, :], 0, max(g.m - 1, 0))
    ok = (lane[None, :] < deg[:, None]) & e_ok[eidx]
    nbr = jnp.where(ok, g.dst[eidx], g.n)  # pad lanes scatter out of range
    return reached.at[nbr.reshape(-1)].max(True, mode="drop")


def khop_csr(
    g: DIGraph,
    seed_ids,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    max_deg: Optional[int] = None,
) -> jax.Array:
    """CSR-gather k-hop: BFS levels, each expanding only the NEW frontier's
    adjacency slices.  Follows DI edges src→dst (the layout CSR indexes);
    use ``khop_mask(direction=-1)`` / ``build_reverse_di`` for pull-side
    walks.  Bitwise-equal to ``khop_mask`` — the union of ≤k expansions is
    the union of the first k BFS levels."""
    if getattr(g, "unsorted", False):
        # combined base++delta overlay view: SEG covers only the sorted base
        # prefix, so the adjacency windows this path gathers would silently
        # miss every delta edge — the caller must use the edge-centric
        # ``khop_mask`` (PropGraph.khop degrades automatically)
        raise ValueError(
            "khop_csr requires a sorted DI graph with valid SEG; got an "
            "unsorted combined view — use khop_mask instead")
    e_ok = _all_edges(g, edge_allowed)
    if max_deg is None:
        max_deg = g.max_deg if g.max_deg >= 0 else int(
            np.max(np.asarray(g.seg[1:] - g.seg[:-1]), initial=0))
    max_deg = max(max_deg, 1)
    seed_ids = np.unique(np.asarray(seed_ids, np.int32))
    reached = jnp.zeros((g.n,), jnp.bool_).at[jnp.asarray(seed_ids)].set(True)
    frontier = seed_ids
    for _ in range(k):
        if frontier.size == 0 or g.m == 0:
            break
        cap = _bucket(frontier.size)
        fidx = np.full((cap,), g.n, np.int32)
        fidx[: frontier.size] = frontier
        new = _csr_step(g, reached, jnp.asarray(fidx), e_ok,
                        cap=cap, max_deg=max_deg)
        fresh = np.asarray(new & ~reached)
        reached = new
        frontier = np.flatnonzero(fresh).astype(np.int32)
    return reached


# ------------------------------------------------------------- sharded path
def _pad_edges(g: DIGraph, edge_vals: jax.Array, p: int, direction: int,
               pad_value):
    """(tail, head, edge_vals) padded to a multiple of the shard count.
    Pad edges point at vertex 0 and carry the semiring's ⊗ absorber as
    their edge value (False / +inf / 0.0), so the relax reads them but
    they never contribute a message."""
    tail, head = _ends(g, direction)
    m = tail.shape[0]
    pad = (-(-max(m, 1) // p)) * p - m
    tail = jnp.pad(tail, (0, pad))
    head = jnp.pad(head, (0, pad))
    edge_vals = jnp.pad(edge_vals, (0, pad), constant_values=pad_value)
    return tail, head, edge_vals


@lru_cache(maxsize=None)
def _sharded_relax_fn(mesh, direction: int, undirected: bool, sr: Semiring):
    """ONE semiring relax under ``shard_map``: every device relaxes only
    its own block of the (padded) edge list into a partial (n,) value
    vector, and ONE ``{pmax,pmin,psum}`` all-reduce ⊕-combines the
    partials — the value vector is the only thing that moves between
    devices per step.  Cached per (mesh, direction, undirected, semiring);
    jit re-specializes on shapes as usual."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import pg_entity_axes

    ax = pg_entity_axes(mesh)
    reduce_fn = getattr(jax.lax, sr.allreduce)

    def local(tail_l, head_l, ev_l, x):
        part = jnp.full((x.shape[0],), sr.zero, x.dtype)
        part = getattr(part.at[head_l], sr.scatter)(sr.extend(x[tail_l], ev_l))
        if undirected:
            part = getattr(part.at[tail_l], sr.scatter)(
                sr.extend(x[head_l], ev_l))
        return reduce_fn(part, ax)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(ax), P(ax), P(ax), P()), out_specs=P())


def semiring_relax_sharded(
    g: DIGraph,
    x: jax.Array,
    edge_vals: jax.Array,
    sr: Semiring,
    *,
    mesh,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """:func:`semiring_relax` with the per-step shard_map/all-reduce
    layout.  Idempotent-⊕ semirings (Boolean, tropical, min-label) are
    bitwise-identical to the single-device relax; ``psum`` reassociates
    float sums, so :data:`COUNTING` agrees within tolerance only."""
    from repro.launch.sharding import pg_entity_shards

    step = _sharded_relax_fn(mesh, direction, undirected, sr)
    tail, head, edge_vals = _pad_edges(
        g, edge_vals, pg_entity_shards(mesh), direction, sr.zero)
    return step(tail, head, edge_vals, x)


@lru_cache(maxsize=None)
def _sharded_khop_fn(mesh, direction: int, undirected: bool,
                     packed: bool = False):
    """Jitted Boolean k-hop whose step is the sharded relax on a frontier
    bitmask.  ``packed=False``: int8 partials, per-step ``pmax`` all-reduce,
    1 byte/entity per step.  ``packed=True`` (the default wire-up via
    :func:`khop_mask_sharded`): each device packs its partial into uint32
    words and the step rides a bitwise-OR all-reduce —
    ``bitplane.or_allreduce``, a ppermute butterfly for power-of-two device
    counts — moving 1 BIT/entity per step, the packed plane's 8× cut
    applied to the only thing the sharded frontier exchanges."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core import bitplane
    from repro.launch.sharding import pg_entity_axes, pg_entity_shards

    ax = pg_entity_axes(mesh)
    p = pg_entity_shards(mesh)

    def local(tail_l, head_l, e_l, f):
        part = jnp.zeros((f.shape[0],), jnp.int8)
        part = part.at[head_l].max((f[tail_l] & e_l).astype(jnp.int8))
        if undirected:
            part = part.at[tail_l].max((f[head_l] & e_l).astype(jnp.int8))
        if packed:
            words = bitplane.or_allreduce(bitplane.pack_mask(part > 0), ax, p)
            return bitplane.unpack_mask(words, part.shape[0])
        return jax.lax.pmax(part, ax) > 0

    step = shard_map(local, mesh=mesh,
                     in_specs=(P(ax), P(ax), P(ax), P()), out_specs=P(),
                     check_rep=False)

    @partial(jax.jit, static_argnames=("k",))
    def fn(g: DIGraph, seed_mask, e_ok, *, k: int):
        tail, head, e_ok = _pad_edges(g, e_ok, p, direction, False)

        def body(state):
            mask, _, it = state
            new = mask | step(tail, head, e_ok, mask)
            return new, jnp.any(new != mask), it + 1

        def cond(state):
            _, changed, it = state
            return changed & (it < k)

        mask, _, _ = jax.lax.while_loop(
            cond, body, (seed_mask, jnp.bool_(True), jnp.int32(0)))
        return mask

    return fn


def khop_mask_sharded(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    k: int,
    mesh,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """``khop_mask`` with the per-step shard_map/all-reduce layout; the
    result is bitwise-identical to the single-device path (packed or byte
    exchange — OR is OR either way)."""
    from repro.core import bitplane

    fn = _sharded_khop_fn(mesh, direction, undirected,
                          bitplane.packed_default())
    return fn(g, seed_mask, _all_edges(g, edge_allowed), k=k)


def reach_closure_sharded(
    g: DIGraph,
    seed_mask: jax.Array,
    edge_allowed: Optional[jax.Array] = None,
    *,
    mesh,
    direction: int = 1,
    undirected: bool = False,
) -> jax.Array:
    """Sharded fixed-point expansion (n rounds always suffice)."""
    return khop_mask_sharded(g, seed_mask, edge_allowed, k=g.n + 1,
                             mesh=mesh, direction=direction,
                             undirected=undirected)
