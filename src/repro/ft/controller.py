"""Fault-tolerant training controller — restart, failure injection, stragglers.

At 1000+-node scale the dominant availability levers on synchronous TPU/TRN
fleets are (a) cheap frequent checkpoints with instant resume, (b) surviving
preemption/node loss by re-scheduling onto a *different* topology (elastic),
and (c) bounding the blast radius of stragglers.  This module wires those
around any ``step_fn``:

  * ``TrainController.run`` — steps with periodic async checkpoints; on start
    it auto-resumes from the newest valid checkpoint (crash ⇒ relaunch ⇒
    continue; validated bitwise in tests/test_ft.py).
  * ``FailureInjector`` — deterministic simulated faults (raise at step k) for
    tests/benchmarks; the run loop converts the fault into a restart.
  * ``accumulate_grads`` — microbatch gradient accumulation with a
    ``drop_mask``: straggler mitigation on synchronous meshes is expressed as
    dropping late microbatches and renormalizing (the bounded-staleness
    variant used by large sync fleets); the mask is an input so schedulers can
    decide per step without recompilation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager

__all__ = ["FailureInjector", "TrainController", "accumulate_grads"]


class FailureInjector:
    """Raises ``SimulatedFailure`` at the scheduled global steps (once each)."""

    class SimulatedFailure(RuntimeError):
        pass

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise self.SimulatedFailure(f"injected failure at step {step}")


def accumulate_grads(loss_fn: Callable, params, microbatches, drop_mask=None):
    """Mean gradients over ``n_micro`` microbatches (leading axis), skipping
    dropped ones.  drop_mask: (n_micro,) bool — True ⇒ contribute."""
    n = jax.tree.leaves(microbatches)[0].shape[0]
    if drop_mask is None:
        drop_mask = jnp.ones((n,), jnp.bool_)

    def body(carry, xs):
        acc, denom = carry
        mb, keep = xs
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        w = keep.astype(jnp.float32)
        acc = jax.tree.map(lambda a, b: a + w * b.astype(jnp.float32), acc, g)
        return (acc, denom + w), loss

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, denom), losses = jax.lax.scan(body, (zeros, jnp.float32(0.0)), (microbatches, drop_mask))
    denom = jnp.maximum(denom, 1.0)
    return jax.tree.map(lambda a: a / denom, acc), losses


@dataclasses.dataclass
class TrainController:
    """Generic restartable step loop.

    step_fn(state, step) -> (state, metrics);  state is a pytree.
    """

    ckpt: CheckpointManager
    step_fn: Callable[[Any, int], Tuple[Any, Dict]]
    ckpt_every: int = 50
    max_restarts: int = 8

    def run(self, state, n_steps: int, *, injector: Optional[FailureInjector] = None,
            shardings=None, log: Optional[Callable[[int, Dict], None]] = None):
        """Run to ``n_steps`` global steps, surviving injected failures by
        restoring the newest checkpoint (the external-scheduler restart path
        collapsed into one process for testing)."""
        restarts = 0
        # Host snapshot of the initial state: step_fns may donate their input
        # buffers, which would invalidate `state` for the no-checkpoint
        # restart path (donation is a no-op on CPU but real on TPU).
        init_snapshot = jax.tree.map(lambda x: jax.device_get(x), state)
        while True:
            start, state = self._resume(init_snapshot, shardings)
            try:
                for step in range(start, n_steps):
                    if injector is not None:
                        injector.check(step)
                    state, metrics = self.step_fn(state, step)
                    if log is not None:
                        log(step, metrics)
                    nxt = step + 1
                    if nxt % self.ckpt_every == 0 or nxt == n_steps:
                        self.ckpt.save_sync(nxt, state)
                return state
            except FailureInjector.SimulatedFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                # fall through: next loop iteration restores latest checkpoint

    def _resume(self, like_state, shardings):
        step, state = self.ckpt.restore_latest(like_state, shardings=shardings)
        return (0, like_state) if step is None else (step, state)
