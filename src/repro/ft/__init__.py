from repro.ft.controller import FailureInjector, TrainController, accumulate_grads

__all__ = ["FailureInjector", "TrainController", "accumulate_grads"]
