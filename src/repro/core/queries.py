"""Property-graph queries and subgraph induction (§VI of the paper).

A query passes a set of attributes and receives the Boolean mask of entities
containing **any** of them (OR semantics).  Masks compose downstream:
"the returned values can be further processed to find the intersections of the
returned vertex and edge arrays to create a subgraph" — that is
``induce_subgraph`` here.  ``filtered_bfs`` is the paper's motivating example
("breadth-first search on specific vertices", §I) built on the same masks.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.di import DIGraph, build_di

__all__ = [
    "induce_edge_mask",
    "induce_edge_mask_directed",
    "extract_subgraph",
    "filtered_bfs",
    "connected_entities",
]


@jax.jit
def induce_edge_mask(
    g: DIGraph,
    vertex_mask: jax.Array,
    edge_mask: jax.Array,
) -> jax.Array:
    """Intersect attribute-query results into a subgraph edge mask:
    an edge survives iff its own mask is set AND both endpoints' masks are set.
    (n,) bool × (m,) bool → (m,) bool."""
    return edge_mask & vertex_mask[g.src] & vertex_mask[g.dst]


@partial(jax.jit, static_argnames=("direction",))
def induce_edge_mask_directed(
    g: DIGraph,
    tail_mask: jax.Array,
    head_mask: jax.Array,
    edge_mask: jax.Array,
    direction: int = 1,
) -> jax.Array:
    """Per-endpoint generalization of :func:`induce_edge_mask` for directed
    pattern hops: an edge survives iff its own mask is set AND its tail end
    satisfies ``tail_mask`` AND its head end satisfies ``head_mask``.
    ``direction=1`` reads tail=src/head=dst; ``-1`` the reverse (a pattern
    hop written ``<-[...]-``).  ``induce_edge_mask(g, vm, em)`` is the
    special case ``tail_mask == head_mask, direction=1``."""
    tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
    return edge_mask & tail_mask[tail] & head_mask[head]


def extract_subgraph(g: DIGraph, edge_mask) -> Tuple[DIGraph, np.ndarray]:
    """Compact a masked edge set into a fresh DI graph (host-side; subgraph
    size is data-dependent).  Returns (subgraph, kept edge indices).  Vertex
    ids are re-normalized; ``node_map`` chains through the parent's so original
    ids survive arbitrarily deep filtering."""
    keep = np.flatnonzero(np.asarray(edge_mask))
    src = np.asarray(g.src)[keep]
    dst = np.asarray(g.dst)[keep]
    sub = build_di(src, dst, normalize=True, dedupe=False)
    # chain node maps: sub ids -> parent ids -> original ids
    parent_map = np.asarray(g.node_map)
    sub = type(sub)(
        src=sub.src,
        dst=sub.dst,
        seg=sub.seg,
        node_map=jnp.asarray(parent_map[np.asarray(sub.node_map)]),
        n=sub.n,
        m=sub.m,
        max_deg=sub.max_deg,
    )
    return sub, keep


@partial(jax.jit, static_argnames=("max_iters",))
def filtered_bfs(
    g: DIGraph,
    sources: jax.Array,
    *,
    edge_allowed: Optional[jax.Array] = None,
    vertex_allowed: Optional[jax.Array] = None,
    max_iters: int = 64,
) -> jax.Array:
    """Property-filtered BFS over DI, edge-centric frontier expansion.

    Each round relaxes *every* edge whose source is in the frontier (the DI
    edge-centric view: perfectly load-balanced over the block-distributed edge
    list, no per-vertex ragged loops).  Edges/vertices excluded by the
    attribute masks never propagate.  Returns (n,) int32 BFS depths, -1 for
    unreached.  Rounds are bounded by ``max_iters`` with early-exit.
    """
    n, = (g.n,)
    e_ok = jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed
    v_ok = jnp.ones((n,), jnp.bool_) if vertex_allowed is None else vertex_allowed

    depth0 = jnp.full((n,), -1, jnp.int32)
    src_ok = v_ok[sources]
    depth0 = depth0.at[sources].set(jnp.where(src_ok, 0, -1))
    frontier0 = jnp.zeros((n,), jnp.bool_).at[sources].set(src_ok)

    def body(state):
        depth, frontier, it, _ = state
        relax = frontier[g.src] & e_ok & v_ok[g.dst]
        cand = jnp.zeros((n,), jnp.bool_).at[g.dst].max(relax)
        new = cand & (depth < 0)
        depth = jnp.where(new, it + 1, depth)
        return depth, new, it + 1, jnp.any(new)

    def cond(state):
        _, _, it, alive = state
        return alive & (it < max_iters)

    depth, _, _, _ = jax.lax.while_loop(
        cond, body, (depth0, frontier0, jnp.int32(0), jnp.any(frontier0))
    )
    return depth


@partial(jax.jit, static_argnames=("max_iters",))
def connected_entities(
    g: DIGraph,
    seed_mask: jax.Array,
    *,
    edge_allowed: Optional[jax.Array] = None,
    max_iters: int = 64,
) -> jax.Array:
    """Closure of ``seed_mask`` under allowed edges (both directions) —
    the 'return the edge set of a new graph that matched the query space'
    operation of §VII-B generalized to reachability."""
    e_ok = jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed

    def body(state):
        mask, _, it = state
        fwd = jnp.zeros_like(mask).at[g.dst].max(mask[g.src] & e_ok)
        bwd = jnp.zeros_like(mask).at[g.src].max(mask[g.dst] & e_ok)
        new_mask = mask | fwd | bwd
        return new_mask, jnp.any(new_mask != mask), it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    mask, _, _ = jax.lax.while_loop(cond, body, (seed_mask, jnp.bool_(True), jnp.int32(0)))
    return mask
