"""DIP-LIST — per-entity attribute lists (§IV-B of the paper), as entity-major CSR.

The paper stores, for every entity, a Chapel list/domain of attribute ids.
Ragged per-entity lists have exactly one TPU-native encoding: offsets + values
(CSR).  ``off[N+1]`` and ``val[nnz]`` are 1-D block-distributable the same way
DI's SEG/DST are — entity-major, so a query's membership scan touches only the
shard-local slice of ``val`` (the paper's O(NK/P) with P = shard count).
That distribution is realized in ``core.dip_shard``: ``val``/``slot_entity``
shard over the slot axis per ``launch.sharding.pg_list_specs`` and the query
runs under ``shard_map`` with one pmax all-reduce combining per-shard masks
(docs/ARCHITECTURE.md §7).

Space O(N·K) worst case (every entity holds every attribute), matching §IV-D.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "DIPList",
    "build_dip_list",
    "build_dip_list_host",
    "query_any",
    "attrs_of_entity_padded",
    "entity_of_slot",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["off", "val", "slot_entity"],
    meta_fields=["k", "n", "nnz"],
)
@dataclasses.dataclass(frozen=True)
class DIPList:
    """Entity-major CSR attribute store.

    ``off[e] .. off[e+1]`` indexes the sorted attribute-id list of entity ``e``
    inside ``val``.  ``slot_entity[nnz]`` materializes "which entity owns slot
    i" (the inverse of ``off``) so membership hits can be scattered back to
    entities without a ragged repeat at query time.
    """

    off: jax.Array  # (n+1,) int32
    val: jax.Array  # (nnz,) int32 attribute ids, sorted within each entity
    slot_entity: jax.Array  # (nnz,) int32 owning entity per slot
    k: int
    n: int
    nnz: int


def build_dip_list_host(
    entity_ids, attr_ids, *, k: int, n: int, dedupe: bool = True
) -> DIPList:
    """``build_dip_list`` with HOST (numpy) storage — identical layout, no
    device allocation.  The sharded path builds here, reads the per-attribute
    stats off ``val``, then places only the padded slot shards on devices
    (docs/ARCHITECTURE.md §7)."""
    import numpy as np

    entity_ids = np.asarray(entity_ids, np.int32).ravel()
    attr_ids = np.asarray(attr_ids, np.int32).ravel()
    order = np.lexsort((attr_ids, entity_ids))
    ent_s, attr_s = entity_ids[order], attr_ids[order]
    if dedupe and ent_s.size:
        keep = np.concatenate(
            [[True], (ent_s[1:] != ent_s[:-1]) | (attr_s[1:] != attr_s[:-1])]
        )
        ent_s, attr_s = ent_s[keep], attr_s[keep]
    nnz = int(ent_s.shape[0])
    counts = np.bincount(ent_s, minlength=n)[:n] if nnz else np.zeros(n, np.int64)
    off = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return DIPList(off=off, val=attr_s, slot_entity=ent_s, k=k, n=n, nnz=nnz)


def build_dip_list(entity_ids, attr_ids, *, k: int, n: int, dedupe: bool = True) -> DIPList:
    """Bulk build from (entity, attribute) pairs: sort by (entity, attr), then
    CSR offsets via bincount+cumsum — the vectorized replacement for the
    paper's mutex-guarded per-element list insertions (§IV-B notes the Chapel
    insertion path is suboptimal; static graphs admit this bulk path).

    Builds host-side, then uploads — one layout definition for both the
    single-device store and the sharded placement path."""
    host = build_dip_list_host(entity_ids, attr_ids, k=k, n=n, dedupe=dedupe)
    return dataclasses.replace(
        host,
        off=jnp.asarray(host.off),
        val=jnp.asarray(host.val),
        slot_entity=jnp.asarray(host.slot_entity),
    )


@jax.jit
def query_any(dlist: DIPList, attr_mask: jax.Array) -> jax.Array:
    """OR-semantics query (§VI-A): every attribute list of every entity is
    scanned — O(nnz) ≤ O(NK), sharded over entities ⇒ O(NK/P).

    hit[i] = attr_mask[val[i]]; mask[e] = OR of hits over e's slots —
    a segment-max expressed as a scatter-max (slots are entity-sorted so the
    scatter is shard-local under entity sharding)."""
    if dlist.nnz == 0:
        return jnp.zeros((dlist.n,), jnp.bool_)
    hit = attr_mask[dlist.val]
    mask = jnp.zeros((dlist.n,), jnp.bool_)
    return mask.at[dlist.slot_entity].max(hit, mode="drop")


@partial(jax.jit, static_argnames=("max_k",))
def attrs_of_entity_padded(dlist: DIPList, e: jax.Array, *, max_k: int) -> Tuple[jax.Array, jax.Array]:
    """Entity→attributes read, padded to ``max_k`` (ragged → mask)."""
    if dlist.nnz == 0:
        lane = jnp.arange(max_k, dtype=jnp.int32)
        return jnp.full((max_k,), -1, jnp.int32), jnp.zeros((max_k,), jnp.bool_)
    start = dlist.off[e]
    deg = dlist.off[e + 1] - start
    lane = jnp.arange(max_k, dtype=jnp.int32)
    idx = jnp.clip(start + lane, 0, max(dlist.nnz - 1, 0))
    valid = lane < deg
    return jnp.where(valid, dlist.val[idx], -1), valid


def entity_of_slot(dlist: DIPList) -> jax.Array:
    """(nnz,) owning entity of each slot (exposed for property tests)."""
    return dlist.slot_entity
