"""Bit-packed mask plane: uint32 words, little-endian bit order.

The DIP-arr byte Boolean array (paper §V) spends one int8 per
(entity, attribute); every mask that crosses a layer boundary — store →
kernel → shard all-reduce → wire — inherits that byte.  This module is
the single source of truth for the packed alternative: entity ``e``
lives in bit ``e % 32`` of word ``e // 32``, the exact layout of
``np.packbits(bitorder='little')`` viewed as ``<u4``, so a packed plane's
byte view IS the wire format and host/device packing agree bit-for-bit.

Invariant enforced everywhere: tail padding bits (entities ≥ n inside the
last word) are ZERO.  Builders scatter only in-range entities, ``pack_mask``
pads with False, and word-level AND/OR preserve zeros — so word-space
algebra (``base | delta & ~tomb``) never needs a masking epilogue.

The byte path stays available for one release behind
``REPRO_PG_BYTE_MASKS=1`` (env) or the ``byte_masks()`` context manager
(tests/smokes use the latter to run both paths in one process).
"""
from __future__ import annotations

import contextlib
import os
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32  # bits per packed word

__all__ = [
    "WORD", "n_words", "packed_default", "byte_masks",
    "pack_bits_host", "unpack_bits_host",
    "pack_mask", "unpack_mask", "or_reduce", "or_allreduce",
]

# None → consult the env var; True/False → explicit override (context manager).
_FORCE_BYTE: Optional[bool] = None


def packed_default() -> bool:
    """True when new stores should pack masks (the default this release)."""
    if _FORCE_BYTE is not None:
        return not _FORCE_BYTE
    return os.environ.get("REPRO_PG_BYTE_MASKS", "0") not in ("1", "true", "yes")


@contextlib.contextmanager
def byte_masks(enabled: bool = True) -> Iterator[None]:
    """Force the byte fallback path (or un-force it) for the enclosed block.

    Process-local and not thread-scoped: flip it only at test/smoke setup,
    before graphs are built — stores capture the flag at build time.
    """
    global _FORCE_BYTE
    prev = _FORCE_BYTE
    _FORCE_BYTE = bool(enabled)
    try:
        yield
    finally:
        _FORCE_BYTE = prev


def n_words(n: int) -> int:
    """Words needed for n entities (ceil(n / 32); 0 entities → 0 words)."""
    return (int(n) + WORD - 1) // WORD


# ---------------------------------------------------------------------------
# Host (numpy) pack / unpack
# ---------------------------------------------------------------------------

def pack_bits_host(bits: np.ndarray) -> np.ndarray:
    """Pack a host bool/int array along its LAST axis into uint32 words.

    ``(..., n)`` → ``(..., ceil(n/32))`` with bit ``e & 31`` of word
    ``e >> 5`` = ``bits[..., e]``; tail bits zero.  Matches
    ``np.packbits(bitorder='little')`` then ``.view('<u4')``.
    """
    bits = np.asarray(bits)
    n = bits.shape[-1]
    w = n_words(n)
    packed8 = np.packbits(bits.astype(bool), axis=-1, bitorder="little")
    # packbits yields ceil(n/8) bytes; pad the byte axis to a 4-byte multiple
    # so the <u4 view lines up.  Pad bytes are zero → tail bits zero.
    pad = 4 * w - packed8.shape[-1]
    if pad:
        packed8 = np.concatenate(
            [packed8, np.zeros(bits.shape[:-1] + (pad,), np.uint8)], axis=-1)
    return np.ascontiguousarray(packed8).view("<u4").astype(np.uint32, copy=False)


def unpack_bits_host(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits_host`: ``(..., W)`` uint32 → ``(..., n)`` bool."""
    words = np.ascontiguousarray(np.asarray(words, dtype="<u4"))
    bits = np.unpackbits(words.view(np.uint8), axis=-1, bitorder="little")
    return bits[..., :n].astype(bool)


# ---------------------------------------------------------------------------
# Device (jax) pack / unpack — identical layout
# ---------------------------------------------------------------------------

def pack_mask(mask: jax.Array) -> jax.Array:
    """Pack a device bool array along its last axis into uint32 words.

    jit-safe; pads the tail with False so padding bits are zero.
    """
    n = mask.shape[-1]
    w = n_words(n)
    pad = w * WORD - n
    if pad:
        cfg = [(0, 0)] * (mask.ndim - 1) + [(0, pad)]
        mask = jnp.pad(mask, cfg, constant_values=False)
    lanes = mask.reshape(mask.shape[:-1] + (w, WORD)).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)  # bit j ↔ entity w*32+j
    return jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32)


def unpack_mask(words: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_mask`: ``(..., W)`` uint32 → ``(..., n)`` bool."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    return flat[..., :n].astype(bool)


def or_reduce(words: jax.Array, axis: int = 0) -> jax.Array:
    """Bitwise-OR reduction of uint32 words over ``axis``.

    ``jnp`` has no ``bitwise_or.reduce``; ``lax.reduce`` with a bitwise-or
    computation lowers to a log-depth tree on TPU/CPU alike.
    """
    return jax.lax.reduce(words, jnp.uint32(0),
                          jax.lax.bitwise_or, (axis,))


def or_allreduce(words: jax.Array, axis_name: str, num_devices: int) -> jax.Array:
    """Bitwise-OR all-reduce of packed words across a mesh axis.

    ``lax.pmax`` on packed words is NOT an OR (max(0b01, 0b10) = 0b10), so
    the frontier/scatter paths need a real OR collective.  For power-of-two
    device counts this is a recursive-doubling butterfly over ``ppermute``
    (log₂P rounds, each moving W words = n/8 bytes — the §7 "1 bit per
    entity" claim made literal); otherwise fall back to all_gather + a
    local OR fold.
    """
    p = int(num_devices)
    if p <= 1:
        return words
    if isinstance(axis_name, (tuple, list)) and len(axis_name) == 1:
        axis_name = axis_name[0]
    if isinstance(axis_name, str) and p & (p - 1) == 0:
        d = 1
        while d < p:
            perm = [(i, i ^ d) for i in range(p)]
            words = words | jax.lax.ppermute(words, axis_name, perm)
            d <<= 1
        return words
    gathered = jax.lax.all_gather(words, axis_name)  # (P, ...)
    return or_reduce(gathered, axis=0)
