"""DI (Double-Index) graph data structure — JAX port of Arachne's base structure.

The DI structure (Du et al., 2021; §III of the paper) stores a directed graph as

  * ``src[m]``, ``dst[m]``  -- the *edge index arrays*, lexicographically sorted
    by (src, dst) so that every vertex's adjacency list is a contiguous slice,
  * ``seg[n+1]``            -- the *vertex index array* (CSR-style offsets);
    ``seg[0] == 0`` and ``seg[n] == m`` always,
  * ``node_map[n]``         -- original (pre-normalization) vertex identifiers.

Neighborhood of ``u`` = ``dst[seg[u] : seg[u+1]]`` — the Chapel zero-copy array
slice becomes a static-shape gather / dynamic-slice here.  DI augments plain CSR
with the explicit, sorted edge list so both edge-centric (load-balanced over
``m``) and vertex-centric (offset lookup over ``n``) algorithms are natural.

Distribution: the edge arrays and the vertex array are 1-D block distributed —
``core.dip_shard.place_graph`` applies the ``launch.sharding.pg_di_specs``
NamedShardings (entity axes = ``("pod", "data")`` on production meshes); all
functions below are pure and pjit-compatible (docs/ARCHITECTURE.md §7).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DIGraph",
    "build_di",
    "build_reverse_di",
    "degrees",
    "neighbors_padded",
    "edge_lookup",
    "max_degree",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["src", "dst", "seg", "node_map"],
    meta_fields=["n", "m", "max_deg", "unsorted"],
)
@dataclasses.dataclass(frozen=True)
class DIGraph:
    """Double-Index graph. ``n`` vertices (normalized ids in [0, n)), ``m`` edges.

    Invariants (property-tested in tests/test_core_di.py):
      * ``src`` is non-decreasing; within equal ``src`` runs ``dst`` is sorted.
      * ``seg[0] == 0``, ``seg[n] == m``, ``seg`` non-decreasing.
      * ``seg[u+1] - seg[u] == out_degree(u)``.
      * ``node_map`` is strictly increasing (sorted unique original ids).

    ``max_deg`` caches the widest adjacency window (max out-degree),
    computed once at build time from the same sort that produced SEG.  It
    is metadata (participates in jit specialization like ``n``/``m``):
    ``edge_lookup`` sizes its binary search to ⌈log₂ max_deg⌉ trips instead
    of ⌈log₂ m⌉, and the traverse CSR fast path reads its lane width off
    it.  ``-1`` = unknown (hand-built graphs); consumers fall back to the
    conservative bound.
    """

    src: jax.Array  # (m,) int32
    dst: jax.Array  # (m,) int32
    seg: jax.Array  # (n+1,) int32
    node_map: jax.Array  # (n,) original vertex ids
    n: int
    m: int
    max_deg: int = -1
    # True for an overlay's combined (base ++ delta) edge view: the sort/SEG
    # invariants above hold only for the base prefix.  Edge-centric consumers
    # (frontier_step, components, induce/extract) never read SEG and stay
    # correct; SEG-dependent fast paths (khop_csr, neighbors_padded,
    # edge_lookup) must refuse or route around such graphs.
    unsorted: bool = False

    # -- convenience -------------------------------------------------------
    def out_degree(self, u) -> jax.Array:
        return self.seg[u + 1] - self.seg[u]

    def edge_index(self) -> jax.Array:
        """(2, m) edge index in the conventional GNN layout."""
        return jnp.stack([self.src, self.dst])


def _as_i32(x) -> jnp.ndarray:
    return jnp.asarray(x).astype(jnp.int32)


def build_di(
    src,
    dst,
    *,
    n: Optional[int] = None,
    normalize: bool = True,
    dedupe: bool = True,
) -> DIGraph:
    """Construct a DI graph from raw endpoint arrays (the Arachne ingestion path).

    Steps mirror §V of the paper: (1) vertex-id normalization to [0, n),
    (2) lexicographic (src, dst) sort, (3) SEG offset generation.  Runs with
    concrete (host-resident) arrays — construction is a one-off bulk step for
    *static* property graphs; downstream queries/analytics are jitted.

    Args:
      src, dst: integer endpoint arrays of equal length.
      n: vertex-count override.  When given with ``normalize=False`` the ids
         are assumed already in [0, n).
      normalize: remap original ids to dense [0, n) via sorted-unique.
      dedupe: collapse structural multi-edges ((u,v) repeated).  The paper keeps
        one structural edge per (u,v); multiplicity lives in the relationship
        attribute store (Fig. 1).
    """
    src = jnp.asarray(src)
    dst = jnp.asarray(dst)
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"src/dst must be equal-length 1-D, got {src.shape} vs {dst.shape}")

    if normalize:
        node_map = jnp.unique(jnp.concatenate([src, dst]))
        n_ = int(node_map.shape[0])
        if n is not None and n < n_:
            raise ValueError(f"n={n} smaller than distinct vertex count {n_}")
        src_n = jnp.searchsorted(node_map, src).astype(jnp.int32)
        dst_n = jnp.searchsorted(node_map, dst).astype(jnp.int32)
        n = n_ if n is None else int(n)
    else:
        if n is None:
            n = int(jnp.max(jnp.concatenate([src, dst]))) + 1 if src.size else 0
        node_map = jnp.arange(n, dtype=jnp.int32)
        src_n, dst_n = _as_i32(src), _as_i32(dst)

    # (2) lexicographic sort by (src, dst).  Two-key lexsort — no fused key, so
    # no int32 overflow for n up to 2**31 (x64 stays off framework-wide).
    order = jnp.lexsort((dst_n, src_n))
    src_s, dst_s = src_n[order], dst_n[order]

    if dedupe and src_s.size:
        keep = jnp.concatenate(
            [jnp.array([True]), (src_s[1:] != src_s[:-1]) | (dst_s[1:] != dst_s[:-1])]
        )
        keep_np = np.asarray(keep)
        src_s = src_s[keep_np]
        dst_s = dst_s[keep_np]

    m = int(src_s.shape[0])
    # (3) SEG: counts → exclusive prefix sum, seg[0]=0, seg[n]=m.
    counts = jnp.bincount(src_s, length=n)
    seg = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    max_deg = int(np.max(np.asarray(counts), initial=0)) if n else 0
    return DIGraph(src=src_s, dst=dst_s, seg=seg, node_map=node_map, n=n, m=m,
                   max_deg=max_deg)


def build_reverse_di(g: DIGraph) -> DIGraph:
    """In-edge view: DI over (dst, src).  Shares node_map; used by pull-style
    algorithms (BFS frontiers, GraphCast mesh2grid) and in-degree stats."""
    order = jnp.lexsort((g.src, g.dst))
    rsrc = g.dst[order]
    rdst = g.src[order]
    counts = jnp.bincount(rsrc, length=g.n)
    seg = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    max_deg = int(np.max(np.asarray(counts), initial=0)) if g.n else 0
    return DIGraph(src=rsrc, dst=rdst, seg=seg, node_map=g.node_map, n=g.n, m=g.m,
                   max_deg=max_deg)


def degrees(g: DIGraph) -> Tuple[jax.Array, jax.Array]:
    """(out_degree[n], in_degree[n]) — Tab. I statistics."""
    out_deg = g.seg[1:] - g.seg[:-1]
    in_deg = jnp.bincount(g.dst, length=g.n)
    return out_deg, in_deg


def max_degree(g: DIGraph) -> int:
    out_deg, in_deg = degrees(g)
    return int(jnp.maximum(out_deg.max() if g.n else 0, in_deg.max() if g.n else 0))


@partial(jax.jit, static_argnames=("max_deg",))
def neighbors_padded(g: DIGraph, u: jax.Array, *, max_deg: int) -> Tuple[jax.Array, jax.Array]:
    """Chapel's ``DST[SEG[u]..SEG[u+1]-1]`` slice, padded to ``max_deg``.

    Returns (neighbors (..., max_deg) int32, valid mask).  Ragged adjacency has
    no native JAX encoding, so callers pick ``max_deg`` (graph max degree or a
    sampling fanout) — out-of-range lanes are masked.  Gathers stay contiguous
    because DI keeps adjacency lists sorted and dense.
    """
    u = jnp.asarray(u)
    start = g.seg[u]
    deg = g.seg[u + 1] - start
    lane = jnp.arange(max_deg, dtype=jnp.int32)
    idx = start[..., None] + lane
    valid = lane < deg[..., None]
    nbrs = jnp.where(valid, g.dst[jnp.clip(idx, 0, max(g.m - 1, 0))], -1)
    return nbrs, valid


@jax.jit
def edge_lookup(g: DIGraph, eu: jax.Array, ev: jax.Array) -> jax.Array:
    """Map endpoint pairs (already-normalized ids) to edge indices in [0, m).

    Two-level search exploiting the DI invariants — SEG narrows each query to
    its source's adjacency window, then a fixed-trip-count vectorized binary
    search finds ``ev`` inside the sorted ``DST`` slice.  This is how attribute
    ingestion locates the internal edge index for each (src, dst, relationship)
    row (§V step 2).  Returns -1 where the edge does not exist.  No fused
    (src*n+dst) key ⇒ safe for any n, m < 2**31.

    The trip count is sized to the graph's cached ``max_deg`` (the sort-once
    statistic ``build_di`` stores): every search window is an adjacency
    slice, so ⌈log₂ max_deg⌉+1 rounds of the gather already pin the answer —
    on skewed real graphs that is a fraction of the ⌈log₂ m⌉ bound the
    conservative fallback (``max_deg`` unknown) uses.  Pinned bitwise-equal
    to an O(m·q) full scan in tests/test_core_di.py.
    """
    if g.m == 0:
        return jnp.full(eu.shape, -1, jnp.int32)
    eu = jnp.asarray(eu, jnp.int32)
    ev = jnp.asarray(ev, jnp.int32)
    lo = g.seg[eu]
    hi = g.seg[eu + 1]

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        go_right = (g.dst[jnp.clip(mid, 0, g.m - 1)] < ev) & (lo < hi)
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    window = g.max_deg if g.max_deg >= 0 else g.m
    trips = max(1, int(np.ceil(np.log2(max(window, 2)))) + 1)
    lo, hi = jax.lax.fori_loop(0, trips, step, (lo, hi))
    pos = jnp.clip(lo, 0, g.m - 1)
    found = (lo < g.seg[eu + 1]) & (g.dst[pos] == ev) & (g.src[pos] == eu)
    return jnp.where(found, pos, -1).astype(jnp.int32)
