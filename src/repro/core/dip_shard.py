"""Multi-device sharded DIP execution — the paper's "distributable" claim.

The three DIP stores are distributable by construction (§IV): their entity
axis block-distributes over P locales, giving O(NK/P) query cost.  This
module realizes that on a JAX device mesh (docs/ARCHITECTURE.md §7):

  * ``place_*`` pads the entity/slot axis of a host-built store up to a
    multiple of the shard count P and places every array with the
    ``NamedSharding`` from ``launch.sharding.pg_specs`` — bitmap rows,
    CSR ``val`` slices and inverted-CSR segments each land block-distributed
    over ``pg_entity_axes(mesh)``.
  * ``query_any_sharded`` runs the OR-semantics query under ``shard_map``:
    every device scans ONLY its local slice.
      - ``arr``: (1, K) @ (K, N/P) matvec / row scan / Pallas kernel per
        device; output stays entity-sharded — zero collectives.
      - ``list`` / ``listd``: slot shards don't align with entity shards at
        the boundaries, so each device scatters its local hits into a full
        (n,) int8 partial mask and ONE ``pmax`` all-reduce ORs them (the
        single mask-combination collective the executor's contract names;
        1 byte/entity, overflow-free at any P).

Padding is harmless by construction: pad slots scatter out of range (list)
or carry ``slot_idx >= nnz`` and are masked (listd); pad bitmap columns are
zero and are sliced off the output.  Every sharded query is
bitwise-identical to its single-device counterpart (tests/test_shard_pg.py
proves it on 8 virtual devices).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import bitplane
from repro.core.di import DIGraph
from repro.core.dip_arr import DIPArr
from repro.core.dip_list import DIPList
from repro.core.dip_listd import DIPListD

__all__ = [
    "ShardedDIPArr",
    "ShardedDIPList",
    "ShardedDIPListD",
    "place_graph",
    "place_store",
    "place_column",
    "query_any_sharded",
    "query_any_batched_sharded",
    "query_any_words_sharded",
    "query_any_batched_words_sharded",
]


def _axes(mesh):
    from repro.launch.sharding import pg_entity_axes

    return pg_entity_axes(mesh)


def _shards(mesh) -> int:
    from repro.launch.sharding import pg_entity_shards

    return pg_entity_shards(mesh)


def _pad_to(x, size: int, fill=0):
    """Pad axis 0 to ``size``.  Host (numpy) inputs pad host-side — the
    O(NK/P) placement contract: the dense form must never materialize on a
    device (``jnp.pad`` on a numpy array would upload it whole)."""
    if x.shape[0] == size:
        return x
    xp = np if isinstance(x, np.ndarray) else jnp
    return xp.pad(x, [(0, size - x.shape[0])] + [(0, 0)] * (x.ndim - 1),
                  constant_values=fill)


# --------------------------------------------------------------- sharded stores
@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bitmap"],
    meta_fields=["k", "n", "n_pad", "mesh", "packed"],
)
@dataclasses.dataclass(frozen=True)
class ShardedDIPArr:
    """DIP-ARR bitmap padded to ``(k, n_pad)`` (n_pad = P⌈n/P⌉) and placed
    ``P(None, entity_axes)`` — K resident everywhere, entities split.

    Packed form shards the WORD axis instead: ``(k, W_pad)`` uint32 with
    ``W_pad = P⌈W/P⌉`` words (``n_pad = 32·W_pad``), so entity ownership
    stays word-aligned — each device owns whole words and a sharded word
    mask is the sharded entity mask, 1 bit/entity."""

    bitmap: jax.Array  # (k, n_pad) int8 OR (k, n_pad/32) uint32, sharded
    k: int
    n: int  # logical entity count (columns/bits ≥ n are zero padding)
    n_pad: int
    mesh: jax.sharding.Mesh
    packed: bool = False


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["val", "slot_entity"],
    meta_fields=["k", "n", "nnz", "nnz_pad", "mesh"],
)
@dataclasses.dataclass(frozen=True)
class ShardedDIPList:
    """DIP-LIST CSR with ``val``/``slot_entity`` padded to nnz_pad and slot-
    sharded.  Pad slots carry ``slot_entity = n`` (out of range), so the
    query's ``mode='drop'`` scatter discards them for free — no validity
    array needed.  The CSR ``off`` stays host-side: the sharded query
    scatters by ``slot_entity`` and never reads per-entity offsets."""

    val: jax.Array  # (nnz_pad,) int32, slot-sharded
    slot_entity: jax.Array  # (nnz_pad,) int32, slot-sharded; pad slots = n
    k: int
    n: int
    nnz: int  # logical slot count (slots ≥ nnz are padding)
    nnz_pad: int
    mesh: jax.sharding.Mesh


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["a_off", "a_ent", "slot_idx"],
    meta_fields=["k", "n", "nnz", "nnz_pad", "mesh"],
)
@dataclasses.dataclass(frozen=True)
class ShardedDIPListD:
    """DIP-LISTD's inverted CSR, slot-sharded.  Only the query-side arrays
    ship to devices: the linked-chain pointer arrays stay host-side (the
    pointer chase is inherently sequential — §VI-B — and is exactly what the
    inverted layout replaces; see docs/ARCHITECTURE.md §2)."""

    a_off: jax.Array  # (k+1,) int32, replicated
    a_ent: jax.Array  # (nnz_pad,) int32, slot-sharded (attribute-major)
    slot_idx: jax.Array  # (nnz_pad,) int32 global slot index, slot-sharded
    k: int
    n: int
    nnz: int
    nnz_pad: int
    mesh: jax.sharding.Mesh


ShardedStore = Union[ShardedDIPArr, ShardedDIPList, ShardedDIPListD]

_ARR_IMPLS = ("matvec", "scan", "kernel")


# ------------------------------------------------------------------- placement
def _put(x: jax.Array, mesh, spec: P) -> jax.Array:
    """Place with ``spec``, falling back to replication when the leading dim
    doesn't divide the shard count (NamedSharding placement requires even
    shards; the DIP stores avoid this by padding, but the DI arrays and
    property columns keep their exact logical sizes — same divisible-or-
    replicate gate as ``launch.sharding.gnn_batch_specs``)."""
    if spec != P() and x.ndim >= 1 and x.shape[0] % _shards(mesh) != 0:
        spec = P()
    return jax.device_put(x, NamedSharding(mesh, spec))


def place_column(col: jax.Array, mesh) -> jax.Array:
    """Entity-shard a (n,)/(m,) column (typed property / valid mask)."""
    from repro.launch.sharding import pg_prop_spec

    return _put(col, mesh, pg_prop_spec(mesh))


def place_graph(g: DIGraph, mesh) -> DIGraph:
    """Place the DI arrays per ``pg_di_specs``: src/dst entity(edge)-sharded
    (when divisible), seg/node_map replicated."""
    from repro.launch.sharding import pg_di_specs

    specs = pg_di_specs(mesh)
    return dataclasses.replace(
        g,
        src=_put(g.src, mesh, specs["src"]),
        dst=_put(g.dst, mesh, specs["dst"]),
        seg=_put(g.seg, mesh, specs["seg"]),
        node_map=_put(g.node_map, mesh, specs["node_map"]),
    )


def _pad_multiple(mesh, size: int) -> int:
    """Smallest positive multiple of the shard count ≥ ``size`` — the padded
    extent of every sharded store axis (shard_map needs even shards)."""
    p = _shards(mesh)
    return max(-(-size // p), 1) * p


def place_store(backend: str, store, mesh) -> ShardedStore:
    """Pad + place a host-built DIP store for sharded execution."""
    if backend == "arr":
        return place_dip_arr(store, mesh)
    if backend == "list":
        return place_dip_list(store, mesh)
    if backend == "listd":
        return place_dip_listd(store, mesh)
    raise ValueError(f"unknown backend {backend!r}")


def place_dip_arr(store: DIPArr, mesh) -> ShardedDIPArr:
    from repro.launch.sharding import pg_arr_specs

    xp = np if isinstance(store.bitmap, np.ndarray) else jnp
    if store.packed:
        # shard the WORD axis: pad to P whole words, n_pad = 32·W_pad bits.
        # Pad words are zero ⇒ pad bits are zero — same invariant as byte
        # pad columns, no epilogue masking anywhere downstream.
        from repro.launch.sharding import pg_word_pad

        w = store.bitmap.shape[1]
        w_pad = pg_word_pad(mesh, store.n)
        assert w_pad >= w
        bitmap = xp.pad(store.bitmap, ((0, 0), (0, w_pad - w)))
        n_pad = w_pad * bitplane.WORD
    else:
        n_pad = _pad_multiple(mesh, store.n)
        bitmap = xp.pad(store.bitmap, ((0, 0), (0, n_pad - store.n)))
    bitmap = jax.device_put(bitmap, NamedSharding(mesh, pg_arr_specs(mesh)["bitmap"]))
    return ShardedDIPArr(bitmap=bitmap, k=store.k, n=store.n, n_pad=n_pad,
                         mesh=mesh, packed=store.packed)


def place_dip_list(store: DIPList, mesh) -> ShardedDIPList:
    from repro.launch.sharding import pg_list_specs

    specs = pg_list_specs(mesh)
    nnz_pad = _pad_multiple(mesh, store.nnz)
    put = lambda x, s: _put(x, mesh, s)
    return ShardedDIPList(
        val=put(_pad_to(store.val, nnz_pad), specs["val"]),
        # pad fill = n: out of range, so the query scatter drops pad slots
        slot_entity=put(_pad_to(store.slot_entity, nnz_pad, fill=store.n),
                        specs["slot_entity"]),
        k=store.k, n=store.n, nnz=store.nnz, nnz_pad=nnz_pad, mesh=mesh,
    )


def place_dip_listd(store: DIPListD, mesh) -> ShardedDIPListD:
    from repro.launch.sharding import pg_listd_specs

    specs = pg_listd_specs(mesh)
    nnz_pad = _pad_multiple(mesh, store.nnz)
    put = lambda x, s: _put(x, mesh, s)
    return ShardedDIPListD(
        a_off=put(store.a_off, specs["a_off"]),
        a_ent=put(_pad_to(store.a_ent, nnz_pad), specs["a_ent"]),
        # host-side arange: device_put splits it per shard, so no device
        # transiently holds the full O(nnz) index array
        slot_idx=put(np.arange(nnz_pad, dtype=np.int32), specs["a_ent"]),
        k=store.k, n=store.n, nnz=store.nnz, nnz_pad=nnz_pad, mesh=mesh,
    )


# --------------------------------------------------------------------- queries
def _local_arr(bitmap_l: jax.Array, packed: bool = False) -> DIPArr:
    """The device-local (K, N/P) bitmap slice as a DIPArr, so the per-device
    query delegates to dip_arr's impls — the OR-of-rows math lives there
    only.  Packed slices are whole words ⇒ a valid packed DIPArr over
    32·W_local entities."""
    n = bitmap_l.shape[1] * (bitplane.WORD if packed else 1)
    return DIPArr(bitmap=bitmap_l, k=bitmap_l.shape[0], n=n, packed=packed)


def _arr_local(bitmap_l: jax.Array, mask: jax.Array, impl: str):
    from repro.core import dip_arr

    return dip_arr.query_any(_local_arr(bitmap_l), mask, impl=impl)


@partial(jax.jit, static_argnames=("impl", "tile_n"))
def _arr_query_words_sharded(ss: ShardedDIPArr, mask: jax.Array, *, impl: str,
                             tile_n: int = 2048) -> jax.Array:
    """Packed sharded query → (ceil(n/32),) uint32, word-sharded output,
    zero collectives (each device ORs its own word slice)."""
    ax = _axes(ss.mesh)
    if impl == "kernel":
        from repro.kernels.bitmap_query import ops as _ops

        out = _ops.bitmap_query_packed_sharded(ss.bitmap, mask, mesh=ss.mesh)
    else:
        def local(bitmap_l, m):
            from repro.core import dip_arr

            return dip_arr.query_any_words(_local_arr(bitmap_l, packed=True), m)

        f = shard_map(local, mesh=ss.mesh, in_specs=(P(None, ax), P()),
                      out_specs=P(ax))
        out = f(ss.bitmap, mask)
    return out[: bitplane.n_words(ss.n)]


@partial(jax.jit, static_argnames=("impl", "tile_n"))
def _arr_query_batched_words_sharded(ss: ShardedDIPArr, masks: jax.Array, *,
                                     impl: str, tile_n: int = 2048) -> jax.Array:
    ax = _axes(ss.mesh)
    if impl == "kernel":
        from repro.kernels.bitmap_query import ops as _ops

        out = _ops.bitmap_query_batched_packed_sharded(ss.bitmap, masks,
                                                       mesh=ss.mesh)
    else:
        def local(bitmap_l, ms):
            from repro.core import dip_arr

            return dip_arr.query_any_batched_words(
                _local_arr(bitmap_l, packed=True), ms)

        f = shard_map(local, mesh=ss.mesh, in_specs=(P(None, ax), P()),
                      out_specs=P(None, ax))
        out = f(ss.bitmap, masks)
    return out[:, : bitplane.n_words(ss.n)]


@partial(jax.jit, static_argnames=("impl", "tile_n"))
def _arr_query_sharded(ss: ShardedDIPArr, mask: jax.Array, *, impl: str,
                       tile_n: int = 2048) -> jax.Array:
    if ss.packed:
        words = _arr_query_words_sharded(ss, mask, impl=impl, tile_n=tile_n)
        return bitplane.unpack_mask(words, ss.n)
    if impl == "kernel":
        from repro.kernels.bitmap_query import ops as _ops

        out = _ops.bitmap_query_sharded(ss.bitmap, mask, mesh=ss.mesh, tile_n=tile_n)
        return out[: ss.n]
    ax = _axes(ss.mesh)
    f = shard_map(
        partial(_arr_local, impl=impl),
        mesh=ss.mesh, in_specs=(P(None, ax), P()), out_specs=P(ax),
    )
    return f(ss.bitmap, mask)[: ss.n]


@partial(jax.jit, static_argnames=("impl", "tile_n"))
def _arr_query_batched_sharded(ss: ShardedDIPArr, masks: jax.Array, *, impl: str,
                               tile_n: int = 2048) -> jax.Array:
    if ss.packed:
        words = _arr_query_batched_words_sharded(ss, masks, impl=impl,
                                                 tile_n=tile_n)
        return bitplane.unpack_mask(words, ss.n)
    if impl == "kernel":
        from repro.kernels.bitmap_query import ops as _ops

        out = _ops.bitmap_query_batched_sharded(ss.bitmap, masks, mesh=ss.mesh,
                                                tile_n=tile_n)
        return out[:, : ss.n]
    ax = _axes(ss.mesh)

    def local(bitmap_l, ms):
        from repro.core import dip_arr

        return dip_arr.query_any_batched(_local_arr(bitmap_l), ms, impl=impl)

    f = shard_map(local, mesh=ss.mesh, in_specs=(P(None, ax), P()),
                  out_specs=P(None, ax))
    return f(ss.bitmap, masks)[:, : ss.n]


def _or_combine(part: jax.Array, ax, p: int, n: int, packed: bool) -> jax.Array:
    """OR the per-shard partial masks: the single mask-combination
    collective.  Byte path: int8 pmax (1 byte/entity).  Packed path: pack
    the local partial to words FIRST, OR-all-reduce the words (1
    bit/entity on the interconnect — the §7 claim made literal), unpack
    after."""
    if packed:
        words = bitplane.pack_mask(part > 0)
        words = bitplane.or_allreduce(words, ax, p)
        return bitplane.unpack_mask(words, n)
    return jax.lax.pmax(part, ax) > 0


@partial(jax.jit, static_argnames=("packed",))
def _list_query_sharded(ss: ShardedDIPList, mask: jax.Array, *,
                        packed: bool = False) -> jax.Array:
    ax = _axes(ss.mesh)
    p = _shards(ss.mesh)

    def local(val_l, ent_l, m):
        # hits among MY slots only; pad slots scatter to entity n → dropped
        hit = m[jnp.clip(val_l, 0, ss.k - 1)]
        part = jnp.zeros((ss.n,), jnp.int8).at[ent_l].max(
            hit.astype(jnp.int8), mode="drop"
        )
        return _or_combine(part, ax, p, ss.n, packed)

    # check_rep=False: the packed OR butterfly replicates via ppermute,
    # which the static replication checker cannot prove
    f = shard_map(local, mesh=ss.mesh,
                  in_specs=(P(ax), P(ax), P()), out_specs=P(),
                  check_rep=False)
    return f(ss.val, ss.slot_entity, mask)


@partial(jax.jit, static_argnames=("packed",))
def _listd_query_sharded(ss: ShardedDIPListD, mask: jax.Array, *,
                         packed: bool = False) -> jax.Array:
    ax = _axes(ss.mesh)
    p = _shards(ss.mesh)

    def local(ent_l, idx_l, a_off, m):
        # slot → owning attribute via the replicated inverted-CSR offsets
        a = jnp.clip(jnp.searchsorted(a_off, idx_l, side="right") - 1, 0, ss.k - 1)
        hit = m[a] & (idx_l < ss.nnz)
        part = jnp.zeros((ss.n,), jnp.int8).at[ent_l].max(
            hit.astype(jnp.int8), mode="drop"
        )
        return _or_combine(part, ax, p, ss.n, packed)

    f = shard_map(local, mesh=ss.mesh,
                  in_specs=(P(ax), P(ax), P(), P()), out_specs=P(),
                  check_rep=False)
    return f(ss.a_ent, ss.slot_idx, ss.a_off, mask)


def query_any_sharded(backend: str, ss: ShardedStore, attr_mask: jax.Array,
                      *, impl: Optional[str] = None) -> jax.Array:
    """(n,) bool OR-semantics query, distributed over the store's mesh.

    ``impl`` follows the single-device namespace; impls whose work layout is
    inherently single-device (``listd`` ``budget``/``linked``) degrade to the
    ``inverted`` slot scan — the planner's estimates still hold (the sharded
    scan is O(nnz/P))."""
    if backend == "arr":
        if (impl or "matvec") not in _ARR_IMPLS:
            raise ValueError(f"unknown impl {impl!r}")
        return _arr_query_sharded(ss, attr_mask, impl=impl or "matvec")
    packed = bitplane.packed_default()
    if backend == "list":
        return _list_query_sharded(ss, attr_mask, packed=packed)
    if backend == "listd":
        # budget/linked are single-device work layouts → inverted slot scan;
        # anything else is a typo and fails like the single-device dispatcher
        if impl not in (None, "inverted", "budget", "linked"):
            raise ValueError(f"unknown impl {impl!r}")
        return _listd_query_sharded(ss, attr_mask, packed=packed)
    raise ValueError(f"unknown backend {backend!r}")


def query_any_batched_sharded(ss: ShardedDIPArr, attr_masks: jax.Array,
                              *, impl: Optional[str] = None) -> jax.Array:
    """(Q, n) bool — the planner's fused multi-mask entry, sharded (arr only;
    other backends batch via a host loop in ``_AttrStore``)."""
    if (impl or "matvec") not in _ARR_IMPLS:
        raise ValueError(f"unknown impl {impl!r}")
    return _arr_query_batched_sharded(ss, attr_masks, impl=impl or "matvec")


def query_any_words_sharded(ss: ShardedDIPArr, attr_mask: jax.Array,
                            *, impl: Optional[str] = None) -> jax.Array:
    """(ceil(n/32),) uint32 packed query over a word-sharded plane."""
    if (impl or "matvec") not in _ARR_IMPLS:
        raise ValueError(f"unknown impl {impl!r}")
    return _arr_query_words_sharded(ss, attr_mask, impl=impl or "matvec")


def query_any_batched_words_sharded(ss: ShardedDIPArr, attr_masks: jax.Array,
                                    *, impl: Optional[str] = None) -> jax.Array:
    """(Q, ceil(n/32)) uint32 packed batched query (fused entry)."""
    if (impl or "matvec") not in _ARR_IMPLS:
        raise ValueError(f"unknown impl {impl!r}")
    return _arr_query_batched_words_sharded(ss, attr_masks, impl=impl or "matvec")
