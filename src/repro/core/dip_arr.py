"""DIP-ARR — the 2-D Boolean byte-array attribute store (§IV-C of the paper).

For each attribute there is a Boolean row of size ``x`` (= n or m depending on
whether vertices or edges are stored); storing an attribute sets ``True`` for
the entities that carry it.  Space Θ(N·K); insert O(NK/P); query O(N/P).

Chapel's ``domain(2) dmapped Block`` becomes a dense ``(K, N)`` array.  One
deliberate layout change (recorded in docs/ARCHITECTURE.md §2): we shard the
*entity* dimension only — ``P(None, entity_axes)`` — rather than both
dimensions, so a query for any attribute subset touches exclusively
locally-owned entities.  This preserves the property the paper credits for
DIP-ARR's scaling ("each locale only processes the array chunk it owns")
while keeping the K dimension (≤ a few hundred) resident everywhere.  The
multi-device realization lives in ``core.dip_shard`` (placement + shard_map
queries over ``launch.sharding.pg_arr_specs``); this module stays
single-device and pure.

Query formulations (benchmarked against each other in §Perf):
  * ``query_any_scan``   — paper-faithful row scan: ``any(bitmap[ids], axis=0)``.
  * ``query_any_matvec`` — beyond-paper: the OR-of-rows recast as an MXU matvec
    ``(mask_f32 @ bitmap_f32) > 0`` — on TPU this feeds the systolic array
    instead of the VPU and is what the Pallas ``bitmap_query`` kernel lowers to.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitplane

__all__ = [
    "DIPArr",
    "build_dip_arr",
    "build_dip_arr_host",
    "insert",
    "query_any_scan",
    "query_any_matvec",
    "query_any",
    "query_any_words",
    "query_any_batched",
    "query_any_batched_words",
    "attrs_of_entity",
    "entities_of_attr",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["bitmap"],
    meta_fields=["k", "n", "packed"],
)
@dataclasses.dataclass(frozen=True)
class DIPArr:
    """(k attributes × n entities) presence bitmap.

    Two storage layouts, selected at build time (``bitplane.packed_default``):
      * byte  — ``(k, n)`` int8 in {0, 1}: the paper's byte Boolean array,
        kept for one release behind ``REPRO_PG_BYTE_MASKS=1``.
      * packed — ``(k, ceil(n/32))`` uint32, little-endian bit order
        (entity ``e`` ↔ bit ``e & 31`` of word ``e >> 5``): 8× less HBM
        traffic on the scan path; tail padding bits are zero by invariant.

    ``packed`` is a pytree META field so jitted queries specialize per
    layout — the two never mix inside one trace.
    """

    bitmap: jax.Array  # (k, n) int8 OR (k, ceil(n/32)) uint32
    k: int
    n: int
    packed: bool = False


def build_dip_arr(entity_ids, attr_ids, *, k: int, n: int,
                  packed: bool | None = None) -> DIPArr:
    """Bulk build: flag ``bitmap[attr, entity] = 1`` for every pair.

    O(nnz) — the paper's per-entity flag write, done as one vectorized
    host-side scatter instead of mutex-guarded loop iterations (static
    graphs ⇒ bulk), then uploaded.  Builds through ``build_dip_arr_host``
    so the bitmap layout (out-of-range pairs dropped) has one definition
    for both the single-device store and the sharded placement path.
    """
    host = build_dip_arr_host(entity_ids, attr_ids, k=k, n=n, packed=packed)
    return dataclasses.replace(host, bitmap=jnp.asarray(host.bitmap))


def build_dip_arr_host(entity_ids, attr_ids, *, k: int, n: int,
                       packed: bool | None = None) -> DIPArr:
    """``build_dip_arr`` with HOST (numpy) storage — same bitmap, no device
    allocation.  The sharded path builds here, derives the per-attribute
    stats, then places only the padded shards on devices
    (docs/ARCHITECTURE.md §7), so no device ever holds the full replica.

    The packed build scatters single-bit ORs straight into the word plane —
    no transient ``(k, n)`` byte array is ever materialized."""
    import numpy as np

    if packed is None:
        packed = bitplane.packed_default()
    entity_ids = np.asarray(entity_ids, np.int32).ravel()
    attr_ids = np.asarray(attr_ids, np.int32).ravel()
    ok = (entity_ids >= 0) & (entity_ids < n) & (attr_ids >= 0) & (attr_ids < k)
    if packed:
        ent, att = entity_ids[ok], attr_ids[ok]
        plane = np.zeros((k, bitplane.n_words(n)), np.uint32)
        np.bitwise_or.at(plane, (att, ent >> 5), np.uint32(1) << (ent & 31))
        return DIPArr(bitmap=plane, k=k, n=n, packed=True)
    bitmap = np.zeros((k, n), np.int8)
    bitmap[attr_ids[ok], entity_ids[ok]] = 1  # mode="drop" equivalent
    return DIPArr(bitmap=bitmap, k=k, n=n, packed=False)


def insert(dip: DIPArr, entity_ids, attr_ids) -> DIPArr:
    """Functional bulk insert of additional (entity, attribute) pairs."""
    ent = jnp.asarray(entity_ids, jnp.int32)
    att = jnp.asarray(attr_ids, jnp.int32)
    if dip.packed:
        # XLA scatter has no bitwise-or combiner (max on words is NOT or),
        # so round-trip through bits.  Insert is the cold pre-seal path —
        # bulk loads go through build_dip_arr_host's direct word scatter.
        bits = bitplane.unpack_mask(dip.bitmap, dip.n)
        bits = bits.at[att, ent].set(True, mode="drop")
        return dataclasses.replace(dip, bitmap=bitplane.pack_mask(bits))
    bitmap = dip.bitmap.at[att, ent].set(1, mode="drop")
    return dataclasses.replace(dip, bitmap=bitmap)


@jax.jit
def query_any_words(dip: DIPArr, attr_mask: jax.Array) -> jax.Array:
    """Packed query, packed result: (k,) bool → (W,) uint32 words.

    OR-of-selected-rows is pure word arithmetic — select via a full-word
    AND mask, then a bitwise-or tree over K.  8× fewer bytes stream from
    HBM than the byte scan; no unpack until the propagation boundary.
    """
    assert dip.packed, "query_any_words requires a packed store"
    sel = jnp.where(attr_mask[:, None], dip.bitmap, jnp.uint32(0))
    return bitplane.or_reduce(sel, axis=0)


@jax.jit
def query_any_batched_words(dip: DIPArr, attr_masks: jax.Array) -> jax.Array:
    """Q packed queries in one launch: (Q, K) bool → (Q, W) uint32."""
    assert dip.packed, "query_any_batched_words requires a packed store"
    sel = jnp.where(attr_masks[:, :, None], dip.bitmap[None], jnp.uint32(0))
    return bitplane.or_reduce(sel, axis=1)


@jax.jit
def query_any_scan(dip: DIPArr, attr_mask: jax.Array) -> jax.Array:
    """Paper-faithful query: scan each selected attribute row, OR into the
    output mask.  ``attr_mask`` is the (k,) bool query (OR semantics, §VI)."""
    if dip.packed:
        return bitplane.unpack_mask(query_any_words(dip, attr_mask), dip.n)
    sel = dip.bitmap.astype(jnp.bool_) & attr_mask[:, None]
    return jnp.any(sel, axis=0)


@jax.jit
def query_any_matvec(dip: DIPArr, attr_mask: jax.Array) -> jax.Array:
    """Beyond-paper query: OR-of-rows as a matvec on the MXU.

    counts[e] = Σ_a mask[a]·bitmap[a,e]  ⇒  mask_out = counts > 0.
    bf16 is safe: counts ≤ k ≤ a few hundred, exactly representable.
    On a packed store there is no MXU trick for word-OR, so "matvec"
    degrades to the word reduction (still the bandwidth winner).
    """
    if dip.packed:
        return bitplane.unpack_mask(query_any_words(dip, attr_mask), dip.n)
    q = attr_mask.astype(jnp.bfloat16)
    counts = q @ dip.bitmap.astype(jnp.bfloat16)
    return counts > 0


def query_any(dip: DIPArr, attr_mask: jax.Array, *, impl: str = "matvec") -> jax.Array:
    if impl == "scan":
        return query_any_scan(dip, attr_mask)
    if impl == "matvec":
        return query_any_matvec(dip, attr_mask)
    if impl == "kernel":  # Pallas bitmap_query kernel (interpret mode on CPU)
        from repro.kernels.bitmap_query import ops as _ops

        if dip.packed:
            return bitplane.unpack_mask(
                _ops.bitmap_query_packed(dip.bitmap, attr_mask), dip.n)
        return _ops.bitmap_query(dip.bitmap, attr_mask)
    raise ValueError(f"unknown impl {impl!r}")


@jax.jit
def query_any_batched_matvec(dip: DIPArr, attr_masks: jax.Array) -> jax.Array:
    """Q OR-queries as one MXU matmul: ``(Q, K) @ (K, N) > 0`` — the bitmap
    streams from HBM once for all Q masks (the pattern planner's fusion)."""
    if dip.packed:
        return bitplane.unpack_mask(
            query_any_batched_words(dip, attr_masks), dip.n)
    q = attr_masks.astype(jnp.bfloat16)
    counts = q @ dip.bitmap.astype(jnp.bfloat16)
    return counts > 0


def query_any_batched(dip: DIPArr, attr_masks: jax.Array, *, impl: str = "matvec") -> jax.Array:
    """attr_masks: (Q, K) bool → (Q, N) bool, one launch for all Q queries."""
    if impl == "matvec":
        return query_any_batched_matvec(dip, attr_masks)
    if impl == "scan":
        return jax.vmap(query_any_scan, in_axes=(None, 0))(dip, attr_masks)
    if impl == "kernel":
        from repro.kernels.bitmap_query import ops as _ops

        if dip.packed:
            return bitplane.unpack_mask(
                _ops.bitmap_query_batched_packed(dip.bitmap, attr_masks), dip.n)
        return _ops.bitmap_query_batched(dip.bitmap, attr_masks)
    raise ValueError(f"unknown impl {impl!r}")


@jax.jit
def attrs_of_entity(dip: DIPArr, e: jax.Array) -> jax.Array:
    """Column read: (k,) bool of attributes held by entity ``e`` (Fig. 4:
    'to extract the value stored for a given vertex or edge')."""
    if dip.packed:
        word = dip.bitmap[:, e >> 5]
        return ((word >> (e & 31).astype(jnp.uint32)) & 1).astype(jnp.bool_)
    return dip.bitmap[:, e].astype(jnp.bool_)


@jax.jit
def entities_of_attr(dip: DIPArr, a: jax.Array) -> jax.Array:
    """Row read: (n,) bool of entities carrying attribute ``a``."""
    if dip.packed:
        return bitplane.unpack_mask(dip.bitmap[a, :], dip.n)
    return dip.bitmap[a, :].astype(jnp.bool_)
