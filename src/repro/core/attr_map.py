"""Attribute string→integer remapping (§V step 1 of the paper).

Arkouda performs the "remap attribute values to an integer identifier" step with
its string/groupby machinery on the host; the device-side DIP stores only ever
see dense int ids.  This module is the host-side equivalent: a stable,
order-preserving interning table with numpy-vectorized encode/decode.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Union

import numpy as np

__all__ = ["AttributeMap"]


class AttributeMap:
    """Stable bidirectional map ``attribute value (str) <-> dense int id``.

    Ids are assigned in first-seen order; the table only grows (static property
    graphs never retire attributes).  ``decode`` uses the "sorted array" lookup
    the paper describes for DIP-ARR row recovery (Fig. 4 caption) — here it is a
    plain list index because ids are dense.
    """

    def __init__(self, values: Iterable[str] = ()):  # noqa: D401
        self._to_id: Dict[str, int] = {}
        self._to_val: List[str] = []
        if values:
            self.encode(list(values))

    # -- encoding ---------------------------------------------------------
    def encode(self, values: Union[str, Sequence[str], np.ndarray]) -> np.ndarray:
        """Intern value(s); returns int32 id array (scalar input → shape ())."""
        scalar = isinstance(values, str)
        vals = [values] if scalar else list(np.asarray(values, dtype=object).ravel())
        out = np.empty(len(vals), dtype=np.int32)
        to_id = self._to_id
        to_val = self._to_val
        for i, v in enumerate(vals):
            v = str(v)
            ident = to_id.get(v)
            if ident is None:
                ident = len(to_val)
                to_id[v] = ident
                to_val.append(v)
            out[i] = ident
        return out[0] if scalar else out

    def lookup(self, values: Union[str, Sequence[str]]) -> np.ndarray:
        """Encode without interning; unknown values map to -1 (empty query)."""
        scalar = isinstance(values, str)
        vals = [values] if scalar else list(values)
        out = np.array([self._to_id.get(str(v), -1) for v in vals], dtype=np.int32)
        return out[0] if scalar else out

    # -- decoding ---------------------------------------------------------
    def decode(self, ids: Union[int, Sequence[int], np.ndarray]) -> Union[str, List[str]]:
        if np.isscalar(ids) or getattr(ids, "ndim", 1) == 0:
            return self._to_val[int(ids)]
        return [self._to_val[int(i)] for i in np.asarray(ids).ravel()]

    # -- protocol ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._to_val)

    def __contains__(self, value: str) -> bool:
        return str(value) in self._to_id

    @property
    def values(self) -> List[str]:
        return list(self._to_val)

    def mask(self, values: Union[str, Sequence[str]], k: int) -> np.ndarray:
        """Boolean (k,) query mask over the attribute set — the device-side
        query format.  Unknown values are simply absent from the mask, and so
        are ids ≥ k: a store sealed at ``k`` attributes can be queried for
        values interned later (the overlay's delta buffers answer those)."""
        ids = np.atleast_1d(self.lookup(values))
        mask = np.zeros(k, dtype=bool)
        mask[ids[(ids >= 0) & (ids < k)]] = True
        return mask
