"""PropGraph — the user-facing property-graph API (mirrors Arachne's Python surface).

Workflow (§V of the paper):

    pg = PropGraph(backend="arr")                      # ar.PropGraph()
    pg.add_edges_from(src, dst)                        # bulk DI build
    pg.add_node_labels(nodes, labels)                  # strings ok
    pg.add_edge_relationships(esrc, edst, rels)
    pg.add_node_properties("age", nodes, ages)         # typed columns
    vmask = pg.query_labels(["person", "place"])       # OR semantics
    emask = pg.query_relationships(["follows"])
    sub, kept = pg.subgraph(labels=[...], relationships=[...])

Ingestion follows the paper's three steps: (1) attribute values remapped to
dense int ids (`AttributeMap`), (2) internal vertex/edge indices generated
(vertex normalization + `edge_lookup` binary search), (3) bulk insert into the
chosen DIP backend.  Backends: ``arr`` (DIP-ARR bitmap), ``list`` (DIP-LIST
CSR), ``listd`` (DIP-LISTD linked chains + inverted CSR).

Distribution (docs/ARCHITECTURE.md §7): ``PropGraph(backend=..., mesh=...)``
opts into multi-device execution via ``core.dip_shard`` and the
``launch.sharding.pg_specs`` family.  The DIP stores — the heavy query-side
data — are padded to the shard count and always entity-sharded, and every
query runs under ``shard_map`` so each device scans only its N/P entity
slice.  DI arrays and typed property columns keep their exact logical sizes:
they shard when their length divides the device count and replicate
otherwise (explicit placements require even shards).  Results are
bitwise-identical to the default single-device path.
"""
from __future__ import annotations

import functools
import operator
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane, dip_arr, dip_list, dip_listd, dip_shard
from repro.core.attr_map import AttributeMap
from repro.core.di import DIGraph, build_di, edge_lookup
from repro.core.queries import extract_subgraph, filtered_bfs, induce_edge_mask
from repro.obs.metrics import GLOBAL as _OBS
from repro.obs.metrics import SIZE_BUCKETS as _SIZE_BUCKETS
from repro.obs.metrics import enabled as _obs_enabled
from repro.overlay.delta import AttrDelta, EdgeDelta, MutationEvent, pair_keys


def _obs_traverse(op: str, rounds: Optional[int], seeds: Optional[int]) -> None:
    """Frontier/semiring engine accounting (docs/ARCHITECTURE.md §13):
    per-op run counts plus the host-known shape of the work — relax-round
    budgets and seed-set sizes.  The exact converged round count lives
    inside a jitted ``while_loop``; reading it back would force a device
    sync per call, so the budget (``k``/``max_iters``, the loop's bound)
    is what's recorded.  Host-side only, never a device sync."""
    if not _obs_enabled():
        return
    _OBS.counter("pg_traverse_runs", "frontier/semiring engine runs",
                 op=op).inc()
    if rounds is not None:
        _OBS.histogram("pg_traverse_relax_rounds",
                       "relax-round budget per run (loop bound)",
                       buckets=_SIZE_BUCKETS, op=op).observe(rounds)
    if seeds is not None:
        _OBS.histogram("pg_traverse_seed_size",
                       "seed/frontier-origin set size per run",
                       buckets=_SIZE_BUCKETS, op=op).observe(seeds)

__all__ = ["PropGraph", "BACKENDS"]

BACKENDS = ("arr", "list", "listd")


def _write_locked(fn):
    """Serialize a mutator (or ``compact``) on the per-graph write lock.

    Writes and compaction are mutually exclusive: ``compact_propgraph``
    gathers the overlay, rebuilds, then swaps the stores — a mutation
    landing inside that window would be silently discarded by the swap, so
    every path that changes graph state takes the same re-entrant lock
    (re-entrant because ``insert_edges`` falls back to ``add_edges_from``
    and ``compact`` runs nested helpers).  Readers stay lock-free: the
    service layer re-checks ``version`` around execution and retries torn
    views, and ``snapshot()`` clones under the lock for a consistent pin."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._write_lock:
            return fn(self, *args, **kwargs)

    return wrapper


class _AttrStore:
    """One DIP instance over ``n_entities`` (vertices or edges).

    With ``mesh`` set, ``finalize_sharded()`` additionally maintains a padded,
    device-placed copy of the store (``core.dip_shard``) and the query paths
    run under ``shard_map``; both caches invalidate together on ``insert``.

    LSM write path (docs/ARCHITECTURE.md §11): the first query *seals* the
    base (dense device store or sharded placement, built at ``_k_base``
    attribute rows).  Later inserts land in ``_delta`` — a small append-only
    host buffer — in O(batch) instead of invalidating and rebuilding the
    O(N·K) dense form.  Queries answer ``base_mask | delta_mask``, exact
    stats come from ``attr_counts`` (base counts + delta counts deduped
    against ``base_keys``), and the overlay compactor folds the delta back
    into the pair lists before a fresh seal.  ``out_n`` is the query result
    length: it tracks the EFFECTIVE entity universe (base + delta edges for
    the edge store) while ``n`` stays the sealed base's row count.
    """

    def __init__(self, backend: str, n_entities: int, mesh=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.backend = backend
        self.n = n_entities
        self.out_n = n_entities
        self.mesh = mesh
        self.amap = AttributeMap()
        self._pairs_e: List[np.ndarray] = []  # entity ids, insertion order
        self._pairs_a: List[np.ndarray] = []  # attribute ids
        self._store = None
        self._sharded = None
        self._host = None  # host-built dense form awaiting upload/placement
        self._counts: Optional[np.ndarray] = None
        self._dirty = True
        self._delta = AttrDelta()  # pairs landed after the base was sealed
        self._k_base: Optional[int] = None  # attribute rows in the sealed base
        self._base_keys: Optional[np.ndarray] = None  # sorted base pair keys

    @property
    def sealed(self) -> bool:
        """A device/sharded base exists — inserts must not invalidate it."""
        return self._store is not None or self._sharded is not None

    @property
    def packed(self) -> bool:
        """True when this store's base holds (or will hold) the bit-packed
        uint32 word plane (arr only).  Captured at build time — a built
        store answers from its own layout even if the process-wide flag
        flips afterwards."""
        if self.backend != "arr":
            return False
        for built in (self._store, self._sharded, self._host):
            if built is not None:
                return bool(built.packed)
        return bitplane.packed_default()

    def insert(self, entity_ids: np.ndarray, values: Sequence[str]) -> None:
        attr_ids = self.amap.encode(values)
        attr_ids = np.broadcast_to(np.atleast_1d(attr_ids), np.shape(entity_ids)).ravel()
        entity_ids = np.asarray(entity_ids, np.int32).ravel()
        ok = entity_ids >= 0  # unmatched edge rows (edge_lookup -1) are dropped
        ent, att = entity_ids[ok], attr_ids[ok].astype(np.int32)
        if self.sealed:
            # LSM path: the sealed base is immutable — O(batch) delta append,
            # no store invalidation, no rebuild
            self._delta.append(ent, att)
            return
        # pre-seal: entities beyond the base universe (delta edges) can never
        # enter the n-row dense build — they live in the delta regardless
        hi = ent >= self.n
        if hi.any():
            self._delta.append(ent[hi], att[hi])
            ent, att = ent[~hi], att[~hi]
        self._pairs_e.append(ent)
        self._pairs_a.append(att)
        self._counts = None
        self._host = None
        self._dirty = True
        self._base_keys = None

    @property
    def k(self) -> int:
        return max(len(self.amap), 1)

    def _build_host(self):
        """Dense store with HOST (numpy) arrays, built from the raw pairs.

        Also derives the per-attribute selectivity stats (``attr_counts``)
        while the dense form is in hand — bitmap row sums / CSR segment
        lengths, computed host-side so the stats never require a device
        store.  The build is stashed in ``_host`` so a stats read followed
        by a query builds once, not twice; ``finalize`` /
        ``finalize_sharded`` consume the stash — after placement the dense
        copy is RELEASED in mesh mode (per-device memory stays O(NK/P),
        docs/ARCHITECTURE.md §7)."""
        if self._host is not None:
            return self._host
        ent = np.concatenate(self._pairs_e) if self._pairs_e else np.zeros(0, np.int32)
        att = np.concatenate(self._pairs_a) if self._pairs_a else np.zeros(0, np.int32)
        if self.backend == "arr":
            host = dip_arr.build_dip_arr_host(ent, att, k=self.k, n=self.n)
            if host.packed:
                # popcount of the word plane rows ≡ the byte row sums
                self._counts = np.bitwise_count(host.bitmap).sum(
                    axis=1, dtype=np.int64)
            else:
                self._counts = host.bitmap.sum(axis=1, dtype=np.int64)
        elif self.backend == "list":
            host = dip_list.build_dip_list_host(ent, att, k=self.k, n=self.n)
            self._counts = np.bincount(np.asarray(host.val), minlength=self.k)
        else:
            host = dip_listd.build_dip_listd_host(ent, att, k=self.k, n=self.n)
            self._counts = np.asarray(host.a_off[1:] - host.a_off[:-1])
        self._host = host
        self._k_base = self.k  # the row count this base answers queries at
        return host

    def finalize(self):
        if not self._dirty and self._store is not None:
            return self._store
        self._store = jax.tree_util.tree_map(jnp.asarray, self._build_host())
        self._host = None  # consumed; the device copy is the cache now
        self._dirty = False
        return self._store

    def finalize_sharded(self):
        """Padded, mesh-placed copy of the store (mesh mode only).

        Builds the dense form host-side, places the padded shards, and
        releases the dense copy — no device (and no cache slot) holds a
        full replica; the selectivity stats survive in ``_counts``."""
        if self._sharded is None:
            self._sharded = dip_shard.place_store(
                self.backend, self._build_host(), self.mesh
            )
            self._host = None  # dense copy released after placement
        return self._sharded

    def known_ids(self, values: Sequence[str]) -> np.ndarray:
        """Interned attribute ids for ``values`` (unknown values dropped)."""
        ids = np.atleast_1d(self.amap.lookup(list(values)))
        return ids[ids >= 0].astype(np.int32)

    def base_keys(self) -> np.ndarray:
        """Sorted unique packed (entity, attribute) keys of the BASE pairs —
        the dedup reference ``attr_counts`` uses so re-inserting a pair that
        already sits in the sealed base never double-counts."""
        if self._base_keys is None:
            ent = np.concatenate(self._pairs_e) if self._pairs_e else np.zeros(0, np.int32)
            att = np.concatenate(self._pairs_a) if self._pairs_a else np.zeros(0, np.int32)
            self._base_keys = np.unique(pair_keys(ent, att))
        return self._base_keys

    def all_pairs(self) -> Tuple[np.ndarray, np.ndarray]:
        """Full (entity, attribute) pair history, base ++ delta, insertion
        order preserved — what the compactor folds into a fresh base."""
        de, da = self._delta.cat()
        ent = self._pairs_e + ([de] if de.size else [])
        att = self._pairs_a + ([da] if da.size else [])
        if not ent:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        return np.concatenate(ent), np.concatenate(att)

    def attr_counts(self, *, dead_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """(k,) per-attribute entity counts — the DIP selectivity statistics
        the planner orders joins with (bitmap row sums / CSR segment
        lengths; each store carries them for free).  Derived host-side
        during ``_build_host`` — reading them never uploads a store — and
        invalidated with the store (``insert`` clears them).  With a live
        delta, the sealed base's counts are padded to the current attribute
        set and the delta's (base-deduped) counts add in — still exact, so
        the planner never orders joins with stale or estimated stats.

        ``dead_ids`` (sorted or not) subtracts the contributions of
        tombstoned entities, so counts agree with what ``query_any`` masked
        by the alive masks actually returns — ``PropGraph.label_counts`` /
        ``relationship_counts`` and the planner pass the tombstone set."""
        if self._counts is None:
            self._build_host()  # sets _counts; build stays stashed for the
            # next finalize, so stats-then-query builds once
        counts = self._counts
        k = self.k
        if len(counts) < k:
            counts = np.concatenate(
                [counts, np.zeros(k - len(counts), counts.dtype)])
        if self._delta.size:
            counts = counts + self._delta.counts(k, self.base_keys())
        if dead_ids is not None and np.asarray(dead_ids).size:
            counts = counts - self._dead_attr_counts(np.asarray(dead_ids))
        return counts

    def _dead_attr_counts(self, dead_ids: np.ndarray) -> np.ndarray:
        """(k,) per-attribute pair counts held by tombstoned entities.

        Mirrors ``attr_counts``'s accounting exactly — base pairs counted
        the way the backend stores them (``listd`` keeps duplicate pairs,
        ``arr``/``list`` dedupe) plus the delta's base-deduped unique pairs
        — so subtracting it yields the alive-only statistic."""
        k = self.k
        out = np.zeros(k, np.int64)
        ent = np.concatenate(self._pairs_e) if self._pairs_e else np.zeros(0, np.int32)
        att = np.concatenate(self._pairs_a) if self._pairs_a else np.zeros(0, np.int32)
        if ent.size:
            if self.backend != "listd":
                keys = np.unique(pair_keys(ent, att))
                ent = (keys >> 31).astype(np.int64)
                att = (keys & 0x7FFFFFFF).astype(np.int64)
            sel = np.isin(ent, dead_ids)
            if sel.any():
                out += np.bincount(att[sel], minlength=k)[:k]
        if self._delta.size:
            de, da = self._delta.cat()
            keys = np.unique(pair_keys(de, da))
            bk = self.base_keys()
            if bk.size:
                pos = np.clip(np.searchsorted(bk, keys), 0, bk.size - 1)
                keys = keys[bk[pos] != keys]
            sel = np.isin((keys >> 31).astype(np.int64), dead_ids)
            if sel.any():
                out += np.bincount(
                    (keys[sel] & 0x7FFFFFFF).astype(np.int64), minlength=k)[:k]
        return out

    @property
    def nnz(self) -> int:
        """Stored (entity, attribute) pair count (post-dedupe where the
        backend dedupes) — Σ attr_counts, so reading it needs no store."""
        return int(np.sum(self.attr_counts()))

    def _pad_to_out(self, mask: jax.Array) -> jax.Array:
        """Extend a (n,)-row base result to the effective universe: entities
        past the sealed base (delta edges) hold no base attributes."""
        if self.out_n > int(mask.shape[0]):
            mask = jnp.concatenate(
                [mask, jnp.zeros((self.out_n - int(mask.shape[0]),), mask.dtype)])
        return mask

    def _query_base(self, values: Sequence[str], *, impl: Optional[str] = None) -> jax.Array:
        """(n,) bool over the sealed base only.  The query mask is built at
        ``_k_base`` — values interned after the seal are invisible here (the
        delta union answers them)."""
        if self.mesh is not None:
            sharded = self.finalize_sharded()
            mask = jnp.asarray(self.amap.mask(values, self._k_base))
            return dip_shard.query_any_sharded(
                self.backend, sharded, mask, impl=impl
            )
        store = self.finalize()
        mask = jnp.asarray(self.amap.mask(values, self._k_base))
        if self.backend == "arr":
            return dip_arr.query_any(store, mask, impl=impl or "matvec")
        if self.backend == "list":
            return dip_list.query_any(store, mask)
        if impl == "budget":
            ids = self.known_ids(values)
            ids = ids[ids < self._k_base]  # delta-only values have no chain
            if ids.size == 0:
                return jnp.zeros((self.n,), jnp.bool_)
            a_off = np.asarray(store.a_off)
            budget = int((a_off[ids + 1] - a_off[ids]).sum())
            budget = max(-(-budget // 128) * 128, 128)  # lane-aligned, ≥1 tile
            return dip_listd.query_any_budget(store, jnp.asarray(ids), budget=budget)
        return dip_listd.query_any(store, mask, impl=impl or "inverted")

    def query_any(self, values: Sequence[str], *, impl: Optional[str] = None) -> jax.Array:
        ids = self.known_ids(values) if len(values) else np.zeros(0, np.int32)
        if ids.size == 0:
            # degenerate query (empty list / all-unknown values): the answer
            # is definitionally empty — skip the store entirely
            return jnp.zeros((self.out_n,), jnp.bool_)
        out = self._pad_to_out(self._query_base(values, impl=impl))
        if self._delta.size:
            # LSM read union, composed BEFORE any propagation consumes it
            dmask = self._delta.mask(ids, self.out_n)
            if dmask.any():
                out = out | jnp.asarray(dmask)
        return out

    def query_any_batched(
        self, values_list: Sequence[Sequence[str]], *, impl: Optional[str] = None
    ) -> jax.Array:
        """(Q, out_n) bool — Q OR-queries in one shot.  On the ``arr`` backend
        all Q masks go through ONE matvec / Pallas-kernel launch (the
        planner's fusion path) and any delta rows OR in as a second stacked
        host mask; other backends fall back to a per-query loop."""
        if self.backend == "arr":
            if self.mesh is not None:
                sharded = self.finalize_sharded()
                masks = jnp.asarray(
                    np.stack([self.amap.mask(v, self._k_base) for v in values_list])
                )
                rows = dip_shard.query_any_batched_sharded(sharded, masks, impl=impl)
            else:
                store = self.finalize()
                masks = jnp.asarray(
                    np.stack([self.amap.mask(v, self._k_base) for v in values_list])
                )
                rows = dip_arr.query_any_batched(store, masks, impl=impl or "matvec")
            if self.out_n > int(rows.shape[1]):
                rows = jnp.concatenate(
                    [rows, jnp.zeros((rows.shape[0], self.out_n - int(rows.shape[1])),
                                     rows.dtype)], axis=1)
            if self._delta.size:
                drows = np.stack(
                    [self._delta.mask(self.known_ids(v), self.out_n)
                     for v in values_list])
                if drows.any():
                    rows = rows | jnp.asarray(drows)
            return rows
        return jnp.stack([self.query_any(v, impl=impl) for v in values_list])

    def _pad_words_to_out(self, words: jax.Array) -> jax.Array:
        """Word-space analog of ``_pad_to_out``: base tail bits past ``n``
        are zero by the build invariant, so extending to the effective
        universe is a zero-word concat — no bit surgery."""
        w_out = bitplane.n_words(self.out_n)
        if w_out > int(words.shape[-1]):
            pad_shape = words.shape[:-1] + (w_out - int(words.shape[-1]),)
            words = jnp.concatenate(
                [words, jnp.zeros(pad_shape, jnp.uint32)], axis=-1)
        return words[..., :w_out]

    def query_any_words(self, values: Sequence[str], *,
                        impl: Optional[str] = None) -> jax.Array:
        """Packed query: (ceil(out_n/32),) uint32 word mask — the executor's
        fused path keeps this packed through mask combination and unpacks
        once at the propagation boundary.  arr + packed base only."""
        assert self.packed, "query_any_words requires a packed arr store"
        ids = self.known_ids(values) if len(values) else np.zeros(0, np.int32)
        w_out = bitplane.n_words(self.out_n)
        if ids.size == 0:
            return jnp.zeros((w_out,), jnp.uint32)
        if self.mesh is not None:
            sharded = self.finalize_sharded()
            mask = jnp.asarray(self.amap.mask(values, self._k_base))
            out = dip_shard.query_any_words_sharded(sharded, mask, impl=impl)
        else:
            store = self.finalize()
            mask = jnp.asarray(self.amap.mask(values, self._k_base))
            if impl == "kernel":
                from repro.kernels.bitmap_query import ops as _ops

                out = _ops.bitmap_query_packed(store.bitmap, mask)
            else:
                out = dip_arr.query_any_words(store, mask)
        out = self._pad_words_to_out(out)
        if self._delta.size:
            dwords = self._delta.mask_words(ids, self.out_n)
            if dwords.any():
                out = out | jnp.asarray(dwords)
        return out

    def query_any_batched_words(
        self, values_list: Sequence[Sequence[str]], *,
        impl: Optional[str] = None
    ) -> jax.Array:
        """(Q, ceil(out_n/32)) uint32 — Q packed OR-queries, one launch."""
        assert self.packed, "query_any_batched_words requires a packed arr store"
        if self.mesh is not None:
            sharded = self.finalize_sharded()
            masks = jnp.asarray(
                np.stack([self.amap.mask(v, self._k_base) for v in values_list])
            )
            rows = dip_shard.query_any_batched_words_sharded(
                sharded, masks, impl=impl)
        else:
            store = self.finalize()
            masks = jnp.asarray(
                np.stack([self.amap.mask(v, self._k_base) for v in values_list])
            )
            if impl == "kernel":
                from repro.kernels.bitmap_query import ops as _ops

                rows = _ops.bitmap_query_batched_packed(store.bitmap, masks)
            else:
                rows = dip_arr.query_any_batched_words(store, masks)
        rows = self._pad_words_to_out(rows)
        if self._delta.size:
            drows = np.stack(
                [self._delta.mask_words(self.known_ids(v), self.out_n)
                 for v in values_list])
            if drows.any():
                rows = rows | jnp.asarray(drows)
        return rows

    def clone(self) -> "_AttrStore":
        """Structurally-shared copy for snapshots/views: the sealed base,
        stash, stats and pair CHUNKS are shared (all append-only or
        immutable); the chunk lists, delta chain and attribute map are
        private so parent and clone diverge without copying the base."""
        c = _AttrStore.__new__(_AttrStore)
        c.backend = self.backend
        c.n = self.n
        c.out_n = self.out_n
        c.mesh = self.mesh
        c.amap = AttributeMap(self.amap.values)
        c._pairs_e = list(self._pairs_e)
        c._pairs_a = list(self._pairs_a)
        c._store = self._store
        c._sharded = self._sharded
        c._host = self._host
        c._counts = self._counts
        c._dirty = self._dirty
        c._delta = self._delta.frozen_copy()
        c._k_base = self._k_base
        c._base_keys = self._base_keys
        return c


class PropGraph:
    """A static, directed, labeled property multigraph over the DI structure.

    ``mesh=None`` (default) runs single-device, exactly as before.  Passing a
    device mesh (e.g. ``launch.mesh.make_entity_mesh()``) distributes the
    entity axis of the DIP stores over its devices (DI arrays and property
    columns shard when divisible, replicate otherwise) — queries return the
    same masks, computed shard-locally (docs/ARCHITECTURE.md §7).
    """

    def __init__(self, backend: str = "arr", mesh=None):
        self.backend = backend
        self.mesh = mesh
        self.graph: Optional[DIGraph] = None
        self._vstore: Optional[_AttrStore] = None
        self._estore: Optional[_AttrStore] = None
        # typed property columns: name -> (values (x,), valid mask (x,))
        self.vertex_props: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        self.edge_props: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        # monotone mutation counter + observers — the service layer's cache
        # invalidation contract.  ``last_mutation`` carries the matching
        # MutationEvent so observers can invalidate by OVERLAP (a cached
        # result survives writes that cannot touch its masks) instead of
        # purging everything on every version bump (docs/ARCHITECTURE.md §11).
        self.version: int = 0
        self.last_mutation: Optional[MutationEvent] = None
        self._mutation_hooks: List = []
        # ---- overlay state (docs/ARCHITECTURE.md §11) -------------------
        self._delta_edges: Optional[EdgeDelta] = None  # structural inserts
        self._dead_v: Optional[np.ndarray] = None  # (n,) bool tombstones
        self._dead_e: Optional[np.ndarray] = None  # sorted global edge ids
        self._eff_cache: Optional[Tuple[int, DIGraph]] = None
        self._frozen = False  # snapshots refuse mutation
        # serializes mutators + compact() (see _write_locked); re-entrant,
        # never taken by the read paths
        self._write_lock = threading.RLock()

    # ----------------------------------------------------------- mutation API
    def on_mutation(self, hook) -> "PropGraph":
        """Register ``hook(pg)`` to run after every mutating call (structure
        or attributes).  Hooks fire AFTER ``version`` is bumped, so a hook
        reading ``pg.version`` sees the post-mutation value."""
        self._mutation_hooks.append(hook)
        return self

    def _bump_version(self) -> None:
        self.version += 1
        for hook in list(self._mutation_hooks):
            hook(self)

    def _check_writable(self) -> None:
        if self._frozen:
            raise RuntimeError(
                "this PropGraph is a frozen snapshot; fork() it for a "
                "writable view")

    # ------------------------------------------------------------- structure
    @_write_locked
    def add_edges_from(self, src, dst) -> "PropGraph":
        """Bulk edge ingestion → DI build (sort + normalize + SEG).

        Rebuilding the structure drops all previously attached attributes
        (fresh stores) AND the whole overlay — and, like every mutator,
        bumps ``version``.  For incremental structural growth that keeps
        attributes and costs O(batch), use ``insert_edges``."""
        self._check_writable()
        src = np.asarray(src)
        if src.size == 0 and self.graph is not None:
            return self  # no-op: nothing to rebuild from, keep caches live
        self.graph = build_di(src, np.asarray(dst))
        if self.mesh is not None:
            self.graph = dip_shard.place_graph(self.graph, self.mesh)
        self._vstore = _AttrStore(self.backend, self.graph.n, mesh=self.mesh)
        self._estore = _AttrStore(self.backend, max(self.graph.m, 1), mesh=self.mesh)
        self._delta_edges = None
        self._dead_v = None
        self._dead_e = None
        self._eff_cache = None
        self.last_mutation = MutationEvent.structural_event("add_edges_from")
        self._bump_version()
        return self

    @_write_locked
    def insert_edges(self, src, dst) -> "PropGraph":
        """O(batch) structural ingestion: append (src, dst) pairs to the edge
        delta instead of re-sorting the whole DI structure.  Endpoints must
        already exist in the vertex universe (growing it means a new
        normalization — that is ``add_edges_from``'s bulk path).  Delta
        edges get global ids ``m_base + i``; queries and analytics see them
        through the combined edge view until ``compact()`` folds them in.
        Pairs already present ALIVE (base or delta) are dropped, matching
        the DI one-structural-edge-per-(u,v) invariant.

        Tombstones behave exactly as they do after ``compact()`` made them
        physical (compaction stays transparent): a pair whose only
        occurrence is tombstoned (``delete_edges``) is re-inserted as a
        fresh BARE delta edge — the dead edge's relationships and property
        values do not carry over, just as a post-compaction re-insert
        starts clean; an endpoint tombstoned by ``delete_vertices`` raises
        ``ValueError``, just as the vertex is unknown post-compaction."""
        self._check_writable()
        if self.graph is None:
            return self.add_edges_from(src, dst)
        src = np.asarray(src).ravel()
        dst = np.asarray(dst).ravel()
        if src.size == 0:
            return self  # no-op
        u = self._vertex_internal(src)
        v = self._vertex_internal(dst)
        if (u < 0).any() or (v < 0).any():
            unknown = np.unique(np.concatenate([src[u < 0], dst[v < 0]]))
            raise ValueError(
                f"insert_edges endpoints must already exist; unknown vertices "
                f"{unknown[:10].tolist()} — use add_edges_from (bulk rebuild) "
                f"to grow the vertex universe")
        if self._dead_v is not None:
            du, dv = self._dead_v[u], self._dead_v[v]
            if du.any() or dv.any():
                gone = np.unique(np.concatenate([src[du], dst[dv]]))
                raise ValueError(
                    f"insert_edges endpoints {gone[:10].tolist()} are "
                    f"tombstoned (delete_vertices) — a deleted vertex is "
                    f"gone before and after compaction; re-add it via "
                    f"add_edges_from (bulk rebuild)")
        if self._delta_edges is None:
            self._delta_edges = EdgeDelta(self.graph.m)
        base_idx = np.asarray(edge_lookup(self.graph, jnp.asarray(u), jnp.asarray(v)))
        alive_in_base = base_idx >= 0
        if self._dead_e is not None and self._dead_e.size:
            # a tombstoned base pair no longer exists — it is insertable
            alive_in_base &= ~np.isin(base_idx, self._dead_e)
        fresh = ~alive_in_base
        added = (self._delta_edges.append(u[fresh], v[fresh], dead=self._dead_e)
                 if fresh.any() else 0)
        if added == 0:
            return self  # every pair already present: caches stay live
        self._estore.out_n = max(self.graph.m + self._delta_edges.size, 1)
        self._eff_cache = None
        self.last_mutation = MutationEvent.structural_event("insert_edges")
        self._bump_version()
        return self

    @_write_locked
    def delete_vertices(self, nodes) -> "PropGraph":
        """Tombstone vertices (and implicitly every incident edge) in the
        overlay — the base structure is untouched, so snapshots taken before
        the delete still see the vertices.  ``compact()`` makes it physical."""
        self._check_writable()
        self._require_graph()
        idx = self._vertex_internal(np.asarray(nodes).ravel())
        idx = idx[idx >= 0]
        if idx.size == 0:
            return self  # no-op
        dead = (np.zeros(self.graph.n, bool) if self._dead_v is None
                else self._dead_v.copy())  # copy-on-write: snapshots share ours
        before = int(dead.sum())
        dead[idx] = True
        if int(dead.sum()) == before:
            return self  # all already dead
        self._dead_v = dead
        self._eff_cache = None
        self.last_mutation = MutationEvent.structural_event("delete_vertices")
        self._bump_version()
        return self

    @_write_locked
    def delete_edges(self, src, dst) -> "PropGraph":
        """Tombstone individual edges (base or delta) by endpoint pair."""
        self._check_writable()
        self._require_graph()
        idx = self._edge_internal(src, dst)
        idx = idx[idx >= 0].astype(np.int32)
        if idx.size == 0:
            return self  # no-op
        cur = self._dead_e if self._dead_e is not None else np.zeros(0, np.int32)
        merged = np.unique(np.concatenate([cur, idx]))
        if merged.size == cur.size:
            return self  # all already dead
        self._dead_e = merged
        self._eff_cache = None
        self.last_mutation = MutationEvent.structural_event("delete_edges")
        self._bump_version()
        return self

    def _effective_graph(self) -> DIGraph:
        """Base DI structure ++ delta edges, as one edge-centric view.

        The combined graph keeps the base's SEG (valid for the sorted base
        prefix only) and is flagged ``unsorted`` so SEG-dependent fast paths
        route around it; everything the executor and frontier engine run is
        edge-centric and consumes it unchanged.  Cached per delta size —
        repeated queries between writes pay the concat once."""
        base = self.graph
        de = self._delta_edges
        if de is None or de.size == 0:
            return base
        if self._eff_cache is not None and self._eff_cache[0] == de.size:
            return self._eff_cache[1]
        ds, dd = de.cat()
        g = DIGraph(
            src=jnp.concatenate([base.src, jnp.asarray(ds)]),
            dst=jnp.concatenate([base.dst, jnp.asarray(dd)]),
            seg=base.seg, node_map=base.node_map,
            n=base.n, m=base.m + de.size, max_deg=-1, unsorted=True)
        self._eff_cache = (de.size, g)
        return g

    def _require_graph(self) -> DIGraph:
        if self.graph is None:
            raise RuntimeError("call add_edges_from(...) first")
        return self._effective_graph()

    def _vertex_internal(self, nodes) -> np.ndarray:
        """Original vertex ids → internal [0, n) ids (−1 if absent)."""
        g = self._require_graph()
        nm = np.asarray(g.node_map)
        nodes = np.asarray(nodes).ravel()
        pos = np.searchsorted(nm, nodes)
        pos = np.clip(pos, 0, len(nm) - 1)
        ok = nm[pos] == nodes
        return np.where(ok, pos, -1).astype(np.int32)

    def _edge_internal(self, src, dst) -> np.ndarray:
        self._require_graph()
        g = self.graph  # edge_lookup needs the SORTED base (SEG windows)
        u = self._vertex_internal(src)
        v = self._vertex_internal(dst)
        u_c = jnp.asarray(np.maximum(u, 0))
        v_c = jnp.asarray(np.maximum(v, 0))
        idx = np.asarray(edge_lookup(g, u_c, v_c))
        idx = np.where((u >= 0) & (v >= 0), idx, -1).astype(np.int32)
        if self._delta_edges is not None and self._delta_edges.size:
            miss = idx < 0
            if miss.any():
                # base misses may still be delta edges (global ids ≥ m_base)
                didx = self._delta_edges.lookup(u[miss], v[miss])
                idx[miss] = np.where((u[miss] >= 0) & (v[miss] >= 0), didx, -1)
        if self._dead_e is not None and self._dead_e.size:
            # a tombstoned edge no longer exists at (u, v): resolve to the
            # revived delta edge (insert_edges after delete_edges) if one
            # exists, else -1 — so attribute/property writes and deletes
            # address exactly what a post-compaction graph would hold
            dead_hit = np.isin(idx, self._dead_e)
            if dead_hit.any():
                if self._delta_edges is not None and self._delta_edges.size:
                    rep = self._delta_edges.lookup(u[dead_hit], v[dead_hit])
                    rep = np.where(np.isin(rep, self._dead_e), -1, rep)
                else:
                    rep = np.full(int(dead_hit.sum()), -1, np.int32)
                idx[dead_hit] = rep
        return idx

    # ------------------------------------------------------------ attributes
    @_write_locked
    def add_node_labels(self, nodes, labels) -> "PropGraph":
        self._check_writable()
        self._require_graph()
        if np.asarray(nodes).size == 0:
            return self  # no-op: nothing changes, caches stay live
        self._vstore.insert(self._vertex_internal(nodes), labels)
        self.last_mutation = MutationEvent.labels_event(labels)
        self._bump_version()
        return self

    @_write_locked
    def add_edge_relationships(self, src, dst, relationships) -> "PropGraph":
        self._check_writable()
        self._require_graph()
        if np.asarray(src).size == 0:
            return self  # no-op
        self._estore.insert(self._edge_internal(src, dst), relationships)
        self.last_mutation = MutationEvent.rels_event(relationships)
        self._bump_version()
        return self

    @_write_locked
    def add_node_properties(self, name: str, nodes, values, fill=0) -> "PropGraph":
        self._check_writable()
        g = self._require_graph()
        if np.asarray(nodes).size == 0:
            return self  # no-op
        idx = self._vertex_internal(nodes)
        vals = np.asarray(values)
        col = np.full((g.n,), fill, dtype=vals.dtype)
        valid = np.zeros((g.n,), dtype=bool)
        ok = idx >= 0
        col[idx[ok]] = vals[ok]
        valid[idx[ok]] = True
        self.vertex_props[name] = self._place_column(col, valid)
        self.last_mutation = MutationEvent.props_event(name)
        self._bump_version()
        return self

    @_write_locked
    def add_edge_properties(self, name: str, src, dst, values, fill=0) -> "PropGraph":
        self._check_writable()
        g = self._require_graph()
        if np.asarray(src).size == 0:
            return self  # no-op
        idx = self._edge_internal(src, dst)
        vals = np.asarray(values)
        col = np.full((g.m,), fill, dtype=vals.dtype)
        valid = np.zeros((g.m,), dtype=bool)
        ok = idx >= 0
        col[idx[ok]] = vals[ok]
        valid[idx[ok]] = True
        self.edge_props[name] = self._place_column(col, valid)
        self.last_mutation = MutationEvent.props_event(name)
        self._bump_version()
        return self

    @_write_locked
    def update_node_properties(self, name: str, nodes, values) -> "PropGraph":
        """Point-update an EXISTING typed column: functional scatter onto a
        fresh array, so snapshots holding the previous column are untouched.
        Unknown vertices are dropped; an unknown property is an error
        (``add_node_properties`` defines columns)."""
        self._check_writable()
        self._require_graph()
        if name not in self.vertex_props:
            raise KeyError(
                f"unknown vertex property {name!r}; add_node_properties first")
        idx = self._vertex_internal(np.asarray(nodes).ravel())
        vals = np.asarray(values).ravel()
        ok = idx >= 0
        if not ok.any():
            return self  # no-op
        col, valid = self.vertex_props[name]
        at = jnp.asarray(idx[ok])
        self.vertex_props[name] = (
            col.at[at].set(jnp.asarray(vals[ok]).astype(col.dtype)),
            valid.at[at].set(True))
        self.last_mutation = MutationEvent.props_event(name)
        self._bump_version()
        return self

    @_write_locked
    def update_edge_properties(self, name: str, src, dst, values) -> "PropGraph":
        """Point-update an existing edge column; delta edges are addressable
        too (the column pads to the effective edge count on first touch)."""
        self._check_writable()
        g = self._require_graph()
        if name not in self.edge_props:
            raise KeyError(
                f"unknown edge property {name!r}; add_edge_properties first")
        idx = self._edge_internal(src, dst)
        vals = np.asarray(values).ravel()
        ok = idx >= 0
        if not ok.any():
            return self  # no-op
        col, valid = self.edge_props[name]
        if int(col.shape[0]) < g.m:
            pad = g.m - int(col.shape[0])
            col = jnp.concatenate([col, jnp.zeros((pad,), col.dtype)])
            valid = jnp.concatenate([valid, jnp.zeros((pad,), jnp.bool_)])
        at = jnp.asarray(idx[ok])
        self.edge_props[name] = (
            col.at[at].set(jnp.asarray(vals[ok]).astype(col.dtype)),
            valid.at[at].set(True))
        self.last_mutation = MutationEvent.props_event(name)
        self._bump_version()
        return self

    def _place_column(self, col, valid) -> Tuple[jax.Array, jax.Array]:
        col, valid = jnp.asarray(col), jnp.asarray(valid)
        if self.mesh is not None:
            col = dip_shard.place_column(col, self.mesh)
            valid = dip_shard.place_column(valid, self.mesh)
        return col, valid

    # ---------------------------------------------------------- alive masks
    def _alive_vertex_mask(self) -> Optional[jax.Array]:
        """(n,) bool (False = tombstoned) or None when nothing is deleted."""
        if self._dead_v is None:
            return None
        return jnp.asarray(~self._dead_v)

    def _alive_edge_mask(self) -> Optional[jax.Array]:
        """(m_eff,) bool or None — False on tombstoned edges and on edges
        with a deleted endpoint (deleting a vertex detaches it)."""
        if self._dead_e is None and self._dead_v is None:
            return None
        g = self._require_graph()
        alive = np.ones(g.m, dtype=bool)
        if self._dead_e is not None and self._dead_e.size:
            alive[self._dead_e] = False
        mask = jnp.asarray(alive)
        av = self._alive_vertex_mask()
        if av is not None:
            mask = mask & av[g.src] & av[g.dst]
        return mask

    def _dead_vertex_ids(self) -> Optional[np.ndarray]:
        """Tombstoned internal vertex ids, or None when nothing is dead —
        the subtraction set for tombstone-exact attribute stats."""
        if self._dead_v is None:
            return None
        ids = np.flatnonzero(self._dead_v)
        return ids if ids.size else None

    def _dead_edge_ids(self) -> Optional[np.ndarray]:
        """Global ids of edges the alive mask excludes (tombstoned edges
        plus edges detached by a dead endpoint) — same universe as
        ``_alive_edge_mask``, as ids instead of a mask."""
        ae = self._alive_edge_mask()
        if ae is None:
            return None
        ids = np.flatnonzero(~np.asarray(ae))
        return ids if ids.size else None

    # --------------------------------------------------------------- queries
    def query_labels(self, labels, *, impl: Optional[str] = None) -> jax.Array:
        """(n,) bool — vertices holding ANY of ``labels`` (§VI OR semantics).
        Overlay-aware: delta-held labels OR in, tombstoned vertices AND out."""
        self._require_graph()
        out = self._vstore.query_any(labels, impl=impl)
        av = self._alive_vertex_mask()
        return out if av is None else out & av

    def query_relationships(self, relationships, *, impl: Optional[str] = None) -> jax.Array:
        """(m,) bool — edges holding ANY of ``relationships`` (effective
        edge universe: base ++ delta, minus tombstones)."""
        self._require_graph()
        out = self._estore.query_any(relationships, impl=impl)
        ae = self._alive_edge_mask()
        if ae is not None and int(ae.shape[0]) == int(out.shape[0]):
            out = out & ae
        return out

    # ------------------------------------------------- typed property masks
    _PRED_OPS = {
        "==": operator.eq,
        "!=": operator.ne,
        "<": operator.lt,
        "<=": operator.le,
        ">": operator.gt,
        ">=": operator.ge,
    }

    def _predicate_mask(
        self, cols: Dict[str, Tuple[jax.Array, jax.Array]], kind: str,
        name: str, op: str, value,
    ) -> jax.Array:
        if name not in cols:
            raise KeyError(
                f"unknown {kind} property {name!r}; known: {sorted(cols)}"
            )
        if op not in self._PRED_OPS:
            raise ValueError(f"unknown predicate op {op!r}; known: {sorted(self._PRED_OPS)}")
        if isinstance(value, str):
            # property columns are numeric typed columns; a str here would
            # silently broadcast to a scalar True/False under ==/!= instead
            # of comparing — string-valued attributes belong in labels/
            # relationships (the DIP stores), not predicates
            raise TypeError(
                f"{kind} predicate {name!r} {op} {value!r}: string comparisons "
                "are not supported on typed property columns — model "
                "string-valued attributes as labels/relationships instead"
            )
        col, valid = cols[name]
        return valid & self._PRED_OPS[op](col, value)

    def _predicate_parts(
        self, kind: str, name: str, op: str, value
    ) -> Tuple[jax.Array, jax.Array]:
        """Host-side half of a predicate: validate (same KeyError /
        ValueError / TypeError contracts as ``_predicate_mask``) and return
        the raw ``(col, valid)`` column pair — the executor's fused packed
        combine evaluates ``valid & op(col, value)`` INSIDE its single
        jitted launch instead of through a separate mask op.  Edge columns
        shorter than the effective universe are handled by the combine
        (missing rows are invalid ⇒ False), not padded here."""
        cols = self.vertex_props if kind == "node" else self.edge_props
        ckind = "vertex" if kind == "node" else "edge"
        if name not in cols:
            raise KeyError(
                f"unknown {ckind} property {name!r}; known: {sorted(cols)}"
            )
        if op not in self._PRED_OPS:
            raise ValueError(f"unknown predicate op {op!r}; known: {sorted(self._PRED_OPS)}")
        if isinstance(value, str):
            raise TypeError(
                f"{ckind} predicate {name!r} {op} {value!r}: string comparisons "
                "are not supported on typed property columns — model "
                "string-valued attributes as labels/relationships instead"
            )
        return cols[name]

    def vertex_predicate_mask(self, name: str, op: str, value) -> jax.Array:
        """(n,) bool — vertices whose typed property ``name`` compares true
        (entities without the property never match: the valid mask ANDs in;
        tombstoned vertices never match either)."""
        self._require_graph()
        out = self._predicate_mask(self.vertex_props, "vertex", name, op, value)
        av = self._alive_vertex_mask()
        return out if av is None else out & av

    def edge_predicate_mask(self, name: str, op: str, value) -> jax.Array:
        """(m_eff,) bool — edges whose typed property ``name`` compares true.
        Columns predating the current delta edges pad with False (a delta
        edge has no value until ``update_edge_properties`` touches it)."""
        g = self._require_graph()
        out = self._predicate_mask(self.edge_props, "edge", name, op, value)
        if int(out.shape[0]) < g.m:
            out = jnp.concatenate(
                [out, jnp.zeros((g.m - int(out.shape[0]),), jnp.bool_)])
        ae = self._alive_edge_mask()
        if ae is not None and int(ae.shape[0]) == int(out.shape[0]):
            out = out & ae
        return out

    # ------------------------------------------------------ pattern matching
    def match(self, pattern, *, impl: Optional[str] = None,
              profile: bool = False):
        """Declarative pattern query: ``pg.match("(a:person {age > 30})-[:follows]->(b:person)")``.

        Parses ``pattern`` (str or a pre-built ``repro.query.Pattern``),
        plans it against this graph's DIP statistics and executes the fused
        mask pipeline.  Returns a ``repro.query.MatchResult`` whose
        ``vertex_mask``/``edge_mask`` cover exactly the entities in at least
        one full match.  ``impl`` force-overrides the planner's per-mask
        implementation choice.

        ``profile=True`` returns ``(MatchResult, ProfileReport)`` instead —
        the EXPLAIN ANALYZE path (docs/ARCHITECTURE.md §13): per-stage wall
        times with the JAX compile-vs-execute split measured by a steady-
        state re-run, so it costs roughly one extra warm execution.
        """
        if profile:
            from repro.obs.profile import profile_match

            return profile_match(self, pattern, impl=impl)
        from repro.query import execute_plan, parse, plan_pattern

        pat = parse(pattern) if isinstance(pattern, str) else pattern
        return execute_plan(self, plan_pattern(self, pat, impl=impl))

    def explain(self, pattern, *, impl: Optional[str] = None) -> str:
        """The plan ``match`` would run, as a human-readable string — which
        DIP impl each mask uses, selectivity estimates, chain orientation,
        and kernel-fusion decisions."""
        from repro.query import parse, plan_pattern

        pat = parse(pattern) if isinstance(pattern, str) else pattern
        return plan_pattern(self, pat, impl=impl).describe()

    def explain_analyze(self, pattern, *, impl: Optional[str] = None):
        """EXPLAIN ANALYZE: run ``pattern`` and return a ``ProfileReport``
        — the executed plan annotated with measured per-stage times
        (parse / plan / mask materialization / propagation) and the
        first-call XLA compilation separated from device execution
        (``report.compile_ms`` / ``report.cold``).  ``report.describe()``
        renders the plan with the timing table appended."""
        from repro.obs.profile import profile_match

        return profile_match(self, pattern, impl=impl)[1]

    def subgraph(
        self,
        labels: Optional[Sequence[str]] = None,
        relationships: Optional[Sequence[str]] = None,
        *,
        impl: Optional[str] = None,
    ) -> Tuple[DIGraph, np.ndarray]:
        """Intersect label/relationship query masks into an induced subgraph."""
        g = self._require_graph()
        vmask = (
            self.query_labels(labels, impl=impl)
            if labels is not None
            else jnp.ones((g.n,), jnp.bool_)
        )
        emask = (
            self.query_relationships(relationships, impl=impl)
            if relationships is not None
            else jnp.ones((g.m,), jnp.bool_)
        )
        av = self._alive_vertex_mask()
        if av is not None:
            vmask = vmask & av
        ae = self._alive_edge_mask()
        if ae is not None and int(ae.shape[0]) == int(emask.shape[0]):
            emask = emask & ae
        return extract_subgraph(g, induce_edge_mask(g, vmask, emask))

    def bfs(
        self,
        sources,
        labels: Optional[Sequence[str]] = None,
        relationships: Optional[Sequence[str]] = None,
        max_iters: int = 64,
    ) -> jax.Array:
        """Property-filtered BFS from original-id sources; (n,) depths."""
        g = self._require_graph()
        v_ok = self.query_labels(labels) if labels is not None else None
        e_ok = self.query_relationships(relationships) if relationships is not None else None
        av = self._alive_vertex_mask()
        if av is not None:
            v_ok = av if v_ok is None else v_ok & av
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = ae if e_ok is None else e_ok & ae
        srcs = jnp.asarray(np.maximum(self._vertex_internal(sources), 0))
        return filtered_bfs(g, srcs, edge_allowed=e_ok, vertex_allowed=v_ok, max_iters=max_iters)

    # -------------------------------------------------- frontier analytics
    def khop(
        self,
        seeds,
        k: int,
        *,
        pattern=None,
        undirected: bool = False,
        impl: Optional[str] = None,
    ) -> jax.Array:
        """Vertices within ≤``k`` hops of ``seeds`` (original ids), following
        only edges the filter ``pattern`` allows — (n,) bool, seeds included.

        ``pattern`` is a node-only or single-hop filter (the same §VI masks
        ``match`` composes): for ``"(a:host)-[:flows {bytes > 0}]->(b)"``
        an edge is traversable iff it holds ``flows``, satisfies the
        predicate, its tail matches ``a`` and its head matches ``b``;
        ``<-[...]-`` walks edges in reverse; a node-only pattern confines
        the traversal to matching vertices.  ``None`` allows everything.

        ``impl``: ``None``/``"frontier"`` = the edge-centric bitmap step
        (one jitted ``while_loop``; the shard_map all-reduce path under a
        mesh); ``"csr"`` = the small-frontier CSR gather fast path —
        O(|frontier|·max_deg) per step instead of O(m) (single-device,
        forward, directed only; degrades to ``frontier`` otherwise, like
        the listd ``budget`` impl under a mesh).  All paths are
        bitwise-identical.
        """
        from repro import traverse

        g = self._require_graph()
        if impl not in (None, "frontier", "csr"):
            raise ValueError(f"unknown impl {impl!r}")
        _obs_traverse("khop", int(k), int(np.asarray(seeds).size))
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        e_ok = jnp.ones((g.m,), jnp.bool_) if e_mask is None else e_mask
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        if v_tail is not None:
            e_ok = e_ok & v_tail[tail]
        if v_head is not None:
            e_ok = e_ok & v_head[head]
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = e_ok & ae  # overlay tombstones compose pre-propagation
        ids = self._vertex_internal(seeds)
        ids = ids[ids >= 0]
        if self._dead_v is not None and ids.size:
            ids = ids[~self._dead_v[ids]]  # dead seeds don't traverse
        if (impl == "csr" and self.mesh is None and direction == 1
                and not undirected and not g.unsorted):
            # the CSR gather fast path needs valid SEG windows — a combined
            # base++delta view has none, so it degrades to the frontier step
            return traverse.khop_csr(g, ids, e_ok, k=k)
        seed_mask = jnp.zeros((g.n,), jnp.bool_).at[jnp.asarray(ids)].set(True)
        if self.mesh is not None:
            return traverse.khop_mask_sharded(
                g, seed_mask, e_ok, k=k, mesh=self.mesh,
                direction=direction, undirected=undirected)
        return traverse.khop_mask(g, seed_mask, e_ok, k=k,
                                  direction=direction, undirected=undirected)

    # -------------------------------------------------- fused sampling (§15)
    def _sampling_view(self):
        """(seg, dst, max_deg, perm) windows for the CURRENT effective
        graph.  A sorted base graph is its own view (perm None); an overlay
        combined view (``unsorted``) has no valid SEG, so the host lexsorts
        the combined endpoints ONCE per version into a sampleable CSR —
        ``perm[j]`` is the global edge id at sorted position j, the gather
        that routes per-edge filters into window space.  Cached per
        version: QPS traffic between writes pays the sort once."""
        g = self._require_graph()
        if not g.unsorted:
            return g.seg, g.dst, int(g.max_deg), None
        cache = getattr(self, "_sample_view_cache", None)
        if cache is not None and cache[0] == self.version:
            return cache[1]
        src_np = np.asarray(g.src)
        order = np.argsort(src_np, kind="stable").astype(np.int32)
        seg = np.searchsorted(src_np[order], np.arange(g.n + 1)).astype(np.int32)
        md = int((seg[1:] - seg[:-1]).max(initial=0))
        view = (jnp.asarray(seg), jnp.asarray(np.asarray(g.dst)[order]), md,
                jnp.asarray(order))
        self._sample_view_cache = (self.version, view)
        return view

    def _sample_edge_words(self, pattern, perm) -> Optional[jax.Array]:
        """Packed (uint32-word) edge-allowed bitmap for sampling under the
        khop-style single-hop filter ``pattern``: an edge is sampleable iff
        it holds the relationship, satisfies the predicates, its tail
        matches the ``a`` constraint, its head matches ``b``, AND it is
        alive in the overlay (tombstoned edges and edges of deleted
        vertices never appear).  ``perm`` routes the mask into an overlay
        view's window order.  None = every live edge.  Cached per
        (version, canonical pattern) so a served pattern packs once."""
        from repro import traverse

        key = (self.version, None if pattern is None else str(pattern),
               perm is not None)
        cache = getattr(self, "_sample_filter_cache", None)
        if cache is not None and cache[0] == key:
            return cache[1]
        g = self._require_graph()
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        if direction != 1:
            raise ValueError(
                "sampling follows out-edges; reverse-direction filter "
                "patterns (<-[...]-) are not supported")
        e_ok = e_mask
        if v_tail is not None or v_head is not None:
            e_ok = jnp.ones((g.m,), jnp.bool_) if e_ok is None else e_ok
            if v_tail is not None:
                e_ok = e_ok & v_tail[g.src]
            if v_head is not None:
                e_ok = e_ok & v_head[g.dst]
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = ae if e_ok is None else e_ok & ae
        if e_ok is None:
            words = None
        else:
            if perm is not None:
                e_ok = jnp.take(e_ok, perm)
            words = bitplane.pack_mask(e_ok)
        self._sample_filter_cache = (key, words)
        return words

    def _sample_rest(self, frontier, nbrs0, mask0, fanouts, key_or_seed,
                     seg, dstv, max_deg, ew_words):
        """Layers 1..L of the layered loop + block assembly, shared by the
        in-process path and the service's coalesced layer-0 launch (which
        must finish each request identically to a solo run).  Layer l keys
        are ``fold_in(base, l)`` — independent per layer; ``key_or_seed``
        may be the base key array or the plain int seed (then the key is
        derived in one jitted dispatch, bitwise the eager form)."""
        from repro.graph.sampler import layer_key, local_block
        from repro.kernels.neighbor_sample import neighbor_sample

        g = self._require_graph()
        layer_frontiers = [frontier]
        layer_samples = [(frontier, nbrs0, mask0)]
        nxt = np.unique(np.concatenate([frontier, nbrs0[mask0]])).astype(
            np.int32)
        layer_frontiers.append(nxt)
        for li in range(1, len(fanouts)):
            cur = layer_frontiers[-1]
            kl = (layer_key(key_or_seed, li)
                  if isinstance(key_or_seed, (int, np.integer))
                  else jax.random.fold_in(key_or_seed, li))
            nb, _ei, mk = neighbor_sample(
                seg, dstv, g.n, g.m, cur, kl, fanout=fanouts[li],
                edge_words=ew_words, max_deg=max_deg)
            nb = np.asarray(nb)[:len(cur)]
            mk = np.asarray(mk)[:len(cur)]
            layer_samples.append((cur, nb, mk))
            layer_frontiers.append(
                np.unique(np.concatenate([cur, nb[mk]])).astype(np.int32))
        blocks = []
        for li in range(len(fanouts) - 1, -1, -1):
            dst_nodes, nb, mk = layer_samples[li]
            blocks.append(
                local_block(dst_nodes, layer_frontiers[li + 1], nb, mk))
        return blocks

    def sample(self, seeds_or_pattern, fanouts, *, key=None, seed: int = 0,
               pattern=None, use_pallas: bool = False):
        """Fused property-filtered neighborhood sampling — the one-launch
        pattern→sample path (docs/ARCHITECTURE.md §15).

        ``seeds_or_pattern``: original vertex ids, or a Cypher-lite pattern
        string — then the seeds are the vertices the pattern's FIRST node
        variable binds, and the packed ``match`` combine's uint32 bitmap
        feeds the window gather directly (no host unpack; the host reads
        one popcount scalar to pick the capacity bucket).  ``fanouts``:
        per-layer caps, innermost first (GraphSAGE order).  ``pattern``:
        an optional khop-style single-hop filter constraining which edges
        may be sampled at EVERY layer (relationship, predicates, endpoint
        labels); overlay tombstones are always excluded.  ``key``/``seed``:
        the base PRNG key — results are bitwise-reproducible given it
        (layer l draws from ``fold_in(key, l)`` only).  ``use_pallas``
        opts the TPU window kernel in for layer 0.

        Returns ``SampledBlock``s innermost-first (``blocks[-1].dst_nodes``
        = the seed batch); node ids are INTERNAL [0, n) ids — index device
        property columns/embedding tables directly, or map back through
        ``graph.node_map``.  Selection is uniform without replacement over
        each seed's filtered adjacency: degree-0 seeds emit fully-masked
        slots, filtered degree ≤ fanout keeps every allowed edge once.
        Unknown and tombstoned seed ids drop out (the ``khop`` rule).
        """
        from repro.kernels.neighbor_sample import (
            neighbor_sample,
            neighbor_sample_from_words,
        )

        g = self._require_graph()
        fanouts = [int(f) for f in fanouts]
        if not fanouts or min(fanouts) < 1:
            raise ValueError(f"fanouts must be ≥1 per layer, got {fanouts}")
        from repro.graph.sampler import layer_key

        seg, dstv, max_deg, perm = self._sampling_view()
        ew_words = self._sample_edge_words(pattern, perm)
        key_or_seed = int(seed) if key is None else key
        k0 = (layer_key(key_or_seed, 0) if key is None
              else jax.random.fold_in(key, 0))
        if isinstance(seeds_or_pattern, str) or hasattr(seeds_or_pattern,
                                                        "nodes"):
            res = self.match(seeds_or_pattern)
            seed_mask = (res.node_masks[0] if res.node_masks
                         else res.vertex_mask)
            words = bitplane.pack_mask(seed_mask)
            count = int(jnp.sum(seed_mask))  # the one host scalar read
            idx, valid, nb, _ei, mk = neighbor_sample_from_words(
                seg, dstv, g.n, g.m, words, count, k0,
                fanout=fanouts[0], edge_words=ew_words, max_deg=max_deg)
            keep = np.asarray(valid)
            frontier = np.asarray(idx)[keep].astype(np.int32)
            nbrs0, mask0 = np.asarray(nb)[keep], np.asarray(mk)[keep]
        else:
            ids = self._vertex_internal(seeds_or_pattern)
            ids = ids[ids >= 0]
            if self._dead_v is not None and ids.size:
                ids = ids[~self._dead_v[ids]]
            nb, _ei, mk = neighbor_sample(
                seg, dstv, g.n, g.m, ids, k0, fanout=fanouts[0],
                edge_words=ew_words, max_deg=max_deg,
                use_pallas=use_pallas)
            frontier = ids.astype(np.int32)
            nbrs0 = np.asarray(nb)[:len(ids)]
            mask0 = np.asarray(mk)[:len(ids)]
        return self._sample_rest(frontier, nbrs0, mask0, fanouts, key_or_seed,
                                 seg, dstv, max_deg, ew_words)

    def components(self, pattern=None, *, max_iters: int = 128) -> jax.Array:
        """Connected components of the subgraph the filter ``pattern``
        allows — (n,) int32 labels (component id = smallest member vertex
        id, internal numbering), -1 for vertices outside the filter.

        Edges count as undirected; an edge participates iff it satisfies
        the pattern's relationship/predicate masks AND both endpoints
        match their node constraints (``pg.components(
        "(a:person)-[:follows]->(b:person)")`` = components of the
        follows-subgraph between persons).  Vertices matching either
        endpoint constraint participate (isolated ones form singletons).
        ``None`` = plain structural components.
        """
        from repro import traverse

        g = self._require_graph()
        _obs_traverse("components", int(max_iters), None)
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        e_ok = jnp.ones((g.m,), jnp.bool_) if e_mask is None else e_mask
        v_ok = None
        if v_tail is not None or v_head is not None:
            vt = jnp.ones((g.n,), jnp.bool_) if v_tail is None else v_tail
            vh = jnp.ones((g.n,), jnp.bool_) if v_head is None else v_head
            e_ok = e_ok & vt[tail] & vh[head]
            v_ok = vt | vh
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = e_ok & ae
        av = self._alive_vertex_mask()
        if av is not None:
            v_ok = av if v_ok is None else v_ok & av
        return traverse.components_masked(g, v_ok, e_ok, max_iters=max_iters)

    def _weighted_edge_filter(self, e_ok, weight: Optional[str]):
        """Fold a numeric edge-property column into a traversal: returns
        (f32 weights or None, edge filter with the column's validity mask
        ANDed in).  An edge without the property is NOT traversable under
        a weighted semiring — there is no sound default weight."""
        if weight is None:
            return None, e_ok
        from repro.query.weights import edge_weight_values

        w, wvalid = edge_weight_values(self, weight)
        return w, (wvalid if e_ok is None else e_ok & wvalid)

    def shortest_paths(
        self,
        seeds,
        *,
        weight: Optional[str] = None,
        pattern=None,
        undirected: bool = False,
        max_iters: Optional[int] = None,
    ) -> jax.Array:
        """Multi-source shortest-path distances from ``seeds`` (original
        ids) over the (min, +) tropical semiring — (n,) f32, 0.0 at the
        seeds, +inf where unreachable (docs/ARCHITECTURE.md §12).

        ``weight`` names a numeric edge property; edges without the
        property do not participate (``None`` = unit weights, hop
        counts).  ``pattern`` is the same node-only or single-hop filter
        ``khop`` takes — the ``shortestPath()``-style hook: the pattern
        constrains each STEP of the walk (relationship, predicates,
        endpoint labels, ``<-[...]-`` direction), the fixed point
        supplies the path structure.  Overlay tombstones and delta edges
        compose exactly as in ``khop``; under a mesh the per-round relax
        all-reduces partial distances with ``pmin`` (bitwise-identical
        to the single-device path)."""
        from repro import traverse

        g = self._require_graph()
        _obs_traverse("shortest_paths",
                      None if max_iters is None else int(max_iters),
                      int(np.asarray(seeds).size))
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        e_ok = jnp.ones((g.m,), jnp.bool_) if e_mask is None else e_mask
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        if v_tail is not None:
            e_ok = e_ok & v_tail[tail]
        if v_head is not None:
            e_ok = e_ok & v_head[head]
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = e_ok & ae
        w, e_ok = self._weighted_edge_filter(e_ok, weight)
        ids = self._vertex_internal(seeds)
        ids = ids[ids >= 0]
        if self._dead_v is not None and ids.size:
            ids = ids[~self._dead_v[ids]]  # dead seeds don't traverse
        seed_mask = jnp.zeros((g.n,), jnp.bool_).at[jnp.asarray(ids)].set(True)
        if self.mesh is not None:
            return traverse.shortest_paths_sharded(
                g, seed_mask, w, e_ok, mesh=self.mesh, direction=direction,
                undirected=undirected, max_iters=max_iters)
        return traverse.shortest_paths_masked(
            g, seed_mask, w, e_ok, direction=direction,
            undirected=undirected, max_iters=max_iters)

    def _subgraph_filters(self, pattern):
        """Whole-subgraph mask composition shared by ``components``-shaped
        analytics (pagerank/communities): pattern endpoint masks gate
        edges AND define vertex membership (either endpoint constraint
        admits a vertex), overlay tombstones AND out of both."""
        from repro import traverse

        g = self._require_graph()
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        e_ok = e_mask
        v_ok = None
        if v_tail is not None or v_head is not None:
            vt = jnp.ones((g.n,), jnp.bool_) if v_tail is None else v_tail
            vh = jnp.ones((g.n,), jnp.bool_) if v_head is None else v_head
            em = jnp.ones((g.m,), jnp.bool_) if e_ok is None else e_ok
            e_ok = em & vt[tail] & vh[head]
            v_ok = vt | vh
        ae = self._alive_edge_mask()
        if ae is not None:
            e_ok = ae if e_ok is None else e_ok & ae
        av = self._alive_vertex_mask()
        if av is not None:
            v_ok = av if v_ok is None else v_ok & av
        return g, v_ok, e_ok, direction

    def pagerank(
        self,
        *,
        pattern=None,
        weight: Optional[str] = None,
        damping: float = 0.85,
        iters: int = 20,
    ) -> jax.Array:
        """PageRank on the subgraph the filter ``pattern`` allows — (n,)
        f32 ranks, 0.0 for vertices outside the filter (§12).

        The (+, ×) semiring instance: per-iteration contributions
        ``rank/out_degree`` flow along allowed edges (``weight`` scales
        them per-edge; edges without the property drop out), teleport and
        dangling mass redistribute over the allowed vertex count.  With
        no filter this is the classic §I kernel (``repro.graph.pagerank``
        delegates here).  Under a mesh the per-step aggregation
        all-reduces partial sums with ``psum`` — equal to the
        single-device ranks within float tolerance."""
        from repro import traverse

        _obs_traverse("pagerank", int(iters), None)
        g, v_ok, e_ok, direction = self._subgraph_filters(pattern)
        w, e_ok = self._weighted_edge_filter(e_ok, weight)
        if self.mesh is not None:
            return traverse.pagerank_sharded(
                g, v_ok, e_ok, w, mesh=self.mesh, damping=damping,
                iters=iters, direction=direction)
        return traverse.pagerank_masked(
            g, v_ok, e_ok, w, damping=damping, iters=iters,
            direction=direction)

    def communities(self, pattern=None, *, max_iters: int = 64) -> jax.Array:
        """Community labels by synchronous label propagation on the
        subgraph the filter ``pattern`` allows — (n,) int32 (label =
        a member vertex id, internal numbering), -1 outside the filter
        (§12).

        Mode relax under a fixed deterministic tie-break (most frequent
        neighbor label, smallest wins ties); edges count as undirected,
        exactly ``components``' participation rule.  Every op is integer,
        so results are exact and identical under a mesh (the sort-based
        mode has no elementwise ⊕ to all-reduce; GSPMD runs the same
        program over the placed arrays)."""
        from repro import traverse

        _obs_traverse("communities", int(max_iters), None)
        g, v_ok, e_ok, _ = self._subgraph_filters(pattern)
        return traverse.label_propagation_masked(
            g, v_ok, e_ok, max_iters=max_iters)

    # ------------------------------------------- snapshots / views / overlay
    def snapshot(self) -> "PropGraph":
        """Immutable view pinned at (base store @ version, frozen delta
        chain).  Zero-copy: the sealed device stores, DI arrays and typed
        columns are SHARED with the parent — only the small delta chunk
        lists are shallow-copied.  Writes keep landing on the parent (its
        delta chain grows past the snapshot's frozen prefix, its columns
        are replaced functionally), so a long-running ``components()`` or
        ``match()`` on the snapshot reads a consistent view throughout.
        Mutators on a snapshot raise; ``fork()`` one to branch."""
        from repro.overlay.views import clone_propgraph

        return clone_propgraph(self, frozen=True)

    def fork(self) -> "PropGraph":
        """Writable copy-on-write view: (base graph @ snapshot, private
        overlay).  Shares the base's device shards with the parent; each
        side's subsequent writes land in its own delta/tombstones — the
        what-if primitive (\"delete this hub, what breaks\") and the
        per-tenant branch the service's ``fork_view`` verb exposes."""
        from repro.overlay.views import clone_propgraph

        return clone_propgraph(self, frozen=False)

    @_write_locked
    def compact(self) -> "PropGraph":
        """Fold the whole overlay (delta edges, delta attribute pairs,
        tombstones) into fresh sealed base stores — the LSM merge step.
        Equivalent to rebuilding from scratch with the surviving data;
        structural for cache purposes (every cached result dies).  No-op
        when there is no overlay."""
        self._check_writable()
        if not self.has_overlay():
            return self
        from repro.overlay.compactor import compact_propgraph

        compact_propgraph(self)
        self.last_mutation = MutationEvent.structural_event("compact")
        self._bump_version()
        return self

    def has_overlay(self) -> bool:
        """Any uncompacted overlay state (delta pairs/edges or tombstones)?"""
        return self.overlay_size() > 0

    def overlay_size(self) -> int:
        """Total overlay entries — the compaction-policy signal the
        background ``Compactor`` thresholds on."""
        size = 0
        if self._delta_edges is not None:
            size += self._delta_edges.size
        if self._vstore is not None:
            size += self._vstore._delta.size
        if self._estore is not None:
            size += self._estore._delta.size
        if self._dead_v is not None:
            size += int(self._dead_v.sum())
        if self._dead_e is not None:
            size += int(self._dead_e.size)
        return size

    def delta_stats(self) -> Dict[str, int]:
        """Per-component overlay sizes (observability; pgserve surfaces it)."""
        return {
            "delta_edges": self._delta_edges.size if self._delta_edges else 0,
            "delta_vertex_pairs": self._vstore._delta.size if self._vstore else 0,
            "delta_edge_pairs": self._estore._delta.size if self._estore else 0,
            "dead_vertices": int(self._dead_v.sum()) if self._dead_v is not None else 0,
            "dead_edges": int(self._dead_e.size) if self._dead_e is not None else 0,
        }

    @property
    def frozen(self) -> bool:
        return self._frozen

    # ------------------------------------------------------------------ info
    @property
    def n_vertices(self) -> int:
        return self._require_graph().n

    @property
    def n_edges(self) -> int:
        return self._require_graph().m

    def label_set(self) -> List[str]:
        return self._vstore.amap.values if self._vstore else []

    def relationship_set(self) -> List[str]:
        return self._estore.amap.values if self._estore else []

    def label_counts(self) -> Dict[str, int]:
        """Per-label vertex counts, read off the cached ``attr_counts()``
        stats (host-derived; never a per-value ``query_any`` scan and never
        a device store upload).  Tombstoned vertices are subtracted, so the
        counts agree with ``query_labels`` (which masks them out)."""
        if self._vstore is None:
            return {}
        counts = self._vstore.attr_counts(dead_ids=self._dead_vertex_ids())
        return {v: int(counts[i]) for i, v in enumerate(self._vstore.amap.values)}

    def relationship_counts(self) -> Dict[str, int]:
        """Per-relationship edge counts, read off the cached
        ``attr_counts()`` stats (same contract as ``label_counts`` —
        tombstoned/detached edges subtracted)."""
        if self._estore is None:
            return {}
        counts = self._estore.attr_counts(dead_ids=self._dead_edge_ids())
        return {v: int(counts[i]) for i, v in enumerate(self._estore.amap.values)}
