"""PropGraph — the user-facing property-graph API (mirrors Arachne's Python surface).

Workflow (§V of the paper):

    pg = PropGraph(backend="arr")                      # ar.PropGraph()
    pg.add_edges_from(src, dst)                        # bulk DI build
    pg.add_node_labels(nodes, labels)                  # strings ok
    pg.add_edge_relationships(esrc, edst, rels)
    pg.add_node_properties("age", nodes, ages)         # typed columns
    vmask = pg.query_labels(["person", "place"])       # OR semantics
    emask = pg.query_relationships(["follows"])
    sub, kept = pg.subgraph(labels=[...], relationships=[...])

Ingestion follows the paper's three steps: (1) attribute values remapped to
dense int ids (`AttributeMap`), (2) internal vertex/edge indices generated
(vertex normalization + `edge_lookup` binary search), (3) bulk insert into the
chosen DIP backend.  Backends: ``arr`` (DIP-ARR bitmap), ``list`` (DIP-LIST
CSR), ``listd`` (DIP-LISTD linked chains + inverted CSR).

Distribution (docs/ARCHITECTURE.md §7): ``PropGraph(backend=..., mesh=...)``
opts into multi-device execution via ``core.dip_shard`` and the
``launch.sharding.pg_specs`` family.  The DIP stores — the heavy query-side
data — are padded to the shard count and always entity-sharded, and every
query runs under ``shard_map`` so each device scans only its N/P entity
slice.  DI arrays and typed property columns keep their exact logical sizes:
they shard when their length divides the device count and replicate
otherwise (explicit placements require even shards).  Results are
bitwise-identical to the default single-device path.
"""
from __future__ import annotations

import operator
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dip_arr, dip_list, dip_listd, dip_shard
from repro.core.attr_map import AttributeMap
from repro.core.di import DIGraph, build_di, edge_lookup
from repro.core.queries import extract_subgraph, filtered_bfs, induce_edge_mask

__all__ = ["PropGraph", "BACKENDS"]

BACKENDS = ("arr", "list", "listd")


class _AttrStore:
    """One DIP instance over ``n_entities`` (vertices or edges).

    With ``mesh`` set, ``finalize_sharded()`` additionally maintains a padded,
    device-placed copy of the store (``core.dip_shard``) and the query paths
    run under ``shard_map``; both caches invalidate together on ``insert``.
    """

    def __init__(self, backend: str, n_entities: int, mesh=None):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.backend = backend
        self.n = n_entities
        self.mesh = mesh
        self.amap = AttributeMap()
        self._pairs_e: List[np.ndarray] = []  # entity ids, insertion order
        self._pairs_a: List[np.ndarray] = []  # attribute ids
        self._store = None
        self._sharded = None
        self._host = None  # host-built dense form awaiting upload/placement
        self._counts: Optional[np.ndarray] = None
        self._dirty = True

    def insert(self, entity_ids: np.ndarray, values: Sequence[str]) -> None:
        attr_ids = self.amap.encode(values)
        attr_ids = np.broadcast_to(np.atleast_1d(attr_ids), np.shape(entity_ids)).ravel()
        entity_ids = np.asarray(entity_ids, np.int32).ravel()
        ok = entity_ids >= 0  # unmatched edge rows (edge_lookup -1) are dropped
        self._pairs_e.append(entity_ids[ok])
        self._pairs_a.append(attr_ids[ok].astype(np.int32))
        self._counts = None
        self._sharded = None
        self._host = None
        self._dirty = True

    @property
    def k(self) -> int:
        return max(len(self.amap), 1)

    def _build_host(self):
        """Dense store with HOST (numpy) arrays, built from the raw pairs.

        Also derives the per-attribute selectivity stats (``attr_counts``)
        while the dense form is in hand — bitmap row sums / CSR segment
        lengths, computed host-side so the stats never require a device
        store.  The build is stashed in ``_host`` so a stats read followed
        by a query builds once, not twice; ``finalize`` /
        ``finalize_sharded`` consume the stash — after placement the dense
        copy is RELEASED in mesh mode (per-device memory stays O(NK/P),
        docs/ARCHITECTURE.md §7)."""
        if self._host is not None:
            return self._host
        ent = np.concatenate(self._pairs_e) if self._pairs_e else np.zeros(0, np.int32)
        att = np.concatenate(self._pairs_a) if self._pairs_a else np.zeros(0, np.int32)
        if self.backend == "arr":
            host = dip_arr.build_dip_arr_host(ent, att, k=self.k, n=self.n)
            self._counts = host.bitmap.sum(axis=1, dtype=np.int64)
        elif self.backend == "list":
            host = dip_list.build_dip_list_host(ent, att, k=self.k, n=self.n)
            self._counts = np.bincount(np.asarray(host.val), minlength=self.k)
        else:
            host = dip_listd.build_dip_listd_host(ent, att, k=self.k, n=self.n)
            self._counts = np.asarray(host.a_off[1:] - host.a_off[:-1])
        self._host = host
        return host

    def finalize(self):
        if not self._dirty and self._store is not None:
            return self._store
        self._store = jax.tree_util.tree_map(jnp.asarray, self._build_host())
        self._host = None  # consumed; the device copy is the cache now
        self._dirty = False
        return self._store

    def finalize_sharded(self):
        """Padded, mesh-placed copy of the store (mesh mode only).

        Builds the dense form host-side, places the padded shards, and
        releases the dense copy — no device (and no cache slot) holds a
        full replica; the selectivity stats survive in ``_counts``."""
        if self._sharded is None:
            self._sharded = dip_shard.place_store(
                self.backend, self._build_host(), self.mesh
            )
            self._host = None  # dense copy released after placement
        return self._sharded

    def known_ids(self, values: Sequence[str]) -> np.ndarray:
        """Interned attribute ids for ``values`` (unknown values dropped)."""
        ids = np.atleast_1d(self.amap.lookup(list(values)))
        return ids[ids >= 0].astype(np.int32)

    def attr_counts(self) -> np.ndarray:
        """(k,) per-attribute entity counts — the DIP selectivity statistics
        the planner orders joins with (bitmap row sums / CSR segment
        lengths; each store carries them for free).  Derived host-side
        during ``_build_host`` — reading them never uploads a store — and
        invalidated with the store (``insert`` clears them); the planner
        reads these on every ``match()``."""
        if self._counts is None:
            self._build_host()  # sets _counts; build stays stashed for the
            # next finalize, so stats-then-query builds once
        return self._counts

    @property
    def nnz(self) -> int:
        """Stored (entity, attribute) pair count (post-dedupe where the
        backend dedupes) — Σ attr_counts, so reading it needs no store."""
        return int(np.sum(self.attr_counts()))

    def query_any(self, values: Sequence[str], *, impl: Optional[str] = None) -> jax.Array:
        if len(values) == 0 or self.known_ids(values).size == 0:
            # degenerate query (empty list / all-unknown values): the answer
            # is definitionally empty — skip the store entirely
            return jnp.zeros((self.n,), jnp.bool_)
        if self.mesh is not None:
            mask = jnp.asarray(self.amap.mask(values, self.k))
            return dip_shard.query_any_sharded(
                self.backend, self.finalize_sharded(), mask, impl=impl
            )
        store = self.finalize()
        mask = jnp.asarray(self.amap.mask(values, self.k))
        if self.backend == "arr":
            return dip_arr.query_any(store, mask, impl=impl or "matvec")
        if self.backend == "list":
            return dip_list.query_any(store, mask)
        if impl == "budget":
            ids = self.known_ids(values)
            a_off = np.asarray(store.a_off)
            budget = int((a_off[ids + 1] - a_off[ids]).sum())
            budget = max(-(-budget // 128) * 128, 128)  # lane-aligned, ≥1 tile
            return dip_listd.query_any_budget(store, jnp.asarray(ids), budget=budget)
        return dip_listd.query_any(store, mask, impl=impl or "inverted")

    def query_any_batched(
        self, values_list: Sequence[Sequence[str]], *, impl: Optional[str] = None
    ) -> jax.Array:
        """(Q, n) bool — Q OR-queries in one shot.  On the ``arr`` backend all
        Q masks go through ONE matvec / Pallas-kernel launch (the planner's
        fusion path); other backends fall back to a per-query loop."""
        if self.backend == "arr":
            masks = jnp.asarray(
                np.stack([self.amap.mask(v, self.k) for v in values_list])
            )
            if self.mesh is not None:
                return dip_shard.query_any_batched_sharded(
                    self.finalize_sharded(), masks, impl=impl
                )
            return dip_arr.query_any_batched(self.finalize(), masks, impl=impl or "matvec")
        return jnp.stack([self.query_any(v, impl=impl) for v in values_list])


class PropGraph:
    """A static, directed, labeled property multigraph over the DI structure.

    ``mesh=None`` (default) runs single-device, exactly as before.  Passing a
    device mesh (e.g. ``launch.mesh.make_entity_mesh()``) distributes the
    entity axis of the DIP stores over its devices (DI arrays and property
    columns shard when divisible, replicate otherwise) — queries return the
    same masks, computed shard-locally (docs/ARCHITECTURE.md §7).
    """

    def __init__(self, backend: str = "arr", mesh=None):
        self.backend = backend
        self.mesh = mesh
        self.graph: Optional[DIGraph] = None
        self._vstore: Optional[_AttrStore] = None
        self._estore: Optional[_AttrStore] = None
        # typed property columns: name -> (values (x,), valid mask (x,))
        self.vertex_props: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        self.edge_props: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        # monotone mutation counter + observers — the service layer's cache
        # invalidation contract (a result cached at version v is dead the
        # moment any mutator runs; see src/repro/service/README.md)
        self.version: int = 0
        self._mutation_hooks: List = []

    # ----------------------------------------------------------- mutation API
    def on_mutation(self, hook) -> "PropGraph":
        """Register ``hook(pg)`` to run after every mutating call (structure
        or attributes).  Hooks fire AFTER ``version`` is bumped, so a hook
        reading ``pg.version`` sees the post-mutation value."""
        self._mutation_hooks.append(hook)
        return self

    def _bump_version(self) -> None:
        self.version += 1
        for hook in list(self._mutation_hooks):
            hook(self)

    # ------------------------------------------------------------- structure
    def add_edges_from(self, src, dst) -> "PropGraph":
        """Bulk edge ingestion → DI build (sort + normalize + SEG).

        Rebuilding the structure drops all previously attached attributes
        (fresh stores) — and, like every mutator, bumps ``version``."""
        self.graph = build_di(np.asarray(src), np.asarray(dst))
        if self.mesh is not None:
            self.graph = dip_shard.place_graph(self.graph, self.mesh)
        self._vstore = _AttrStore(self.backend, self.graph.n, mesh=self.mesh)
        self._estore = _AttrStore(self.backend, max(self.graph.m, 1), mesh=self.mesh)
        self._bump_version()
        return self

    def _require_graph(self) -> DIGraph:
        if self.graph is None:
            raise RuntimeError("call add_edges_from(...) first")
        return self.graph

    def _vertex_internal(self, nodes) -> np.ndarray:
        """Original vertex ids → internal [0, n) ids (−1 if absent)."""
        g = self._require_graph()
        nm = np.asarray(g.node_map)
        nodes = np.asarray(nodes).ravel()
        pos = np.searchsorted(nm, nodes)
        pos = np.clip(pos, 0, len(nm) - 1)
        ok = nm[pos] == nodes
        return np.where(ok, pos, -1).astype(np.int32)

    def _edge_internal(self, src, dst) -> np.ndarray:
        g = self._require_graph()
        u = self._vertex_internal(src)
        v = self._vertex_internal(dst)
        u_c = jnp.asarray(np.maximum(u, 0))
        v_c = jnp.asarray(np.maximum(v, 0))
        idx = np.asarray(edge_lookup(g, u_c, v_c))
        return np.where((u >= 0) & (v >= 0), idx, -1).astype(np.int32)

    # ------------------------------------------------------------ attributes
    def add_node_labels(self, nodes, labels) -> "PropGraph":
        self._require_graph()
        self._vstore.insert(self._vertex_internal(nodes), labels)
        self._bump_version()
        return self

    def add_edge_relationships(self, src, dst, relationships) -> "PropGraph":
        self._require_graph()
        self._estore.insert(self._edge_internal(src, dst), relationships)
        self._bump_version()
        return self

    def add_node_properties(self, name: str, nodes, values, fill=0) -> "PropGraph":
        g = self._require_graph()
        idx = self._vertex_internal(nodes)
        vals = np.asarray(values)
        col = np.full((g.n,), fill, dtype=vals.dtype)
        valid = np.zeros((g.n,), dtype=bool)
        ok = idx >= 0
        col[idx[ok]] = vals[ok]
        valid[idx[ok]] = True
        self.vertex_props[name] = self._place_column(col, valid)
        self._bump_version()
        return self

    def add_edge_properties(self, name: str, src, dst, values, fill=0) -> "PropGraph":
        g = self._require_graph()
        idx = self._edge_internal(src, dst)
        vals = np.asarray(values)
        col = np.full((g.m,), fill, dtype=vals.dtype)
        valid = np.zeros((g.m,), dtype=bool)
        ok = idx >= 0
        col[idx[ok]] = vals[ok]
        valid[idx[ok]] = True
        self.edge_props[name] = self._place_column(col, valid)
        self._bump_version()
        return self

    def _place_column(self, col, valid) -> Tuple[jax.Array, jax.Array]:
        col, valid = jnp.asarray(col), jnp.asarray(valid)
        if self.mesh is not None:
            col = dip_shard.place_column(col, self.mesh)
            valid = dip_shard.place_column(valid, self.mesh)
        return col, valid

    # --------------------------------------------------------------- queries
    def query_labels(self, labels, *, impl: Optional[str] = None) -> jax.Array:
        """(n,) bool — vertices holding ANY of ``labels`` (§VI OR semantics)."""
        self._require_graph()
        return self._vstore.query_any(labels, impl=impl)

    def query_relationships(self, relationships, *, impl: Optional[str] = None) -> jax.Array:
        """(m,) bool — edges holding ANY of ``relationships``."""
        self._require_graph()
        return self._estore.query_any(relationships, impl=impl)

    # ------------------------------------------------- typed property masks
    _PRED_OPS = {
        "==": operator.eq,
        "!=": operator.ne,
        "<": operator.lt,
        "<=": operator.le,
        ">": operator.gt,
        ">=": operator.ge,
    }

    def _predicate_mask(
        self, cols: Dict[str, Tuple[jax.Array, jax.Array]], kind: str,
        name: str, op: str, value,
    ) -> jax.Array:
        if name not in cols:
            raise KeyError(
                f"unknown {kind} property {name!r}; known: {sorted(cols)}"
            )
        if op not in self._PRED_OPS:
            raise ValueError(f"unknown predicate op {op!r}; known: {sorted(self._PRED_OPS)}")
        if isinstance(value, str):
            # property columns are numeric typed columns; a str here would
            # silently broadcast to a scalar True/False under ==/!= instead
            # of comparing — string-valued attributes belong in labels/
            # relationships (the DIP stores), not predicates
            raise TypeError(
                f"{kind} predicate {name!r} {op} {value!r}: string comparisons "
                "are not supported on typed property columns — model "
                "string-valued attributes as labels/relationships instead"
            )
        col, valid = cols[name]
        return valid & self._PRED_OPS[op](col, value)

    def vertex_predicate_mask(self, name: str, op: str, value) -> jax.Array:
        """(n,) bool — vertices whose typed property ``name`` compares true
        (entities without the property never match: the valid mask ANDs in)."""
        self._require_graph()
        return self._predicate_mask(self.vertex_props, "vertex", name, op, value)

    def edge_predicate_mask(self, name: str, op: str, value) -> jax.Array:
        """(m,) bool — edges whose typed property ``name`` compares true."""
        self._require_graph()
        return self._predicate_mask(self.edge_props, "edge", name, op, value)

    # ------------------------------------------------------ pattern matching
    def match(self, pattern, *, impl: Optional[str] = None):
        """Declarative pattern query: ``pg.match("(a:person {age > 30})-[:follows]->(b:person)")``.

        Parses ``pattern`` (str or a pre-built ``repro.query.Pattern``),
        plans it against this graph's DIP statistics and executes the fused
        mask pipeline.  Returns a ``repro.query.MatchResult`` whose
        ``vertex_mask``/``edge_mask`` cover exactly the entities in at least
        one full match.  ``impl`` force-overrides the planner's per-mask
        implementation choice.
        """
        from repro.query import execute_plan, parse, plan_pattern

        pat = parse(pattern) if isinstance(pattern, str) else pattern
        return execute_plan(self, plan_pattern(self, pat, impl=impl))

    def explain(self, pattern, *, impl: Optional[str] = None) -> str:
        """The plan ``match`` would run, as a human-readable string — which
        DIP impl each mask uses, selectivity estimates, chain orientation,
        and kernel-fusion decisions."""
        from repro.query import parse, plan_pattern

        pat = parse(pattern) if isinstance(pattern, str) else pattern
        return plan_pattern(self, pat, impl=impl).describe()

    def subgraph(
        self,
        labels: Optional[Sequence[str]] = None,
        relationships: Optional[Sequence[str]] = None,
        *,
        impl: Optional[str] = None,
    ) -> Tuple[DIGraph, np.ndarray]:
        """Intersect label/relationship query masks into an induced subgraph."""
        g = self._require_graph()
        vmask = (
            self.query_labels(labels, impl=impl)
            if labels is not None
            else jnp.ones((g.n,), jnp.bool_)
        )
        emask = (
            self.query_relationships(relationships, impl=impl)
            if relationships is not None
            else jnp.ones((g.m,), jnp.bool_)
        )
        return extract_subgraph(g, induce_edge_mask(g, vmask, emask))

    def bfs(
        self,
        sources,
        labels: Optional[Sequence[str]] = None,
        relationships: Optional[Sequence[str]] = None,
        max_iters: int = 64,
    ) -> jax.Array:
        """Property-filtered BFS from original-id sources; (n,) depths."""
        g = self._require_graph()
        v_ok = self.query_labels(labels) if labels is not None else None
        e_ok = self.query_relationships(relationships) if relationships is not None else None
        srcs = jnp.asarray(np.maximum(self._vertex_internal(sources), 0))
        return filtered_bfs(g, srcs, edge_allowed=e_ok, vertex_allowed=v_ok, max_iters=max_iters)

    # -------------------------------------------------- frontier analytics
    def khop(
        self,
        seeds,
        k: int,
        *,
        pattern=None,
        undirected: bool = False,
        impl: Optional[str] = None,
    ) -> jax.Array:
        """Vertices within ≤``k`` hops of ``seeds`` (original ids), following
        only edges the filter ``pattern`` allows — (n,) bool, seeds included.

        ``pattern`` is a node-only or single-hop filter (the same §VI masks
        ``match`` composes): for ``"(a:host)-[:flows {bytes > 0}]->(b)"``
        an edge is traversable iff it holds ``flows``, satisfies the
        predicate, its tail matches ``a`` and its head matches ``b``;
        ``<-[...]-`` walks edges in reverse; a node-only pattern confines
        the traversal to matching vertices.  ``None`` allows everything.

        ``impl``: ``None``/``"frontier"`` = the edge-centric bitmap step
        (one jitted ``while_loop``; the shard_map all-reduce path under a
        mesh); ``"csr"`` = the small-frontier CSR gather fast path —
        O(|frontier|·max_deg) per step instead of O(m) (single-device,
        forward, directed only; degrades to ``frontier`` otherwise, like
        the listd ``budget`` impl under a mesh).  All paths are
        bitwise-identical.
        """
        from repro import traverse

        g = self._require_graph()
        if impl not in (None, "frontier", "csr"):
            raise ValueError(f"unknown impl {impl!r}")
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        e_ok = jnp.ones((g.m,), jnp.bool_) if e_mask is None else e_mask
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        if v_tail is not None:
            e_ok = e_ok & v_tail[tail]
        if v_head is not None:
            e_ok = e_ok & v_head[head]
        ids = self._vertex_internal(seeds)
        ids = ids[ids >= 0]
        if impl == "csr" and self.mesh is None and direction == 1 and not undirected:
            return traverse.khop_csr(g, ids, e_ok, k=k)
        seed_mask = jnp.zeros((g.n,), jnp.bool_).at[jnp.asarray(ids)].set(True)
        if self.mesh is not None:
            return traverse.khop_mask_sharded(
                g, seed_mask, e_ok, k=k, mesh=self.mesh,
                direction=direction, undirected=undirected)
        return traverse.khop_mask(g, seed_mask, e_ok, k=k,
                                  direction=direction, undirected=undirected)

    def components(self, pattern=None, *, max_iters: int = 128) -> jax.Array:
        """Connected components of the subgraph the filter ``pattern``
        allows — (n,) int32 labels (component id = smallest member vertex
        id, internal numbering), -1 for vertices outside the filter.

        Edges count as undirected; an edge participates iff it satisfies
        the pattern's relationship/predicate masks AND both endpoints
        match their node constraints (``pg.components(
        "(a:person)-[:follows]->(b:person)")`` = components of the
        follows-subgraph between persons).  Vertices matching either
        endpoint constraint participate (isolated ones form singletons).
        ``None`` = plain structural components.
        """
        from repro import traverse

        g = self._require_graph()
        v_tail, v_head, e_mask, direction = traverse.single_hop_filters(
            self, pattern)
        tail, head = (g.src, g.dst) if direction == 1 else (g.dst, g.src)
        e_ok = jnp.ones((g.m,), jnp.bool_) if e_mask is None else e_mask
        v_ok = None
        if v_tail is not None or v_head is not None:
            vt = jnp.ones((g.n,), jnp.bool_) if v_tail is None else v_tail
            vh = jnp.ones((g.n,), jnp.bool_) if v_head is None else v_head
            e_ok = e_ok & vt[tail] & vh[head]
            v_ok = vt | vh
        return traverse.components_masked(g, v_ok, e_ok, max_iters=max_iters)

    # ------------------------------------------------------------------ info
    @property
    def n_vertices(self) -> int:
        return self._require_graph().n

    @property
    def n_edges(self) -> int:
        return self._require_graph().m

    def label_set(self) -> List[str]:
        return self._vstore.amap.values if self._vstore else []

    def relationship_set(self) -> List[str]:
        return self._estore.amap.values if self._estore else []

    def label_counts(self) -> Dict[str, int]:
        """Per-label vertex counts, read off the cached ``attr_counts()``
        stats (host-derived; never a per-value ``query_any`` scan and never
        a device store upload)."""
        if self._vstore is None:
            return {}
        counts = self._vstore.attr_counts()
        return {v: int(counts[i]) for i, v in enumerate(self._vstore.amap.values)}

    def relationship_counts(self) -> Dict[str, int]:
        """Per-relationship edge counts, read off the cached
        ``attr_counts()`` stats (same contract as ``label_counts``)."""
        if self._estore is None:
            return {}
        counts = self._estore.attr_counts()
        return {v: int(counts[i]) for i, v in enumerate(self._estore.amap.values)}
