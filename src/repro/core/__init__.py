"""repro.core — the paper's contribution: DI + DIP property-graph structures."""
from repro.core.attr_map import AttributeMap
from repro.core.di import (
    DIGraph,
    build_di,
    build_reverse_di,
    degrees,
    edge_lookup,
    max_degree,
    neighbors_padded,
)
from repro.core.dip_arr import DIPArr, build_dip_arr
from repro.core.dip_list import DIPList, build_dip_list
from repro.core.dip_listd import DIPListD, build_dip_listd
from repro.core.property_graph import PropGraph
from repro.core.queries import (
    connected_entities,
    extract_subgraph,
    filtered_bfs,
    induce_edge_mask,
)

__all__ = [
    "AttributeMap",
    "DIGraph",
    "build_di",
    "build_reverse_di",
    "degrees",
    "edge_lookup",
    "max_degree",
    "neighbors_padded",
    "DIPArr",
    "build_dip_arr",
    "DIPList",
    "build_dip_list",
    "DIPListD",
    "build_dip_listd",
    "PropGraph",
    "connected_entities",
    "extract_subgraph",
    "filtered_bfs",
    "induce_edge_mask",
]
