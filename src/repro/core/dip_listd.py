"""DIP-LISTD — doubly-linked attribute chains (§IV-B), two ways.

The paper threads a distributed doubly-linked list through every Node that
carries a given attribute, with ``last_entity_tracker[attr]`` holding the most
recently inserted Node, so attribute→entities traversal walks prev pointers —
O(N) *sequential*, hopping locales (the measured ~10× slowdown, §VII-B).

TPUs have no remote pointer dereference, so this module ships two forms:

  1. **Faithful emulation** (`query_any_linked`): Nodes become parallel arrays
     ``(entity, attr, prev, next)`` in insertion order + ``last_tracker[k]``;
     traversal is a ``lax.while_loop`` pointer chase.  Kept as the
     paper-faithful baseline — and it reproduces the paper's finding: it is
     ~10× slower than DIP-LIST/DIP-ARR in our benchmarks too (bench_query.py).

  2. **Inverted CSR** (`query_any_inverted` / `query_any_budget`): the
     TPU-idiomatic replacement recorded in docs/ARCHITECTURE.md §2 —
     attribute-major
     offsets ``a_off[k+1]`` + entity list ``a_ent[nnz]`` deliver the same
     attribute→entities capability with parallel reads.  ``query_any_budget``
     is genuinely output-sized: it touches only the selected attributes'
     segments (padded to a static budget), the analogue of "traverse only the
     entities that make one particular attribute" (Fig. 3) *without* the
     serialization.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DIPListD",
    "build_dip_listd",
    "build_dip_listd_host",
    "query_any_linked",
    "query_any_inverted",
    "query_any_budget",
    "query_any",
]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["entity", "attr", "prev", "nxt", "last_tracker", "a_off", "a_ent"],
    meta_fields=["k", "n", "nnz"],
)
@dataclasses.dataclass(frozen=True)
class DIPListD:
    """Node arrays in insertion order + per-attribute chain heads + inverted CSR.

    Per-node payload mirrors the paper's §IV-D accounting (attr id, entity id,
    prev, next ⇒ the constant-factor overhead c); ``last_tracker[a]`` = index of
    the last node inserted for attribute ``a`` (-1 if none).
    """

    entity: jax.Array  # (nnz,) int32
    attr: jax.Array  # (nnz,) int32
    prev: jax.Array  # (nnz,) int32 — previous node with same attr, -1 at head
    nxt: jax.Array  # (nnz,) int32 — next node with same attr, -1 at tail
    last_tracker: jax.Array  # (k,) int32
    a_off: jax.Array  # (k+1,) int32 inverted-CSR offsets
    a_ent: jax.Array  # (nnz,) int32 entities grouped by attribute
    k: int
    n: int
    nnz: int


def build_dip_listd_host(entity_ids, attr_ids, *, k: int, n: int) -> DIPListD:
    """``build_dip_listd`` with HOST (numpy) storage — identical layout, no
    device allocation (the construction is host-side replay anyway; this
    entry just skips the final upload).  The sharded path builds here,
    reads the per-attribute stats off ``a_off``, then places only the
    padded inverted-CSR shards on devices (docs/ARCHITECTURE.md §7)."""
    ent = np.asarray(entity_ids, dtype=np.int32).ravel()
    att = np.asarray(attr_ids, dtype=np.int32).ravel()
    nnz = int(ent.shape[0])
    prev = np.full(nnz, -1, dtype=np.int32)
    nxt = np.full(nnz, -1, dtype=np.int32)
    last = np.full(k, -1, dtype=np.int32)
    for i in range(nnz):  # host-side replay of the insertion order
        a = att[i]
        p = last[a]
        prev[i] = p
        if p >= 0:
            nxt[p] = i
        last[a] = i

    # inverted CSR (attribute-major), stable in insertion order within attr
    order = np.argsort(att, kind="stable")
    a_ent = ent[order]
    counts = np.bincount(att, minlength=k)
    a_off = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    return DIPListD(
        entity=ent, attr=att, prev=prev, nxt=nxt, last_tracker=last,
        a_off=a_off, a_ent=a_ent, k=k, n=n, nnz=nnz,
    )


def build_dip_listd(entity_ids, attr_ids, *, k: int, n: int) -> DIPListD:
    """Build from insertion-ordered (entity, attribute) pairs.

    The linked-chain pointers replay the paper's insertion protocol exactly
    (update next of the previous node, prev of the new node, bump the
    tracker) — vectorized on the host since construction is bulk/static.
    """
    host = build_dip_listd_host(entity_ids, attr_ids, k=k, n=n)
    return dataclasses.replace(
        host,
        entity=jnp.asarray(host.entity),
        attr=jnp.asarray(host.attr),
        prev=jnp.asarray(host.prev),
        nxt=jnp.asarray(host.nxt),
        last_tracker=jnp.asarray(host.last_tracker),
        a_off=jnp.asarray(host.a_off),
        a_ent=jnp.asarray(host.a_ent),
    )


@jax.jit
def query_any_linked(d: DIPListD, attr_mask: jax.Array) -> jax.Array:
    """Paper-faithful query: for each selected attribute walk the prev-chain
    from ``last_tracker`` marking entities.  Sequential by construction — this
    is the O(N) pointer chase of §VI-B and is *expected* to lose to the other
    stores (validating the paper's 10× observation)."""

    if d.nnz == 0:
        return jnp.zeros((d.n,), jnp.bool_)

    def walk_attr(a, mask):
        def body(state):
            node, mask = state
            mask = mask.at[d.entity[node]].set(True)
            return d.prev[node], mask

        def cond(state):
            node, _ = state
            return node >= 0

        head = jnp.where(attr_mask[a], d.last_tracker[a], -1)
        _, mask = jax.lax.while_loop(cond, body, (head, mask))
        return mask

    mask0 = jnp.zeros((d.n,), jnp.bool_)
    return jax.lax.fori_loop(0, d.k, lambda a, m: walk_attr(a, m), mask0)


@jax.jit
def query_any_inverted(d: DIPListD, attr_mask: jax.Array) -> jax.Array:
    """Inverted-CSR query, full-scan form: hit every slot whose attribute is
    selected, scatter-max by entity.  O(nnz/P) parallel — the drop-in
    replacement for the linked walk."""
    if d.nnz == 0:
        return jnp.zeros((d.n,), jnp.bool_)
    slot_attr_hit = jnp.repeat(
        attr_mask, d.a_off[1:] - d.a_off[:-1], total_repeat_length=d.nnz
    )
    mask = jnp.zeros((d.n,), jnp.bool_)
    return mask.at[d.a_ent].max(slot_attr_hit, mode="drop")


@partial(jax.jit, static_argnames=("budget",))
def query_any_budget(d: DIPListD, attr_ids: jax.Array, *, budget: int) -> jax.Array:
    """Output-sized inverted-CSR query: gather only the selected attributes'
    segments, padded to a static ``budget`` (≥ Σ selected segment sizes; the
    host picks it from ``a_off``).  Work is O(budget), independent of nnz —
    the true beyond-paper win when queries are selective (§Perf).

    ``attr_ids``: (A,) int32, -1 entries ignored.
    """
    if d.nnz == 0:
        return jnp.zeros((d.n,), jnp.bool_)
    seg_len = jnp.where(attr_ids >= 0, d.a_off[attr_ids + 1] - d.a_off[attr_ids], 0)
    seg_start = jnp.where(attr_ids >= 0, d.a_off[attr_ids], 0)
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(seg_len).astype(jnp.int32)])
    # slot j of the budget belongs to query segment q(j) = searchsorted(cum, j)
    j = jnp.arange(budget, dtype=jnp.int32)
    q = jnp.searchsorted(cum, j, side="right") - 1
    q = jnp.clip(q, 0, attr_ids.shape[0] - 1)
    within = j - cum[q]
    valid = j < cum[-1]
    src = jnp.clip(seg_start[q] + within, 0, max(d.nnz - 1, 0))
    ent = jnp.where(valid, d.a_ent[src], 0)
    mask = jnp.zeros((d.n,), jnp.bool_)
    return mask.at[ent].max(valid, mode="drop")


def query_any(d: DIPListD, attr_mask: jax.Array, *, impl: str = "inverted") -> jax.Array:
    if impl == "linked":
        return query_any_linked(d, attr_mask)
    if impl == "inverted":
        return query_any_inverted(d, attr_mask)
    raise ValueError(f"unknown impl {impl!r}")
