"""Property-graph persistence — save/load a fully-attributed PropGraph.

Built on the same atomic-directory format the checkpoint manager uses, so a
property graph ingested once (the expensive sort/remap path, §V) is reloaded
in seconds by later analysis sessions — the interactive-workflow pattern the
paper targets ("improves data science workflow uptime", §VI).

Stores: DI arrays, both attribute stores' raw pairs (backend-independent —
the load can pick a DIFFERENT backend), attribute maps, typed property
columns.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.attr_map import AttributeMap
from repro.core.di import DIGraph
from repro.core.property_graph import PropGraph, _AttrStore

__all__ = ["save_propgraph", "load_propgraph"]

_FORMAT_VERSION = 1


def _store_pairs(store: Optional[_AttrStore]):
    if store is None or not store._pairs_e:
        return np.zeros(0, np.int32), np.zeros(0, np.int32), []
    return (np.concatenate(store._pairs_e), np.concatenate(store._pairs_a),
            store.amap.values)


def save_propgraph(path: str, pg: PropGraph) -> str:
    """Atomic save (unique tmp dir + swap).  Overwrites an existing graph at
    ``path``: the new directory is renamed in only after it is complete, and
    the old one is moved aside first (``os.rename`` onto a non-empty
    directory raises).  A reader never observes a half-written graph at
    ``path``; a crash mid-swap can at worst leave the previous version
    parked in a ``<name>.old.*`` sibling, never a torn one.

    A graph with a live overlay (delta edges / delta attribute pairs /
    tombstones) is flattened first — compact-on-save on a private fork, so
    the caller's overlay is untouched — because the on-disk format stores
    only base state; ``load_propgraph`` then round-trips bitwise."""
    if getattr(pg, "has_overlay", None) is not None and pg.has_overlay():
        pg = pg.fork()
        pg.compact()
    g = pg._require_graph()
    path = path.rstrip(os.sep)
    parent = os.path.dirname(os.path.abspath(path)) or os.sep
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=os.path.basename(path) + ".tmp.", dir=parent)
    try:
        ve, va, vvals = _store_pairs(pg._vstore)
        ee, ea, evals = _store_pairs(pg._estore)
        arrays = {
            "src": np.asarray(g.src), "dst": np.asarray(g.dst),
            "seg": np.asarray(g.seg), "node_map": np.asarray(g.node_map),
            "v_ent": ve, "v_attr": va, "e_ent": ee, "e_attr": ea,
        }
        for name, (col, valid) in pg.vertex_props.items():
            arrays[f"vp_{name}"] = np.asarray(col)
            arrays[f"vpm_{name}"] = np.asarray(valid)
        for name, (col, valid) in pg.edge_props.items():
            arrays[f"ep_{name}"] = np.asarray(col)
            arrays[f"epm_{name}"] = np.asarray(valid)
        np.savez_compressed(os.path.join(tmp, "graph.npz"), **arrays)
        manifest = {
            "version": _FORMAT_VERSION, "n": g.n, "m": g.m,
            "backend": pg.backend,
            "vertex_labels": vvals, "edge_relationships": evals,
            "vertex_props": list(pg.vertex_props),
            "edge_props": list(pg.edge_props),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.lexists(path):
            # replace-or-swap: move the old graph aside (same filesystem, so
            # both renames are atomic), expose the new one, then reclaim
            old = tempfile.mkdtemp(prefix=os.path.basename(path) + ".old.",
                                   dir=parent)
            old_g = os.path.join(old, "g")
            os.rename(path, old_g)
            try:
                os.rename(tmp, path)
            except BaseException:
                os.rename(old_g, path)  # roll the previous version back in
                shutil.rmtree(old, ignore_errors=True)
                raise
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, path)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return path


def load_propgraph(
    path: str, *, backend: Optional[str] = None, mesh=None
) -> PropGraph:
    """Load; ``backend`` may differ from the saved one (stores are rebuilt
    from raw pairs — the bulk build is the cheap step, §VII-B).  ``mesh``
    loads the graph directly onto a device mesh (the saved format is
    placement-independent) with the docs/ARCHITECTURE.md §7 layout — DIP
    stores entity-sharded, DI arrays/columns sharded when divisible — so an
    ingested-once graph reopens distributed without re-ingesting."""
    with open(os.path.join(path, "manifest.json")) as f:
        man = json.load(f)
    if man["version"] != _FORMAT_VERSION:
        raise ValueError(f"unsupported propgraph format v{man['version']}")
    with np.load(os.path.join(path, "graph.npz")) as z:
        data = {k: z[k] for k in z.files}

    pg = PropGraph(backend=backend or man["backend"], mesh=mesh)
    seg_np = data["seg"]
    g = DIGraph(
        src=jnp.asarray(data["src"]), dst=jnp.asarray(data["dst"]),
        seg=jnp.asarray(seg_np), node_map=jnp.asarray(data["node_map"]),
        n=int(man["n"]), m=int(man["m"]),
        max_deg=int(np.max(seg_np[1:] - seg_np[:-1], initial=0)),
    )
    if mesh is not None:
        from repro.core import dip_shard

        g = dip_shard.place_graph(g, mesh)
    pg.graph = g
    pg._vstore = _AttrStore(pg.backend, g.n, mesh=mesh)
    pg._estore = _AttrStore(pg.backend, max(g.m, 1), mesh=mesh)
    pg._vstore.amap = AttributeMap(man["vertex_labels"])
    pg._estore.amap = AttributeMap(man["edge_relationships"])
    if len(data["v_ent"]):
        pg._vstore._pairs_e.append(data["v_ent"])
        pg._vstore._pairs_a.append(data["v_attr"])
    if len(data["e_ent"]):
        pg._estore._pairs_e.append(data["e_ent"])
        pg._estore._pairs_a.append(data["e_attr"])
    for name in man["vertex_props"]:
        pg.vertex_props[name] = pg._place_column(
            jnp.asarray(data[f"vp_{name}"]), jnp.asarray(data[f"vpm_{name}"])
        )
    for name in man["edge_props"]:
        pg.edge_props[name] = pg._place_column(
            jnp.asarray(data[f"ep_{name}"]), jnp.asarray(data[f"epm_{name}"])
        )
    return pg
