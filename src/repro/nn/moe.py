"""Mixture-of-Experts FFN — grouped top-k routing with capacity (GShard layout).

TPU-native formulation with two deliberate design points:

1. **Grouped dispatch** (the GShard/t5x 'G' dim): tokens are split into
   ``n_groups`` dispatch groups — one per data-parallel shard — and routing
   positions/capacity are computed *within* each group, so the dispatch
   buffers are (G, E, C_g, D) with G sharded over the dp axes.  A single
   global-capacity buffer cannot be sharded by GSPMD (scatter positions span
   all of C) and replicates: measured 337 GiB/device on mixtral train_4k
   vs 5 GiB grouped (§Perf log).

2. **Scatter-based dispatch** instead of the classic dense one-hot einsums:
   O(T·k·D) instead of O(T·E·C·D) FLOPs; lowers to the same collective
   pattern.  (``dispatch='einsum'`` keeps the dense A/B baseline.)

Routing: softmax over top-k logits (Mixtral) or full-softmax-then-top-k
(DBRX) via ``renorm``.  Tokens beyond per-group capacity C_g are dropped
(standard static-shape TPU behavior).  Switch-style aux loss returned.
``shard_axes`` (optional, static) adds with_sharding_constraint annotations:
{'dp': (axis, ...), 'expert': axis|None, 'tp': axis|None}.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn.layers import init_linear

__all__ = ["init_moe", "moe_ffn", "moe_capacity"]


def moe_capacity(n_tokens: int, n_experts: int, top_k: int, factor: float = 1.25) -> int:
    c = int(n_tokens * top_k / n_experts * factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *, gated: bool = True,
             virtual_split: int = 1, dtype=jnp.float32) -> Dict:
    """virtual_split=s stores each expert as s F-slices ("virtual experts"):
    weights (E·s, D, F/s).  Exact for (gated) MLPs — silu/mul/down partial
    sums over F-slices add — and it makes E·s divide the model axis so the
    dispatch buffers shard as pure EP (no cross-TP xb-grad all-reduce in the
    backward: measured 420 GB/layer on mixtral train_4k with F-TP; §Perf)."""
    ks = jax.random.split(key, 4)
    scale = d_model ** -0.5
    s = virtual_split
    assert d_ff % s == 0
    ev, ffv = n_experts * s, d_ff // s
    p = {
        "router": init_linear(ks[0], d_model, n_experts, dtype=dtype),
        "up": jax.random.normal(ks[1], (ev, d_model, ffv), dtype) * scale,
        "down": jax.random.normal(ks[2], (ev, ffv, d_model), dtype) * (d_ff ** -0.5),
    }
    if gated:
        p["gate"] = jax.random.normal(ks[3], (ev, d_model, ffv), dtype) * scale
    return p


def _constrain(x, spec: Optional[P]):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def moe_ffn(
    p: Dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    renorm: str = "topk",  # 'topk' (Mixtral) | 'full' (DBRX)
    act=jax.nn.silu,
    dispatch: str = "scatter",
    n_groups: int = 1,
    virtual_split: int = 1,
    shard_axes: Optional[dict] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (T, D) tokens → (out (T, D), aux_loss scalar)."""
    T, D = x.shape
    s = virtual_split
    EV = p["up"].shape[0]          # virtual experts = E·s
    E = EV // s                    # routed (real) experts
    G = max(1, n_groups)
    assert T % G == 0, (T, G)
    Tg = T // G
    C = moe_capacity(Tg, E, top_k, capacity_factor)

    dp_ax = e_ax = tp_ax = None
    if shard_axes:
        dp_ax = shard_axes.get("dp")
        e_ax = shard_axes.get("expert")   # axis for the VIRTUAL expert dim
        tp_ax = shard_axes.get("tp")
    # real-expert buffers: expert dim when it divides (s==1), else capacity dim
    # over the expert axis (keeps fwd/bwd xb shards local; the E-replicated
    # form all-gathers 4 GiB f32 per layer in the backward — §Perf log)
    spec_xb = P(dp_ax, e_ax if s == 1 else None, None if s == 1 else e_ax, None) \
        if shard_axes else None
    spec_xbv = P(dp_ax, e_ax, None, None) if shard_axes else None
    spec_h = P(dp_ax, e_ax, None, tp_ax) if shard_axes else None
    spec_tok = P(dp_ax, None, None) if shard_axes else None

    xg = _constrain(x.reshape(G, Tg, D), spec_tok)
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    if renorm == "full":
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, top_k)  # (G, Tg, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    else:
        top_logits, idx = jax.lax.top_k(logits, top_k)
        gate_vals = jax.nn.softmax(top_logits, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)

    # Switch aux loss (per group, then mean): E · Σ_e f_e · P_e
    me = jnp.mean(probs, axis=1)  # (G, E)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=1)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    # --- per-group buffer positions: choice-major priority (GShard) ---------
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (G, Tg, k, E)
    ohf = jnp.swapaxes(oh, 1, 2).reshape(G, top_k * Tg, E)
    pos_all = jnp.cumsum(ohf, axis=1) - 1
    pos_flat = jnp.sum(pos_all * ohf, axis=-1)  # (G, k·Tg)
    e_flat = jnp.swapaxes(idx, 1, 2).reshape(G, -1)
    g_flat = jnp.swapaxes(gate_vals, 1, 2).reshape(G, -1)
    keep = pos_flat < C
    tok_flat = jnp.tile(jnp.arange(Tg), (top_k,))  # (k·Tg,) within-group token

    if dispatch == "einsum":
        disp = (
            jax.nn.one_hot(e_flat, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos_flat, C), C + 1, dtype=x.dtype)[..., None, :C]
        )  # (G, k·Tg, E, C)
        xb = jnp.einsum("gtec,gtd->gecd", disp, xg[:, tok_flat])
    else:
        # index-scatter + data-GATHER dispatch: the only scatter touches tiny
        # (E, C) int32 slot tables; token features then arrive via gather,
        # which GSPMD partitions freely on output dims.  Scattering the (E,C,D)
        # feature buffers directly replicates them across 'model' and drags
        # f32/u32 companion scatters through the backward (measured 420 GiB/
        # layer on mixtral train_4k; §Perf log).
        e_safe = jnp.where(keep, e_flat, E - 1)
        c_safe = jnp.where(keep, pos_flat, C)  # C is OOB ⇒ dropped (mode='drop')

        def slots_group(es, cs, tf, kp):
            slot_tok = jnp.zeros((E, C), jnp.int32).at[es, cs].set(tf, mode="drop")
            slot_ok = jnp.zeros((E, C), jnp.bool_).at[es, cs].set(kp, mode="drop")
            return slot_tok, slot_ok

        slot_tok, slot_ok = jax.vmap(slots_group)(
            e_safe, c_safe, jnp.broadcast_to(tok_flat, e_safe.shape), keep)

        def gather_group(xg_g, st, so):
            return xg_g[st] * so[..., None].astype(x.dtype)

        xb = jax.vmap(gather_group)(xg, slot_tok, slot_ok)
    xb = _constrain(xb, spec_xb)  # (G, E, C, D)

    # --- virtual expansion: every real expert's buffer feeds its s F-slices ---
    if s > 1:
        xb = jnp.broadcast_to(xb[:, :, None], (G, E, s, C, D)).reshape(G, E * s, C, D)
        xb = _constrain(xb, spec_xbv)

    # --- expert FFN (shared virtual experts, batched over G) ------------------
    h = jnp.einsum("gecd,edf->gecf", xb, p["up"].astype(x.dtype))
    if "gate" in p:
        hg = jnp.einsum("gecd,edf->gecf", xb, p["gate"].astype(x.dtype))
        h = act(hg) * h
    else:
        h = act(h)
    h = _constrain(h, spec_h)
    yb = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(x.dtype))
    yb = _constrain(yb, spec_xbv)
    if s > 1:  # partial outputs over F-slices sum
        yb = yb.reshape(G, E, s, C, D).sum(axis=2)
        yb = _constrain(yb, spec_xb)

    # --- combine: gather per token-choice, then sum over the k choices --------
    # (tok_flat is tile(arange(Tg), k) choice-major ⇒ the per-token sum is a
    # plain reshape-sum — no scatter anywhere on the combine path)
    def gather_out(buf_y, es, cs, kp, gv):
        got = buf_y[jnp.where(kp, es, 0), jnp.where(kp, cs, 0)]  # (k·Tg, D)
        return got * (gv * kp).astype(x.dtype)[:, None]

    contrib = jax.vmap(gather_out)(yb, e_flat, pos_flat, keep, g_flat)  # (G, k·Tg, D)
    out = contrib.reshape(G, top_k, Tg, D).sum(axis=1)
    out = _constrain(out, spec_tok)
    return out.reshape(T, D), aux
