"""Attention — XLA reference paths (direct + KV-chunked online-softmax) and the
Pallas flash kernel dispatch.

Supports: causal masking, sliding-window (SWA), Gemma-2 logit softcap, GQA
(n_kv_heads < n_heads), decode with query offset against a KV cache.

``impl`` selection:
  * ``direct``  — materializes (Sq, Skv) scores; fine for short sequences.
  * ``chunked`` — lax.scan over KV chunks with running (max, denom, acc):
    FlashAttention's algorithm expressed in XLA.  This is what the dry-run
    lowers (no O(S²) intermediate ⇒ honest memory roofline), and it is the
    §Perf "chunked attention" lever.
  * ``flash``   — Pallas TPU kernel (repro/kernels/flash_attention), interpret
    mode on CPU; numerically validated against ``direct`` in tests.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import softcap as _softcap

__all__ = ["attention"]

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(…, Sq, Skv) additive mask bias from position grids."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), jnp.bool_)
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def _direct(q, k, v, *, causal, window, cap, q_offset, kv_len=None):
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(k.dtype)
    scale = D ** -0.5
    # native-dtype dot, f32 accumulation: no materialized f32 copies of K/V
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, cap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Skv)
    s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)
    if kv_len is not None:  # decode: mask beyond current cache fill
        s = jnp.where(k_pos[None, None, None, None, :] < kv_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def _chunked(q, k, v, *, causal, window, cap, q_offset, kv_len=None, chunk: int = 1024):
    """Online-softmax over KV chunks (flash algorithm in XLA)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(k.dtype)
    scale = D ** -0.5
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs  # kb: (B, chunk, Hkv, D)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, cap)
        k_pos = ci * chunk + jnp.arange(chunk)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
        s = s + bias
        valid_len = Skv if kv_len is None else kv_len
        s = jnp.where(k_pos[None, None, None, None, :] < valid_len, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)),
    )
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cap: Optional[float] = None,
    q_offset=0,
    kv_len=None,
    impl: str = "auto",
    chunk: int = 1024,
) -> jax.Array:
    """q: (B,Sq,Hq,D); k,v: (B,Skv,Hkv,D) → (B,Sq,Hq,D)."""
    Skv = k.shape[1]
    if impl == "auto":
        impl = "direct" if (q.shape[1] * Skv <= 1024 * 2048) else "chunked"
    if impl == "direct":
        return _direct(q, k, v, causal=causal, window=window, cap=cap,
                       q_offset=q_offset, kv_len=kv_len)
    if impl == "chunked":
        return _chunked(q, k, v, causal=causal, window=window, cap=cap,
                        q_offset=q_offset, kv_len=kv_len, chunk=min(chunk, Skv))
    if impl == "flash":
        from repro.kernels.flash_attention import ops as _ops

        return _ops.flash_attention(q, k, v, causal=causal, window=window, cap=cap,
                                    q_offset=q_offset)
    raise ValueError(f"unknown impl {impl!r}")
