"""Shared neural-net layers — functional (init/apply), params as plain pytrees.

No flax/haiku dependency: every layer is ``init_*(key, ...) -> params`` plus a
pure apply function, so params compose into nested dicts that pjit shards via
PartitionSpec trees (see repro/launch/sharding.py).  Computation dtype is
bf16 by default with f32 accumulation/normalization, matching TPU practice.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_linear",
    "linear",
    "init_rmsnorm",
    "rmsnorm",
    "init_layernorm",
    "layernorm",
    "init_mlp",
    "mlp",
    "rope",
    "softcap",
]

Dtype = jnp.dtype


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False, scale: Optional[float] = None,
                dtype=jnp.float32):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, *, eps: float = 1e-6, plus_one: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = p["scale"].astype(jnp.float32)
    s = 1.0 + s if plus_one else s  # gemma convention stores scale-1
    return (y * s).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _act(name: str, x):
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


def init_mlp(key, d_model: int, d_ff: int, *, gated: bool, act: str = "silu", dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": init_linear(k1, d_model, d_ff, dtype=dtype),
        "down": init_linear(k2, d_ff, d_model, dtype=dtype),
        "act": act,  # static string survives as aux? no — keep out of pytree
    }
    p.pop("act")
    if gated:
        p["gate"] = init_linear(k3, d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, *, act: str = "silu"):
    h = linear(p["up"], x)
    if "gate" in p:
        h = _act(act, linear(p["gate"], x)) * h
    else:
        h = _act(act, h)
    return linear(p["down"], h)


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.  x: (..., seq, n_heads, d_head); positions
    broadcastable to (..., seq).  Pairs (even, odd) halves — GPT-NeoX layout.
    """
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap·tanh(x/cap).  None ⇒ identity."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
