"""EXPLAIN ANALYZE — executed-plan profiling with the compile/execute
split (docs/ARCHITECTURE.md §13).

``PropGraph.explain()`` shows the plan the optimizer CHOSE;
``explain_analyze()`` runs it and reports where the wall time WENT:
per-stage times (parse, plan, mask materialization, propagation) and —
the number JAX makes easy to misread — how much of the first call was
XLA compilation versus device execution.

The split is measured, not inferred: each device stage runs twice under
``jax.block_until_ready``.  The first run pays tracing + compilation iff
the jit cache is cold for this (plan structure, graph shape) signature;
the immediate re-run hits the compiled executable, so

    compile_ms ≈ max(0, first_ms − steady_ms)   per stage.

On a warm cache both runs take ~the same time and compile_ms ≈ 0 — which
is exactly the acceptance probe: profile a fresh pattern shape, then
profile it again, and the report's compile share collapses.  The re-run
costs one extra steady-state execution (µs–ms); that's the price of an
honest number and why this is a profiling verb, not the default path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax

from repro.obs import metrics as _metrics

__all__ = ["ProfileReport", "profile_match"]

_now = time.perf_counter

# below this, first-vs-steady deltas are timer noise, not compilation
_COMPILE_NOISE_MS = 0.5


@dataclass
class ProfileReport:
    """Executed-plan annotation returned by ``explain_analyze()`` /
    ``match(..., profile=True)``.  All times in milliseconds; ``*_first``
    is the as-observed first call, the unsuffixed device-stage fields are
    the steady-state re-run."""

    plan: Any
    parse_ms: float
    plan_ms: float
    masks_first_ms: float
    masks_ms: float
    execute_first_ms: float
    execute_ms: float
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def compile_ms(self) -> float:
        """Estimated XLA tracing+compilation share of the first call."""
        c = (max(0.0, self.masks_first_ms - self.masks_ms)
             + max(0.0, self.execute_first_ms - self.execute_ms))
        return c if c >= _COMPILE_NOISE_MS else 0.0

    @property
    def cold(self) -> bool:
        """True iff the first call visibly paid compilation."""
        return self.compile_ms > 0.0

    @property
    def total_first_ms(self) -> float:
        return (self.parse_ms + self.plan_ms
                + self.masks_first_ms + self.execute_first_ms)

    @property
    def steady_ms(self) -> float:
        return self.parse_ms + self.plan_ms + self.masks_ms + self.execute_ms

    def to_dict(self) -> Dict[str, Any]:
        return {
            "parse_ms": round(self.parse_ms, 4),
            "plan_ms": round(self.plan_ms, 4),
            "masks_first_ms": round(self.masks_first_ms, 4),
            "masks_ms": round(self.masks_ms, 4),
            "execute_first_ms": round(self.execute_first_ms, 4),
            "execute_ms": round(self.execute_ms, 4),
            "compile_ms": round(self.compile_ms, 4),
            "total_first_ms": round(self.total_first_ms, 4),
            "steady_ms": round(self.steady_ms, 4),
            "cold": self.cold,
            **self.attrs,
        }

    def describe(self) -> str:
        """``Plan.describe()`` plus the measured timing annotation."""
        n_steps = len(self.plan.mask_steps)
        n_fused = len(self.plan.fused_node_slots)
        lines = [self.plan.describe(), "-- analyze --"]
        lines.append(f"  parse                {self.parse_ms:9.3f} ms")
        lines.append(f"  plan                 {self.plan_ms:9.3f} ms")
        lines.append(
            f"  {'masks (%d steps, %d fused)' % (n_steps, n_fused):<21}"
            f" first {self.masks_first_ms:9.3f} ms"
            f" / steady {self.masks_ms:9.3f} ms")
        lines.append(
            f"  propagate            first {self.execute_first_ms:9.3f} ms"
            f" / steady {self.execute_ms:9.3f} ms")
        if self.cold:
            lines.append(
                f"  compile (first call) {self.compile_ms:9.3f} ms"
                "  <- XLA tracing+compilation, absent on warm cache")
        else:
            lines.append("  compile (first call)     ~0       ms  (jit cache warm)")
        lines.append(
            f"  total                first {self.total_first_ms:9.3f} ms"
            f" / steady {self.steady_ms:9.3f} ms")
        return "\n".join(lines)


def profile_match(pg, pattern, *, impl: Optional[str] = None):
    """Run ``pattern`` against ``pg`` with per-stage timing; returns
    ``(MatchResult, ProfileReport)``.  Implements
    ``PropGraph.match(..., profile=True)`` and ``explain_analyze()``."""
    from repro.query import parse, plan_pattern
    from repro.query.executor import _materialize_masks, execute_plan_with_masks

    t0 = _now()
    pat = parse(pattern) if isinstance(pattern, str) else pattern
    t1 = _now()
    plan = plan_pattern(pg, pat, impl=impl)
    t2 = _now()

    pg._require_graph()
    label_masks, rel_masks = _materialize_masks(pg, plan)
    jax.block_until_ready((label_masks, rel_masks))
    t3 = _now()
    label_masks, rel_masks = _materialize_masks(pg, plan)
    jax.block_until_ready((label_masks, rel_masks))
    t4 = _now()

    result = execute_plan_with_masks(pg, plan, label_masks, rel_masks)
    jax.block_until_ready(result)
    t5 = _now()
    result = execute_plan_with_masks(pg, plan, label_masks, rel_masks)
    jax.block_until_ready(result)
    t6 = _now()

    report = ProfileReport(
        plan=plan,
        parse_ms=(t1 - t0) * 1e3,
        plan_ms=(t2 - t1) * 1e3,
        masks_first_ms=(t3 - t2) * 1e3,
        masks_ms=(t4 - t3) * 1e3,
        execute_first_ms=(t5 - t4) * 1e3,
        execute_ms=(t6 - t5) * 1e3,
        attrs={"backend": plan.backend,
               "mask_steps": len(plan.mask_steps),
               "fused_slots": len(plan.fused_node_slots),
               "traversal": plan.has_traversal},
    )
    _metrics.GLOBAL.counter(
        "pg_profile_runs", "explain_analyze invocations").inc()
    return result, report
