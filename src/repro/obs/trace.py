"""Per-query trace spans (docs/ARCHITECTURE.md §13).

A ``Trace`` is one query's tree of timed ``Span``s — the canonical span
vocabulary is parse → plan → cache → batch.wait → compile → execute →
serialize, though callers may nest anything.  Traces are explicit
objects handed along the call chain rather than thread-locals, because a
served query hops threads twice (submit thread → scheduler worker →
session writer) and implicit context would silently detach.

Trace ids are caller-supplied (the wire client mints one per query and
sends it in the frame header; the server echoes the finished span tree
back in the response header) or minted locally.  Finished traces land in
a per-service ``TraceBuffer``: a bounded ring plus a slow-query ring for
traces over a wall-time threshold.

Everything here is wall-clock bookkeeping on the host — ``Span`` never
touches device state, so a span around a jitted call measures dispatch
unless the caller blocks (the EXPLAIN ANALYZE path in obs/profile.py is
the one that inserts ``block_until_ready`` to split compile from
execute).
"""
from __future__ import annotations

import threading
import time
import uuid
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Trace", "TraceBuffer", "new_trace_id"]

_now = time.perf_counter


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


class Span:
    """One timed node in a trace tree.  Context manager::

        with trace.span("plan") as sp:
            plan = plan_pattern(...)
            sp.annotate(steps=len(plan.mask_steps))
    """

    __slots__ = ("name", "t0", "t1", "attrs", "children", "_trace")

    def __init__(self, name: str, trace: "Trace",
                 t0: Optional[float] = None):
        self.name = name
        self.t0 = _now() if t0 is None else t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, Any] = {}
        self.children: List["Span"] = []
        self._trace = trace

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.finish()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)

    def finish(self) -> None:
        if self.t1 is None:
            self.t1 = _now()

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def span(self, name: str) -> "Span":
        """Open a child span (returns it started; use as a context manager
        or ``finish()`` it explicitly)."""
        child = Span(name, self._trace)
        with self._trace._lock:
            self.children.append(child)
        return child

    @property
    def duration_ms(self) -> float:
        end = self.t1 if self.t1 is not None else _now()
        return (end - self.t0) * 1e3

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name,
                             "ms": round(self.duration_ms, 4)}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["spans"] = [c.to_dict() for c in self.children]
        return d


class Trace:
    """One query's span tree, rooted at ``name`` (e.g. ``"query"``)."""

    __slots__ = ("trace_id", "root", "_lock")

    def __init__(self, name: str = "query",
                 trace_id: Optional[str] = None):
        self.trace_id = trace_id or new_trace_id()
        self._lock = threading.Lock()
        self.root = Span(name, self)

    def span(self, name: str, parent: Optional[Span] = None) -> Span:
        return (parent or self.root).span(name)

    def add_span(self, name: str, t0: float, t1: float,
                 parent: Optional[Span] = None, **attrs) -> Span:
        """Record a span from explicit ``perf_counter`` endpoints — for
        stage timings measured once per coalesced GROUP and copied into
        every member request's trace afterwards."""
        sp = Span(name, self, t0=t0)
        sp.t1 = t1
        sp.attrs.update(attrs)
        with self._lock:
            (parent or self.root).children.append(sp)
        return sp

    def annotate(self, **attrs) -> "Trace":
        self.root.annotate(**attrs)
        return self

    def finish(self) -> "Trace":
        self.root.finish()
        return self

    @property
    def finished(self) -> bool:
        return self.root.t1 is not None

    @property
    def duration_ms(self) -> float:
        return self.root.duration_ms

    def to_dict(self) -> Dict[str, Any]:
        d = self.root.to_dict()
        d["trace_id"] = self.trace_id
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Trace":
        """Rehydrate a serialized span tree (client side of the wire
        round-trip).  Durations are preserved as recorded; absolute
        perf_counter epochs are not meaningful across processes, so spans
        are re-anchored at 0."""
        tr = cls(name=d.get("name", "query"), trace_id=d.get("trace_id"))

        def _load(node: Dict[str, Any], into: Span) -> None:
            into.t0 = 0.0
            into.t1 = float(node.get("ms", 0.0)) / 1e3
            into.attrs = dict(node.get("attrs", {}))
            for child in node.get("spans", []):
                sp = Span(child.get("name", "?"), tr)
                into.children.append(sp)
                _load(child, sp)

        _load(d, tr.root)
        return tr


class TraceBuffer:
    """Bounded ring of finished traces + a slow-query ring.

    ``push`` finishes the trace if the caller hasn't, appends to the main
    ring (oldest evicted), and mirrors traces at or above ``slow_ms``
    into the slow ring.  ``slow_ms=0`` captures everything (the tests'
    lever); ``maxlen=0`` disables collection entirely.
    """

    def __init__(self, maxlen: int = 256, slow_ms: float = 250.0,
                 slow_maxlen: int = 64):
        self.maxlen = int(maxlen)
        self.slow_ms = float(slow_ms)
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=max(self.maxlen, 1))
        self._slow: deque = deque(maxlen=max(int(slow_maxlen), 1))

    def push(self, trace: Trace) -> None:
        if self.maxlen <= 0:
            return
        trace.finish()
        with self._lock:
            self._ring.append(trace)
            if trace.duration_ms >= self.slow_ms:
                self._slow.append(trace)

    def traces(self) -> List[Dict[str, Any]]:
        with self._lock:
            items = list(self._ring)
        return [t.to_dict() for t in items]

    def slow(self) -> List[Dict[str, Any]]:
        with self._lock:
            items = list(self._slow)
        return [t.to_dict() for t in items]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)
