"""repro.obs — observability layer (docs/ARCHITECTURE.md §13).

One instrumentation vocabulary for the whole stack:

* ``obs.metrics`` — thread-safe counters / gauges / fixed-bucket
  histograms in per-``Service`` and process-``GLOBAL`` registries, with
  Prometheus text exposition and a module-level kill switch
  (``set_enabled(False)`` → every call site degrades to one branch).
* ``obs.trace`` — per-query span trees (parse→plan→cache→batch→execute→
  serialize) with wire-propagated trace ids, a bounded trace ring and a
  slow-query log.
* ``obs.profile`` — EXPLAIN ANALYZE: executed plans annotated with
  per-stage wall times and the measured JAX compile-vs-execute split.
"""
from repro.obs.metrics import (
    GLOBAL,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    enabled,
    parse_prometheus,
    render_prometheus,
    set_enabled,
)
from repro.obs.profile import ProfileReport, profile_match
from repro.obs.trace import Span, Trace, TraceBuffer, new_trace_id

__all__ = [
    "GLOBAL",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "enabled",
    "parse_prometheus",
    "render_prometheus",
    "set_enabled",
    "ProfileReport",
    "profile_match",
    "Span",
    "Trace",
    "TraceBuffer",
    "new_trace_id",
]
