"""Process-wide metrics primitives — counters, gauges, fixed-bucket
histograms — with Prometheus text exposition (docs/ARCHITECTURE.md §13).

One vocabulary for every subsystem's accounting instead of per-module
ad-hoc dicts: the scheduler, the LRU caches, the executor and frontier
engines, the overlay/compactor and the wire layer all register their
instruments here, and three consumers read them back —
``Service.stats()`` (the flat snapshot dict), the ``metrics`` wire verb
(Prometheus text), and the benchmark overhead guard.

Two registry scopes, by OWNERSHIP of the instrumented object:

* ``GLOBAL`` — the module-level registry for process-wide call sites
  (wire frames/bytes, executor plan counts, compactor sweeps): code that
  has no natural owner object.  A server process has exactly one of
  everything, so Prometheus exposition renders ``GLOBAL`` plus the
  service's own registry as one scrape.
* per-``Service`` ``MetricsRegistry`` instances — counters whose
  lifetime IS the service's (request/batch/cache accounting).  Tests
  build many short-lived services in one process; giving each its own
  registry keeps their ``stats()`` deltas deterministic instead of
  accumulating across instances.

Cost model: every mutating call checks the module-level ``_ENABLED``
flag first and returns immediately when instrumentation is off — the
disabled path is one global read and a branch (the bench_serve overhead
guard pins it at <5% on the coalesce row).  When enabled, counters and
gauges are one lock + int add; histograms add a bisect over a small
fixed bucket list.  Instrument objects are created once and cached on
``(name, labels)``, so steady-state call sites never re-enter the
registry lock.

Naming: short legacy keys (``result_hits`` — what ``Service.stats()``
has always returned) are accepted as metric names and normalized to
Prometheus conventions only at render time (``pg_service_result_hits_total``);
names that already carry a ``pg_`` prefix render as-is.  ``parse_prometheus``
is the matching reader (tests and the smoke gates use it to assert the
exposition agrees with ``stats()``).
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "enabled",
    "set_enabled",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "GLOBAL",
    "DEFAULT_MS_BUCKETS",
    "SIZE_BUCKETS",
    "render_prometheus",
    "parse_prometheus",
]

_ENABLED = True  # module-level switch; call sites read it once per call


def enabled() -> bool:
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip instrumentation globally; returns the PREVIOUS value (so
    benchmark guards can restore it).  Applies to every registry at once —
    the flag is the module's, not a registry's."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


# latency histograms (milliseconds): sub-100µs scheduler waits up to
# multi-second compiles land in distinct buckets
DEFAULT_MS_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0,
)
# occupancy/width histograms (counts): powers of two up to the scheduler's
# max_batch × the largest Q bucket
SIZE_BUCKETS: Tuple[float, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256)


class _Metric:
    """Shared identity: ``name`` plus a frozen label set."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = threading.Lock()

    def key(self) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
        return (self.name, self.labels)


class Counter(_Metric):
    """Monotonic counter.  ``inc`` is atomic (lock + int add) — safe under
    the scheduler worker, session writer threads and the compactor daemon
    concurrently (the ``Service._bump`` lost-update audit's fix)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()):
        super().__init__(name, help, labels)
        self._value = 0

    def inc(self, n: int = 1) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._value += n

    def set_total(self, value) -> None:
        """Mirror an externally-maintained monotonic total (the LRU caches
        keep their own hit/miss ints; exposition copies them in here so the
        text format and ``stats()`` can never disagree).  Monotonicity is
        the CALLER's contract."""
        with self._lock:
            self._value = value

    def value(self):
        with self._lock:
            return self._value


class Gauge(_Metric):
    """Point-in-time value (cache occupancy, capacity).  NOT gated on the
    enable flag: gauges record state rather than hot-path events — they
    are set at exposition time (``Service.metrics_text`` mirrors cache
    occupancy in) and must stay truthful even with instrumentation off."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = ()):
        super().__init__(name, help, labels)
        self._value = 0.0

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n=1) -> None:
        self.inc(-n)

    def value(self):
        with self._lock:
            return self._value


class Histogram(_Metric):
    """Fixed-bucket histogram: cumulative bucket counts, sum, count —
    the Prometheus ``le`` semantics.  Buckets are chosen at registration
    and never resize (observation cost stays a bisect + two adds)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Tuple[Tuple[str, str], ...] = (),
                 buckets: Tuple[float, ...] = DEFAULT_MS_BUCKETS):
        super().__init__(name, help, labels)
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"buckets must be sorted and non-empty: {buckets}")
        self.buckets = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.buckets) + 1)  # last = +Inf overflow
        self._sum = 0.0
        self._count = 0

    def observe(self, value) -> None:
        if not _ENABLED:
            return
        v = float(value)
        i = bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def value(self) -> Dict[str, object]:
        """Snapshot as a plain dict (what ``Service.stats()`` embeds)."""
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, out = 0, {}
        for b, c in zip(self.buckets, counts):
            cum += c
            out[b] = cum
        return {"count": total, "sum": s, "buckets": out}


class MetricsRegistry:
    """Thread-safe get-or-create home for instruments.

    ``counter("result_hits")`` returns THE counter of that (name, labels)
    identity — repeated calls are a dict hit, so call sites may fetch by
    name on the hot path or hold the object, whichever reads better."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, _Metric] = {}

    def _get(self, cls, name: str, help: str, labels: dict, **kw) -> _Metric:
        lab = tuple(sorted((str(k), str(v)) for k, v in labels.items()))
        key = (name, lab)
        m = self._metrics.get(key)  # racy fast path: dict get is atomic
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, help=help, labels=lab, **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_MS_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> Dict[str, object]:
        """Flat dict: unlabeled metrics key by bare name, labeled ones by
        ``name{k=v,...}``.  Counters/gauges → numbers, histograms → the
        ``value()`` dict.  This is ``Service.stats()``'s backing read."""
        out: Dict[str, object] = {}
        for m in self.metrics():
            if m.labels:
                lab = ",".join(f"{k}={v}" for k, v in m.labels)
                out[f"{m.name}{{{lab}}}"] = m.value()
            else:
                out[m.name] = m.value()
        return out


GLOBAL = MetricsRegistry()


# --------------------------------------------------------------- exposition
def _prom_name(m: _Metric) -> str:
    """Normalize a metric name to Prometheus conventions: short legacy
    service keys pick up the ``pg_service_`` namespace, counters the
    ``_total`` suffix; explicit ``pg_*`` names pass through."""
    name = m.name
    if not name.startswith("pg_"):
        name = "pg_service_" + name
    name = "".join(c if (c.isalnum() or c == "_") else "_" for c in name)
    if m.kind == "counter" and not name.endswith("_total"):
        name += "_total"
    return name


def _fmt_labels(labels: Iterable[Tuple[str, str]], extra: str = "") -> str:
    parts = [
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in labels
    ]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v) -> str:
    f = float(v)
    return str(int(f)) if f.is_integer() else repr(f)


def render_prometheus(*registries: MetricsRegistry) -> str:
    """Prometheus text format (version 0.0.4) for every instrument in
    ``registries``, grouped by family so ``# TYPE`` appears once per name.
    Disabled instrumentation still renders — values just stop moving."""
    families: Dict[str, List[_Metric]] = {}
    kinds: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for reg in registries:
        for m in reg.metrics():
            pname = _prom_name(m)
            families.setdefault(pname, []).append(m)
            kinds.setdefault(pname, m.kind)
            if m.help:
                helps.setdefault(pname, m.help)
    lines: List[str] = []
    for pname in sorted(families):
        if pname in helps:
            lines.append(f"# HELP {pname} {helps[pname]}")
        lines.append(f"# TYPE {pname} {kinds[pname]}")
        for m in families[pname]:
            if isinstance(m, Histogram):
                snap = m.value()
                for le, cum in snap["buckets"].items():
                    le_lab = 'le="%s"' % _fmt_value(le)
                    lines.append(
                        f"{pname}_bucket{_fmt_labels(m.labels, le_lab)} {cum}")
                inf_lab = 'le="+Inf"'
                lines.append(
                    f"{pname}_bucket{_fmt_labels(m.labels, inf_lab)} "
                    f"{snap['count']}")
                lines.append(
                    f"{pname}_sum{_fmt_labels(m.labels)} {_fmt_value(snap['sum'])}")
                lines.append(
                    f"{pname}_count{_fmt_labels(m.labels)} {snap['count']}")
            else:
                lines.append(
                    f"{pname}{_fmt_labels(m.labels)} {_fmt_value(m.value())}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, float]:
    """Strict-enough reader for the text format: returns
    ``{"name" | "name{labels}": value}``.  Raises ``ValueError`` on any
    malformed sample line — the smoke gates call this to assert the
    exposition actually parses, so leniency here would defeat them."""
    out: Dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # name{labels} value  |  name value   (no timestamps emitted here)
        if "}" in line:
            name_part, _, rest = line.partition("}")
            name_part += "}"
            value_part = rest.strip()
            if "{" not in name_part:
                raise ValueError(f"line {lineno}: unbalanced labels: {line!r}")
        else:
            name_part, _, value_part = line.partition(" ")
        if not name_part or not value_part:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        try:
            value = float(value_part.split()[0])
        except ValueError:
            raise ValueError(
                f"line {lineno}: bad value {value_part!r}") from None
        bare = name_part.split("{", 1)[0]
        if not bare or not (bare[0].isalpha() or bare[0] == "_"):
            raise ValueError(f"line {lineno}: bad metric name {bare!r}")
        out[name_part] = value
    return out
