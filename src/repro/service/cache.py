"""Thread-safe LRU caches for the analytics service.

Two instances per ``Service`` (src/repro/service/README.md "Cache keys"):

* **plan cache** — key ``(canonical pattern, backend, impl)`` → ``Plan``.
  Plans are graph-independent semantically (a plan is the pattern plus
  per-mask impl choices; reorientation only changes propagation ORDER, not
  the match set), so the key deliberately excludes the graph version —
  a plan survives mutations; only its selectivity estimates go stale,
  which costs performance, never correctness.
* **result cache** — key ``(graph name, canonical pattern, impl)`` →
  ``(version, pattern refs, MatchResult)``.  Freshness is maintained by
  OVERLAP-BASED purging instead of a version key component: when the
  registry reports a mutation, the service drops only entries whose
  pattern footprint (labels/relationships/properties, carried in the
  value) the mutation's ``MutationEvent`` touches — a result cached at
  snapshot S keeps serving hits across writes that only grew the delta
  chain past S with unrelated attributes (docs/ARCHITECTURE.md §11).
  Structural events (edge inserts/deletes, rebuilds, compaction) purge
  every entry for the graph.

``maxsize=0`` disables a cache (every ``get`` misses, ``put`` is a no-op) —
the benchmark's "coalescing only" configuration.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional

__all__ = ["LRUCache"]

_MISS = object()


class LRUCache:
    """OrderedDict-based LRU with hit/miss/eviction accounting.

    All operations take the internal lock — safe to share between client
    threads (submit-side result-cache probes), the scheduler worker and
    mutation hooks (purge)."""

    def __init__(self, maxsize: int):
        if maxsize < 0:
            raise ValueError(f"maxsize must be ≥ 0, got {maxsize}")
        self.maxsize = maxsize
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            val = self._data.get(key, _MISS)
            if val is _MISS:
                self.misses += 1
                return default
            self._data.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key: Hashable, value: Any) -> None:
        if self.maxsize == 0:
            return
        with self._lock:
            self._data[key] = value
            # move_to_end is load-bearing on overwrite: assignment to an
            # EXISTING key keeps its old OrderedDict position, and a hot
            # re-inserted entry left there would be evicted as if cold
            # (tests/test_service.py: ..._put_on_existing_key_refreshes...)
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge(self, predicate: Callable[[Hashable, Any], bool]) -> int:
        """Drop every entry where ``predicate(key, value)`` holds; returns
        the number dropped (the service's invalidation counter feed).  The
        value participates so the result cache can purge by OVERLAP — its
        entries carry the pattern's attribute footprint (§11)."""
        with self._lock:
            dead = [k for k, v in self._data.items() if predicate(k, v)]
            for k in dead:
                del self._data[k]
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "maxsize": self.maxsize,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
