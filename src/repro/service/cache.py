"""Thread-safe LRU caches for the analytics service.

Two instances per ``Service`` (src/repro/service/README.md "Cache keys"):

* **plan cache** — key ``(canonical pattern, backend, impl)`` → ``Plan``.
  Plans are graph-independent semantically (a plan is the pattern plus
  per-mask impl choices; reorientation only changes propagation ORDER, not
  the match set), so the key deliberately excludes the graph version —
  a plan survives mutations; only its selectivity estimates go stale,
  which costs performance, never correctness.
* **result cache** — key ``(graph name, version, canonical pattern, impl)``
  → ``MatchResult``.  The version component makes stale reads structurally
  impossible: every ``PropGraph`` mutator bumps ``version``, so a cached
  result is unreachable the moment its graph changes.  ``purge`` drops the
  dead entries eagerly when the registry reports a mutation (they would
  otherwise linger until LRU eviction).

``maxsize=0`` disables a cache (every ``get`` misses, ``put`` is a no-op) —
the benchmark's "coalescing only" configuration.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional

__all__ = ["LRUCache"]

_MISS = object()


class LRUCache:
    """OrderedDict-based LRU with hit/miss/eviction accounting.

    All operations take the internal lock — safe to share between client
    threads (submit-side result-cache probes), the scheduler worker and
    mutation hooks (purge)."""

    def __init__(self, maxsize: int):
        if maxsize < 0:
            raise ValueError(f"maxsize must be ≥ 0, got {maxsize}")
        self.maxsize = maxsize
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, default: Any = None) -> Any:
        with self._lock:
            val = self._data.get(key, _MISS)
            if val is _MISS:
                self.misses += 1
                return default
            self._data.move_to_end(key)
            self.hits += 1
            return val

    def put(self, key: Hashable, value: Any) -> None:
        if self.maxsize == 0:
            return
        with self._lock:
            self._data[key] = value
            # move_to_end is load-bearing on overwrite: assignment to an
            # EXISTING key keeps its old OrderedDict position, and a hot
            # re-inserted entry left there would be evicted as if cold
            # (tests/test_service.py: ..._put_on_existing_key_refreshes...)
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
                self.evictions += 1

    def purge(self, predicate: Callable[[Hashable], bool]) -> int:
        """Drop every entry whose KEY satisfies ``predicate``; returns the
        number dropped (the service's invalidation counter feed)."""
        with self._lock:
            dead = [k for k in self._data if predicate(k)]
            for k in dead:
                del self._data[k]
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def stats(self) -> dict:
        with self._lock:
            return {
                "size": len(self._data),
                "maxsize": self.maxsize,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }
