"""Wire codec for the pgd network front-end — length-prefixed JSON + binary.

Arachne rides Arkouda's request/reply server: a thin Python client sends
small messages naming server-held objects, the server answers with small
metadata plus (when needed) bulk array payloads (paper §III,
docs/ARCHITECTURE.md §9).  This module is that message format for the
analytics service — one codec shared by ``server.py`` and ``client.py`` so
the two can never disagree about framing.

Frame layout (all integers big-endian)::

    MAGIC (4 bytes, b"PGW1")
    payload_len   uint32        # bytes after this field
    header_len    uint32        # JSON part of the payload
    header        UTF-8 JSON    # op/id/fields + "arrays": [spec, ...]
    blob          bytes         # the arrays' buffers, concatenated

The header is small and human-debuggable JSON; bulk data (masks, id
arrays, property columns) travels as raw buffers described by per-array
specs ``{"dtype", "shape"}`` appended by the codec.  Bool arrays are
``np.packbits(bitorder="little")``-packed on the wire (8× smaller) and
restored exactly — mask round-trips are bitwise, which the cross-process
equivalence gate relies on (``pgserve --net --smoke``).  Little-endian bit
order makes the wire bytes IDENTICAL to the ``core.bitplane`` word plane's
byte view, so a mask the server already holds packed ships verbatim
(:class:`PackedMask` — no unpack→repack; ``result_to_wire`` packs device
masks in one launch each and hands the codec the raw words).

``recv_msg`` raises ``ConnectionError`` on a clean EOF at a frame
boundary (peer closed) and ``ProtocolError`` on everything else —
truncated frames, bad magic, oversized payloads.
"""
from __future__ import annotations

import dataclasses
import json
import socket
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "MAGIC",
    "MAX_PAYLOAD",
    "ProtocolError",
    "RemoteError",
    "PackedMask",
    "encode_msg",
    "send_msg",
    "recv_msg",
    "result_to_wire",
    "wire_to_result",
    "WireMatchResult",
    "WireSampledBlock",
    "blocks_to_wire",
    "wire_to_blocks",
    "exc_to_wire",
    "wire_to_exc",
]

MAGIC = b"PGW1"
MAX_PAYLOAD = 1 << 30  # 1 GiB — fail fast on garbage length prefixes
_LEN = struct.Struct("!I")

# process-global frame/byte accounting (docs/ARCHITECTURE.md §13); the
# counters are resolved once at import so the per-frame cost with metrics
# ON is two lock+add pairs, and with metrics OFF a single flag check
from repro.obs.metrics import enabled as _obs_enabled  # noqa: E402


def _wire_counters(direction: str):
    from repro.obs.metrics import GLOBAL

    return (GLOBAL.counter("pg_wire_frames", "wire frames", dir=direction),
            GLOBAL.counter("pg_wire_bytes", "wire bytes", dir=direction))


_SENT = _wire_counters("sent")
_RECEIVED = _wire_counters("received")


class ProtocolError(RuntimeError):
    """Malformed frame: bad magic, truncated payload, oversized length."""


class RemoteError(RuntimeError):
    """A server-side exception type we cannot reconstruct locally."""

    def __init__(self, type_name: str, message: str):
        super().__init__(f"{type_name}: {message}")
        self.type_name = type_name
        self.message = message


# ------------------------------------------------------------------ arrays
@dataclasses.dataclass(frozen=True)
class PackedMask:
    """A (n,) bool mask already bit-packed in ``core.bitplane`` layout.

    The codec ships its little-endian byte view verbatim (tail bits are
    zero by the bitplane invariant, exactly what ``np.packbits`` would
    emit) and the receiver sees a plain bool array — senders holding
    packed words skip the unpack→repack round-trip entirely."""

    words: np.ndarray  # (ceil(n/32),) uint32, little-endian bit order
    n: int


def _pack_array(a) -> Tuple[dict, bytes]:
    if isinstance(a, PackedMask):
        spec = {"dtype": "bool", "shape": [int(a.n)]}
        nbytes = (int(a.n) + 7) // 8
        words = np.ascontiguousarray(np.asarray(a.words, dtype="<u4"))
        return spec, words.view(np.uint8)[:nbytes].tobytes()
    a = np.ascontiguousarray(a)
    spec = {"dtype": str(a.dtype), "shape": list(a.shape)}
    if a.dtype == np.bool_:
        return spec, np.packbits(a.reshape(-1), bitorder="little").tobytes()
    return spec, a.tobytes()


def _parse_spec(spec) -> Tuple[np.dtype, Tuple[int, ...], int]:
    """Validate an untrusted array spec → (dtype, shape, element count);
    anything off is ``ProtocolError`` (a corrupt frame must never surface
    as a raw numpy error — the server session and client loops only handle
    protocol exceptions).  The count is computed with Python ints, so an
    absurd shape cannot overflow into a plausible-looking size."""
    try:
        dtype = np.dtype(spec["dtype"])
        shape = tuple(spec["shape"])
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"bad array spec {spec!r}: {e}") from None
    if dtype.hasobject or not all(
            isinstance(d, int) and 0 <= d <= MAX_PAYLOAD for d in shape):
        raise ProtocolError(f"bad array spec {spec!r}")
    count = 1
    for d in shape:
        count *= d
    if count * max(dtype.itemsize, 1) > MAX_PAYLOAD:
        raise ProtocolError(f"bad array spec {spec!r}: too large")
    return dtype, shape, count


def _blob_nbytes(dtype: np.dtype, count: int) -> int:
    if dtype == np.bool_:
        return (count + 7) // 8
    return count * dtype.itemsize


def _unpack_array(dtype: np.dtype, shape: Tuple[int, ...], count: int,
                  buf: memoryview) -> np.ndarray:
    if dtype == np.bool_:
        bits = np.unpackbits(np.frombuffer(buf, np.uint8), count=count,
                             bitorder="little")
        return bits.astype(np.bool_).reshape(shape)
    return np.frombuffer(buf, dtype=dtype, count=count).reshape(shape)


# ------------------------------------------------------------------ framing
def encode_msg(header: Dict, arrays: Sequence[np.ndarray] = ()) -> bytes:
    """One complete frame.  ``header`` must be JSON-serializable; the codec
    owns the ``"arrays"`` key."""
    specs, blobs = [], []
    for a in arrays:
        spec, blob = _pack_array(a if isinstance(a, PackedMask)
                                 else np.asarray(a))
        specs.append(spec)
        blobs.append(blob)
    hdr = dict(header)
    hdr["arrays"] = specs
    hbytes = json.dumps(hdr, sort_keys=True).encode("utf-8")
    payload_len = _LEN.size + len(hbytes) + sum(len(b) for b in blobs)
    if payload_len > MAX_PAYLOAD:
        raise ProtocolError(f"frame too large: {payload_len} bytes")
    parts = [MAGIC, _LEN.pack(payload_len), _LEN.pack(len(hbytes)), hbytes]
    parts.extend(blobs)
    return b"".join(parts)


def send_msg(sock: socket.socket, header: Dict,
             arrays: Sequence[np.ndarray] = ()) -> None:
    buf = encode_msg(header, arrays)
    if _obs_enabled():
        frames, nbytes = _SENT
        frames.inc()
        nbytes.inc(len(buf))
    sock.sendall(buf)


def _recv_exact(sock: socket.socket, n: int, *, at_boundary: bool) -> bytes:
    chunks, got = [], 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if at_boundary and got == 0:
                raise ConnectionError("peer closed the connection")
            raise ProtocolError(f"truncated frame: wanted {n} bytes, got {got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket) -> Tuple[Dict, List[np.ndarray]]:
    """Read one frame → ``(header, arrays)``; blocks until complete."""
    head = _recv_exact(sock, len(MAGIC) + _LEN.size, at_boundary=True)
    if _obs_enabled():
        frames, nbytes = _RECEIVED
        frames.inc()
        nbytes.inc(len(head))
    if head[: len(MAGIC)] != MAGIC:
        raise ProtocolError(f"bad magic {head[:len(MAGIC)]!r}")
    (payload_len,) = _LEN.unpack(head[len(MAGIC):])
    if payload_len > MAX_PAYLOAD or payload_len < _LEN.size:
        raise ProtocolError(f"bad payload length {payload_len}")
    payload = memoryview(_recv_exact(sock, payload_len, at_boundary=False))
    if _obs_enabled():
        _RECEIVED[1].inc(payload_len)
    (header_len,) = _LEN.unpack(payload[: _LEN.size])
    if _LEN.size + header_len > payload_len:
        raise ProtocolError(f"bad header length {header_len}")
    try:
        header = json.loads(bytes(payload[_LEN.size:_LEN.size + header_len]))
    except ValueError as e:
        raise ProtocolError(f"bad header JSON: {e}") from None
    specs = header.pop("arrays", []) if isinstance(header, dict) else None
    if not isinstance(specs, list):
        raise ProtocolError("header is not an object with an array list")
    arrays: List[np.ndarray] = []
    off = _LEN.size + header_len
    for spec in specs:
        dtype, shape, count = _parse_spec(spec)
        n = _blob_nbytes(dtype, count)
        if off + n > payload_len:
            raise ProtocolError("array blobs exceed payload")
        arrays.append(_unpack_array(dtype, shape, count, payload[off:off + n]))
        off += n
    return header, arrays


# ------------------------------------------------------------ MatchResult
@dataclasses.dataclass(frozen=True)
class WireMatchResult:
    """Client-side view of a ``query.executor.MatchResult``.

    Carries the participation masks and name-keyed bindings (computed
    server-side — the ``Plan`` object itself never crosses the wire); the
    mask payloads are bitwise-identical to the in-process result's.
    """

    vertex_mask: np.ndarray  # (n,) bool
    edge_mask: np.ndarray  # (m,) bool
    _bindings: Dict[str, np.ndarray]

    def bindings(self) -> Dict[str, np.ndarray]:
        return dict(self._bindings)

    def n_vertices(self) -> int:
        return int(self.vertex_mask.sum())

    def n_edges(self) -> int:
        return int(self.edge_mask.sum())


def _mask_payload(mask):
    """Bool device masks pack ON DEVICE into bitplane words and ship as
    :class:`PackedMask` — the codec's wire bytes without ever
    materializing the byte-per-entity host copy.  Anything else (host
    arrays, non-bool) goes through the generic path."""
    try:
        import jax

        from repro.core import bitplane
    except ImportError:  # jax-free client process
        return np.asarray(mask)
    if isinstance(mask, jax.Array) and mask.dtype == bool and mask.ndim == 1:
        n = int(mask.shape[0])
        return PackedMask(words=np.asarray(bitplane.pack_mask(mask)), n=n)
    return np.asarray(mask)


def result_to_wire(res) -> Tuple[Dict, List[np.ndarray]]:
    """``MatchResult`` → (meta, arrays): masks first, bindings after in
    ``meta["vars"]`` order.  Masks travel bit-packed end to end."""
    bindings = res.bindings()
    names = sorted(bindings)
    arrays = [_mask_payload(res.vertex_mask), _mask_payload(res.edge_mask)]
    arrays.extend(_mask_payload(bindings[k]) for k in names)
    return {"vars": names}, arrays


def _as_bool_mask(a) -> np.ndarray:
    """Normalize a result payload to a (n,) bool array.  The codec already
    delivers bool (``_unpack_array``); in-process callers that short-circuit
    the transport may hand back the ``PackedMask`` from ``result_to_wire``
    (possibly wrapped in a 0-d object array by ``np.asarray``) — unpack it
    host-side (numpy only, so jax-free clients stay jax-free)."""
    if isinstance(a, np.ndarray) and a.dtype == object and a.ndim == 0:
        a = a.item()
    if isinstance(a, PackedMask):
        words = np.ascontiguousarray(np.asarray(a.words, dtype="<u4"))
        bits = np.unpackbits(words.view(np.uint8), bitorder="little")
        return bits[:a.n].astype(bool)
    return np.asarray(a)


def wire_to_result(meta: Dict, arrays: Sequence[np.ndarray]) -> WireMatchResult:
    names = meta["vars"]
    if len(arrays) != 2 + len(names):
        raise ProtocolError(
            f"result carries {len(arrays)} arrays for {len(names)} vars")
    arrays = [_as_bool_mask(a) for a in arrays]
    return WireMatchResult(
        vertex_mask=arrays[0], edge_mask=arrays[1],
        _bindings=dict(zip(names, arrays[2:])),
    )


# ------------------------------------------------------------ SampledBlock
@dataclasses.dataclass(frozen=True)
class WireSampledBlock:
    """Client-side view of one ``graph.sampler.SampledBlock`` layer.

    Same field contract (ids are the server graph's INTERNAL ids, edge_*
    are local indices into src_nodes/dst_nodes, edge_mask False = padded
    slot) but plain numpy — the client stays jax-free.  Payloads are
    bitwise the in-process blocks': the deterministic-mode wire-parity
    gate in ``pgserve --net --smoke`` depends on that.
    """

    src_nodes: np.ndarray  # (n_src,) int32
    dst_nodes: np.ndarray  # (n_dst,) int32
    edge_src: np.ndarray  # (n_edges,) int32 local
    edge_dst: np.ndarray  # (n_edges,) int32 local
    edge_mask: np.ndarray  # (n_edges,) bool

    @property
    def n_src(self) -> int:
        return int(self.src_nodes.shape[0])

    @property
    def n_dst(self) -> int:
        return int(self.dst_nodes.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.edge_src.shape[0])


def blocks_to_wire(blocks) -> Tuple[Dict, List[np.ndarray]]:
    """SampledBlock list → (meta, arrays): five arrays per layer in block
    order (src_nodes, dst_nodes, edge_src, edge_dst, edge_mask) — the id/
    index arrays as int32 blobs, the mask bit-packed by the codec (device
    masks pack on device, §15's "blocks ship as packed masks + index
    arrays")."""
    arrays: List[np.ndarray] = []
    for b in blocks:
        arrays.append(np.asarray(b.src_nodes, np.int32))
        arrays.append(np.asarray(b.dst_nodes, np.int32))
        arrays.append(np.asarray(b.edge_src, np.int32))
        arrays.append(np.asarray(b.edge_dst, np.int32))
        arrays.append(_mask_payload(b.edge_mask))
    return {"layers": len(blocks)}, arrays


def wire_to_blocks(meta: Dict, arrays: Sequence[np.ndarray]
                   ) -> List[WireSampledBlock]:
    layers = int(meta["layers"])
    if len(arrays) != 5 * layers:
        raise ProtocolError(
            f"sample result carries {len(arrays)} arrays for {layers} layers")
    blocks = []
    for li in range(layers):
        s, d, es, ed, em = arrays[5 * li:5 * li + 5]
        blocks.append(WireSampledBlock(
            src_nodes=np.asarray(s, np.int32),
            dst_nodes=np.asarray(d, np.int32),
            edge_src=np.asarray(es, np.int32),
            edge_dst=np.asarray(ed, np.int32),
            edge_mask=_as_bool_mask(em),
        ))
    return blocks


# -------------------------------------------------------------- exceptions
def exc_to_wire(e: BaseException) -> Dict[str, str]:
    return {"type": type(e).__name__, "message": str(e)}


def wire_to_exc(d: Dict[str, str]) -> BaseException:
    """Rebuild a builtin exception when possible (so ``pytest.raises
    (KeyError)`` works across the wire), ``RemoteError`` otherwise."""
    cls = getattr(__builtins__, d["type"], None) if not isinstance(
        __builtins__, dict) else __builtins__.get(d["type"])
    if isinstance(cls, type) and issubclass(cls, Exception):
        try:
            return cls(d["message"])
        except Exception:  # noqa: BLE001 — odd constructor signature
            pass
    return RemoteError(d["type"], d["message"])
