"""pgd server — the network front-end over ``Service`` (ARCHITECTURE §9).

The paper's deployment model (§III) is Arkouda's: one persistent parallel
server owns the graphs and the device mesh; many lightweight Python
clients drive it with small framed messages.  ``PGServer`` is that loop
for the analytics service: a listener thread accepts connections, each
connection gets a session thread that decodes ``wire`` frames and maps
them onto the in-process ``Service`` — so every client process shares ONE
registry, ONE scheduler (whose micro-batching now coalesces across
processes, not just threads) and ONE pair of caches.

Request ops (header ``{"op": ..., "id": ...}`` + optional array blobs):

    ping / graphs / stats            server + service introspection
    metrics                          Prometheus text exposition of the
                                       service + process registries (§13)
    traces                           recent trace trees + slow-query log
    load_graph {name, path, backend, mesh}   registry.load from disk
    query {graph, pattern, impl}     → Service.submit(); the response is
                                       written when the FUTURE resolves,
                                       so a pipelining client overlaps
                                       requests and the scheduler batches
                                       them into coalesced launches
    explain {graph, pattern, impl}   planner report (text)
    mutate {graph, action, ...}      add_edges_from / add_node_labels /
                                       add_edge_relationships /
                                       add_{node,edge}_properties /
                                       insert_edges / delete_vertices /
                                       delete_edges /
                                       update_{node,edge}_properties
    analytics {graph, analytic, ..}  shortest_paths / pagerank /
                                       communities through the semiring
                                       frontier engine (§12); the (n,)
                                       result vector rides back as an
                                       array blob
    sample {graph, fanouts, ...}     fused neighborhood sampling (§15):
                                       seeds as an id array or a
                                       ``seed_pattern``; async like query
                                       so the scheduler coalesces sample
                                       requests across sessions into one
                                       batched launch; blocks return as
                                       packed masks + index arrays
    snapshot {graph, name?}          pin a frozen snapshot, register it
    fork_view {graph, name?}         writable copy-on-write view
    drop_view {name}                 unregister a snapshot/fork
    compact {graph}                  merge the overlay into base stores
    drain                            stop accepting connections, wait for
                                       every in-flight request
    shutdown                         drain + release the server

Responses echo the request ``id`` (queries resolve out of order —
result-cache fastpath hits overtake executing batches); errors travel as
``{"ok": false, "error": {type, message}}`` and fail only their own
request.  A malformed frame kills just that session.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from typing import Dict, Optional

from repro.obs import metrics as obs_metrics
from repro.obs.trace import Trace
from repro.service import wire
from repro.service.service import Service

__all__ = ["PGServer"]

_MUTATORS = (
    "add_edges_from",
    "add_node_labels",
    "add_edge_relationships",
    "add_node_properties",
    "add_edge_properties",
    "insert_edges",
    "delete_vertices",
    "delete_edges",
    "update_node_properties",
    "update_edge_properties",
)


class _Session:
    """One client connection: socket, a writer thread, in-flight futures.

    All responses go through the writer thread's queue.  Query responses
    are produced by the scheduler's ONE worker thread (future callbacks);
    if it wrote to sockets directly, a client that stops reading would
    block ``sendall`` once the TCP buffer fills and stall query execution
    for every session.  The queue decouples them: a slow consumer stalls
    only its own writer, and an overflowing queue (``maxsize``) marks the
    session dead instead of growing without bound."""

    _SENTINEL = object()

    def __init__(self, sock: socket.socket, peer):
        self.sock = sock
        self.peer = peer
        self.pending: Dict[int, object] = {}  # request id → Future
        self.dispatching = 0  # frames received but not yet registered in
        # pending — drain must count them as in-flight or a query caught
        # mid-Service.submit() would be dropped at close
        self.plock = threading.Lock()
        self.closed = False
        self._outq: "queue.Queue" = queue.Queue(maxsize=1024)
        self._writer = threading.Thread(
            target=self._write_loop, name=f"pgd-writer-{peer[1]}", daemon=True)
        self._writer.start()

    def send(self, header, arrays=()) -> None:
        if self.closed:
            return
        try:
            self._outq.put_nowait((header, arrays))
        except queue.Full:
            # consumer stopped reading long ago; kill the socket too so the
            # peer sees EOF instead of hanging on responses that were
            # silently dropped (and so our reader thread unblocks and
            # cleans the session up)
            self.closed = True
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass

    def _write_loop(self) -> None:
        while not self.closed:
            try:
                item = self._outq.get(timeout=1.0)
            except queue.Empty:
                continue
            try:
                if item is self._SENTINEL:
                    return
                try:
                    wire.send_msg(self.sock, *item)
                except OSError:
                    self.closed = True  # peer went away mid-response
            finally:
                self._outq.task_done()

    def flush(self, timeout: float) -> None:
        """Best-effort wait for queued responses to reach the socket.
        Watches ``unfinished_tasks`` (not ``empty()``) so a frame the
        writer has dequeued but is still sending counts as in flight —
        closing the socket mid-``sendall`` would truncate it."""
        deadline = time.monotonic() + timeout
        while self._outq.unfinished_tasks and not self.closed:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)

    def stop_writer(self) -> None:
        self.closed = True
        try:
            self._outq.put_nowait(self._SENTINEL)
        except queue.Full:
            pass  # writer exits via the closed flag within its poll tick


class PGServer:
    """Threaded socket front-end for a ``Service``.

    ``start()`` binds and returns immediately (``.port`` is then real —
    bind with ``port=0`` for an OS-assigned one).  ``close(drain=True)``
    is graceful: no new connections, in-flight queries finish, sessions
    close.  The server owns neither the service nor its graphs — callers
    compose (and may keep using the service in-process alongside).
    """

    def __init__(self, service: Service, *, host: str = "127.0.0.1",
                 port: int = 0, backlog: int = 64):
        self.service = service
        self.host = host
        self._port = port
        self.backlog = backlog
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._sessions: set = set()
        self._slock = threading.Lock()
        self._closing = threading.Event()
        self._shutdown_requested = threading.Event()

    # ------------------------------------------------------------ lifecycle
    @property
    def port(self) -> int:
        return self._port

    def start(self) -> "PGServer":
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self.host, self._port))
        ls.listen(self.backlog)
        self._port = ls.getsockname()[1]
        self._listener = ls
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="pgd-accept", daemon=True)
        self._accept_thread.start()
        return self

    def wait_shutdown(self, timeout: Optional[float] = None) -> bool:
        """Block until a client sends ``shutdown`` (the serve-mode CLI's
        foreground wait); returns False on timeout."""
        return self._shutdown_requested.wait(timeout)

    def drain(self, timeout: float = 30.0) -> None:
        """Stop accepting and wait until no session has in-flight futures.

        Re-samples until quiescent (bounded by ``timeout``): connected
        sessions keep dispatching while draining, so a one-shot snapshot
        would miss a query that arrived just after it — and its accepted
        request would be dropped at close."""
        self._stop_listening()
        deadline = time.monotonic() + timeout
        while True:
            with self._slock:
                sessions = list(self._sessions)
            futs, mid_dispatch = [], False
            for sess in sessions:
                with sess.plock:
                    futs.extend(sess.pending.values())
                    mid_dispatch |= sess.dispatching > 0
            if (not futs and not mid_dispatch) or time.monotonic() >= deadline:
                return
            for f in futs:
                try:
                    f.result(timeout=max(0.0, deadline - time.monotonic()))
                except Exception:  # noqa: BLE001 — failures already routed
                    pass  # to their own responses; drain only waits
            if mid_dispatch:
                time.sleep(0.005)  # let the dispatch register its future

    def close(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        if drain:
            self.drain(timeout=timeout)
        self._closing.set()
        self._stop_listening()
        with self._slock:
            sessions = list(self._sessions)
        for sess in sessions:
            if drain:
                sess.flush(timeout=5.0)  # let queued responses leave first
            sess.stop_writer()
            try:
                sess.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sess.sock.close()
            except OSError:
                pass

    def _stop_listening(self) -> None:
        ls, self._listener = self._listener, None
        if ls is not None:
            # shutdown BEFORE close: the accept thread blocked in accept()
            # holds a kernel reference to the listening socket, so a bare
            # close() would leave it accepting; shutdown wakes it with an
            # error and the port actually stops listening
            try:
                ls.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                ls.close()
            except OSError:
                pass

    def __enter__(self) -> "PGServer":
        return self.start() if self._listener is None else self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- accepting
    def _accept_loop(self) -> None:
        ls = self._listener
        while ls is not None and not self._closing.is_set():
            try:
                sock, peer = ls.accept()
            except OSError:
                return  # listener closed (drain/shutdown)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sess = _Session(sock, peer)
            with self._slock:
                self._sessions.add(sess)
            threading.Thread(target=self._serve_session, args=(sess,),
                             name=f"pgd-session-{peer[1]}", daemon=True).start()
            ls = self._listener

    def _serve_session(self, sess: _Session) -> None:
        try:
            while not sess.closed:
                try:
                    header, arrays = wire.recv_msg(sess.sock)
                except (ConnectionError, OSError):
                    return  # client hung up
                except wire.ProtocolError:
                    return  # garbage on the socket: drop the session
                with sess.plock:
                    sess.dispatching += 1
                try:
                    self._dispatch(sess, header, arrays)
                finally:
                    with sess.plock:
                        sess.dispatching -= 1
        finally:
            sess.flush(timeout=5.0)  # in-flight responses drain before close
            sess.stop_writer()
            try:
                sess.sock.close()
            except OSError:
                pass
            with self._slock:
                self._sessions.discard(sess)

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, sess: _Session, header: Dict, arrays) -> None:
        op = header.get("op")
        rid = header.get("id")
        t0 = time.perf_counter()
        try:
            if op == "query":
                self._op_query(sess, rid, header)
                return  # response rides the future callback
            if op == "sample":
                self._op_sample(sess, rid, header, arrays)
                return  # response rides the future callback
            handler = getattr(self, f"_op_{op}", None)
            if handler is None:
                raise ValueError(f"unknown op {op!r}")
            out_header, out_arrays = handler(header, arrays)
        except Exception as e:  # noqa: BLE001 — per-request isolation
            sess.send({"id": rid, "ok": False, "error": wire.exc_to_wire(e)})
            return
        finally:
            if obs_metrics.enabled():
                # per-op server latency; for "query" this covers submit +
                # fastpath only — device time lands on the trace instead
                obs_metrics.GLOBAL.histogram(
                    "pg_wire_op_ms", "server-side op handling latency",
                    op=str(op)).observe((time.perf_counter() - t0) * 1e3)
        out_header.update({"id": rid, "ok": True})
        sess.send(out_header, out_arrays)
        if op == "shutdown":
            self._shutdown_requested.set()

    def _op_query(self, sess: _Session, rid, header: Dict) -> None:
        # a client-minted trace id roots the server-side span tree; the
        # finished tree rides back in the response header so the client
        # can see where ITS query's time went (docs/ARCHITECTURE.md §13)
        tr = None
        tid = header.get("trace")
        if tid is not None and self.service.config.trace_buffer > 0:
            tr = Trace("query", trace_id=str(tid))
        fut = self.service.submit(header["graph"], header["pattern"],
                                  impl=header.get("impl"), trace=tr)
        with sess.plock:
            sess.pending[rid] = fut

        def _respond(f) -> None:
            with sess.plock:
                sess.pending.pop(rid, None)
            err = f.exception()
            if err is not None:
                hdr = {"id": rid, "ok": False, "error": wire.exc_to_wire(err)}
                if tr is not None:
                    hdr["trace"] = tr.finish().to_dict()
                sess.send(hdr)
                return
            t0 = time.perf_counter()
            meta, out = wire.result_to_wire(f.result())
            t1 = time.perf_counter()
            hdr = {"id": rid, "ok": True, "result": meta}
            if tr is not None:
                tr.add_span("serialize", t0, t1)
                tr.root.t1 = t1  # extend the root over serialization; the
                # service pushed this trace into its ring at resolve time,
                # and rings hold live objects, so the span is visible there
                hdr["trace"] = tr.to_dict()
            sess.send(hdr, out)

        fut.add_done_callback(_respond)

    def _op_sample(self, sess: _Session, rid, header: Dict, arrays) -> None:
        """Fused neighborhood sampling over the wire (§15).  Seeds arrive
        either as ``header["seed_pattern"]`` (Cypher-lite, matched
        server-side and fed to the sampler as a packed bitmap) or as the
        one request array of explicit vertex ids.  Async like ``query``:
        the future resolves when the scheduler's coalesced launch lands,
        so pipelined sample requests across sessions share ONE kernel
        launch per (graph, fanouts, bucket) group."""
        tr = None
        tid = header.get("trace")
        if tid is not None and self.service.config.trace_buffer > 0:
            tr = Trace("sample", trace_id=str(tid))
        seeds = header.get("seed_pattern")
        if seeds is None:
            if not arrays:
                raise ValueError("sample needs seed ids or a seed_pattern")
            seeds = arrays[0]
        fut = self.service.submit_sample(
            header["graph"], seeds, tuple(header["fanouts"]),
            pattern=header.get("pattern"), seed=int(header.get("seed", 0)),
            deterministic=bool(header.get("deterministic", True)), trace=tr)
        with sess.plock:
            sess.pending[rid] = fut

        def _respond(f) -> None:
            with sess.plock:
                sess.pending.pop(rid, None)
            err = f.exception()
            if err is not None:
                hdr = {"id": rid, "ok": False, "error": wire.exc_to_wire(err)}
                if tr is not None:
                    hdr["trace"] = tr.finish().to_dict()
                sess.send(hdr)
                return
            t0 = time.perf_counter()
            meta, out = wire.blocks_to_wire(f.result())
            t1 = time.perf_counter()
            hdr = {"id": rid, "ok": True, "sample": meta}
            if tr is not None:
                tr.add_span("serialize", t0, t1)
                tr.root.t1 = t1
                hdr["trace"] = tr.to_dict()
            sess.send(hdr, out)

        fut.add_done_callback(_respond)

    # sync ops: return (header fields, arrays) --------------------------------
    def _op_ping(self, header, arrays):
        import jax

        return {"pong": True, "devices": len(jax.devices())}, ()

    def _op_graphs(self, header, arrays):
        reg = self.service.registry
        return {"graphs": {n: reg.version(n) for n in reg.names()}}, ()

    def _op_stats(self, header, arrays):
        return {"stats": self.service.stats()}, ()

    def _op_metrics(self, header, arrays):
        return {"metrics": self.service.metrics_text()}, ()

    def _op_traces(self, header, arrays):
        return {"traces": self.service.trace_log(),
                "slow": self.service.slow_queries()}, ()

    def _op_load_graph(self, header, arrays):
        mesh = None
        if header.get("mesh"):
            from repro.launch.mesh import make_entity_mesh

            mesh = make_entity_mesh()
        self.service.load_graph(header["name"], header["path"],
                                backend=header.get("backend"), mesh=mesh)
        pg = self.service.registry.get(header["name"])
        return {"name": header["name"], "n": pg.n_vertices,
                "m": pg.n_edges, "backend": pg.backend}, ()

    def _op_explain(self, header, arrays):
        pg = self.service.registry.get(header["graph"])
        return {"explain": pg.explain(header["pattern"],
                                      impl=header.get("impl"))}, ()

    def _op_mutate(self, header, arrays):
        action = header["action"]
        if action not in _MUTATORS:
            raise ValueError(f"unknown mutate action {action!r}")
        pg = self.service.registry.get(header["graph"])
        if action == "add_edges_from":
            src, dst = arrays
            pg.add_edges_from(src, dst)
        elif action == "add_node_labels":
            pg.add_node_labels(arrays[0], header["strings"])
        elif action == "add_edge_relationships":
            src, dst = arrays
            pg.add_edge_relationships(src, dst, header["strings"])
        elif action == "add_node_properties":
            nodes, values = arrays
            pg.add_node_properties(header["name"], nodes, values,
                                   fill=header.get("fill", 0))
        elif action == "add_edge_properties":
            src, dst, values = arrays
            pg.add_edge_properties(header["name"], src, dst, values,
                                   fill=header.get("fill", 0))
        elif action == "insert_edges":
            src, dst = arrays
            pg.insert_edges(src, dst)
        elif action == "delete_vertices":
            pg.delete_vertices(arrays[0])
        elif action == "delete_edges":
            src, dst = arrays
            pg.delete_edges(src, dst)
        elif action == "update_node_properties":
            nodes, values = arrays
            pg.update_node_properties(header["name"], nodes, values)
        else:  # update_edge_properties
            src, dst, values = arrays
            pg.update_edge_properties(header["name"], src, dst, values)
        return {"version": pg.version}, ()

    def _op_analytics(self, header, arrays):
        """Semiring analytics over the wire: ``{"analytic": shortest_paths
        | pagerank | communities, "graph": ..., ...}``; seeds for
        shortest_paths ride as the one request array.  The (n,) result
        vector returns as a response array blob (f32 distances/ranks or
        i32 labels) — dense numeric payloads never go through the header."""
        analytic = header["analytic"]
        graph = header["graph"]
        if analytic == "shortest_paths":
            out = self.service.shortest_paths(
                graph, arrays[0], weight=header.get("weight"),
                pattern=header.get("pattern"),
                undirected=bool(header.get("undirected", False)),
                max_iters=header.get("max_iters"))
        elif analytic == "pagerank":
            out = self.service.pagerank(
                graph, weight=header.get("weight"),
                pattern=header.get("pattern"),
                damping=header.get("damping", 0.85),
                iters=header.get("iters", 20))
        elif analytic == "communities":
            out = self.service.communities(
                graph, pattern=header.get("pattern"),
                max_iters=header.get("max_iters", 64))
        else:
            raise ValueError(f"unknown analytic {analytic!r}")
        return {"analytic": analytic, "dtype": str(out.dtype)}, (out,)

    # overlay verbs: snapshot isolation over the wire --------------------------
    def _op_snapshot(self, header, arrays):
        name = self.service.snapshot_graph(header["graph"],
                                           name=header.get("name"))
        pg = self.service.registry.get(name)
        return {"name": name, "version": pg.version}, ()

    def _op_fork_view(self, header, arrays):
        name = self.service.fork_graph(header["graph"],
                                       name=header.get("name"))
        pg = self.service.registry.get(name)
        return {"name": name, "version": pg.version}, ()

    def _op_drop_view(self, header, arrays):
        self.service.drop_graph(header["name"])
        return {"dropped": header["name"]}, ()

    def _op_compact(self, header, arrays):
        stats = self.service.compact_graph(header["graph"])
        return {"compacted": header["graph"], "overlay": stats}, ()

    def _op_drain(self, header, arrays):
        self.drain()
        return {"drained": True}, ()

    def _op_shutdown(self, header, arrays):
        self.drain()
        return {"drained": True}, ()
