"""pgd client — the lightweight Python side of the wire (ARCHITECTURE §9).

The paper's interactivity story (§III, §VI) depends on the client staying
thin: it holds no graph data, just names — every byte of real work happens
where the graphs and devices live.  ``PGClient`` speaks the ``wire`` frame
format over one TCP connection and exposes the same verbs as the
in-process ``Service`` plus the registry's mutators:

    with PGClient(port=p) as c:
        c.load_graph("social", "/data/social.pg")
        res = c.query("social", "(a:person)-[:follows]->(b:person)")
        res.vertex_mask, res.bindings()          # numpy, bitwise == match()

    # pipelined: all requests go out before any response is read, so the
    # server's micro-batcher sees them as ONE pressure wave and coalesces
    handles = [c.submit("social", p) for p in patterns]
    results = [h.result() for h in handles]      # same as query_batch(...)

A ``PGClient`` is one session: requests carry monotone ids, responses may
arrive out of order (cache fastpath hits overtake executing batches) and
are matched back by id.  One OS thread per client — instances are NOT
thread-safe; concurrent client threads each open their own connection
(that is the multi-process tenancy model, and what ``bench_serve``'s net
sweep measures).
"""
from __future__ import annotations

import socket
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import new_trace_id
from repro.service import wire
from repro.service.wire import WireMatchResult

__all__ = ["PGClient", "PGFuture", "PGSampleFuture"]


class PGFuture:
    """Handle for one pipelined request; ``result()`` blocks on its id.

    After ``result()`` returns, ``trace`` holds the server's span tree for
    this query (dict, rooted at the trace id this client minted) when the
    server has tracing enabled — ``None`` before resolution or when the
    server traced nothing."""

    def __init__(self, client: "PGClient", rid: int,
                 trace_id: Optional[str] = None):
        self._client = client
        self._rid = rid
        self.trace_id = trace_id
        self.trace: Optional[Dict] = None

    def result(self, timeout: Optional[float] = None) -> WireMatchResult:
        header, arrays = self._client._wait_frame(self._rid, timeout=timeout)
        self.trace = header.get("trace")
        if self.trace is not None:
            self._client.last_trace = self.trace
        if "result" in header:
            return wire.wire_to_result(header["result"], arrays)
        return header


class PGSampleFuture:
    """Handle for one pipelined ``sample`` request; ``result()`` → block
    list.  ``trace`` fills in after resolution like :class:`PGFuture`."""

    def __init__(self, client: "PGClient", rid: int,
                 trace_id: Optional[str] = None):
        self._client = client
        self._rid = rid
        self.trace_id = trace_id
        self.trace: Optional[Dict] = None

    def result(self, timeout: Optional[float] = None
               ) -> List[wire.WireSampledBlock]:
        header, arrays = self._client._wait_frame(self._rid, timeout=timeout)
        self.trace = header.get("trace")
        if self.trace is not None:
            self._client.last_trace = self.trace
        return wire.wire_to_blocks(header["sample"], arrays)


class PGClient:
    """Blocking + pipelined client for ``PGServer`` (module docstring)."""

    def __init__(self, host: str = "127.0.0.1", *, port: int,
                 connect_timeout: float = 30.0,
                 timeout: Optional[float] = 120.0):
        self._sock = socket.create_connection((host, port),
                                              timeout=connect_timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._timeout = timeout
        self._sock.settimeout(timeout)
        self._next_id = 0
        self._broken: Optional[str] = None  # why the stream is unusable
        self._stash: Dict[int, tuple] = {}  # id → (header, arrays) arrived
        # while we were waiting for a different id (out-of-order responses)
        self.trace = True  # mint a trace id per query; the server's span
        # tree comes back on the handle (PGFuture.trace / last_trace)
        self.last_trace: Optional[Dict] = None  # most recent query's tree

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "PGClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- plumbing
    def _send(self, op: str, arrays: Sequence[np.ndarray] = (),
              **fields) -> int:
        if self._broken is not None:
            raise ConnectionError(f"client is unusable: {self._broken}")
        self._next_id += 1
        rid = self._next_id
        header = {"op": op, "id": rid, **fields}
        try:
            wire.send_msg(self._sock, header, arrays)
        except OSError as e:
            # a partial frame may be on the wire — the stream is desynced,
            # same fail-fast treatment as the read path
            self._broken = f"{type(e).__name__}: {e}"
            raise
        return rid

    def _wait_frame(self, rid: int, timeout: Optional[float] = None):
        """Read frames until ``rid``'s response arrives; other ids are
        stashed for their own waiters (pipelining).  Returns the raw
        ``(header, arrays)`` frame after the ok-check — the analytics
        verbs consume the array blobs directly.

        ``timeout`` overrides the connection default for THIS wait only
        (``None`` keeps the default).  A timeout mid-frame leaves the
        stream positioned mid-message, so the client is marked broken —
        every later call fails fast instead of misparsing bytes."""
        if self._broken is not None:
            raise ConnectionError(f"client is unusable: {self._broken}")
        if timeout is not None:
            self._sock.settimeout(timeout)
        try:
            while rid not in self._stash:
                try:
                    header, arrays = wire.recv_msg(self._sock)
                except (socket.timeout, wire.ProtocolError) as e:
                    self._broken = f"{type(e).__name__}: {e}"
                    raise
                self._stash[header["id"]] = (header, arrays)
        finally:
            if timeout is not None:
                self._sock.settimeout(self._timeout)
        header, arrays = self._stash.pop(rid)
        if not header.get("ok"):
            raise wire.wire_to_exc(header["error"])
        return header, arrays

    def _wait(self, rid: int, timeout: Optional[float] = None):
        header, arrays = self._wait_frame(rid, timeout=timeout)
        if "result" in header:
            return wire.wire_to_result(header["result"], arrays)
        return header

    def _call(self, op: str, arrays: Sequence[np.ndarray] = (), **fields):
        return self._wait(self._send(op, arrays, **fields))

    # -------------------------------------------------------------- queries
    def submit(self, graph: str, pattern: str, *,
               impl: Optional[str] = None) -> PGFuture:
        """Pipelined query: sends the request, returns without reading.

        Every handle should eventually be ``result()``-ed: a response whose
        handle is abandoned stays stashed on the client for the life of
        the connection (the stream has no way to un-receive it)."""
        tid = new_trace_id() if self.trace else None
        return PGFuture(self, self._send("query", graph=graph,
                                         pattern=pattern, impl=impl,
                                         trace=tid),
                        trace_id=tid)

    def query(self, graph: str, pattern: str, *,
              impl: Optional[str] = None) -> WireMatchResult:
        return self.submit(graph, pattern, impl=impl).result()

    def query_batch(self, graph: str, patterns: Sequence[str], *,
                    impl: Optional[str] = None) -> List[WireMatchResult]:
        """All requests on the wire before any response is read — the
        server's batching window sees the whole group.  Every handle is
        awaited even when one fails (their responses would otherwise pile
        up in the stash for the life of the connection); the first failure
        then raises, matching ``Service.query_batch``."""
        handles = [self.submit(graph, p, impl=impl) for p in patterns]
        results: List[WireMatchResult] = []
        first_err: Optional[BaseException] = None
        for h in handles:
            try:
                results.append(h.result())
            except ConnectionError:
                raise  # stream is dead/desynced: nothing more will arrive
            except BaseException as e:  # noqa: BLE001
                if first_err is None:
                    first_err = e
                results.append(None)
        if first_err is not None:
            raise first_err
        return results

    def explain(self, graph: str, pattern: str, *,
                impl: Optional[str] = None) -> str:
        return self._call("explain", graph=graph, pattern=pattern,
                          impl=impl)["explain"]

    # ------------------------------------------------------------- sampling
    def submit_sample(self, graph: str, seeds_or_pattern, fanouts, *,
                      pattern: Optional[str] = None, seed: int = 0,
                      deterministic: bool = True) -> "PGSampleFuture":
        """Pipelined fused neighborhood sample (ARCHITECTURE §15).

        ``seeds_or_pattern`` is either a Cypher-lite pattern string (seeds
        = its matched anchor vertices, selected server-side without the
        mask ever visiting this client) or an array of external vertex
        ids.  ``pattern`` filters which EDGES may be sampled; ``seed``
        keys the PRNG — with ``deterministic=True`` the result is bitwise
        reproducible (and server-cacheable), with ``deterministic=False``
        the server mixes in fresh entropy per request.  Handles returned
        before any ``result()`` call land in the server's batching window
        together and coalesce into one launch per (graph, fanouts,
        bucket) group."""
        fanouts = [int(f) for f in fanouts]
        tid = new_trace_id() if self.trace else None
        fields = dict(graph=graph, fanouts=fanouts, pattern=pattern,
                      seed=int(seed), deterministic=bool(deterministic),
                      trace=tid)
        if isinstance(seeds_or_pattern, str):
            rid = self._send("sample", seed_pattern=seeds_or_pattern,
                             **fields)
        else:
            rid = self._send(
                "sample", [np.asarray(seeds_or_pattern, np.int64)], **fields)
        return PGSampleFuture(self, rid, trace_id=tid)

    def sample(self, graph: str, seeds_or_pattern, fanouts, *,
               pattern: Optional[str] = None, seed: int = 0,
               deterministic: bool = True) -> List[wire.WireSampledBlock]:
        """Blocking fused sample → ``WireSampledBlock`` list (innermost
        layer first, ids in the server graph's internal space — bitwise
        the in-process ``PropGraph.sample`` blocks for the same key)."""
        return self.submit_sample(
            graph, seeds_or_pattern, fanouts, pattern=pattern, seed=seed,
            deterministic=deterministic).result()

    # ------------------------------------------------------------ analytics
    def shortest_paths(self, graph: str, seeds, *,
                       weight: Optional[str] = None,
                       pattern: Optional[str] = None,
                       undirected: bool = False,
                       max_iters: Optional[int] = None) -> np.ndarray:
        """Weighted multi-source shortest paths server-side: (n,) f32
        distances (+inf = unreachable), result-cached on the server under
        the pattern's refs plus the ``weight`` property."""
        _, arrays = self._wait_frame(self._send(
            "analytics", [np.asarray(seeds, np.int64)], analytic="shortest_paths",
            graph=graph, weight=weight, pattern=pattern,
            undirected=undirected, max_iters=max_iters))
        return arrays[0]

    def pagerank(self, graph: str, *, weight: Optional[str] = None,
                 pattern: Optional[str] = None, damping: float = 0.85,
                 iters: int = 20) -> np.ndarray:
        """PageRank over the server's (optionally pattern-filtered,
        optionally weighted) graph: (n,) f32 ranks."""
        _, arrays = self._wait_frame(self._send(
            "analytics", (), analytic="pagerank", graph=graph, weight=weight,
            pattern=pattern, damping=damping, iters=iters))
        return arrays[0]

    def communities(self, graph: str, *, pattern: Optional[str] = None,
                    max_iters: int = 64) -> np.ndarray:
        """Label-propagation communities server-side: (n,) i32 labels
        (-1 = outside the filter)."""
        _, arrays = self._wait_frame(self._send(
            "analytics", (), analytic="communities", graph=graph,
            pattern=pattern, max_iters=max_iters))
        return arrays[0]

    # ------------------------------------------------------------- registry
    def load_graph(self, name: str, path: str, *,
                   backend: Optional[str] = None, mesh: bool = False) -> Dict:
        """Server-side ``load_propgraph`` + register; returns {n, m, backend}."""
        return self._call("load_graph", name=name, path=path,
                          backend=backend, mesh=mesh)

    def graphs(self) -> Dict[str, int]:
        """Registered graph names → current versions."""
        return self._call("graphs")["graphs"]

    # ------------------------------------------------------------ mutations
    def add_edges_from(self, graph: str, src, dst) -> int:
        return self._call("mutate", [np.asarray(src), np.asarray(dst)],
                          graph=graph, action="add_edges_from")["version"]

    def add_node_labels(self, graph: str, nodes, labels) -> int:
        return self._call("mutate", [np.asarray(nodes)], graph=graph,
                          action="add_node_labels",
                          strings=list(map(str, labels)))["version"]

    def add_edge_relationships(self, graph: str, src, dst,
                               relationships) -> int:
        return self._call("mutate", [np.asarray(src), np.asarray(dst)],
                          graph=graph, action="add_edge_relationships",
                          strings=list(map(str, relationships)))["version"]

    def add_node_properties(self, graph: str, name: str, nodes, values,
                            fill=0) -> int:
        return self._call("mutate", [np.asarray(nodes), np.asarray(values)],
                          graph=graph, action="add_node_properties",
                          name=name, fill=fill)["version"]

    def add_edge_properties(self, graph: str, name: str, src, dst, values,
                            fill=0) -> int:
        return self._call(
            "mutate", [np.asarray(src), np.asarray(dst), np.asarray(values)],
            graph=graph, action="add_edge_properties", name=name, fill=fill,
        )["version"]

    def insert_edges(self, graph: str, src, dst) -> int:
        """Delta-path edge append (known endpoints, no rebuild)."""
        return self._call("mutate", [np.asarray(src), np.asarray(dst)],
                          graph=graph, action="insert_edges")["version"]

    def delete_vertices(self, graph: str, nodes) -> int:
        return self._call("mutate", [np.asarray(nodes)], graph=graph,
                          action="delete_vertices")["version"]

    def delete_edges(self, graph: str, src, dst) -> int:
        return self._call("mutate", [np.asarray(src), np.asarray(dst)],
                          graph=graph, action="delete_edges")["version"]

    def update_node_properties(self, graph: str, name: str, nodes,
                               values) -> int:
        return self._call("mutate", [np.asarray(nodes), np.asarray(values)],
                          graph=graph, action="update_node_properties",
                          name=name)["version"]

    def update_edge_properties(self, graph: str, name: str, src, dst,
                               values) -> int:
        return self._call(
            "mutate", [np.asarray(src), np.asarray(dst), np.asarray(values)],
            graph=graph, action="update_edge_properties", name=name,
        )["version"]

    # ------------------------------------------------------ snapshots / views
    def snapshot(self, graph: str, name: Optional[str] = None) -> str:
        """Pin a frozen snapshot of ``graph`` server-side; queries against
        the returned name are isolated from later writes to ``graph``."""
        return self._call("snapshot", graph=graph, name=name)["name"]

    def fork_view(self, graph: str, name: Optional[str] = None) -> str:
        """Register a writable copy-on-write fork of ``graph``."""
        return self._call("fork_view", graph=graph, name=name)["name"]

    def drop_view(self, name: str) -> None:
        self._call("drop_view", name=name)

    def compact(self, graph: str) -> Dict:
        """Merge ``graph``'s overlay into its base stores; returns the
        pre-compaction overlay stats."""
        return self._call("compact", graph=graph)["overlay"]

    # ---------------------------------------------------------------- admin
    def ping(self) -> bool:
        return bool(self.server_info()["pong"])

    def server_info(self) -> Dict:
        """The server's ping payload: ``{"pong": True, "devices": N}`` —
        ``devices`` is the SERVER process's accelerator count (what a mesh
        load will shard over), not this client's."""
        info = self._call("ping")
        return {k: v for k, v in info.items() if k not in ("id", "ok")}

    def stats(self) -> Dict:
        return self._call("stats")["stats"]

    def metrics(self) -> str:
        """Prometheus text exposition from the server (service registry +
        process-global wire/executor/compactor instruments) — feed it to a
        scraper or ``repro.obs.parse_prometheus``."""
        return self._call("metrics")["metrics"]

    def traces(self) -> Dict:
        """Server-side observability rings: ``{"traces": [...], "slow":
        [...]}`` — recent per-query span trees and the slow-query log."""
        out = self._call("traces")
        return {"traces": out["traces"], "slow": out["slow"]}

    def drain(self) -> None:
        self._call("drain")

    def shutdown(self) -> None:
        """Graceful remote stop: drain, then the server releases itself."""
        self._call("shutdown")
