"""Service — the client-facing concurrent graph analytics API.

Wires the three subsystems together (src/repro/service/README.md walks the
request lifecycle):

    registry (named, versioned graphs)
      └─ scheduler (micro-batches compatible requests, coalesces masks)
           ├─ plan cache    (canonical pattern, backend, impl) → Plan
           └─ result cache  (graph, canonical, impl)
                              → (version, pattern refs, MatchResult)
                            invalidated by mutation-event OVERLAP, so
                            entries survive unrelated writes (§11)

``submit()`` returns a ``concurrent.futures.Future`` immediately;
``query()`` blocks on one request; ``query_batch()`` is the synchronous
entry that runs a whole group through the coalesced path in the caller's
thread (deterministic batching — what the equivalence tests and benchmarks
use).  All device execution happens on one scheduler thread, so concurrent
clients never race in the JAX runtime, and cache bookkeeping has a single
writer for the async path.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.metrics import SIZE_BUCKETS, MetricsRegistry, render_prometheus
from repro.obs.trace import Trace, TraceBuffer
from repro.overlay.delta import overlaps, pattern_refs
from repro.query import Pattern, execute_plan, parse, plan_pattern
from repro.service.cache import LRUCache
from repro.service.registry import GraphRegistry
from repro.service.scheduler import MicroBatcher, execute_coalesced

__all__ = ["Service", "ServiceConfig"]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs, all orthogonal.  ``coalesce=False`` + zero cache sizes turns
    the service into a plain per-request executor — the benchmark's
    sequential baseline inside the same machinery."""

    max_batch: int = 32  # requests per micro-batch
    window_ms: float = 2.0  # batching window opened by the first request
    adaptive_window: bool = True  # skip the window when the queue is empty
    # (c=1 pays no batching latency); open it only under queue pressure
    grace_ms: float = 0.25  # adaptive early close: end the window once the
    # queue has stayed empty this long (nothing more is coming to coalesce)
    plan_cache_size: int = 256
    result_cache_size: int = 256
    coalesce: bool = True  # fuse compatible mask steps into batched launches
    submit_fastpath: bool = True  # resolve result-cache hits at submit(),
    # before the queue — hot patterns skip the batching window entirely
    auto_compact_threshold: Optional[int] = None  # overlay entries per graph
    # before the background Compactor folds deltas into the base (None = off)
    trace_buffer: int = 256  # finished per-query traces kept in the ring
    # (0 = tracing off: no Trace objects allocated on the serve path)
    slow_query_ms: float = 250.0  # traces at/over this wall time are
    # mirrored into the slow-query log (0 = log every traced query)


@dataclasses.dataclass
class _Request:
    graph: str
    canonical: str
    ast: Pattern
    impl: Optional[str]
    future: Future
    trace: Optional[Trace] = None
    t_enqueue: float = 0.0  # perf_counter at submit → the batch.wait span


@dataclasses.dataclass
class _SampleRequest:
    """One neighborhood-sampling request (docs/ARCHITECTURE.md §15).

    ``seeds_or_pattern`` is either explicit original vertex ids or a
    Cypher-lite seed pattern; ``filter_canonical``/``filter_ast`` carry the
    optional khop-style edge filter; ``seed_val`` is the PRNG seed (layer
    keys are folded from it — the request samples bitwise-identically solo
    or coalesced).  ``cache_key`` is None for keyed-entropy
    (``deterministic=False``) requests, which are NEVER cached."""

    graph: str
    seeds_or_pattern: object
    fanouts: tuple
    filter_canonical: str
    filter_ast: Optional[Pattern]
    seed_val: int
    cache_key: Optional[tuple]
    refs: tuple
    future: Future
    trace: Optional[Trace] = None
    t_enqueue: float = 0.0


class Service:
    """In-process graph analytics service (see module docstring).

    Use as a context manager or call ``close()`` — the scheduler owns a
    worker thread.
    """

    def __init__(self, registry: Optional[GraphRegistry] = None, *,
                 config: Optional[ServiceConfig] = None):
        self.registry = registry if registry is not None else GraphRegistry()
        self.config = config if config is not None else ServiceConfig()
        self.plan_cache = LRUCache(self.config.plan_cache_size)
        self.result_cache = LRUCache(self.config.result_cache_size)
        self._canon_cache = LRUCache(512)  # raw text → (canonical, ast)
        # per-instance metrics registry (docs/ARCHITECTURE.md §13): request/
        # batch/cache counters live with THIS service — many short-lived
        # services in one test process keep independent stats() deltas.
        # The audit of the old `_stats` dict found its single-lock `_bump`
        # race-free but contended across the scheduler worker, session
        # writer threads and the compactor; per-counter locks replace it.
        self.metrics = MetricsRegistry()
        # per-key counter cache: _bump is on the submit fastpath, so it
        # must not pay the registry's key construction per call.  Plain
        # dict — GIL-atomic get/set, and counter identity is stable (the
        # registry dedups), so a racing double-store is benign.
        self._counters: Dict[str, object] = {}
        self._m_coalesce_width = self.metrics.histogram(
            "pg_sched_coalesce_width",
            "requests fused per coalesced launch", buckets=SIZE_BUCKETS)
        self.traces = TraceBuffer(maxlen=self.config.trace_buffer,
                                  slow_ms=self.config.slow_query_ms)
        self._sample_nonce = itertools.count()  # keyed-entropy requests
        self.registry.subscribe(self._on_mutation)
        self._batcher = MicroBatcher(
            self._execute_batch,
            max_batch=self.config.max_batch,
            window_ms=self.config.window_ms,
            adaptive=self.config.adaptive_window,
            grace_ms=self.config.grace_ms,
            metrics=self.metrics,
        )
        self._compactor = None
        if self.config.auto_compact_threshold is not None:
            from repro.overlay.compactor import Compactor

            self._compactor = Compactor(
                self.registry, self.config.auto_compact_threshold)
            self._compactor.start()

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._compactor is not None:
            self._compactor.stop()
        self._batcher.close()
        # a shared registry must not keep feeding (and pinning) this
        # service's caches after shutdown
        self.registry.unsubscribe(self._on_mutation)

    def __enter__(self) -> "Service":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- graphs
    def add_graph(self, name: str, pg) -> "Service":
        """Register a built ``PropGraph`` under ``name``."""
        self.registry.register(name, pg)
        return self

    def load_graph(self, name: str, path: str, *, backend: Optional[str] = None,
                   mesh=None) -> "Service":
        """Reopen a saved graph (optionally onto a mesh) and serve it."""
        self.registry.load(name, path, backend=backend, mesh=mesh)
        return self

    def snapshot_graph(self, graph: str, name: Optional[str] = None) -> str:
        """Pin an immutable snapshot of ``graph`` and serve it under its own
        name (default ``"<graph>@s<version>"``).  The snapshot shares the
        parent's device-resident base — zero-copy — and never changes, so
        results cached under the snapshot name stay valid FOREVER while the
        parent keeps absorbing writes (docs/ARCHITECTURE.md §11).  Taking
        the same snapshot name at the same parent version is idempotent."""
        pg = self.registry.get(graph)
        name = name if name is not None else f"{graph}@s{pg.version}"
        try:
            existing = self.registry.get(name)
            if existing.frozen and existing.version == pg.version:
                return name  # same pin — keep it (and its cached results)
        except KeyError:
            pass
        self.registry.register(name, pg.snapshot())
        self._bump("snapshots")
        return name

    def fork_graph(self, graph: str, name: Optional[str] = None) -> str:
        """Register a writable copy-on-write view of ``graph`` (default name
        ``"<graph>@fork<version>"``) — the per-tenant what-if branch."""
        pg = self.registry.get(graph)
        name = name if name is not None else f"{graph}@fork{pg.version}"
        self.registry.register(name, pg.fork())
        self._bump("forks")
        return name

    def drop_graph(self, name: str) -> "Service":
        """Stop serving ``name`` (snapshot, fork or plain graph) and drop
        every result cached under it."""
        self.registry.unregister(name)
        dropped = self.result_cache.purge(lambda k, v: k[0] == name)
        if dropped:
            self._bump("invalidated_results", dropped)
        return self

    def compact_graph(self, name: str) -> Dict[str, int]:
        """Foreground compaction of ``name``'s overlay; returns the overlay
        stats that were folded in (all zero = it was already compact)."""
        pg = self.registry.get(name)
        stats = pg.delta_stats()
        pg.compact()
        return stats

    # --------------------------------------------------------------- clients
    def submit(self, graph: str, pattern: Union[str, Pattern], *,
               impl: Optional[str] = None,
               trace: Optional[Trace] = None) -> Future:
        """Enqueue one pattern query; returns its ``Future`` immediately.

        Parse errors surface here (caller's thread), not on the future —
        a malformed pattern is a client bug, not a serving failure.

        ``trace`` carries a caller-minted span tree (the wire server hands
        in one rooted at the client's trace id); with tracing enabled
        (``ServiceConfig.trace_buffer > 0``) an untraced submit mints its
        own.  The trace travels WITH the request across the thread hops
        and lands finished in ``Service.traces``."""
        if self._batcher.closed:
            # uniform closed-service contract: even a pattern the result
            # cache could answer raises, like every cache miss would
            raise RuntimeError("scheduler is closed")
        t0 = time.perf_counter()
        canonical, ast = self._canon(pattern)
        t1 = time.perf_counter()
        tr = trace
        if tr is None and self.config.trace_buffer > 0:
            tr = Trace("query")
        if tr is not None:
            tr.annotate(graph=graph, pattern=canonical)
            tr.add_span("parse", t0, t1)
        fut: Future = Future()
        self._bump("submitted")
        if self.config.submit_fastpath:
            if graph in self.registry:
                # entry liveness is maintained by overlap purging, not a
                # version key: a hit here may have been cached several
                # (non-overlapping) writes ago and is still exact (§11)
                hit = self.result_cache.get((graph, canonical, impl))
                if hit is not None:
                    self._bump("result_hits")
                    self._bump("fastpath_hits")
                    self._bump("completed")
                    if tr is not None:
                        tr.add_span("cache", t1, time.perf_counter(),
                                    hit=True, fastpath=True)
                    fut.set_result(hit[2])
                    if tr is not None:
                        self.traces.push(tr)
                    return fut
        self._batcher.submit(
            _Request(graph=graph, canonical=canonical, ast=ast, impl=impl,
                     future=fut, trace=tr, t_enqueue=time.perf_counter())
        )
        return fut

    def query(self, graph: str, pattern: Union[str, Pattern], *,
              impl: Optional[str] = None, timeout: Optional[float] = 60.0):
        """Blocking single query → ``MatchResult``."""
        return self.submit(graph, pattern, impl=impl).result(timeout=timeout)

    def query_batch(self, graph: str, patterns: Sequence[Union[str, Pattern]],
                    *, impl: Optional[str] = None) -> List:
        """Synchronous coalesced execution of ``patterns`` as ONE group in
        the caller's thread (bypasses the queue — batch composition is
        deterministic, which the bitwise-equivalence tests rely on).
        The first failing pattern's error raises; prior semantics of a
        plain loop of ``match()`` calls."""
        pg = self.registry.get(graph)
        positions: Dict[str, List[int]] = {}  # canonical → indices (dedup)
        canon_asts: Dict[str, Pattern] = {}
        for i, pat in enumerate(patterns):
            canonical, ast = self._canon(pat)
            if canonical in positions:
                self._bump("dedup_hits")
            else:
                canon_asts[canonical] = ast
            positions.setdefault(canonical, []).append(i)
        outcomes = self._serve_group(pg, graph, impl, canon_asts)
        out: List = [None] * len(patterns)
        for canonical, idxs in positions.items():
            res = outcomes[canonical]
            if isinstance(res, BaseException):
                raise res
            for i in idxs:
                out[i] = res
        self._bump("batches")
        self._bump("batched_requests", len(patterns))
        self._bump("completed", len(patterns))
        return out

    # -------------------------------------------------------------- sampling
    def submit_sample(self, graph: str, seeds_or_pattern, fanouts, *,
                      pattern: Union[str, Pattern, None] = None,
                      seed: int = 0, deterministic: bool = True,
                      trace: Optional[Trace] = None) -> Future:
        """Enqueue one ``PropGraph.sample`` request; Future → SampledBlock
        list (innermost first, internal ids — the §15 contract).

        The MicroBatcher coalesces sample requests across clients: same
        (graph, fanouts, seed-count bucket) → ONE batched layer-0 launch,
        results keyed back out per request.  Each request draws only from
        its own ``fold_in``-derived keys, so the result is bitwise the solo
        run — coalescing changes schedules, never samples.

        ``deterministic=True`` (seeded) requests are cacheable — repeats
        of the same (graph, seeds, fanouts, filter, seed) serve from the
        result cache until a mutation invalidates them.
        ``deterministic=False`` ignores ``seed``, draws a fresh nonce per
        request, and is NEVER cached."""
        if self._batcher.closed:
            raise RuntimeError("scheduler is closed")
        fanouts = tuple(int(f) for f in fanouts)
        if not fanouts or min(fanouts) < 1:
            raise ValueError(f"fanouts must be ≥1 per layer, got {fanouts}")
        if pattern is not None:
            fcanon, fast = self._canon(pattern)
            refs = pattern_refs(fast)
        else:
            fcanon, fast = "", None
            refs = (frozenset(), frozenset(), frozenset())
        if isinstance(seeds_or_pattern, (str, Pattern)):
            scanon, sast = self._canon(seeds_or_pattern)
            seeds_or_pattern = sast
            sref = pattern_refs(sast)
            refs = tuple(a | b for a, b in zip(refs, sref))
            spec = f"p:{scanon}"
        else:
            seeds_or_pattern = np.asarray(seeds_or_pattern).ravel()
            spec = f"v:{','.join(str(int(s)) for s in seeds_or_pattern)}"
        if deterministic:
            seed_val = int(seed)
            cache_key = (graph,
                         f"sample:{spec}:f={fanouts}:q={fcanon}:s={seed_val}",
                         None)
        else:
            seed_val = (time.time_ns() ^ (next(self._sample_nonce) << 17)
                        ) & 0x7FFFFFFF
            cache_key = None
        tr = trace
        if tr is None and self.config.trace_buffer > 0:
            tr = Trace("sample")
        if tr is not None:
            tr.annotate(graph=graph, fanouts=str(fanouts), filter=fcanon)
        fut: Future = Future()
        self._bump("sample_requests")
        if (cache_key is not None and self.config.submit_fastpath
                and graph in self.registry):
            hit = self.result_cache.get(cache_key)
            if hit is not None:
                self._bump("result_hits")
                self._bump("fastpath_hits")
                self._bump("completed")
                fut.set_result(hit[2])
                if tr is not None:
                    self.traces.push(tr)
                return fut
        self._batcher.submit(_SampleRequest(
            graph=graph, seeds_or_pattern=seeds_or_pattern, fanouts=fanouts,
            filter_canonical=fcanon, filter_ast=fast, seed_val=seed_val,
            cache_key=cache_key, refs=refs, future=fut, trace=tr,
            t_enqueue=time.perf_counter()))
        return fut

    def sample(self, graph: str, seeds_or_pattern, fanouts, *,
               pattern: Union[str, Pattern, None] = None, seed: int = 0,
               deterministic: bool = True,
               timeout: Optional[float] = 60.0):
        """Blocking single sample → SampledBlock list."""
        return self.submit_sample(
            graph, seeds_or_pattern, fanouts, pattern=pattern, seed=seed,
            deterministic=deterministic).result(timeout=timeout)

    def sample_batch(self, graph: str, specs: Sequence, fanouts, *,
                     pattern: Union[str, Pattern, None] = None,
                     deterministic: bool = True) -> List:
        """Synchronous coalesced sampling: ``specs`` is a sequence of
        ``(seeds_or_pattern, prng_seed)`` pairs served as deterministic
        groups in the caller's thread (the ``query_batch`` analogue the
        parity tests and benchmarks drive).  Returns one block list per
        spec; the first failure raises."""
        futs = []
        reqs = []
        fanouts = tuple(int(f) for f in fanouts)
        for seeds, sv in specs:
            fut: Future = Future()
            fut.set_running_or_notify_cancel()
            if pattern is not None:
                fcanon, fast = self._canon(pattern)
            else:
                fcanon, fast = "", None
            if isinstance(seeds, (str, Pattern)):
                _, seeds = self._canon(seeds)
            else:
                seeds = np.asarray(seeds).ravel()
            # cache_key stays None: this entry exists for deterministic
            # grouping (tests/benches), not caching
            reqs.append(_SampleRequest(
                graph=graph, seeds_or_pattern=seeds, fanouts=fanouts,
                filter_canonical=fcanon, filter_ast=fast,
                seed_val=int(sv), cache_key=None,
                refs=(frozenset(), frozenset(), frozenset()),
                future=fut))
            futs.append(fut)
        self._serve_samples(reqs, started=True)
        return [f.result(timeout=0) for f in futs]

    # ------------------------------------------------------------- analytics
    def shortest_paths(self, graph: str, seeds, *,
                       weight: Optional[str] = None,
                       pattern: Union[str, Pattern, None] = None,
                       undirected: bool = False,
                       max_iters: Optional[int] = None):
        """Serve ``PropGraph.shortest_paths`` under ``graph``: (n,) f32
        distances as numpy.  Cached like pattern queries — the entry's
        footprint is the filter pattern's refs PLUS the weight property,
        so a write to ``weight``'s column invalidates it while unrelated
        property writes leave it live (§11, §12)."""
        canon_seeds = tuple(sorted({int(s) for s in np.ravel(seeds)}))
        params = (f"s={canon_seeds}:w={weight}:u={int(bool(undirected))}"
                  f":k={max_iters}")
        return self._analytics(
            graph, "shortest_paths", params, pattern, weight,
            lambda pg: pg.shortest_paths(
                list(canon_seeds), weight=weight, pattern=pattern,
                undirected=undirected, max_iters=max_iters))

    def pagerank(self, graph: str, *, weight: Optional[str] = None,
                 pattern: Union[str, Pattern, None] = None,
                 damping: float = 0.85, iters: int = 20):
        """Serve ``PropGraph.pagerank`` under ``graph``: (n,) f32 ranks as
        numpy, cached/invalidated like :meth:`shortest_paths`."""
        params = f"w={weight}:d={damping!r}:it={iters}"
        return self._analytics(
            graph, "pagerank", params, pattern, weight,
            lambda pg: pg.pagerank(pattern=pattern, weight=weight,
                                   damping=damping, iters=iters))

    def communities(self, graph: str, *,
                    pattern: Union[str, Pattern, None] = None,
                    max_iters: int = 64):
        """Serve ``PropGraph.communities`` under ``graph``: (n,) int32
        labels as numpy, cached/invalidated like :meth:`shortest_paths`."""
        params = f"k={max_iters}"
        return self._analytics(
            graph, "communities", params, pattern, None,
            lambda pg: pg.communities(pattern=pattern, max_iters=max_iters))

    def _analytics(self, graph: str, op: str, params: str,
                   pattern, weight: Optional[str], run):
        """Shared serve path for the semiring analytics verbs: result cache
        keyed ``(graph, "analytics:op:pattern:params", None)`` — key[0] is
        the graph name, so every existing purge path (drop, structural
        events, overlap tests against the stored refs) applies unchanged.
        Runs in the caller's thread (the mutator precedent): analytics hit
        the frontier engine directly, never the plan/coalesce pipeline.
        Consistency under concurrent mutators mirrors ``_serve_group``:
        version read before running, re-checked after, up to 3 attempts;
        a torn view is returned best-effort but never cached, and the
        put-then-purge guard drops an entry a racing write may have missed."""
        pg = self.registry.get(graph)
        if pattern is not None:
            canonical, ast = self._canon(pattern)
            refs = pattern_refs(ast)
        else:
            canonical, refs = "", (frozenset(), frozenset(), frozenset())
        if weight is not None:
            refs = (refs[0], refs[1], refs[2] | frozenset((str(weight),)))
        key = (graph, f"analytics:{op}:{canonical}:{params}", None)
        self._bump("analytics_requests")
        hit = self.result_cache.get(key)
        if hit is not None:
            self._bump("result_hits")
            return hit[2]
        self._bump("result_misses")
        res = None
        for attempt in range(3):
            version = pg.version
            try:
                res = np.asarray(run(pg))
            except Exception:
                if pg.version != version and attempt < 2:
                    continue  # a concurrent mutation tore the view — retry
                self._bump("errors")
                raise
            if pg.version == version:
                self.result_cache.put(key, (version, refs, res))
                if pg.version != version:
                    # a write landed between the stability check and the
                    # put — drop our own entry (see _serve_group)
                    self.result_cache.purge(lambda kk, vv, _k=key: kk == _k)
                break
        return res

    # ----------------------------------------------------------------- stats
    def stats(self) -> Dict[str, object]:
        """Counter snapshot: request/batch totals, coalescing activity,
        cache hit/miss/eviction/invalidation accounting.  Backed by the
        per-service metrics registry — the same instruments the Prometheus
        exposition renders, so the two views cannot disagree.  Legacy flat
        keys (``submitted``, ``result_hits``, …) are unchanged; registry
        histograms appear under their ``pg_``-prefixed names as dicts."""
        out: Dict[str, object] = self.metrics.snapshot()
        out["plan_cache"] = self.plan_cache.stats()
        out["result_cache"] = self.result_cache.stats()
        if self._compactor is not None:
            # background compactions and their failures must be visible to
            # operators — a failing graph is skipped, never silently retried
            out["compactor"] = self._compactor.stats()
        return out

    def metrics_text(self) -> str:
        """Prometheus text exposition: this service's registry (request/
        batch/cache counters, scheduler histograms) plus the process
        ``GLOBAL`` registry (wire, executor, compactor).  Cache and
        compactor internals keep their own counters; they are mirrored
        into labeled instruments here at render time so the scrape always
        agrees with ``stats()``."""
        for tier, cache in (("plan", self.plan_cache),
                            ("result", self.result_cache)):
            s = cache.stats()
            for k in ("hits", "misses", "evictions"):
                self.metrics.counter(
                    f"pg_cache_{k}", f"LRU cache {k} by tier",
                    tier=tier).set_total(s[k])
            self.metrics.gauge(
                "pg_cache_size", "LRU cache live entries",
                tier=tier).set(s["size"])
            self.metrics.gauge(
                "pg_cache_maxsize", "LRU cache capacity",
                tier=tier).set(s["maxsize"])
        # compactor sweeps/failures live in GLOBAL (pg_compact_*): the
        # Compactor instruments itself, so nothing to mirror here
        return render_prometheus(self.metrics, obs_metrics.GLOBAL)

    def trace_log(self) -> List[Dict[str, object]]:
        """Finished per-query trace trees, oldest first (bounded ring)."""
        return self.traces.traces()

    def slow_queries(self) -> List[Dict[str, object]]:
        """Traces that ran at/over ``ServiceConfig.slow_query_ms``."""
        return self.traces.slow()

    def _bump(self, key: str, n: int = 1) -> None:
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = self.metrics.counter(key)
        c.inc(n)

    # ------------------------------------------------------------- internals
    def _canon(self, pattern: Union[str, Pattern]):
        """Pattern → (canonical text, AST); the canonical form is
        ``parse(...).to_text()``, so textual variants ("(a)-[]->(b)" with
        odd spacing) share cache entries."""
        if isinstance(pattern, Pattern):
            return pattern.to_text(), pattern
        cached = self._canon_cache.get(pattern)
        if cached is not None:
            return cached
        ast = parse(pattern)
        entry = (ast.to_text(), ast)
        self._canon_cache.put(pattern, entry)
        return entry

    def _plan(self, pg, canonical: str, ast: Pattern, impl: Optional[str]):
        key = (canonical, pg.backend, impl)
        plan = self.plan_cache.get(key)
        if plan is not None:
            self._bump("plan_hits")
            return plan
        self._bump("plan_misses")
        plan = plan_pattern(pg, ast, impl=impl)
        self.plan_cache.put(key, plan)
        return plan

    def _execute_plans(self, pg, plans: List, impl: Optional[str]) -> List:
        if not self.config.coalesce:
            return [execute_plan(pg, p) for p in plans]
        local: Dict[str, int] = {}
        results = execute_coalesced(pg, plans, impl=impl, stats=local)
        for k, v in local.items():
            self._bump(k, v)
        self._m_coalesce_width.observe(len(plans))
        return results

    def _serve_group(self, pg, graph: str, impl: Optional[str],
                     canon_asts: Dict[str, Pattern],
                     timings: Optional[Dict[str, object]] = None
                     ) -> Dict[str, object]:
        """The serve pipeline for ONE deduplicated group: result-cache
        probe → per-request planning → coalesced execution → cache put.
        Returns canonical → ``MatchResult`` or ``Exception`` — both entry
        points (``query_batch`` and the scheduler worker) fan the outcomes
        out to their callers.

        Failure isolation: planning errors (bad property names etc.) fail
        only their own request; if the COALESCED execution raises, the
        group re-runs per-request so one poisoned plan cannot take down
        co-batched tenants.  Consistency under concurrent mutators: the
        version is read before executing and re-checked after — a
        mid-flight mutation (torn graph/store view) retries the group and
        nothing torn is ever cached or returned as authoritative.

        ``timings`` (optional mutable dict) receives the group's stage
        endpoints — ``cache``/``plan``/``execute`` → ``(t0, t1)`` in
        ``perf_counter`` seconds plus ``cache_hits`` (canonicals served
        from cache) — measured ONCE per group; the batch path copies them
        into every member request's trace."""
        t_cache0 = time.perf_counter()
        outcomes: Dict[str, object] = {}
        todo: Dict[str, Pattern] = {}
        for canonical, ast in canon_asts.items():
            hit = self.result_cache.get((graph, canonical, impl))
            if hit is not None:
                self._bump("result_hits")
                outcomes[canonical] = hit[2]
            else:
                self._bump("result_misses")
                todo[canonical] = ast
        t_cache1 = time.perf_counter()
        if timings is not None:
            timings["cache"] = (t_cache0, t_cache1)
            timings["cache_hits"] = set(outcomes)
        if not todo:
            return outcomes

        plans: Dict[str, object] = {}
        for canonical, ast in todo.items():
            try:
                plans[canonical] = self._plan(pg, canonical, ast, impl)
            except Exception as e:  # noqa: BLE001 — isolated to this request
                outcomes[canonical] = e
                self._bump("errors")
        t_plan1 = time.perf_counter()
        if timings is not None:
            timings["plan"] = (t_cache1, t_plan1)
        if not plans:
            return outcomes

        keys = list(plans)
        results: List[object] = []
        stable = False
        for attempt in range(3):
            version = pg.version
            try:
                results = self._execute_plans(pg, [plans[c] for c in keys], impl)
            except Exception as e:  # noqa: BLE001
                if pg.version != version and attempt < 2:
                    continue  # a concurrent mutation tore the view — retry
                # the group itself failed: isolate by per-request execution
                results = []
                for c in keys:
                    try:
                        results.append(execute_plan(pg, plans[c]))
                    except Exception as ee:  # noqa: BLE001
                        results.append(ee)
                break
            if pg.version == version:
                stable = True
                break  # consistent snapshot — safe to cache
        if timings is not None:
            timings["execute"] = (t_plan1, time.perf_counter())
        put_keys = []
        for c, res in zip(keys, results):
            if isinstance(res, BaseException):
                outcomes[c] = res
                self._bump("errors")
            else:
                if stable:
                    refs = pattern_refs(canon_asts[c])
                    self.result_cache.put((graph, c, impl), (version, refs, res))
                    put_keys.append((graph, c, impl))
                outcomes[c] = res
        if put_keys and pg.version != version:
            # a write landed between the stability check and the put: the
            # overlap purge it triggered may have run BEFORE our put made
            # the entry visible — without a version in the key that entry
            # would now serve stale hits forever, so drop our own puts
            for k in put_keys:
                self.result_cache.purge(lambda kk, vv, _k=k: kk == _k)
        return outcomes

    def _resolve_sample_seeds(self, pg, seeds_or_pattern) -> np.ndarray:
        """Request seeds → internal ids, exactly ``PropGraph.sample``'s
        rule: pattern seeds are the first node variable's matches in
        ascending internal order (what the device ``nonzero`` extraction
        yields); explicit ids keep caller order, unknown and tombstoned
        ids drop out."""
        if isinstance(seeds_or_pattern, (str, Pattern)):
            res = pg.match(seeds_or_pattern)
            mask = res.node_masks[0] if res.node_masks else res.vertex_mask
            return np.flatnonzero(np.asarray(mask)).astype(np.int32)
        ids = pg._vertex_internal(seeds_or_pattern)
        ids = ids[ids >= 0]
        if pg._dead_v is not None and ids.size:
            ids = ids[~pg._dead_v[ids]]
        return ids.astype(np.int32)

    def _serve_samples(self, reqs: List[_SampleRequest],
                       started: bool = False) -> None:
        """Serve a window's sample requests: cache probe → seed resolution
        → group by (graph, fanouts, seed-count bucket) → ONE batched
        layer-0 launch per group + per-request deeper layers.  The group
        key carries the CAPACITY BUCKET because the per-request uniform
        draw is shaped (bucket, window): equal buckets are what make a
        coalesced row bitwise its solo run.  Never raises — failures land
        on the affected futures."""
        from repro.kernels.neighbor_sample import bucketed_seeds

        groups: Dict[tuple, List] = {}
        for r in reqs:
            if not started and not r.future.set_running_or_notify_cancel():
                continue
            try:
                pg = self.registry.get(r.graph)
            except KeyError as e:
                r.future.set_exception(e)
                self._bump("errors")
                continue
            if r.cache_key is not None:
                hit = self.result_cache.get(r.cache_key)
                if hit is not None:
                    self._bump("result_hits")
                    self._bump("completed")
                    r.future.set_result(hit[2])
                    if r.trace is not None:
                        self.traces.push(r.trace)
                    continue
                self._bump("result_misses")
            try:
                ids = self._resolve_sample_seeds(pg, r.seeds_or_pattern)
            except Exception as e:  # noqa: BLE001 — isolated to this request
                r.future.set_exception(e)
                self._bump("errors")
                continue
            key = (r.graph, r.fanouts, bucketed_seeds(max(ids.size, 1)))
            groups.setdefault(key, []).append((r, pg, ids))
        for (gname, fanouts, cap), entries in groups.items():
            self._serve_sample_group(gname, fanouts, cap, entries)

    def _serve_sample_group(self, gname: str, fanouts: tuple, cap: int,
                            entries: List) -> None:
        """One coalesced group: R request rows (padded to the request
        bucket) through ``neighbor_sample_batched`` — layer 0 of every
        request in ONE launch — then each request finishes its deeper
        layers via ``PropGraph._sample_rest`` (identical keys to a solo
        run).  Version consistency mirrors ``_serve_group``: read before,
        re-check after, up to 3 attempts; torn views are returned
        best-effort but never cached."""
        import jax
        import jax.numpy as jnp

        from repro.core import bitplane
        from repro.graph import sampler
        from repro.kernels.neighbor_sample import (
            bucketed_requests,
            neighbor_sample_batched,
        )

        pg = entries[0][1]
        R = len(entries)
        results: List[object] = [None] * R
        version = None
        stable = False
        for attempt in range(3):
            version = pg.version
            try:
                seg, dstv, max_deg, perm = pg._sampling_view()
                g = pg._require_graph()
                ew_rows, any_words = [], False
                for r, _pg, _ids in entries:
                    ew = pg._sample_edge_words(
                        r.filter_canonical if r.filter_canonical else None,
                        perm)
                    ew_rows.append(ew)
                    any_words = any_words or ew is not None
                nw = bitplane.n_words(max(g.m, 1))
                rcap = bucketed_requests(R)
                seeds_m = np.zeros((rcap, cap), np.int32)
                valid_m = np.zeros((rcap, cap), bool)
                seedvals = np.zeros((rcap,), np.int32)
                for i, (r, _pg, ids) in enumerate(entries):
                    s = min(ids.size, cap)
                    seeds_m[i, :s] = ids[:s]
                    valid_m[i, :s] = True
                    seedvals[i] = r.seed_val
                seedvals[R:] = seedvals[R - 1]  # pad rows: all-invalid
                # all R layer-0 keys in ONE dispatch; row i is bitwise
                # fold_in(PRNGKey(seed_i), 0), the solo-run key
                keys = sampler.layer_keys_batch(jnp.asarray(seedvals), 0)
                words_m = None
                if any_words:
                    ones = np.full((nw,), 0xFFFFFFFF, np.uint32)
                    words_m = jnp.stack([
                        (jnp.asarray(ones) if ew is None else ew)
                        for ew in ew_rows
                    ] + [jnp.asarray(ones)] * (rcap - R))
                nb, _ei, mk = neighbor_sample_batched(
                    seg, dstv, g.n, g.m, seeds_m, valid_m, keys,
                    fanout=fanouts[0], edge_words=words_m, max_deg=max_deg)
                nb_h, mk_h = np.asarray(nb), np.asarray(mk)
                self._bump("sample_coalesced_launches")
                for i, (r, _pg, ids) in enumerate(entries):
                    s = min(ids.size, cap)
                    try:
                        results[i] = pg._sample_rest(
                            ids[:s], nb_h[i, :s], mk_h[i, :s], list(fanouts),
                            int(r.seed_val), seg, dstv, max_deg, ew_rows[i])
                    except Exception as e:  # noqa: BLE001
                        results[i] = e
            except Exception as e:  # noqa: BLE001
                if pg.version != version and attempt < 2:
                    continue  # a concurrent mutation tore the view — retry
                results = [e] * R
                break
            if pg.version == version:
                stable = True
                break
        put_keys = []
        for (r, _pg, _ids), res in zip(entries, results):
            if isinstance(res, BaseException):
                r.future.set_exception(res)
                self._bump("errors")
            else:
                if stable and r.cache_key is not None:
                    self.result_cache.put(r.cache_key,
                                          (version, r.refs, res))
                    put_keys.append(r.cache_key)
                r.future.set_result(res)
                self._bump("completed")
            if r.trace is not None:
                self.traces.push(r.trace)
        if put_keys and pg.version != version:
            # the _serve_group put-then-purge guard: a write racing the put
            # may have purged before our entry became visible — drop ours
            for k in put_keys:
                self.result_cache.purge(lambda kk, vv, _k=k: kk == _k)

    def _on_mutation(self, name: str, pg) -> None:
        """Registry subscriber: drop result-cache entries the mutation can
        have changed.  Attribute-scoped events (``pg.last_mutation``) purge
        by OVERLAP with each entry's pattern footprint — a result cached at
        snapshot S survives writes that only grew the delta chain past S
        with attributes its pattern never reads.  Structural events (edge
        inserts/deletes, rebuilds, compaction, registration) and graphs
        without event info purge everything under the name (§11)."""
        ev = getattr(pg, "last_mutation", None)
        if ev is None or ev.structural:
            dropped = self.result_cache.purge(lambda k, v: k[0] == name)
        else:
            dropped = self.result_cache.purge(
                lambda k, v, _ev=ev: k[0] == name and overlaps(_ev, v[1]))
        self._bump("invalidation_events")
        if dropped:
            self._bump("invalidated_results", dropped)

    def _execute_batch(self, batch: List[_Request]) -> None:
        """MicroBatcher callback: group compatible requests, serve cache
        hits, run the rest coalesced.  Never raises — failures land on the
        affected futures."""
        self._bump("batches")
        self._bump("batched_requests", len(batch))
        samples = [r for r in batch if isinstance(r, _SampleRequest)]
        if samples:
            self._serve_samples(samples)
        groups: Dict[tuple, List[_Request]] = {}
        for req in batch:
            if isinstance(req, _SampleRequest):
                continue
            groups.setdefault((req.graph, req.impl), []).append(req)
        for (gname, impl), reqs in groups.items():
            try:
                pg = self.registry.get(gname)
            except KeyError as e:
                for r in reqs:
                    if r.future.set_running_or_notify_cancel():
                        r.future.set_exception(e)
                        self._bump("errors")
                        if r.trace is not None:
                            r.trace.annotate(error="KeyError")
                            self.traces.push(r.trace)
                continue
            # duplicate canonicals inside one window execute ONCE and fan
            # the result out (the multi-tenant hot-pattern case)
            by_canonical: Dict[str, List[_Request]] = {}
            canon_asts: Dict[str, Pattern] = {}
            for r in reqs:
                if not r.future.set_running_or_notify_cancel():
                    continue  # client cancelled while queued
                if r.canonical in by_canonical:
                    self._bump("dedup_hits")
                else:
                    canon_asts[r.canonical] = r.ast
                by_canonical.setdefault(r.canonical, []).append(r)
            if not by_canonical:
                continue
            traced = [r for rs in by_canonical.values() for r in rs
                      if r.trace is not None]
            t_batch = time.perf_counter()
            for r in traced:
                r.trace.add_span("batch.wait", r.t_enqueue, t_batch,
                                 batch_size=len(batch))
            timings: Optional[Dict[str, object]] = {} if traced else None
            outcomes = self._serve_group(pg, gname, impl, canon_asts,
                                         timings=timings)
            for canonical, rs in by_canonical.items():
                res = outcomes[canonical]
                for r in rs:
                    if r.trace is not None and timings is not None:
                        hits = timings.get("cache_hits", ())
                        for stage in ("cache", "plan", "execute"):
                            tt = timings.get(stage)
                            if tt is None:
                                continue
                            attrs = ({"hit": canonical in hits}
                                     if stage == "cache" else {})
                            r.trace.add_span(stage, tt[0], tt[1], **attrs)
                    if isinstance(res, BaseException):
                        r.future.set_exception(res)
                    else:
                        r.future.set_result(res)
                        self._bump("completed")
                    if r.trace is not None:
                        self.traces.push(r.trace)
