"""GraphRegistry — named, versioned property graphs for the service layer.

The Arkouda/Arachne deployment model (PAPER.md) is a persistent parallel
server holding symbol-table entries that many Python clients name in their
messages; this registry is that symbol table for ``PropGraph``s.  Each
entry is (name → graph), the graph carries its own monotone ``version``
(bumped by every mutator — ``core/property_graph.py``), and the registry
fans mutation events out to subscribers (the service's result-cache
invalidation hook).

Mesh-awareness comes for free: a registered graph keeps whatever placement
it was built or loaded with (``PropGraph(mesh=...)`` /
``load_propgraph(path, mesh=...)``) — the registry never touches device
state.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from repro.core.property_graph import PropGraph

__all__ = ["GraphRegistry"]


class GraphRegistry:
    """Thread-safe name → ``PropGraph`` map with mutation fan-out.

    ``subscribe(listener)`` registers ``listener(name, pg)``, called after
    any mutation of a registered graph (and on registration itself, so a
    subscriber can treat "new graph under this name" and "graph changed"
    uniformly — both invalidate anything cached under the name).
    """

    def __init__(self):
        self._graphs: Dict[str, PropGraph] = {}
        self._listeners: List[Callable[[str, PropGraph], None]] = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------ population
    def register(self, name: str, pg: PropGraph) -> PropGraph:
        """Attach ``pg`` under ``name``; future mutations of ``pg`` notify
        subscribers.  Re-registering a name replaces the graph (and
        notifies, since cached results for the old graph are now dead).

        Exactly one hook per (registry, name, graph): refreshing the same
        registration is idempotent, and a replaced graph's hook goes
        silent (``_dispatch`` forwards only while the graph is still the
        one served under the name) instead of purging forever."""
        with self._lock:
            self._graphs[name] = pg
        marks = getattr(pg, "_registry_marks", None)
        if marks is None:
            marks = pg._registry_marks = set()
        # id(self) cannot be recycled while a mark exists: the installed
        # hook's closure holds this registry, so the graph pins it alive
        key = (id(self), name)
        if key not in marks:
            marks.add(key)
            pg.on_mutation(lambda g, _name=name: self._dispatch(_name, g))
        # registration is structural as far as observers go: anything cached
        # under this name belongs to whatever was served before, so the
        # notify must purge ALL of it — not just what the graph's last
        # (possibly attribute-scoped) mutation event would overlap
        from repro.overlay.delta import MutationEvent

        pg.last_mutation = MutationEvent.structural_event("register")
        self._notify(name, pg)
        return pg

    def unregister(self, name: str) -> None:
        """Drop ``name`` (no-op if absent).  The graph's installed hook goes
        silent via the ``_dispatch`` currency check; no notification fires —
        observers drop their own state via ``Service.drop_graph``."""
        with self._lock:
            self._graphs.pop(name, None)

    def _dispatch(self, name: str, pg: PropGraph) -> None:
        with self._lock:
            current = self._graphs.get(name)
        if current is pg:
            self._notify(name, pg)

    def load(self, name: str, path: str, *, backend: Optional[str] = None,
             mesh=None) -> PropGraph:
        """``load_propgraph`` + ``register`` — reopen an ingested-once graph
        (optionally straight onto a device mesh) and serve it by name."""
        from repro.core.io import load_propgraph

        return self.register(name, load_propgraph(path, backend=backend, mesh=mesh))

    # -------------------------------------------------------------- queries
    def get(self, name: str) -> PropGraph:
        with self._lock:
            try:
                return self._graphs[name]
            except KeyError:
                raise KeyError(
                    f"unknown graph {name!r}; registered: {sorted(self._graphs)}"
                ) from None

    def version(self, name: str) -> int:
        """The graph's current mutation counter — the freshness component of
        every result-cache key."""
        return self.get(name).version

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._graphs)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._graphs

    def __len__(self) -> int:
        with self._lock:
            return len(self._graphs)

    # ---------------------------------------------------------- subscription
    def subscribe(self, listener: Callable[[str, PropGraph], None]) -> None:
        with self._lock:
            self._listeners.append(listener)

    def unsubscribe(self, listener: Callable[[str, PropGraph], None]) -> None:
        """Remove ``listener`` if present (no-op otherwise) — a closed
        service detaches so a shared registry stops feeding dead caches."""
        with self._lock:
            try:
                self._listeners.remove(listener)
            except ValueError:
                pass

    def _notify(self, name: str, pg: PropGraph) -> None:
        # snapshot under the lock: services subscribe/unsubscribe (open/
        # close) concurrently with mutation dispatch, and an unsynchronized
        # list mutation mid-iteration would skip or crash a listener.
        # Dispatch OUTSIDE the lock — listeners (cache purges) must not be
        # able to deadlock against registry readers.
        with self._lock:
            listeners = list(self._listeners)
        for listener in listeners:
            listener(name, pg)
