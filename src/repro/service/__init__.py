"""repro.service — concurrent graph analytics service + network front-end.

The serving layer over the property-graph stack (docs/ARCHITECTURE.md
§8–§9): a ``GraphRegistry`` of named, versioned ``PropGraph``s, a
micro-batching scheduler (adaptive window) that coalesces concurrent
pattern queries into single ``bitmap_query_batched`` launches, a two-tier
plan/result cache keyed to survive exactly as long as correctness allows,
and the ``pgd`` wire layer — ``PGServer``/``PGClient`` over a
length-prefixed JSON+binary codec (``wire.py``) — so multiple OS
processes share one registry, one mesh and one scheduler, the paper §III
deployment shape.  README.md in this directory documents the request
lifecycle, coalescing rules, cache keys and the client/server quickstart;
``repro.launch.pgserve`` is the CLI driver (``--net`` for the network
path).

    from repro.service import Service
    with Service() as svc:
        svc.add_graph("social", pg)
        res = svc.query("social", "(a:person)-[:follows]->(b:person)")
        futs = [svc.submit("social", p) for p in patterns]  # concurrent
"""
from repro.service.cache import LRUCache
from repro.service.client import PGClient
from repro.service.registry import GraphRegistry
from repro.service.scheduler import MicroBatcher, execute_coalesced
from repro.service.server import PGServer
from repro.service.service import Service, ServiceConfig

__all__ = [
    "Service",
    "ServiceConfig",
    "GraphRegistry",
    "LRUCache",
    "MicroBatcher",
    "execute_coalesced",
    "PGServer",
    "PGClient",
]
