"""repro.service — in-process concurrent graph analytics service.

The serving layer over the property-graph stack (docs/ARCHITECTURE.md §8):
a ``GraphRegistry`` of named, versioned ``PropGraph``s, a micro-batching
scheduler that coalesces concurrent pattern queries into single
``bitmap_query_batched`` launches, and a two-tier plan/result cache keyed
to survive exactly as long as correctness allows.  README.md in this
directory documents the request lifecycle, coalescing rules and cache
keys; ``repro.launch.pgserve`` is the CLI driver.

    from repro.service import Service
    with Service() as svc:
        svc.add_graph("social", pg)
        res = svc.query("social", "(a:person)-[:follows]->(b:person)")
        futs = [svc.submit("social", p) for p in patterns]  # concurrent
"""
from repro.service.cache import LRUCache
from repro.service.registry import GraphRegistry
from repro.service.scheduler import MicroBatcher, execute_coalesced
from repro.service.service import Service, ServiceConfig

__all__ = [
    "Service",
    "ServiceConfig",
    "GraphRegistry",
    "LRUCache",
    "MicroBatcher",
    "execute_coalesced",
]
