"""Micro-batching scheduler: many small queries → few large fused launches.

Two pieces, both policy-free about caches (the ``Service`` owns those):

* ``execute_coalesced(pg, plans)`` — the coalescing core.  A group of
  compatible plans (same graph, same version, same impl override) has ALL
  of its label mask steps materialized in ONE ``query_any_batched`` call on
  the vertex store and all relationship steps in one call on the edge
  store; on the ``arr`` backend each call is a single
  ``bitmap_query_batched`` device launch — ``(Q, K) @ (K, N)`` with Q the
  total mask count across requests — sharded or not (the shard_map'd
  batched kernel path of ``kernels/bitmap_query/ops.py`` composes
  unchanged).  Each request then runs its own constraint propagation via
  ``execute_plan_with_masks``.  ``list``/``listd`` stores have no batched
  kernel; they fall back to per-request ``execute_plan`` behind the same
  signature, so callers never branch on backend.  Variable-length
  traversal plans (``*`` hops) also run per-request — their propagation
  is a per-plan frontier loop, not a shareable mask launch — while the
  result cache still serves them (keys are the extended canonical text).

  Q varies with load, and the batched entries specialize on it, so mask
  batches are padded to ``bucketed_q(Q)`` with empty queries (all-False
  mask rows → all-False result rows, dropped on distribution): compile
  count stays bounded by ``Q_BUCKETS``, not by every batch size the
  workload produces.

  Bitwise contract: the output list equals ``[execute_plan(pg, p) for p in
  plans]`` exactly, on every backend — the DIP-ARR impls agree bitwise
  (tests/test_query_engine.py), so fusing scan/matvec/kernel-planned steps
  into one matvec launch changes schedules, never masks.

* ``MicroBatcher`` — the concurrency piece: a worker thread drains a queue
  of requests; the first request opens a batching window (``window_ms``)
  and everything arriving inside it (up to ``max_batch``) executes as one
  batch.  The window is ADAPTIVE by default: when the queue is empty at
  dequeue time (an idle service, c=1) the request executes immediately —
  no latency tax for batching that cannot happen — and the window opens
  only under queue pressure, where waiting actually buys coalescing; it
  also CLOSES early once the queue has stayed empty for a short grace
  period (``grace_ms``): coalescible arrivals land µs apart, so a queue
  that stays dry for the grace means every in-flight client is blocked
  on this very batch and the rest of the window would be pure stall.
  Single worker by design: device work serializes anyway, and one consumer
  makes version reads and cache updates race-free.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro.kernels.bitmap_query.ops import bucketed_q
from repro.query import execute_plan, execute_plan_with_masks

__all__ = ["execute_coalesced", "MicroBatcher"]


def _batched_rows(store, values_list: Sequence, impl: Optional[str]) -> List:
    """All OR-queries in ``values_list`` through one ``query_any_batched``
    call, Q padded to the bucket size (pad queries are empty ⇒ zero mask
    rows, sliced off here)."""
    q = len(values_list)
    padded = list(values_list) + [()] * (bucketed_q(q) - q)
    rows = store.query_any_batched(padded, impl=impl)
    return [rows[i] for i in range(q)]


def execute_coalesced(pg, plans: Sequence, *, impl: Optional[str] = None,
                      stats: Optional[Dict[str, int]] = None) -> List:
    """Execute ``plans`` against ``pg``; returns one ``MatchResult`` per
    plan, bitwise-identical to sequential ``execute_plan`` calls.

    ``stats`` (optional mutable dict) is incremented in place:
    ``coalesced_launches`` (batched store calls made), ``coalesced_masks``
    (mask steps that went through them), ``fallback_requests`` (plans that
    ran the sequential path because the backend has no batched kernel),
    ``traversal_fallback_requests`` (variable-length plans, which always
    run per-request: their propagation is a per-plan ``while_loop``/layer
    unroll, not a shareable batched mask launch — see plan.has_traversal).
    """
    out: List = [None] * len(plans)
    trav = [i for i, p in enumerate(plans) if p.has_traversal]
    if trav:
        if stats is not None:
            stats["traversal_fallback_requests"] = (
                stats.get("traversal_fallback_requests", 0) + len(trav))
        for i in trav:
            out[i] = execute_plan(pg, plans[i])
    fixed = [i for i, p in enumerate(plans) if not p.has_traversal]
    if not fixed:
        return out

    n_masks = sum(len(plans[i].mask_steps) for i in fixed)
    if pg.backend != "arr" or n_masks < 2:
        # list/listd: per-request execution behind the same API (their
        # query_any_batched is a host loop — batching buys nothing); tiny
        # arr groups: a fused launch would fuse one mask, skip the ceremony
        if stats is not None and pg.backend != "arr":
            stats["fallback_requests"] = stats.get("fallback_requests", 0) + len(fixed)
        for i in fixed:
            out[i] = execute_plan(pg, plans[i])
        return out

    node_jobs = []  # (plan index, slot, values)
    edge_jobs = []
    for i in fixed:
        for s in plans[i].mask_steps:
            (node_jobs if s.kind == "node" else edge_jobs).append((i, s.slot, s.values))

    label_masks: Dict[int, Dict[int, object]] = {i: {} for i in fixed}
    rel_masks: Dict[int, Dict[int, object]] = {i: {} for i in fixed}
    launches = 0
    if node_jobs:
        rows = _batched_rows(pg._vstore, [j[2] for j in node_jobs], impl)
        for (i, slot, _), row in zip(node_jobs, rows):
            label_masks[i][slot] = row
        launches += 1
    if edge_jobs:
        rows = _batched_rows(pg._estore, [j[2] for j in edge_jobs], impl)
        for (i, slot, _), row in zip(edge_jobs, rows):
            rel_masks[i][slot] = row
        launches += 1
    if stats is not None:
        stats["coalesced_launches"] = stats.get("coalesced_launches", 0) + launches
        stats["coalesced_masks"] = stats.get("coalesced_masks", 0) + n_masks

    for i in fixed:
        out[i] = execute_plan_with_masks(pg, plans[i], label_masks[i], rel_masks[i])
    return out


class MicroBatcher:
    """Queue + worker thread turning a request stream into batches.

    ``execute_batch(requests)`` is the owner's callback (the ``Service``
    groups by graph/version there); it must never raise — per-request
    errors belong on the requests' futures.  ``submit`` after ``close``
    raises ``RuntimeError``.
    """

    _SENTINEL = object()

    def __init__(self, execute_batch: Callable[[List], None], *,
                 max_batch: int = 32, window_ms: float = 2.0,
                 adaptive: bool = True, grace_ms: float = 0.25,
                 metrics=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be ≥ 1, got {max_batch}")
        self._execute_batch = execute_batch
        self.max_batch = max_batch
        self.window_s = window_ms / 1e3
        self.adaptive = adaptive
        self.grace_s = grace_ms / 1e3
        # optional obs.MetricsRegistry: batch occupancy + window wait
        # histograms (docs/ARCHITECTURE.md §13); instruments are created
        # here once so the worker loop never enters the registry lock
        self._m_occupancy = self._m_wait = None
        if metrics is not None:
            from repro.obs.metrics import SIZE_BUCKETS

            self._m_occupancy = metrics.histogram(
                "pg_sched_batch_occupancy",
                "requests per executed micro-batch", buckets=SIZE_BUCKETS)
            self._m_wait = metrics.histogram(
                "pg_sched_window_wait_ms",
                "batch-window wait from first dequeue to execution")
        self._queue: "queue.Queue" = queue.Queue()
        self._closed = False
        self._lifecycle = threading.Lock()  # orders submit vs close: nothing
        # can land behind the shutdown sentinel and silently never execute
        self._worker = threading.Thread(
            target=self._loop, name="pgserve-scheduler", daemon=True
        )
        self._worker.start()

    @property
    def closed(self) -> bool:
        return self._closed

    def submit(self, request) -> None:
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._queue.put(request)

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Drain-then-stop: requests enqueued before close still execute."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
            self._queue.put(self._SENTINEL)
        self._worker.join(timeout=timeout)

    # ---------------------------------------------------------------- worker
    def _loop(self) -> None:
        while True:
            first = self._queue.get()
            if first is self._SENTINEL:
                return
            batch = [first]
            stop = False
            t_first = time.monotonic()
            # adaptive window: an empty queue means nothing can coalesce —
            # skip the window entirely (c=1 pays zero batching latency);
            # a non-empty queue means pressure, so the window opens and
            # late arrivals join the batch
            open_window = not (self.adaptive and self._queue.empty())
            deadline = time.monotonic() + (self.window_s if open_window else 0.0)
            while open_window and len(batch) < self.max_batch:
                # clamp: under load the deadline may already be in the past,
                # and a negative timeout must never reach the queue wait
                remaining = max(0.0, deadline - time.monotonic())
                try:
                    # remaining == 0 (window_ms=0 or expired) still drains
                    # whatever is already queued, without blocking
                    if remaining == 0.0:
                        req = self._queue.get_nowait()
                    elif self.adaptive:
                        # arrivals that will coalesce land µs apart; a
                        # queue that stays empty for a full grace period
                        # means nothing else is coming this window (a
                        # closed-loop client set is blocked on THIS batch)
                        # — execute instead of burning the rest of it
                        req = self._queue.get(
                            timeout=min(remaining, self.grace_s))
                    else:
                        req = self._queue.get(timeout=remaining)
                except queue.Empty:
                    break
                if req is self._SENTINEL:
                    stop = True
                    break
                batch.append(req)
            if self._m_occupancy is not None:
                self._m_occupancy.observe(len(batch))
                self._m_wait.observe((time.monotonic() - t_first) * 1e3)
            try:
                self._execute_batch(batch)
            except Exception as e:  # noqa: BLE001 — keep the worker alive
                # the callback contract says "never raise"; if it does,
                # fail the batch's futures instead of hanging their clients
                for req in batch:
                    fut = getattr(req, "future", None)
                    if fut is not None and not fut.done():
                        fut.set_exception(e)
            if stop:
                return
