"""Overlay subsystem: LSM-style delta write path, snapshots, CoW views.

See README.md in this directory and docs/ARCHITECTURE.md §11.

Import layering: ``overlay.delta`` is pure numpy (core imports it);
``overlay.views`` and ``overlay.compactor`` import core (PropGraph reaches
them through lazy imports in ``snapshot``/``fork``/``compact``).
"""
from repro.overlay.delta import (AttrDelta, EdgeDelta, MutationEvent,
                                 overlaps, pattern_refs)

__all__ = [
    "AttrDelta",
    "EdgeDelta",
    "MutationEvent",
    "pattern_refs",
    "overlaps",
    "clone_propgraph",
    "compact_propgraph",
    "Compactor",
]


def __getattr__(name):
    # lazy: these pull in core.property_graph (heavier import chain)
    if name == "clone_propgraph":
        from repro.overlay.views import clone_propgraph
        return clone_propgraph
    if name in ("compact_propgraph", "Compactor"):
        from repro.overlay import compactor
        return getattr(compactor, name)
    raise AttributeError(name)
