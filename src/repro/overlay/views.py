"""Snapshots and copy-on-write views over a PropGraph (ARCHITECTURE §11).

Both are the same structural-sharing clone; the only difference is the
``frozen`` bit:

* ``pg.snapshot()``  → frozen clone.  Pins (base store @ version, frozen
  delta chain); every mutator raises.  Long-running analytics read it while
  writes keep landing on the parent.
* ``pg.fork()``      → writable clone.  A View = (base graph @ snapshot,
  private overlay): what-if mutations land in the clone's own delta buffers
  and tombstones, sharing the parent's device-resident base shards.

Sharing is safe because every heavyweight piece is immutable or replaced
functionally by the mutators, never mutated in place:

  shared by reference   base DIGraph (+ placed shards), sealed DIP stores,
                        ``_host`` stash, ``_counts``, ``_base_keys``, typed
                        property columns (jax arrays; updates build new
                        arrays), tombstone arrays (copy-on-write reassign),
                        pair/delta CHUNK arrays
  private per clone     chunk LISTS (appends diverge), delta index dicts,
                        AttributeMap (interning mutates), props dicts,
                        mutation hooks, effective-graph cache
"""
from __future__ import annotations

import threading

from repro.core.attr_map import AttributeMap  # noqa: F401  (re-export site)
from repro.core.property_graph import PropGraph

__all__ = ["clone_propgraph"]


def clone_propgraph(pg: PropGraph, *, frozen: bool) -> PropGraph:
    # the parent's write lock keeps the multi-field read consistent — a
    # concurrent mutator or background compaction cannot hand us a torn
    # (new graph, old stores) pin; the clone is its own write domain and
    # gets a fresh lock
    with pg._write_lock:
        c = PropGraph.__new__(PropGraph)
        c.backend = pg.backend
        c.mesh = pg.mesh
        c.graph = pg.graph
        c._vstore = pg._vstore.clone() if pg._vstore is not None else None
        c._estore = pg._estore.clone() if pg._estore is not None else None
        c.vertex_props = dict(pg.vertex_props)
        c.edge_props = dict(pg.edge_props)
        c.version = pg.version
        c.last_mutation = None
        c._mutation_hooks = []  # observers watch the parent, not its views
        c._delta_edges = (pg._delta_edges.frozen_copy()
                          if pg._delta_edges is not None else None)
        c._dead_v = pg._dead_v  # copy-on-write: mutators reassign, never edit
        c._dead_e = pg._dead_e
        c._eff_cache = None
        c._frozen = frozen
        c._write_lock = threading.RLock()
        return c
