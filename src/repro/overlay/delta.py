"""Delta buffers — the overlay subsystem's LSM-style write path (ARCHITECTURE §11).

The paper's DIP stores are bulk-built and read-mostly: every mutator used
to rebuild a dense host store and re-place it, O(rebuild) per write batch.
The overlay turns each store into a two-level LSM pair:

    sealed base (dense DIP store / sharded placement, immutable)
      + delta   (small append-only host buffers, this module)

Writes append to the delta in O(batch); queries union the sealed base's
mask with a scatter over the delta (``base_mask | delta_mask``), composed
BEFORE propagation so the frontier engine and the executor never see the
split.  A background compactor (``repro.overlay.compactor``) merges the
delta back into the base past a size threshold.

Everything here is host-side numpy and append-only: chunks are never
mutated after they are appended, so a *frozen copy* (shallow copy of the
chunk lists) is a complete, immutable snapshot of the delta chain — the
structural-sharing primitive ``PropGraph.snapshot()`` / ``fork()`` are
built on (``repro.overlay.views``).

``MutationEvent`` is the cache-invalidation contract change that rides
along: each mutator publishes WHICH attribute values / property names a
write touched, so the service purges only overlapping cached results —
a result cached under snapshot S stays live across writes that only grew
the delta chain past S.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["AttrDelta", "EdgeDelta", "MutationEvent", "pattern_refs", "overlaps"]


def pair_keys(ents: np.ndarray, atts: np.ndarray) -> np.ndarray:
    """Fused (entity, attribute) sort keys — both ids are < 2**31, so the
    packed int64 is collision-free for any store this framework builds."""
    return (ents.astype(np.int64) << 31) | atts.astype(np.int64)


class AttrDelta:
    """Append-only (entity, attribute) pair buffer over one DIP store.

    Chunks are immutable once appended; ``frozen_copy`` shares them.  The
    delta answers the same OR-query as the base store — ``mask(ids, out_n)``
    scatters the matching entities — and carries EXACT selectivity stats
    (``counts`` dedupes within the delta and against the base's key set, so
    ``attr_counts`` stays the planner's exact statistic, never an estimate).
    """

    def __init__(self):
        self._ents: List[np.ndarray] = []
        self._atts: List[np.ndarray] = []
        self._size = 0
        self._cat: Optional[Tuple[np.ndarray, np.ndarray]] = None

    @property
    def size(self) -> int:
        return self._size

    def append(self, ents: np.ndarray, atts: np.ndarray) -> None:
        ents = np.asarray(ents, np.int32).ravel()
        if ents.size == 0:
            return
        self._ents.append(ents)
        self._atts.append(np.asarray(atts, np.int32).ravel())
        self._size += ents.size
        self._cat = None

    def cat(self) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenated (entities, attributes) — cached until the next append."""
        if self._cat is None:
            if self._ents:
                self._cat = (np.concatenate(self._ents),
                             np.concatenate(self._atts))
            else:
                self._cat = (np.zeros(0, np.int32), np.zeros(0, np.int32))
        return self._cat

    def mask(self, attr_ids: np.ndarray, out_n: int) -> np.ndarray:
        """(out_n,) bool — entities holding ANY of ``attr_ids`` in the delta."""
        out = np.zeros(out_n, dtype=bool)
        if self._size:
            ents, atts = self.cat()
            sel = np.isin(atts, attr_ids)
            if sel.any():
                out[ents[sel]] = True
        return out

    def mask_words(self, attr_ids: np.ndarray, out_n: int) -> np.ndarray:
        """Packed form of :meth:`mask`: (ceil(out_n/32),) uint32 word mask,
        little-endian bit order — scatters single-bit ORs directly into
        words so the overlay algebra ``base | delta ∧ ~tombstones`` stays
        in word space.  Tail padding bits stay zero (only in-range
        entities are scattered)."""
        from repro.core import bitplane

        out = np.zeros(bitplane.n_words(out_n), np.uint32)
        if self._size:
            ents, atts = self.cat()
            sel = np.isin(atts, attr_ids)
            if sel.any():
                e = ents[sel]
                np.bitwise_or.at(out, e >> 5, np.uint32(1) << (e & 31))
        return out

    def counts(self, k: int, base_keys: Optional[np.ndarray]) -> np.ndarray:
        """(k,) int64 per-attribute counts of pairs the delta ADDS: deduped
        within the delta and against ``base_keys`` (the sealed base's sorted
        pair keys), so base + delta counts are exact."""
        out = np.zeros(k, np.int64)
        if not self._size:
            return out
        ents, atts = self.cat()
        keys = np.unique(pair_keys(ents, atts))
        if base_keys is not None and base_keys.size:
            pos = np.searchsorted(base_keys, keys)
            pos = np.clip(pos, 0, base_keys.size - 1)
            keys = keys[base_keys[pos] != keys]
        if keys.size:
            out += np.bincount((keys & 0x7FFFFFFF).astype(np.int64), minlength=k)
        return out

    def frozen_copy(self) -> "AttrDelta":
        """Immutable-prefix snapshot: shares the (never-mutated) chunks;
        later appends to the parent grow only the parent's chunk list."""
        c = AttrDelta()
        c._ents = list(self._ents)
        c._atts = list(self._atts)
        c._size = self._size
        c._cat = self._cat
        return c


class EdgeDelta:
    """Append-only structural edge buffer: (src, dst) internal-id chunks.

    Delta edges get GLOBAL edge ids ``m_base + position`` — attribute and
    property writes address them uniformly with base edges.  ``append``
    dedupes within the delta (the DI structure keeps one structural edge
    per (u, v); callers drop ALIVE base duplicates via ``edge_lookup``
    first).  ``size`` counts physical appended edges — a revive (see
    ``append``'s ``dead`` parameter) orphans its tombstoned predecessor in
    the chunks, so ``size`` can exceed ``len(_index)``.
    """

    def __init__(self, m_base: int):
        self.m_base = m_base
        self._src: List[np.ndarray] = []
        self._dst: List[np.ndarray] = []
        self._index: Dict[Tuple[int, int], int] = {}
        self._n = 0  # physical appended edges == Σ chunk lengths
        self._cat: Optional[Tuple[np.ndarray, np.ndarray]] = None

    @property
    def size(self) -> int:
        return self._n

    def append(self, src: np.ndarray, dst: np.ndarray,
               dead: Optional[np.ndarray] = None) -> int:
        """Add (src, dst) pairs not yet LIVE in the delta; returns how many
        were appended.  ``dead`` (tombstoned global edge ids) marks index
        entries that no longer exist: a key currently mapped to a dead id
        is re-mapped to a fresh id — the revive path ``insert_edges`` uses
        after ``delete_edges``.  The dead physical edge stays in the chunks
        (its tombstone keeps masking it); ``lookup`` answers with the
        latest, live id."""
        src = np.asarray(src, np.int32).ravel()
        dst = np.asarray(dst, np.int32).ravel()
        dead_set = (frozenset(map(int, np.asarray(dead).ravel()))
                    if dead is not None else frozenset())
        ns, nd = [], []
        idx = self._index
        gid = self.m_base + self._n
        for u, v in zip(src.tolist(), dst.tolist()):
            key = (u, v)
            cur = idx.get(key)
            if cur is not None and cur not in dead_set:
                continue
            idx[key] = gid
            gid += 1
            ns.append(u)
            nd.append(v)
        if not ns:
            return 0
        self._src.append(np.asarray(ns, np.int32))
        self._dst.append(np.asarray(nd, np.int32))
        self._n += len(ns)
        self._cat = None
        return len(ns)

    def lookup(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Global edge ids for (src, dst) pairs; -1 where absent.  A revived
        pair answers with its latest (live) id, never the orphaned one."""
        src = np.asarray(src).ravel()
        dst = np.asarray(dst).ravel()
        idx = self._index
        return np.asarray(
            [idx.get((int(u), int(v)), -1) for u, v in zip(src, dst)], np.int32)

    def cat(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._cat is None:
            if self._src:
                self._cat = (np.concatenate(self._src), np.concatenate(self._dst))
            else:
                self._cat = (np.zeros(0, np.int32), np.zeros(0, np.int32))
        return self._cat

    def frozen_copy(self) -> "EdgeDelta":
        c = EdgeDelta(self.m_base)
        c._src = list(self._src)
        c._dst = list(self._dst)
        c._index = dict(self._index)
        c._n = self._n
        c._cat = self._cat
        return c


# --------------------------------------------------------------- invalidation
@dataclasses.dataclass(frozen=True)
class MutationEvent:
    """What one mutation touched — the overlap-based invalidation contract.

    ``structural=True`` (edges inserted/deleted, vertices deleted, rebuild,
    compaction) invalidates every cached result for the graph: unconstrained
    pattern slots match ANY entity, so no attribute overlap test is sound.
    Attribute events carry the touched label/relationship values and
    property names; a cached result dies only if its pattern references one
    of them.
    """

    kind: str
    structural: bool = False
    labels: FrozenSet[str] = frozenset()
    rels: FrozenSet[str] = frozenset()
    props: FrozenSet[str] = frozenset()

    @classmethod
    def structural_event(cls, kind: str) -> "MutationEvent":
        return cls(kind=kind, structural=True)

    @classmethod
    def labels_event(cls, values: Sequence[str]) -> "MutationEvent":
        return cls(kind="labels", labels=frozenset(map(str, np.ravel(values))))

    @classmethod
    def rels_event(cls, values: Sequence[str]) -> "MutationEvent":
        return cls(kind="rels", rels=frozenset(map(str, np.ravel(values))))

    @classmethod
    def props_event(cls, name: str) -> "MutationEvent":
        return cls(kind="props", props=frozenset((str(name),)))


def pattern_refs(pattern) -> Tuple[FrozenSet[str], FrozenSet[str], FrozenSet[str]]:
    """(labels, relationships, property names) a pattern AST references —
    the result-cache entry's overlap footprint."""
    labels, rels, props = set(), set(), set()
    for node in pattern.nodes:
        labels.update(node.labels)
        props.update(p.name for p in node.predicates)
    for edge in pattern.edges:
        rels.update(edge.rels)
        props.update(p.name for p in edge.predicates)
    return frozenset(labels), frozenset(rels), frozenset(props)


def overlaps(event: MutationEvent,
             refs: Tuple[FrozenSet[str], FrozenSet[str], FrozenSet[str]]) -> bool:
    """Does ``event`` touch anything the cached pattern reads?"""
    if event.structural:
        return True
    labels, rels, props = refs
    return bool(event.labels & labels or event.rels & rels
                or event.props & props)
