"""Overlay compaction — the LSM merge step (ARCHITECTURE §11).

``compact_propgraph`` folds a graph's whole overlay (delta edges, delta
attribute pairs, vertex/edge tombstones) into fresh sealed base stores, as
if the surviving data had been bulk-ingested from scratch: same ``build_di``
sort, same pair insertion order, same attribute-map ordering — so
post-compaction ``match()`` / ``khop()`` / ``components()`` are
bitwise-identical to a from-scratch build.

``Compactor`` is the background policy thread: it sweeps a service
registry's graphs and compacts any writable graph whose ``overlay_size()``
crossed the threshold, keeping the read-amplification of the delta union
bounded while writes stream in.  Snapshots (frozen views) are never
compacted — their pinned delta chain IS their contract.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import GLOBAL as _OBS
from repro.obs.metrics import enabled as _obs_enabled

from repro.core import dip_shard
from repro.core.attr_map import AttributeMap
from repro.core.di import build_di, edge_lookup
from repro.core.property_graph import PropGraph, _AttrStore

__all__ = ["compact_propgraph", "Compactor"]


def compact_propgraph(pg: PropGraph) -> PropGraph:
    """Merge overlay into base, in place on ``pg`` (caller bumps version).

    Host-side throughout: gather the full effective state FIRST (so nothing
    is lost when stores are swapped), rebuild the DI structure from the
    surviving original-id edge list, then remap attribute pairs and typed
    columns through the old→new internal-id maps.

    Runs under the graph's write lock (``PropGraph.compact`` takes it, as
    does every mutator), so no mutation can land between the gather and the
    swap and be discarded.  Lock-free readers may observe the swap torn;
    the version bump that follows makes the service retry them.
    """
    g_eff = pg._require_graph()
    base = pg.graph
    nm_old = np.asarray(base.node_map)
    src = np.asarray(g_eff.src)
    dst = np.asarray(g_eff.dst)
    m_eff = len(src)

    alive_e = np.ones(m_eff, dtype=bool)
    if pg._dead_e is not None and pg._dead_e.size:
        alive_e[pg._dead_e] = False
    if pg._dead_v is not None:
        av = ~pg._dead_v
        alive_e &= av[src] & av[dst]

    # ---- gather the complete effective state before any swap -------------
    v_ent, v_att = pg._vstore.all_pairs()
    v_values = pg._vstore.amap.values
    e_ent, e_att = pg._estore.all_pairs()
    e_values = pg._estore.amap.values
    vprops = {k: (np.asarray(c), np.asarray(m))
              for k, (c, m) in pg.vertex_props.items()}
    eprops = {k: (np.asarray(c), np.asarray(m))
              for k, (c, m) in pg.edge_props.items()}

    # ---- rebuild structure from surviving original-id edges --------------
    new_g = build_di(nm_old[src[alive_e]], nm_old[dst[alive_e]])
    if pg.mesh is not None:
        new_g = dip_shard.place_graph(new_g, pg.mesh)
    nm_new = np.asarray(new_g.node_map)

    # old internal id → new internal id (−1 = dropped).  The new universe is
    # the surviving edges' endpoint set — dead and detached vertices vanish,
    # exactly as a from-scratch build of the surviving edge list would have it.
    if nm_new.size:
        pos = np.searchsorted(nm_new, nm_old)
        pos_c = np.clip(pos, 0, nm_new.size - 1)
        vmap = np.where(nm_new[pos_c] == nm_old, pos_c, -1).astype(np.int32)
    else:
        vmap = np.full(nm_old.size, -1, np.int32)
    if pg._dead_v is not None:
        vmap[pg._dead_v] = -1

    # old global edge id → new edge id, via endpoints through the new SEG
    new_eid_all = np.full(m_eff, -1, np.int32)
    eu, ev = vmap[src], vmap[dst]
    ok_e = alive_e & (eu >= 0) & (ev >= 0)
    if ok_e.any() and new_g.m > 0:
        new_eid_all[ok_e] = np.asarray(
            edge_lookup(new_g, jnp.asarray(eu[ok_e]), jnp.asarray(ev[ok_e])))

    # ---- attribute stores: replay the pair history remapped --------------
    vs = _AttrStore(pg.backend, new_g.n, mesh=pg.mesh)
    vs.amap = AttributeMap(v_values)  # id order preserved → same masks
    if v_ent.size:
        ne = vmap[v_ent]
        keep = ne >= 0
        if keep.any():
            vs._pairs_e.append(ne[keep].astype(np.int32))
            vs._pairs_a.append(v_att[keep].astype(np.int32))

    es = _AttrStore(pg.backend, max(new_g.m, 1), mesh=pg.mesh)
    es.amap = AttributeMap(e_values)
    if e_ent.size:
        ne = new_eid_all[e_ent]
        keep = ne >= 0
        if keep.any():
            es._pairs_e.append(ne[keep].astype(np.int32))
            es._pairs_a.append(e_att[keep].astype(np.int32))

    # ---- typed columns ---------------------------------------------------
    new_vprops = {}
    if vprops:
        inv = np.searchsorted(nm_old, nm_new)  # nm_new ⊆ nm_old: exact hits
        for name, (col, msk) in vprops.items():
            new_vprops[name] = pg._place_column(col[inv], msk[inv])
    new_eprops = {}
    for name, (col, msk) in eprops.items():
        c = np.zeros(m_eff, col.dtype)
        c[:len(col)] = col  # columns may predate the delta edges
        mm = np.zeros(m_eff, dtype=bool)
        mm[:len(msk)] = msk
        nc = np.zeros(new_g.m, col.dtype)
        nmk = np.zeros(new_g.m, dtype=bool)
        okc = new_eid_all >= 0
        nc[new_eid_all[okc]] = c[okc]
        nmk[new_eid_all[okc]] = mm[okc]
        new_eprops[name] = pg._place_column(nc, nmk)

    # ---- swap (caller sets last_mutation + bumps version) ----------------
    pg.graph = new_g
    pg._vstore = vs
    pg._estore = es
    pg.vertex_props = new_vprops
    pg.edge_props = new_eprops
    pg._delta_edges = None
    pg._dead_v = None
    pg._dead_e = None
    pg._eff_cache = None
    return pg


class Compactor(threading.Thread):
    """Background merge policy: sweep a registry, compact writable graphs
    whose overlay crossed ``threshold`` entries.

    Safe against concurrent WRITERS because ``PropGraph.compact()`` and
    every mutator serialize on the graph's write lock — a client write can
    never land inside the gather→rebuild→swap window and be discarded by
    the swap.  Concurrent READERS need no lock: the service's
    ``_serve_group`` retries executions whose graph version moved
    underneath them, so a compaction landing mid-query is
    indistinguishable from any other write.  ``sweep()`` is callable
    directly for deterministic tests.

    Failures are never silent: a per-graph compaction error is counted
    (``errors``/``last_error``, surfaced through ``Service.stats()``) and
    after ``MAX_FAILURES`` consecutive failures the graph is skipped — a
    deterministically-failing graph cannot pin the thread in a hot retry
    loop; its counter resets if a later manual ``compact()`` drains the
    overlay or a sweep succeeds.
    """

    MAX_FAILURES = 3  # consecutive per-graph failures before it is skipped

    def __init__(self, registry, threshold: int, interval: float = 0.05):
        super().__init__(daemon=True, name="overlay-compactor")
        self._registry = registry
        self.threshold = threshold
        self.interval = interval
        self.compactions = 0
        self.errors = 0
        self.last_error: Optional[str] = None
        self._failures: Dict[str, int] = {}  # graph name → consecutive failures
        self._stop_evt = threading.Event()

    def sweep(self) -> int:
        t0 = time.perf_counter()
        done = 0
        for name in self._registry.names():
            try:
                pg = self._registry.get(name)
            except KeyError:
                continue  # dropped between names() and get()
            if pg is None or getattr(pg, "_frozen", False):
                continue
            overlay = pg.overlay_size()
            if overlay < self.threshold:
                # overlay below threshold — if it previously failed here,
                # something (a manual compact) drained it: forgive it
                self._failures.pop(name, None)
                continue
            if self._failures.get(name, 0) >= self.MAX_FAILURES:
                continue  # repeatedly failing graph: stop burning CPU on it
            if _obs_enabled():
                _OBS.histogram(
                    "pg_compact_delta_size",
                    "overlay entries folded per compaction",
                    buckets=(16, 64, 256, 1024, 4096, 16384, 65536),
                ).observe(overlay)
            try:
                pg.compact()
            except Exception as e:  # noqa: BLE001 — isolate to this graph
                self.errors += 1
                self._failures[name] = self._failures.get(name, 0) + 1
                self.last_error = f"{name}: {type(e).__name__}: {e}"
                if _obs_enabled():
                    _OBS.counter("pg_compact_failures",
                                 "background compaction failures").inc()
                continue
            self._failures.pop(name, None)
            done += 1
        self.compactions += done
        if _obs_enabled():
            _OBS.counter("pg_compact_compactions",
                         "background compactions completed").inc(done)
            _OBS.histogram("pg_compact_sweep_ms",
                           "compactor sweep duration").observe(
                (time.perf_counter() - t0) * 1e3)
        return done

    def stats(self) -> Dict[str, object]:
        """Operator-facing counters (``Service.stats()['compactor']``)."""
        return {
            "compactions": self.compactions,
            "errors": self.errors,
            "last_error": self.last_error,
            "failing_graphs": dict(self._failures),
        }

    def run(self) -> None:
        delay = self.interval
        while not self._stop_evt.wait(delay):
            try:
                self.sweep()
                delay = self.interval
            except Exception as e:  # noqa: BLE001 — registry-level failure:
                # record it and back off instead of spinning silently
                self.errors += 1
                self.last_error = f"sweep: {type(e).__name__}: {e}"
                delay = min(max(delay * 2, self.interval), 2.0)

    def stop(self, timeout: Optional[float] = 2.0) -> None:
        self._stop_evt.set()
        if self.is_alive():
            self.join(timeout=timeout)
