"""repro — Property Graphs in Arachne, reproduced as a JAX/TPU framework.

Public API entry points:

    from repro.core import PropGraph, build_di          # the paper
    from repro.graph import pagerank, sample_layers     # analytics substrate
    from repro.kernels import bitmap_query, seg_mm      # Pallas TPU kernels
    from repro.launch.train import run_training         # restartable training
    from repro.launch.mesh import make_production_mesh  # 16×16 / 2×16×16

See README.md for the map, DESIGN.md for the paper→TPU adaptation, and
EXPERIMENTS.md for the dry-run/roofline/perf evidence.
"""

__version__ = "1.0.0"
