"""Selectivity-aware pattern planner.

Decisions, all driven by per-attribute entity counts read off the DIP stores
(``_AttrStore.attr_counts()`` — bitmap row sums / CSR segment lengths, the
stats the paper's stores carry for free):

1. **Chain orientation** (join order for a path): constraint propagation
   starts from the more selective end of the chain, so if the rightmost node
   pattern is estimated smaller than the leftmost the whole pattern is
   reversed (semantically identical; ``Pattern.reversed()``).
2. **Per-mask implementation**:
     * ``arr``:   ``scan`` for tiny attribute universes (k < SCAN_MAX_K,
                  where padding to the MXU wastes lanes), else ``matvec``.
     * ``list``:  single implementation (``list``).
     * ``listd``: ``budget`` (output-sized gather, O(est hits)) when the
                  query is selective — est hits ≤ BUDGET_SEL_CUTOFF·nnz —
                  else ``inverted`` (full O(nnz) scan).
3. **Kernel fusion** (``arr`` only): when ≥2 node slots carry label masks
   (resp. ≥2 edge slots carry relationship masks), they are batched into ONE
   ``bitmap_query`` launch against their store (the batched multi-mask entry
   point) instead of one launch per slot.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.query.ast import Pattern
from repro.query.plan import MaskStep, Plan, PredicateStep

__all__ = [
    "plan_pattern",
    "validate_pattern",
    "SCAN_MAX_K",
    "BUDGET_SEL_CUTOFF",
    "FUSE_MIN_MASKS",
    "MAX_VARLEN",
]

SCAN_MAX_K = 8  # arr: below this attribute-universe size the VPU row scan wins
BUDGET_SEL_CUTOFF = 0.25  # listd: budget gather only pays off for selective queries
FUSE_MIN_MASKS = 2  # arr: batch node-label masks into one kernel launch from here
MAX_VARLEN = 32  # bounded '*lo..hi' hops unroll hi layers; cap the program size


def validate_pattern(pattern: Pattern) -> None:
    """Plan-time pattern checks — everything that can only fail later but
    is knowable NOW, so clients (including remote ``PGClient`` users) get
    the error before paying for execution or a round-trip:

    * string predicate literals: property columns are numeric typed
      columns, so ``{name == "alice"}`` can never compare element-wise —
      rejected here naming the column (it used to parse and only fail at
      execution).
    * traversal bounds the executor cannot run: bounded hops unroll, so
      ``hi`` is capped at ``MAX_VARLEN``; unbounded hops run to a fixed
      point, which supports ``lo ≤ 1`` only (an exact "walks of length
      ≥ lo" test for lo ≥ 2 needs a bounded upper end — any walk shortens
      to ≤ n-1 edges, so ``*lo..{2n}`` is an exact substitute).
    """
    ents = [("vertex", nd) for nd in pattern.nodes]
    ents += [("edge", e) for e in pattern.edges]
    for kind, ent in ents:
        for p in ent.predicates:
            if isinstance(p.value, str):
                raise TypeError(
                    f"{kind} predicate {p.name!r} {p.op} {p.value!r}: string "
                    "comparisons are not supported on typed property columns "
                    "— model string-valued attributes as "
                    "labels/relationships instead"
                )
    for edge in pattern.edges:
        if edge.hi is None and edge.lo > 1:
            raise ValueError(
                f"unbounded traversal {edge._star_text()!r} supports a lower "
                f"bound of at most 1; give an explicit upper bound "
                f"(*{edge.lo}..k) — any walk shortens to < n edges, so "
                "*lo..2n is exact"
            )
        if edge.hi is not None and edge.hi > MAX_VARLEN:
            raise ValueError(
                f"traversal upper bound {edge.hi} exceeds MAX_VARLEN="
                f"{MAX_VARLEN} (bounded hops unroll); use an unbounded "
                "'*' hop for fixed-point reachability"
            )


def _estimate(store, values: Tuple[str, ...], universe: int,
              counts=None) -> Tuple[int, float]:
    """(estimated hit count, selectivity) for an OR query over ``values``.

    Σ of per-attribute counts — exact for disjoint attributes, an upper
    bound under overlap; either way monotone in the true count, which is all
    the ordering decisions need.  ``counts`` overrides the per-attribute
    stats (``plan_pattern`` passes the tombstone-adjusted array so the
    estimates stay exact on graphs with uncompacted deletes).
    """
    if store is None or not values:
        return 0, 0.0
    if counts is None:
        counts = store.attr_counts()
    ids = store.amap.lookup(list(values))
    ids = ids[ids >= 0]
    est = int(counts[ids].sum()) if ids.size else 0
    return est, est / max(universe, 1)


def _choose_impl(
    backend: str, est_count: int, nnz: int, k: int, override: Optional[str]
) -> str:
    if override is not None:
        return override
    if backend == "arr":
        return "scan" if k < SCAN_MAX_K else "matvec"
    if backend == "list":
        return "list"
    # listd: output-sized budget gather vs full inverted-CSR scan
    if nnz > 0 and est_count <= BUDGET_SEL_CUTOFF * nnz:
        return "budget"
    return "inverted"


def plan_pattern(pg, pattern: Pattern, *, impl: Optional[str] = None) -> Plan:
    """Plan ``pattern`` against ``pg`` (a ``repro.core.PropGraph``).

    ``impl`` force-overrides the per-mask implementation choice (the same
    escape hatch ``PropGraph.query_labels(impl=...)`` exposes); fusion is
    disabled under an override so the requested impl actually runs.
    """
    g = pg._require_graph()
    vstore, estore = pg._vstore, pg._estore
    validate_pattern(pattern)

    # tombstone-adjusted stats (computed once per plan): dead entities are
    # masked out of every query result, so they must not inflate the
    # selectivity estimates either
    vcounts = (vstore.attr_counts(dead_ids=pg._dead_vertex_ids())
               if vstore is not None else None)
    ecounts = (estore.attr_counts(dead_ids=pg._dead_edge_ids())
               if estore is not None else None)

    # -- 1. chain orientation: start from the more selective end ------------
    reversed_chain = False
    if pattern.hops >= 1:
        first, _ = _estimate(vstore, pattern.nodes[0].labels, g.n, vcounts)
        last, _ = _estimate(vstore, pattern.nodes[-1].labels, g.n, vcounts)
        first = first if pattern.nodes[0].labels else g.n
        last = last if pattern.nodes[-1].labels else g.n
        if last < first:
            pattern = pattern.reversed()
            reversed_chain = True

    # -- 2. per-slot mask steps with impl choice ----------------------------
    mask_steps = []
    predicate_steps = []
    for slot, node in enumerate(pattern.nodes):
        if node.labels:
            est, sel = _estimate(vstore, node.labels, g.n, vcounts)
            # stats-only read: nnz comes off attr_counts, so planning never
            # materializes a store (mesh mode would otherwise build a dense
            # device copy just to read its size)
            chosen = _choose_impl(pg.backend, est, vstore.nnz, vstore.k, impl)
            mask_steps.append(
                MaskStep(
                    kind="node",
                    slot=slot,
                    values=node.labels,
                    impl=chosen,
                    est_count=est,
                    est_selectivity=sel,
                )
            )
        for pred in node.predicates:
            predicate_steps.append(PredicateStep(kind="node", slot=slot, predicate=pred))
    for slot, edge in enumerate(pattern.edges):
        if edge.rels:
            est, sel = _estimate(estore, edge.rels, g.m, ecounts)
            chosen = _choose_impl(pg.backend, est, estore.nnz, estore.k, impl)
            mask_steps.append(
                MaskStep(
                    kind="edge",
                    slot=slot,
                    values=edge.rels,
                    impl=chosen,
                    est_count=est,
                    est_selectivity=sel,
                )
            )
        for pred in edge.predicates:
            predicate_steps.append(PredicateStep(kind="edge", slot=slot, predicate=pred))

    # -- 3. fusion: batch arr label/relationship masks, one launch per store
    fused_slots: Tuple[int, ...] = ()
    fused_eslots: Tuple[int, ...] = ()
    if pg.backend == "arr" and impl is None:
        import jax

        fused_impl = "kernel" if jax.default_backend() == "tpu" else "matvec"
        node_mask_slots = [s.slot for s in mask_steps if s.kind == "node"]
        edge_mask_slots = [s.slot for s in mask_steps if s.kind == "edge"]
        if len(node_mask_slots) >= FUSE_MIN_MASKS:
            fused_slots = tuple(node_mask_slots)
        # edge masks batch against THEIR store on the same criterion — they
        # previously always ran standalone even when the plan carried several
        if len(edge_mask_slots) >= FUSE_MIN_MASKS:
            fused_eslots = tuple(edge_mask_slots)
        fused_kinds = (("node",) if fused_slots else ()) + (
            ("edge",) if fused_eslots else ())
        if fused_kinds:
            mask_steps = [
                (
                    dataclasses.replace(s, impl=fused_impl, fused=True)
                    if s.kind in fused_kinds
                    else s
                )
                for s in mask_steps
            ]

    return Plan(
        pattern=pattern,
        mask_steps=tuple(mask_steps),
        predicate_steps=tuple(predicate_steps),
        backend=pg.backend,
        reversed_chain=reversed_chain,
        fused_node_slots=fused_slots,
        fused_edge_slots=fused_eslots,
    )
