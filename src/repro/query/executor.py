"""Plan execution — one fused, jitted constraint-propagation pipeline.

Execution in three stages:

1. **Mask materialization** (host-orchestrated, device-executed): every
   planned attribute mask runs through the DIP store with the planner's
   chosen impl; ``arr`` node-label masks marked ``fused`` go through the
   batched ``bitmap_query`` entry in ONE launch.  Predicate masks come off
   the typed property columns.
2. **Local consistency**: per hop, an edge survives iff its own mask is set
   and both endpoint candidate masks are set (the §VI mask-intersection
   contract, directional — ``induce_edge_mask`` generalized per endpoint).
3. **Chain propagation** (single jit, static hop structure): a forward pass
   computes per-position reachable sets, a backward pass prunes to vertices
   /edges that participate in at least one COMPLETE match of the pattern —
   the ``repro.traverse`` frontier step run once in each direction instead
   of k times in one.  Variable-length hops (``-[:r*lo..hi]->``, ``*``)
   expand through the same step: bounded hops unroll ``hi`` exact-length
   frontier layers in each direction and combine them (walk-length algebra
   below); unbounded hops run the frontier to a fixed point
   (``while_loop``, ≤ n rounds).  For a var hop between slots i and i+1
   with forward layers ``u_s`` (s steps from the forward-complete slot-i
   set) and backward layers ``w_t`` (t reverse steps from the
   backward-complete slot-i+1 set):

     slot-i survivors   = fwd_i ∧ ∪_{L∈[lo,hi]} w_L
     hop edges (alive)  = allowed ∧ ∪_{s+t∈[lo-1,hi-1]} u_s[tail] ∧ w_t[head]
     interior vertices  = ∪_{s,t≥1, lo≤s+t≤hi} u_s ∧ w_t

   Interior vertices are unconstrained by the slot masks (Cypher-style);
   every traversed edge must satisfy the hop's relationship/predicate
   masks.  Matches are WALKS: a traversal may revisit vertices and edges
   (see query/README.md "Variable-length hops").

The result is exact (not an estimate): ``vertex_mask``/``edge_mask`` are
the unions of all full-pattern assignments.

Sharded execution (``PropGraph(mesh=...)``): stages 1–2 run shard-local —
every DIP mask comes off a ``shard_map`` query that touches only the
device's own entity slice (``core.dip_shard``), and predicate masks come
off entity-sharded columns.  At the mask-combination point the per-slot
candidate masks are replicated across the mesh in ONE all-gather
(``_gather_masks``) so the chain propagation's arbitrary src/dst gathers
run collective-free; masks are tiny (1 byte/entity) next to the stores the
shard-local stage avoided streaming.
"""
from __future__ import annotations

import dataclasses
import operator
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplane
from repro.core.di import DIGraph
from repro.core.queries import extract_subgraph, induce_edge_mask_directed
from repro.obs.metrics import GLOBAL as _OBS
from repro.obs.metrics import enabled as _obs_enabled
from repro.query.plan import Plan
from repro.traverse.engine import frontier_step, reach_closure

__all__ = ["MatchResult", "execute_plan", "execute_plan_with_masks"]

# process-global execution accounting (docs/ARCHITECTURE.md §13) —
# resolved once at import; host-side counts only, never a device sync
_M_PLANS = _OBS.counter("pg_exec_plans", "plans run through propagation")
_M_MASKS = _OBS.counter("pg_exec_mask_steps", "attribute mask steps materialized")
_M_FUSED = _OBS.counter(
    "pg_exec_fused_masks", "mask steps that rode a fused batched launch")


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["vertex_mask", "edge_mask", "node_masks", "edge_masks"],
    meta_fields=["plan"],
)
@dataclasses.dataclass(frozen=True)
class MatchResult:
    """Result of ``PropGraph.match``: exact participation masks.

    ``node_masks[i]`` / ``edge_masks[i]`` are per-slot masks in the PLAN's
    chain order (use ``bindings()`` for name-keyed access — variable names
    travel with their slots through any planner reorientation).  For a
    variable-length hop, ``edge_masks[i]`` covers every edge on some
    matched walk of that hop, and interior walk vertices appear in
    ``vertex_mask`` but in no ``node_masks`` slot (they bind no variable).
    Registered as a pytree (masks = leaves) so ``jax.block_until_ready`` /
    ``jit`` compose with results directly.
    """

    vertex_mask: jax.Array  # (n,) bool — vertices in ≥1 full match
    edge_mask: jax.Array  # (m,) bool — edges in ≥1 full match
    node_masks: Tuple[jax.Array, ...]  # per node slot, (n,) bool
    edge_masks: Tuple[jax.Array, ...]  # per edge slot, (m,) bool
    plan: Plan

    def bindings(self) -> Dict[str, jax.Array]:
        """Variable name → participation mask (node vars (n,), edge vars (m,))."""
        out: Dict[str, jax.Array] = {}
        for node, mask in zip(self.plan.pattern.nodes, self.node_masks):
            if node.var:
                out[node.var] = out[node.var] | mask if node.var in out else mask
        for edge, mask in zip(self.plan.pattern.edges, self.edge_masks):
            if edge.var:
                out[edge.var] = out[edge.var] | mask if edge.var in out else mask
        return out

    def n_vertices(self) -> int:
        return int(jnp.sum(self.vertex_mask))

    def n_edges(self) -> int:
        return int(jnp.sum(self.edge_mask))

    def subgraph(self, g: DIGraph):
        """Materialize the matched edges as a fresh DI graph."""
        return extract_subgraph(g, self.edge_mask)

    def expand(self, g: DIGraph, k: int, *, edge_allowed: Optional[jax.Array] = None):
        """NScale-style neighborhood expansion: vertices within ``k`` hops of
        the match, following ``edge_allowed`` (default: every edge)."""
        from repro.graph.typed_algorithms import khop_typed

        seeds = jnp.asarray(np.flatnonzero(np.asarray(self.vertex_mask)), jnp.int32)
        allowed = (
            jnp.ones((g.m,), jnp.bool_) if edge_allowed is None else edge_allowed
        )
        return khop_typed(g, seeds, allowed, k=k)


@partial(jax.jit, static_argnames=("hops",))
def _propagate(
    g: DIGraph,
    cands: Tuple[jax.Array, ...],
    emasks: Tuple[jax.Array, ...],
    hops: Tuple[Tuple[int, int, int], ...],
):
    """Forward/backward chain propagation (static hop structure ⇒ fully
    unrolled, one XLA program for the whole pattern).  ``hops`` carries one
    ``(direction, lo, hi)`` per hop; ``hi == -1`` means unbounded.

    Fixed hops ((d, 1, 1) — the original math):
      forward:  f_0 = c_0;  f_i = heads(A_i ∧ f_{i-1}[tail])
      backward: b_h = f_h;  alive_i = A_i ∧ f_{i-1}[tail] ∧ b_i[head];
                b_{i-1} = tails(alive_i)
    where A_i is the locally-consistent edge set of hop i and tail/head
    follow each hop's direction.  b_i = position-i vertices on a full match;
    alive_i = hop-i edges on a full match.

    Variable-length hops run the module-docstring walk algebra through
    ``repro.traverse.frontier_step``: bounded hops keep exact-step frontier
    layers in both directions; unbounded hops keep the two fixed-point
    closures.  Interior walk vertices are returned separately (they belong
    to no slot) and union into the vertex mask only.
    """
    h = len(hops)
    ends = [(g.src, g.dst) if d == 1 else (g.dst, g.src) for d, _, _ in hops]

    fwd = [cands[0]]
    local = [None] * h  # fixed hops: locally-consistent edge sets
    flayers = [None] * h  # bounded var hops: forward exact-step layers
    fclosure = [None] * h  # unbounded var hops: forward closure
    for i, (d, lo, hi) in enumerate(hops):
        tail, head = ends[i]
        if (lo, hi) == (1, 1):
            local[i] = induce_edge_mask_directed(
                g, cands[i], cands[i + 1], emasks[i], d)
            a = local[i] & fwd[i][tail]
            fwd.append(jnp.zeros_like(cands[i + 1]).at[head].max(a))
        elif hi == -1:
            U = reach_closure(g, fwd[i], emasks[i], direction=d)
            fclosure[i] = U
            reach = U if lo == 0 else frontier_step(g, U, emasks[i], direction=d)
            fwd.append(cands[i + 1] & reach)
        else:
            layers = [fwd[i]]
            for _ in range(hi):
                layers.append(frontier_step(g, layers[-1], emasks[i], direction=d))
            flayers[i] = layers
            reach = layers[lo]
            for L in range(lo + 1, hi + 1):
                reach = reach | layers[L]
            fwd.append(cands[i + 1] & reach)

    back = [None] * (h + 1)
    back[h] = fwd[h]
    alive = [None] * h
    interiors = []  # var-hop walk vertices that belong to no slot
    for i in range(h - 1, -1, -1):
        d, lo, hi = hops[i]
        tail, head = ends[i]
        if (lo, hi) == (1, 1):
            al = local[i] & fwd[i][tail] & back[i + 1][head]
            alive[i] = al
            back[i] = jnp.zeros_like(fwd[i]).at[tail].max(al)
        elif hi == -1:
            U = fclosure[i]
            W = reach_closure(g, back[i + 1], emasks[i], direction=-d)
            alive[i] = emasks[i] & U[tail] & W[head]
            back[i] = fwd[i] & (
                W if lo == 0 else frontier_step(g, W, emasks[i], direction=-d))
            interiors.append(
                frontier_step(g, U, emasks[i], direction=d)
                & frontier_step(g, W, emasks[i], direction=-d)
            )
        else:
            u = flayers[i]
            w = [back[i + 1]]
            for _ in range(hi):
                w.append(frontier_step(g, w[-1], emasks[i], direction=-d))
            # prefix unions keep the per-s window unions O(1) whenever the
            # window reaches down to its base (always true for lo ≤ 1, the
            # common patterns) — without them this pass is O(hi²) masks,
            # the program-size blowup MAX_VARLEN exists to bound
            pre0 = [w[0]]  # pre0[j] = w[0] | … | w[j]
            for t in range(1, hi + 1):
                pre0.append(pre0[-1] | w[t])
            pre1 = [None, w[1]] if hi >= 1 else [None]  # pre1[j] = w[1] | … | w[j]
            for t in range(2, hi + 1):
                pre1.append(pre1[-1] | w[t])

            def w_union(a, b):  # ∪ w[a..b], 0 ≤ a ≤ b ≤ hi
                if a == 0:
                    return pre0[b]
                if a == 1:
                    return pre1[b]
                out = w[a]
                for t in range(a + 1, b + 1):
                    out = out | w[t]
                return out

            back[i] = fwd[i] & w_union(lo, hi)
            acc = jnp.zeros((g.m,), jnp.bool_)
            for s in range(hi):
                hu = w_union(max(0, lo - 1 - s), hi - 1 - s)
                acc = acc | (u[s][tail] & hu[head])
            alive[i] = emasks[i] & acc
            inter = jnp.zeros((g.n,), jnp.bool_)
            for s in range(1, hi):
                a, b = max(1, lo - s), hi - s
                if a <= b:
                    inter = inter | (u[s] & w_union(a, b))
            interiors.append(inter)

    vmask = back[0]
    for b in back[1:]:
        vmask = vmask | b
    for x in interiors:
        vmask = vmask | x
    if h:
        emask = alive[0]
        for a in alive[1:]:
            emask = emask | a
    else:
        emask = jnp.zeros((g.m,), jnp.bool_)
    return vmask, emask, tuple(back), tuple(alive)


def _fused_step_sets(plan: Plan):
    """The (node steps, edge steps) riding the fused batched launches, plus
    the fused slot-id sets — shared by the bool and packed materializers so
    the ``pg_exec_fused_masks`` accounting is identical on both paths."""
    fused_n = set(plan.fused_node_slots)
    fused_e = set(getattr(plan, "fused_edge_slots", ()))
    nsteps = [s for s in plan.mask_steps if s.kind == "node" and s.slot in fused_n]
    esteps = [s for s in plan.mask_steps if s.kind == "edge" and s.slot in fused_e]
    if _obs_enabled():
        _M_MASKS.inc(len(plan.mask_steps))
        _M_FUSED.inc(len(nsteps) + len(esteps))
    return fused_n, fused_e, nsteps, esteps


def _materialize_masks(pg, plan: Plan) -> Tuple[Dict[int, jax.Array], Dict[int, jax.Array]]:
    """Run every planned attribute mask, fusing batched slots into one call.

    Node AND edge slots marked fused each coalesce into one
    ``query_any_batched`` launch against their store (node and edge stores
    are distinct (K, N) planes, so that is the launch floor: two)."""
    node_masks: Dict[int, jax.Array] = {}
    edge_masks: Dict[int, jax.Array] = {}

    fused_n, fused_e, fused_nsteps, fused_esteps = _fused_step_sets(plan)
    if fused_nsteps:
        stacked = pg._vstore.query_any_batched(
            [s.values for s in fused_nsteps], impl=fused_nsteps[0].impl
        )
        for s, row in zip(fused_nsteps, stacked):
            node_masks[s.slot] = row
    if fused_esteps:
        stacked = pg._estore.query_any_batched(
            [s.values for s in fused_esteps], impl=fused_esteps[0].impl
        )
        for s, row in zip(fused_esteps, stacked):
            edge_masks[s.slot] = row

    for s in plan.mask_steps:
        if s.kind == "node" and s.slot not in fused_n:
            node_masks[s.slot] = pg._vstore.query_any(s.values, impl=s.impl)
        elif s.kind == "edge" and s.slot not in fused_e:
            edge_masks[s.slot] = pg._estore.query_any(s.values, impl=s.impl)
    return node_masks, edge_masks


def _materialize_mask_words(pg, plan: Plan) -> Tuple[Dict[int, jax.Array], Dict[int, jax.Array]]:
    """Packed analog of ``_materialize_masks``: every mask stays a uint32
    word vector off the stores' packed planes — no bool materialization."""
    node_words: Dict[int, jax.Array] = {}
    edge_words: Dict[int, jax.Array] = {}

    fused_n, fused_e, fused_nsteps, fused_esteps = _fused_step_sets(plan)
    if fused_nsteps:
        stacked = pg._vstore.query_any_batched_words(
            [s.values for s in fused_nsteps], impl=fused_nsteps[0].impl
        )
        for s, row in zip(fused_nsteps, stacked):
            node_words[s.slot] = row
    if fused_esteps:
        stacked = pg._estore.query_any_batched_words(
            [s.values for s in fused_esteps], impl=fused_esteps[0].impl
        )
        for s, row in zip(fused_esteps, stacked):
            edge_words[s.slot] = row

    for s in plan.mask_steps:
        if s.kind == "node" and s.slot not in fused_n:
            node_words[s.slot] = pg._vstore.query_any_words(s.values, impl=s.impl)
        elif s.kind == "edge" and s.slot not in fused_e:
            edge_words[s.slot] = pg._estore.query_any_words(s.values, impl=s.impl)
    return node_words, edge_words


def _gather_masks(masks, mesh):
    """The sharded pipeline's single all-gather: replicate the combined
    per-slot masks across the mesh in ONE batched transfer."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    return list(jax.device_put(list(masks), [rep] * len(masks)))


# predicate ops mirrored from PropGraph._PRED_OPS (plain operator functions;
# kept local so the fused combine needs no property_graph import)
_PRED_FNS = {
    "==": operator.eq, "!=": operator.ne, "<": operator.lt,
    "<=": operator.le, ">": operator.gt, ">=": operator.ge,
}


def _ones_words(n: int) -> jax.Array:
    """Packed all-True mask over ``n`` entities — full words 0xFFFFFFFF,
    tail bits zero (the invariant every word-space AND/OR preserves)."""
    w = bitplane.n_words(n)
    words = jnp.full((w,), 0xFFFFFFFF, jnp.uint32)
    rem = n % bitplane.WORD
    if w and rem:
        words = words.at[-1].set(jnp.uint32((1 << rem) - 1))
    return words


@partial(jax.jit, static_argnames=("n", "m", "vops", "eops"))
def _combine_packed(nwords, ewords, vpreds, epreds, av, ae, *,
                    n: int, m: int, vops, eops):
    """The fused mask-combination launch (tentpole stage 3): predicate
    evaluation, bit-packing, word-space AND with label/relationship words
    and packed tombstone masks, and the SINGLE unpack at the propagation
    boundary — one jitted program instead of one mask op per predicate
    composed through separate dispatches.

    ``nwords[slot]`` / ``ewords[slot]``: packed store words or None
    (unconstrained).  ``vpreds[slot]`` / ``epreds[slot]``: tuples of
    ``(col, valid, value)`` with the matching op names in the static
    ``vops`` / ``eops``.  ``av`` / ``ae``: alive bool masks or None.
    """
    av_w = bitplane.pack_mask(av) if av is not None else None
    ae_w = bitplane.pack_mask(ae) if ae is not None else None

    def combine(words, preds, ops, size, alive_w):
        out = words if words is not None else _ones_words(size)
        for (col, valid, value), op in zip(preds, ops):
            pm = valid & _PRED_FNS[op](col, value)
            if int(pm.shape[0]) < size:  # short edge column: pad rows invalid
                pm = jnp.concatenate(
                    [pm, jnp.zeros((size - int(pm.shape[0]),), jnp.bool_)])
            out = out & bitplane.pack_mask(pm)
        if alive_w is not None:
            out = out & alive_w
        return bitplane.unpack_mask(out, size)

    cands = tuple(
        combine(nwords[i], vpreds[i], vops[i], n, av_w)
        for i in range(len(nwords)))
    emasks = tuple(
        combine(ewords[i], epreds[i], eops[i], m, ae_w)
        for i in range(len(ewords)))
    return cands, emasks


def _packed_combine_applies(pg) -> bool:
    """The packed end-to-end combine path: single-device arr graphs whose
    stores hold word planes.  Mesh graphs keep the bool combine (their
    masks replicate across devices before propagation anyway) but still
    scan packed planes inside ``dip_shard``."""
    return (
        pg.backend == "arr"
        and getattr(pg, "mesh", None) is None
        and pg._vstore.packed
        and pg._estore.packed
    )


def _execute_plan_packed(pg, plan: Plan) -> MatchResult:
    """Packed execution: store words → fused predicate/alive combine in
    word space → ONE unpack at the propagation boundary."""
    g = pg._require_graph()
    if _obs_enabled():
        _M_PLANS.inc()
    node_words, edge_words = _materialize_mask_words(pg, plan)

    n_slots = len(plan.pattern.nodes)
    e_slots = len(plan.pattern.edges)
    vpreds = [[] for _ in range(n_slots)]
    vops = [[] for _ in range(n_slots)]
    epreds = [[] for _ in range(e_slots)]
    eops = [[] for _ in range(e_slots)]
    for step in plan.predicate_steps:
        # host-side validation (KeyError/ValueError/TypeError fire eagerly,
        # before any launch) + raw column fetch for the fused combine
        col, valid = pg._predicate_parts(
            step.kind, step.predicate.name, step.predicate.op,
            step.predicate.value)
        entry = (col, valid, jnp.asarray(step.predicate.value))
        if step.kind == "node":
            vpreds[step.slot].append(entry)
            vops[step.slot].append(step.predicate.op)
        else:
            epreds[step.slot].append(entry)
            eops[step.slot].append(step.predicate.op)

    av = pg._alive_vertex_mask() if hasattr(pg, "_alive_vertex_mask") else None
    ae = pg._alive_edge_mask() if hasattr(pg, "_alive_edge_mask") else None
    cands, emasks = _combine_packed(
        tuple(node_words.get(i) for i in range(n_slots)),
        tuple(edge_words.get(i) for i in range(e_slots)),
        tuple(map(tuple, vpreds)), tuple(map(tuple, epreds)), av, ae,
        n=g.n, m=g.m,
        vops=tuple(map(tuple, vops)), eops=tuple(map(tuple, eops)))
    return _finish_propagation(pg, plan, g, list(cands), list(emasks))


def execute_plan(pg, plan: Plan) -> MatchResult:
    """Execute ``plan`` against ``pg``; see module docstring for stages."""
    pg._require_graph()  # the documented RuntimeError, before store access
    if _packed_combine_applies(pg):
        return _execute_plan_packed(pg, plan)
    label_masks, rel_masks = _materialize_masks(pg, plan)
    return execute_plan_with_masks(pg, plan, label_masks, rel_masks)


def execute_plan_with_masks(
    pg,
    plan: Plan,
    label_masks: Dict[int, jax.Array],
    rel_masks: Dict[int, jax.Array],
) -> MatchResult:
    """Stages 2–3 of ``execute_plan``, taking PRE-MATERIALIZED attribute
    masks: ``label_masks[slot]`` / ``rel_masks[slot]`` replace the plan's
    ``mask_steps`` outputs (missing slots mean "no attribute constraint").

    This is the service layer's coalescing entry point
    (``src/repro/service/``): a micro-batch of requests materializes ALL
    its label/relationship masks in one ``bitmap_query_batched`` launch,
    then runs each request's propagation here.  Masks must cover the same
    entity universe the plan's own steps would produce — for bitwise parity
    with ``execute_plan``, hand in masks computed from the same stores
    (any DIP-ARR impl; they agree bitwise)."""
    g = pg._require_graph()
    if _obs_enabled():
        _M_PLANS.inc()

    cands = []
    for slot, node in enumerate(plan.pattern.nodes):
        c = label_masks.get(slot, jnp.ones((g.n,), jnp.bool_))
        for step in plan.predicate_steps:
            if step.kind == "node" and step.slot == slot:
                c = c & pg.vertex_predicate_mask(
                    step.predicate.name, step.predicate.op, step.predicate.value
                )
        cands.append(c)

    emasks = []
    for slot, edge in enumerate(plan.pattern.edges):
        e = rel_masks.get(slot, jnp.ones((g.m,), jnp.bool_))
        for step in plan.predicate_steps:
            if step.kind == "edge" and step.slot == slot:
                e = e & pg.edge_predicate_mask(
                    step.predicate.name, step.predicate.op, step.predicate.value
                )
        emasks.append(e)

    # overlay tombstones (docs/ARCHITECTURE.md §11): deleted vertices/edges
    # drop out of EVERY slot — including unconstrained ones, whose all-ones
    # default would otherwise resurrect them — before propagation runs
    av = pg._alive_vertex_mask() if hasattr(pg, "_alive_vertex_mask") else None
    if av is not None:
        cands = [c & av for c in cands]
    ae = pg._alive_edge_mask() if hasattr(pg, "_alive_edge_mask") else None
    if ae is not None:
        emasks = [e & ae for e in emasks]

    return _finish_propagation(pg, plan, g, cands, emasks)


def _finish_propagation(pg, plan: Plan, g: DIGraph, cands, emasks) -> MatchResult:
    """Shared stage-3 tail: mesh replication of the combined per-slot masks
    (no-op single-device), the static-hop chain propagation, and result
    packaging — identical for the bool and packed combine paths."""
    mesh = getattr(pg, "mesh", None)
    if mesh is not None:
        cands = _gather_masks(cands, mesh)
        emasks = _gather_masks(emasks, mesh)

    hops = tuple(
        (e.direction, e.lo, -1 if e.hi is None else e.hi)
        for e in plan.pattern.edges
    )
    vmask, emask, node_masks, alive = _propagate(
        g, tuple(cands), emasks=tuple(emasks), hops=hops)
    return MatchResult(
        vertex_mask=vmask,
        edge_mask=emask,
        node_masks=node_masks,
        edge_masks=alive,
        plan=plan,
    )
