"""Query plans — the bridge between the pattern AST and mask execution.

A ``Plan`` is a flat list of mask-producing steps plus chain metadata.  Each
``MaskStep`` records which DIP implementation the planner chose (`matvec`,
`scan`, `kernel`, `inverted`, `budget`, …) and the selectivity estimate that
drove the choice — ``Plan.describe()`` is what ``PropGraph.explain()``
prints, so the decisions are auditable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.query.ast import Pattern, Predicate

__all__ = ["MaskStep", "PredicateStep", "Plan"]


@dataclasses.dataclass(frozen=True)
class MaskStep:
    """One attribute-store OR-query: slot ``slot`` of the (reoriented) chain.

    ``kind`` is 'node' (label mask over n vertices) or 'edge' (relationship
    mask over m edges).  ``fused`` marks steps the executor batches into a
    single kernel launch instead of running standalone.
    """

    kind: str  # 'node' | 'edge'
    slot: int
    values: Tuple[str, ...]
    impl: str
    est_count: int  # estimated matching entities (Σ per-attribute counts)
    est_selectivity: float  # est_count / entity-universe size
    fused: bool = False

    def describe(self) -> str:
        tag = f"fused-batch[{self.impl}]" if self.fused else self.impl
        return (
            f"{self.kind}[{self.slot}] any{list(self.values)} "
            f"→ impl={tag} (est {self.est_count} hits, "
            f"sel={self.est_selectivity:.4f})"
        )


@dataclasses.dataclass(frozen=True)
class PredicateStep:
    """One typed-column comparison AND-ed into slot ``slot``'s mask."""

    kind: str  # 'node' | 'edge'
    slot: int
    predicate: Predicate

    def describe(self) -> str:
        return f"{self.kind}[{self.slot}] filter {self.predicate.to_text()}"


@dataclasses.dataclass(frozen=True)
class Plan:
    """Executable plan for one pattern.

    ``pattern`` is already reoriented: if ``reversed_chain`` is set the
    planner flipped the user's pattern so constraint propagation starts from
    the more selective end (the chain-join-order decision).
    """

    pattern: Pattern
    mask_steps: Tuple[MaskStep, ...]
    predicate_steps: Tuple[PredicateStep, ...]
    backend: str
    reversed_chain: bool = False
    fused_node_slots: Tuple[int, ...] = ()  # slots batched into one kernel call
    fused_edge_slots: Tuple[int, ...] = ()  # edge slots riding a batched launch

    @property
    def hops(self) -> int:
        return self.pattern.hops

    @property
    def has_traversal(self) -> bool:
        """True when any hop is variable-length (``*`` bounds).  The
        service's coalescer checks this: traversal plans run per-request
        (their propagation is a per-plan ``while_loop``/layer unroll, not
        a shareable batched mask launch)."""
        return any(not e.is_fixed for e in self.pattern.edges)

    def describe(self) -> str:
        lines = [
            f"Plan[{self.backend}] {self.pattern.to_text()}",
            f"  chain: {self.hops} hop(s), "
            + (
                "propagate right→left (reversed: right end more selective)"
                if self.reversed_chain
                else "propagate left→right"
            ),
        ]
        if self.fused_node_slots:
            lines.append(
                f"  fusion: label masks for node slots {list(self.fused_node_slots)} "
                "batched into one bitmap_query kernel launch"
            )
        if self.fused_edge_slots:
            lines.append(
                f"  fusion: relationship masks for edge slots "
                f"{list(self.fused_edge_slots)} batched into one "
                "bitmap_query kernel launch"
            )
        for slot, edge in enumerate(self.pattern.edges):
            if not edge.is_fixed:
                mode = (
                    "fixed-point frontier closure"
                    if edge.hi is None
                    else f"unrolled frontier layers (≤{edge.hi} steps)"
                )
                lines.append(
                    f"  edge[{slot}] traverse {edge._star_text()} → {mode}"
                )
        for s in self.mask_steps:
            lines.append("  " + s.describe())
        for s in self.predicate_steps:
            lines.append("  " + s.describe())
        return "\n".join(lines)
