"""Pattern AST — the declarative layer above the §VI OR-mask queries.

A ``Pattern`` is a linear chain of ``NodePattern``s joined by
``EdgePattern``s (Cypher-lite paths).  Node labels and edge relationship
types keep the paper's OR semantics (``:a|b`` matches either attribute);
``Predicate``s are typed comparisons over the ``PropGraph`` property
columns.  Every node is AND-composed from its label mask and its predicate
masks; the chain itself is an AND across hops (conjunctive path query).

All AST classes are frozen dataclasses with a ``to_text()`` inverse of the
parser, so ``parse(p.to_text()) == p`` round-trips (property-tested).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

__all__ = ["Predicate", "NodePattern", "EdgePattern", "Pattern", "OPS"]

# comparison operators over typed property columns; "=" normalizes to "=="
OPS = ("==", "!=", "<=", ">=", "<", ">")


@dataclasses.dataclass(frozen=True)
class Predicate:
    """``name op value`` over a typed property column (e.g. ``age > 30``)."""

    name: str
    op: str  # one of OPS
    value: Union[int, float, str]

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op must be one of {OPS}, got {self.op!r}")

    def to_text(self) -> str:
        v = self.value
        v_txt = f'"{v}"' if isinstance(v, str) else repr(v)
        return f"{self.name} {self.op} {v_txt}"


@dataclasses.dataclass(frozen=True)
class NodePattern:
    """``(var:labelA|labelB {pred, ...})`` — labels OR'd, predicates AND'd."""

    var: Optional[str] = None
    labels: Tuple[str, ...] = ()
    predicates: Tuple[Predicate, ...] = ()

    def to_text(self) -> str:
        parts = [self.var or ""]
        if self.labels:
            parts.append(":" + "|".join(self.labels))
        if self.predicates:
            parts.append(" {" + ", ".join(p.to_text() for p in self.predicates) + "}")
        return "(" + "".join(parts) + ")"


@dataclasses.dataclass(frozen=True)
class EdgePattern:
    """``-[var:relA|relB {pred, ...}]->`` (direction=1) or ``<-[...]-`` (-1).

    ``direction`` is relative to the pattern's left-to-right reading:
    +1 means the DI edge points left→right, -1 right→left.

    ``lo``/``hi`` are the variable-length bounds (``-[:r*lo..hi]->``):
    the hop matches a walk of L ∈ [lo, hi] edges, every one holding the
    relationship/predicate constraints; intermediate vertices are
    unconstrained.  ``hi=None`` means unbounded (``*`` — executed to a
    fixed point).  The default (1, 1) is a plain fixed hop.
    """

    var: Optional[str] = None
    rels: Tuple[str, ...] = ()
    predicates: Tuple[Predicate, ...] = ()
    direction: int = 1
    lo: int = 1
    hi: Optional[int] = 1

    def __post_init__(self):
        if self.direction not in (1, -1):
            raise ValueError(f"direction must be ±1, got {self.direction}")
        if self.lo < 0:
            raise ValueError(f"traversal bounds must be ≥ 0, got lo={self.lo}")
        if self.hi is not None and self.hi < self.lo:
            raise ValueError(
                f"traversal upper bound below lower: *{self.lo}..{self.hi}")

    @property
    def is_fixed(self) -> bool:
        """True for a plain single hop (no ``*`` traversal)."""
        return self.lo == 1 and self.hi == 1

    def _star_text(self) -> str:
        if self.is_fixed:
            return ""
        if self.hi is None:
            return "*" if self.lo == 1 else f"*{self.lo}.."
        if self.lo == self.hi:
            return f"*{self.lo}"
        return f"*{self.lo}..{self.hi}"

    def to_text(self) -> str:
        parts = [self.var or ""]
        if self.rels:
            parts.append(":" + "|".join(self.rels))
        parts.append(self._star_text())
        if self.predicates:
            parts.append(" {" + ", ".join(p.to_text() for p in self.predicates) + "}")
        body = "[" + "".join(parts) + "]"
        return f"-{body}->" if self.direction == 1 else f"<-{body}-"


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A path pattern: ``nodes[0] edges[0] nodes[1] … edges[h-1] nodes[h]``."""

    nodes: Tuple[NodePattern, ...]
    edges: Tuple[EdgePattern, ...] = ()

    def __post_init__(self):
        if len(self.nodes) != len(self.edges) + 1:
            raise ValueError(
                f"path needs len(nodes) == len(edges)+1, got "
                f"{len(self.nodes)} nodes / {len(self.edges)} edges"
            )

    @property
    def hops(self) -> int:
        return len(self.edges)

    def to_text(self) -> str:
        out = [self.nodes[0].to_text()]
        for e, nd in zip(self.edges, self.nodes[1:]):
            out.append(e.to_text())
            out.append(nd.to_text())
        return "".join(out)

    def reversed(self) -> "Pattern":
        """The same pattern read right-to-left (edge directions flip).

        Semantically identical match set — the planner uses this to start
        constraint propagation from the more selective end.
        """
        nodes = tuple(reversed(self.nodes))
        edges = tuple(
            dataclasses.replace(e, direction=-e.direction) for e in reversed(self.edges)
        )
        return Pattern(nodes=nodes, edges=edges)
