"""Weight-property extraction — the query layer's numeric-column front door
for the weighted analytics (docs/ARCHITECTURE.md §12).

A pattern predicate (``{bytes > 0}``) consumes a typed edge column as a
Boolean mask; the tropical / counting semirings consume the COLUMN ITSELF
as the per-edge ⊗ operand.  ``edge_weight_values`` is that read path:
one typed edge-property column, padded to the effective (base ++ delta)
edge universe, as (f32 values, validity mask).  An edge without the
property (delta edges predating the column, never-assigned base edges)
is NOT traversable under a weighted semiring — there is no sound default
weight — so callers AND the validity mask into their edge filter, which
the differential tests pin as the "property-masked edges" case.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["edge_weight_values"]


def edge_weight_values(pg, name: str) -> Tuple[jax.Array, jax.Array]:
    """(values (m_eff,) f32, valid (m_eff,) bool) for edge property ``name``.

    Columns predating the current delta edges pad with (0, False) — a
    delta edge has no weight until ``update_edge_properties`` assigns one,
    exactly the padding rule ``edge_predicate_mask`` applies to Boolean
    reads of the same column.
    """
    g = pg._require_graph()
    if name not in pg.edge_props:
        raise KeyError(
            f"unknown edge property {name!r}; known: {sorted(pg.edge_props)}")
    col, valid = pg.edge_props[name]
    if int(col.shape[0]) < g.m:
        pad = g.m - int(col.shape[0])
        col = jnp.concatenate([col, jnp.zeros((pad,), col.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), jnp.bool_)])
    return col.astype(jnp.float32), valid
