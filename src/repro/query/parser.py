"""Cypher-lite pattern parser.

Grammar (see README.md in this package for the prose version)::

    pattern := node (edge node)*
    node    := '(' [ident] [':' alts] [props] ')'
    edge    := '-' '[' body ']' '->'  |  '<-' '[' body ']' '-'
    body    := [ident] [':' alts] [props]
    alts    := value ('|' value)*
    props   := '{' pred (',' pred)* '}'
    pred    := ident op literal        ;  op ∈ {=, ==, !=, <, <=, >, >=}
    literal := number | quoted string | bareword

Hand-rolled recursive descent over a regex token stream — no parser
dependency, exact source positions in errors.  ``=`` normalizes to ``==``;
numeric literals become int/float so predicate masks compare natively
against the typed property columns.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple, Union

from repro.query.ast import EdgePattern, NodePattern, Pattern, Predicate

__all__ = ["parse", "ParseError"]


class ParseError(ValueError):
    """Pattern syntax error, with position context."""


# NB ordering: arrows before comparison ops ('->' vs '>'), numbers before
# punct so a signed literal like '-3' beats the lone '-' edge dash.  A '<'
# immediately followed by '-' always reads as an incoming edge, so negative
# literals after '<' need a space: '{age < -3}'.
_TOKEN_RE = re.compile(
    r"""\s*(?:
        (?P<arrow_in>\<\-)        # <-
      | (?P<arrow_out>\-\>)       # ->
      | (?P<op>==|!=|<=|>=|=|<|>)
      | (?P<string>"[^"]*"|'[^']*')
      | (?P<number>[+-]?\d+\.\d*(?:[eE][+-]?\d+)?|[+-]?\.?\d+(?:[eE][+-]?\d+)?)
      | (?P<punct>[()\[\]{}:,|\-])
      | (?P<ident>[A-Za-z_][A-Za-z0-9_.]*)
    )""",
    re.VERBOSE,
)


def _tokenize(text: str) -> List[Tuple[str, str, int]]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None or m.end() == m.start():
            rest = text[pos:].lstrip()
            if not rest:
                break
            raise ParseError(f"unexpected character {rest[0]!r} at position {pos} in {text!r}")
        kind = m.lastgroup
        toks.append((kind, m.group(kind), m.start(kind)))
        pos = m.end()
    return toks


class _Cursor:
    def __init__(self, text: str):
        self.text = text
        self.toks = _tokenize(text)
        self.i = 0

    def peek(self) -> Optional[Tuple[str, str, int]]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> Tuple[str, str, int]:
        tok = self.peek()
        if tok is None:
            raise ParseError(f"unexpected end of pattern in {self.text!r}")
        self.i += 1
        return tok

    def expect(self, value: str) -> None:
        kind, val, pos = self.next()
        if val != value:
            raise ParseError(
                f"expected {value!r} but found {val!r} at position {pos} in {self.text!r}"
            )

    def accept(self, value: str) -> bool:
        tok = self.peek()
        if tok is not None and tok[1] == value:
            self.i += 1
            return True
        return False


def _literal(cur: _Cursor) -> Union[int, float, str]:
    kind, val, pos = cur.next()
    if kind == "string":
        return val[1:-1]
    if kind == "number":
        return float(val) if any(c in val for c in ".eE") else int(val)
    if kind == "ident":
        return val
    raise ParseError(f"expected a literal, found {val!r} at position {pos} in {cur.text!r}")


def _alts(cur: _Cursor) -> Tuple[str, ...]:
    """``a|b|c`` after a ':' — attribute values, OR semantics (§VI)."""
    out = [str(_literal(cur))]
    while cur.accept("|"):
        out.append(str(_literal(cur)))
    return tuple(out)


def _props(cur: _Cursor) -> Tuple[Predicate, ...]:
    if not cur.accept("{"):
        return ()
    preds = []
    while True:
        kind, name, pos = cur.next()
        if kind != "ident":
            raise ParseError(
                f"expected property name, found {name!r} at position {pos} in {cur.text!r}"
            )
        kind, op, pos = cur.next()
        if kind != "op":
            raise ParseError(
                f"expected comparison operator, found {op!r} at position {pos} in {cur.text!r}"
            )
        preds.append(Predicate(name=name, op="==" if op == "=" else op, value=_literal(cur)))
        if cur.accept("}"):
            return tuple(preds)
        cur.expect(",")


def _entity_body(cur: _Cursor) -> Tuple[Optional[str], Tuple[str, ...], Tuple[Predicate, ...]]:
    """Shared interior of node ``(...)`` and edge ``[...]``."""
    var = None
    tok = cur.peek()
    if tok is not None and tok[0] == "ident":
        var = cur.next()[1]
    labels: Tuple[str, ...] = ()
    if cur.accept(":"):
        labels = _alts(cur)
    return var, labels, _props(cur)


def _node(cur: _Cursor) -> NodePattern:
    cur.expect("(")
    var, labels, preds = _entity_body(cur)
    cur.expect(")")
    return NodePattern(var=var, labels=labels, predicates=preds)


def _edge(cur: _Cursor) -> EdgePattern:
    """``-[...]->`` or ``<-[...]-`` (the only two directed forms)."""
    kind, val, pos = cur.next()
    incoming = kind == "arrow_in"
    if not incoming and val != "-":
        raise ParseError(f"expected edge, found {val!r} at position {pos} in {cur.text!r}")
    cur.expect("[")
    var, rels, preds = _entity_body(cur)
    cur.expect("]")
    if incoming:
        cur.expect("-")
    else:
        kind, val, pos = cur.next()
        if kind != "arrow_out":
            raise ParseError(
                f"expected '->' closing an edge, found {val!r} at position {pos} "
                f"in {cur.text!r}"
            )
    return EdgePattern(var=var, rels=rels, predicates=preds, direction=-1 if incoming else 1)


def parse(text: str) -> Pattern:
    """Parse a pattern string into a :class:`Pattern` AST."""
    cur = _Cursor(text)
    nodes = [_node(cur)]
    edges = []
    while cur.peek() is not None:
        edges.append(_edge(cur))
        nodes.append(_node(cur))
    return Pattern(nodes=tuple(nodes), edges=tuple(edges))
